"""CSR/COO graph structures and the standard GNN preprocessing transforms.

All host-side preprocessing is numpy/scipy (this mirrors the paper, which does
preprocessing on CPU and caches the result). Device-side code consumes padded
COO edge lists / CSR blocks with static shapes.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np
import scipy.sparse as sp


@dataclasses.dataclass
class CSRGraph:
    """A directed graph in CSR form with optional edge weights.

    indptr:  (N+1,) int64
    indices: (E,)   int32 — column indices (out-neighbors)
    weights: (E,)   float32 or None
    """

    indptr: np.ndarray
    indices: np.ndarray
    weights: Optional[np.ndarray] = None

    @property
    def num_nodes(self) -> int:
        return len(self.indptr) - 1

    @property
    def num_edges(self) -> int:
        return len(self.indices)

    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr)

    def to_scipy(self) -> sp.csr_matrix:
        n = self.num_nodes
        w = self.weights if self.weights is not None else np.ones(self.num_edges, np.float32)
        return sp.csr_matrix((w, self.indices, self.indptr), shape=(n, n))

    @staticmethod
    def from_scipy(m: sp.spmatrix) -> "CSRGraph":
        m = m.tocsr()
        m.sort_indices()
        return CSRGraph(
            indptr=m.indptr.astype(np.int64),
            indices=m.indices.astype(np.int32),
            weights=m.data.astype(np.float32),
        )

    def neighbors(self, u: int) -> np.ndarray:
        return self.indices[self.indptr[u]: self.indptr[u + 1]]

    def to_coo(self) -> Tuple[np.ndarray, np.ndarray]:
        """Return (src, dst) int32 arrays."""
        src = np.repeat(np.arange(self.num_nodes, dtype=np.int32), self.degrees())
        return src, self.indices.copy()


def sorted_lookup(haystack: np.ndarray,
                  needles: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Positions of ``needles`` in the SORTED array ``haystack``.

    Returns ``(idx, hit)``: ``idx`` is clamped into range (meaningful only
    where ``hit``); ``hit`` marks needles actually present. The one home of
    the searchsorted + clamp + equality idiom (membership filters, routing
    patches, incremental-PPR row alignment) — the clamp guards the
    out-of-range searchsorted result and the equality test subsumes any
    ``pos < len`` check.
    """
    needles = np.asarray(needles)
    if len(haystack) == 0:
        return (np.zeros(needles.shape, np.int64),
                np.zeros(needles.shape, bool))
    idx = np.minimum(np.searchsorted(haystack, needles), len(haystack) - 1)
    return idx, haystack[idx] == needles


def coo_to_csr(src: np.ndarray, dst: np.ndarray, num_nodes: int,
               weights: Optional[np.ndarray] = None) -> CSRGraph:
    w = weights if weights is not None else np.ones(len(src), np.float32)
    m = sp.csr_matrix((w, (src, dst)), shape=(num_nodes, num_nodes))
    m.sum_duplicates()
    m.sort_indices()
    return CSRGraph.from_scipy(m)


def make_undirected(g: CSRGraph) -> CSRGraph:
    """A := max(A, A^T) with unit weights (paper: 'make the graph undirected')."""
    m = g.to_scipy()
    m = m.maximum(m.T)
    m.data[:] = 1.0
    return CSRGraph.from_scipy(m)


def add_self_loops(g: CSRGraph) -> CSRGraph:
    m = g.to_scipy().tolil()
    m.setdiag(1.0)
    return CSRGraph.from_scipy(m.tocsr())


def sym_normalize(g: CSRGraph) -> CSRGraph:
    """D^{-1/2} A D^{-1/2} (GCN normalization). Degrees from row sums."""
    m = g.to_scipy()
    deg = np.asarray(m.sum(axis=1)).ravel()
    dinv = np.where(deg > 0, 1.0 / np.sqrt(np.maximum(deg, 1e-12)), 0.0)
    m = sp.diags(dinv) @ m @ sp.diags(dinv)
    return CSRGraph.from_scipy(m.tocsr())


def row_normalize(g: CSRGraph) -> CSRGraph:
    """D^{-1} A (random-walk normalization, used by PPR)."""
    m = g.to_scipy()
    deg = np.asarray(m.sum(axis=1)).ravel()
    dinv = np.where(deg > 0, 1.0 / np.maximum(deg, 1e-12), 0.0)
    m = sp.diags(dinv) @ m
    return CSRGraph.from_scipy(m.tocsr())


def gcn_preprocess(g: CSRGraph) -> CSRGraph:
    """Paper App. B: undirected + self-loops + symmetric normalization.

    The normalization factors are GLOBAL and re-used inside every mini-batch
    (the paper found this as accurate and cheaper than per-batch renorm).
    """
    return sym_normalize(add_self_loops(make_undirected(g)))


def induced_subgraph(g: CSRGraph, nodes: np.ndarray) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Subgraph induced by `nodes` (sorted unique int array).

    Returns (src_local, dst_local, weights) with indices into `nodes`.
    Vectorized: slice CSR rows, filter columns by membership via searchsorted.
    """
    nodes = np.asarray(nodes)
    starts = g.indptr[nodes]
    ends = g.indptr[nodes + 1]
    counts = (ends - starts).astype(np.int64)
    # gather all candidate edges of the selected rows
    total = int(counts.sum())
    if total == 0:
        return (np.zeros(0, np.int32), np.zeros(0, np.int32), np.zeros(0, np.float32))
    # flat gather indices into g.indices
    offsets = np.repeat(starts, counts) + (
        np.arange(total, dtype=np.int64) - np.repeat(np.cumsum(counts) - counts, counts))
    cols = g.indices[offsets]
    rows_local = np.repeat(np.arange(len(nodes), dtype=np.int32), counts)
    w = g.weights[offsets] if g.weights is not None else np.ones(total, np.float32)
    # membership of cols in nodes
    pos, keep = sorted_lookup(nodes, cols)
    return rows_local[keep], pos[keep].astype(np.int32), w[keep].astype(np.float32)
