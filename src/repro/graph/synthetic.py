"""Synthetic homophilic graph datasets (ogbn-* stand-ins for the offline box).

We need datasets with the qualitative properties the paper exploits:
homophily (nearby nodes share labels), power-ish degree distribution, low
label rates, and sizes large enough that batching matters on 1 CPU core.

Generator: degree-corrected stochastic block model (DC-SBM).
  - K communities = K classes (homophily by construction).
  - node degrees ~ lognormal (heavy tail like citation/co-purchase graphs).
  - features = class centroid + Gaussian noise, so a GNN that aggregates
    neighborhoods genuinely benefits from more relevant auxiliary nodes —
    which is exactly what IBMB's influence selection is supposed to buy.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.graph.csr import CSRGraph, coo_to_csr, make_undirected


@dataclasses.dataclass
class SyntheticSpec:
    name: str
    num_nodes: int
    num_classes: int
    avg_degree: float
    feat_dim: int
    homophily: float      # probability an edge endpoint is intra-community
    train_frac: float
    val_frac: float
    test_frac: float
    noise: float = 1.0
    seed: int = 0


# Scaled-down analogues of the paper's four datasets (name → spirit):
#   arxiv-like:    ~20k nodes, deg 7,  40 classes, 54% labeled (ogbn-arxiv has 91k/169k train)
#   products-like: ~50k nodes, deg 25, 47 classes, 8% train (ogbn-products 197k/2.4M)
#   reddit-like:   ~30k nodes, deg 50, 41 classes, 66% train
#   papers-like:   ~200k nodes, deg 10, 64 classes, 0.6% train (ogbn-papers100M: 1.2M/111M)
DATASET_SPECS: Dict[str, SyntheticSpec] = {
    "arxiv-like": SyntheticSpec("arxiv-like", 20_000, 40, 7.0, 128, 0.88, 0.54, 0.17, 0.29, seed=1),
    "products-like": SyntheticSpec("products-like", 50_000, 47, 25.0, 100, 0.90, 0.08, 0.02, 0.90, seed=2),
    "reddit-like": SyntheticSpec("reddit-like", 30_000, 41, 50.0, 128, 0.85, 0.66, 0.10, 0.24, seed=3),
    "papers-like": SyntheticSpec("papers-like", 200_000, 64, 10.0, 64, 0.90, 0.006, 0.003, 0.05, seed=4),
    # tiny configs for unit tests / smoke
    "tiny": SyntheticSpec("tiny", 400, 5, 6.0, 16, 0.9, 0.5, 0.2, 0.3, seed=5),
    "small": SyntheticSpec("small", 3_000, 10, 8.0, 32, 0.88, 0.3, 0.2, 0.5, seed=6),
}


def _sample_dcsbm_edges(spec: SyntheticSpec, rng: np.random.Generator):
    """Sample a degree-corrected SBM edge list.

    We sample E ≈ N·avg_degree/2 undirected edges. For each edge: pick the
    source by degree-propensity; intra-community with prob `homophily`
    (target from same block, degree-weighted), else uniform block.
    """
    n, k = spec.num_nodes, spec.num_classes
    labels = rng.integers(0, k, size=n)
    # heavy-tailed degree propensity
    theta = rng.lognormal(mean=0.0, sigma=1.0, size=n)
    # group nodes by block for fast intra-block sampling
    order = np.argsort(labels, kind="stable")
    sorted_labels = labels[order]
    block_starts = np.searchsorted(sorted_labels, np.arange(k))
    block_ends = np.searchsorted(sorted_labels, np.arange(k), side="right")
    block_nodes = [order[block_starts[b]:block_ends[b]] for b in range(k)]
    block_probs = []
    for b in range(k):
        p = theta[block_nodes[b]]
        s = p.sum()
        block_probs.append(p / s if s > 0 else None)

    num_edges = int(n * spec.avg_degree / 2)
    p_global = theta / theta.sum()
    src = rng.choice(n, size=num_edges, p=p_global)
    intra = rng.random(num_edges) < spec.homophily
    dst = np.empty(num_edges, dtype=np.int64)
    # intra-block targets (vectorized per block)
    for b in range(k):
        mask = intra & (labels[src] == b)
        cnt = int(mask.sum())
        if cnt and len(block_nodes[b]):
            dst[mask] = rng.choice(block_nodes[b], size=cnt, p=block_probs[b])
        elif cnt:
            dst[mask] = rng.choice(n, size=cnt, p=p_global)
    # inter-block targets: global degree-weighted
    mask = ~intra | (dst == 0) & False  # just ~intra; keep line simple
    mask = ~intra
    cnt = int(mask.sum())
    if cnt:
        dst[mask] = rng.choice(n, size=cnt, p=p_global)
    keep = src != dst
    return src[keep].astype(np.int32), dst[keep].astype(np.int32), labels.astype(np.int32)


def make_sbm_dataset(spec: SyntheticSpec):
    """Build (graph, features, labels, splits) for a spec. Deterministic per seed."""
    rng = np.random.default_rng(spec.seed)
    src, dst, labels = _sample_dcsbm_edges(spec, rng)
    g = coo_to_csr(src, dst, spec.num_nodes)
    g = make_undirected(g)

    # class-centroid features + noise
    centroids = rng.normal(size=(spec.num_classes, spec.feat_dim)).astype(np.float32)
    feats = centroids[labels] + spec.noise * rng.normal(
        size=(spec.num_nodes, spec.feat_dim)).astype(np.float32)

    # splits
    perm = rng.permutation(spec.num_nodes)
    n_tr = int(spec.train_frac * spec.num_nodes)
    n_va = int(spec.val_frac * spec.num_nodes)
    n_te = int(spec.test_frac * spec.num_nodes)
    splits = {
        "train": np.sort(perm[:n_tr]).astype(np.int32),
        "val": np.sort(perm[n_tr:n_tr + n_va]).astype(np.int32),
        "test": np.sort(perm[n_tr + n_va:n_tr + n_va + n_te]).astype(np.int32),
    }
    return g, feats, labels, splits
