"""Baseline mini-batching methods the paper compares against (Sec. 5).

All produce the same PaddedBatch format as IBMB so that model/trainer code is
shared and the comparison is fair (paper: "the same training pipeline for all
methods"). Methods that resample per epoch are flagged `fixed = False` — their
per-epoch resampling cost is exactly the overhead the paper attributes to
them; we measure it in the benchmarks.

* NeighborSampling  — GraphSAGE [21]: per-layer fanout sampling per output.
* LADIES            — [42]: layer-dependent importance sampling (per-batch
                      node budget per layer; we take the union of layer
                      samples and run on the induced subgraph — faithful to
                      the shared-activation structure at subgraph level).
* GraphSAINT-RW     — [40]: random-walk sampled subgraphs; outputs = training
                      nodes inside the sample.
* ClusterGCN        — [7]: fixed graph partitions; aux = partition itself
                      (no influence-based aux selection — the ablation IBMB
                      beats).
* ShadowPPR         — [41]: per-output top-k PPR subgraphs, batched randomly
                      WITHOUT output partitioning; per-node subgraphs are
                      disjoint copies (duplicated computation — its known
                      cost).
* FullBatch         — chunked full-graph inference baseline.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.graph.csr import CSRGraph, induced_subgraph
from repro.graph.datasets import GraphDataset
from repro.core.batches import PaddedBatch, build_batches
from repro.core.ppr import push_appr, TopKPPR
from repro.core.partition import graph_partition, random_partition


class Batcher:
    """Interface: `epoch_batches(rng_epoch)` returns the batch list; `fixed`
    tells the trainer whether re-generation per epoch is required."""

    fixed: bool = True
    name: str = "batcher"

    def __init__(self, ds: GraphDataset, split: str = "train"):
        self.ds = ds
        self.split = split
        self.outputs = ds.splits[split]

    def epoch_batches(self, epoch: int = 0) -> List[PaddedBatch]:
        raise NotImplementedError

    # shape caps shared across epochs so one executable serves all epochs
    _caps = None

    def _build(self, parts, aux) -> List[PaddedBatch]:
        pad = 128
        if self._caps is None:
            batches = build_batches(self.ds.norm_graph, self.ds.features,
                                    self.ds.labels, parts, aux, pad_multiple=pad)
            b0 = batches[0]
            # leave headroom for resampling variance
            self._caps = (int(b0.node_ids.shape[0] * 1.5) // pad * pad + pad,
                          int(b0.edge_src.shape[0] * 1.5) // pad * pad + pad,
                          b0.output_idx.shape[0])
            return batches
        mn, me, mo = self._caps
        return build_batches(self.ds.norm_graph, self.ds.features,
                             self.ds.labels, parts, aux, pad_multiple=pad,
                             max_nodes=mn, max_edges=me, max_outputs=mo)


class NeighborSampling(Batcher):
    fixed = False
    name = "neighbor_sampling"

    def __init__(self, ds: GraphDataset, split: str = "train",
                 num_batches: int = 12, fanouts: Sequence[int] = (6, 5, 5),
                 seed: int = 0):
        super().__init__(ds, split)
        self.num_batches = num_batches
        self.fanouts = list(fanouts)
        self.seed = seed

    def epoch_batches(self, epoch: int = 0) -> List[PaddedBatch]:
        rng = np.random.default_rng(self.seed + epoch)
        perm = rng.permutation(self.outputs)
        parts = [np.sort(c).astype(np.int32)
                 for c in np.array_split(perm, self.num_batches) if len(c)]
        aux = []
        g = self.ds.graph
        for batch in parts:
            frontier = batch
            nodes = [batch.astype(np.int64)]
            for fanout in self.fanouts:
                nxt = []
                for u in frontier:
                    nb = g.neighbors(int(u))
                    if len(nb) > fanout:
                        nb = rng.choice(nb, size=fanout, replace=False)
                    nxt.append(nb.astype(np.int64))
                frontier = np.unique(np.concatenate(nxt)) if nxt else np.zeros(0, np.int64)
                nodes.append(frontier)
            aux.append(np.unique(np.concatenate(nodes)).astype(np.int32))
        return self._build(parts, aux)


class Ladies(Batcher):
    fixed = False
    name = "ladies"

    def __init__(self, ds: GraphDataset, split: str = "train",
                 num_batches: int = 12, nodes_per_layer: int = 2048,
                 num_layers: int = 3, seed: int = 0):
        super().__init__(ds, split)
        self.num_batches = num_batches
        self.npl = nodes_per_layer
        self.num_layers = num_layers
        self.seed = seed
        # column-squared-norm importance ∝ Σ_u A_uv² (precomputed once)
        m = ds.norm_graph.to_scipy()
        self.col_imp = np.asarray(m.multiply(m).sum(axis=0)).ravel() + 1e-12
        self.csc = m.tocsc()

    def epoch_batches(self, epoch: int = 0) -> List[PaddedBatch]:
        rng = np.random.default_rng(self.seed + epoch)
        perm = rng.permutation(self.outputs)
        parts = [np.sort(c).astype(np.int32)
                 for c in np.array_split(perm, self.num_batches) if len(c)]
        aux = []
        m = self.ds.norm_graph.to_scipy()
        for batch in parts:
            layers = [batch.astype(np.int64)]
            rows = batch
            for _ in range(self.num_layers):
                # candidate columns restricted to rows' neighborhoods
                sub = m[rows]
                cand = np.unique(sub.indices)
                if len(cand) == 0:
                    break
                p = self.col_imp[cand]
                p = p / p.sum()
                k = min(self.npl, len(cand))
                sel = rng.choice(cand, size=k, replace=False, p=p)
                layers.append(sel.astype(np.int64))
                rows = sel
            aux.append(np.unique(np.concatenate(layers)).astype(np.int32))
        return self._build(parts, aux)


class GraphSaintRW(Batcher):
    fixed = False
    name = "graphsaint_rw"

    def __init__(self, ds: GraphDataset, split: str = "train",
                 num_steps: int = 8, batch_roots: int = 2000,
                 walk_length: int = 2, seed: int = 0):
        super().__init__(ds, split)
        self.num_steps = num_steps
        self.batch_roots = batch_roots
        self.walk_length = walk_length
        self.seed = seed
        self._train_mask = np.zeros(ds.num_nodes, bool)
        self._train_mask[self.outputs] = True

    def _walk(self, rng, roots: np.ndarray) -> np.ndarray:
        g = self.ds.graph
        nodes = [roots.astype(np.int64)]
        cur = roots
        for _ in range(self.walk_length):
            nxt = np.empty_like(cur)
            for i, u in enumerate(cur):
                nb = g.neighbors(int(u))
                nxt[i] = nb[rng.integers(len(nb))] if len(nb) else u
            nodes.append(nxt.astype(np.int64))
            cur = nxt
        return np.unique(np.concatenate(nodes))

    def epoch_batches(self, epoch: int = 0) -> List[PaddedBatch]:
        rng = np.random.default_rng(self.seed + epoch)
        parts, aux = [], []
        for _ in range(self.num_steps):
            roots = rng.choice(self.outputs, size=min(self.batch_roots, len(self.outputs)),
                               replace=False)
            sample = self._walk(rng, roots)
            outs = sample[self._train_mask[sample]]
            if len(outs) == 0:
                outs = roots[:1].astype(np.int64)
            parts.append(np.sort(outs).astype(np.int32))
            aux.append(sample.astype(np.int32))
        return self._build(parts, aux)


class ClusterGCN(Batcher):
    fixed = True
    name = "cluster_gcn"

    def __init__(self, ds: GraphDataset, split: str = "train",
                 num_batches: int = 8, method: str = "fennel", seed: int = 0):
        super().__init__(ds, split)
        from repro.core.partition import _fennel, _louvain  # reuse partitioners
        if method == "fennel":
            assign = _fennel(ds.graph, num_batches, seed=seed)
        else:
            assign = _louvain(ds.graph, seed=seed)
        parts, aux = [], []
        for p in np.unique(assign):
            members = np.where(assign == p)[0].astype(np.int32)
            outs = members[np.isin(members, self.outputs)]
            if len(outs) == 0:
                continue
            parts.append(np.sort(outs))
            aux.append(members)     # aux = whole partition (no influence sel.)
        self._batches = self._build(parts, aux)

    def epoch_batches(self, epoch: int = 0) -> List[PaddedBatch]:
        return self._batches


class ShadowPPR(Batcher):
    fixed = True
    name = "shadow_ppr"

    def __init__(self, ds: GraphDataset, split: str = "train",
                 k: int = 16, outputs_per_batch: int = 256,
                 alpha: float = 0.25, eps: float = 2e-4, seed: int = 0):
        super().__init__(ds, split)
        ppr = push_appr(ds.graph, self.outputs, alpha=alpha, eps=eps,
                        max_iters=3, topk=k)
        rng = np.random.default_rng(seed)
        perm = rng.permutation(len(self.outputs))
        nb = max(1, len(self.outputs) // outputs_per_batch)
        groups = np.array_split(perm, nb)
        # Disjoint-union batches: each output node's subgraph is its own copy.
        self._batches = []
        raw = []
        for grp in groups:
            # build one disjoint union graph per group
            all_nodes, all_src, all_dst, all_w, out_local, out_ids = [], [], [], [], [], []
            offset = 0
            for gi in grp:
                nodes, _ = ppr.row(gi)
                nodes = np.unique(np.concatenate([nodes, [ppr.roots[gi]]])).astype(np.int64)
                src, dst, w = induced_subgraph(ds.norm_graph, nodes)
                all_nodes.append(nodes)
                all_src.append(src + offset)
                all_dst.append(dst + offset)
                all_w.append(w)
                out_local.append(offset + int(np.searchsorted(nodes, ppr.roots[gi])))
                out_ids.append(int(ppr.roots[gi]))
                offset += len(nodes)
            raw.append((np.concatenate(all_nodes), np.concatenate(all_src),
                        np.concatenate(all_dst), np.concatenate(all_w),
                        np.array(out_local, np.int32), np.array(out_ids, np.int64)))
        pad = 128
        mn = max(len(r[0]) for r in raw); mn = (mn + pad - 1) // pad * pad
        me = max(len(r[1]) for r in raw); me = (me + pad - 1) // pad * pad
        mo = max(len(r[4]) for r in raw); mo = (mo + pad - 1) // pad * pad
        for nodes, src, dst, w, out_local, out_ids in raw:
            nn, ne, no = len(nodes), len(src), len(out_local)
            node_ids = np.full(mn, -1, np.int32); node_ids[:nn] = nodes
            node_mask = np.zeros(mn, bool); node_mask[:nn] = True
            es = np.zeros(me, np.int32); ed = np.zeros(me, np.int32)
            ew = np.zeros(me, np.float32); em = np.zeros(me, bool)
            es[:ne] = src; ed[:ne] = dst; ew[:ne] = w; em[:ne] = True
            oi = np.full(mo, -1, np.int32); oi[:no] = out_local
            om = np.zeros(mo, bool); om[:no] = True
            lab = np.zeros(mo, np.int32); lab[:no] = ds.labels[out_ids]
            feats = np.zeros((mn, ds.features.shape[1]), np.float32)
            feats[:nn] = ds.features[nodes]
            self._batches.append(PaddedBatch(node_ids, node_mask, es, ed, ew, em,
                                             oi, om, feats, lab))

    def epoch_batches(self, epoch: int = 0) -> List[PaddedBatch]:
        return self._batches


class FullBatch(Batcher):
    """Whole graph as one batch (chunked on GPU in the paper; one padded batch
    here). Used for 'full-batch inference' comparisons."""
    fixed = True
    name = "full_batch"

    def __init__(self, ds: GraphDataset, split: str = "train"):
        super().__init__(ds, split)
        all_nodes = np.arange(ds.num_nodes, dtype=np.int32)
        self._batches = build_batches(
            ds.norm_graph, ds.features, ds.labels,
            [self.outputs], [all_nodes], pad_multiple=128)

    def epoch_batches(self, epoch: int = 0) -> List[PaddedBatch]:
        return self._batches


def make_batcher(name: str, ds: GraphDataset, split: str = "train", **kw) -> Batcher:
    cls = {
        "neighbor_sampling": NeighborSampling,
        "ladies": Ladies,
        "graphsaint_rw": GraphSaintRW,
        "cluster_gcn": ClusterGCN,
        "shadow_ppr": ShadowPPR,
        "full_batch": FullBatch,
    }[name]
    return cls(ds, split, **kw)
