"""Graph substrate: sparse structures, synthetic datasets, baseline batchers."""
from repro.graph.csr import CSRGraph, coo_to_csr, make_undirected, add_self_loops, sym_normalize
from repro.graph.synthetic import make_sbm_dataset, DATASET_SPECS
from repro.graph.datasets import get_dataset, GraphDataset

__all__ = [
    "CSRGraph", "coo_to_csr", "make_undirected", "add_self_loops", "sym_normalize",
    "make_sbm_dataset", "DATASET_SPECS", "get_dataset", "GraphDataset",
]
