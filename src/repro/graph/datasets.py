"""Dataset registry with on-disk caching.

Mirrors the paper's workflow: expensive preprocessing (graph build, PPR) is
done once, cached, and re-used across runs/models/seeds.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, Optional

import numpy as np

from repro.graph.csr import CSRGraph, gcn_preprocess
from repro.graph.synthetic import DATASET_SPECS, make_sbm_dataset

_CACHE_DIR = os.environ.get("REPRO_DATA_DIR", "/root/repo/.data_cache")


@dataclasses.dataclass
class GraphDataset:
    name: str
    graph: CSRGraph             # raw undirected graph (unit weights)
    norm_graph: CSRGraph        # GCN-normalized (self-loops, sym-norm)
    features: np.ndarray        # (N, F) float32
    labels: np.ndarray          # (N,) int32
    splits: Dict[str, np.ndarray]

    @property
    def num_nodes(self) -> int:
        return self.graph.num_nodes

    @property
    def num_classes(self) -> int:
        return int(self.labels.max()) + 1

    @property
    def feat_dim(self) -> int:
        return self.features.shape[1]


_MEMO: Dict[str, GraphDataset] = {}


def get_dataset(name: str, cache: bool = True) -> GraphDataset:
    if name in _MEMO:
        return _MEMO[name]
    spec = DATASET_SPECS[name]
    path = os.path.join(_CACHE_DIR, f"{name}-v1.npz")
    if cache and os.path.exists(path):
        z = np.load(path, allow_pickle=False)
        g = CSRGraph(z["indptr"], z["indices"], z["weights"])
        ng = CSRGraph(z["n_indptr"], z["n_indices"], z["n_weights"])
        ds = GraphDataset(name, g, ng, z["features"], z["labels"],
                          {"train": z["train"], "val": z["val"], "test": z["test"]})
    else:
        g, feats, labels, splits = make_sbm_dataset(spec)
        ng = gcn_preprocess(g)
        ds = GraphDataset(name, g, ng, feats, labels, splits)
        if cache:
            os.makedirs(_CACHE_DIR, exist_ok=True)
            np.savez_compressed(
                path,
                indptr=g.indptr, indices=g.indices,
                weights=g.weights if g.weights is not None else np.ones(g.num_edges, np.float32),
                n_indptr=ng.indptr, n_indices=ng.indices, n_weights=ng.weights,
                features=ds.features, labels=ds.labels,
                train=splits["train"], val=splits["val"], test=splits["test"])
    _MEMO[name] = ds
    return ds
