"""Prefetching host→device loader over the precomputed batch cache.

The paper fully pipelines data loading by prefetching the next batch in
parallel (Sec. 5) and observes that ONE worker suffices because loading is
memory-bandwidth-bound. We reproduce exactly that: one background thread
stages batch t+1 onto the device while step t computes — with IBMB's
contiguous cache a stage is a single sequential read + DMA.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional, Sequence

import jax
import numpy as np


def device_put_batch(batch: Dict[str, np.ndarray], device=None):
    return {k: jax.device_put(v, device) for k, v in batch.items()}


class PrefetchLoader:
    """Iterate device-resident batches in `order`, prefetch depth 1 (paper:
    more workers don't help — memory bandwidth is shared)."""

    def __init__(self, batches: Sequence[Dict[str, np.ndarray]],
                 order: Optional[np.ndarray] = None, device=None,
                 prefetch: int = 1):
        self.batches = batches
        self.order = np.arange(len(batches)) if order is None else order
        self.device = device
        self.prefetch = max(1, prefetch)

    def __len__(self) -> int:
        return len(self.order)

    def __iter__(self) -> Iterator:
        q: "queue.Queue" = queue.Queue(maxsize=self.prefetch)
        stop = object()

        def worker():
            for i in self.order:
                q.put(device_put_batch(self.batches[int(i)], self.device))
            q.put(stop)

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        while True:
            item = q.get()
            if item is stop:
                break
            yield item
        t.join()
