"""Prefetching host→device loader over the precomputed batch cache.

The paper fully pipelines data loading by prefetching the next batch in
parallel (Sec. 5) and observes that ONE worker suffices because loading is
memory-bandwidth-bound. We reproduce exactly that: one background thread
stages batch t+1 onto the device while step t computes — with IBMB's
contiguous cache a stage is a single sequential read + DMA.

Out-of-core plans stream through the SAME loader (DESIGN.md §13): a Plan
backed by ``repro.ooc.LazyBatchCache`` stages each batch (and, via the
cache's ``stack`` hook, each super-step) through the checksum-verified
lazy read with a bounded resident-batch budget, so one worker prefetching
batch/super-step t+1 from disk while step t computes holds O(budget) batch
payload — the paper's pipelining argument, applied to graphs bigger than
RAM.

Shutdown is sentinel/Event based: a consumer that abandons the iterator
early (break, exception, GC) triggers the generator's ``finally``, which
sets the cancel event; the worker only ever blocks on ``q.put`` with a
timeout and re-checks the event, so it can never be left stranded on a
full queue and the thread always joins.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional, Sequence

import jax
import numpy as np

from repro.faults import NO_FAULTS

_STOP = object()


def device_put_batch(batch: Dict[str, np.ndarray], device=None):
    return {k: jax.device_put(v, device) for k, v in batch.items()}


class PrefetchLoader:
    """Iterate device-resident batches in `order`, prefetch depth 1 (paper:
    more workers don't help — memory bandwidth is shared).

    `batches` is anything indexable that yields device-array dicts: a raw
    list, a `BatchCache`, or a `Plan` (DESIGN.md §8) — a Plan is staged
    straight from its contiguous cache and, when no explicit `order` is
    given, iterated in the plan's precomputed schedule order.

    `group` switches to super-step staging (DESIGN.md §9): the loader
    yields `(stacked_batch, weights)` pairs of `group` batches each —
    every field gains a leading axis of length `group`, the ragged tail
    repeats the last real batch with weight 0 — and `device` may be a
    `jax.sharding.Sharding` (e.g. the executor's data-axis sharding), so
    the stack + sharded device_put of super-step t+1 overlaps with the
    shard_map compute of super-step t."""

    def __init__(self, batches,
                 order: Optional[np.ndarray] = None, device=None,
                 prefetch: int = 1, group: Optional[int] = None,
                 faults=NO_FAULTS):
        plan_schedule = getattr(batches, "schedule", None)
        cache = getattr(batches, "cache", None)
        if cache is not None:                    # Plan → its contiguous cache
            batches = cache
        if order is None:
            order = np.asarray(plan_schedule) if plan_schedule is not None \
                else np.arange(len(batches))
        order = np.asarray(order)
        # Fail in the caller, not the worker thread: a schedule carried over
        # from a DIFFERENT plan version can reference batches this container
        # no longer holds (refreshed plans may shrink, DESIGN.md §10), and
        # an IndexError raised mid-prefetch surfaces as a cryptic re-raise.
        if len(order) and (int(order.min()) < 0
                           or int(order.max()) >= len(batches)):
            raise IndexError(
                f"order references batch {int(order.max())} but the "
                f"container holds {len(batches)} batches — is this schedule "
                f"from a different (e.g. pre-refresh) plan version?")
        self.batches = batches
        self.order = order
        self.device = device
        self.prefetch = max(1, prefetch)
        self.group = group
        self.faults = faults            # "loader" injection point (§12)
        self.failed: Optional[BaseException] = None   # last worker error
        self._worker: Optional[threading.Thread] = None  # most recent; tests

    def __len__(self) -> int:
        if self.group:
            return -(-len(self.order) // self.group)     # super-steps
        return len(self.order)

    def _items(self):
        """What the worker stages: per-batch dicts, or (stacked, weights)
        super-steps when `group` is set."""
        if not self.group:
            for i in self.order:
                self.faults.fire("loader")
                yield self.batches[int(i)]
            return
        from repro.dist.data_parallel import stack_batches, superstep_indices
        for idx, w in superstep_indices(self.order, self.group):
            self.faults.fire("loader")
            yield stack_batches(self.batches, idx), w

    def __iter__(self) -> Iterator:
        q: "queue.Queue" = queue.Queue(maxsize=self.prefetch)
        cancel = threading.Event()

        def put(item) -> bool:
            """Blocking put that aborts when the consumer cancels."""
            while not cancel.is_set():
                try:
                    q.put(item, timeout=0.05)
                    return True
                except queue.Full:
                    continue
            return False

        def worker():
            try:
                for item in self._items():
                    if cancel.is_set():
                        return
                    if isinstance(item, tuple):          # (stacked, weights)
                        item = (device_put_batch(item[0], self.device),
                                item[1])
                    else:
                        item = device_put_batch(item, self.device)
                    if not put(item):
                        return
                put(_STOP)
            except BaseException as e:   # surface in the consumer, never hang
                self.failed = e          # observable even if consumer is gone
                put(e)

        t = threading.Thread(target=worker, daemon=True)
        self._worker = t
        t.start()
        try:
            while True:
                item = q.get()
                if item is _STOP:
                    break
                if isinstance(item, BaseException):
                    raise item
                yield item
        finally:
            # reached on exhaustion AND on early exit (GeneratorExit)
            cancel.set()
            t.join(timeout=10.0)
