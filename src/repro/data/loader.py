"""Prefetching host→device loader over the precomputed batch cache.

The paper fully pipelines data loading by prefetching the next batch in
parallel (Sec. 5) and observes that ONE worker suffices because loading is
memory-bandwidth-bound. We reproduce exactly that: one background thread
stages batch t+1 onto the device while step t computes — with IBMB's
contiguous cache a stage is a single sequential read + DMA.

Shutdown is sentinel/Event based: a consumer that abandons the iterator
early (break, exception, GC) triggers the generator's ``finally``, which
sets the cancel event; the worker only ever blocks on ``q.put`` with a
timeout and re-checks the event, so it can never be left stranded on a
full queue and the thread always joins.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional, Sequence

import jax
import numpy as np

_STOP = object()


def device_put_batch(batch: Dict[str, np.ndarray], device=None):
    return {k: jax.device_put(v, device) for k, v in batch.items()}


class PrefetchLoader:
    """Iterate device-resident batches in `order`, prefetch depth 1 (paper:
    more workers don't help — memory bandwidth is shared).

    `batches` is anything indexable that yields device-array dicts: a raw
    list, a `BatchCache`, or a `Plan` (DESIGN.md §8) — a Plan is staged
    straight from its contiguous cache and, when no explicit `order` is
    given, iterated in the plan's precomputed schedule order."""

    def __init__(self, batches,
                 order: Optional[np.ndarray] = None, device=None,
                 prefetch: int = 1):
        plan_schedule = getattr(batches, "schedule", None)
        cache = getattr(batches, "cache", None)
        if cache is not None:                    # Plan → its contiguous cache
            batches = cache
        if order is None:
            order = np.asarray(plan_schedule) if plan_schedule is not None \
                else np.arange(len(batches))
        self.batches = batches
        self.order = order
        self.device = device
        self.prefetch = max(1, prefetch)
        self._worker: Optional[threading.Thread] = None  # most recent; tests

    def __len__(self) -> int:
        return len(self.order)

    def __iter__(self) -> Iterator:
        q: "queue.Queue" = queue.Queue(maxsize=self.prefetch)
        cancel = threading.Event()

        def put(item) -> bool:
            """Blocking put that aborts when the consumer cancels."""
            while not cancel.is_set():
                try:
                    q.put(item, timeout=0.05)
                    return True
                except queue.Full:
                    continue
            return False

        def worker():
            try:
                for i in self.order:
                    if cancel.is_set():
                        return
                    if not put(device_put_batch(self.batches[int(i)],
                                                self.device)):
                        return
                put(_STOP)
            except BaseException as e:   # surface in the consumer, never hang
                put(e)

        t = threading.Thread(target=worker, daemon=True)
        self._worker = t
        t.start()
        try:
            while True:
                item = q.get()
                if item is _STOP:
                    break
                if isinstance(item, BaseException):
                    raise item
                yield item
        finally:
            # reached on exhaustion AND on early exit (GeneratorExit)
            cancel.set()
            t.join(timeout=10.0)
