from repro.data.loader import PrefetchLoader, device_put_batch

__all__ = ["PrefetchLoader", "device_put_batch"]
