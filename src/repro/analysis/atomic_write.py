"""Atomic-write checker (DESIGN.md §12/§13/§15).

Every persisted artifact — plans, plan stores, checkpoints, bench
trajectory JSONs — must be published with the tmp + ``os.replace``
idiom (``repro.ioutil``): readers see the old file or the new one,
never a truncated in-between, and a crash mid-write leaves no commit
point behind.

The rule flags write-mode ``open()`` calls in artifact-producing scopes
unless the write demonstrably flows through the idiom: the enclosing
function is an ``atomic_*`` helper, calls one, or calls
``os.replace``/``os.rename`` itself — or (for streaming writers like
``PlanStoreWriter``, whose commit point is a later ``finalize``) some
method of the enclosing class does.
"""
from __future__ import annotations

import ast
from typing import List, Optional

from repro.analysis.model import Checker, Finding, Module, Project, call_name

RULE = "atomic-write"

SCOPE_PREFIXES = ("src/repro/checkpoint/", "src/repro/ooc/", "benchmarks/")
SCOPE_FILES = ("src/repro/core/plan.py",)

_PUBLISH_CALLS = {"os.replace", "os.rename"}


def in_scope(relpath: str) -> bool:
    return relpath.startswith(SCOPE_PREFIXES) or relpath in SCOPE_FILES


def _write_mode(node: ast.Call) -> Optional[str]:
    """The mode string of an `open()` call iff it writes; None for reads
    or non-literal modes (which we cannot judge statically)."""
    mode_node = None
    if len(node.args) >= 2:
        mode_node = node.args[1]
    for kw in node.keywords:
        if kw.arg == "mode":
            mode_node = kw.value
    if isinstance(mode_node, ast.Constant) and isinstance(mode_node.value,
                                                          str):
        mode = mode_node.value
        if any(c in mode for c in "wax+"):
            return mode
    return None


def _publishes(tree: ast.AST) -> bool:
    """True if any call inside ``tree`` is os.replace/os.rename or an
    atomic_* helper."""
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            name = call_name(node)
            if name in _PUBLISH_CALLS or "atomic" in name.split(".")[-1]:
                return True
    return False


class AtomicWriteChecker(Checker):
    name = "atomic-write"
    rules = (RULE,)

    def run(self, project: Project) -> List[Finding]:
        out: List[Finding] = []
        for mod in project.iter_modules(in_scope):
            out.extend(self._check_module(mod))
        return out

    def _check_module(self, mod: Module) -> List[Finding]:
        out: List[Finding] = []
        # parent chain: for each write-open, find enclosing function+class
        stack: List[ast.AST] = []

        def visit(node: ast.AST) -> None:
            if isinstance(node, ast.Call) and call_name(node) == "open":
                mode = _write_mode(node)
                if mode is not None and not self._sanctioned(stack):
                    out.append(Finding(
                        RULE, mod.relpath, node.lineno,
                        f"write-mode open(..., {mode!r}) on an artifact "
                        "path without a tmp + os.replace publish — route "
                        "it through repro.ioutil (atomic_write_text / "
                        "atomic_write_json / atomic_savez)"))
            stack.append(node)
            for child in ast.iter_child_nodes(node):
                visit(child)
            stack.pop()

        visit(mod.tree)
        return out

    @staticmethod
    def _sanctioned(stack: List[ast.AST]) -> bool:
        fn = next((n for n in reversed(stack)
                   if isinstance(n, (ast.FunctionDef,
                                     ast.AsyncFunctionDef))), None)
        if fn is not None:
            if "atomic" in fn.name:
                return True
            if _publishes(fn):
                return True
        cls = next((n for n in reversed(stack)
                    if isinstance(n, ast.ClassDef)), None)
        if cls is not None and _publishes(cls):
            # streaming writer: payload appends commit via a later
            # finalize() that publishes atomically
            return True
        return False
