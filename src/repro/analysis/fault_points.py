"""Fault-point registry checker (DESIGN.md §12/§15).

``repro.faults.FAULT_POINTS`` is the canonical registry of injection
point names. This checker parses it STATICALLY (never imports repo
code) and enforces, in both directions:

* every point name passed to ``fire``/``delay``/``should_fire`` on a
  fault-injector receiver, and every key of a ``rates=``/``script=``
  dict literal at a ``FaultInjector(...)`` construction, is registered;
* point names at injection sites are string literals (a computed name
  cannot be checked against the registry);
* every registered point is actually used by at least one call site;
* the DESIGN.md §12 table lists exactly the registered points.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.model import Checker, Finding, Module, Project, call_name

RULE = "fault-point"

FAULTS_MODULE = "src/repro/faults.py"
DESIGN_FILE = "DESIGN.md"
DESIGN_SECTION = "12"

_FIRE_TAILS = ("fire", "should_fire", "delay")
_TABLE_ROW = re.compile(r"^\|\s*`([a-z_]+)`\s*\|", re.MULTILINE)
_SECTION_RE = re.compile(r"^##\s+§12\b.*?(?=^##\s+§|\Z)",
                         re.MULTILINE | re.DOTALL)


def registry_from_source(source: str) -> Optional[Dict[str, str]]:
    """Parse FAULT_POINTS out of faults.py source without importing it."""
    tree = ast.parse(source)
    for node in tree.body:
        targets = []
        if isinstance(node, ast.Assign):
            targets = node.targets
            value = node.value
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            targets = [node.target]
            value = node.value
        else:
            continue
        for t in targets:
            if isinstance(t, ast.Name) and t.id == "FAULT_POINTS":
                try:
                    reg = ast.literal_eval(value)
                except (ValueError, SyntaxError):
                    return None
                return reg if isinstance(reg, dict) else None
    return None


def design_table_points(design_text: str) -> Optional[Set[str]]:
    """Point names in the DESIGN.md §12 fault table, or None if the
    section is missing."""
    m = _SECTION_RE.search(design_text)
    if not m:
        return None
    return set(_TABLE_ROW.findall(m.group(0)))


def _point_calls(mod) -> List[Tuple[int, Optional[str], str]]:
    """(line, point-or-None, call-text) for every fault-injection call
    site in a module. ``point`` is None for non-literal names."""
    sites = []
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        name = call_name(node)
        tail = name.split(".")[-1] if name else ""
        receiver = name[:len(name) - len(tail) - 1] if "." in name else ""
        if tail in _FIRE_TAILS and "fault" in receiver.lower():
            if node.args and isinstance(node.args[0], ast.Constant) \
                    and isinstance(node.args[0].value, str):
                sites.append((node.lineno, node.args[0].value, name))
            else:
                sites.append((node.lineno, None, name))
        elif tail == "FaultInjector" or name == "FaultInjector":
            for kw in node.keywords:
                if kw.arg in ("rates", "script", "delays") \
                        and isinstance(kw.value, ast.Dict):
                    for k in kw.value.keys:
                        if isinstance(k, ast.Constant) \
                                and isinstance(k.value, str):
                            sites.append((k.lineno, k.value,
                                          f"FaultInjector({kw.arg}=)"))
    return sites


class FaultPointChecker(Checker):
    name = "fault-points"
    rules = (RULE,)

    def run(self, project: Project) -> List[Finding]:
        out: List[Finding] = []
        faults_mod = project.module(FAULTS_MODULE)
        if faults_mod is None:
            return out  # out-of-repo fixture project without faults.py
        registry = registry_from_source(faults_mod.source)
        if registry is None:
            return [Finding(RULE, FAULTS_MODULE, 1,
                            "no FAULT_POINTS literal dict found — the "
                            "canonical injection-point registry is gone")]

        used: Set[str] = set()
        for mod in project.iter_modules():
            if mod.relpath == FAULTS_MODULE:
                continue  # the injector's own should_fire(point) plumbing
            for line, point, text in _point_calls(mod):
                if point is None:
                    out.append(Finding(
                        RULE, mod.relpath, line,
                        f"`{text}` with a non-literal point name — "
                        "points must be string literals so the registry "
                        "stays statically checkable"))
                elif point not in registry:
                    out.append(Finding(
                        RULE, mod.relpath, line,
                        f"unregistered fault point `{point}` — add it to "
                        "repro.faults.FAULT_POINTS and the DESIGN.md "
                        "§12 table"))
                else:
                    used.add(point)

        for point in sorted(set(registry) - used):
            out.append(Finding(
                RULE, FAULTS_MODULE, 1,
                f"registered fault point `{point}` has no injection "
                "site in src/ or benchmarks/ — dead registry entry"))

        design = project.text(DESIGN_FILE)
        if design is not None:
            table = design_table_points(design)
            if table is None:
                out.append(Finding(RULE, DESIGN_FILE, 1,
                                   "DESIGN.md has no §12 fault table"))
            else:
                for point in sorted(set(registry) - table):
                    out.append(Finding(
                        RULE, DESIGN_FILE, 1,
                        f"registered point `{point}` missing from the "
                        "DESIGN.md §12 table"))
                for point in sorted(table - set(registry)):
                    out.append(Finding(
                        RULE, DESIGN_FILE, 1,
                        f"DESIGN.md §12 table lists `{point}` which is "
                        "not in repro.faults.FAULT_POINTS"))
        return out
