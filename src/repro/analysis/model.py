"""Shared project model for the invariant checkers (DESIGN.md §15).

A ``Project`` is a set of parsed ``Module`` trees keyed by repo-relative
path plus access to non-Python resources (DESIGN.md, tools). Checkers
are pure functions over that model: they never import repo code, so the
analyzer runs without jax/numpy installed and can never be confused by
import-time side effects.

Suppression has two layers, both explicit and reviewable:

* ``# lint: allow(<rule>[, <rule>...])`` on the offending line or on a
  comment-only line directly above it — for violations that are by
  design. Each allow should carry a justification comment.
* a checked-in baseline (``tools/analysis_baseline.json``) listing
  findings that predate a rule — shipped EMPTY and expected to stay so
  (real violations get fixed, not baselined).
"""
from __future__ import annotations

import ast
import dataclasses
import json
import os
import re
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

#: subdirectories of the repo root that are scanned for Python modules
DEFAULT_SUBDIRS = ("src", "benchmarks", "tools", "examples")

#: default location of the baseline file, relative to the repo root
BASELINE_RELPATH = "tools/analysis_baseline.json"

_ALLOW_RE = re.compile(r"#\s*lint:\s*allow\(([\w\s,-]+)\)")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a file:line."""
    rule: str
    path: str  # repo-relative, forward slashes
    line: int
    message: str
    severity: str = "error"  # "error" gates CI; "warning" is informational

    def key(self) -> Tuple[str, str, int]:
        return (self.rule, self.path, self.line)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


class Module:
    """One parsed Python source file: AST + per-line allowlist."""

    def __init__(self, relpath: str, source: str):
        self.relpath = relpath
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=relpath)
        # 1-based line -> set of rule names allowed on that line
        self.allow: Dict[int, set] = {}
        for i, line in enumerate(self.lines, 1):
            m = _ALLOW_RE.search(line)
            if m:
                rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
                if rules:
                    self.allow[i] = rules

    def _comment_only(self, lineno: int) -> bool:
        if not (1 <= lineno <= len(self.lines)):
            return False
        return self.lines[lineno - 1].lstrip().startswith("#")

    def allowed(self, rule: str, lineno: int) -> bool:
        """True if ``rule`` is suppressed at ``lineno`` — by a trailing
        ``# lint: allow(rule)`` on the same line, or by one on a
        comment-only line directly above."""
        if rule in self.allow.get(lineno, ()):
            return True
        prev = lineno - 1
        return (rule in self.allow.get(prev, ())
                and self._comment_only(prev))

    def allow_count(self, rule: str) -> int:
        """Number of allow annotations naming ``rule`` in this module."""
        return sum(1 for rules in self.allow.values() if rule in rules)


class Project:
    """All scanned modules plus lazy access to non-Python root files."""

    def __init__(self, root: Optional[str], modules: Dict[str, Module]):
        self.root = root
        self.modules = modules

    @classmethod
    def load(cls, root: str,
             subdirs: Sequence[str] = DEFAULT_SUBDIRS) -> "Project":
        modules: Dict[str, Module] = {}
        for sub in subdirs:
            base = os.path.join(root, sub)
            if not os.path.isdir(base):
                continue
            for dirpath, dirnames, filenames in os.walk(base):
                dirnames[:] = sorted(d for d in dirnames
                                     if not d.startswith(".")
                                     and d != "__pycache__")
                for fn in sorted(filenames):
                    if not fn.endswith(".py"):
                        continue
                    path = os.path.join(dirpath, fn)
                    rel = os.path.relpath(path, root).replace(os.sep, "/")
                    with open(path, "r", encoding="utf-8") as f:
                        modules[rel] = Module(rel, f.read())
        return cls(root, modules)

    @classmethod
    def from_sources(cls, sources: Dict[str, str],
                     root: Optional[str] = None) -> "Project":
        """Build a project from in-memory {relpath: source} — the fixture
        harness: known-bad snippets are mapped to virtual paths inside a
        checker's scope."""
        return cls(root, {rel: Module(rel, src)
                          for rel, src in sources.items()})

    def iter_modules(self, pred=None) -> Iterable[Module]:
        for rel in sorted(self.modules):
            if pred is None or pred(rel):
                yield self.modules[rel]

    def module(self, relpath: str) -> Optional[Module]:
        return self.modules.get(relpath)

    def text(self, relpath: str) -> Optional[str]:
        """Source of any root-relative file (e.g. DESIGN.md), whether or
        not it was scanned as a module."""
        mod = self.modules.get(relpath)
        if mod is not None:
            return mod.source
        if self.root is None:
            return None
        path = os.path.join(self.root, relpath.replace("/", os.sep))
        if not os.path.isfile(path):
            return None
        with open(path, "r", encoding="utf-8") as f:
            return f.read()


class Checker:
    """Protocol: a named rule family over a Project."""

    #: checker name, used for --only selection
    name: str = "?"
    #: rule identifiers this checker can emit (for allow() comments)
    rules: Sequence[str] = ()

    def run(self, project: Project) -> List[Finding]:
        raise NotImplementedError


# ---------------------------------------------------------------- helpers

def dotted_name(node: ast.AST) -> str:
    """Best-effort dotted text of a Name/Attribute chain: ``time.time``,
    ``self.faults.fire``, ``np.random.default_rng``. Empty string for
    anything that is not a plain attribute chain (calls, subscripts)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    elif parts:
        # chain rooted at a call/subscript — keep the attribute tail so
        # e.g. ``store.open(...).as_plan`` still reports ``.as_plan``
        parts.append("")
    else:
        return ""
    return ".".join(reversed(parts))


def call_name(node: ast.Call) -> str:
    return dotted_name(node.func)


def filter_allowed(findings: Iterable[Finding],
                   project: Project) -> Tuple[List[Finding], List[Finding]]:
    """Split findings into (kept, suppressed-by-allow-comment)."""
    kept: List[Finding] = []
    suppressed: List[Finding] = []
    for f in findings:
        mod = project.module(f.path)
        if mod is not None and mod.allowed(f.rule, f.line):
            suppressed.append(f)
        else:
            kept.append(f)
    return kept, suppressed


def load_baseline(path: str) -> List[dict]:
    """Baseline entries: [{"rule": ..., "path": ..., "line": ...}]. A
    missing file is an empty baseline."""
    if not os.path.isfile(path):
        return []
    with open(path, "r", encoding="utf-8") as f:
        data = json.load(f)
    entries = data.get("findings", []) if isinstance(data, dict) else data
    if not isinstance(entries, list):
        raise ValueError(f"baseline {path}: expected a list of findings")
    return entries


def filter_baselined(findings: Iterable[Finding],
                     baseline: Sequence[dict]
                     ) -> Tuple[List[Finding], List[Finding]]:
    """Split findings into (kept, matched-by-baseline). Baseline entries
    match on (rule, path) and, when present, line — line drift within a
    file does not resurrect a baselined finding."""
    kept: List[Finding] = []
    matched: List[Finding] = []
    for f in findings:
        hit = any(e.get("rule") == f.rule and e.get("path") == f.path
                  and ("line" not in e or e["line"] == f.line)
                  for e in baseline)
        (matched if hit else kept).append(f)
    return kept, matched
