"""jit-cache hygiene checker (DESIGN.md §14/§15).

``jax.jit`` returns a fresh executable cache: constructing one per
request or per loop iteration silently retraces and recompiles on every
call — the classic serving-tier performance rot. The sanctioned homes
are module level, ``__init__``/builder functions whose result is stored
(``_build_forward`` + the keyed executable cache in
``GNNInferenceEngine``), and decorators on module-level functions.

The rule flags any ``jax.jit`` (bare or via ``functools.partial``)
constructed inside a loop, or inside a per-request entry point
(``run``/``submit``/``query``/``dispatch``/``forward``/``__call__``).
"""
from __future__ import annotations

import ast
from typing import List

from repro.analysis.model import Checker, Finding, Module, Project, \
    call_name, dotted_name

RULE = "jit-cache"

SCOPE_PREFIXES = ("src/repro/",)

#: function names that run once per request / per step — a jit built
#: here is rebuilt on every call
PER_REQUEST = {"run", "submit", "query", "dispatch", "_dispatch",
               "forward", "__call__", "handle", "answer", "serve"}


def in_scope(relpath: str) -> bool:
    return relpath.startswith(SCOPE_PREFIXES)


def _mentions_jit(node: ast.AST) -> bool:
    """True for `jax.jit`, `jax.jit(...)`, or `partial(jax.jit, ...)`."""
    if isinstance(node, ast.Attribute):
        return dotted_name(node) == "jax.jit"
    if isinstance(node, ast.Call):
        name = call_name(node)
        if name == "jax.jit":
            return True
        if name in ("partial", "functools.partial") and node.args:
            return dotted_name(node.args[0]) == "jax.jit"
    return False


class JitCacheChecker(Checker):
    name = "jit-cache"
    rules = (RULE,)

    def run(self, project: Project) -> List[Finding]:
        out: List[Finding] = []
        for mod in project.iter_modules(in_scope):
            out.extend(self._check_module(mod))
        return out

    def _check_module(self, mod: Module) -> List[Finding]:
        out: List[Finding] = []
        stack: List[ast.AST] = []  # loops + functions enclosing the node

        def classify(node: ast.AST) -> str:
            in_loop = any(isinstance(n, (ast.For, ast.While))
                          for n in stack)
            if in_loop:
                return ("jax.jit constructed inside a loop — a fresh "
                        "executable cache (and a retrace+recompile) "
                        "every iteration")
            fn = next((n for n in reversed(stack)
                       if isinstance(n, (ast.FunctionDef,
                                         ast.AsyncFunctionDef))), None)
            if fn is not None and fn.name in PER_REQUEST:
                return (f"jax.jit constructed inside per-request entry "
                        f"point `{fn.name}()` — hoist to module level, "
                        "__init__, or a keyed executable cache "
                        "(the _build_forward idiom)")
            return ""

        def visit(node: ast.AST) -> None:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                # decorators evaluate in the ENCLOSING scope, not inside
                # the function they decorate
                for dec in node.decorator_list:
                    if _mentions_jit(dec):
                        msg = classify(dec)
                        if msg:
                            out.append(Finding(RULE, mod.relpath,
                                               dec.lineno, msg))
            elif _mentions_jit(node) and isinstance(node, ast.Call):
                msg = classify(node)
                if msg:
                    out.append(Finding(RULE, mod.relpath, node.lineno,
                                       msg))
            stack.append(node)
            for child in ast.iter_child_nodes(node):
                # skip decorator subtrees: handled above with the right
                # scope, and a second visit would double-report
                if isinstance(node, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)) \
                        and child in node.decorator_list:
                    continue
                visit(child)
            stack.pop()

        visit(mod.tree)
        return out
