"""Determinism checker (DESIGN.md §8/§14/§15).

Plan builds must be bitwise-reproducible: the plan fingerprint chains
config + payload, streamed builds must equal resident builds, and shard
manifests chain per-shard fingerprints. That dies silently the moment a
build path reads the wall clock into an artifact, draws from an
unseeded/global RNG, iterates a ``set`` into an array, or keys anything
on ``id()``/``hash()`` (both salted per process).

Scope: ``src/repro/core/`` plus the streaming build paths
``src/repro/ooc/stream.py`` and ``src/repro/ooc/shard.py``. Timing-only
wall-clock reads (bench counters that never feed an artifact) are
annotated ``# lint: allow(determinism)`` with a justification.
"""
from __future__ import annotations

import ast
from typing import List

from repro.analysis.model import (Checker, Finding, Module, Project,
                                  call_name)

RULE = "determinism"

SCOPE_PREFIXES = ("src/repro/core/",)
SCOPE_FILES = ("src/repro/ooc/stream.py", "src/repro/ooc/shard.py")

WALL_CLOCK = {
    "time.time", "time.time_ns", "time.monotonic", "time.monotonic_ns",
    "time.perf_counter", "time.perf_counter_ns", "time.process_time",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "datetime.date.today",
}

#: legacy numpy global-state RNG entry points (process-wide, unseeded by
#: default, order-dependent across call sites)
NP_GLOBAL_RNG = {
    "np.random." + fn for fn in (
        "seed", "rand", "randn", "randint", "random", "random_sample",
        "permutation", "shuffle", "choice", "normal", "uniform")
} | {
    "numpy.random." + fn for fn in (
        "seed", "rand", "randn", "randint", "random", "random_sample",
        "permutation", "shuffle", "choice", "normal", "uniform")
}

DEFAULT_RNG = {"np.random.default_rng", "numpy.random.default_rng"}


def in_scope(relpath: str) -> bool:
    return (relpath.startswith(SCOPE_PREFIXES) or relpath in SCOPE_FILES)


def _is_set_like(node: ast.AST) -> bool:
    """Direct set-valued expressions whose iteration order is salted."""
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call):
        return call_name(node) in ("set", "frozenset")
    return False


class DeterminismChecker(Checker):
    name = "determinism"
    rules = (RULE,)

    def run(self, project: Project) -> List[Finding]:
        out: List[Finding] = []
        for mod in project.iter_modules(in_scope):
            out.extend(self._check_module(mod))
        return out

    def _check_module(self, mod: Module) -> List[Finding]:
        out: List[Finding] = []

        def finding(node: ast.AST, msg: str) -> None:
            out.append(Finding(RULE, mod.relpath, node.lineno, msg))

        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call):
                name = call_name(node)
                if name in WALL_CLOCK:
                    finding(node,
                            f"wall-clock read `{name}()` in a fingerprinted "
                            "build path; if this is timing-only telemetry "
                            "that never feeds an artifact, annotate it "
                            "`# lint: allow(determinism)` with a "
                            "justification")
                elif name in DEFAULT_RNG and not node.args \
                        and not node.keywords:
                    finding(node,
                            "unseeded `np.random.default_rng()` — thread "
                            "the config seed through (the "
                            "`seed=cfg.seed` idiom in core/update.py)")
                elif name in NP_GLOBAL_RNG:
                    finding(node,
                            f"global-state RNG `{name}` — use a seeded "
                            "`np.random.default_rng(seed)` Generator "
                            "instead")
                elif name in ("id", "hash"):
                    finding(node,
                            f"`{name}()` is salted per process — never "
                            "stable across runs; key on content "
                            "(fingerprints, crc32) instead")
            elif isinstance(node, (ast.For, ast.comprehension)):
                it = node.iter
                if _is_set_like(it):
                    finding(it if hasattr(it, "lineno") else node,
                            "iterating a set: order is hash-salted per "
                            "process, so anything built from it is "
                            "non-reproducible — wrap in `sorted(...)`")
        return out
