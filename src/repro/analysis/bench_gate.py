"""Bench-gate cross-checker (DESIGN.md §15).

``tools/check_bench_json.py`` gates CI on named bench rows; the rows
are emitted by the modules under ``benchmarks/``. Nothing previously
tied the two together: renaming a row in a bench module silently turns
the CI gate into a tautology (or a permanent failure).

This checker parses the gate's required-row tables (``REQUIRED_ROWS``,
``REQUIRED_PREFIXES`` — one literal dict each, shared with the gate
logic itself) and verifies every required op name / prefix is emitted
somewhere under ``benchmarks/``. Ops built with f-strings
(``f"kernels/agg_e2e_{name}"``) are matched by their constant parts.
"""
from __future__ import annotations

import ast
import re
from typing import List, Optional, Set, Tuple

from repro.analysis.model import Checker, Finding, Module, Project

RULE = "bench-gate"

GATE_MODULE = "tools/check_bench_json.py"
BENCH_PREFIX = "benchmarks/"


def _literal_dict(source: str, name: str) -> Optional[dict]:
    tree = ast.parse(source)
    for node in tree.body:
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id == name:
                    try:
                        val = ast.literal_eval(node.value)
                    except (ValueError, SyntaxError):
                        return None
                    return val if isinstance(val, dict) else None
    return None


def emitted_patterns(mod: Module) -> Tuple[Set[str], List[Tuple[str, str]]]:
    """(exact string literals, [(regex, static_prefix)] for f-strings)
    for every op-shaped string in a bench module. Only strings with a
    '/' are considered — op names are namespaced ``table/row``."""
    exact: Set[str] = set()
    patterns: List[Tuple[str, str]] = []
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Constant) and isinstance(node.value, str):
            if "/" in node.value:
                exact.add(node.value)
        elif isinstance(node, ast.JoinedStr):
            parts = []
            prefix_parts = []
            prefix_open = True
            for v in node.values:
                if isinstance(v, ast.Constant) and isinstance(v.value, str):
                    parts.append(re.escape(v.value))
                    if prefix_open:
                        prefix_parts.append(v.value)
                else:
                    parts.append(".*")
                    prefix_open = False
            prefix = "".join(prefix_parts)
            if "/" in prefix:
                patterns.append(("".join(parts), prefix))
    return exact, patterns


class BenchGateChecker(Checker):
    name = "bench-gate"
    rules = (RULE,)

    def run(self, project: Project) -> List[Finding]:
        gate = project.module(GATE_MODULE) or None
        gate_src = gate.source if gate else project.text(GATE_MODULE)
        if gate_src is None:
            return []  # fixture project without a gate — nothing to check
        rows = _literal_dict(gate_src, "REQUIRED_ROWS")
        prefixes = _literal_dict(gate_src, "REQUIRED_PREFIXES")
        if rows is None or prefixes is None:
            return [Finding(
                RULE, GATE_MODULE, 1,
                "REQUIRED_ROWS / REQUIRED_PREFIXES literal tables not "
                "found — the gate's required rows are no longer "
                "statically checkable")]

        exact: Set[str] = set()
        patterns: List[Tuple[str, str]] = []
        for mod in project.iter_modules(
                lambda p: p.startswith(BENCH_PREFIX)):
            e, pats = emitted_patterns(mod)
            exact |= e
            patterns.extend(pats)

        out: List[Finding] = []
        for mode, ops in sorted(rows.items()):
            for op in ops:
                if op in exact:
                    continue
                if any(re.fullmatch(pat, op) for pat, _ in patterns):
                    continue
                out.append(Finding(
                    RULE, GATE_MODULE, 1,
                    f"required row `{op}` (mode {mode}) is never "
                    "emitted by any module under benchmarks/"))
        for mode, pres in sorted(prefixes.items()):
            for pre in pres:
                if any(lit.startswith(pre) for lit in exact):
                    continue
                if any(pre.startswith(sp) or sp.startswith(pre)
                       for _, sp in patterns):
                    continue
                out.append(Finding(
                    RULE, GATE_MODULE, 1,
                    f"required row prefix `{pre}` (mode {mode}) matches "
                    "nothing emitted under benchmarks/"))
        return out
