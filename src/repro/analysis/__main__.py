"""``python -m repro.analysis`` entry point (DESIGN.md §15)."""
import sys

from repro.analysis.cli import main

if __name__ == "__main__":
    sys.exit(main())
