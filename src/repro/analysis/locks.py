"""Lock-discipline checker for the threaded tiers (DESIGN.md §11/§12/§15).

Builds the lock-acquisition graph of every ``with <lock>:`` site across
the serving tier, the prefetch loader, and the elastic coordinator, then
enforces four rules:

* ``lock-order`` — the per-module acquisition graph must be acyclic: two
  functions that nest the same pair of locks in opposite orders can
  deadlock under concurrency.
* ``lock-blocking`` — no blocking call while a lock is held: file I/O,
  ``Future.result()``, thread joins, ``Event.wait``, jit compilation, or
  an engine ``run``/``swap`` (which jit-compiles on first use and may
  fault in out-of-core batches). Holding a lock across any of these
  stalls every thread behind it.
* ``condvar-wait`` — ``Condition.wait`` must sit inside a ``while``
  predicate loop: bare waits miss spurious wakeups and lost notifies.
* ``clock-injectable`` — threaded code never touches ``time.time`` /
  ``time.sleep`` directly; all timing flows through the injectable clock
  (``repro.serve.common.SystemClock`` / a ``clock=`` parameter) so the
  FakeClock test suite can drive it deterministically. The
  ``SystemClock`` class itself is the one sanctioned home for the real
  clock and is exempt by name.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.model import (Checker, Finding, Module, Project,
                                  call_name, dotted_name)

RULE_ORDER = "lock-order"
RULE_BLOCKING = "lock-blocking"
RULE_CONDVAR = "condvar-wait"
RULE_CLOCK = "clock-injectable"

SCOPE_PREFIXES = ("src/repro/serve/",)
SCOPE_FILES = ("src/repro/data/loader.py", "src/repro/train/elastic.py")

#: attribute tails that mark a `with` context expression as a lock
_LOCK_TAIL = re.compile(r"(^|_)(lock|cond|condition|mutex)$")

#: calls that block (or can block unboundedly) regardless of receiver
_BLOCKING_CALLS = {
    "open", "os.replace", "os.rename", "os.fsync", "os.remove",
    "np.load", "np.save", "np.savez", "np.savez_compressed",
    "json.dump", "json.load", "shutil.rmtree", "shutil.copyfile",
    "time.sleep",
}

#: direct wall-clock references banned outside SystemClock
_CLOCK_REFS = {"time.time", "time.sleep", "time.monotonic",
               "time.perf_counter"}

_THREADISH = re.compile(r"thread|worker|proc|fut")


def in_scope(relpath: str) -> bool:
    return relpath.startswith(SCOPE_PREFIXES) or relpath in SCOPE_FILES


def lock_label(expr: ast.AST, class_name: str) -> Optional[str]:
    """Label of a lock-acquisition context expr, or None if the `with`
    item is not a lock. Labels are qualified by enclosing class so
    same-named locks on different objects don't alias in the graph."""
    name = dotted_name(expr)
    if not name:
        return None
    tail = name.split(".")[-1]
    if not _LOCK_TAIL.search(tail):
        return None
    local = name[5:] if name.startswith("self.") else name
    return f"{class_name or '<module>'}:{local}"


def _is_jit_call(node: ast.Call) -> bool:
    name = call_name(node)
    if name == "jax.jit":
        return True
    if name in ("partial", "functools.partial") and node.args:
        return dotted_name(node.args[0]) == "jax.jit"
    return False


class _FunctionScanner(ast.NodeVisitor):
    """Walks ONE function body tracking held locks and loop depth."""

    def __init__(self, checker: "LockDisciplineChecker", mod: Module,
                 class_name: str):
        self.checker = checker
        self.mod = mod
        self.class_name = class_name
        self.held: List[str] = []      # lock labels, outermost first
        self.loop_depth = 0

    def _finding(self, rule: str, node: ast.AST, msg: str) -> None:
        self.checker.found.append(
            Finding(rule, self.mod.relpath, node.lineno, msg))

    # -- locks ----------------------------------------------------------
    def visit_With(self, node: ast.With) -> None:
        pushed = 0
        for item in node.items:
            label = lock_label(item.context_expr, self.class_name)
            if label is not None:
                if self.held:
                    self.checker.edges.setdefault(
                        (self.held[-1], label), (self.mod.relpath,
                                                 node.lineno))
                self.held.append(label)
                pushed += 1
            else:
                # e.g. `with open(...)` under a held lock
                self.visit(item.context_expr)
        for stmt in node.body:
            self.visit(stmt)
        del self.held[len(self.held) - pushed:]

    # -- loops (for the condvar predicate rule) -------------------------
    def visit_While(self, node: ast.While) -> None:
        self.loop_depth += 1
        self.generic_visit(node)
        self.loop_depth -= 1

    visit_For = visit_While  # type: ignore[assignment]

    # -- nested defs get their own scanner ------------------------------
    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        # a nested def/lambda body does not run under the enclosing
        # `with`; scan it with a fresh lock stack
        sub = _FunctionScanner(self.checker, self.mod, self.class_name)
        for stmt in node.body:
            sub.visit(stmt)

    visit_AsyncFunctionDef = visit_FunctionDef  # type: ignore[assignment]

    def visit_Lambda(self, node: ast.Lambda) -> None:
        sub = _FunctionScanner(self.checker, self.mod, self.class_name)
        sub.visit(node.body)

    # -- calls ----------------------------------------------------------
    def visit_Call(self, node: ast.Call) -> None:
        name = call_name(node)
        tail = name.split(".")[-1] if name else ""
        receiver = name[:len(name) - len(tail) - 1] if "." in name else ""

        if tail == "wait" and "cond" in receiver:
            if self.loop_depth == 0:
                self._finding(
                    RULE_CONDVAR, node,
                    f"`{name}()` outside a `while <predicate>` loop — "
                    "spurious wakeups and lost notifies require "
                    "re-checking the predicate after every wait")
        elif self.held:
            self._check_blocking(node, name, tail, receiver)
        self.generic_visit(node)

    def _check_blocking(self, node: ast.Call, name: str, tail: str,
                        receiver: str) -> None:
        held = self.held[-1]
        msg = None
        if name in _BLOCKING_CALLS:
            msg = f"blocking call `{name}` while holding `{held}`"
        elif _is_jit_call(node):
            msg = f"jit compilation under held lock `{held}`"
        elif tail == "result":
            msg = (f"`{name}()` (future result — unbounded wait) while "
                   f"holding `{held}`")
        elif tail == "join" and _THREADISH.search(receiver):
            msg = f"`{name}()` (thread join) while holding `{held}`"
        elif tail == "wait" and "cond" not in receiver:
            msg = (f"`{name}()` (event wait) while holding `{held}` — "
                   "the waiter can never be woken by a thread stuck on "
                   "this lock")
        elif tail in ("run", "swap") and "engine" in receiver:
            msg = (f"`{name}()` under held lock `{held}` — engine "
                   f"{tail} jit-compiles on first use and may fault in "
                   "out-of-core batches (disk I/O)")
        if msg:
            self._finding(RULE_BLOCKING, node,
                          msg + "; move the slow work outside the "
                          "critical section or annotate the by-design "
                          "case `# lint: allow(lock-blocking)`")


class LockDisciplineChecker(Checker):
    name = "locks"
    rules = (RULE_ORDER, RULE_BLOCKING, RULE_CONDVAR, RULE_CLOCK)

    def run(self, project: Project) -> List[Finding]:
        self.found: List[Finding] = []
        for mod in project.iter_modules(in_scope):
            # per-module acquisition graph: (outer, inner) -> provenance
            self.edges: Dict[Tuple[str, str], Tuple[str, int]] = {}
            self._scan_module(mod)
            self.found.extend(self._order_findings())
        return self.found

    def _scan_module(self, mod: Module) -> None:
        # only top-level functions and direct methods: nested defs are
        # scanned (with a fresh lock stack) by their enclosing scanner
        def top_functions(body, cls):
            for node in body:
                if isinstance(node, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                    yield node, cls
                elif isinstance(node, ast.ClassDef):
                    yield from top_functions(node.body, node.name)

        for fn, cls in top_functions(mod.tree.body, ""):
            if cls == "SystemClock":
                continue  # the sanctioned real-clock shim
            scanner = _FunctionScanner(self, mod, cls)
            for stmt in fn.body:
                scanner.visit(stmt)
        self._scan_clock_refs(mod)

    def _scan_clock_refs(self, mod: Module) -> None:
        """Flag any reference (not just call) to the raw clock outside
        class SystemClock — `self._now = time.time` is as untestable as
        calling it."""
        sanctioned: Set[int] = set()
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.ClassDef) and node.name == "SystemClock":
                sanctioned.update(
                    n.lineno for n in ast.walk(node)
                    if hasattr(n, "lineno"))
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Attribute) \
                    and dotted_name(node) in _CLOCK_REFS \
                    and node.lineno not in sanctioned:
                self.found.append(Finding(
                    RULE_CLOCK, mod.relpath, node.lineno,
                    f"direct `{dotted_name(node)}` in threaded code — "
                    "route timing through the injectable clock "
                    "(repro.serve.common.SystemClock / a clock= "
                    "parameter) so FakeClock tests stay deterministic"))

    def _order_findings(self) -> List[Finding]:
        """DFS cycle detection over the module's acquisition graph."""
        out: List[Finding] = []
        graph: Dict[str, List[str]] = {}
        for (a, b) in self.edges:
            graph.setdefault(a, []).append(b)

        def reaches(src: str, dst: str, seen: Set[str]) -> bool:
            if src == dst:
                return True
            for nxt in graph.get(src, ()):
                if nxt not in seen:
                    seen.add(nxt)
                    if reaches(nxt, dst, seen):
                        return True
            return False

        for (a, b), (path, line) in sorted(self.edges.items(),
                                           key=lambda kv: kv[1]):
            # edge a->b closes a cycle iff b already reaches a
            if reaches(b, a, {b}):
                out.append(Finding(
                    RULE_ORDER, path, line,
                    f"lock-order inversion: `{a}` -> `{b}` here, but "
                    f"another site nests them in the opposite order — "
                    "pick one global order"))
        return out
