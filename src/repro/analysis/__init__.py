"""AST-based invariant analyzer for this repo (DESIGN.md §15).

``repro.analysis`` mechanically enforces the contracts the rest of the
codebase states in prose: deterministic plan builds (§8/§14), lock
discipline in the threaded serving tier (§11–§12), atomic artifact
writes (§12–§13), a single canonical fault-point registry (§12),
jit-executable cache hygiene (§14), and bench-gate/emitter agreement.

Run it with ``python -m repro.analysis [--format json] [paths]``; see
``repro.analysis.cli``. The package is stdlib-only (``ast`` + ``re`` +
``json``) so the CI gate needs no scientific stack installed.
"""
from repro.analysis.model import (Checker, Finding, Module, Project,
                                  load_baseline)
from repro.analysis.determinism import DeterminismChecker
from repro.analysis.locks import LockDisciplineChecker
from repro.analysis.atomic_write import AtomicWriteChecker
from repro.analysis.fault_points import FaultPointChecker
from repro.analysis.jit_cache import JitCacheChecker
from repro.analysis.bench_gate import BenchGateChecker

ALL_CHECKERS = (
    DeterminismChecker,
    LockDisciplineChecker,
    AtomicWriteChecker,
    FaultPointChecker,
    JitCacheChecker,
    BenchGateChecker,
)

__all__ = [
    "ALL_CHECKERS",
    "AtomicWriteChecker",
    "BenchGateChecker",
    "Checker",
    "DeterminismChecker",
    "FaultPointChecker",
    "Finding",
    "JitCacheChecker",
    "LockDisciplineChecker",
    "Module",
    "Project",
    "load_baseline",
]
