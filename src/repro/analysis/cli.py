"""CLI for the invariant analyzer: ``python -m repro.analysis``.

Exit code 0 iff no findings remain after allow-comment and baseline
filtering. ``--format json`` emits a machine-readable report (the CI
artifact); the default text format prints one ``path:line: [rule]``
line per finding plus a summary.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List, Optional, Sequence

from repro.analysis import ALL_CHECKERS
from repro.analysis.model import (BASELINE_RELPATH, Finding, Project,
                                  filter_allowed, filter_baselined,
                                  load_baseline)


def find_repo_root() -> str:
    """The repo root is three levels above this package (src/repro/
    analysis) — overridable with --root for out-of-tree use."""
    here = os.path.dirname(os.path.abspath(__file__))
    return os.path.abspath(os.path.join(here, "..", "..", ".."))


def run_checkers(project: Project,
                 only: Optional[Sequence[str]] = None) -> List[Finding]:
    findings: List[Finding] = []
    for cls in ALL_CHECKERS:
        if only and cls.name not in only:
            continue
        findings.extend(cls().run(project))
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule))


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="AST-based invariant analyzer (DESIGN.md §15)")
    ap.add_argument("paths", nargs="*",
                    help="restrict findings to these repo-relative path "
                         "prefixes (default: whole repo)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--root", default=None,
                    help="repo root (default: auto-detected)")
    ap.add_argument("--only", action="append", default=None,
                    metavar="CHECKER",
                    help="run only this checker (repeatable): " +
                         ", ".join(c.name for c in ALL_CHECKERS))
    ap.add_argument("--baseline", default=None,
                    help=f"baseline file (default: <root>/"
                         f"{BASELINE_RELPATH})")
    args = ap.parse_args(argv)

    root = os.path.abspath(args.root) if args.root else find_repo_root()
    project = Project.load(root)
    findings = run_checkers(project, only=args.only)

    if args.paths:
        prefixes = tuple(p.rstrip("/") for p in args.paths)
        findings = [f for f in findings
                    if f.path in prefixes
                    or any(f.path.startswith(p + "/") for p in prefixes)]

    findings, allowed = filter_allowed(findings, project)
    baseline_path = args.baseline or os.path.join(root, BASELINE_RELPATH)
    findings, baselined = filter_baselined(findings,
                                           load_baseline(baseline_path))

    if args.format == "json":
        json.dump({
            "root": root,
            "findings": [f.as_dict() for f in findings],
            "suppressed": {
                "allow_comments": [f.as_dict() for f in allowed],
                "baseline": [f.as_dict() for f in baselined],
            },
        }, sys.stdout, indent=1)
        sys.stdout.write("\n")
    else:
        for f in findings:
            print(f.render())
        print(f"{len(findings)} finding(s) "
              f"({len(allowed)} allowed by lint comments, "
              f"{len(baselined)} baselined) over "
              f"{len(project.modules)} modules")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
