"""Streaming (out-of-core) plan construction (DESIGN.md §13).

``IBMBPipeline.plan(split, out_of_core=True, store_dir=...)`` lands here.
The resident build holds every padded batch in memory at once
(``preprocess`` → ``BatchCache``); this builder produces a BIT-IDENTICAL
plan while never materializing more than one chunk of batches:

1. **Id-only partition** — ``pipe.partition(split)`` runs influence scores
   → output partition → auxiliary selection exactly as the resident path
   does (it IS the resident path: ``preprocess = partition +
   build_batches``), returning per-batch global-id lists. O(outputs · k)
   memory, no payload.
2. **Sizing sweep** — one structure-only pass over the batches measures the
   exact node/edge/output maxima the resident ``build_batches`` would have
   padded to (and, for the bcsr backend, the global column-tile count K
   after batch-local reordering). Chunked builds pass these as explicit
   caps, so every chunk pads to the SAME bucket the resident build picks —
   the precondition for bitwise-equal payload. One batch's induced
   subgraph is alive at a time.
3. **Chunked materialize + append** — ``build_batches`` runs over
   ``chunk_batches`` batches at a time (explicit caps + ``bcsr_pad_k``);
   each chunk's stacked fields are appended to the
   :class:`~repro.ooc.store.PlanStore` and dropped. Per-chunk we keep only
   the small per-batch side products the plan header needs: real labels
   (schedule input), routing triplets, and the membership rows.
4. **Index + commit** — schedule via the same ``make_schedule`` call the
   resident path makes, routing via ``RoutingIndex.from_triplets`` over the
   concatenated chunk triplets (one stable sort ⇒ identical to a resident
   ``from_cache``), then ``finalize`` writes index + header (the header is
   the commit point — a crash mid-stream leaves nothing openable).

The returned :class:`~repro.core.plan.Plan` is backed by a
:class:`~repro.ooc.store.LazyBatchCache` with a bounded resident-batch
budget; its fingerprint, schedule, routing, membership, and per-batch
payload are bitwise equal to ``pipe.plan(split)``'s — the §13 acceptance
bar the ``tests/test_ooc.py`` equality suite pins.

The trade is deliberate: the sizing sweep re-derives each batch's induced
subgraph (and the bcsr pass re-tiles it), so streaming costs roughly one
extra structure pass of preprocessing time in exchange for O(chunk) peak
payload memory. ``BENCH_ooc.json`` prices it.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import List, Optional

import numpy as np

from repro.core import autotune
from repro.core.batches import BatchCache, _round_up, build_batches
from repro.core.plan import Plan, RoutingIndex, encode_backends
from repro.core.scheduling import make_schedule
from repro.faults import NO_FAULTS
from repro.graph.csr import induced_subgraph
from repro.ooc.store import PlanStore, PlanStoreWriter


@dataclasses.dataclass(frozen=True)
class OOCConfig:
    """Knobs of the out-of-core build/serve path (DESIGN.md §13).

    chunk_batches:    batches materialized per streaming append — peak
                      builder payload is ~chunk_batches padded batches.
    resident_batches: LazyBatchCache LRU budget of the returned plan —
                      peak serving payload is ~resident_batches batches.
    io_retries:       bounded retries of a transient per-batch read fault
                      (the ``batch_io`` point; checksum mismatches are
                      never retried).
    """
    chunk_batches: int = 8
    resident_batches: int = 8
    io_retries: int = 2


def _measure_caps(pipe, parts: List[np.ndarray], aux: List[np.ndarray]):
    """The sizing sweep: per-batch real (nodes, edges, outputs) counts plus
    the padded caps the resident ``build_batches`` would derive. Only one
    batch's induced subgraph exists at a time."""
    g = pipe.ds.norm_graph
    pad = pipe.cfg.pad_multiple
    nn_max = ne_max = no_max = 0
    for outs, a in zip(parts, aux):
        nodes = np.unique(np.concatenate([outs, a]))
        src, _dst, _w = induced_subgraph(g, nodes)
        nn_max = max(nn_max, len(nodes))
        ne_max = max(ne_max, len(src))
        no_max = max(no_max, len(outs))
    mn = _round_up(nn_max, pad)
    me = _round_up(max(ne_max, 1), pad)
    mo = _round_up(no_max, pad)
    return mn, me, mo


def _measure_bcsr(pipe, parts, aux, mn: int):
    """Tile-shape half of the sizing sweep: analytically derive, per
    candidate tile size, the padded-flops cost and the global column-tile
    count K over each batch's (reordered) adjacency —
    ``autotune.tile_shape_stats`` computes exactly what ``csr_to_bcsr``
    would emit, without materializing tiles. Returns ``(block, pad_k)``:
    the winning tile size (the SAME argmin the resident
    ``autotune.retune_tile_block`` takes, over the same edge sets) and the
    K chunks must pad to so batches built in different chunks share one
    tile-table shape."""
    from repro.core.batches import batch_node_order
    g = pipe.ds.norm_graph
    cfg = pipe.cfg
    if cfg.autotune and cfg.tune_blocks:
        cand = autotune.tile_block_candidates(cfg, mn)
    else:
        cand = [math.gcd(cfg.bcsr_block, mn)]
    costs = {b: 0 for b in cand}
    kmax = {b: 1 for b in cand}
    for outs, a in zip(parts, aux):
        nodes = np.unique(np.concatenate([outs, a]))
        src, dst, w = induced_subgraph(g, nodes)
        if cfg.reorder != "none":
            perm = batch_node_order(len(nodes), src, dst,
                                    mode=cfg.reorder)
            inv = np.empty(len(nodes), np.int64)
            inv[perm] = np.arange(len(nodes))
            src = inv[src].astype(np.int32)
            dst = inv[dst].astype(np.int32)
        for b in cand:
            t, k = autotune.tile_shape_stats(src, dst, w, mn, b)
            costs[b] += t * b * b
            kmax[b] = max(kmax[b], k)
    win = autotune.pick_tile_block(costs)
    return win, kmax[win]


def stream_chunks(pipe, parts, aux, caps, pad_k: Optional[int],
                  writer: PlanStoreWriter, chunk: int,
                  bcsr_block: Optional[int] = None):
    """Stage 3 of the streaming build: materialize ``chunk`` batches at a
    time with the GLOBAL caps, append each chunk's stacked fields to
    ``writer``, and keep only the index-scale side products. Returns
    ``(labels, (trip_ids, trip_b, trip_r), members, decisions)`` —
    schedule input, routing triplets in batch-major order (batch indices
    local to this writer), the (B, max_nodes) membership rows, and the
    autotuner's per-batch ``(backends, block_fs, stats)`` lists
    (DESIGN.md §14; computed chunk by chunk through the same
    ``autotune.decide_batches`` the resident build runs). ``bcsr_block``
    overrides the configured tile size with the sweep winner. Shared by
    :func:`stream_plan` (one store) and ``repro.ooc.shard.build_shards``
    (one store per contiguous batch range)."""
    cfg = pipe.cfg
    mn, me, mo = caps
    labels: List[np.ndarray] = []
    trip_ids, trip_b, trip_r = [], [], []
    members: List[np.ndarray] = []
    backs: List[str] = []
    bfs: List[int] = []
    bstats: List[dict] = []
    for s in range(0, len(parts), chunk):
        e = min(s + chunk, len(parts))
        batches = build_batches(
            pipe.ds.norm_graph, pipe.ds.features, pipe.ds.labels,
            parts[s:e], aux[s:e], cache_features=cfg.cache_features,
            pad_multiple=cfg.pad_multiple,
            max_nodes=mn, max_edges=me, max_outputs=mo,
            bcsr_block=(bcsr_block or cfg.bcsr_block)
            if cfg.backend == "bcsr" else None,
            reorder=cfg.reorder, bcsr_pad_k=pad_k)
        cb, cf, cs = autotune.decide_batches(batches, cfg)
        backs.extend(cb); bfs.extend(cf); bstats.extend(cs)
        cache = BatchCache(batches)        # one chunk resident, then dropped
        meta_counts = np.array(
            [[m["nodes"], m["edges"], m["outputs"]] for m in cache.meta],
            np.int64)
        writer.append(cache.fields, meta_counts)
        labels.extend(b.labels[b.output_mask] for b in batches)
        node_ids = np.stack([b.node_ids for b in batches])
        members.append(node_ids)
        # same row-major walk as RoutingIndex.from_cache, chunk offset
        # shifts batch indices into writer-local coordinates
        omask = np.stack([b.output_mask for b in batches])
        oidx = np.stack([np.maximum(b.output_idx, 0) for b in batches])
        b_loc, r = np.nonzero(omask)
        trip_ids.append(node_ids[b_loc, oidx[b_loc, r]].astype(np.int64))
        trip_b.append(b_loc.astype(np.int64) + s)
        trip_r.append(r)
    return labels, (trip_ids, trip_b, trip_r), members, (backs, bfs, bstats)


def stream_plan(pipe, split: str, for_inference: bool, store_dir: str,
                ooc: Optional[OOCConfig] = None, faults=NO_FAULTS) -> Plan:
    """Build ``pipe.plan(split, for_inference)`` out of core: stream chunks
    of batches into a :class:`PlanStore` at ``store_dir`` and return the
    lazily-backed plan. See the module docstring for the four stages."""
    ooc = ooc or OOCConfig()
    cfg = pipe.cfg
    mode = "inference" if for_inference else "train"
    # lint: allow(determinism) — timing telemetry only, never fed into the plan payload or fingerprint
    t0 = time.time()
    parts, aux = pipe.partition(split, for_inference)
    caps = _measure_caps(pipe, parts, aux)
    pad_k = block = None
    if cfg.backend == "bcsr":
        block, pad_k = _measure_bcsr(pipe, parts, aux, caps[0])

    writer = PlanStoreWriter(store_dir)
    chunk = max(1, int(ooc.chunk_batches))
    try:
        labels, (trip_ids, trip_b, trip_r), members, decisions = \
            stream_chunks(pipe, parts, aux, caps, pad_k, writer, chunk,
                          bcsr_block=block)
        backs, bfs, bstats = decisions

        # lint: allow(determinism) — timing telemetry only, never fed into the plan payload or fingerprint
        pipe.timings[f"preprocess/{split}/{mode}"] = time.time() - t0
        # lint: allow(determinism) — timing telemetry only, never fed into the plan payload or fingerprint
        t1 = time.time()
        sched = make_schedule(labels, pipe.ds.num_classes, mode=cfg.schedule,
                              num_epochs=1, seed=cfg.seed)
        routing = RoutingIndex.from_triplets(np.concatenate(trip_ids),
                                             np.concatenate(trip_b),
                                             np.concatenate(trip_r))
        # lint: allow(determinism) — timing telemetry only, never fed into the plan payload or fingerprint
        pipe.timings[f"plan/{split}/{mode}"] = time.time() - t1
        meta = dict(split=split, mode=mode, variant=cfg.variant,
                    backend=cfg.backend,
                    num_classes=int(pipe.ds.num_classes),
                    num_batches=len(parts), dataset=pipe.ds.name,
                    batch_stats=bstats,
                    out_of_core=True, chunk_batches=chunk)
        own = (f"ppr/{split}", f"preprocess/{split}/{mode}",
               f"plan/{split}/{mode}")
        writer.finalize(
            sched, routing, pipe.fingerprint(split, for_inference), meta,
            {k: v for k, v in pipe.timings.items() if k in own},
            node_ids=np.concatenate(members),
            ppr=pipe._ppr_cache.get(split),
            batch_backend=encode_backends(backs),
            batch_block_f=np.asarray(bfs, np.int32))
    except BaseException:
        writer.abort()
        raise
    store = PlanStore.open(store_dir, faults=faults,
                           io_retries=ooc.io_retries)
    return store.as_plan(resident_batches=ooc.resident_batches)
