"""Sharded plans: partition the output set along batch boundaries, serve
one shard per host (DESIGN.md §13).

Out-of-core storage removes the RAM ceiling on ONE host; sharding removes
the single-host ceiling. A shard build runs the FULL split's partition +
sizing sweep exactly once (so every shard pads to the same global shape
bucket the resident plan would), then cuts the batch list into
``num_shards`` contiguous ranges and streams each range into its own
:class:`~repro.ooc.store.PlanStore` at ``root/shard_NNNNN/``. Because
IBMB assigns each output node to exactly one batch, a batch-aligned cut IS
a partition of the output set — and because every shard's batches are the
GLOBAL plan's batches (same parts/aux, same caps, same bcsr K), a
shard-routed query returns logits bitwise identical to the resident
single-host engine. Re-planning each shard's outputs from scratch would
lose both properties: different partitions, different padding, different
floats.

``manifest.json`` at the root records, per shard, its batch range, the
shard plan's fingerprint, and a FINGERPRINT CHAIN

    chain_i = sha256(chain_{i-1} || fingerprint_i)[:16]

so the manifest's final ``chain`` commits to every shard plan in order: a
swapped, stale, or re-built shard breaks the chain even when its own store
is internally consistent (the §10 parent-chain idea applied across space
instead of time). ``owners.npz`` alongside maps every output node id to
its owner shard (first-batch-wins on duplicates, matching the resident
routing index), so a router can say "shard 3 owns this id" without
loading shard 3. Both are written atomically, manifest LAST — it is the
commit point of the build.

Serving: :class:`ShardRouter` loads any subset of shards (a multi-host
deployment loads one per host; ``shards=None`` loads all — the
single-host and test path), verifies the chain, and fans each query out
to owner-shard engines, merging logits back in query order. An id owned
by a shard this router did NOT load raises a clear error naming the shard
to load; an id no shard owns raises the plan-level KeyError — never a
silent wrong answer.
"""
from __future__ import annotations

import hashlib
import json
import os
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.plan import PlanFormatError, RoutingIndex, encode_backends
from repro.core.scheduling import make_schedule
from repro.faults import NO_FAULTS
from repro.ioutil import atomic_write_text as _atomic_write_text
from repro.ooc.store import PlanStore, PlanStoreWriter
from repro.ooc.stream import (OOCConfig, _measure_bcsr, _measure_caps,
                              stream_chunks)

_MANIFEST = "manifest.json"
_OWNERS = "owners.npz"
SHARD_FORMAT = "ibmb-plan-shards"


def _chain(prev: str, fingerprint: str) -> str:
    return hashlib.sha256((prev + fingerprint).encode()).hexdigest()[:16]


def shard_name(i: int) -> str:
    return f"shard_{i:05d}"


def _shard_split(split: str, i: int, num_shards: int) -> str:
    return f"{split}@shard{i}/{num_shards}"


def build_shards(pipe, split: str, num_shards: int, root: str,
                 for_inference: bool = False,
                 ooc: Optional[OOCConfig] = None) -> Dict:
    """Cut ``split``'s batch list into ``num_shards`` contiguous ranges and
    stream each into its own out-of-core store under ``root``; commit the
    chained manifest + owner table. Returns the manifest dict.

    Partition, sizing, and (for bcsr) the global tile count run ONCE over
    the full split, so shard batches are bit-identical to the resident
    plan's — the bitwise-equality bar shard-routed serving is held to."""
    import dataclasses as _dc
    if num_shards < 1:
        raise ValueError(f"num_shards must be >= 1, got {num_shards}")
    os.makedirs(root, exist_ok=True)
    if os.path.exists(os.path.join(root, _MANIFEST)):
        raise ValueError(f"{root}: already holds a committed shard build "
                         f"— refusing to overwrite")
    ooc = ooc or OOCConfig()
    cfg = pipe.cfg
    mode = "inference" if for_inference else "train"

    # lint: allow(determinism) — timing telemetry only, never fed into the plan payload or fingerprint
    t0 = time.time()
    parts, aux = pipe.partition(split, for_inference)
    if num_shards > len(parts):
        raise ValueError(f"cannot cut {len(parts)} batches into "
                         f"{num_shards} shards — lower num_shards or "
                         f"max_outputs_per_batch")
    caps = _measure_caps(pipe, parts, aux)
    pad_k = block = None
    if cfg.backend == "bcsr":
        block, pad_k = _measure_bcsr(pipe, parts, aux, caps[0])
    ranges = np.array_split(np.arange(len(parts)), num_shards)

    # one pipeline over a dataset carrying the shard output-splits: each
    # shard fingerprint is the ordinary (config, dataset, shard-split, mode)
    # fingerprint, so per-shard load-time checking needs no new scheme. The
    # content sha is reused, not recomputed.
    splits = dict(pipe.ds.splits)
    shard_outputs = [np.sort(np.concatenate([parts[b] for b in r]))
                     for r in ranges]
    for i, ids in enumerate(shard_outputs):
        splits[_shard_split(split, i, num_shards)] = ids.astype(np.int64)
    spipe = type(pipe)(_dc.replace(pipe.ds, splits=splits), cfg)
    spipe._content_sha_cache = pipe._content_sha_cache or pipe._content_sha()

    chain = ""
    shards: List[Dict] = []
    own_ids, own_shard = [], []
    chunk = max(1, int(ooc.chunk_batches))
    for i, brange in enumerate(ranges):
        sdir = os.path.join(root, shard_name(i))
        writer = PlanStoreWriter(sdir)
        try:
            sparts = [parts[b] for b in brange]
            saux = [aux[b] for b in brange]
            labels, (tids, tb, tr), members, (backs, bfs, bstats) = \
                stream_chunks(pipe, sparts, saux, caps, pad_k, writer,
                              chunk, bcsr_block=block)
            sched = make_schedule(labels, pipe.ds.num_classes,
                                  mode=cfg.schedule, num_epochs=1,
                                  seed=cfg.seed)
            routing = RoutingIndex.from_triplets(np.concatenate(tids),
                                                 np.concatenate(tb),
                                                 np.concatenate(tr))
            fp = spipe.fingerprint(_shard_split(split, i, num_shards),
                                   for_inference)
            meta = dict(split=split, mode=mode, variant=cfg.variant,
                        backend=cfg.backend,
                        num_classes=int(pipe.ds.num_classes),
                        num_batches=len(brange), dataset=pipe.ds.name,
                        shard=i, num_shards=num_shards,
                        batch_start=int(brange[0]), batch_stats=bstats)
            writer.finalize(sched, routing, fp, meta, {},
                            node_ids=np.concatenate(members),
                            batch_backend=encode_backends(backs),
                            batch_block_f=np.asarray(bfs, np.int32))
        except BaseException:
            writer.abort()
            raise
        chain = _chain(chain, fp)
        shards.append(dict(dir=shard_name(i), fingerprint=fp, chain=chain,
                           num_outputs=int(len(shard_outputs[i])),
                           num_batches=int(len(brange)),
                           batch_start=int(brange[0])))
        # owner table triplets: routing already dedupes within a shard
        # (first batch wins); cross-shard duplicates are resolved below by
        # the same rule via a stable sort on (id, shard order).
        own_ids.append(routing.node_ids)
        own_shard.append(np.full(len(routing.node_ids), i, np.int32))

    ids = np.concatenate(own_ids)
    owner = np.concatenate(own_shard)
    order = np.argsort(ids, kind="stable")   # ties keep lower shard = the
    ids, owner = ids[order], owner[order]    # earlier batch, as resident
    keep = np.ones(len(ids), bool)           # routing would pick
    if len(ids) > 1:
        keep[1:] = ids[1:] != ids[:-1]
    np.savez(os.path.join(root, _OWNERS), node_ids=ids[keep],
             shard=owner[keep])
    manifest = dict(format=SHARD_FORMAT, version=1, split=split, mode=mode,
                    num_shards=num_shards, dataset=pipe.ds.name,
                    num_batches=len(parts), chain=chain, shards=shards,
                    # lint: allow(determinism) — timing telemetry only, never fed into the plan payload or fingerprint
                    build_seconds=time.time() - t0)
    _atomic_write_text(os.path.join(root, _MANIFEST),
                       json.dumps(manifest, indent=1))
    return manifest


def load_manifest(root: str) -> Dict:
    """Read + verify a shard manifest: format and the fingerprint chain
    recomputed from the per-shard fingerprints must hold before anything
    is served."""
    mpath = os.path.join(root, _MANIFEST)
    if not os.path.exists(mpath):
        raise FileNotFoundError(
            f"{root}: no committed shard build here (missing {_MANIFEST})")
    try:
        with open(mpath) as f:
            manifest = json.load(f)
    except ValueError as e:
        raise PlanFormatError(f"{mpath}: corrupt manifest ({e})") from e
    if manifest.get("format") != SHARD_FORMAT:
        raise PlanFormatError(f"{mpath}: not a shard manifest "
                              f"(format={manifest.get('format')!r})")
    if len(manifest["shards"]) != int(manifest["num_shards"]):
        raise PlanFormatError(f"{mpath}: {len(manifest['shards'])} shard "
                              f"entries, header says "
                              f"{manifest['num_shards']}")
    chain = ""
    for i, s in enumerate(manifest["shards"]):
        chain = _chain(chain, s["fingerprint"])
        if chain != s["chain"]:
            raise PlanFormatError(
                f"{mpath}: fingerprint chain breaks at shard {i} "
                f"(expected {chain!r}, manifest says {s['chain']!r}) — a "
                f"shard plan was swapped or re-built out of order")
    if chain != manifest.get("chain", ""):
        raise PlanFormatError(f"{mpath}: final chain mismatch")
    return manifest


class PlanShard:
    """One loaded shard: its store, lazy plan, and engine."""

    def __init__(self, index: int, store: PlanStore, plan, engine):
        self.index = index
        self.store = store
        self.plan = plan
        self.engine = engine


class ShardRouter:
    """Route per-node queries across shard engines (DESIGN.md §13).

    The owner table gives O(log |outputs|) owner lookup without loading
    every shard; loaded shards answer through their own
    :class:`~repro.serve.gnn_engine.GNNInferenceEngine` (lazy batch
    faulting under the shard's resident budget, per-shard output LRU).
    Logits are bitwise identical to the resident single-host engine —
    shard batches ARE the global plan's batches."""

    def __init__(self, manifest: Dict, owners: Dict[str, np.ndarray],
                 shards: Dict[int, PlanShard]):
        self.manifest = manifest
        self.owner_ids = np.asarray(owners["node_ids"], np.int64)
        self.owner_shard = np.asarray(owners["shard"], np.int32)
        self.shards = shards
        self.stats = dict(requests=0, nodes=0, shard_misses=0)

    @staticmethod
    def load(root: str, model_cfg, params,
             shards: Optional[Sequence[int]] = None,
             resident_batches: int = 8, cache_batches: int = 8,
             faults=NO_FAULTS, io_retries: int = 2) -> "ShardRouter":
        """Open ``root`` and serve the given shard indices (``None`` = all;
        a multi-host deployment passes its own shard). Chain-verified
        manifest first; each shard store opens O(metadata) and faults
        batches in lazily, so loading one shard of a huge build is cheap."""
        from repro.serve.gnn_engine import GNNInferenceEngine
        manifest = load_manifest(root)
        opath = os.path.join(root, _OWNERS)
        try:
            with np.load(opath, allow_pickle=False) as z:
                owners = {k: z[k] for k in ("node_ids", "shard")}
        except FileNotFoundError:
            raise PlanFormatError(f"{root}: owner table missing ({_OWNERS})")
        except Exception as e:
            raise PlanFormatError(f"{opath}: corrupt owner table "
                                  f"({type(e).__name__}: {e})") from e
        want = range(manifest["num_shards"]) if shards is None else shards
        loaded: Dict[int, PlanShard] = {}
        for i in want:
            i = int(i)
            if not 0 <= i < manifest["num_shards"]:
                raise ValueError(f"shard {i} out of range "
                                 f"[0, {manifest['num_shards']})")
            entry = manifest["shards"][i]
            store = PlanStore.open(os.path.join(root, entry["dir"]),
                                   faults=faults, io_retries=io_retries)
            if store.fingerprint != entry["fingerprint"]:
                raise PlanFormatError(
                    f"shard {i}: store fingerprint {store.fingerprint!r} "
                    f"does not match the manifest "
                    f"({entry['fingerprint']!r}) — chain broken on disk")
            plan = store.as_plan(resident_batches=resident_batches)
            engine = GNNInferenceEngine(plan, model_cfg, params,
                                        cache_batches=cache_batches)
            loaded[i] = PlanShard(i, store, plan, engine)
        return ShardRouter(manifest, owners, loaded)

    def owner(self, node_ids: Sequence[int]) -> np.ndarray:
        """Owner shard per query id; KeyError for ids no shard owns."""
        q = np.asarray(node_ids, dtype=np.int64).ravel()
        pos = np.searchsorted(self.owner_ids, q)
        safe = np.minimum(pos, max(len(self.owner_ids) - 1, 0))
        bad = (len(self.owner_ids) == 0) | (pos >= len(self.owner_ids)) | \
            (self.owner_ids[safe] != q)
        if np.any(bad):
            missing = q[bad] if len(q) else q
            raise KeyError(f"node ids not covered by any shard: "
                           f"{missing[:8].tolist()}"
                           f"{'...' if len(missing) > 8 else ''}")
        return self.owner_shard[safe]

    def query(self, node_ids: Sequence[int]) -> np.ndarray:
        """Logits in query order, fanned out across owner shards. KeyError
        when an owner shard is not loaded (says which one to route to)."""
        q = np.asarray(node_ids, dtype=np.int64).ravel()
        own = self.owner(q)
        self.stats["requests"] += 1
        self.stats["nodes"] += len(q)
        out = None
        for si in np.unique(own):
            shard = self.shards.get(int(si))
            if shard is None:
                self.stats["shard_misses"] += 1
                raise KeyError(
                    f"node ids {q[own == si][:8].tolist()} are owned by "
                    f"shard {int(si)}, which this router did not load "
                    f"(loaded: {sorted(self.shards)}) — route the request "
                    f"to the host serving that shard")
            sel = own == si
            lg = shard.engine.query(q[sel])
            if out is None:
                out = np.empty((len(q), lg.shape[1]), lg.dtype)
            out[sel] = lg
        if out is None:
            first = next(iter(self.shards.values()), None)
            width = (first.plan.meta.get("num_classes", 0) if first else 0)
            return np.zeros((0, width), np.float32)
        return out

    def shards_hit(self, node_ids: Sequence[int]) -> int:
        """How many distinct shards a query touches (bench evidence that
        routed traffic really spans shards)."""
        return len(np.unique(self.owner(node_ids)))

    def snapshot(self) -> Dict:
        """Router + per-shard engine/cache observability (§11 idiom)."""
        return dict(self.stats,
                    loaded=sorted(self.shards),
                    num_shards=int(self.manifest["num_shards"]),
                    per_shard={i: dict(engine=s.engine.stats,
                                       cache=s.plan.cache.snapshot())
                               for i, s in self.shards.items()})
