"""Out-of-core plans (DESIGN.md §13): mmap-backed batch storage
(``store``), streaming chunked preprocessing (``stream``), and sharded
multi-host serving (``shard``). Entry points:

    plan  = pipe.plan(split, out_of_core=True, store_dir=d)   # stream build
    store = PlanStore.open(d); plan = store.as_plan(resident_batches=8)
    build_shards(pipe, split, num_shards, root)
    router = ShardRouter.load(root, model_cfg, params, shards=[i])
"""
from repro.ooc.store import (FieldSpec, LazyBatchCache, PlanStore,
                             PlanStoreWriter, write_store)
from repro.ooc.stream import OOCConfig, stream_plan
from repro.ooc.shard import (PlanShard, ShardRouter, build_shards,
                             load_manifest, shard_name)

__all__ = [
    "FieldSpec", "LazyBatchCache", "PlanStore", "PlanStoreWriter",
    "write_store", "OOCConfig", "stream_plan", "PlanShard", "ShardRouter",
    "build_shards", "load_manifest", "shard_name",
]
