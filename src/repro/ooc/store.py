"""Mmap-backed, per-batch-addressable Plan storage (DESIGN.md §13).

The paper's systems insight — precomputed batches laid out consecutively —
is exactly what makes disk-backed plans practical: reading batch ``i`` of
field ``f`` is ONE contiguous slice of one flat file, never a random
neighbor gather. ``PlanStore`` turns that into a storage format:

    store_dir/
      header.json        # metadata, field dtypes/shapes, chunk table —
                         # written LAST (tmp + os.replace): its presence is
                         # the commit point of a build. A crash mid-stream
                         # leaves no header ⇒ open() refuses the directory.
      index.npz          # schedule, routing index, per-batch meta counts,
                         # membership (node_ids), warm PPR state, and the
                         # (B, num_fields) per-batch crc32 table
      fields/<name>.bin  # raw C-order little-endian bytes, shape
                         # (num_batches, *field_shape): batch i IS the
                         # byte range [i*rowbytes, (i+1)*rowbytes)

Batches are appended in CHUNKS (a few batches at a time) by the streaming
builder (``repro.ooc.stream``): each append is a sequential write to every
field file, so building never holds more than one chunk of padded payload.

Reading is the mirror image. ``PlanStore.open`` reads header + index only
(O(metadata)); field payload is exposed two ways:

* ``mmap_fields()`` — a dict of read-only ``np.memmap`` views shaped like a
  resident ``BatchCache.fields``, for whole-plan consumers (``check_routing``,
  schedule re-derivation) that touch a few small fields: the OS pages in
  only what is read.
* ``read_batch(i)`` — the serving path: copy batch i's slice of every field
  out of the maps (a contiguous read), verify its crc32 against the index
  table, and hand back an ordinary dict. Transient ``OSError`` retries up
  to ``io_retries`` times (the ``batch_io`` fault point fires per attempt,
  DESIGN.md §12); a checksum mismatch raises ``PlanFormatError`` — corrupt
  bytes are never retried and never served.

``LazyBatchCache`` wraps a store in the ``BatchCache`` interface with a
bounded RESIDENT-BATCH BUDGET: at most ``resident_batches`` verified batch
dicts are held (LRU eviction of cold batches), so ``GNNInferenceEngine`` /
``AsyncGNNEngine`` fault in only the batches requests route to and
``PrefetchLoader`` streams training super-steps from disk at O(budget)
host memory however large the plan is.
"""
from __future__ import annotations

import dataclasses
import json
import os
import zlib
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.batches import BatchCache
from repro.core.plan import Plan, PlanFormatError, RoutingIndex, _frozen
from repro.core.ppr import TopKPPR
from repro.faults import NO_FAULTS, FaultStats
from repro.ioutil import atomic_savez as _atomic_savez
from repro.ioutil import atomic_write_text as _atomic_write_text

STORE_VERSION = 1
_HEADER = "header.json"
_INDEX = "index.npz"
_FIELD_DIR = "fields"


def _row_crc32(stacked: np.ndarray) -> np.ndarray:
    """crc32 of each leading-axis slice of a stacked field array."""
    a = np.ascontiguousarray(stacked)
    flat = a.reshape(len(a), -1)
    return np.array([zlib.crc32(flat[i].tobytes()) for i in range(len(a))],
                    dtype=np.uint32)


@dataclasses.dataclass(frozen=True)
class FieldSpec:
    """Per-batch dtype/shape of one stored field."""
    name: str
    dtype: str                    # numpy dtype string, e.g. "float32"
    shape: tuple                  # per-batch shape (without the batch axis)

    @property
    def rowbytes(self) -> int:
        return int(np.dtype(self.dtype).itemsize * int(np.prod(self.shape,
                                                               dtype=np.int64)))


class PlanStoreWriter:
    """Append-only builder of a ``PlanStore`` directory.

    ``append(stacked_fields, meta_counts)`` writes one chunk of batches
    sequentially to every field file; ``finalize(...)`` writes the index
    and then the header — the header is the COMMIT: until it exists,
    ``PlanStore.open`` refuses the directory, so a crash mid-build can
    never be served (§12's atomic-artifact rule applied to a directory)."""

    def __init__(self, path: str):
        self.path = path
        if os.path.exists(os.path.join(path, _HEADER)):
            raise ValueError(f"{path}: already holds a finalized PlanStore "
                             f"— refusing to overwrite")
        os.makedirs(os.path.join(path, _FIELD_DIR), exist_ok=True)
        self.specs: List[FieldSpec] = []
        self._files: Dict[str, "object"] = {}
        self._crcs: Dict[str, List[np.ndarray]] = {}
        self._meta: List[np.ndarray] = []
        self._chunks: List[Dict[str, int]] = []
        self.num_batches = 0
        self._finalized = False

    def append(self, stacked: Dict[str, np.ndarray],
               meta_counts: np.ndarray) -> None:
        """Write one chunk: ``stacked[f]`` is (C, *field_shape) for every
        field, ``meta_counts`` is (C, 3) real (nodes, edges, outputs)."""
        count = len(next(iter(stacked.values())))
        if not self.specs:                      # first chunk fixes the schema
            self.specs = [FieldSpec(k, str(v.dtype), tuple(v.shape[1:]))
                          for k, v in sorted(stacked.items())]
            for s in self.specs:
                self._files[s.name] = open(
                    os.path.join(self.path, _FIELD_DIR, s.name + ".bin"),
                    "wb")
                self._crcs[s.name] = []
        if set(stacked) != {s.name for s in self.specs}:
            raise ValueError(f"chunk fields {sorted(stacked)} != store "
                             f"schema {[s.name for s in self.specs]}")
        for s in self.specs:
            v = np.ascontiguousarray(stacked[s.name])
            if v.shape[1:] != s.shape or str(v.dtype) != s.dtype:
                raise ValueError(
                    f"field {s.name!r}: chunk is {v.dtype}{v.shape[1:]} but "
                    f"the store schema says {s.dtype}{s.shape} — chunked "
                    f"builds must share one padded shape bucket")
            self._files[s.name].write(v.tobytes())
            self._crcs[s.name].append(_row_crc32(v))
        self._meta.append(np.asarray(meta_counts, np.int64).reshape(count, 3))
        self._chunks.append({"start": self.num_batches, "count": count})
        self.num_batches += count

    def finalize(self, schedule: np.ndarray, routing: RoutingIndex,
                 fingerprint: str, meta: Dict, timings: Dict[str, float],
                 version: int = 0, parent: str = "",
                 node_ids: Optional[np.ndarray] = None,
                 ppr: Optional[TopKPPR] = None,
                 batch_backend: Optional[np.ndarray] = None,
                 batch_block_f: Optional[np.ndarray] = None) -> None:
        assert self.num_batches > 0, "finalize() before any append()"
        for f in self._files.values():
            f.flush()
            os.fsync(f.fileno())
            f.close()
        crc_table = np.stack(
            [np.concatenate(self._crcs[s.name]) for s in self.specs], axis=1)
        index = {
            "schedule": np.asarray(schedule, np.int64),
            "route/node_ids": np.asarray(routing.node_ids, np.int64),
            "route/batch": np.asarray(routing.batch, np.int32),
            "route/row": np.asarray(routing.row, np.int32),
            "meta_counts": np.concatenate(self._meta),
            "batch_crc32": crc_table,
        }
        if node_ids is not None:
            index["batch_node_ids"] = np.asarray(node_ids, np.int32)
        # autotuner decisions (plan format v3, DESIGN.md §14) ride in the
        # index next to the other per-batch metadata
        if batch_backend is not None:
            index["batch_backend"] = np.asarray(batch_backend, np.int8)
        if batch_block_f is not None:
            index["batch_block_f"] = np.asarray(batch_block_f, np.int32)
        if ppr is not None:
            index["ppr/roots"] = ppr.roots
            index["ppr/indices"] = ppr.indices
            index["ppr/values"] = ppr.values
        _atomic_savez(os.path.join(self.path, _INDEX), **index)
        header = {
            "format": "ibmb-plan-store",
            "store_version": STORE_VERSION,
            "fingerprint": fingerprint,
            "plan_version": int(version),
            "parent": parent,
            "meta": dict(meta),
            "timings": {k: float(v) for k, v in timings.items()},
            "num_batches": int(self.num_batches),
            "fields": [dataclasses.asdict(s) for s in self.specs],
            "chunks": self._chunks,
        }
        _atomic_write_text(os.path.join(self.path, _HEADER),
                           json.dumps(header, indent=1))
        self._finalized = True

    def abort(self) -> None:
        """Drop a half-written build (nothing was ever visible to open)."""
        for f in self._files.values():
            try:
                f.close()
            except OSError:
                pass


class PlanStore:
    """Read side of the store: header + index resident, payload mmap'd."""

    def __init__(self, path: str, header: Dict, index: Dict[str, np.ndarray],
                 faults=NO_FAULTS, io_retries: int = 2):
        self.path = path
        self.header = header
        self.fingerprint = header.get("fingerprint", "")
        self.meta = header.get("meta", {})
        self.timings = header.get("timings", {})
        self.num_batches = int(header["num_batches"])
        self.specs = [FieldSpec(f["name"], f["dtype"], tuple(f["shape"]))
                      for f in header["fields"]]
        self.schedule = index["schedule"]
        self.routing = RoutingIndex(_frozen(index["route/node_ids"]),
                                    _frozen(index["route/batch"]),
                                    _frozen(index["route/row"]))
        self.meta_counts = index["meta_counts"]
        self.batch_crc32 = index["batch_crc32"]
        self.node_ids = index.get("batch_node_ids")
        self.batch_backend = index.get("batch_backend")
        self.batch_block_f = index.get("batch_block_f")
        self.ppr = None
        if "ppr/roots" in index:
            self.ppr = TopKPPR(roots=index["ppr/roots"],
                               indices=index["ppr/indices"],
                               values=index["ppr/values"])
        self.faults = faults
        self.io_retries = max(0, int(io_retries))
        self.stats = FaultStats("reads", "io_retries", "crc_failures")
        self._mmaps: Dict[str, np.memmap] = {}
        self._validate_payload_sizes()

    # ------------------------------------------------------------- opening
    @staticmethod
    def open(path: str, faults=NO_FAULTS, io_retries: int = 2) -> "PlanStore":
        """Open a finalized store. O(metadata): header + index only — no
        field payload is read (that is ``read_batch``'s job). A directory
        without a committed header, a truncated field file, or an index the
        zip layer cannot verify all raise :class:`PlanFormatError`."""
        hpath = os.path.join(path, _HEADER)
        if not os.path.isdir(path) or not os.path.exists(hpath):
            raise FileNotFoundError(
                f"{path}: no finalized PlanStore here (missing {_HEADER} — "
                f"a crash mid-build leaves no header on purpose)")
        try:
            with open(hpath) as f:
                header = json.load(f)
        except ValueError as e:
            raise PlanFormatError(f"{hpath}: corrupt store header "
                                  f"({e})") from e
        if header.get("format") != "ibmb-plan-store" or \
                header.get("store_version") != STORE_VERSION:
            raise PlanFormatError(
                f"{path}: not a PlanStore this build reads "
                f"(format={header.get('format')!r}, "
                f"store_version={header.get('store_version')!r}, "
                f"want {STORE_VERSION})")
        ipath = os.path.join(path, _INDEX)
        try:
            with np.load(ipath, allow_pickle=False) as z:
                index = {k: z[k] for k in z.files}   # zip CRC verified here
        except FileNotFoundError:
            raise PlanFormatError(f"{path}: store index missing ({_INDEX})")
        except Exception as e:
            raise PlanFormatError(f"{ipath}: corrupt or truncated store "
                                  f"index ({type(e).__name__}: {e})") from e
        return PlanStore(path, header, index, faults=faults,
                         io_retries=io_retries)

    def _field_path(self, name: str) -> str:
        return os.path.join(self.path, _FIELD_DIR, name + ".bin")

    def _validate_payload_sizes(self) -> None:
        """A truncated chunk (crash/partial copy) is caught at open time by
        SIZE, before any mmap slice could read past EOF."""
        if self.batch_crc32.shape != (self.num_batches, len(self.specs)):
            raise PlanFormatError(
                f"{self.path}: crc table shape {self.batch_crc32.shape} "
                f"does not match {self.num_batches} batches x "
                f"{len(self.specs)} fields")
        for s in self.specs:
            p = self._field_path(s.name)
            want = s.rowbytes * self.num_batches
            got = os.path.getsize(p) if os.path.exists(p) else -1
            if got != want:
                raise PlanFormatError(
                    f"{p}: field payload is {got} bytes, header says "
                    f"{want} ({self.num_batches} batches x {s.rowbytes} "
                    f"B/batch) — truncated or foreign chunk data")

    # ------------------------------------------------------------- payload
    def mmap_fields(self) -> Dict[str, np.memmap]:
        """Read-only ``np.memmap`` per field, shaped (B, *field_shape) like
        a resident ``BatchCache.fields`` — whole-plan consumers read through
        OS paging, resident set stays at what they actually touch."""
        for s in self.specs:
            if s.name not in self._mmaps:
                self._mmaps[s.name] = np.memmap(
                    self._field_path(s.name), dtype=np.dtype(s.dtype),
                    mode="r", shape=(self.num_batches,) + s.shape)
        return dict(self._mmaps)

    def read_batch(self, i: int) -> Dict[str, np.ndarray]:
        """Materialize + verify batch ``i``: one contiguous copy per field,
        crc32-checked against the index table. The ``batch_io`` fault point
        fires per attempt; transient ``OSError`` retries up to
        ``io_retries`` times, checksum mismatch raises
        :class:`PlanFormatError` immediately (corrupt data is a recovery
        decision, not a retry, DESIGN.md §12)."""
        if not 0 <= i < self.num_batches:
            raise IndexError(f"batch {i} out of range [0, {self.num_batches})")
        self.stats.bump("reads")
        last: Optional[BaseException] = None
        for attempt in range(self.io_retries + 1):
            try:
                self.faults.fire("batch_io", OSError)
                maps = self.mmap_fields()
                out = {s.name: np.array(maps[s.name][i]) for s in self.specs}
                break
            except OSError as e:
                last = e
                self._mmaps.clear()       # a stale map is part of the fault
                if attempt < self.io_retries:
                    self.stats.bump("io_retries")
                    continue
                raise
        for fi, s in enumerate(self.specs):
            got = zlib.crc32(np.ascontiguousarray(out[s.name]).tobytes())
            want = int(self.batch_crc32[i, fi])
            if got != want:
                self.stats.bump("crc_failures")
                raise PlanFormatError(
                    f"{self._field_path(s.name)}: checksum mismatch for "
                    f"batch {i} (stored {want:#010x}, computed {got:#010x}) "
                    f"— artifact corrupt")
        return out

    def __len__(self) -> int:
        return self.num_batches

    def payload_nbytes(self) -> int:
        """Logical size of the full batch payload (what a resident
        ``BatchCache`` would hold) — the number the RSS budget is up
        against."""
        return sum(s.rowbytes for s in self.specs) * self.num_batches

    # ---------------------------------------------------------------- plan
    def as_plan(self, resident_batches: int = 8) -> Plan:
        """A servable :class:`Plan` whose cache is a
        :class:`LazyBatchCache` over this store — drop-in for
        ``GNNInferenceEngine`` / ``GNNTrainer`` / ``PrefetchLoader``, with
        at most ``resident_batches`` batches materialized at once."""
        cache = LazyBatchCache(self, resident_batches=resident_batches)
        return Plan(cache=cache, schedule=_frozen(np.asarray(self.schedule)),
                    routing=self.routing, fingerprint=self.fingerprint,
                    meta=dict(self.meta), timings=dict(self.timings),
                    version=int(self.header.get("plan_version", 0)),
                    parent=self.header.get("parent", ""),
                    node_ids=None if self.node_ids is None
                    else _frozen(self.node_ids),
                    ppr=self.ppr,
                    batch_backend=None if self.batch_backend is None
                    else _frozen(self.batch_backend),
                    batch_block_f=None if self.batch_block_f is None
                    else _frozen(self.batch_block_f))


def write_store(path: str, plan: Plan, chunk_batches: int = 8) -> PlanStore:
    """Write an in-memory (resident) plan as a ``PlanStore`` directory —
    the bulk-export path (sharding uses it for resident shard builds; the
    streaming builder in ``repro.ooc.stream`` appends chunks as they are
    born instead). Chunked so the writer never buffers more than
    ``chunk_batches`` batches of payload beyond the source plan."""
    w = PlanStoreWriter(path)
    try:
        fields = plan.cache.fields
        meta = np.array([[m.get("nodes", 0), m.get("edges", 0),
                          m.get("outputs", 0)] for m in plan.cache.meta],
                        np.int64)
        for s in range(0, len(plan.cache), chunk_batches):
            e = min(s + chunk_batches, len(plan.cache))
            w.append({k: v[s:e] for k, v in fields.items()}, meta[s:e])
        w.finalize(plan.schedule, plan.routing, plan.fingerprint, plan.meta,
                   plan.timings, version=plan.version, parent=plan.parent,
                   node_ids=plan.node_ids, ppr=plan.ppr,
                   batch_backend=plan.batch_backend,
                   batch_block_f=plan.batch_block_f)
    except BaseException:
        w.abort()
        raise
    return PlanStore.open(path)


class LazyBatchCache:
    """``BatchCache``-shaped view over a :class:`PlanStore` with a bounded
    resident-batch budget (DESIGN.md §13).

    * ``cache[i]`` — verified batch dict through an LRU of at most
      ``resident_batches`` entries (cold batches evict; hot batches are
      free repeats). This is the path the engines and ``PrefetchLoader``
      take, so serving a plan 100x bigger than RAM holds O(budget) batch
      payload plus whatever the engine's own output LRU keeps.
    * ``cache.fields`` — the store's read-only memmaps, shaped exactly like
      resident ``BatchCache.fields`` (``check_routing``, ``batch_labels``
      and other metadata readers work unchanged; the OS pages in only the
      small fields they touch).
    * ``cache.stack(idx)`` — super-step staging through the LRU/verify
      path; ``repro.dist.data_parallel.stack_batches`` dispatches to it.
    """

    def __init__(self, store: PlanStore, resident_batches: int = 8):
        self.store = store
        self.resident_batches = max(1, int(resident_batches))
        self.num_batches = len(store)
        self.meta = [dict(nodes=int(n), edges=int(e), outputs=int(o))
                     for n, e, o in np.asarray(store.meta_counts)]
        self._lru: "OrderedDict[int, Dict[str, np.ndarray]]" = OrderedDict()
        self.stats = dict(loads=0, hits=0, evictions=0)

    def __len__(self) -> int:
        return self.num_batches

    @property
    def fields(self) -> Dict[str, np.memmap]:
        return self.store.mmap_fields()

    def __getitem__(self, i: int) -> Dict[str, np.ndarray]:
        i = int(i)
        hit = self._lru.get(i)
        if hit is not None:
            self._lru.move_to_end(i)
            self.stats["hits"] += 1
            return hit
        batch = self.store.read_batch(i)
        self.stats["loads"] += 1
        self._lru[i] = batch
        while len(self._lru) > self.resident_batches:
            self._lru.popitem(last=False)
            self.stats["evictions"] += 1
        return batch

    def stack(self, idx: Sequence[int]) -> Dict[str, np.ndarray]:
        """One super-step's stacked fields, each member verified through
        the LRU path (eviction keeps the worker at O(budget + group))."""
        dicts = [self[int(i)] for i in idx]
        return {k: np.stack([d[k] for d in dicts]) for k in dicts[0]}

    def nbytes(self) -> int:
        """Logical (fully-materialized) payload size — kept comparable with
        ``BatchCache.nbytes`` so memory accounting reports what the lazy
        cache AVOIDS holding; see ``resident_nbytes`` for what it does."""
        return self.store.payload_nbytes()

    def resident_nbytes(self) -> int:
        return sum(sum(v.nbytes for v in d.values())
                   for d in self._lru.values())

    def snapshot(self) -> Dict[str, int]:
        """Observability surface (§11 idiom): LRU traffic + store I/O."""
        return dict(self.stats, resident=len(self._lru),
                    resident_bytes=self.resident_nbytes(),
                    budget=self.resident_batches,
                    **{f"io_{k}": v for k, v in
                       self.store.stats.snapshot().items()})
