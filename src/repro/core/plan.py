"""The frozen, serializable preprocessing artifact — ``Plan`` (DESIGN.md §8).

The paper's headline amortization is that preprocessing is computed ONCE and
reused across models, seeds and runs. A ``Plan`` makes that reuse a
first-class artifact instead of a transient ``List[PaddedBatch]``: it bundles

* the contiguous :class:`~repro.core.batches.BatchCache` (padded batches,
  including BCSR tiles when built for the bcsr backend),
* the batch **schedule** (epoch-0 order from ``core.scheduling``),
* a **routing index** — the inverse map ``output node id → (batch, row)``
  that request-level serving (``repro.serve.gnn_engine``) needs to answer
  per-node queries without scanning batches,
* a config **fingerprint** (IBMB config + dataset signature + split + mode)
  so a loaded plan can never silently be served against the wrong
  config/graph, and
* the preprocessing **timings**, preserved for amortization accounting.

``Plan.save``/``Plan.load`` give a versioned on-disk format: one ``.npz``
(uncompressed by default — the dominant payload, the stacked batch cache, is
stored exactly as the in-memory contiguous blocks, so loading is one
sequential read per field; ``compress=True`` trades that for a zipped
archive, auto-detected on load) and the result is fully materialized (the
file handle is closed before ``load`` returns).

Plans are additionally **versioned along a refresh chain** (DESIGN.md §10):
``version`` counts refreshes since the original build and ``parent`` names
the fingerprint this plan was refreshed from (empty for a fresh build).
``core.update.PlanUpdater`` consumes a plan's ``node_ids`` (per-batch global
node membership) and ``ppr`` (the stored top-k influence scores) to map a
``GraphDelta`` to the minimal dirty-batch set instead of rebuilding the
world.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import zlib
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.batches import BatchCache, PaddedBatch
from repro.core.ppr import TopKPPR
from repro.faults import NO_FAULTS

PLAN_VERSION = 3
# still-loadable on-disk versions: v2 artifacts predate per-batch backend
# decisions (DESIGN.md §14) — they load with decision = the config backend.
COMPAT_PLAN_VERSIONS = (2, PLAN_VERSION)

# the on-disk per-batch backend-decision encoding (plan format v3). A fixed
# serialization table, deliberately independent of the runtime BACKENDS
# tuple's order — appending a backend must not re-number saved artifacts.
BACKEND_CODES = {"segment": 0, "bcsr": 1, "dense": 2}
BACKEND_NAMES = {v: k for k, v in BACKEND_CODES.items()}

_JSON_KEY = "__plan_json__"
_SCHEDULE_KEY = "schedule"
_ROUTE_NODES_KEY = "route/node_ids"
_ROUTE_BATCH_KEY = "route/batch"
_ROUTE_ROW_KEY = "route/row"
_NODE_IDS_KEY = "batch_node_ids"
_BATCH_BACKEND_KEY = "batch_backend"
_BATCH_BLOCK_F_KEY = "batch_block_f"
_PPR_ROOTS_KEY = "ppr/roots"
_PPR_INDICES_KEY = "ppr/indices"
_PPR_VALUES_KEY = "ppr/values"
_CACHE_PREFIX = "cache/"


class PlanFormatError(ValueError):
    """The on-disk artifact is not a plan this code can load (bad version,
    missing fields) or fails the fingerprint check."""


@dataclasses.dataclass(frozen=True)
class PlanHeader:
    """The metadata half of a saved plan — everything ``Plan.save`` put in
    the JSON header, WITHOUT the array payload. ``Plan.open`` returns one in
    O(metadata): routing decisions (does the fingerprint match? which split/
    mode/version is this? how many batches?) never need the stacked batch
    cache materialized."""

    path: str
    fingerprint: str
    version: int                 # refresh-chain version (Plan.version)
    parent: str
    meta: Dict
    timings: Dict[str, float]
    checksums: Dict[str, int]    # per-array crc32, payload integrity table

    @property
    def num_batches(self) -> int:
        return int(self.meta.get("num_batches", 0))


def _parse_header(raw: str, path: str) -> PlanHeader:
    """Validate + decode the JSON header string shared by ``Plan.open``
    (header-only) and ``Plan.load`` (full payload)."""
    header = json.loads(raw)
    version = header.get("version")
    if version not in COMPAT_PLAN_VERSIONS:
        raise PlanFormatError(
            f"{path}: plan version {version!r} unsupported "
            f"(this build reads versions {COMPAT_PLAN_VERSIONS})")
    return PlanHeader(
        path=path,
        fingerprint=header.get("fingerprint", ""),
        version=int(header.get("plan_version", 0)),
        parent=header.get("parent", ""),
        meta=header.get("meta", {}),
        timings=header.get("timings", {}),
        checksums={k: int(v) for k, v in header.get("checksums", {}).items()})


def plan_fingerprint(cfg_fields: Dict, dataset_sig: Dict, split: str,
                     mode: str) -> str:
    """Deterministic fingerprint of (IBMB config, dataset, split, mode).

    Two pipelines produce the same fingerprint iff a plan computed by one is
    byte-for-byte what the other would compute — so ``Plan.load`` can refuse
    artifacts from a different config/graph (DESIGN.md §8).
    """
    blob = json.dumps({"cfg": cfg_fields, "dataset": dataset_sig,
                       "split": split, "mode": mode},
                      sort_keys=True, default=str)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def _frozen(a: np.ndarray) -> np.ndarray:
    a = np.ascontiguousarray(a)
    a.setflags(write=False)
    return a


def _crc32(a: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(a).tobytes())


def encode_backends(names: Sequence[str]) -> np.ndarray:
    """Backend names → the (B,) int8 code array stored in a v3 plan."""
    return _frozen(np.array([BACKEND_CODES[str(n)] for n in names], np.int8))


def decode_backends(codes: np.ndarray) -> List[str]:
    return [BACKEND_NAMES[int(c)] for c in np.asarray(codes)]


@dataclasses.dataclass(frozen=True)
class RoutingIndex:
    """Inverse map ``global output node id → (batch index, output row)``.

    ``node_ids`` is sorted so lookup is a binary search; ``batch`` / ``row``
    are aligned with it. When an output node appears in several batches
    (resampling baselines), the first occurrence wins — any batch containing
    the node yields its logits.
    """

    node_ids: np.ndarray    # (M,) int64, sorted
    batch: np.ndarray       # (M,) int32
    row: np.ndarray         # (M,) int32 — row into the batch's output axis

    def __len__(self) -> int:
        return len(self.node_ids)

    def lookup(self, query: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """(batch, row) for every queried node id; KeyError on unknown ids."""
        q = np.asarray(query, dtype=np.int64).ravel()
        if len(self.node_ids) == 0:
            if len(q):
                raise KeyError(f"node ids not covered by this plan: "
                               f"{q[:8].tolist()}")
            return np.zeros(0, np.int32), np.zeros(0, np.int32)
        pos = np.searchsorted(self.node_ids, q)
        safe = np.minimum(pos, len(self.node_ids) - 1)
        bad = (pos >= len(self.node_ids)) | (self.node_ids[safe] != q)
        if bad.any():
            missing = q[bad]
            raise KeyError(f"node ids not covered by this plan: "
                           f"{missing[:8].tolist()}"
                           f"{'...' if len(missing) > 8 else ''}")
        return self.batch[safe], self.row[safe]

    def batch_occupancy(self, num_batches: int) -> np.ndarray:
        """``counts[b]`` = number of output nodes routed to batch ``b`` —
        the capacity hint the micro-batching window policy needs
        (DESIGN.md §11): once a window holds a full batch's worth of
        distinct routed rows for some batch, waiting longer cannot coalesce
        any more work into that batch's forward."""
        return _frozen(np.bincount(self.batch, minlength=num_batches)
                       .astype(np.int64))

    @staticmethod
    def from_batches(batches: Sequence[PaddedBatch]) -> "RoutingIndex":
        if not len(batches):
            return RoutingIndex(_frozen(np.zeros(0, np.int64)),
                                _frozen(np.zeros(0, np.int32)),
                                _frozen(np.zeros(0, np.int32)))
        return RoutingIndex.from_cache(
            np.stack([b.node_ids for b in batches]),
            np.stack([np.maximum(b.output_idx, 0) for b in batches]),
            np.stack([b.output_mask for b in batches]))

    @staticmethod
    def from_cache(node_ids: np.ndarray, output_idx: np.ndarray,
                   output_mask: np.ndarray) -> "RoutingIndex":
        """Build the routing index from stacked per-batch arrays — the one
        constructor behind ``from_batches`` (fresh builds) and the refresh
        path (``PlanUpdater``, where only some batches exist as
        ``PaddedBatch`` objects, DESIGN.md §10).

        node_ids:    (B, max_nodes) global ids, -1 pad
        output_idx:  (B, max_outputs) local indices (cache field, 0-clamped)
        output_mask: (B, max_outputs) nonzero for real output rows

        ``np.nonzero`` walks row-major, so entries come batch-major exactly
        like the old per-batch concatenation — the stable sort then makes
        the FIRST batch win for duplicated output nodes (resampling
        baselines).
        """
        b_all, r_all = np.nonzero(output_mask > 0)
        ids = node_ids[b_all, output_idx[b_all, r_all]].astype(np.int64)
        return RoutingIndex.from_triplets(ids, b_all, r_all)

    @staticmethod
    def from_triplets(ids: np.ndarray, batch: np.ndarray,
                      row: np.ndarray) -> "RoutingIndex":
        """Build the index from unsorted ``(id, batch, row)`` triplets in
        batch-major order — the tail of ``from_cache``, split out so the
        streaming builder (``repro.ooc.stream``, DESIGN.md §13) can emit
        triplets chunk by chunk and sort ONCE over the concatenation,
        guaranteed to produce the same index as a resident ``from_cache``
        over the full stacked arrays."""
        ids = np.asarray(ids, dtype=np.int64)
        b_all = np.asarray(batch)
        r_all = np.asarray(row)
        order = np.argsort(ids, kind="stable")
        ids = ids[order]
        bidx = b_all[order].astype(np.int32)
        rows = r_all[order].astype(np.int32)
        keep = np.ones(len(ids), bool)
        if len(ids) > 1:                          # drop duplicate node ids
            keep[1:] = ids[1:] != ids[:-1]
        return RoutingIndex(_frozen(ids[keep]), _frozen(bidx[keep]),
                            _frozen(rows[keep]))


@dataclasses.dataclass(frozen=True)
class Plan:
    """Frozen result of one preprocessing run (DESIGN.md §8).

    Built by :meth:`repro.core.pipeline.IBMBPipeline.plan`; consumed by
    ``GNNTrainer.fit/evaluate`` and ``repro.serve.gnn_engine``. Treat it as
    immutable — the schedule/routing arrays are write-protected, and the
    fingerprint binds the artifact to the config+graph that produced it.
    """

    cache: BatchCache
    schedule: np.ndarray
    routing: RoutingIndex
    fingerprint: str
    meta: Dict                      # split, mode, variant, num_classes, ...
    timings: Dict[str, float]
    # refresh-chain versioning (DESIGN.md §10): version counts refreshes
    # since the original build; parent is the fingerprint this plan was
    # refreshed from ("" for a fresh build).
    version: int = 0
    parent: str = ""
    # (B, max_nodes) global node id per batch row, -1 pad — the membership
    # table PlanUpdater needs to localize feature patches and structural
    # dirtiness. None only for hand-constructed plans.
    node_ids: Optional[np.ndarray] = None
    # stored top-k influence scores (node/random variants) — the warm state
    # push_appr_incremental refreshes instead of recomputing from scratch.
    ppr: Optional[TopKPPR] = None
    # plan format v3 (DESIGN.md §14): the plan-build autotuner's per-batch
    # execution decisions — backend code per batch (see BACKEND_CODES) and
    # the tuned bcsr feature-tile width (0 = untuned default). None on v2
    # artifacts and hand-built plans: decisions fall back to meta["backend"].
    batch_backend: Optional[np.ndarray] = None    # (B,) int8
    batch_block_f: Optional[np.ndarray] = None    # (B,) int32

    # ------------------------------------------------------------- views
    @property
    def num_batches(self) -> int:
        return len(self.cache)

    def __len__(self) -> int:
        return len(self.cache)

    def batch_occupancy(self) -> np.ndarray:
        """Per-batch count of routed output rows (DESIGN.md §11) — how many
        distinct rows of precomputed batch ``b`` request traffic can ever
        address. The async serving tier dispatches a micro-batching window
        early when pending requests cover a full batch's worth of rows."""
        return self.routing.batch_occupancy(len(self.cache))

    def batch_labels(self) -> List[np.ndarray]:
        """Per-batch real (unpadded) output labels — what the scheduler
        consumes to re-derive per-epoch orders."""
        lab = self.cache.fields["labels"]
        msk = self.cache.fields["output_mask"]
        return [lab[i][msk[i] > 0] for i in range(len(self.cache))]

    def batch_backends(self) -> List[str]:
        """Per-batch backend decision (DESIGN.md §14). v2 plans and
        hand-built plans carry no decisions — every batch falls back to the
        backend the plan was configured with (``meta["backend"]``), which is
        exactly what those plans executed before auto dispatch existed."""
        if self.batch_backend is not None:
            return decode_backends(self.batch_backend)
        fallback = str(self.meta.get("backend", "segment") or "segment")
        if fallback not in BACKEND_CODES:
            fallback = "segment"
        return [fallback] * len(self.cache)

    def batch_block_fs(self) -> np.ndarray:
        """Per-batch tuned bcsr feature-tile width; 0 = untuned default."""
        if self.batch_block_f is not None:
            return np.asarray(self.batch_block_f, np.int32)
        return np.zeros(len(self.cache), np.int32)

    def nbytes(self) -> int:
        extra = 0 if self.node_ids is None else self.node_ids.nbytes
        if self.ppr is not None:
            extra += (self.ppr.roots.nbytes + self.ppr.indices.nbytes +
                      self.ppr.values.nbytes)
        return (self.cache.nbytes() + self.schedule.nbytes +
                self.routing.node_ids.nbytes + self.routing.batch.nbytes +
                self.routing.row.nbytes + extra)

    def supersteps(self, world: int) -> List[Tuple[np.ndarray, np.ndarray]]:
        """Group this plan's precomputed schedule into `world`-sized
        super-steps for data-parallel execution (DESIGN.md §9): a list of
        ``(batch indices, weights)`` pairs where the ragged tail repeats
        the last real batch with weight 0. All batches of a plan share one
        padded shape bucket (the BatchCache invariant), which is what makes
        the stacked super-step a single static-shape executable."""
        from repro.dist.data_parallel import superstep_indices
        return superstep_indices(self.schedule, world)

    # ------------------------------------------------------ construction
    @staticmethod
    def from_batches(batches: Sequence[PaddedBatch],
                     schedule: Optional[np.ndarray] = None,
                     fingerprint: str = "",
                     meta: Optional[Dict] = None,
                     timings: Optional[Dict[str, float]] = None,
                     cache: Optional[BatchCache] = None,
                     version: int = 0,
                     parent: str = "",
                     ppr: Optional[TopKPPR] = None,
                     batch_backend: Optional[np.ndarray] = None,
                     batch_block_f: Optional[np.ndarray] = None) -> "Plan":
        """Wrap a raw batch list (from IBMB or any baseline batcher) into a
        plan — the back-compat bridge from the list-based API."""
        cache = cache or BatchCache(batches)
        sched = np.arange(len(cache), dtype=np.int64) if schedule is None \
            else np.asarray(schedule, dtype=np.int64)
        node_ids = _frozen(np.stack([b.node_ids for b in batches]))
        return Plan(cache=cache, schedule=_frozen(sched),
                    routing=RoutingIndex.from_batches(batches),
                    fingerprint=fingerprint, meta=dict(meta or {}),
                    timings=dict(timings or {}),
                    version=version, parent=parent,
                    node_ids=node_ids, ppr=ppr,
                    batch_backend=None if batch_backend is None
                    else _frozen(np.asarray(batch_backend, np.int8)),
                    batch_block_f=None if batch_block_f is None
                    else _frozen(np.asarray(batch_block_f, np.int32)))

    # ------------------------------------------------------- persistence
    def save(self, path: str, compress: bool = False,
             faults=NO_FAULTS) -> None:
        """Versioned on-disk format: one npz. Cache fields are stored under
        ``cache/``; schedule/routing/membership/ppr/meta alongside.
        ``compress=True`` writes a zipped npz (smaller artifact, slower
        sequential load); ``load`` auto-detects either.

        The write is ATOMIC (DESIGN.md §12): bytes go to ``path + ".tmp"``
        and are published with ``os.replace``, so a crash mid-save can never
        leave a truncated artifact at ``path`` — readers see the old plan or
        the new one, nothing in between. The header additionally records a
        crc32 per array so ``load`` detects payload corruption that slips
        past the zip layer. ``faults`` is the injection hook for the
        ``plan_io`` point."""
        meta_counts = np.array(
            [[m.get("nodes", 0), m.get("edges", 0), m.get("outputs", 0)]
             for m in self.cache.meta], np.int64)
        arrays = {
            _SCHEDULE_KEY: np.asarray(self.schedule, np.int64),
            _ROUTE_NODES_KEY: self.routing.node_ids,
            _ROUTE_BATCH_KEY: self.routing.batch,
            _ROUTE_ROW_KEY: self.routing.row,
            _CACHE_PREFIX + BatchCache._META_KEY: meta_counts,
        }
        if self.node_ids is not None:
            arrays[_NODE_IDS_KEY] = np.asarray(self.node_ids, np.int32)
        if self.batch_backend is not None:
            arrays[_BATCH_BACKEND_KEY] = np.asarray(self.batch_backend,
                                                    np.int8)
        if self.batch_block_f is not None:
            arrays[_BATCH_BLOCK_F_KEY] = np.asarray(self.batch_block_f,
                                                    np.int32)
        if self.ppr is not None:
            arrays[_PPR_ROOTS_KEY] = self.ppr.roots
            arrays[_PPR_INDICES_KEY] = self.ppr.indices
            arrays[_PPR_VALUES_KEY] = self.ppr.values
        for k, v in self.cache.fields.items():
            arrays[_CACHE_PREFIX + k] = v
        header = json.dumps({
            "version": PLAN_VERSION,
            "fingerprint": self.fingerprint,
            "plan_version": int(self.version),
            "parent": self.parent,
            "meta": self.meta,
            "timings": {k: float(v) for k, v in self.timings.items()},
            "checksums": {k: _crc32(v) for k, v in arrays.items()},
        })
        arrays[_JSON_KEY] = np.array(header)
        faults.fire("plan_io", OSError)
        # savez through an open file object: numpy appends ".npz" to bare
        # PATHS but leaves file objects alone, which keeps the tmp name
        # exact for the os.replace publish.
        tmp = path + ".tmp"
        try:
            with open(tmp, "wb") as f:
                (np.savez_compressed if compress else np.savez)(f, **arrays)
            os.replace(tmp, path)
        except BaseException:
            try:
                os.remove(tmp)
            except OSError:
                pass
            raise

    @staticmethod
    def open(path: str, expect_fingerprint: Optional[str] = None,
             faults=NO_FAULTS) -> PlanHeader:
        """Read ONLY the metadata header of a saved plan — O(metadata), not
        O(payload). ``np.load`` on an npz is lazy (it reads the zip
        directory; members decompress on access), so pulling just the JSON
        header never touches the stacked batch cache. This is what shard
        manifests, routing tiers and ``auto_resume``-style pickers should
        use to DECIDE about an artifact before paying to materialize it
        (``Plan.load`` used to be the only option and eagerly read every
        array). The payload checksums are returned, not verified — only
        ``load`` reads the arrays they describe."""
        faults.fire("plan_io", OSError)
        try:
            with np.load(path, allow_pickle=False) as z:
                if _JSON_KEY not in z.files:
                    raise PlanFormatError(f"{path}: not a Plan artifact "
                                          f"(missing {_JSON_KEY})")
                raw = str(z[_JSON_KEY])
        except (FileNotFoundError, PlanFormatError):
            raise
        except Exception as e:
            raise PlanFormatError(
                f"{path}: corrupt or truncated plan artifact "
                f"({type(e).__name__}: {e})") from e
        header = _parse_header(raw, path)
        if expect_fingerprint is not None and \
                header.fingerprint != expect_fingerprint:
            raise PlanFormatError(
                f"{path}: fingerprint mismatch — artifact was built from a "
                f"different config/dataset/split/mode (got "
                f"{header.fingerprint!r}, expected {expect_fingerprint!r})")
        return header

    @staticmethod
    def load(path: str, expect_fingerprint: Optional[str] = None,
             faults=NO_FAULTS) -> "Plan":
        """Load a saved plan. ``expect_fingerprint`` (or
        ``IBMBPipeline.load_plan``) rejects artifacts produced by a
        different config/dataset/split/mode. A truncated or byte-flipped
        artifact raises :class:`PlanFormatError` (DESIGN.md §12) — caught by
        the zip member CRC on read or by the header's per-array checksums —
        never a half-loaded plan. ``FileNotFoundError`` still propagates
        as-is (absent and corrupt are different recovery decisions)."""
        faults.fire("plan_io", OSError)
        try:
            with np.load(path, allow_pickle=False) as z:
                arrays = {k: z[k] for k in z.files}   # materialize: zip CRC
        except FileNotFoundError:
            raise
        except PlanFormatError:
            raise
        except Exception as e:
            # zipfile.BadZipFile / zlib.error / ValueError / EOFError / ...
            # — all mean the same thing to a caller: the artifact is not
            # loadable. Normalize so recovery code has ONE type to catch.
            raise PlanFormatError(
                f"{path}: corrupt or truncated plan artifact "
                f"({type(e).__name__}: {e})") from e
        return Plan._load_from(arrays, path, expect_fingerprint)

    @staticmethod
    def _load_from(z: Dict[str, np.ndarray], path: str,
                   expect_fingerprint: Optional[str]) -> "Plan":
        if _JSON_KEY not in z:
            raise PlanFormatError(f"{path}: not a Plan artifact "
                                  f"(missing {_JSON_KEY})")
        header = _parse_header(str(z[_JSON_KEY]), path)
        for k, want in header.checksums.items():
            if k not in z:
                raise PlanFormatError(
                    f"{path}: plan artifact is missing checksummed "
                    f"field {k!r}")
            got = _crc32(z[k])
            if got != int(want):
                raise PlanFormatError(
                    f"{path}: checksum mismatch for {k!r} (stored "
                    f"{int(want):#010x}, computed {got:#010x}) — "
                    f"artifact corrupt")
        fingerprint = header.fingerprint
        if expect_fingerprint is not None and fingerprint != expect_fingerprint:
            raise PlanFormatError(
                f"{path}: fingerprint mismatch — artifact was built from a "
                f"different config/dataset/split/mode (got {fingerprint!r}, "
                f"expected {expect_fingerprint!r}); re-run "
                f"IBMBPipeline.plan() or load with the matching pipeline")
        required = (_SCHEDULE_KEY, _ROUTE_NODES_KEY, _ROUTE_BATCH_KEY,
                    _ROUTE_ROW_KEY, _CACHE_PREFIX + BatchCache._META_KEY)
        missing = [k for k in required if k not in z]
        if missing:
            raise PlanFormatError(
                f"{path}: plan artifact is missing fields {missing}")
        fields = {k[len(_CACHE_PREFIX):]: z[k] for k in z
                  if k.startswith(_CACHE_PREFIX)
                  and k != _CACHE_PREFIX + BatchCache._META_KEY}
        if not fields:
            raise PlanFormatError(f"{path}: plan has no cache fields")
        cache = BatchCache.from_fields(
            fields, z[_CACHE_PREFIX + BatchCache._META_KEY])
        routing = RoutingIndex(_frozen(z[_ROUTE_NODES_KEY]),
                               _frozen(z[_ROUTE_BATCH_KEY]),
                               _frozen(z[_ROUTE_ROW_KEY]))
        node_ids = _frozen(z[_NODE_IDS_KEY]) if _NODE_IDS_KEY in z \
            else None
        ppr = None
        if _PPR_ROOTS_KEY in z:
            ppr = TopKPPR(roots=z[_PPR_ROOTS_KEY],
                          indices=z[_PPR_INDICES_KEY],
                          values=z[_PPR_VALUES_KEY])
        # v3 decision arrays; absent on v2 artifacts (batch_backends() then
        # falls back to the config backend in meta)
        batch_backend = _frozen(z[_BATCH_BACKEND_KEY]) \
            if _BATCH_BACKEND_KEY in z else None
        batch_block_f = _frozen(z[_BATCH_BLOCK_F_KEY]) \
            if _BATCH_BLOCK_F_KEY in z else None
        return Plan(cache=cache, schedule=_frozen(z[_SCHEDULE_KEY]),
                    routing=routing, fingerprint=fingerprint,
                    meta=header.meta, timings=header.timings,
                    version=header.version, parent=header.parent,
                    node_ids=node_ids, ppr=ppr,
                    batch_backend=batch_backend,
                    batch_block_f=batch_block_f)


def check_routing(plan: Plan) -> Dict[str, int]:
    """Validate the routing-index invariants of a plan; raise ValueError on
    the first violation, return summary counts otherwise.

    Invariants (DESIGN.md §8/§10) — checked after build, load and refresh:

    * ``node_ids`` strictly increasing (sorted AND duplicate-free, so binary
      search is well-defined and the map is injective);
    * every entry addresses a real slot: batch in range, row in range, the
      row's ``output_mask`` set;
    * the map is bijective onto the plan's output nodes: the sorted routing
      ids equal the sorted distinct global ids over all real output rows
      (requires ``plan.node_ids``; membership-less plans check coverage
      count only);
    * when membership is available, the addressed slot actually holds the
      node: ``node_ids[b][output_idx[b, r]] == id``.
    """
    r = plan.routing
    ids = np.asarray(r.node_ids)
    if len(ids) and not np.all(ids[1:] > ids[:-1]):
        raise ValueError("routing node_ids not strictly increasing")
    if len(r.batch) != len(ids) or len(r.row) != len(ids):
        raise ValueError("routing arrays are not aligned")
    out_mask = plan.cache.fields["output_mask"]
    out_idx = plan.cache.fields["output_idx"]
    nb, mo = out_mask.shape
    if len(ids) and (r.batch.min() < 0 or r.batch.max() >= nb):
        raise ValueError(f"routing batch index out of range [0, {nb})")
    if len(ids) and (r.row.min() < 0 or r.row.max() >= mo):
        raise ValueError(f"routing row index out of range [0, {mo})")
    if len(ids) and not np.all(out_mask[r.batch, r.row] > 0):
        raise ValueError("routing entry addresses a padded output row")
    if plan.node_ids is not None:
        got = plan.node_ids[r.batch, out_idx[r.batch, r.row]]
        if not np.array_equal(got.astype(np.int64), ids):
            raise ValueError("routing entry does not address its node: "
                             "node_ids[batch][output_idx[batch, row]] != id")
        b_all, r_all = np.nonzero(out_mask > 0)
        covered = np.unique(
            plan.node_ids[b_all, out_idx[b_all, r_all]].astype(np.int64))
        if not np.array_equal(covered, ids):
            raise ValueError(
                f"routing is not bijective over output nodes: plan holds "
                f"{len(covered)} distinct output ids, routing maps {len(ids)}")
    else:
        if len(ids) > int((out_mask > 0).sum()):
            raise ValueError("routing maps more ids than real output rows")
    return {"entries": int(len(ids)), "batches": int(nb)}
