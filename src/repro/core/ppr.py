"""Personalized PageRank as the influence-score approximation (paper Sec. 3).

Two production paths, mirroring the paper's two IBMB instantiations:

* ``push_appr`` — node-wise approximate PPR (Andersen/Chung/Lang push).
  TPU/vector adaptation: instead of the sequential per-node push queue of the
  original (numba on CPU in the paper), we run *frontier-synchronous sweeps*:
  every residual entry above the ε·deg(v) threshold is pushed simultaneously;
  one sweep is one sparse matvec. This is the data-parallel formulation of
  push and keeps the classic guarantee (all residuals < ε·deg on
  convergence ⇒ per-entry error ≤ ε·deg). The paper itself uses the same
  relaxation ("push-flow algorithm with a fixed number of iterations").

* ``topic_sensitive_ppr`` — batch-wise PPR via power iteration with a batch
  teleport vector (the paper uses 50 power iterations). Dense (b, N) iterate;
  each step is a sparse matmul — this maps directly onto the TPU SpMM kernel.

``dense_ppr`` is the closed-form oracle used by tests.

Dynamic graphs (DESIGN.md §10): ``push_appr`` is *local* — a capped
frontier-synchronous push from root ``s`` only ever reads edges and degrees
inside the ``max_iters``-hop ball around ``s``. ``ppr_dirty_roots`` exploits
that to bound which roots a ``GraphDelta`` can affect (BFS from the edited
endpoints in the old AND new adjacency), and ``push_appr_incremental``
re-pushes ONLY those roots, splicing every other root's stored top-k row
through unchanged. For an untouched root the warm-started push would
perform zero pushes — its stored state already satisfies the residual
invariant on the new graph — so skipping it entirely is the exact form of
the warm start, and the refreshed result is bit-identical to a from-scratch
``push_appr`` on the new graph.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np
import scipy.sparse as sp

from repro.graph.csr import CSRGraph, sorted_lookup


@dataclasses.dataclass
class TopKPPR:
    """Sparse per-root top-k PPR result.

    roots:   (R,) int32 root (output) node ids
    indices: (R, k) int32 neighbor ids (padded with -1)
    values:  (R, k) float32 PPR scores (padded with 0)
    """

    roots: np.ndarray
    indices: np.ndarray
    values: np.ndarray

    @property
    def k(self) -> int:
        return self.indices.shape[1]

    def row(self, i: int) -> Tuple[np.ndarray, np.ndarray]:
        m = self.indices[i] >= 0
        return self.indices[i][m], self.values[i][m]


def row_stochastic(g: CSRGraph) -> sp.csr_matrix:
    """P = D^{-1} A on the (assumed undirected) graph with unit weights.

    Built directly from the graph's CSR structure: row i's entries are all
    ``1/deg(i)``, so the data vector is ``np.repeat(dinv, deg)`` and the
    ``indices``/``indptr`` buffers are SHARED with ``g`` (``copy=False``) —
    no intermediate adjacency copy. That matters out of core (DESIGN.md
    §13): when ``g``'s arrays are ``np.memmap``-backed, the only resident
    allocation this makes is the O(E) float64 data vector; the old
    ``diag @ A`` formulation materialized two full adjacency copies.
    Values are bit-identical to the old path (same ``dinv`` doubles, same
    sorted CSR structure)."""
    deg = np.diff(g.indptr).astype(np.int64)
    dinv = np.where(deg > 0, 1.0 / np.maximum(deg.astype(np.float64), 1e-12),
                    0.0)
    data = np.repeat(dinv, deg)
    return sp.csr_matrix((data, g.indices, g.indptr),
                         shape=(g.num_nodes, g.num_nodes), copy=False)


_row_stochastic = row_stochastic      # internal alias (pre-§13 name)


def push_appr(
    g: CSRGraph,
    roots: np.ndarray,
    alpha: float = 0.25,
    eps: float = 2e-4,
    max_iters: int = 3,
    topk: Optional[int] = None,
    chunk: int = 4096,
) -> TopKPPR:
    """Frontier-synchronous push APPR for a set of root nodes.

    Sweep update (α-teleport PPR, residual form):
        active = r ⊙ 1[r > ε·deg]
        p += α · active
        r  = (r − active) + (1−α) · active @ P
    After convergence every residual satisfies r(v) ≤ ε·deg(v), giving the
    standard per-entry approximation bound. The paper caps iterations (3),
    we do the same by default.
    """
    roots = np.asarray(roots, dtype=np.int64)
    n = g.num_nodes
    deg = np.maximum(g.degrees().astype(np.float64), 1.0)
    P = _row_stochastic(g)
    k = topk if topk is not None else 32

    out_idx = np.full((len(roots), k), -1, dtype=np.int32)
    out_val = np.zeros((len(roots), k), dtype=np.float32)

    for c0 in range(0, len(roots), chunk):
        rts = roots[c0:c0 + chunk]
        m = len(rts)
        r = sp.csr_matrix(
            (np.ones(m, np.float64), (np.arange(m), rts)), shape=(m, n))
        p = sp.csr_matrix((m, n), dtype=np.float64)
        for _ in range(max_iters):
            if r.nnz == 0:
                break
            thresh = eps * deg[r.indices]
            mask = r.data > thresh
            if not mask.any():
                break
            active = r.copy()
            active.data = np.where(mask, r.data, 0.0)
            active.eliminate_zeros()
            p = p + alpha * active
            r = (r - active) + (1.0 - alpha) * (active @ P)
            r.eliminate_zeros()
        p = p.tocsr()
        p.sum_duplicates()
        # Vectorized per-row top-k (indptr-segmented): ONE lexsort orders all
        # nonzeros by (row asc, value desc); the within-row rank is then just
        # position − row start, and `rank < k` keeps each row's top-k. This
        # replaces the per-root Python loop that dominated preprocessing on
        # large root sets — preprocessing is the paper's amortized cost.
        lens = np.diff(p.indptr)
        if p.nnz:
            row_ids = np.repeat(np.arange(m), lens)
            order = np.lexsort((-p.data, row_ids))
            rows_s = row_ids[order]          # grouped by row, values desc
            rank = np.arange(p.nnz) - p.indptr[rows_s]
            keep = rank < k
            out_idx[c0 + rows_s[keep], rank[keep]] = p.indices[order][keep]
            out_val[c0 + rows_s[keep], rank[keep]] = p.data[order][keep]
        # isolated roots keep themselves with full mass
        empty = np.where(lens == 0)[0]
        out_idx[c0 + empty, 0] = rts[empty]
        out_val[c0 + empty, 0] = 1.0
    return TopKPPR(roots=roots.astype(np.int32), indices=out_idx, values=out_val)


def _hop_neighbors(g: CSRGraph, nodes: np.ndarray) -> np.ndarray:
    """Union of out-neighbors of `nodes` (vectorized CSR row gather)."""
    nodes = np.asarray(nodes, dtype=np.int64)
    starts = g.indptr[nodes]
    counts = (g.indptr[nodes + 1] - starts).astype(np.int64)
    total = int(counts.sum())
    if total == 0:
        return np.zeros(0, np.int64)
    offsets = np.repeat(starts, counts) + (
        np.arange(total, dtype=np.int64)
        - np.repeat(np.cumsum(counts) - counts, counts))
    return np.unique(g.indices[offsets].astype(np.int64))


def ppr_dirty_roots(
    roots: np.ndarray,
    touched: np.ndarray,
    graphs: Sequence[CSRGraph],
    hops: int,
) -> np.ndarray:
    """Boolean mask over `roots`: which roots a structural edit can affect.

    A capped push from root ``s`` only ever reads adjacency rows and
    degrees of nodes within ``max_iters−1`` hops of ``s`` (the sweep-``t``
    residual is supported on the ``t``-hop ball, and the LAST sweep reads
    rows of its active set), so its result can only change if an edited
    endpoint lies within ``max_iters−1`` hops of ``s`` — pass
    ``hops = max_iters − 1``. We BFS ``hops`` levels from ``touched`` in
    every supplied adjacency (old AND new graph — either execution could
    have read the edit) and flag the reached roots (DESIGN.md §10).
    """
    roots = np.asarray(roots, dtype=np.int64)
    touched = np.unique(np.asarray(touched, dtype=np.int64))
    if len(touched) == 0 or len(roots) == 0:
        return np.zeros(len(roots), dtype=bool)
    n = max(g.num_nodes for g in graphs)
    reached = np.zeros(n, dtype=bool)
    in_range = touched[touched < n]
    reached[in_range] = True
    frontier = in_range
    for _ in range(hops):
        if len(frontier) == 0:
            break
        nxt = np.unique(np.concatenate(
            [_hop_neighbors(g, frontier[frontier < g.num_nodes])
             for g in graphs] or [np.zeros(0, np.int64)]))
        frontier = nxt[~reached[nxt]]
        reached[frontier] = True
    safe = np.minimum(roots, n - 1)
    return np.where(roots < n, reached[safe], False)


def push_appr_incremental(
    g: CSRGraph,
    roots: np.ndarray,
    prev: TopKPPR,
    dirty: np.ndarray,
    alpha: float = 0.25,
    eps: float = 2e-4,
    max_iters: int = 3,
    topk: Optional[int] = None,
    chunk: int = 4096,
) -> TopKPPR:
    """Refresh a stored ``TopKPPR`` after a graph delta (DESIGN.md §10).

    ``dirty`` is a boolean mask over ``roots`` (typically from
    ``ppr_dirty_roots``, plus any roots absent from ``prev``). Dirty roots
    are re-pushed on the new graph ``g`` with the exact same capped push as
    ``push_appr`` — per-root results are independent of chunk composition,
    so the spliced result is bit-identical to a full from-scratch
    ``push_appr(g, roots, ...)``. Clean roots reuse their stored row with
    zero work: their warm-started push would terminate immediately.
    """
    roots = np.asarray(roots, dtype=np.int64)
    dirty = np.asarray(dirty, dtype=bool).copy()
    k = topk if topk is not None else prev.k
    # align stored rows by root id; roots prev never solved are dirty
    prev_order = np.argsort(prev.roots, kind="stable")
    prev_sorted = prev.roots[prev_order].astype(np.int64)
    safe, known = sorted_lookup(prev_sorted, roots)
    dirty |= ~known
    if prev.k != k:          # stored top-k width no longer matches config
        dirty[:] = True

    out_idx = np.full((len(roots), k), -1, dtype=np.int32)
    out_val = np.zeros((len(roots), k), dtype=np.float32)
    clean = ~dirty
    if clean.any():
        src_rows = prev_order[safe[clean]]
        out_idx[clean] = prev.indices[src_rows]
        out_val[clean] = prev.values[src_rows]
    if dirty.any():
        fresh = push_appr(g, roots[dirty], alpha=alpha, eps=eps,
                          max_iters=max_iters, topk=k, chunk=chunk)
        out_idx[dirty] = fresh.indices
        out_val[dirty] = fresh.values
    return TopKPPR(roots=roots.astype(np.int32), indices=out_idx,
                   values=out_val)


def topic_sensitive_ppr(
    g: CSRGraph,
    batches: Sequence[np.ndarray],
    alpha: float = 0.25,
    num_iters: int = 50,
) -> np.ndarray:
    """Batch-wise (topic-sensitive) PPR: π_b = α t_b + (1−α) π_b P.

    t_b is uniform over the output nodes of batch b. Returns dense (b, N).
    """
    n = g.num_nodes
    P = _row_stochastic(g)
    Pt = P.T.tocsr()   # so that (π P) = (Pᵀ πᵀ)ᵀ
    b = len(batches)
    t = np.zeros((b, n), dtype=np.float64)
    for i, nodes in enumerate(batches):
        nodes = np.asarray(nodes)
        if len(nodes):
            t[i, nodes] = 1.0 / len(nodes)
    pi = t.copy()
    for _ in range(num_iters):
        pi = alpha * t + (1.0 - alpha) * (Pt @ pi.T).T
    return pi.astype(np.float32)


def dense_ppr(g: CSRGraph, alpha: float = 0.25) -> np.ndarray:
    """Closed form Π = α (I − (1−α) D^{-1}A)^{-1}. Oracle for tests (small N)."""
    n = g.num_nodes
    P = _row_stochastic(g).toarray()
    return alpha * np.linalg.inv(np.eye(n) - (1.0 - alpha) * P)


def heat_kernel(
    g: CSRGraph,
    batches: Sequence[np.ndarray],
    t: float = 3.0,
    num_terms: int = 30,
) -> np.ndarray:
    """Heat-kernel diffusion e^{-t} Σ_j t^j/j! P^j  (paper Table 5 alternative)."""
    n = g.num_nodes
    P = _row_stochastic(g)
    Pt = P.T.tocsr()
    b = len(batches)
    v = np.zeros((b, n), dtype=np.float64)
    for i, nodes in enumerate(batches):
        nodes = np.asarray(nodes)
        if len(nodes):
            v[i, nodes] = 1.0 / len(nodes)
    acc = v * np.exp(-t)
    term = v.copy()
    coef = np.exp(-t)
    for j in range(1, num_terms):
        term = (Pt @ term.T).T
        coef = coef * t / j
        acc = acc + coef * term
    return acc.astype(np.float32)
