"""Versioned plan updates for dynamic graphs (DESIGN.md §10).

IBMB's whole advantage is that batches are precomputed once and reused; a
frozen ``Plan`` must therefore survive a *living* graph without rebuild-the-
world re-preprocessing. This module makes updates first-class:

* :class:`GraphDelta` — a declarative record of change: feature row updates,
  undirected edge inserts/deletes, label updates, per-split output-set
  adds/removes. ``delta.apply(ds)`` produces the post-delta dataset
  (copy-on-write; GCN renormalization recomputed only for structural
  deltas).
* :class:`PlanUpdater` — maps a delta to the minimal dirty-batch set using
  the incremental PPR push (``core.ppr.push_appr_incremental``: re-push
  only roots within ``push_iters`` hops of an edited endpoint, splice every
  other stored top-k row through bit-identically), rebuilds exactly those
  batches inside the parent plan's padded caps, patches payload arrays
  (features/labels) in place for batches whose influence-selected aux set
  did not change, and emits a new :class:`~repro.core.plan.Plan` with a
  bumped ``version`` and parent fingerprint.
* :class:`PlanDelta` — the audit record of one refresh: which batches were
  rebuilt / patched / untouched, how many roots were re-pushed, per-stage
  timings, and the fallback reason when the fast path could not apply.

``IBMBPipeline.refresh(plan, delta)`` is the user-facing wrapper and
``GNNInferenceEngine.swap(plan, delta)`` consumes the audit record to
invalidate only the dirty LRU entries (zero-downtime hot swap).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core import autotune
from repro.core.aux_selection import batch_wise_aux, node_wise_aux
from repro.core.batches import BatchCache, PaddedBatch, build_batches
from repro.core.partition import (
    graph_partition, ppr_distance_partition, random_partition)
from repro.core.plan import Plan, RoutingIndex, _frozen, encode_backends
from repro.core.ppr import TopKPPR, ppr_dirty_roots, push_appr, \
    push_appr_incremental
from repro.core.scheduling import make_schedule
from repro.graph.csr import CSRGraph, gcn_preprocess, sorted_lookup


def _ids(a, dtype=np.int64) -> np.ndarray:
    return np.asarray(a, dtype=dtype).ravel()


@dataclasses.dataclass(frozen=True)
class GraphDelta:
    """One batch of changes to a :class:`~repro.graph.datasets.GraphDataset`.

    feat_nodes/feat_values:   (U,) node ids / (U, F) replacement feature rows
    edge_inserts/edge_deletes:(E, 2) undirected pairs (both directions applied)
    label_nodes/label_values: (L,) node ids / (L,) replacement labels
    output_adds/output_removes: per-split node-id arrays (output-set changes)
    """

    feat_nodes: Optional[np.ndarray] = None
    feat_values: Optional[np.ndarray] = None
    edge_inserts: Optional[np.ndarray] = None
    edge_deletes: Optional[np.ndarray] = None
    label_nodes: Optional[np.ndarray] = None
    label_values: Optional[np.ndarray] = None
    output_adds: Mapping[str, np.ndarray] = \
        dataclasses.field(default_factory=dict)
    output_removes: Mapping[str, np.ndarray] = \
        dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if (self.feat_nodes is None) != (self.feat_values is None):
            raise ValueError("feat_nodes and feat_values must come together")
        if (self.label_nodes is None) != (self.label_values is None):
            raise ValueError("label_nodes and label_values must come together")
        for name in ("feat_nodes", "label_nodes"):
            ids = getattr(self, name)
            if ids is not None and len(np.unique(ids)) != len(_ids(ids)):
                # duplicates are ambiguous: apply()'s fancy assignment keeps
                # the LAST occurrence while a membership patch would take
                # the first — refuse rather than silently diverge
                raise ValueError(f"{name} contains duplicate node ids")
        for name in ("edge_inserts", "edge_deletes"):
            e = getattr(self, name)
            if e is not None and (np.asarray(e).ndim != 2
                                  or np.asarray(e).shape[1] != 2):
                raise ValueError(f"{name} must be an (E, 2) array of pairs")

    # ------------------------------------------------------------- queries
    @property
    def is_structural(self) -> bool:
        """True iff the delta edits edges (degrees / GCN weights move)."""
        return bool(
            (self.edge_inserts is not None and len(self.edge_inserts)) or
            (self.edge_deletes is not None and len(self.edge_deletes)))

    def touched_nodes(self) -> np.ndarray:
        """Endpoints of every edited edge — the seed of all structural
        dirtiness (an edge edit moves the degrees, hence the GCN weights,
        of exactly its endpoints)."""
        parts = [np.asarray(e, dtype=np.int64).ravel()
                 for e in (self.edge_inserts, self.edge_deletes)
                 if e is not None and len(e)]
        return np.unique(np.concatenate(parts)) if parts \
            else np.zeros(0, np.int64)

    def summary(self) -> Dict[str, int]:
        def n(a):
            return 0 if a is None else len(a)
        return {
            "feat_updates": n(self.feat_nodes),
            "edge_inserts": n(self.edge_inserts),
            "edge_deletes": n(self.edge_deletes),
            "label_updates": n(self.label_nodes),
            "output_adds": sum(len(v) for v in self.output_adds.values()),
            "output_removes":
                sum(len(v) for v in self.output_removes.values()),
        }

    # -------------------------------------------------------------- apply
    def _check_range(self, name: str, ids: np.ndarray, n: int) -> np.ndarray:
        ids = _ids(ids)
        if len(ids) and (ids.min() < 0 or ids.max() >= n):
            # a negative id would silently wrap in fancy indexing while the
            # membership patch skips it — an undetectable refresh divergence
            raise ValueError(f"{name} node ids out of range [0, {n})")
        return ids

    def apply(self, ds):
        """Post-delta dataset (copy-on-write — `ds` is never mutated)."""
        n = ds.num_nodes
        features, labels = ds.features, ds.labels
        if self.feat_nodes is not None and len(self.feat_nodes):
            nodes = self._check_range("feat_nodes", self.feat_nodes, n)
            vals = np.asarray(self.feat_values, dtype=features.dtype)
            if vals.shape != (len(nodes), features.shape[1]):
                raise ValueError(
                    f"feat_values shape {vals.shape} != "
                    f"({len(nodes)}, {features.shape[1]})")
            features = features.copy()
            features[nodes] = vals
        if self.label_nodes is not None and len(self.label_nodes):
            labels = labels.copy()
            labels[self._check_range("label_nodes", self.label_nodes, n)] = \
                np.asarray(self.label_values, dtype=labels.dtype)

        graph, norm_graph = ds.graph, ds.norm_graph
        if self.is_structural:
            m = ds.graph.to_scipy().tolil()
            for pairs, val in ((self.edge_deletes, 0.0),
                               (self.edge_inserts, 1.0)):
                if pairs is None or not len(pairs):
                    continue
                e = np.asarray(pairs, dtype=np.int64)
                if e.min() < 0 or e.max() >= n:
                    raise ValueError(f"edge endpoint out of range [0, {n})")
                if np.any(e[:, 0] == e[:, 1]):
                    raise ValueError("self-loop edits are not supported — "
                                     "GCN self-loops are added by "
                                     "gcn_preprocess, not stored")
                m[e[:, 0], e[:, 1]] = val       # undirected: both directions
                m[e[:, 1], e[:, 0]] = val
            csr = m.tocsr()
            csr.eliminate_zeros()
            graph = CSRGraph.from_scipy(csr)
            norm_graph = gcn_preprocess(graph)

        splits = dict(ds.splits)
        for split, adds in self.output_adds.items():
            adds = self._check_range(f"output_adds[{split!r}]", adds, n)
            if np.isin(adds, splits[split]).any():
                raise ValueError(f"output_adds[{split!r}] contains nodes "
                                 f"already in the split")
            splits[split] = np.concatenate([splits[split],
                                            np.sort(adds)]).astype(
                                                splits[split].dtype)
        for split, rm in self.output_removes.items():
            rm = _ids(rm)
            missing = rm[~np.isin(rm, splits[split])]
            if len(missing):
                raise ValueError(f"output_removes[{split!r}] names nodes not "
                                 f"in the split: {missing[:8].tolist()}")
            splits[split] = splits[split][~np.isin(splits[split], rm)]
        return dataclasses.replace(ds, graph=graph, norm_graph=norm_graph,
                                   features=features, labels=labels,
                                   splits=splits)


@dataclasses.dataclass(frozen=True)
class PlanDelta:
    """Audit record of one plan refresh (DESIGN.md §10)."""

    parent_fingerprint: str
    child_fingerprint: str
    version: int                     # the CHILD plan's version
    rebuilt: np.ndarray              # batch indices fully rebuilt
    patched: np.ndarray              # batch indices payload-patched in place
    untouched: np.ndarray            # batch indices carried over verbatim
    dirty_roots: int                 # roots re-pushed by incremental PPR
    timings: Dict[str, float]
    fallback: Optional[str] = None   # why the minimal path did not apply

    @property
    def dirty(self) -> np.ndarray:
        """Batches whose OUTPUT logits may have changed — what an engine
        must drop from its LRU on swap."""
        return np.union1d(self.rebuilt, self.patched)

    def summary(self) -> str:
        fb = f", fallback={self.fallback}" if self.fallback else ""
        return (f"v{self.version}: {len(self.rebuilt)} rebuilt, "
                f"{len(self.patched)} patched, "
                f"{len(self.untouched)} untouched, "
                f"{self.dirty_roots} roots re-pushed{fb}")


class PlanUpdater:
    """Map a :class:`GraphDelta` to the minimal dirty-batch set and emit the
    refreshed plan. Stateless apart from the inputs; one instance per
    refresh. Prefer :meth:`repro.core.pipeline.IBMBPipeline.refresh`, which
    wires the datasets, fingerprints and PPR caches for you.
    """

    def __init__(self, cfg, old_ds, new_ds, delta: GraphDelta):
        self.cfg = cfg
        self.old_ds = old_ds
        self.new_ds = new_ds
        self.delta = delta
        self.new_ppr: Optional[TopKPPR] = None   # exposed for pipeline cache

    # ----------------------------------------------------------- internals
    def _caps(self, plan: Plan) -> Tuple[int, int, int]:
        f = plan.cache.fields
        return (f["node_mask"].shape[1], f["edge_src"].shape[1],
                f["output_idx"].shape[1])

    def _partition(self, ppr: Optional[TopKPPR],
                   outputs: np.ndarray, mode: str) -> List[np.ndarray]:
        cfg = self.cfg
        cap = cfg.max_outputs_per_batch * (2 if mode == "inference" else 1)
        nb = cfg.num_batches or max(1, int(np.ceil(len(outputs) / cap)))
        if cfg.variant == "node":
            return ppr_distance_partition(ppr, outputs, cap, seed=cfg.seed)
        if cfg.variant == "random":
            return random_partition(outputs, nb, seed=cfg.seed)
        if cfg.variant == "batch":
            return graph_partition(self.new_ds.graph, outputs, nb,
                                   method=cfg.partition_method, seed=cfg.seed)
        raise ValueError(f"unknown IBMB variant: {cfg.variant}")

    def _aux_for(self, parts: Sequence[np.ndarray],
                 ppr: Optional[TopKPPR]) -> List[np.ndarray]:
        cfg = self.cfg
        if cfg.variant in ("node", "random"):
            return node_wise_aux(ppr, parts, cfg.k_per_output)
        return batch_wise_aux(self.new_ds.graph, parts,
                              budget=cfg.aux_budget, alpha=cfg.alpha,
                              num_iters=cfg.power_iters,
                              method=cfg.diffusion, heat_t=cfg.heat_t)

    def _parts_from_plan(self, plan: Plan) -> List[np.ndarray]:
        """Recover the parent's output partition (batch order = row order)
        from the routing index."""
        ro = plan.routing
        parts = []
        for i in range(len(plan)):
            m = ro.batch == i
            ids, rows = ro.node_ids[m], ro.row[m]
            parts.append(ids[np.argsort(rows)].astype(np.int64))
        return parts

    def _build(self, parts, aux, caps=None,
               block: Optional[int] = None) -> List[PaddedBatch]:
        cfg = self.cfg
        mn, me, mo = caps if caps is not None else (None, None, None)
        return build_batches(
            self.new_ds.norm_graph, self.new_ds.features, self.new_ds.labels,
            parts, aux, cache_features=cfg.cache_features,
            pad_multiple=cfg.pad_multiple,
            max_nodes=mn, max_edges=me, max_outputs=mo,
            bcsr_block=(block or cfg.bcsr_block)
            if cfg.backend == "bcsr" else None,
            reorder=cfg.reorder)

    # -------------------------------------------------------------- refresh
    def refresh(self, plan: Plan, fingerprint: str,
                old_ppr: Optional[TopKPPR] = None
                ) -> Tuple[Plan, PlanDelta]:
        """The delta-PPR refresh (DESIGN.md §10). Returns the child plan
        plus the audit record; `fingerprint` is the POST-delta pipeline's
        fingerprint for the plan's (split, mode)."""
        cfg, delta = self.cfg, self.delta
        split = plan.meta.get("split")
        mode = plan.meta.get("mode", "train")
        outputs = self.new_ds.splits[split]
        timings: Dict[str, float] = {}
        fallback = None

        # ---- stage 1: incremental PPR -----------------------------------
        # lint: allow(determinism) — timing telemetry only, never fed into the plan payload or fingerprint
        t0 = time.time()
        ppr_new, dirty_mask = None, np.zeros(len(outputs), bool)
        if cfg.variant in ("node", "random"):
            prev = old_ppr if old_ppr is not None else plan.ppr
            topk = cfg.ppr_topk()
            if prev is None:
                fallback = "no stored PPR (plan predates v2 or was wrapped "\
                           "from raw batches) — full re-push"
                dirty_mask[:] = True
                ppr_new = push_appr(
                    self.new_ds.graph, outputs, alpha=cfg.alpha, eps=cfg.eps,
                    max_iters=cfg.push_iters, topk=topk)
            else:
                dirty_mask = ppr_dirty_roots(
                    outputs, delta.touched_nodes(),
                    [self.old_ds.graph, self.new_ds.graph],
                    max(cfg.push_iters - 1, 0))
                dirty_mask |= ~np.isin(outputs, prev.roots)
                ppr_new = push_appr_incremental(
                    self.new_ds.graph, outputs, prev, dirty_mask,
                    alpha=cfg.alpha, eps=cfg.eps, max_iters=cfg.push_iters,
                    topk=topk)
            self.new_ppr = ppr_new
        # lint: allow(determinism) — timing telemetry only, never fed into the plan payload or fingerprint
        timings["refresh/ppr"] = time.time() - t0

        # ---- stage 2: partition + positional diff -----------------------
        # lint: allow(determinism) — timing telemetry only, never fed into the plan payload or fingerprint
        t0 = time.time()
        parts_old = self._parts_from_plan(plan)
        # Reuse the parent partition outright when its INPUTS are provably
        # unchanged — determinism then guarantees a from-scratch run would
        # recompute the identical partition, so skipping is exact:
        # node:   f(stored top-k rows, outputs, cap, seed) — rows unchanged
        #         iff the incremental push spliced every row through;
        # random: f(outputs, seed);
        # batch:  f(graph, outputs, seed) — graph unchanged iff the delta
        #         is not structural.
        outputs_same = np.array_equal(outputs, self.old_ds.splits[split])
        prev = old_ppr if old_ppr is not None else plan.ppr
        if cfg.variant == "node":
            reuse = outputs_same and prev is not None \
                and np.array_equal(ppr_new.indices, prev.indices) \
                and np.array_equal(ppr_new.values, prev.values)
        elif cfg.variant == "random":
            reuse = outputs_same
        else:
            reuse = outputs_same and not delta.is_structural
        parts_new = parts_old if reuse \
            else self._partition(ppr_new, outputs, mode)
        b_old, b_new = len(parts_old), len(parts_new)
        same_membership = np.zeros(b_new, bool)
        if reuse:
            same_membership[:] = True
        else:
            for i in range(min(b_old, b_new)):
                same_membership[i] = np.array_equal(
                    parts_new[i].astype(np.int64), parts_old[i])
        # lint: allow(determinism) — timing telemetry only, never fed into the plan payload or fingerprint
        timings["refresh/partition"] = time.time() - t0

        # ---- stage 3: classify batches ----------------------------------
        # lint: allow(determinism) — timing telemetry only, never fed into the plan payload or fingerprint
        t0 = time.time()
        n = self.new_ds.num_nodes
        dirty_out = np.zeros(max(n, 1), bool)
        if dirty_mask.any():
            dirty_out[outputs[dirty_mask]] = True
        touched = np.zeros(max(n, 1), bool)
        tn = delta.touched_nodes()
        touched[tn[tn < n]] = True

        rebuild = set(range(b_new)) - set(np.nonzero(same_membership)[0])
        if plan.node_ids is None:
            fallback = fallback or "plan has no membership table — " \
                                   "full rebuild"
            rebuild = set(range(b_new))
        elif cfg.variant == "batch" and delta.is_structural:
            # topic-sensitive PPR is a global diffusion: any edge edit
            # moves every batch's aux scores — no locality to exploit.
            fallback = "batch-wise aux is a global diffusion — structural " \
                       "delta dirties every batch"
            rebuild = set(range(b_new))
        else:
            aux_candidates = []
            for i in range(b_new):
                if i in rebuild:
                    continue
                members = plan.node_ids[i]
                members = members[members >= 0].astype(np.int64)
                if touched[members].any():
                    rebuild.add(i)        # induced edges / GCN weights moved
                elif dirty_out[parts_new[i]].any():
                    aux_candidates.append(i)
            if aux_candidates and cfg.variant in ("node", "random"):
                aux_cand = self._aux_for([parts_new[i]
                                          for i in aux_candidates], ppr_new)
                for i, aux in zip(aux_candidates, aux_cand):
                    members = plan.node_ids[i]
                    stored = np.sort(
                        members[members >= 0]).astype(np.int64)
                    if not np.array_equal(stored, aux.astype(np.int64)):
                        rebuild.add(i)    # influence-selected aux set moved
        rebuild_idx = np.array(sorted(rebuild), dtype=np.int64)
        # lint: allow(determinism) — timing telemetry only, never fed into the plan payload or fingerprint
        timings["refresh/classify"] = time.time() - t0

        # ---- stage 4: rebuild dirty batches inside the parent's caps ----
        # lint: allow(determinism) — timing telemetry only, never fed into the plan payload or fingerprint
        t0 = time.time()
        caps = self._caps(plan)
        rebuilt_batches: List[PaddedBatch] = []
        if len(rebuild_idx):
            parts_r = [parts_new[i] for i in rebuild_idx]
            aux_r = self._aux_for(parts_r, ppr_new)
            # rebuilt batches must tile at the PARENT's (possibly autotuned)
            # block so they splice into its (R, K, B, B) cache shape
            tv = plan.cache.fields.get("tile_vals")
            parent_block = int(tv.shape[-1]) if tv is not None else None
            try:
                rebuilt_batches = self._build(parts_r, aux_r, caps=caps,
                                              block=parent_block)
            except ValueError as e:
                # a rebuilt batch outgrew the frozen shape bucket: rebuild
                # the world with fresh caps (serving executables recompile,
                # which is exactly what growing shapes costs anywhere)
                return self._full_rebuild(
                    plan, fingerprint, parts_new, ppr_new, dirty_mask,
                    timings, f"caps exceeded ({e}) — full rebuild", t0)
        # lint: allow(determinism) — timing telemetry only, never fed into the plan payload or fingerprint
        timings["refresh/build"] = time.time() - t0

        # ---- stage 5: assemble the child cache --------------------------
        # lint: allow(determinism) — timing telemetry only, never fed into the plan payload or fingerprint
        t0 = time.time()
        parent_fields = plan.cache.fields
        mn = caps[0]
        if b_new == b_old:
            fields = {k: v.copy() for k, v in parent_fields.items()}
            node_ids = np.asarray(plan.node_ids).copy()
            meta = [dict(m) for m in plan.cache.meta]
        else:
            fields = {k: np.zeros((b_new,) + v.shape[1:], v.dtype)
                      for k, v in parent_fields.items()}
            node_ids = np.full((b_new, mn), -1, np.int32)
            meta = [dict() for _ in range(b_new)]
            for i in range(min(b_old, b_new)):
                if i not in rebuild:
                    for k in fields:
                        fields[k][i] = parent_fields[k][i]
                    node_ids[i] = plan.node_ids[i]
                    meta[i] = dict(plan.cache.meta[i])

        # BCSR K reconciliation: zero tiles only, no math effect
        if rebuilt_batches and rebuilt_batches[0].has_bcsr:
            k_old = fields["tile_cols"].shape[2]
            k_new = rebuilt_batches[0].tile_cols.shape[1]
            if k_new > k_old:
                pad = k_new - k_old
                fields["tile_cols"] = np.pad(
                    fields["tile_cols"], ((0, 0), (0, 0), (0, pad)))
                fields["tile_vals"] = np.pad(
                    fields["tile_vals"],
                    ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        for i, pb in zip(rebuild_idx, rebuilt_batches):
            da = pb.device_arrays()
            for k, v in da.items():
                if v.shape != fields[k].shape[1:]:     # K smaller than cache
                    pad = [(0, a - b) for a, b in
                           zip(fields[k].shape[1:], v.shape)]
                    v = np.pad(v, pad)
                fields[k][i] = v
            node_ids[i] = pb.node_ids
            meta[i] = dict(nodes=pb.num_real_nodes, edges=pb.num_real_edges,
                           outputs=pb.num_real_outputs)

        # ---- stage 6: payload patches on clean batches ------------------
        patched = set()
        clean = np.array([i for i in range(b_new) if i not in rebuild],
                         dtype=np.int64)
        if len(clean) and delta.feat_nodes is not None \
                and len(delta.feat_nodes):
            upd = _ids(delta.feat_nodes)
            order = np.argsort(upd, kind="stable")
            upd_s = upd[order]
            vals_s = np.asarray(delta.feat_values,
                                dtype=fields["features"].dtype)[order]
            sub = node_ids[clean].astype(np.int64)          # (C, mn)
            safe, hit = sorted_lookup(upd_s, sub)
            hit &= sub >= 0                                 # -1 pads
            rows_c, cols = np.nonzero(hit)
            if len(rows_c):
                fields["features"][clean[rows_c], cols] = \
                    vals_s[safe[rows_c, cols]]
                patched.update(int(i) for i in np.unique(clean[rows_c]))
        if len(clean) and delta.label_nodes is not None \
                and len(delta.label_nodes):
            lab_ids = _ids(delta.label_nodes)
            lab_vals = np.asarray(delta.label_values,
                                  dtype=fields["labels"].dtype)
            ro = plan.routing
            safe, known = sorted_lookup(ro.node_ids, lab_ids)
            clean_set = set(clean.tolist())
            for j in np.nonzero(known)[0]:
                bi, row = int(ro.batch[safe[j]]), int(ro.row[safe[j]])
                if bi in clean_set:
                    fields["labels"][bi, row] = lab_vals[j]
                    patched.add(bi)

        # ---- stage 7: schedule (reuse when label multisets unchanged) ---
        if b_new == b_old \
                and np.array_equal(fields["labels"], parent_fields["labels"]) \
                and np.array_equal(fields["output_mask"],
                                   parent_fields["output_mask"]):
            schedule = np.asarray(plan.schedule, np.int64)
        else:
            labels = [fields["labels"][i][fields["output_mask"][i] > 0]
                      for i in range(b_new)]
            schedule = make_schedule(labels, self.new_ds.num_classes,
                                     mode=cfg.schedule, seed=cfg.seed)
        routing = RoutingIndex.from_cache(node_ids, fields["output_idx"],
                                          fields["output_mask"])
        # lint: allow(determinism) — timing telemetry only, never fed into the plan payload or fingerprint
        timings["refresh/assemble"] = time.time() - t0

        meta_counts = np.array(
            [[m.get("nodes", 0), m.get("edges", 0), m.get("outputs", 0)]
             for m in meta], np.int64)
        cache = BatchCache.from_fields(fields, meta_counts)
        # re-run the autotuner's per-batch half over the spliced cache:
        # rebuilt batches get fresh decisions, untouched ones re-derive the
        # same answer (pure function of unchanged structure, DESIGN.md §14)
        backs, bfs, bstats = autotune.decide_cache(cache, self.cfg)
        new_meta = dict(plan.meta, num_batches=b_new,
                        num_classes=int(self.new_ds.num_classes),
                        batch_stats=bstats)
        child = Plan(cache=cache, schedule=_frozen(schedule),
                     routing=routing, fingerprint=fingerprint,
                     meta=new_meta, timings=timings,
                     version=plan.version + 1, parent=plan.fingerprint,
                     node_ids=_frozen(node_ids), ppr=ppr_new,
                     batch_backend=_frozen(encode_backends(backs)),
                     batch_block_f=_frozen(np.asarray(bfs, np.int32)))
        untouched = np.array(
            [i for i in range(b_new)
             if i not in rebuild and i not in patched], np.int64)
        audit = PlanDelta(
            parent_fingerprint=plan.fingerprint,
            child_fingerprint=fingerprint, version=child.version,
            rebuilt=rebuild_idx,
            patched=np.array(sorted(patched), np.int64),
            untouched=untouched, dirty_roots=int(dirty_mask.sum()),
            timings=timings, fallback=fallback)
        return child, audit

    def _full_rebuild(self, plan, fingerprint, parts_new, ppr_new,
                      dirty_mask, timings, reason, t0):
        """Rebuild-the-world fallback, still versioned along the chain."""
        aux = self._aux_for(parts_new, ppr_new)
        batches = self._build(parts_new, aux, caps=None)
        cfg = self.cfg
        if cfg.backend == "bcsr" and cfg.autotune and \
                getattr(cfg, "tune_blocks", ()):
            # same per-plan tile sweep a from-scratch plan() runs
            batches, _block = autotune.retune_tile_block(batches, cfg)
        # lint: allow(determinism) — timing telemetry only, never fed into the plan payload or fingerprint
        timings["refresh/build"] = time.time() - t0
        # lint: allow(determinism) — timing telemetry only, never fed into the plan payload or fingerprint
        t1 = time.time()
        labels = [b.labels[b.output_mask] for b in batches]
        schedule = make_schedule(labels, self.new_ds.num_classes,
                                 mode=self.cfg.schedule, seed=self.cfg.seed)
        backs, bfs, bstats = autotune.decide_batches(batches, cfg)
        child = Plan.from_batches(
            batches, schedule=schedule, fingerprint=fingerprint,
            meta=dict(plan.meta, num_batches=len(batches),
                      num_classes=int(self.new_ds.num_classes),
                      batch_stats=bstats),
            timings=timings, version=plan.version + 1,
            parent=plan.fingerprint, ppr=ppr_new,
            batch_backend=encode_backends(backs),
            batch_block_f=np.asarray(bfs, np.int32))
        # lint: allow(determinism) — timing telemetry only, never fed into the plan payload or fingerprint
        timings["refresh/assemble"] = time.time() - t1
        audit = PlanDelta(
            parent_fingerprint=plan.fingerprint,
            child_fingerprint=fingerprint, version=child.version,
            rebuilt=np.arange(len(batches), dtype=np.int64),
            patched=np.zeros(0, np.int64), untouched=np.zeros(0, np.int64),
            dirty_roots=int(dirty_mask.sum()), timings=timings,
            fallback=reason)
        return child, audit
