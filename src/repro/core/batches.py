"""Induced-subgraph mini-batches with static TPU-friendly shapes.

The paper's systems insight: batches are PRECOMPUTED and cached in consecutive
memory so training/inference does contiguous reads instead of random gathers.
On TPU this pays twice — XLA requires static shapes, and IBMB's fixed batches
let us pad ONCE at preprocessing time to a single (max_nodes, max_edges)
shape, so every step reuses one compiled executable and the host→device DMA
reads one contiguous buffer per batch.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.graph.csr import CSRGraph, induced_subgraph


@dataclasses.dataclass
class PaddedBatch:
    """One IBMB mini-batch, padded to static shapes.

    node_ids:    (max_nodes,) int32, -1 padded — global ids of batch nodes
    node_mask:   (max_nodes,) bool
    edge_src:    (max_edges,) int32 — local indices (into node_ids)
    edge_dst:    (max_edges,) int32
    edge_weight: (max_edges,) float32 — global GCN normalization (paper App. B)
    edge_mask:   (max_edges,) bool
    output_idx:  (max_outputs,) int32 — local indices of output nodes, -1 pad
    output_mask: (max_outputs,) bool
    features:    (max_nodes, F) float32 — gathered once, cached contiguously
    labels:      (max_outputs,) int32 — labels of output nodes, 0 padded
    """

    node_ids: np.ndarray
    node_mask: np.ndarray
    edge_src: np.ndarray
    edge_dst: np.ndarray
    edge_weight: np.ndarray
    edge_mask: np.ndarray
    output_idx: np.ndarray
    output_mask: np.ndarray
    features: Optional[np.ndarray]
    labels: np.ndarray

    @property
    def num_real_nodes(self) -> int:
        return int(self.node_mask.sum())

    @property
    def num_real_edges(self) -> int:
        return int(self.edge_mask.sum())

    @property
    def num_real_outputs(self) -> int:
        return int(self.output_mask.sum())

    def nbytes(self) -> int:
        total = 0
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if isinstance(v, np.ndarray):
                total += v.nbytes
        return total

    def device_arrays(self) -> Dict[str, np.ndarray]:
        """The arrays a train/serve step consumes (features must be cached)."""
        assert self.features is not None
        return dict(
            edge_src=self.edge_src, edge_dst=self.edge_dst,
            edge_weight=self.edge_weight,
            node_mask=self.node_mask.astype(np.float32),
            output_idx=np.maximum(self.output_idx, 0),
            output_mask=self.output_mask.astype(np.float32),
            features=self.features, labels=self.labels,
        )


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def build_batches(
    norm_graph: CSRGraph,
    features: np.ndarray,
    labels: np.ndarray,
    output_batches: Sequence[np.ndarray],
    aux_batches: Sequence[np.ndarray],
    cache_features: bool = True,
    pad_multiple: int = 128,
    max_nodes: Optional[int] = None,
    max_edges: Optional[int] = None,
    max_outputs: Optional[int] = None,
) -> List[PaddedBatch]:
    """Materialize padded induced-subgraph batches.

    Shapes are padded to the max across batches (rounded to `pad_multiple`,
    which keeps the trailing dims MXU/VPU aligned) so all batches share ONE
    shape ⇒ one XLA executable.
    """
    assert len(output_batches) == len(aux_batches)
    raw = []
    for outs, aux in zip(output_batches, aux_batches):
        nodes = np.unique(np.concatenate([outs, aux])).astype(np.int64)
        src, dst, w = induced_subgraph(norm_graph, nodes)
        out_local = np.searchsorted(nodes, outs).astype(np.int32)
        raw.append((nodes, src, dst, w, out_local, outs))

    mn = max_nodes or _round_up(max(len(r[0]) for r in raw), pad_multiple)
    me = max_edges or _round_up(max(max(len(r[1]) for r in raw), 1), pad_multiple)
    mo = max_outputs or _round_up(max(len(r[4]) for r in raw), pad_multiple)

    batches: List[PaddedBatch] = []
    for nodes, src, dst, w, out_local, outs in raw:
        nn, ne, no = len(nodes), len(src), len(out_local)
        if nn > mn or ne > me or no > mo:
            raise ValueError(f"batch exceeds caps: nodes {nn}>{mn} or edges {ne}>{me} or outputs {no}>{mo}")
        node_ids = np.full(mn, -1, np.int32); node_ids[:nn] = nodes
        node_mask = np.zeros(mn, bool); node_mask[:nn] = True
        # padded edges point at the last (guaranteed-padding or masked) slot
        # with weight 0 so segment-sums are unaffected.
        e_src = np.zeros(me, np.int32); e_dst = np.zeros(me, np.int32)
        e_w = np.zeros(me, np.float32); e_m = np.zeros(me, bool)
        e_src[:ne] = src; e_dst[:ne] = dst; e_w[:ne] = w; e_m[:ne] = True
        o_idx = np.full(mo, -1, np.int32); o_idx[:no] = out_local
        o_m = np.zeros(mo, bool); o_m[:no] = True
        lab = np.zeros(mo, np.int32); lab[:no] = labels[outs]
        feats = None
        if cache_features:
            feats = np.zeros((mn, features.shape[1]), np.float32)
            feats[:nn] = features[nodes]
        batches.append(PaddedBatch(node_ids, node_mask, e_src, e_dst, e_w, e_m,
                                   o_idx, o_m, feats, lab))
    return batches


class BatchCache:
    """Contiguous host-side cache of padded batches.

    All batches share one shape, so the cache is a dict of stacked arrays —
    one contiguous block per field. Reading batch i is a contiguous slice
    (the paper's "consecutive memory accesses"), ready for zero-copy DMA.
    """

    def __init__(self, batches: Sequence[PaddedBatch]):
        assert len(batches) > 0
        self.num_batches = len(batches)
        self.fields: Dict[str, np.ndarray] = {}
        sample = batches[0].device_arrays()
        for k, v in sample.items():
            self.fields[k] = np.ascontiguousarray(
                np.stack([b.device_arrays()[k] for b in batches]))
        self.meta = [dict(nodes=b.num_real_nodes, edges=b.num_real_edges,
                          outputs=b.num_real_outputs) for b in batches]

    def __len__(self) -> int:
        return self.num_batches

    def __getitem__(self, i: int) -> Dict[str, np.ndarray]:
        return {k: v[i] for k, v in self.fields.items()}

    def nbytes(self) -> int:
        return sum(v.nbytes for v in self.fields.values())

    def save(self, path: str) -> None:
        np.savez(path, **self.fields)

    @staticmethod
    def load(path: str) -> "BatchCache":
        z = np.load(path)
        obj = BatchCache.__new__(BatchCache)
        obj.fields = {k: z[k] for k in z.files}
        obj.num_batches = next(iter(obj.fields.values())).shape[0]
        obj.meta = [{} for _ in range(obj.num_batches)]
        return obj
