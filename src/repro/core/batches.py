"""Induced-subgraph mini-batches with static TPU-friendly shapes.

The paper's systems insight: batches are PRECOMPUTED and cached in consecutive
memory so training/inference does contiguous reads instead of random gathers.
On TPU this pays twice — XLA requires static shapes, and IBMB's fixed batches
let us pad ONCE at preprocessing time to a single (max_nodes, max_edges)
shape, so every step reuses one compiled executable and the host→device DMA
reads one contiguous buffer per batch.

When ``bcsr_block`` is set, preprocessing additionally emits a per-batch
padded block-CSR adjacency (DESIGN.md §7) — after a batch-local node
reordering that concentrates nonzeros into diagonal tiles — so the GNN
aggregation can run as dense MXU matmuls over nonzero tiles instead of
COO gathers + segment sums.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.graph.csr import CSRGraph, coo_to_csr, induced_subgraph


@dataclasses.dataclass
class PaddedBatch:
    """One IBMB mini-batch, padded to static shapes.

    node_ids:    (max_nodes,) int32, -1 padded — global ids of batch nodes
    node_mask:   (max_nodes,) bool
    edge_src:    (max_edges,) int32 — local indices (into node_ids)
    edge_dst:    (max_edges,) int32
    edge_weight: (max_edges,) float32 — global GCN normalization (paper App. B)
    edge_mask:   (max_edges,) bool
    output_idx:  (max_outputs,) int32 — local indices of output nodes, -1 pad
    output_mask: (max_outputs,) bool
    features:    (max_nodes, F) float32 — gathered once, cached contiguously
    labels:      (max_outputs,) int32 — labels of output nodes, 0 padded
    tile_cols:   (R, K) int32 block-CSR column-tile ids (DESIGN.md §7), or None
    tile_vals:   (R, K, B, B) float32 block-CSR tiles (R·B == max_nodes), or None
    """

    node_ids: np.ndarray
    node_mask: np.ndarray
    edge_src: np.ndarray
    edge_dst: np.ndarray
    edge_weight: np.ndarray
    edge_mask: np.ndarray
    output_idx: np.ndarray
    output_mask: np.ndarray
    features: Optional[np.ndarray]
    labels: np.ndarray
    tile_cols: Optional[np.ndarray] = None
    tile_vals: Optional[np.ndarray] = None

    @property
    def num_real_nodes(self) -> int:
        return int(self.node_mask.sum())

    @property
    def num_real_edges(self) -> int:
        return int(self.edge_mask.sum())

    @property
    def num_real_outputs(self) -> int:
        return int(self.output_mask.sum())

    @property
    def has_bcsr(self) -> bool:
        return self.tile_cols is not None and self.tile_vals is not None

    def bcsr_stats(self) -> dict:
        """Tile-population stats of the emitted block-CSR adjacency."""
        assert self.has_bcsr, "batch was built without bcsr_block"
        from repro.kernels.spmm.ops import BCSR
        n = self.node_ids.shape[0]
        return BCSR(self.tile_cols, self.tile_vals, n, n).density_stats()

    def nbytes(self) -> int:
        total = 0
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if isinstance(v, np.ndarray):
                total += v.nbytes
        return total

    def device_arrays(self) -> Dict[str, np.ndarray]:
        """The arrays a train/serve step consumes (features must be cached)."""
        assert self.features is not None
        out = dict(
            edge_src=self.edge_src, edge_dst=self.edge_dst,
            edge_weight=self.edge_weight,
            node_mask=self.node_mask.astype(np.float32),
            output_idx=np.maximum(self.output_idx, 0),
            output_mask=self.output_mask.astype(np.float32),
            features=self.features, labels=self.labels,
        )
        if self.has_bcsr:
            out["tile_cols"] = self.tile_cols
            out["tile_vals"] = self.tile_vals
        return out


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def batch_node_order(num_nodes: int, src: np.ndarray, dst: np.ndarray,
                     mode: str = "bfs") -> np.ndarray:
    """Batch-local node reordering permutation (DESIGN.md §7).

    "bfs"    — reverse Cuthill-McKee: BFS from a peripheral low-degree node
               with degree-ordered tie-breaking. Minimizes bandwidth, i.e.
               concentrates nonzeros near the diagonal ⇒ fewer, fuller tiles.
    "degree" — descending degree (hubs share the leading tiles).
    "none"   — identity (nodes stay in sorted-global-id order).
    """
    if mode == "none" or num_nodes <= 1:
        return np.arange(num_nodes, dtype=np.int64)
    import scipy.sparse as sp
    a = sp.csr_matrix((np.ones(len(src), np.float32), (src, dst)),
                      shape=(num_nodes, num_nodes))
    a = (a + a.T).tocsr()
    if mode == "bfs":
        from scipy.sparse.csgraph import reverse_cuthill_mckee
        return np.asarray(reverse_cuthill_mckee(a, symmetric_mode=True),
                          dtype=np.int64)
    if mode == "degree":
        return np.argsort(-np.diff(a.indptr), kind="stable").astype(np.int64)
    raise ValueError(f"unknown reorder mode: {mode}")


def _check_symmetric(src: np.ndarray, dst: np.ndarray, w: np.ndarray) -> bool:
    """True iff the COO adjacency equals its transpose (weights included)."""
    fwd = np.lexsort((dst, src))
    bwd = np.lexsort((src, dst))
    return (np.array_equal(src[fwd], dst[bwd])
            and np.array_equal(dst[fwd], src[bwd])
            and np.allclose(w[fwd], w[bwd]))


def build_batches(
    norm_graph: CSRGraph,
    features: np.ndarray,
    labels: np.ndarray,
    output_batches: Sequence[np.ndarray],
    aux_batches: Sequence[np.ndarray],
    cache_features: bool = True,
    pad_multiple: int = 128,
    max_nodes: Optional[int] = None,
    max_edges: Optional[int] = None,
    max_outputs: Optional[int] = None,
    bcsr_block: Optional[int] = None,
    reorder: str = "bfs",
    bcsr_pad_k: Optional[int] = None,
) -> List[PaddedBatch]:
    """Materialize padded induced-subgraph batches.

    Shapes are padded to the max across batches (rounded to `pad_multiple`,
    which keeps the trailing dims MXU/VPU aligned) so all batches share ONE
    shape ⇒ one XLA executable.

    bcsr_block: when set, also emit the block-CSR adjacency of every batch
    (block size = gcd(bcsr_block, max_nodes) so tiles always divide the
    padded node count). Requires a symmetric batch adjacency — guaranteed by
    ``graph.csr.gcn_preprocess`` — because the bcsr training backend reuses
    the same tiles for the transpose in the backward pass (DESIGN.md §7).
    reorder: batch-local node ordering applied before tiling (see
    ``batch_node_order``); only active when bcsr_block is set.
    bcsr_pad_k: pad every batch's tile table to this K instead of the max
    over THIS call's batches — chunked out-of-core builds (DESIGN.md §13)
    pass the global K so batches built in different chunks share one shape.
    """
    assert len(output_batches) == len(aux_batches)
    raw = []
    for outs, aux in zip(output_batches, aux_batches):
        nodes = np.unique(np.concatenate([outs, aux])).astype(np.int64)
        src, dst, w = induced_subgraph(norm_graph, nodes)
        out_local = np.searchsorted(nodes, outs).astype(np.int32)
        if bcsr_block is not None and reorder != "none":
            perm = batch_node_order(len(nodes), src, dst, mode=reorder)
            inv = np.empty(len(nodes), np.int64)
            inv[perm] = np.arange(len(nodes))
            nodes = nodes[perm]
            src = inv[src].astype(np.int32)
            dst = inv[dst].astype(np.int32)
            out_local = inv[out_local].astype(np.int32)
        raw.append((nodes, src, dst, w, out_local, outs))

    mn = max_nodes or _round_up(max(len(r[0]) for r in raw), pad_multiple)
    me = max_edges or _round_up(max(max(len(r[1]) for r in raw), 1), pad_multiple)
    mo = max_outputs or _round_up(max(len(r[4]) for r in raw), pad_multiple)

    bcsr_list = []
    if bcsr_block is not None:
        from repro.kernels.spmm.ops import csr_to_bcsr
        block = math.gcd(bcsr_block, mn)
        for nodes, src, dst, w, _ol, _o in raw:
            if len(src) and not _check_symmetric(src, dst, w):
                raise ValueError(
                    "bcsr backend needs a symmetric batch adjacency (the "
                    "backward pass reuses the forward tiles, DESIGN.md §7); "
                    "got an asymmetric induced subgraph — preprocess with "
                    "gcn_preprocess/make_undirected or use backend='segment'")
            sub = coo_to_csr(src, dst, mn, weights=w)
            bcsr_list.append(csr_to_bcsr(sub.indptr, sub.indices, sub.weights,
                                         mn, mn, block=block))
        kmax = max(bc.tile_cols.shape[1] for bc in bcsr_list)
        if bcsr_pad_k is not None:
            if kmax > bcsr_pad_k:
                raise ValueError(
                    f"batch needs K={kmax} column tiles but bcsr_pad_k="
                    f"{bcsr_pad_k} — the caps measured for this chunked "
                    f"build are stale")
            kmax = bcsr_pad_k
        bcsr_list = [bc.with_pad_k(kmax) for bc in bcsr_list]

    batches: List[PaddedBatch] = []
    for bi, (nodes, src, dst, w, out_local, outs) in enumerate(raw):
        nn, ne, no = len(nodes), len(src), len(out_local)
        if nn > mn or ne > me or no > mo:
            raise ValueError(f"batch exceeds caps: nodes {nn}>{mn} or edges {ne}>{me} or outputs {no}>{mo}")
        node_ids = np.full(mn, -1, np.int32); node_ids[:nn] = nodes
        node_mask = np.zeros(mn, bool); node_mask[:nn] = True
        # padded edges point at the last (guaranteed-padding or masked) slot
        # with weight 0 so segment-sums are unaffected.
        e_src = np.zeros(me, np.int32); e_dst = np.zeros(me, np.int32)
        e_w = np.zeros(me, np.float32); e_m = np.zeros(me, bool)
        e_src[:ne] = src; e_dst[:ne] = dst; e_w[:ne] = w; e_m[:ne] = True
        o_idx = np.full(mo, -1, np.int32); o_idx[:no] = out_local
        o_m = np.zeros(mo, bool); o_m[:no] = True
        lab = np.zeros(mo, np.int32); lab[:no] = labels[outs]
        feats = None
        if cache_features:
            feats = np.zeros((mn, features.shape[1]), np.float32)
            feats[:nn] = features[nodes]
        tc = tv = None
        if bcsr_list:
            tc, tv = bcsr_list[bi].tile_cols, bcsr_list[bi].tile_vals
        batches.append(PaddedBatch(node_ids, node_mask, e_src, e_dst, e_w, e_m,
                                   o_idx, o_m, feats, lab,
                                   tile_cols=tc, tile_vals=tv))
    return batches


class BatchCache:
    """Contiguous host-side cache of padded batches.

    All batches share one shape, so the cache is a dict of stacked arrays —
    one contiguous block per field. Reading batch i is a contiguous slice
    (the paper's "consecutive memory accesses"), ready for zero-copy DMA.
    """

    _META_KEY = "__meta_counts__"

    def __init__(self, batches: Sequence[PaddedBatch]):
        assert len(batches) > 0
        self.num_batches = len(batches)
        self.fields: Dict[str, np.ndarray] = {}
        sample = batches[0].device_arrays()
        for k, v in sample.items():
            self.fields[k] = np.ascontiguousarray(
                np.stack([b.device_arrays()[k] for b in batches]))
        self.meta = [dict(nodes=b.num_real_nodes, edges=b.num_real_edges,
                          outputs=b.num_real_outputs) for b in batches]

    def __len__(self) -> int:
        return self.num_batches

    def __getitem__(self, i: int) -> Dict[str, np.ndarray]:
        return {k: v[i] for k, v in self.fields.items()}

    def nbytes(self) -> int:
        return sum(v.nbytes for v in self.fields.values())

    def save(self, path: str) -> None:
        # .get: a cache loaded from a pre-meta-fix npz has empty meta dicts;
        # re-saving it writes zeros rather than crashing.
        meta = np.array([[m.get("nodes", 0), m.get("edges", 0),
                          m.get("outputs", 0)] for m in self.meta], np.int64)
        np.savez(path, **{self._META_KEY: meta}, **self.fields)

    @staticmethod
    def from_fields(fields: Dict[str, np.ndarray],
                    meta_counts: Optional[np.ndarray] = None) -> "BatchCache":
        """Rebuild a cache from already-stacked field arrays — the
        deserialization constructor shared by ``load`` and ``Plan.load``
        (DESIGN.md §8). ``meta_counts`` is the (num_batches, 3) array of
        real (nodes, edges, outputs) counts; None means a pre-meta-fix
        artifact (meta restored as empty dicts)."""
        obj = BatchCache.__new__(BatchCache)
        obj.fields = dict(fields)
        obj.num_batches = next(iter(obj.fields.values())).shape[0]
        if meta_counts is not None:
            obj.meta = [dict(nodes=int(n), edges=int(e), outputs=int(o))
                        for n, e, o in np.asarray(meta_counts)]
        else:
            obj.meta = [{} for _ in range(obj.num_batches)]
        return obj

    @staticmethod
    def load(path: str) -> "BatchCache":
        with np.load(path) as z:
            fields = {k: z[k] for k in z.files if k != BatchCache._META_KEY}
            meta = z[BatchCache._META_KEY] if BatchCache._META_KEY in z.files \
                else None
        return BatchCache.from_fields(fields, meta)
