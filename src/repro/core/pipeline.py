"""End-to-end IBMB preprocessing pipeline — the public API.

    cfg  = IBMBConfig(variant="node", k_per_output=16, max_outputs_per_batch=1024)
    pipe = IBMBPipeline(dataset, cfg)
    plan = pipe.plan("train")                      # frozen Plan artifact (§8)
    plan.save("train_plan.npz")                    # preprocess once, reuse
    plan = pipe.load_plan("train_plan.npz", "train")   # fingerprint-checked

``plan()`` is the primary entry point (DESIGN.md §8): it returns a frozen,
serializable :class:`~repro.core.plan.Plan` bundling the contiguous batch
cache (+ BCSR tiles), the batch schedule, preprocessing timings, the config
fingerprint, and the routing index that request-level serving
(``repro.serve.gnn_engine``) uses. ``preprocess()`` remains the lower-level
stage returning the raw ``List[PaddedBatch]``.

``refresh(plan, delta)`` is the dynamic-graph entry point (DESIGN.md §10):
it advances the pipeline to the post-delta dataset and emits the next plan
in the version chain, rebuilding only the batches the delta actually
dirtied (incremental PPR push decides) plus a ``PlanDelta`` audit record.

Variants (paper Sec. 5 setup):
* "node"  — node-wise IBMB: PPR-distance partitioning + node-wise top-k aux.
* "batch" — batch-wise IBMB: graph partitioning + batch-wise (topic) PPR aux.
* "random" — fixed-random partition + node-wise aux (the paper's ablation).
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.graph.datasets import GraphDataset
from repro.core.ppr import push_appr, TopKPPR
from repro.core.partition import ppr_distance_partition, graph_partition, random_partition
from repro.core.aux_selection import node_wise_aux, batch_wise_aux
from repro.core import autotune
from repro.core.batches import PaddedBatch, build_batches, BatchCache
from repro.core.plan import Plan, encode_backends, plan_fingerprint
from repro.core.scheduling import make_schedule
from repro.core.update import GraphDelta, PlanDelta, PlanUpdater


@dataclasses.dataclass
class IBMBConfig:
    variant: str = "node"            # node | batch | random
    alpha: float = 0.25              # PPR teleport (paper default 0.25)
    eps: float = 2e-4                # push threshold
    push_iters: int = 3              # paper: 3 push sweeps
    power_iters: int = 50            # paper: 50 power iterations
    k_per_output: int = 16           # aux nodes per output (main free knob)
    max_outputs_per_batch: int = 1024
    num_batches: Optional[int] = None   # for batch/random variants
    aux_budget: Optional[int] = None    # batch-wise: None → |partition|
    partition_method: str = "fennel"    # fennel | louvain | random
    diffusion: str = "ppr"              # ppr | heat  (Table 5)
    heat_t: float = 3.0
    schedule: str = "tsp"               # tsp | weighted | none  (Fig. 7)
    pad_multiple: int = 128
    cache_features: bool = True
    seed: int = 0
    # aggregation backend the batches are built for (DESIGN.md §7):
    # "segment"/"dense" need only the COO edge list; "bcsr" additionally
    # emits the per-batch block-CSR tiles after batch-local node reordering.
    backend: str = "segment"
    bcsr_block: int = 128               # tile size (gcd'd with max_nodes)
    reorder: str = "bfs"                # bfs | degree | none (tile locality)
    # plan-build autotuner (DESIGN.md §14): per-batch backend decision +
    # tuned feature-tile width stored in the Plan (format v3); all knobs
    # are fingerprinted (the whole config is), so a tuned plan is pinned.
    autotune: bool = True
    tune_blocks: tuple = ()             # extra tile-size B candidates to sweep
    tune_block_fs: tuple = (128, 256, 512)   # feature-tile width candidates
    auto_kappa: float = 16.0            # bcsr wins iff tile flops <= kappa·|E|
    tune_vmem_kb: int = 8192            # fused-kernel working-set budget

    def ppr_topk(self) -> int:
        """Stored top-k width of the node-wise APPR. ONE home for the
        formula: ``node_ppr`` computes with it and the refresh path
        (``core.update``) aligns stored rows against it — if they ever
        disagreed, ``push_appr_incremental`` would silently mark every
        root dirty on every refresh."""
        return max(self.k_per_output * 2, 32)


class IBMBPipeline:
    def __init__(self, dataset: GraphDataset, cfg: IBMBConfig):
        if cfg.backend not in ("segment", "bcsr", "dense"):
            raise ValueError(f"unknown IBMBConfig.backend {cfg.backend!r}; "
                             "want segment | bcsr | dense (DESIGN.md §7)")
        self.ds = dataset
        self.cfg = cfg
        self._ppr_cache: Dict[str, TopKPPR] = {}
        self._content_sha_cache: Optional[str] = None
        self.timings: Dict[str, float] = {}

    # -- influence scores ---------------------------------------------------
    def node_ppr(self, split: str) -> TopKPPR:
        """Node-wise APPR for the split's output nodes (cached — the paper
        re-uses preprocessing across models/seeds)."""
        if split not in self._ppr_cache:
            # lint: allow(determinism) — timing telemetry only, never fed into the plan payload or fingerprint
            t0 = time.time()
            roots = self.ds.splits[split]
            self._ppr_cache[split] = push_appr(
                self.ds.graph, roots, alpha=self.cfg.alpha, eps=self.cfg.eps,
                max_iters=self.cfg.push_iters, topk=self.cfg.ppr_topk())
            # lint: allow(determinism) — timing telemetry only, never fed into the plan payload or fingerprint
            self.timings[f"ppr/{split}"] = time.time() - t0
        return self._ppr_cache[split]

    # -- fingerprint --------------------------------------------------------
    def _content_sha(self) -> str:
        """Digest of the actual graph/feature/label CONTENT (not just
        shapes), so a regenerated dataset with identical dimensions still
        invalidates old plans. Computed once per pipeline — preprocessing-
        time cost, amortized like everything else."""
        if self._content_sha_cache is None:
            h = hashlib.sha256()
            g = self.ds.norm_graph
            for a in (g.indptr, g.indices, g.weights,
                      self.ds.features, self.ds.labels):
                h.update(np.ascontiguousarray(a).tobytes())
            self._content_sha_cache = h.hexdigest()[:16]
        return self._content_sha_cache

    def fingerprint(self, split: str, for_inference: bool = False) -> str:
        """Fingerprint of (config, dataset, split, mode) — what a saved Plan
        is checked against on load (DESIGN.md §8)."""
        sig = {
            "name": self.ds.name,
            "num_nodes": int(self.ds.num_nodes),
            "num_edges": int(self.ds.graph.num_edges),
            "feat_dim": int(self.ds.feat_dim),
            "num_classes": int(self.ds.num_classes),
            "content_sha": self._content_sha(),
            "split_sha": hashlib.sha256(
                np.ascontiguousarray(
                    self.ds.splits[split], dtype=np.int64).tobytes()
            ).hexdigest()[:16],
        }
        mode = "inference" if for_inference else "train"
        return plan_fingerprint(dataclasses.asdict(self.cfg), sig, split, mode)

    # -- the primary entry point: frozen Plan artifact ----------------------
    def plan(self, split: str, for_inference: bool = False,
             out_of_core: bool = False, store_dir: Optional[str] = None,
             ooc=None) -> Plan:
        """Run preprocessing end to end and freeze the result (DESIGN.md §8):
        batches + cache + schedule + routing index + fingerprint + timings.
        The returned Plan is what ``GNNTrainer.fit/evaluate``,
        ``GNNInferenceEngine`` and ``Plan.save`` consume.

        ``out_of_core=True`` (DESIGN.md §13) streams the build instead:
        batches are constructed chunk by chunk and appended to a
        :class:`~repro.ooc.store.PlanStore` at ``store_dir`` as they finish —
        the full padded batch payload is NEVER resident at once — and the
        returned Plan is backed by a lazy, mmap-backed cache with a bounded
        resident-batch budget (``ooc`` is an optional
        :class:`~repro.ooc.stream.OOCConfig`). Per-batch contents, schedule,
        routing index and fingerprint are bit-identical to the resident
        build."""
        if out_of_core:
            from repro.ooc.stream import stream_plan
            if store_dir is None:
                raise ValueError("out_of_core=True needs store_dir (the "
                                 "PlanStore directory to stream batches to)")
            return stream_plan(self, split, for_inference, store_dir, ooc)
        mode = "inference" if for_inference else "train"
        batches = self.preprocess(split, for_inference=for_inference)
        # lint: allow(determinism) — timing telemetry only, never fed into the plan payload or fingerprint
        t0 = time.time()
        cache = BatchCache(batches)
        sched = self.schedule(batches)
        # the autotuner's per-batch half (DESIGN.md §14): backend decision
        # + tuned feature-tile width, stored in the plan (format v3) so
        # serving dispatches without re-measuring anything
        backs, bfs, bstats = autotune.decide_batches(batches, self.cfg)
        # lint: allow(determinism) — timing telemetry only, never fed into the plan payload or fingerprint
        self.timings[f"plan/{split}/{mode}"] = time.time() - t0
        meta = dict(split=split, mode=mode, variant=self.cfg.variant,
                    backend=self.cfg.backend,
                    num_classes=int(self.ds.num_classes),
                    num_batches=len(batches), dataset=self.ds.name,
                    batch_stats=bstats)
        # only THIS split/mode's timings: the artifact stays self-describing
        # even when one pipeline planned several splits
        own = (f"ppr/{split}", f"preprocess/{split}/{mode}",
               f"plan/{split}/{mode}")
        return Plan.from_batches(
            batches, schedule=sched, cache=cache,
            fingerprint=self.fingerprint(split, for_inference),
            meta=meta,
            batch_backend=encode_backends(backs),
            batch_block_f=np.asarray(bfs, np.int32),
            timings={k: v for k, v in self.timings.items() if k in own},
            # the stored warm state future refreshes splice from (§10);
            # batch-wise plans carry none (their aux diffusion is global)
            ppr=self._ppr_cache.get(split))

    def load_plan(self, path: str, split: str,
                  for_inference: bool = False) -> Plan:
        """Load a saved Plan, refusing artifacts whose fingerprint does not
        match THIS pipeline's (config, dataset, split, mode)."""
        return Plan.load(
            path, expect_fingerprint=self.fingerprint(split, for_inference))

    # -- dynamic graphs: versioned plan refresh (DESIGN.md §10) -------------
    def refresh(self, plan: Plan, delta: GraphDelta):
        """Apply ``delta`` to this pipeline's dataset and emit the next plan
        in the version chain: ``(child_plan, plan_delta)``.

        The pipeline ADVANCES to the post-delta graph (subsequent ``plan``/
        ``fingerprint`` calls see it; the plan's split keeps a warm PPR
        cache spliced by the incremental push, other splits' caches are
        dropped as stale). ``plan`` must belong to this pipeline's
        pre-delta state — a foreign or stale artifact is refused exactly
        like ``load_plan`` would refuse it. The child plan's logits are
        numerically identical to a from-scratch ``plan()`` on the
        post-delta graph; only the dirty subset of batches is rebuilt
        (``plan_delta`` records which, for ``GNNInferenceEngine.swap``).
        """
        split, mode = plan.meta.get("split"), plan.meta.get("mode", "train")
        if split not in self.ds.splits:
            raise ValueError(f"plan names unknown split {split!r}")
        for_inference = mode == "inference"
        expect = self.fingerprint(split, for_inference)
        if plan.fingerprint != expect:
            raise ValueError(
                f"refresh: plan fingerprint {plan.fingerprint!r} does not "
                f"match this pipeline's pre-delta state ({expect!r}) — "
                f"refresh continues a chain, it cannot adopt a foreign plan")
        # lint: allow(determinism) — timing telemetry only, never fed into the plan payload or fingerprint
        t0 = time.time()
        old_ds = self.ds
        new_ds = delta.apply(old_ds)
        updater = PlanUpdater(self.cfg, old_ds, new_ds, delta)
        old_ppr = self._ppr_cache.get(split)
        # advance the pipeline to the post-delta graph
        self.ds = new_ds
        self._content_sha_cache = None
        self._ppr_cache.clear()
        child, audit = updater.refresh(
            plan, fingerprint=self.fingerprint(split, for_inference),
            old_ppr=old_ppr)
        if updater.new_ppr is not None:
            self._ppr_cache[split] = updater.new_ppr
        # lint: allow(determinism) — timing telemetry only, never fed into the plan payload or fingerprint
        self.timings[f"refresh/{split}/{mode}"] = time.time() - t0
        return child, audit

    # -- full preprocessing -------------------------------------------------
    def partition(self, split: str, for_inference: bool = False
                  ) -> Tuple[List[np.ndarray], List[np.ndarray]]:
        """The id-only half of preprocessing: influence scores → output
        partition → auxiliary selection. Returns ``(parts, aux)``, two
        aligned lists of global node-id arrays (one pair per batch) and NO
        payload — this is what the streaming out-of-core build
        (``repro.ooc.stream``, DESIGN.md §13) runs up front, O(outputs·k)
        memory, before materializing batches chunk by chunk. ``preprocess``
        is exactly ``partition`` + ``build_batches``, so the two paths can
        never diverge."""
        cfg = self.cfg
        outputs = self.ds.splits[split]
        # inference batches can be ~2x larger (no gradient storage, App. B)
        cap = cfg.max_outputs_per_batch * (2 if for_inference else 1)
        nb = cfg.num_batches or max(1, int(np.ceil(len(outputs) / cap)))

        if cfg.variant == "node":
            ppr = self.node_ppr(split)
            parts = ppr_distance_partition(ppr, outputs, cap, seed=cfg.seed)
            aux = node_wise_aux(ppr, parts, cfg.k_per_output)
        elif cfg.variant == "batch":
            parts = graph_partition(self.ds.graph, outputs, nb,
                                    method=cfg.partition_method, seed=cfg.seed)
            aux = batch_wise_aux(self.ds.graph, parts, budget=cfg.aux_budget,
                                 alpha=cfg.alpha, num_iters=cfg.power_iters,
                                 method=cfg.diffusion, heat_t=cfg.heat_t)
        elif cfg.variant == "random":
            ppr = self.node_ppr(split)
            parts = random_partition(outputs, nb, seed=cfg.seed)
            aux = node_wise_aux(ppr, parts, cfg.k_per_output)
        else:
            raise ValueError(f"unknown IBMB variant: {cfg.variant}")
        return parts, aux

    def preprocess(self, split: str, for_inference: bool = False) -> List[PaddedBatch]:
        cfg = self.cfg
        # lint: allow(determinism) — timing telemetry only, never fed into the plan payload or fingerprint
        t0 = time.time()
        parts, aux = self.partition(split, for_inference)

        batches = build_batches(
            self.ds.norm_graph, self.ds.features, self.ds.labels,
            parts, aux, cache_features=cfg.cache_features,
            pad_multiple=cfg.pad_multiple,
            bcsr_block=cfg.bcsr_block if cfg.backend == "bcsr" else None,
            reorder=cfg.reorder)
        if cfg.backend == "bcsr" and cfg.autotune and cfg.tune_blocks:
            # the autotuner's per-plan half: sweep tile-size candidates by
            # padded MXU work and retile to the winner (DESIGN.md §14)
            batches, _block = autotune.retune_tile_block(batches, cfg)
        # keyed by mode as well as split: preprocessing the same split for
        # training AND inference must not silently overwrite one timing.
        mode = "inference" if for_inference else "train"
        # lint: allow(determinism) — timing telemetry only, never fed into the plan payload or fingerprint
        self.timings[f"preprocess/{split}/{mode}"] = time.time() - t0
        return batches

    def build_cache(self, batches: List[PaddedBatch]) -> BatchCache:
        return BatchCache(batches)

    def schedule(self, batches: List[PaddedBatch], num_epochs: int = 1) -> np.ndarray:
        labels = [b.labels[b.output_mask] for b in batches]
        return make_schedule(labels, self.ds.num_classes,
                             mode=self.cfg.schedule, num_epochs=num_epochs,
                             seed=self.cfg.seed)
