"""Influence scores (paper Sec. 3, Theorem 1) — exact computation for
validation of the PPR approximation.

I(v, u) = Σ_i Σ_j | ∂h_u,i^{(L)} / ∂X_v,j |

Used by tests to confirm (on small graphs + GCN models) that PPR ranks
auxiliary nodes consistently with the exact influence score — the empirical
justification for IBMB's practical instantiation.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


def exact_influence(
    apply_fn: Callable[[jnp.ndarray], jnp.ndarray],
    features: np.ndarray,
    output_node: int,
) -> np.ndarray:
    """Exact I(v, u) for all v, for one output node u.

    apply_fn: X (N, F) -> H (N, C) full-graph forward.
    Returns (N,) influence of each node's features on node u's logits.
    """
    x = jnp.asarray(features)

    def out_u(feats):
        return apply_fn(feats)[output_node]          # (C,)

    jac = jax.jacobian(out_u)(x)                      # (C, N, F)
    return np.asarray(jnp.abs(jac).sum(axis=(0, 2)))  # Σ_i Σ_j |·|


def expected_influence_rw(adj_row_norm: np.ndarray, num_layers: int,
                          alpha: float = 0.0) -> np.ndarray:
    """Expected influence ∝ L-step random walk (with optional restart),
    Xu et al. [38] / paper Sec. 3. Dense, for tests: returns (N, N) where
    entry (u, v) is the influence of v on u."""
    n = adj_row_norm.shape[0]
    if alpha <= 0:
        return np.linalg.matrix_power(adj_row_norm, num_layers)
    acc = np.eye(n) * alpha
    walk = np.eye(n)
    for _ in range(num_layers):
        walk = (1 - alpha) * walk @ adj_row_norm
        acc = acc + alpha * walk
    return acc
