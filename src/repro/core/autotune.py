"""Plan-build autotuner: tile-shape search + per-batch backend decisions.

DESIGN.md §14. Preprocessing already measures every batch exactly (IBMB
batches are frozen), so the backend/tile choice can be made ONCE, at plan
build time, and stored in the Plan (format v3) instead of re-guessed at
serving time. Three decisions live here, all DETERMINISTIC analytic
functions of batch structure — never wall-clock measurements, so the same
plan always tunes to the same answer and the choice can be pinned by the
config fingerprint:

* **Tile block B** (per plan — every batch in a cache shares the
  (R, K, B, B) tile shape): sweep ``IBMBConfig.tune_blocks`` candidates and
  keep the one minimizing the padded MXU work the SpMM actually executes,
  ``Σ_batches nonzero_tiles(B) · B²``. Ties break to the LARGER block
  (fewer, denser tiles amortize fixed per-tile cost).
* **Backend** (per batch): bcsr beats the segment path when the padded
  tile flops it does are within ``auto_kappa`` of the exact per-edge work
  the COO gather does — ``nonzero_tiles · B² ≤ auto_kappa · num_edges``.
  Low-fill batches (scattered adjacency the reordering could not bunch)
  stay on the segment path; a plan can mix.
* **Feature-tile width block_f** (per batch): the widest
  ``tune_block_fs`` candidate whose fused-kernel working set — one K-row
  of value tiles + double-buffered x stripes + the output block — fits the
  ``tune_vmem_kb`` budget.

The streaming (out-of-core) builder makes the SAME decisions from the same
inputs (``repro.ooc.stream``), so resident and streamed plans stay
bitwise-identical — including the stored decision arrays.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.batches import PaddedBatch


def tile_shape_stats(src: np.ndarray, dst: np.ndarray, w: np.ndarray,
                     mn: int, block: int) -> Tuple[int, int]:
    """(nonzero_tiles, K) of the block-CSR that ``csr_to_bcsr`` would emit
    for this COO adjacency at tile size ``block`` — computed analytically
    (one ``np.unique``), no tiles materialized. Zero-weight (padded)
    entries are dropped exactly as the converter drops them."""
    nz = np.asarray(w) != 0
    rows = np.asarray(src, np.int64)[nz] // block
    cols = np.asarray(dst, np.int64)[nz] // block
    if len(rows) == 0:
        return 0, 1
    c_tiles = (mn + block - 1) // block
    keys = np.unique(rows * c_tiles + cols)
    k = int(np.bincount(keys // c_tiles).max())
    return int(len(keys)), max(k, 1)


def tile_block_candidates(cfg, mn: int) -> List[int]:
    """Effective candidate blocks: the configured default plus the sweep
    list, each gcd'd with the padded node count exactly as
    ``build_batches`` folds them, deduplicated, ascending."""
    cand = {math.gcd(int(cfg.bcsr_block), mn)}
    for c in getattr(cfg, "tune_blocks", ()) or ():
        cand.add(math.gcd(int(c), mn))
    return sorted(b for b in cand if b >= 1)


def pick_tile_block(costs: Dict[int, int]) -> int:
    """argmin over ``{block: Σ nonzero_tiles·B²}``; ties → larger block."""
    return min(costs, key=lambda b: (costs[b], -b))


def tune_block_f(k: int, block: int, candidates: Sequence[int],
                 vmem_kb: int) -> int:
    """Widest feature-tile width whose fused-kernel working set fits the
    VMEM budget: one (K, B, B) row of value tiles, ``nbuf`` (B, block_f)
    x stripes, and the (B, block_f) output accumulator, all float32."""
    if not candidates:
        return 0
    nbuf = 2 if k > 1 else 1
    budget = int(vmem_kb) * 1024
    vals = 4 * k * block * block
    fit = [c for c in sorted(int(c) for c in candidates)
           if vals + 4 * (nbuf + 1) * block * c <= budget]
    return fit[-1] if fit else int(min(int(c) for c in candidates))


def batch_tile_stats(batch: PaddedBatch) -> dict:
    """JSON-safe per-batch structure record: tile population (at the
    batch's built block shape) + the degree stats the backend decision is
    driven by. This is what plan meta stores as ``batch_stats``."""
    nodes = batch.num_real_nodes
    edges = batch.num_real_edges
    out = dict(nodes=nodes, edges=edges,
               avg_degree=float(edges) / max(nodes, 1))
    if batch.has_bcsr:
        s = batch.bcsr_stats()
        out.update(block=int(batch.tile_vals.shape[-1]),
                   nonzero_tiles=int(s["nonzero_tiles"]),
                   max_tiles_per_row=int(s["max_tiles_per_row"]),
                   tile_fill=float(s["tile_fill"]))
    return out


def decide_backend(stats: dict, auto_kappa: float) -> str:
    """bcsr iff the padded tile flops stay within ``auto_kappa`` of the
    segment path's exact per-edge work (equivalently: tile fill is at
    least 1/kappa of dense). Batches without tiles have no choice."""
    if "nonzero_tiles" not in stats:
        return "segment"
    block = stats["block"]
    padded = stats["nonzero_tiles"] * block * block
    return "bcsr" if padded <= auto_kappa * max(stats["edges"], 1) else "segment"


def decide_batches(batches: Sequence[PaddedBatch], cfg
                   ) -> Tuple[List[str], List[int], List[dict]]:
    """The per-batch half of the autotuner: ``(backends, block_fs, stats)``
    aligned with ``batches``. Pure function of the built batches + config,
    so the resident and streaming builders (which call it chunk by chunk)
    can never diverge. With ``autotune=False`` the decision degenerates to
    the configured backend for every batch (stats are still recorded)."""
    backends: List[str] = []
    block_fs: List[int] = []
    stats: List[dict] = []
    for b in batches:
        s = batch_tile_stats(b)
        if not b.has_bcsr:
            backend = cfg.backend if cfg.backend in ("segment", "dense") \
                else "segment"
        elif getattr(cfg, "autotune", True):
            backend = decide_backend(s, getattr(cfg, "auto_kappa", 16.0))
        else:
            backend = "bcsr"
        bf = 0
        if backend == "bcsr":
            bf = tune_block_f(b.tile_cols.shape[1], b.tile_vals.shape[-1],
                              getattr(cfg, "tune_block_fs", ()),
                              getattr(cfg, "tune_vmem_kb", 8192))
        s["backend"] = backend
        s["block_f"] = bf
        backends.append(backend)
        block_fs.append(bf)
        stats.append(s)
    return backends, block_fs, stats


class _CacheBatchView:
    """Adapter presenting one stacked-cache entry through the few
    ``PaddedBatch`` accessors :func:`decide_batches` touches — the refresh
    path (``core.update``) splices caches rather than keeping batch
    objects, but must make the SAME decisions."""

    def __init__(self, arrays: dict, meta: dict):
        self.tile_cols = arrays.get("tile_cols")
        self.tile_vals = arrays.get("tile_vals")
        self._arrays = arrays
        self._meta = meta

    @property
    def has_bcsr(self) -> bool:
        return self.tile_cols is not None and self.tile_vals is not None

    @property
    def num_real_nodes(self) -> int:
        n = self._meta.get("nodes", 0)
        return int(n) if n else int(np.count_nonzero(
            self._arrays["node_mask"]))

    @property
    def num_real_edges(self) -> int:
        e = self._meta.get("edges", 0)
        return int(e) if e else int(np.count_nonzero(
            self._arrays["edge_weight"]))

    def bcsr_stats(self) -> dict:
        from repro.kernels.spmm.ops import BCSR
        n = self.tile_vals.shape[0] * self.tile_vals.shape[-1]
        return BCSR(self.tile_cols, self.tile_vals, n, n).density_stats()


def decide_cache(cache, cfg) -> Tuple[List[str], List[int], List[dict]]:
    """:func:`decide_batches` over an already-stacked ``BatchCache`` —
    used by the plan-refresh path, which splices parent/rebuilt payload
    instead of keeping ``PaddedBatch`` objects around."""
    views = [_CacheBatchView(cache[i], cache.meta[i])
             for i in range(len(cache))]
    return decide_batches(views, cfg)


def sweep_tile_blocks(batches: Sequence[PaddedBatch],
                      candidates: Sequence[int]
                      ) -> Tuple[Dict[int, int], Dict[int, int]]:
    """Candidate sweep over BUILT batches (resident path): per candidate
    block, the total padded-flops cost and the global K the cache would
    pad to. Works off the padded COO arrays, so it never needs the tiles
    that were (or were not) built."""
    mn = int(batches[0].node_ids.shape[0])
    costs = {b: 0 for b in candidates}
    kmax = {b: 1 for b in candidates}
    for batch in batches:
        for b in candidates:
            t, k = tile_shape_stats(batch.edge_src, batch.edge_dst,
                                    batch.edge_weight, mn, b)
            costs[b] += t * b * b
            kmax[b] = max(kmax[b], k)
    return costs, kmax


def retile_batches(batches: Sequence[PaddedBatch], block: int,
                   pad_k: int) -> List[PaddedBatch]:
    """Re-emit every batch's block-CSR tiles at tile size ``block`` padded
    to ``pad_k`` slots — from the padded COO edge arrays, which carry the
    exact (reordered) batch adjacency with weight-0 padding the converter
    drops. Bitwise-identical to having built at ``block`` directly (the
    streaming builder does exactly that)."""
    import dataclasses

    from repro.graph.csr import coo_to_csr
    from repro.kernels.spmm.ops import csr_to_bcsr

    mn = int(batches[0].node_ids.shape[0])
    out = []
    for batch in batches:
        nz = batch.edge_weight != 0
        sub = coo_to_csr(batch.edge_src[nz], batch.edge_dst[nz], mn,
                         weights=batch.edge_weight[nz])
        bc = csr_to_bcsr(sub.indptr, sub.indices, sub.weights, mn, mn,
                         block=block, pad_k=pad_k)
        out.append(dataclasses.replace(batch, tile_cols=bc.tile_cols,
                                       tile_vals=bc.tile_vals))
    return out


def retune_tile_block(batches: Sequence[PaddedBatch], cfg
                      ) -> Tuple[List[PaddedBatch], int]:
    """The per-plan half of the autotuner (resident path): sweep the
    candidate tile blocks, keep the winner, and retile the batches when it
    differs from what ``build_batches`` already emitted. Returns
    ``(batches, winning_block)``."""
    if not batches or not batches[0].has_bcsr:
        return list(batches), 0
    mn = int(batches[0].node_ids.shape[0])
    cand = tile_block_candidates(cfg, mn)
    built = int(batches[0].tile_vals.shape[-1])
    if len(cand) == 1:
        return list(batches), built
    costs, kmax = sweep_tile_blocks(batches, cand)
    win = pick_tile_block(costs)
    if win == built:
        return list(batches), built
    return retile_batches(batches, win, kmax[win]), win
