"""Auxiliary-node selection (paper Sec. 3.1).

* node-wise: per output node take its top-k APPR neighbors; the batch's aux
  set is the union (optimizes the worst-case objective, Eq. 6).
* batch-wise: topic-sensitive PPR with the batch as teleport set; take the
  top-`budget` nodes (optimizes the average objective, Eq. 5).
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

from repro.graph.csr import CSRGraph
from repro.core.ppr import TopKPPR, topic_sensitive_ppr, heat_kernel


def node_wise_aux(
    ppr: TopKPPR,
    batches: Sequence[np.ndarray],
    k_per_output: int,
) -> List[np.ndarray]:
    """Union of each output node's top-k PPR neighbors (node-wise IBMB)."""
    root_pos = {int(r): i for i, r in enumerate(ppr.roots)}
    out: List[np.ndarray] = []
    for batch in batches:
        sel: List[np.ndarray] = []
        for u in batch:
            i = root_pos[int(u)]
            m = ppr.indices[i] >= 0
            cols = ppr.indices[i][m][:k_per_output]
            sel.append(cols)
        aux = np.unique(np.concatenate(sel + [np.asarray(batch, dtype=np.int32)]))
        out.append(aux.astype(np.int32))
    return out


def batch_wise_aux(
    g: CSRGraph,
    batches: Sequence[np.ndarray],
    budget: Optional[int] = None,
    alpha: float = 0.25,
    num_iters: int = 50,
    method: str = "ppr",
    heat_t: float = 3.0,
) -> List[np.ndarray]:
    """Top-`budget` nodes of the batch-teleport diffusion (batch-wise IBMB).

    budget=None uses the paper's default: as many auxiliary nodes as the
    batch has output nodes (|aux| = |partition|).
    """
    if method == "ppr":
        pi = topic_sensitive_ppr(g, batches, alpha=alpha, num_iters=num_iters)
    elif method == "heat":
        pi = heat_kernel(g, batches, t=heat_t)
    else:
        raise ValueError(f"unknown diffusion: {method}")
    out: List[np.ndarray] = []
    for i, batch in enumerate(batches):
        b = budget if budget is not None else len(batch)
        row = pi[i]
        k = min(b, (row > 0).sum())
        top = np.argpartition(-row, k - 1)[:k] if k > 0 else np.zeros(0, np.int64)
        aux = np.unique(np.concatenate([top.astype(np.int32),
                                        np.asarray(batch, dtype=np.int32)]))
        out.append(aux.astype(np.int32))
    return out
