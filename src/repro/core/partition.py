"""Output-node partitioning (paper Sec. 3.2).

Three schemes:
* ``ppr_distance_partition`` — the paper's greedy merge over sorted PPR
  magnitudes with a union-find and a size cap (node-wise IBMB).
* ``graph_partition`` — METIS stand-in (batch-wise IBMB / Cluster-GCN).
  METIS is unavailable offline; we provide (a) a Fennel single-pass streaming
  partitioner with degree-penalized balance and (b) networkx Louvain
  communities packed to the target size. Both preserve the property the
  paper needs: nearby output nodes land in the same batch so their auxiliary
  sets overlap.
* ``random_partition`` — the paper's "fixed random" ablation.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.graph.csr import CSRGraph, sorted_lookup
from repro.core.ppr import TopKPPR


class _UnionFind:
    def __init__(self, n: int):
        self.parent = np.arange(n)
        self.size = np.ones(n, dtype=np.int64)

    def find(self, x: int) -> int:
        root = x
        while self.parent[root] != root:
            root = self.parent[root]
        while self.parent[x] != root:   # path compression
            self.parent[x], x = root, self.parent[x]
        return root

    def union_capped(self, a: int, b: int, cap: int) -> bool:
        ra, rb = self.find(a), self.find(b)
        if ra == rb:
            return False
        if self.size[ra] + self.size[rb] > cap:
            return False
        if self.size[ra] < self.size[rb]:
            ra, rb = rb, ra
        self.parent[rb] = ra
        self.size[ra] += self.size[rb]
        return True


def ppr_distance_partition(
    ppr: TopKPPR,
    output_nodes: np.ndarray,
    max_outputs_per_batch: int,
    seed: int = 0,
) -> List[np.ndarray]:
    """Greedy merge partitioning from node-wise PPR scores (paper Sec. 3.2).

    Every output node starts in its own batch; (u, v) pairs where both are
    output nodes are scanned in descending PPR magnitude and their batches
    merged while staying under the size cap. Small leftovers are merged
    randomly — from a Generator seeded HERE with ``seed`` (the config
    seed at the pipeline call sites), so the partition is a pure function
    of (ppr, outputs, cap, seed) like every other fingerprinted build
    step. Supports incremental streaming by construction (greedy).
    """
    rng = np.random.default_rng(seed)
    output_nodes = np.asarray(output_nodes)
    n_out = len(output_nodes)
    # map global node id -> position in output_nodes, via one sort (the
    # former per-entry dict lookup was a Python loop over every stored
    # (root, neighbor) pair and dominated partitioning time)
    out_sorted_order = np.argsort(output_nodes, kind="stable")
    out_sorted = output_nodes[out_sorted_order]

    def _positions(ids):
        """Position of each id in output_nodes, -1 when absent."""
        p, hit = sorted_lookup(out_sorted, ids)
        return np.where(hit, out_sorted_order[p], -1)

    root_local = _positions(np.asarray(ppr.roots, dtype=np.int64))
    if (root_local < 0).any():
        bad = np.asarray(ppr.roots)[root_local < 0]
        raise KeyError(f"PPR roots not in output_nodes: {bad[:8].tolist()}")
    # collect (score, u_local, v_local) for pairs of output nodes, in the
    # same root-major / within-row order the scan always used
    idx, val = ppr.indices, ppr.values
    flat = idx.astype(np.int64).ravel()
    v_local = _positions(flat)
    u_local = np.repeat(root_local, idx.shape[1])
    keep = (v_local >= 0) & (v_local != u_local)
    us = u_local[keep]
    vs = v_local[keep]
    ws = val.ravel()[keep]
    uf = _UnionFind(n_out)
    if len(ws):
        order = np.argsort(-ws)
        us = us[order]; vs = vs[order]
        for u, v in zip(us, vs):
            uf.union_capped(int(u), int(v), max_outputs_per_batch)

    # group by root
    roots = np.array([uf.find(i) for i in range(n_out)])
    groups: dict = {}
    for i, r in enumerate(roots):
        groups.setdefault(int(r), []).append(i)
    batches = [np.array(g, dtype=np.int64) for g in groups.values()]

    # randomly merge small leftovers under the cap
    rng.shuffle(batches)
    merged: List[np.ndarray] = []
    cur = None
    batches.sort(key=len)   # small first so leftovers coalesce
    for b in batches:
        if cur is None:
            cur = b
        elif len(cur) + len(b) <= max_outputs_per_batch:
            cur = np.concatenate([cur, b])
        else:
            merged.append(cur)
            cur = b
    if cur is not None and len(cur):
        merged.append(cur)
    return [np.sort(output_nodes[b]).astype(np.int32) for b in merged]


def _fennel(g: CSRGraph, num_parts: int, gamma: float = 1.5,
            seed: int = 0) -> np.ndarray:
    """Fennel streaming partitioner (Tsourakakis et al.): assign each node to
    argmax_p |N(v) ∩ p| − α·γ·size(p)^{γ−1}. Single pass in degree-descending
    order (a common Fennel heuristic)."""
    n = g.num_nodes
    e = max(g.num_edges, 1)
    alpha = np.sqrt(num_parts) * e / (n ** gamma)
    cap = int(1.1 * n / num_parts) + 1
    assign = np.full(n, -1, dtype=np.int64)
    sizes = np.zeros(num_parts, dtype=np.int64)
    order = np.argsort(-g.degrees())
    nbr_count = np.zeros(num_parts, dtype=np.float64)
    for v in order:
        nbr_count[:] = 0.0
        for u in g.neighbors(int(v)):
            a = assign[u]
            if a >= 0:
                nbr_count[a] += 1.0
        score = nbr_count - alpha * gamma * np.power(np.maximum(sizes, 1), gamma - 1)
        score[sizes >= cap] = -np.inf
        p = int(np.argmax(score))
        assign[v] = p
        sizes[p] += 1
    return assign


def _louvain(g: CSRGraph, seed: int = 0) -> np.ndarray:
    import networkx as nx
    src, dst = g.to_coo()
    G = nx.Graph()
    G.add_nodes_from(range(g.num_nodes))
    G.add_edges_from(zip(src.tolist(), dst.tolist()))
    comms = nx.community.louvain_communities(G, seed=seed)
    assign = np.zeros(g.num_nodes, dtype=np.int64)
    for i, c in enumerate(comms):
        assign[list(c)] = i
    return assign


def graph_partition(
    g: CSRGraph,
    output_nodes: np.ndarray,
    num_batches: int,
    method: str = "fennel",
    seed: int = 0,
) -> List[np.ndarray]:
    """Partition the WHOLE graph (METIS-style), then group output nodes by
    their partition (Cluster-GCN / batch-wise IBMB). Partitions that end up
    with no output nodes are dropped; overfull ones are split."""
    output_nodes = np.asarray(output_nodes)
    if method == "fennel":
        assign = _fennel(g, num_batches, seed=seed)
    elif method == "louvain":
        assign = _louvain(g, seed=seed)
    elif method == "random":
        rng = np.random.default_rng(seed)
        assign = rng.integers(0, num_batches, size=g.num_nodes)
    else:
        raise ValueError(f"unknown partition method: {method}")

    out_assign = assign[output_nodes]
    batches: List[np.ndarray] = []
    for p in np.unique(out_assign):
        nodes = output_nodes[out_assign == p]
        if len(nodes):
            batches.append(np.sort(nodes).astype(np.int32))
    # pack to approximately num_batches: split overly large, merge tiny
    target = max(1, int(np.ceil(len(output_nodes) / num_batches)))
    out: List[np.ndarray] = []
    for b in batches:
        if len(b) > 2 * target:
            for s in range(0, len(b), target):
                out.append(b[s:s + target])
        else:
            out.append(b)
    out.sort(key=len)
    merged: List[np.ndarray] = []
    cur: Optional[np.ndarray] = None
    for b in out:
        if cur is None:
            cur = b
        elif len(cur) + len(b) <= target:
            cur = np.sort(np.concatenate([cur, b]))
        else:
            merged.append(cur)
            cur = b
    if cur is not None and len(cur):
        merged.append(cur)
    return merged


def random_partition(
    output_nodes: np.ndarray,
    num_batches: int,
    seed: int = 0,
) -> List[np.ndarray]:
    """Fixed random batches (paper's ablation baseline)."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(np.asarray(output_nodes))
    return [np.sort(c).astype(np.int32) for c in np.array_split(perm, num_batches) if len(c)]
