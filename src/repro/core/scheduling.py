"""Batch scheduling (paper Sec. 4, Fig. 7).

Similar consecutive batches make the optimizer take compounding steps in a
suboptimal direction → accuracy spikes. The paper measures batch similarity
via symmetrized KL divergence of training-label distributions and proposes:
 (i) a fixed order maximizing consecutive distance (max-TSP, solved with
     simulated annealing — paper App. B uses python-tsp's SA), and
 (ii) sampling the next batch weighted by distance to the current one.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np


def label_distributions(batch_labels: Sequence[np.ndarray], num_classes: int,
                        smooth: float = 1e-6) -> np.ndarray:
    """Normalized training-label distribution p_i = c_i / Σ_j c_j per batch."""
    out = np.zeros((len(batch_labels), num_classes), dtype=np.float64)
    for i, lab in enumerate(batch_labels):
        cnt = np.bincount(np.asarray(lab), minlength=num_classes).astype(np.float64)
        out[i] = cnt + smooth
        out[i] /= out[i].sum()
    return out


def pairwise_kl_distance(p: np.ndarray) -> np.ndarray:
    """Symmetrized KL: d_ab = KL(a‖b) + KL(b‖a). Returns (B, B)."""
    logp = np.log(p)
    # KL(a||b) = Σ p_a (log p_a − log p_b)
    ent = (p * logp).sum(axis=1)                       # Σ p_a log p_a
    cross = p @ logp.T                                 # Σ p_a log p_b
    kl = ent[:, None] - cross
    return kl + kl.T


def tsp_max_order(dist: np.ndarray, iters: int = 20_000, seed: int = 0,
                  t0: float = 1.0, t1: float = 1e-3) -> np.ndarray:
    """Max-distance closed tour via simulated annealing (2-opt + swap moves).

    Maximizing total consecutive distance ≡ solving max-TSP on the loop that
    visits every batch (paper: 'traveling salesman problem for finding the
    maximum distance loop').
    """
    n = dist.shape[0]
    if n <= 2:
        return np.arange(n)
    rng = np.random.default_rng(seed)
    order = rng.permutation(n)

    def tour_len(o):
        return dist[o, np.roll(o, -1)].sum()

    cur = tour_len(order)
    best, best_len = order.copy(), cur
    for it in range(iters):
        temp = t0 * (t1 / t0) ** (it / max(iters - 1, 1))
        i, j = sorted(rng.integers(0, n, size=2))
        if i == j:
            continue
        if rng.random() < 0.5:
            cand = order.copy()
            cand[i:j + 1] = cand[i:j + 1][::-1]     # 2-opt segment reversal
        else:
            cand = order.copy()
            cand[i], cand[j] = cand[j], cand[i]     # swap
        new = tour_len(cand)
        # MAXIMIZE: accept if longer, or with SA probability
        if new > cur or rng.random() < np.exp((new - cur) / max(temp, 1e-9)):
            order, cur = cand, new
            if cur > best_len:
                best, best_len = order.copy(), cur
    return best


def weighted_sampling_order(dist: np.ndarray, num_epochs: int = 1,
                            seed: int = 0) -> np.ndarray:
    """Sample the next batch ∝ distance to the current batch, without
    replacement within an epoch (every batch used exactly once per epoch,
    keeping training unbiased — paper Sec. 4)."""
    n = dist.shape[0]
    rng = np.random.default_rng(seed)
    orders = []
    cur = int(rng.integers(n))
    for _ in range(num_epochs):
        remaining = set(range(n))
        epoch = []
        for _ in range(n):
            rem = np.array(sorted(remaining))
            w = dist[cur, rem].astype(np.float64)
            w = np.maximum(w, 1e-12)
            cur = int(rng.choice(rem, p=w / w.sum()))
            remaining.discard(cur)
            epoch.append(cur)
        orders.append(np.array(epoch))
    return np.concatenate(orders) if num_epochs > 1 else orders[0]


def make_schedule(
    batch_labels: Sequence[np.ndarray],
    num_classes: int,
    mode: str = "tsp",
    num_epochs: int = 1,
    seed: int = 0,
) -> np.ndarray:
    """Return the batch visit order for `num_epochs` epochs, flattened."""
    n = len(batch_labels)
    if mode == "none" or n <= 2:
        return np.tile(np.arange(n), num_epochs)
    p = label_distributions(batch_labels, num_classes)
    d = pairwise_kl_distance(p)
    if mode == "tsp":
        order = tsp_max_order(d, seed=seed)
        return np.tile(order, num_epochs)
    if mode == "weighted":
        return weighted_sampling_order(d, num_epochs=num_epochs, seed=seed)
    raise ValueError(f"unknown schedule mode: {mode}")
