"""The paper's primary contribution: influence-based mini-batching (IBMB).

Pipeline:  influence ≈ PPR  →  output-node partitioning  →  auxiliary-node
selection  →  induced padded subgraph batches  →  batch scheduling.
"""
from repro.core.ppr import (
    push_appr, topic_sensitive_ppr, dense_ppr, heat_kernel, TopKPPR,
    ppr_dirty_roots, push_appr_incremental,
)
from repro.core.partition import (
    ppr_distance_partition, graph_partition, random_partition,
)
from repro.core.aux_selection import node_wise_aux, batch_wise_aux
from repro.core.batches import PaddedBatch, build_batches, BatchCache
from repro.core.plan import (
    Plan, RoutingIndex, PlanFormatError, plan_fingerprint, check_routing,
)
from repro.core.update import GraphDelta, PlanDelta, PlanUpdater
from repro.core.scheduling import (
    label_distributions, pairwise_kl_distance, tsp_max_order, weighted_sampling_order,
)
from repro.core.pipeline import IBMBPipeline, IBMBConfig
from repro.core import autotune

__all__ = [
    "autotune",
    "push_appr", "topic_sensitive_ppr", "dense_ppr", "heat_kernel", "TopKPPR",
    "ppr_dirty_roots", "push_appr_incremental",
    "ppr_distance_partition", "graph_partition", "random_partition",
    "node_wise_aux", "batch_wise_aux",
    "PaddedBatch", "build_batches", "BatchCache",
    "Plan", "RoutingIndex", "PlanFormatError", "plan_fingerprint",
    "check_routing",
    "GraphDelta", "PlanDelta", "PlanUpdater",
    "label_distributions", "pairwise_kl_distance", "tsp_max_order", "weighted_sampling_order",
    "IBMBPipeline", "IBMBConfig",
]
