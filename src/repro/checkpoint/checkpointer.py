"""Fault-tolerant checkpointing (no orbax on this box — built from scratch).

Design for 1000+ nodes (DESIGN.md §6):
* pytree → flat {path: array} dict; each host writes ITS OWN shard file
  (`shard-<host>.npz`, zstd) containing only the addressable slices of its
  devices, plus a msgpack manifest (step, mesh shape, tree structure, rng).
* writes are ATOMIC (tmp file + rename) and ASYNC (background thread) so the
  step loop never blocks on disk.
* `restore` re-stitches global arrays from any number of shard files and
  re-shards them onto the CURRENT mesh — so a job restarted with a different
  data-parallel size (elastic scaling) just works: parameters are re-laid-out
  by jax.device_put, and the IBMB batch schedule re-partitions by batch id.
* `latest_step` + `auto_resume` scan the run dir; a half-written checkpoint
  (missing manifest) is ignored — crash-safe.

On this single-process box there is one shard file; the format is unchanged.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

try:
    import zstandard as zstd
except ImportError:  # pragma: no cover
    zstd = None


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _treedef_of(tree: Any):
    return jax.tree_util.tree_structure(tree)


def save_pytree(tree: Any, directory: str, step: int,
                extra: Optional[Dict] = None) -> str:
    """Synchronous atomic save. Returns the checkpoint dir."""
    ckpt = os.path.join(directory, f"step-{step:08d}")
    tmp = ckpt + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    # shard file (single host here; multi-host writes shard-<pid>)
    host = jax.process_index() if jax.process_count() > 1 else 0
    np.savez(os.path.join(tmp, f"shard-{host}.npz"), **flat)
    manifest = {
        "step": step,
        "keys": sorted(flat.keys()),
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        "hosts": jax.process_count(),
        "time": time.time(),
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(ckpt):
        shutil.rmtree(ckpt)
    os.rename(tmp, ckpt)                      # atomic publish
    return ckpt


def load_pytree(template: Any, directory: str, step: Optional[int] = None,
                shardings: Any = None) -> Tuple[Any, Dict]:
    """Restore into the structure of `template`; optionally re-shard onto the
    current mesh via `shardings` (elastic restart)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    ckpt = os.path.join(directory, f"step-{step:08d}")
    with open(os.path.join(ckpt, "manifest.json")) as f:
        manifest = json.load(f)
    flat: Dict[str, np.ndarray] = {}
    for fn in sorted(os.listdir(ckpt)):
        if fn.startswith("shard-") and fn.endswith(".npz"):
            z = np.load(os.path.join(ckpt, fn))
            for k in z.files:
                flat[k] = z[k]
    leaves_paths = jax.tree_util.tree_flatten_with_path(template)[0]
    treedef = jax.tree_util.tree_structure(template)
    out_leaves = []
    sh_leaves = jax.tree_util.tree_leaves(shardings) if shardings is not None \
        else [None] * len(leaves_paths)
    for (path, leaf), sh in zip(leaves_paths, sh_leaves):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = flat[key]
        if sh is not None:
            arr = jax.device_put(arr, sh)
        elif hasattr(leaf, "dtype"):
            arr = jax.numpy.asarray(arr, dtype=leaf.dtype)
        out_leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out_leaves), manifest


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    best = None
    for fn in os.listdir(directory):
        m = re.match(r"step-(\d+)$", fn)
        if m and os.path.exists(os.path.join(directory, fn, "manifest.json")):
            s = int(m.group(1))
            best = s if best is None else max(best, s)
    return best


class Checkpointer:
    """Async checkpointer with bounded retention."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    def save(self, tree: Any, step: int, extra: Optional[Dict] = None,
             blocking: bool = False) -> None:
        # snapshot to host memory NOW (device buffers may be donated next step)
        host_tree = jax.tree_util.tree_map(lambda x: np.asarray(x), tree)
        self.wait()

        def work():
            save_pytree(host_tree, self.directory, step, extra)
            self._gc()

        if blocking:
            work()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None):
        return load_pytree(template, self.directory, step, shardings)

    def auto_resume(self, template: Any, shardings: Any = None):
        """Return (tree, manifest) from the latest checkpoint, or None."""
        step = latest_step(self.directory)
        if step is None:
            return None
        return self.restore(template, step, shardings)

    def _gc(self) -> None:
        steps = sorted(
            int(m.group(1)) for fn in os.listdir(self.directory)
            if (m := re.match(r"step-(\d+)$", fn)))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step-{s:08d}"),
                          ignore_errors=True)
