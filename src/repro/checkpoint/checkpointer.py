"""Fault-tolerant checkpointing (no orbax on this box — built from scratch).

Design for 1000+ nodes (DESIGN.md §6):
* pytree → flat {path: array} dict; each host writes ITS OWN shard file
  (`shard-<host>.npz`, zstd) containing only the addressable slices of its
  devices, plus a msgpack manifest (step, mesh shape, tree structure, rng).
* writes are ATOMIC (tmp file + rename) and ASYNC (background thread) so the
  step loop never blocks on disk.
* `restore` re-stitches global arrays from any number of shard files and
  re-shards them onto the CURRENT mesh — so a job restarted with a different
  data-parallel size (elastic scaling) just works: parameters are re-laid-out
  by jax.device_put, and the IBMB batch schedule re-partitions by batch id.
* `latest_step` + `auto_resume` scan the run dir; a half-written checkpoint
  (missing manifest) is ignored — crash-safe.
* the manifest carries a crc32 per array (DESIGN.md §12); a byte-flipped or
  truncated shard raises :class:`CheckpointCorruptError` on restore instead
  of resuming from garbage, and `auto_resume` falls back to the newest
  INTACT step.

On this single-process box there is one shard file; the format is unchanged.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.faults import NO_FAULTS

try:
    import zstandard as zstd
except ImportError:  # pragma: no cover
    zstd = None


class CheckpointError(RuntimeError):
    """A checkpoint operation failed (including an ASYNC save whose error
    is re-raised on the next ``save()``/``wait()`` — DESIGN.md §12)."""


class CheckpointCorruptError(CheckpointError):
    """The on-disk checkpoint exists but fails integrity checks (truncated
    shard, checksum mismatch, unreadable manifest)."""


def _crc32(a: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(a).tobytes())


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _treedef_of(tree: Any):
    return jax.tree_util.tree_structure(tree)


def save_pytree(tree: Any, directory: str, step: int,
                extra: Optional[Dict] = None, faults=NO_FAULTS) -> str:
    """Synchronous atomic save. Returns the checkpoint dir.

    The manifest is written LAST inside the tmp dir and the dir rename is
    the publish point, so a crash anywhere before the rename leaves only an
    ignorable ``.tmp``; the manifest records a crc32 per array so restore
    can prove shard integrity (DESIGN.md §12)."""
    ckpt = os.path.join(directory, f"step-{step:08d}")
    tmp = ckpt + ".tmp"
    try:
        faults.fire("ckpt_io", OSError)
        os.makedirs(tmp, exist_ok=True)
        flat = _flatten(tree)
        # shard file (single host here; multi-host writes shard-<pid>)
        host = jax.process_index() if jax.process_count() > 1 else 0
        np.savez(os.path.join(tmp, f"shard-{host}.npz"), **flat)
        manifest = {
            "step": step,
            "keys": sorted(flat.keys()),
            "shapes": {k: list(v.shape) for k, v in flat.items()},
            "dtypes": {k: str(v.dtype) for k, v in flat.items()},
            "checksums": {k: _crc32(v) for k, v in flat.items()},
            "hosts": jax.process_count(),
            "time": time.time(),
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(ckpt):
            shutil.rmtree(ckpt)
        os.rename(tmp, ckpt)                  # atomic publish
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return ckpt


def load_pytree(template: Any, directory: str, step: Optional[int] = None,
                shardings: Any = None) -> Tuple[Any, Dict]:
    """Restore into the structure of `template`; optionally re-shard onto the
    current mesh via `shardings` (elastic restart)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    ckpt = os.path.join(directory, f"step-{step:08d}")
    try:
        with open(os.path.join(ckpt, "manifest.json")) as f:
            manifest = json.load(f)
    except FileNotFoundError:
        raise
    except (json.JSONDecodeError, OSError, UnicodeDecodeError) as e:
        raise CheckpointCorruptError(
            f"{ckpt}: unreadable manifest ({type(e).__name__}: {e})") from e
    flat: Dict[str, np.ndarray] = {}
    try:
        for fn in sorted(os.listdir(ckpt)):
            if fn.startswith("shard-") and fn.endswith(".npz"):
                with np.load(os.path.join(ckpt, fn),
                             allow_pickle=False) as z:
                    for k in z.files:
                        flat[k] = z[k]       # materialize: zip member CRC
    except CheckpointError:
        raise
    except Exception as e:
        # BadZipFile / zlib.error / ValueError / EOFError — the shard is
        # truncated or mangled; one catchable type for recovery code.
        raise CheckpointCorruptError(
            f"{ckpt}: corrupt or truncated shard "
            f"({type(e).__name__}: {e})") from e
    for k, want in manifest.get("checksums", {}).items():
        if k not in flat:
            raise CheckpointCorruptError(
                f"{ckpt}: shard files are missing checksummed leaf {k!r}")
        got = _crc32(flat[k])
        if got != int(want):
            raise CheckpointCorruptError(
                f"{ckpt}: checksum mismatch for leaf {k!r} (stored "
                f"{int(want):#010x}, computed {got:#010x})")
    leaves_paths = jax.tree_util.tree_flatten_with_path(template)[0]
    treedef = jax.tree_util.tree_structure(template)
    out_leaves = []
    sh_leaves = jax.tree_util.tree_leaves(shardings) if shardings is not None \
        else [None] * len(leaves_paths)
    for (path, leaf), sh in zip(leaves_paths, sh_leaves):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = flat[key]
        if sh is not None:
            arr = jax.device_put(arr, sh)
        elif hasattr(leaf, "dtype"):
            arr = jax.numpy.asarray(arr, dtype=leaf.dtype)
        out_leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out_leaves), manifest


def all_steps(directory: str) -> List[int]:
    """Published checkpoint steps (manifest present), newest first."""
    if not os.path.isdir(directory):
        return []
    steps = []
    for fn in os.listdir(directory):
        m = re.match(r"step-(\d+)$", fn)
        if m and os.path.exists(os.path.join(directory, fn, "manifest.json")):
            steps.append(int(m.group(1)))
    return sorted(steps, reverse=True)


def latest_step(directory: str) -> Optional[int]:
    steps = all_steps(directory)
    return steps[0] if steps else None


class Checkpointer:
    """Async checkpointer with bounded retention.

    Failure contract (DESIGN.md §12): an error in the BACKGROUND save
    thread is captured, not swallowed — the next ``save()`` or ``wait()``
    re-raises it as :class:`CheckpointError` (chained to the original), so
    a training loop that keeps checkpointing cannot silently lose every
    checkpoint to a full disk. ``faults`` is the ``ckpt_io`` injection
    hook."""

    def __init__(self, directory: str, keep: int = 3, faults=NO_FAULTS):
        self.directory = directory
        self.keep = keep
        self.faults = faults
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        os.makedirs(directory, exist_ok=True)

    def save(self, tree: Any, step: int, extra: Optional[Dict] = None,
             blocking: bool = False) -> None:
        # snapshot to host memory NOW (device buffers may be donated next step)
        host_tree = jax.tree_util.tree_map(lambda x: np.asarray(x), tree)
        self.wait()

        def work():
            try:
                save_pytree(host_tree, self.directory, step, extra,
                            faults=self.faults)
                self._gc()
            except BaseException as e:   # captured, re-raised by wait()
                self._error = e

        if blocking:
            work()
            self.wait()                  # surface a blocking-save error too
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        """Join any in-flight save; re-raise its stored error (one-shot)."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise CheckpointError(
                f"async checkpoint save failed: "
                f"{type(err).__name__}: {err}") from err

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None):
        return load_pytree(template, self.directory, step, shardings)

    def auto_resume(self, template: Any, shardings: Any = None):
        """Return (tree, manifest) from the newest INTACT checkpoint, or
        None when the dir holds no published checkpoints at all.

        Corrupt steps (truncated shard, checksum mismatch) are skipped
        newest-to-oldest (DESIGN.md §12) — losing one save interval beats
        resuming from garbage or refusing to start. Raises
        :class:`CheckpointCorruptError` only when checkpoints exist and
        EVERY one of them is corrupt."""
        steps = all_steps(self.directory)
        if not steps:
            return None
        last_err: Optional[CheckpointError] = None
        for step in steps:
            try:
                return self.restore(template, step, shardings)
            except CheckpointCorruptError as e:
                last_err = e
        raise CheckpointCorruptError(
            f"{self.directory}: all {len(steps)} checkpoints are corrupt "
            f"(newest failure: {last_err})") from last_err

    def _gc(self) -> None:
        steps = sorted(
            int(m.group(1)) for fn in os.listdir(self.directory)
            if (m := re.match(r"step-(\d+)$", fn)))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step-{s:08d}"),
                          ignore_errors=True)
