from repro.checkpoint.checkpointer import (
    Checkpointer, save_pytree, load_pytree, latest_step,
)

__all__ = ["Checkpointer", "save_pytree", "load_pytree", "latest_step"]
