from repro.checkpoint.checkpointer import (
    Checkpointer, CheckpointCorruptError, CheckpointError,
    all_steps, save_pytree, load_pytree, latest_step,
)

__all__ = ["Checkpointer", "CheckpointCorruptError", "CheckpointError",
           "all_steps", "save_pytree", "load_pytree", "latest_step"]
