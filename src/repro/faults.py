"""Deterministic fault injection + fault-handling observability
(DESIGN.md §12).

Production GNN serving is judged on what happens when things break (the
full-graph-vs-mini-batch systems comparison, arXiv 2406.00552): a crashed
worker must not hang futures, a corrupt artifact must not be served, a
failed swap must leave the tenant on the stale-but-correct parent plan.
IBMB's precomputed, deterministic batches make principled recovery cheap —
and make the *faults themselves* replayable: every failure path in this
repo is driven through one seeded :class:`FaultInjector` with NAMED
injection points, so a chaos run is a (seed, rates/script) pair, not a
flaky accident.

Injection points (the table in DESIGN.md §12):

==================  ========================================================
point               fires inside
==================  ========================================================
``forward``         ``AsyncGNNEngine._dispatch`` — before each attempt of a
                    window's coalesced forward (transient model failure)
``dispatch_delay``  ``AsyncGNNEngine._dispatch`` — stall before running the
                    window (slow accelerator / noisy neighbor)
``worker_death``    ``AsyncGNNEngine.step`` — after windows are taken off
                    the queue (the dispatcher thread dies mid-flight)
``plan_io``         ``Plan.save`` / ``Plan.load`` (disk write/read error)
``batch_io``        ``repro.ooc.store.PlanStore.read_batch`` — the lazy
                    per-batch disk read behind out-of-core serving/training
                    (DESIGN.md §13). Transient ``OSError`` is retried
                    (bounded); a checksum mismatch is NOT retried — it
                    raises ``PlanFormatError`` like every other corrupt
                    artifact (§12 semantics).
``ckpt_io``         ``Checkpointer`` background save (async write error)
``loader``          ``PrefetchLoader`` worker — staging batch t+1 fails
==================  ========================================================

Two firing modes, combinable per point:

* ``rates={"forward": 0.01}`` — every call draws from a per-point seeded
  ``np.random.Generator``; deterministic for a fixed (seed, call sequence).
* ``script={"forward": [0, 3]}`` — fire on exactly those call indices
  (0-based per point); what the FakeClock test suite uses to place a fault
  on a precise window. When a point has BOTH, they union: scripted indices
  always fire, every other call falls through to the rate draw — how the
  chaos bench guarantees at least one injected fault on top of a
  background rate.

The default everywhere is the :data:`NO_FAULTS` singleton whose
``fire``/``delay`` are constant-returning no-ops — production paths pay one
attribute load + one trivially-inlined call, and no RNG state exists.

Byte corruption (the ``corrupt`` failure class) is not an in-process raise:
tests and benches call :func:`corrupt_file` to deterministically flip bytes
in an artifact on disk, then assert the loader *detects* it
(``PlanFormatError`` / ``CheckpointCorruptError``) instead of serving
garbage.
"""
from __future__ import annotations

import os
import zlib
from typing import Dict, List, Optional, Sequence

import numpy as np


class InjectedFault(RuntimeError):
    """An error raised by a FaultInjector injection point (never by real
    code paths) — test/bench assertions can distinguish injected chaos from
    genuine bugs."""


class WorkerDeath(InjectedFault):
    """Injected crash of a dispatcher/worker loop (the ``worker_death``
    point) — the watchdog-restart failure class."""


#: The canonical registry of injection-point names: point -> where it
#: fires. Every ``fire``/``delay``/``should_fire`` call site, every
#: ``rates=``/``script=`` key in src/ and benchmarks/, and the DESIGN.md
#: §12 table must agree with this dict — enforced both directions by the
#: ``fault-point`` rule of ``repro.analysis`` and by
#: ``tools/check_docs_refs.py`` (DESIGN.md §15). Unit tests may exercise
#: arbitrary point names against a bare ``FaultInjector``; the registry
#: governs the named points production code paths use.
FAULT_POINTS: Dict[str, str] = {
    "forward": "AsyncGNNEngine._dispatch, before each forward attempt",
    "dispatch_delay": "AsyncGNNEngine._dispatch, stall before a window",
    "worker_death": "AsyncGNNEngine.step, after windows go in-flight",
    "plan_io": "Plan.save / Plan.load",
    "ckpt_io": "Checkpointer background save",
    "loader": "PrefetchLoader worker, staging batch t+1",
    "batch_io": "PlanStore.read_batch, before each per-batch disk read",
}


class _NoFaults:
    """Inert injector: the production default. ``fire`` and ``should_fire``
    never trigger, ``delay`` is 0.0, and no RNG/counter state exists, so
    hot paths pay ~zero cost."""

    active = False

    def should_fire(self, point: str) -> bool:
        return False

    def fire(self, point: str, exc=None) -> None:
        return None

    def delay(self, point: str) -> float:
        return 0.0

    def snapshot(self) -> Dict[str, Dict[str, int]]:
        return {}


NO_FAULTS = _NoFaults()


class FaultInjector:
    """Seeded, named-point fault injector (DESIGN.md §12).

    ``rates`` maps point → per-call firing probability; ``script`` maps
    point → explicit 0-based call indices that fire (when both name a
    point they union: scripted indices always fire, other calls fall
    through to the rate). ``delays`` maps point → seconds returned by
    ``delay`` when that point fires (for stall-style faults). Each point
    gets its own ``np.random.Generator`` derived from (seed, point), so
    adding traffic on one point never perturbs another point's draw
    sequence.
    """

    def __init__(self, seed: int = 0,
                 rates: Optional[Dict[str, float]] = None,
                 script: Optional[Dict[str, Sequence[int]]] = None,
                 delays: Optional[Dict[str, float]] = None):
        self.seed = int(seed)
        self.rates = dict(rates or {})
        self.script = {k: frozenset(int(i) for i in v)
                       for k, v in (script or {}).items()}
        self.delays = dict(delays or {})
        self._rng: Dict[str, np.random.Generator] = {}
        self.calls: Dict[str, int] = {}
        self.fired: Dict[str, int] = {}

    active = True

    def _gen(self, point: str) -> np.random.Generator:
        g = self._rng.get(point)
        if g is None:
            g = self._rng[point] = np.random.default_rng(
                [self.seed, zlib.crc32(point.encode())])
        return g

    def should_fire(self, point: str) -> bool:
        """Advance this point's call counter; True when this call faults."""
        n = self.calls.get(point, 0)
        self.calls[point] = n + 1
        if point in self.script and n in self.script[point]:
            hit = True
        elif point in self.rates:
            hit = bool(self._gen(point).random() < self.rates[point])
        else:
            hit = False
        if hit:
            self.fired[point] = self.fired.get(point, 0) + 1
        return hit

    def fire(self, point: str, exc=None) -> None:
        """Raise ``exc`` (default :class:`InjectedFault`) when this call of
        ``point`` faults; no-op otherwise."""
        if self.should_fire(point):
            cls = exc or InjectedFault
            raise cls(f"injected fault at {point!r} "
                      f"(call {self.calls[point] - 1}, seed {self.seed})")

    def delay(self, point: str) -> float:
        """Seconds to stall when this call of ``point`` faults, else 0."""
        if point in self.delays and self.should_fire(point):
            return float(self.delays[point])
        return 0.0

    def snapshot(self) -> Dict[str, Dict[str, int]]:
        """Per-point {calls, fired} — the chaos bench's evidence that the
        injected failure actually happened."""
        return {p: {"calls": self.calls[p], "fired": self.fired.get(p, 0)}
                for p in sorted(self.calls)}


class FaultStats:
    """Counter bag for fault-handling observability — the ``ServeStats``
    idiom (DESIGN.md §11) applied to the degradation machinery: each layer
    instantiates it with its own counter names, mutates under its own lock,
    and exposes a consistent dict via ``snapshot()`` (DESIGN.md §12)."""

    def __init__(self, *names: str):
        self._names = tuple(names)
        for k in names:
            setattr(self, k, 0)

    def bump(self, name: str, n: int = 1) -> None:
        setattr(self, name, getattr(self, name) + n)

    def snapshot(self) -> Dict[str, int]:
        return {k: getattr(self, k) for k in self._names}


def corrupt_file(path: str, seed: int = 0, nbytes: int = 8,
                 offset: Optional[int] = None) -> List[int]:
    """Deterministically flip ``nbytes`` bytes of ``path`` in place (the
    ``corrupt`` failure class, DESIGN.md §12). With ``offset=None`` the
    positions are drawn seeded from the back half of the file — past the
    zip directory/headers of an ``.npz``, into array payload, where only a
    checksum (ours or the zip member CRC) can catch the damage. Returns the
    corrupted byte offsets so tests can report what they broke."""
    size = os.path.getsize(path)
    if size == 0:
        raise ValueError(f"{path} is empty — nothing to corrupt")
    rng = np.random.default_rng([seed, zlib.crc32(b"corrupt_file")])
    if offset is not None:
        positions = [int(offset) + i for i in range(nbytes)]
    else:
        lo = size // 2
        positions = sorted(int(p) for p in rng.integers(
            lo, size, size=min(nbytes, max(1, size - lo))))
    with open(path, "r+b") as f:
        for p in positions:
            f.seek(p)
            b = f.read(1)
            f.seek(p)
            f.write(bytes([b[0] ^ 0xFF]))
    return positions
