"""`repro.dist` — logical-axis sharding for the whole stack (DESIGN.md §5).

Four layers:
* `annotate(x, *logical_axes)` — the ONLY distribution primitive model code
  touches. A sharding constraint expressed in logical axis names; a no-op
  outside a `logical_rules` context, so the same model runs unsharded on CPU.
* `repro.dist.logical` — name→mesh-axis binding with priority arbitration.
* `repro.dist.sharding` — path/shape-driven specs for parameter, optimizer,
  cache, and batch pytrees, plus the divisibility-fallback `fit_spec`.
* `repro.dist.data_parallel` — data-parallel Plan execution (DESIGN.md §9):
  `ShardedPlanExecutor` runs a Plan's schedule as shard_map super-steps
  (one batch per device, psum-mean gradients). Imported lazily by its
  consumers (trainer/engine/loader) so `import repro.dist` stays light.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding

from repro.dist.logical import (
    current_mesh, current_rules, logical_rules, spec_for)
from repro.dist.sharding import (
    batch_spec, cache_spec, data_axes, fit_spec, logical_rules_for, opt_spec,
    param_spec, tree_shardings, with_shardings)

__all__ = [
    "annotate", "logical_rules", "spec_for", "current_mesh", "current_rules",
    "fit_spec", "param_spec", "opt_spec", "cache_spec", "batch_spec",
    "tree_shardings", "with_shardings", "logical_rules_for", "data_axes",
]


def annotate(x, *logical_axes):
    """Constrain `x`'s sharding by logical axis names; identity when no
    `logical_rules` context is active.

    Entries may be None (dimension explicitly unconstrained). Axes align to
    the TRAILING dims of `x` when ranks differ (stacked/scanned prefixes stay
    unconstrained), and any mesh axis that does not divide its dimension is
    dropped — `annotate` can therefore be called unconditionally on every
    (arch × shape) combination."""
    rules = current_rules()
    if rules is None:
        return x
    if len(logical_axes) > x.ndim:
        logical_axes = logical_axes[len(logical_axes) - x.ndim:]
    spec = spec_for(logical_axes, rules)
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = fit_spec(mesh, x.shape, tuple(spec))
    if all(e is None for e in spec):
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
