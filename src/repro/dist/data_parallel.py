"""Data-parallel Plan execution over a device mesh (DESIGN.md §9).

The paper's amortization precomputes fixed-shape batches; the next scale
lever is executing those frozen batches across a mesh instead of one device
at a time. The unit of multi-device work is the **super-step**: the Plan's
schedule is grouped into consecutive runs of `world` batches (`world` =
product of the mesh's data-axis sizes), every device takes one batch, and
one `shard_map`-ed forward/backward runs per super-step with a `psum`
gradient mean — semantically identical to single-device training with
gradient accumulation over `world` micro-batches.

Spec choices (DESIGN.md §9):

* **batches shard, params replicate.** Every stacked batch field gets its
  leading (super-step) dim partitioned over the mesh's data axes; GNN
  params/optimizer state are small, so they follow `repro.dist.sharding`'s
  "replication is always correct" policy — `replicated_shardings` routes
  through the same `fit_spec`/`tree_shardings` machinery as the LM stack.
* **ragged tails pad with weight 0.** All batches in one Plan already share
  ONE padded shape bucket (BatchCache stacks them contiguously and records
  the real counts in its padding meta), so the only raggedness left is the
  last super-step of an epoch: it is padded by repeating the final real
  batch with weight 0, and the weighted `psum` mean divides by the REAL
  count — bitwise the same update `GradAccumulator.flush` would apply.
* **backends.** Every aggregation backend runs under `shard_map`. The bcsr
  SpMM off-TPU is the compiled streaming path (`spmm_bcsr_stream` — plain
  XLA scan, DESIGN.md §14), so it partitions exactly like the segment
  gather + segment-sum; on TPU it is the fused Pallas kernel, invoked
  per-device inside the manually partitioned body. Backend selection is a
  `BackendPolicy` (fixed or per-batch auto from the plan's autotuned
  decisions); the executor keeps one set of super-step executables per
  (backend, block_f) decision, built lazily.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.dist.sharding import data_axes, fit_spec, tree_shardings
from repro.models.gnn import policy as gnn_policy
from repro.models.gnn.models import (
    GNNConfig, gnn_apply, masked_xent, output_logits,
)
from repro.optim.optimizers import apply_updates


# ------------------------------------------------------------------- meshes
def data_mesh(num_devices: Optional[int] = None) -> Mesh:
    """A 1-D pure data-parallel mesh over (the first `num_devices` of) the
    local devices — the mesh `GNNTrainer.fit(mesh=...)` and
    `GNNInferenceEngine(mesh=...)` expect. On CPU, fake an 8-way mesh with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (set before jax
    initializes)."""
    devs = jax.devices()
    n = len(devs) if num_devices is None else num_devices
    if n < 1 or n > len(devs):
        raise ValueError(f"num_devices={num_devices} but {len(devs)} present")
    return Mesh(np.array(devs[:n]), ("data",))


def mesh_world(mesh: Mesh) -> int:
    """Batches per super-step: the product of the mesh's data-axis sizes."""
    dp = data_axes(mesh)
    if not dp:
        raise ValueError(
            f"mesh {mesh.axis_names} has no data axis ('data'/'pod') — "
            "data-parallel Plan execution needs one")
    w = 1
    for a in dp:
        w *= mesh.shape[a]
    return w


# --------------------------------------------------------------- super-steps
def superstep_indices(order: Sequence[int], world: int
                      ) -> List[Tuple[np.ndarray, np.ndarray]]:
    """Group a schedule into device-count-sized super-steps.

    Returns a list of ``(idx, weight)`` pairs, each of length `world`:
    `idx` are batch indices into the cache, `weight` is 1.0 for real
    entries and 0.0 for the ragged-tail pads (which repeat the last real
    batch — same shape bucket, zero contribution to the psum mean)."""
    order = np.asarray(order, dtype=np.int64)
    if world < 1:
        raise ValueError(f"world must be >= 1, got {world}")
    steps = []
    for s in range(0, len(order), world):
        chunk = order[s:s + world]
        pad = world - len(chunk)
        idx = np.concatenate([chunk, np.full(pad, chunk[-1], np.int64)])
        w = np.concatenate([np.ones(len(chunk), np.float32),
                            np.zeros(pad, np.float32)])
        steps.append((idx, w))
    return steps


def stack_batches(host, idx: np.ndarray) -> Dict[str, np.ndarray]:
    """Stack batches `idx` of an indexable host container into one
    super-step: every field gains a leading axis of length len(idx).

    Fast path: a ``BatchCache`` (or a ``Plan``'s cache) answers with one
    fancy-index per contiguous field block. All selected batches must share
    one shape bucket — guaranteed within a Plan, asserted otherwise.

    A host exposing ``stack(idx)`` (the out-of-core ``LazyBatchCache``,
    DESIGN.md §13) wins over the fields fast path: its members must come
    through the checksum-verified, LRU-budgeted per-batch read — fancy-
    indexing its memmaps would silently skip both."""
    stack = getattr(host, "stack", None)
    if stack is not None:                        # verified lazy path (§13)
        return stack(np.asarray(idx))
    fields = getattr(host, "fields", None)
    if fields is not None:                       # BatchCache fast path
        return {k: v[idx] for k, v in fields.items()}
    dicts = [host[int(i)] for i in idx]
    for d in dicts[1:]:
        assert all(np.shape(d[k]) == np.shape(dicts[0][k]) for k in d), \
            "super-step members must share one padded shape bucket"
    return {k: np.stack([d[k] for d in dicts]) for k in dicts[0]}


# ------------------------------------------------------------------- specs
def replicated_shardings(mesh: Mesh, tree):
    """Replicate every leaf of `tree` on `mesh` — the executor's param/opt
    policy. GNN parameter trees are small (DESIGN.md §9), and replication
    is always correct (`repro.dist.sharding`'s fallback rule); routed
    through `fit_spec` so the behaviour matches the rest of the dist
    layer (an empty axes tuple fits every shape)."""
    return tree_shardings(
        mesh, tree, lambda m, path, leaf: fit_spec(m, leaf.shape, ()))


def superstep_sharding(mesh: Mesh) -> NamedSharding:
    """Sharding for a stacked super-step field of any rank: leading
    (super-step) dim over the mesh's data axes, everything else
    replicated."""
    dp = data_axes(mesh)
    return NamedSharding(mesh, P(dp[0] if len(dp) == 1 else dp))


def replicate(tree, mesh: Mesh):
    """device_put `tree` fully replicated across `mesh`."""
    return jax.device_put(tree, replicated_shardings(mesh, tree))


# --------------------------------------------------------------- the executor
@dataclasses.dataclass(frozen=True)
class SuperstepFns:
    """One decision's jit'd super-step executables (DESIGN.md §9/§14)."""
    train: "object"
    eval: "object"
    forward: "object"


class ShardedPlanExecutor:
    """Execute a Plan's schedule data-parallel over `mesh` (DESIGN.md §9).

    Owns the jit'd super-step executables — train (forward/backward +
    psum-mean gradients + optimizer update), eval (per-device masked
    loss/accuracy sums) and forward (per-device output logits, consumed by
    ``GNNInferenceEngine``) — one set per (backend, block_f) decision,
    built lazily and traced ONCE each since all super-steps share one
    stacked shape. Every backend (segment, bcsr, dense) runs inside the
    ``shard_map`` body: the bcsr SpMM is ordinary compiled XLA off-TPU and
    the fused Pallas kernel on TPU (DESIGN.md §14), so there is no
    per-device fallback loop and ``sharded`` is always True.

    `opt` (a ``repro.optim`` Optimizer) is only needed for training.
    `backend` accepts a name, ``"auto"`` or a
    :class:`~repro.models.gnn.policy.BackendPolicy`; with an auto policy,
    callers pick the per-super-step executable via :meth:`steps_for` +
    ``policy.superstep_decision`` (``evaluate`` does this itself).
    """

    def __init__(self, mesh: Mesh, model_cfg: GNNConfig, opt=None,
                 backend=None):
        model_cfg, self.policy = gnn_policy.resolve(model_cfg, backend)
        self.mesh = mesh
        self.cfg = model_cfg
        self.opt = opt
        self.world = mesh_world(mesh)
        self.backend = model_cfg.backend
        self.sharded = True        # every backend runs under shard_map (§14)
        self.batch_sharding = superstep_sharding(mesh)
        self._steps: Dict[Tuple[str, int], "SuperstepFns"] = {}
        base = self.steps_for(self.backend,
                              int(getattr(model_cfg, "bcsr_block_f", 0)))
        # the fixed-decision executables, kept as plain attributes for the
        # single-executable callers (and back-compat)
        self.train_superstep = base.train
        self.eval_superstep = base.eval
        self.forward_superstep = base.forward

    # ------------------------------------------------------------ staging
    def replicate(self, tree):
        return replicate(tree, self.mesh)

    def supersteps(self, order) -> List[Tuple[np.ndarray, np.ndarray]]:
        return superstep_indices(order, self.world)

    def stage(self, host, idx: np.ndarray, weights: np.ndarray):
        """Stack + device_put one super-step, sharded over the data axes."""
        stacked = stack_batches(host, idx)
        stacked = jax.device_put(stacked, self.batch_sharding)
        weights = jax.device_put(np.asarray(weights, np.float32),
                                 self.batch_sharding)
        return stacked, weights

    def decisions(self, host) -> List[Tuple[str, int]]:
        """Per-batch (backend, block_f) under this executor's policy —
        the plan's stored autotuner decisions when ``host`` carries them
        (DESIGN.md §14)."""
        return gnn_policy.batch_decisions(host, self.policy, self.cfg)

    # ------------------------------------------------------------- builds
    def steps_for(self, backend: str, block_f: int = 0) -> "SuperstepFns":
        """The (train, eval, forward) super-step executables for one
        (backend, block_f) decision — built lazily, cached for the
        executor's lifetime (one trace per decision in play)."""
        key = (backend, int(block_f))
        if key not in self._steps:
            self._steps[key] = self._build(backend, int(block_f))
        return self._steps[key]

    def _build(self, backend: str, block_f: int) -> "SuperstepFns":
        cfg = gnn_policy.batch_config(self.cfg, backend, block_f)
        P_rep, P_dp = P(), self.batch_sharding.spec

        def loss_fn(params, batch, rng):
            h = gnn_apply(cfg, params, batch, rng=rng, train=rng is not None)
            logits = output_logits(h, batch)
            return masked_xent(logits, batch["labels"], batch["output_mask"])

        def eval_fn(params, batch):
            h = gnn_apply(cfg, params, batch, train=False)
            logits = output_logits(h, batch)
            msk = batch["output_mask"]
            loss = masked_xent(logits, batch["labels"], msk)
            acc = ((logits.argmax(-1) == batch["labels"]).astype(jnp.float32)
                   * msk).sum()
            return loss * msk.sum(), acc, msk.sum()

        def _one(tree):               # strip the per-device leading dim of 1
            return jax.tree_util.tree_map(lambda x: x[0], tree)

        # the reduction axes must be exactly the axes the super-step is
        # sharded over — a ('pod', 'data') mesh psums over both, or the
        # replicas silently diverge
        dp = data_axes(self.mesh)

        # --- sharded bodies: each device holds ONE batch of the super-step
        def train_body(params, batch, w, rng):
            loss, grads = jax.value_and_grad(loss_fn)(
                params, _one(batch), rng[0])
            w = w[0]
            denom = jax.lax.psum(w, dp)
            grads = jax.tree_util.tree_map(
                lambda g: jax.lax.psum(g * w, dp) / denom, grads)
            return grads, loss[None]

        def eval_body(params, batch, w):
            l, a, n = eval_fn(params, _one(batch))
            w = w[0]
            return (l * w)[None], (a * w)[None], (n * w)[None]

        def fwd_body(params, batch):
            b = _one(batch)
            h = gnn_apply(cfg, params, b, train=False)
            return output_logits(h, b)[None]

        mesh = self.mesh

        @partial(jax.jit, donate_argnums=(0, 1))
        def train_superstep(params, opt_state, batch, weights, lr, rngs):
            grads, losses = shard_map(
                train_body, mesh=mesh,
                in_specs=(P_rep, P_dp, P_dp, P_dp),
                out_specs=(P_rep, P_dp), check_rep=False)(
                params, batch, weights, rngs)
            updates, opt_state = self.opt.update(
                grads, opt_state, params, lr)
            return apply_updates(params, updates), opt_state, losses

        @jax.jit
        def eval_superstep(params, batch, weights):
            return shard_map(
                eval_body, mesh=mesh,
                in_specs=(P_rep, P_dp, P_dp),
                out_specs=(P_dp, P_dp, P_dp), check_rep=False)(
                params, batch, weights)

        @jax.jit
        def forward_superstep(params, batch):
            return shard_map(
                fwd_body, mesh=mesh,
                in_specs=(P_rep, P_dp),
                out_specs=P_dp, check_rep=False)(params, batch)

        return SuperstepFns(train_superstep, eval_superstep,
                            forward_superstep)

    # ---------------------------------------------------------- evaluation
    def evaluate(self, params, host, decisions=None) -> Dict[str, float]:
        """Mini-batched evaluation over every batch of `host`, mesh-
        parallel; numerically the per-batch sums of the single-device
        ``GNNTrainer.evaluate``. Under an auto policy each super-step runs
        the executable its group's stored decision selects
        (``policy.superstep_decision``); pass ``decisions`` when `host` is
        a bare cache whose owning Plan carried the stored decisions."""
        if decisions is None:
            decisions = self.decisions(host)
        tot_l = tot_a = tot_n = 0.0
        for idx, w in self.supersteps(np.arange(len(host))):
            fns = self.steps_for(
                *gnn_policy.superstep_decision(decisions, idx))
            batch, wd = self.stage(host, idx, w)
            l, a, n = fns.eval(params, batch, wd)
            tot_l += float(np.sum(l)); tot_a += float(np.sum(a))
            tot_n += float(np.sum(n))
        n = max(tot_n, 1.0)
        return {"loss": tot_l / n, "acc": tot_a / n}
