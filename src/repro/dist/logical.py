"""Logical-axis sharding rules (DESIGN.md §5).

Model code names tensor dimensions with LOGICAL axes ("batch", "seq",
"heads", ...) via `repro.dist.annotate`; a `logical_rules` context binds
those names to PHYSICAL mesh axes ("data", "model", "pod"). Two properties
make this usable inside one shared model implementation:

* PRIORITY ARBITRATION — several logical axes of one tensor may map to the
  same mesh axis (e.g. sequence parallelism maps "seq"→"model" while tensor
  parallelism maps "heads"→"model"). A mesh axis can shard only one
  dimension, so `spec_for` awards it to the highest-priority claimant:
  TP-primary contraction axes (heads/mlp/vocab/expert/...) beat "batch",
  which beats the yielding axes "seq"/"cache_seq". This is what makes the
  SP→TP transition implicit: annotating q as ("batch", "seq", "heads", None)
  *is* the gather of the sequence axis.
* NO-OP OUTSIDE A CONTEXT — without active rules, `annotate` returns its
  input unchanged, so single-device tests and CPU smoke runs never pay for
  (or depend on) a mesh.

The context is a plain module-global stack: rules are installed around
trace time (inside `jax.jit` lowering), which is single-threaded per trace.
"""
from __future__ import annotations

import contextlib
from typing import Dict, Optional, Sequence, Tuple, Union

from jax.sharding import PartitionSpec as P

MeshAxes = Union[str, Tuple[str, ...], None]

# Lower value = stronger claim on a contested mesh axis (DESIGN.md §5).
# TP-primary axes are the ones a tensor-parallel matmul contracts or tiles
# over — losing one would silently turn TP off, while "seq"/"cache_seq"
# merely fall back to a gathered (replicated) sequence dimension.
_PRIORITY: Dict[str, int] = {
    "heads": 0, "kv_heads": 0, "mlp": 0, "vocab": 0, "expert": 0, "embed": 0,
    "batch": 1,
    "seq": 3, "cache_seq": 3,
}
_DEFAULT_PRIORITY = 2

# (mapping, mesh) frames; innermost last.
_STACK: list = []


@contextlib.contextmanager
def logical_rules(mapping: Dict[str, MeshAxes], mesh=None):
    """Bind logical-axis names to mesh axes for the dynamic extent.

    `mapping` values are a mesh-axis name, a tuple of names (the dimension is
    sharded over their product, e.g. batch over ("pod", "data")), or None.
    `mesh` optionally pins the mesh `annotate` fits shapes against; when
    omitted, the ambient `with mesh:` context is used.
    """
    _STACK.append((dict(mapping), mesh))
    try:
        yield
    finally:
        _STACK.pop()


def current_rules() -> Optional[Dict[str, MeshAxes]]:
    """The innermost active mapping, or None outside any context."""
    return _STACK[-1][0] if _STACK else None


def _ambient_mesh():
    try:
        from jax._src import mesh as mesh_lib
        m = mesh_lib.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    return None


def current_mesh():
    """The mesh in effect: the one given to `logical_rules`, else the ambient
    `with mesh:` context manager's mesh, else None."""
    if _STACK and _STACK[-1][1] is not None:
        return _STACK[-1][1]
    return _ambient_mesh()


def _as_tuple(v: MeshAxes) -> Tuple[str, ...]:
    if v is None:
        return ()
    if isinstance(v, str):
        return (v,)
    return tuple(v)


def spec_for(axes: Sequence[Optional[str]],
             rules: Optional[Dict[str, MeshAxes]] = None) -> P:
    """Resolve logical axis names to a PartitionSpec under the active rules,
    arbitrating contested mesh axes by priority (ties: leftmost dimension).

    >>> with logical_rules({"seq": "model", "heads": "model", "batch": "data"}):
    ...     spec_for(("batch", "seq", "heads", None))
    PartitionSpec('data', None, 'model', None)
    """
    if rules is None:
        rules = current_rules()
    if rules is None:
        return P(*([None] * len(axes)))
    entries: list = [None] * len(axes)
    order = sorted(
        (i for i, name in enumerate(axes) if name is not None),
        key=lambda i: (_PRIORITY.get(axes[i], _DEFAULT_PRIORITY), i))
    claimed: set = set()
    for i in order:
        want = tuple(a for a in _as_tuple(rules.get(axes[i]))
                     if a not in claimed)
        if not want:
            continue
        claimed.update(want)
        entries[i] = want[0] if len(want) == 1 else want
    return P(*entries)
