"""Path- and shape-driven sharding specs for whole pytrees (DESIGN.md §5).

Policy in one line: weights are FSDP-sharded over "data" and tensor-parallel
over "model"; activations/batches are data-parallel over ("pod",) "data" with
the sequence dimension on "model" (sequence parallelism) until a TP-primary
axis claims it; caches shard batch and kv-heads.

Everything funnels through `fit_spec`, which enforces the two global
invariants:
* LEFT-PADDING — spec entries align to the TRAILING dims, so the same rule
  covers a parameter and its scan-stacked (repeat, ...) variant.
* DIVISIBILITY FALLBACK — a mesh axis whose size does not divide the
  dimension is dropped (replicated) instead of erroring, so one policy
  serves every (arch × shape × mesh) cell of the dry-run.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Sequence, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist.logical import _as_tuple

# ------------------------------------------------------------------ fit_spec
def _axis_size(mesh, entry) -> int:
    """Product of the named axes' sizes; 0 if any axis is not in the mesh
    (the caller then drops the entry — part of the fallback contract)."""
    n = 1
    for a in _as_tuple(entry):
        if a not in mesh.shape:
            return 0
        n *= mesh.shape[a]
    return n


def fit_spec(mesh, shape: Sequence[int], axes: Sequence) -> P:
    """Fit mesh-axis names to the trailing dims of `shape`.

    `axes` may be shorter than `shape` (stacked/leading dims get None) and
    entries may be a name, a tuple of names, or None. Names that do not
    divide their dimension — or do not exist in this mesh — are dropped
    (replicated)."""
    shape = tuple(shape)
    axes = tuple(axes)
    if len(axes) > len(shape):
        axes = axes[len(axes) - len(shape):]
    pad = len(shape) - len(axes)
    entries = [None] * pad
    for dim, entry in zip(shape[pad:], axes):
        if entry is not None and _axis_size(mesh, entry) > 0 \
                and dim % _axis_size(mesh, entry) == 0:
            entries.append(entry)
        else:
            entries.append(None)
    return P(*entries)


def data_axes(mesh) -> Tuple[str, ...]:
    """The data-parallel (DP/FSDP) axes of a mesh, outermost first."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _dp(mesh):
    dp = data_axes(mesh)
    if not dp:
        return None
    return dp[0] if len(dp) == 1 else dp


def _tp(mesh):
    return "model" if "model" in mesh.axis_names else None


# ---------------------------------------------------------------- param_spec
# Classification by parameter NAME (the last pytree key). Canonical specs are
# for the unstacked rank; fit_spec left-pads the scan "repeat" axis.
#   column-parallel: contraction dim FSDP-sharded on "data", output on "model"
#   row-parallel:    "model"-contracted input, output gathered onto "data"
_COL = {
    "wq", "wk", "wv", "w_in", "w_gate", "wkv_a", "wq_a", "wq_b", "wk_b",
    "wv_b", "router", "sh_in", "sh_gate", "w_main", "wa", "wi", "lora_a",
    "wr", "wg", "ck", "cr", "w",
}
_ROW = {"wo", "w_out", "sh_out", "cv", "wb_w", "proj"}
# expert-parallel: leading expert dim on "model" (EP), d_model FSDP on "data"
_EXPERT = {"we_in", "we_gate", "we_out"}
# embedding/unembedding tables: (vocab, embed) → vocab TP, embed FSDP
_TABLE = {"table"}


def _key_name(entry) -> str:
    return str(getattr(entry, "key", getattr(entry, "name",
               getattr(entry, "idx", entry))))


def _classify(name: str):
    if name in _COL:
        return ("data", "model")
    if name in _ROW:
        return ("model", "data")
    if name in _EXPERT:
        return ("model", "data", None)
    if name in _TABLE:
        return ("model", "data")
    return None


def param_spec(mesh, path, leaf) -> P:
    """Sharding for one parameter leaf, keyed on its pytree path.

    Unrecognized names (norm scales, biases, gates, decay vectors, ...) are
    replicated — they are small, and replication is always correct."""
    name = _key_name(path[-1]) if path else ""
    axes = _classify(name)
    if axes is None:
        return P(*([None] * getattr(leaf, "ndim", len(leaf.shape))))
    return fit_spec(mesh, leaf.shape, axes)


# ------------------------------------------------------------------ opt_spec
_FACTORED_SLOTS = {"vr", "vc"}


def opt_spec(mesh, path, leaf, extra: Dict[str, Any]) -> P:
    """Optimizer-state sharding: mirror the owning parameter (DESIGN.md §4).

    Adam-family states nest the param tree under "m"/"v"/"mu"/"acc", so the
    LAST key is still the parameter name and `param_spec` applies verbatim.
    Adafactor's factored slots ("vr"/"vc") are rank-reduced vectors hanging
    UNDER the parameter key: replicate them (they are the whole point of
    factoring — tiny), and shard an unfactored "v" slot like its parent."""
    names = [_key_name(k) for k in path]
    if names and names[-1] in _FACTORED_SLOTS:
        return P(*([None] * leaf.ndim))
    if len(names) >= 2 and names[-1] == "v" \
            and _classify(names[-2]) is not None:
        return fit_spec(mesh, leaf.shape, _classify(names[-2]))
    return param_spec(mesh, path, leaf)


# ---------------------------------------------------------------- cache_spec
# Canonical (unstacked) trailing specs per cache leaf name. "BATCH" stands in
# for the mesh's data axes, resolved at call time.
_BATCH = object()
_CACHE = {
    "k":       (_BATCH, None, "model", None),   # (B, S, KV, hd)
    "v":       (_BATCH, None, "model", None),
    "c_kv":    (_BATCH, None, None),            # MLA compressed (B, S, r)
    "k_rope":  (_BATCH, None, None),
    "wkv":     (_BATCH, "model", None, None),   # RWKV state (B, H, dk, dv)
    "shift_t": (_BATCH, None),
    "shift_c": (_BATCH, None),
    "h":       (_BATCH, None),                  # RG-LRU state (B, W)
    "conv":    (_BATCH, None, None),
}


def cache_spec(mesh, path, leaf) -> P:
    """Decode-cache sharding: batch over the data axes, kv-heads / rwkv heads
    over "model"; recurrent per-channel states replicate their channel dim."""
    name = _key_name(path[-1]) if path else ""
    axes = _CACHE.get(name)
    if axes is None:
        return P(*([None] * leaf.ndim))
    dp = _dp(mesh)
    return fit_spec(mesh, leaf.shape,
                    tuple(dp if a is _BATCH else a for a in axes))


# ---------------------------------------------------------------- batch_spec
def batch_spec(mesh, name: str, shape: Sequence[int]) -> P:
    """Model-input sharding: dim 0 (batch) over the data axes, dim 1 (seq)
    over "model" (sequence parallelism). Divisibility fallback makes this
    safe for decode steps (S=1) and ragged prefix lengths."""
    shape = tuple(shape)
    if not shape:
        return P()
    axes: list = [_dp(mesh)]
    if len(shape) > 1:
        axes.append(_tp(mesh))
    axes += [None] * (len(shape) - len(axes))
    return fit_spec(mesh, shape, axes)


# ------------------------------------------------------------------ pytrees
def tree_shardings(mesh, tree: Any,
                   spec_fn: Callable[[Any, Any, Any], P]) -> Any:
    """Map `spec_fn(mesh, path, leaf)` over a pytree → NamedSharding tree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, spec_fn(mesh, path, leaf)),
        tree)


def with_shardings(tree: Any, shardings: Any) -> Any:
    """Attach a sharding tree to an abstract (ShapeDtypeStruct) tree."""
    return jax.tree_util.tree_map(
        lambda leaf, sh: jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                              sharding=sh),
        tree, shardings)


# ------------------------------------------------------------- logical rules
def logical_rules_for(cfg, mesh) -> Dict[str, Any]:
    """The logical→mesh binding for the LM stack on this mesh (DESIGN.md §5).

    "batch" spans every data axis; all TP-primary names plus the yielding
    "seq"/"cache_seq" share "model" — `spec_for` arbitration decides, per
    tensor, which one actually holds it. "embed" is deliberately unmapped:
    the residual stream keeps its channel dim gathered, and TP happens
    through the weight shardings (param_spec), not activation constraints."""
    rules: Dict[str, Any] = {"batch": _dp(mesh)}
    tp = _tp(mesh)
    if tp is not None:
        for name in ("seq", "cache_seq", "heads", "kv_heads", "mlp",
                     "vocab", "expert"):
            rules[name] = tp
    return rules
