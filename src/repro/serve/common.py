"""Shared serving-tier machinery (DESIGN.md §11).

The LM ``ServeEngine`` (slot-based continuous batching) and the GNN
``AsyncGNNEngine`` (micro-batching windows over ``GNNInferenceEngine``)
share one lifecycle vocabulary:

* a **clock** — all timing goes through an injectable ``now()`` source so
  every window/deadline behavior is testable with a fake clock instead of
  wall-clock sleeps (the same determinism discipline as the
  ``PrefetchLoader`` Event/sentinel shutdown);
* a **future** — completion is signaled through a ``threading.Event``-backed
  :class:`ServeFuture`, never by polling;
* a **slot pool** — fixed-capacity admission with busy-rejection and
  immediate slot reuse on completion (:class:`SlotPool`), the unit the LM
  engine's continuous batching and its tests are written against.
"""
from __future__ import annotations

import threading
import time
from typing import Generic, List, Optional, TypeVar

T = TypeVar("T")


class SystemClock:
    """Default clock: monotonic seconds. The serving tier only ever
    compares differences of ``now()``, so any monotonic origin works —
    which is exactly what lets tests substitute a manually-advanced fake.
    ``sleep`` rides along for the same reason: retry backoff
    (DESIGN.md §12) waits through the clock, so a fake clock's ``sleep``
    can simply advance time and tests stay sleep-free."""

    def now(self) -> float:
        return time.monotonic()

    def sleep(self, seconds: float) -> None:
        time.sleep(seconds)


class ServeError(RuntimeError):
    """Base class for serving-tier request failures."""


class ServeRejected(ServeError):
    """Admission control refused the request on arrival (queue full,
    deadline infeasible, or ids not routable under the current plan)."""


class ServeExpired(ServeError):
    """The request was admitted but its deadline passed while queued."""


class ServeUnavailable(ServeRejected):
    """Fast-reject because the tenant's circuit breaker is OPEN
    (DESIGN.md §12): the tenant has failed ``threshold`` consecutive
    windows and is cooling down. ``retry_after_ms`` tells the client when
    the breaker will admit a half-open probe — the graceful-degradation
    contract: shed load in O(1) instead of queueing work that will fail."""

    def __init__(self, msg: str, retry_after_ms: float = 0.0):
        super().__init__(msg)
        self.retry_after_ms = float(retry_after_ms)


class ServeClosed(ServeError):
    """The engine was closed; no further requests are accepted."""


class ServeFuture:
    """Event-backed completion handle for one submitted request.

    ``result(timeout)`` blocks on the event (no polling) and either returns
    the value or raises the recorded exception — :class:`ServeRejected` /
    :class:`ServeExpired` / :class:`ServeClosed` for lifecycle failures, or
    whatever a faulty tenant forward raised (fault isolation: the error of
    ONE window must reach exactly that window's futures)."""

    def __init__(self, tenant: str = "", t_submit: float = 0.0):
        self.tenant = tenant
        self.t_submit = t_submit
        self.t_done: Optional[float] = None
        self._ev = threading.Event()
        self._value = None
        self._exc: Optional[BaseException] = None

    # ------------------------------------------------------------ producer
    def finish(self, value=None, exc: Optional[BaseException] = None,
               t_done: Optional[float] = None) -> bool:
        """Complete the future (one-shot). Returns True when THIS call
        completed it — the watchdog path uses this to count how many
        in-flight futures it actually failed (DESIGN.md §12)."""
        if self._ev.is_set():            # completion is one-shot
            return False
        self._value, self._exc, self.t_done = value, exc, t_done
        self._ev.set()
        return True

    # ------------------------------------------------------------ consumer
    def done(self) -> bool:
        return self._ev.is_set()

    def wait(self, timeout: Optional[float] = None) -> bool:
        return self._ev.wait(timeout)

    def result(self, timeout: Optional[float] = None):
        if not self._ev.wait(timeout):
            raise TimeoutError("request not complete")
        if self._exc is not None:
            raise self._exc
        return self._value

    def exception(self, timeout: Optional[float] = None):
        if not self._ev.wait(timeout):
            raise TimeoutError("request not complete")
        return self._exc

    @property
    def rejected(self) -> bool:
        return isinstance(self._exc, ServeRejected)

    @property
    def latency_s(self) -> Optional[float]:
        if self.t_done is None:
            return None
        return self.t_done - self.t_submit


class SlotPool(Generic[T]):
    """Fixed pool of serving slots: acquire → occupy → release.

    The admission contract the LM engine's tests pin: ``acquire`` returns
    the FIRST free slot index (so reuse after a mid-stream completion lands
    in the vacated slot) or None while all slots are busy — no silent
    queueing, no eviction. ``release_all`` is the shutdown/exhaustion path:
    it empties every slot and returns the evicted occupants so the caller
    can account for them (slot state must never leak past the stream that
    created it)."""

    def __init__(self, num_slots: int):
        self._slots: List[Optional[T]] = [None] * num_slots

    def __len__(self) -> int:
        return len(self._slots)

    def __getitem__(self, i: int) -> Optional[T]:
        return self._slots[i]

    def __iter__(self):
        return iter(self._slots)

    @property
    def slots(self) -> List[Optional[T]]:
        return self._slots

    @property
    def free_count(self) -> int:
        return sum(1 for s in self._slots if s is None)

    def acquire(self, item: T) -> Optional[int]:
        for i, s in enumerate(self._slots):
            if s is None:
                self._slots[i] = item
                return i
        return None

    def release(self, i: int) -> Optional[T]:
        item, self._slots[i] = self._slots[i], None
        return item

    def release_all(self) -> List[T]:
        evicted = [s for s in self._slots if s is not None]
        self._slots = [None] * len(self._slots)
        return evicted


class CircuitBreaker:
    """Per-tenant circuit breaker over micro-batching windows
    (DESIGN.md §12).

    State machine (all transitions driven by the injectable clock, so the
    full lifecycle is testable against a FakeClock with zero sleeps)::

        CLOSED --[threshold consecutive window failures]--> OPEN
        OPEN   --[cooldown_s elapsed, next allow()]-------> HALF_OPEN
        HALF_OPEN --[window succeeds]--> CLOSED
        HALF_OPEN --[window fails]-----> OPEN   (cooldown restarts)

    While OPEN, ``allow`` returns ``(False, retry_after_s)`` and the tier
    fast-rejects with :class:`ServeUnavailable` — a wedged tenant sheds its
    load in O(1) instead of queueing requests its forwards will fail, and
    other tenants behind the same queue are untouched. The half-open probe
    is how a recovered tenant re-earns traffic: ONE window is admitted and
    its outcome decides. Any window success resets the consecutive-failure
    count (the breaker counts *consecutive* failures, not a failure rate).
    """

    CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"

    def __init__(self, threshold: int, cooldown_s: float):
        if threshold < 1:
            raise ValueError(f"breaker threshold must be >= 1: {threshold}")
        self.threshold = int(threshold)
        self.cooldown_s = float(cooldown_s)
        self.state = self.CLOSED
        self.consecutive_failures = 0
        self.opened_at: Optional[float] = None
        self.opens = 0
        self.closes = 0

    def allow(self, now: float) -> "tuple[bool, float]":
        """(admit?, retry_after_s). Transitions OPEN → HALF_OPEN when the
        cooldown has elapsed (the caller's admission IS the probe)."""
        if self.state == self.OPEN:
            waited = now - self.opened_at
            if waited < self.cooldown_s:
                return False, self.cooldown_s - waited
            self.state = self.HALF_OPEN
        return True, 0.0

    def record_success(self, now: float) -> None:
        self.consecutive_failures = 0
        if self.state != self.CLOSED:
            self.state = self.CLOSED
            self.closes += 1

    def record_failure(self, now: float) -> bool:
        """Record one window failure; True when this failure OPENED the
        breaker (a half-open probe failure re-opens immediately)."""
        self.consecutive_failures += 1
        if self.state == self.HALF_OPEN or (
                self.state == self.CLOSED
                and self.consecutive_failures >= self.threshold):
            self.state = self.OPEN
            self.opened_at = now
            self.opens += 1
            return True
        return False

    def snapshot(self) -> dict:
        return {"state": self.state,
                "consecutive_failures": self.consecutive_failures,
                "opens": self.opens, "closes": self.closes}
