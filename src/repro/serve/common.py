"""Shared serving-tier machinery (DESIGN.md §11).

The LM ``ServeEngine`` (slot-based continuous batching) and the GNN
``AsyncGNNEngine`` (micro-batching windows over ``GNNInferenceEngine``)
share one lifecycle vocabulary:

* a **clock** — all timing goes through an injectable ``now()`` source so
  every window/deadline behavior is testable with a fake clock instead of
  wall-clock sleeps (the same determinism discipline as the
  ``PrefetchLoader`` Event/sentinel shutdown);
* a **future** — completion is signaled through a ``threading.Event``-backed
  :class:`ServeFuture`, never by polling;
* a **slot pool** — fixed-capacity admission with busy-rejection and
  immediate slot reuse on completion (:class:`SlotPool`), the unit the LM
  engine's continuous batching and its tests are written against.
"""
from __future__ import annotations

import threading
import time
from typing import Generic, List, Optional, TypeVar

T = TypeVar("T")


class SystemClock:
    """Default clock: monotonic seconds. The serving tier only ever
    compares differences of ``now()``, so any monotonic origin works —
    which is exactly what lets tests substitute a manually-advanced fake."""

    def now(self) -> float:
        return time.monotonic()


class ServeError(RuntimeError):
    """Base class for serving-tier request failures."""


class ServeRejected(ServeError):
    """Admission control refused the request on arrival (queue full,
    deadline infeasible, or ids not routable under the current plan)."""


class ServeExpired(ServeError):
    """The request was admitted but its deadline passed while queued."""


class ServeClosed(ServeError):
    """The engine was closed; no further requests are accepted."""


class ServeFuture:
    """Event-backed completion handle for one submitted request.

    ``result(timeout)`` blocks on the event (no polling) and either returns
    the value or raises the recorded exception — :class:`ServeRejected` /
    :class:`ServeExpired` / :class:`ServeClosed` for lifecycle failures, or
    whatever a faulty tenant forward raised (fault isolation: the error of
    ONE window must reach exactly that window's futures)."""

    def __init__(self, tenant: str = "", t_submit: float = 0.0):
        self.tenant = tenant
        self.t_submit = t_submit
        self.t_done: Optional[float] = None
        self._ev = threading.Event()
        self._value = None
        self._exc: Optional[BaseException] = None

    # ------------------------------------------------------------ producer
    def finish(self, value=None, exc: Optional[BaseException] = None,
               t_done: Optional[float] = None) -> None:
        if self._ev.is_set():            # completion is one-shot
            return
        self._value, self._exc, self.t_done = value, exc, t_done
        self._ev.set()

    # ------------------------------------------------------------ consumer
    def done(self) -> bool:
        return self._ev.is_set()

    def wait(self, timeout: Optional[float] = None) -> bool:
        return self._ev.wait(timeout)

    def result(self, timeout: Optional[float] = None):
        if not self._ev.wait(timeout):
            raise TimeoutError("request not complete")
        if self._exc is not None:
            raise self._exc
        return self._value

    def exception(self, timeout: Optional[float] = None):
        if not self._ev.wait(timeout):
            raise TimeoutError("request not complete")
        return self._exc

    @property
    def rejected(self) -> bool:
        return isinstance(self._exc, ServeRejected)

    @property
    def latency_s(self) -> Optional[float]:
        if self.t_done is None:
            return None
        return self.t_done - self.t_submit


class SlotPool(Generic[T]):
    """Fixed pool of serving slots: acquire → occupy → release.

    The admission contract the LM engine's tests pin: ``acquire`` returns
    the FIRST free slot index (so reuse after a mid-stream completion lands
    in the vacated slot) or None while all slots are busy — no silent
    queueing, no eviction. ``release_all`` is the shutdown/exhaustion path:
    it empties every slot and returns the evicted occupants so the caller
    can account for them (slot state must never leak past the stream that
    created it)."""

    def __init__(self, num_slots: int):
        self._slots: List[Optional[T]] = [None] * num_slots

    def __len__(self) -> int:
        return len(self._slots)

    def __getitem__(self, i: int) -> Optional[T]:
        return self._slots[i]

    def __iter__(self):
        return iter(self._slots)

    @property
    def slots(self) -> List[Optional[T]]:
        return self._slots

    @property
    def free_count(self) -> int:
        return sum(1 for s in self._slots if s is None)

    def acquire(self, item: T) -> Optional[int]:
        for i, s in enumerate(self._slots):
            if s is None:
                self._slots[i] = item
                return i
        return None

    def release(self, i: int) -> Optional[T]:
        item, self._slots[i] = self._slots[i], None
        return item

    def release_all(self) -> List[T]:
        evicted = [s for s in self._slots if s is not None]
        self._slots = [None] * len(self._slots)
        return evicted
