"""Request-level GNN inference over a frozen Plan (DESIGN.md §8).

The paper's 130x inference speedup comes from precomputed batches; this
engine turns that into a *serving* story: queries are arbitrary sets of
output-node ids (think: "score these users"), answered from a
``Plan.load``-ed artifact with NO preprocessing on the request path.

Dispatch, per query:

1. **Route** — the plan's routing index maps every queried node id to its
   precomputed ``(batch, row)`` in O(log M) per id (binary search over the
   sorted output-node table).
2. **Coalesce** — requests in flight that hit the same precomputed batch
   share ONE forward pass (``run``), the GNN analogue of ``ServeEngine``'s
   slot-based continuous batching: the unit of execution is the batch, the
   unit of admission is the request.
3. **Execute** — one jit'd forward per (backend, block_f) decision. The
   backend override is a :class:`~repro.models.gnn.policy.BackendPolicy`
   (or a plain name): fixed policies run every batch on one backend;
   ``BackendPolicy.auto()`` dispatches each batch on the plan's stored
   autotuner decision (DESIGN.md §14). Static shapes ⇒ one executable per
   distinct decision, never recompiled. With ``mesh=...`` the misses
   additionally coalesce ACROSS DEVICES: one batch per device per
   shard_map super-step (DESIGN.md §9), so a cold burst's latency
   amortizes over the mesh.
4. **Gather** — per-node logit rows are sliced out of the batch output and
   scattered back into each request.

Repeat traffic is served from an LRU of recent batch *outputs* — hot
batches answer from host memory without touching the accelerator.

Dynamic graphs (DESIGN.md §10): ``swap(plan, delta)`` hot-swaps the engine
onto a refreshed plan atomically between requests. Only the LRU entries of
batches the refresh rebuilt or patched are invalidated; untouched batches
keep serving from cache, and the per-``versions`` stats table (requests /
lru_hits / batch_runs / hit_rate per plan version) is the observable proof
that traffic kept flowing across the swap.

The engine is single-threaded: "concurrent" means requests admitted into
one ``run`` call, which coalesces them. The async serving tier
(``repro.serve.async_engine.AsyncGNNEngine``, DESIGN.md §11) is the
multi-threaded front: it owns one engine per tenant, accumulates a live
request stream into micro-batching windows, and serializes every ``run``
and ``swap`` behind a per-tenant lock.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.core.plan import Plan
from repro.serve.common import SystemClock
from repro.models.gnn import ops as gnn_ops
from repro.models.gnn import policy as gnn_policy
from repro.models.gnn.models import GNNConfig, gnn_apply, output_logits


@dataclasses.dataclass
class GNNRequest:
    """One inference request: logits for an arbitrary set of node ids."""
    node_ids: np.ndarray
    logits: Optional[np.ndarray] = None     # (len(node_ids), C) when done
    latency_s: Optional[float] = None
    done: bool = False
    error: Optional[str] = None             # set instead of done on bad ids


class GNNInferenceEngine:
    """Serve per-node GNN predictions from a frozen ``Plan``.

    ``query`` answers one request synchronously; ``run`` drains a list of
    requests, coalescing all requests that touch the same precomputed batch
    into one forward pass. Per-batch output logits are LRU-cached
    (``cache_batches`` entries) so repeat traffic skips the forward
    entirely. The engine never re-preprocesses: everything it needs is in
    the plan (DESIGN.md §8).
    """

    def __init__(self, plan: Plan, model_cfg: GNNConfig, params,
                 backend=None, cache_batches: int = 8,
                 mesh=None, clock=None):
        # `backend` is a name, "auto", or a BackendPolicy (DESIGN.md §14)
        model_cfg, self.policy = gnn_policy.resolve(model_cfg, backend)
        self.plan = plan
        self.cfg = model_cfg
        self.params = params
        # request-latency timing through the injectable clock (DESIGN.md
        # §11) — FakeClock tests can observe deterministic latencies
        self.clock = clock if clock is not None else SystemClock()
        self.cache_batches = max(0, cache_batches)
        # fail fast at construction, not on the first unlucky query; the
        # auto policy validates by tile presence (every decision the plan
        # stored is executable on the batches it stored it for)
        self._vb = "auto" if self.policy.is_auto else model_cfg.backend
        gnn_ops.validate_batch_for_backend(plan.cache[0], self._vb,
                                           model_cfg.kind)
        # per-batch (backend, block_f): the plan's stored autotuner
        # decisions under an auto policy, uniform under a fixed one
        self._decisions = gnn_policy.batch_decisions(plan, self.policy,
                                                     model_cfg)
        self._lru: "OrderedDict[int, np.ndarray]" = OrderedDict()
        self.stats: Dict = dict(
            requests=0, nodes=0, batch_runs=0, lru_hits=0, supersteps=0,
            evictions=0, swap_count=0, swap_rollbacks=0, versions={})
        # audit trail of swap attempts (DESIGN.md §12): one record per call,
        # including refused swaps that rolled back to the parent version
        self.swap_audit: List[Dict] = []
        self._vstats = self._version_bucket(getattr(plan, "version", 0))

        # mesh serving (DESIGN.md §9): concurrent requests coalesce ACROSS
        # devices — missing batches are grouped one-per-device and answered
        # by a single shard_map forward per super-step. Every backend runs
        # under shard_map (bcsr uses the compiled streaming SpMM off-TPU,
        # the fused Pallas kernel on TPU — DESIGN.md §14).
        self._ex = None
        if mesh is not None:
            from repro.dist.data_parallel import ShardedPlanExecutor
            self._ex = ShardedPlanExecutor(mesh, model_cfg,
                                           backend=self.policy)
            self.params = self._ex.replicate(params)

        # one jit'd forward per (backend, block_f) decision, built lazily;
        # `_forward` holds the base decision's executable as a plain
        # attribute (the patchable surface tests inject faults through)
        self._fwd: Dict = {}
        self._base_key = (model_cfg.backend,
                          int(getattr(model_cfg, "bcsr_block_f", 0)))
        self._forward = self._build_forward(*self._base_key)

    def _build_forward(self, backend: str, block_f: int):
        cfg = gnn_policy.batch_config(self.cfg, backend, block_f)

        @jax.jit
        def _forward(params, batch):
            h = gnn_apply(cfg, params, batch, train=False)
            return output_logits(h, batch)          # (max_outputs, C)

        return _forward

    def _forward_for(self, backend: str, block_f: int = 0):
        """The per-batch forward executable for one (backend, block_f)
        decision — traced once per distinct decision in play (§14). The
        base decision answers through the ``_forward`` attribute so a
        patched attribute (fault injection) is honoured."""
        key = (backend, int(block_f))
        if key == self._base_key:
            return self._forward
        if key not in self._fwd:
            self._fwd[key] = self._build_forward(backend, int(block_f))
        return self._fwd[key]

    # ----------------------------------------------------------- hot swap
    def swap(self, plan: Plan, delta=None, validate: bool = True
             ) -> Dict[str, int]:
        """Hot-swap onto a refreshed plan (DESIGN.md §10), atomically
        between requests (the engine is single-threaded, so "atomic" means
        no query ever observes a half-updated plan/LRU pair: everything is
        computed first, then assigned).

        ``delta`` is the :class:`~repro.core.update.PlanDelta` audit record
        from ``IBMBPipeline.refresh``: only its rebuilt/patched batches are
        dropped from the LRU — untouched batches keep serving from cache,
        which is the zero-downtime property the per-``versions`` stats
        prove. Without a delta the whole LRU is cleared conservatively; a
        delta that does not link the SERVING plan to the INCOMING plan
        (parent/child fingerprint mismatch) is refused with ValueError
        before any serving state changes — a mismatched (plan, audit) pair
        would silently keep stale logits cached.

        Graceful degradation (DESIGN.md §12): with ``validate=True`` the
        incoming plan's routing invariants are checked
        (:func:`repro.core.plan.check_routing`) on top of the backend and
        audit checks, so a corrupt or hand-damaged plan is refused. ANY
        failure rolls the engine back to the plan it was serving — the
        stale-but-correct parent version keeps answering bit-identically —
        and appends a rollback record to ``swap_audit`` before the error
        propagates. Returns ``{"invalidated": ..., "kept": ...}``.
        """
        prev = (self.plan, self._lru, self._vstats, self._decisions)
        try:
            # fail fast, BEFORE touching any serving state
            gnn_ops.validate_batch_for_backend(
                plan.cache[0], self._vb, self.cfg.kind)
            if delta is not None:
                if delta.parent_fingerprint != self.plan.fingerprint:
                    raise ValueError(
                        f"swap: delta parents {delta.parent_fingerprint!r} "
                        f"but the engine is serving "
                        f"{self.plan.fingerprint!r} — refresh the serving "
                        f"plan, not another chain")
                if delta.child_fingerprint != plan.fingerprint:
                    raise ValueError(
                        f"swap: delta produced {delta.child_fingerprint!r} "
                        f"but the incoming plan is {plan.fingerprint!r} — "
                        f"this audit record does not describe that plan, "
                        f"and trusting it would keep stale LRU entries "
                        f"serving")
            if validate:
                from repro.core.plan import check_routing
                check_routing(plan)
            if delta is None:
                dirty = set(self._lru)              # conservative: drop all
            else:
                dirty = set(int(i) for i in delta.dirty)
            keep = OrderedDict((bi, out) for bi, out in self._lru.items()
                               if bi not in dirty and bi < len(plan))
            invalidated = len(self._lru) - len(keep)
            # the incoming plan carries its OWN autotuner decisions (a
            # refresh may re-decide rebuilt batches, DESIGN.md §14)
            decisions = gnn_policy.batch_decisions(plan, self.policy,
                                                   self.cfg)
            # the actual swap: plan (with routing index) + LRU + per-batch
            # decisions move together
            self.plan, self._lru, self._decisions = plan, keep, decisions
            self.stats["swap_count"] += 1
            self.stats["evictions"] += invalidated
            self._vstats = self._version_bucket(getattr(plan, "version", 0))
        except Exception as e:
            # roll back (defensively — validation failures precede any
            # mutation) and audit: the tenant keeps serving the parent
            self.plan, self._lru, self._vstats, self._decisions = prev
            self.stats["swap_rollbacks"] += 1
            self.swap_audit.append(dict(
                ok=False, serving_version=getattr(self.plan, "version", 0),
                refused_version=getattr(plan, "version", None),
                reason=f"{type(e).__name__}: {e}"))
            raise
        self.swap_audit.append(dict(
            ok=True, from_version=getattr(prev[0], "version", 0),
            to_version=getattr(plan, "version", 0),
            invalidated=invalidated, kept=len(keep)))
        return {"invalidated": invalidated, "kept": len(keep)}

    def ooc_stats(self) -> Optional[Dict]:
        """Resident-budget/IO counters of an out-of-core plan's lazy cache
        (DESIGN.md §13), or ``None`` for a resident plan — the engine-level
        hook the serving tier's ``snapshot`` surfaces so operators can see
        batch faulting, eviction pressure, and retried reads per tenant."""
        snap = getattr(self.plan.cache, "snapshot", None)
        return snap() if callable(snap) else None

    # ------------------------------------------------------------ internals
    def _version_bucket(self, version: int) -> Dict[str, float]:
        """Per-plan-version counters inside ``stats['versions']`` — the
        hot-swap observability surface (DESIGN.md §10)."""
        return self.stats["versions"].setdefault(
            int(version), dict(requests=0, lru_hits=0, batch_runs=0,
                               hit_rate=0.0))

    def _bump(self, **inc) -> None:
        for k, v in inc.items():
            self.stats[k] += v
            if k in self._vstats:
                self._vstats[k] += v
        served = self._vstats["lru_hits"] + self._vstats["batch_runs"]
        if served:
            self._vstats["hit_rate"] = self._vstats["lru_hits"] / served

    def _lru_put(self, bi: int, out: np.ndarray) -> np.ndarray:
        self._bump(batch_runs=1)
        if self.cache_batches:
            self._lru[bi] = out
            while len(self._lru) > self.cache_batches:
                self._lru.popitem(last=False)
                self.stats["evictions"] += 1
        return out

    def _flush_misses(self, missing):
        """Compute the logits of `missing` (≤ world batches), yielding
        (bi, logits). A lone miss skips the super-step machinery — padding
        it to `world` identical copies would waste world−1 devices' staging
        and compute — and runs the plain per-batch forward instead (the
        replicated params commit the computation to the mesh either way)."""
        if len(missing) == 1 or self._ex is None:
            for bi in missing:
                fwd = self._forward_for(*self._decisions[bi])
                yield bi, self._lru_put(bi, np.asarray(
                    fwd(self.params, self.plan.cache[bi])))
            return
        from repro.dist.data_parallel import superstep_indices
        (idx, w), = superstep_indices(np.asarray(missing), self._ex.world)
        fns = self._ex.steps_for(
            *gnn_policy.superstep_decision(self._decisions, idx))
        batch, _w = self._ex.stage(self.plan.cache, idx, w)
        lg = np.asarray(fns.forward(self.params, batch))
        self.stats["supersteps"] += 1
        for j in range(len(idx)):
            if w[j] > 0:
                yield int(idx[j]), self._lru_put(int(idx[j]), lg[j])

    def _iter_logits(self, need):
        """Yield (bi, output-row logits) for every batch index in `need`,
        through the LRU. Misses run coalesced — one batch per device per
        shard_map super-step when a mesh is configured — but are flushed
        chunk by chunk, so peak host memory beyond the LRU stays at
        O(world) batch outputs however many batches a request set touches
        (the caller scatters each batch's rows and drops the reference)."""
        world = self._ex.world if self._ex is not None else 1
        missing: List[int] = []
        for bi in need:
            bi = int(bi)
            if bi in self._lru:
                self._lru.move_to_end(bi)
                self._bump(lru_hits=1)
                yield bi, self._lru[bi]
                continue
            missing.append(bi)
            if len(missing) == world:
                yield from self._flush_misses(missing)
                missing = []
        if missing:
            yield from self._flush_misses(missing)

    def _batch_logits(self, bi: int) -> np.ndarray:
        """Output-row logits of precomputed batch `bi`, through the LRU."""
        return dict(self._iter_logits([bi]))[int(bi)]

    # -------------------------------------------------------------- queries
    def query(self, node_ids: Sequence[int]) -> np.ndarray:
        """Logits for `node_ids` (any output nodes covered by the plan),
        in query order. Raises KeyError for ids the plan does not cover."""
        q = np.asarray(node_ids, dtype=np.int64).ravel()
        bidx, rows = self.plan.routing.lookup(q)
        self._bump(requests=1, nodes=len(q))
        out = None
        for bi, lg in self._iter_logits(np.unique(bidx)):
            if out is None:
                out = np.empty((len(q), lg.shape[1]), lg.dtype)
            sel = bidx == bi
            out[sel] = lg[rows[sel]]
        if out is None:                              # empty query
            out = np.zeros((0, self.cfg.out_dim), np.float32)
        return out

    def run(self, requests: List[GNNRequest]) -> Dict[str, float]:
        """Drain `requests`, coalescing across them: every precomputed batch
        needed by ANY request runs at most once (then serves them all).
        Records per-request latency (admission → completion). A request with
        ids the plan does not cover gets its `error` set and is skipped —
        it never denies service to the rest of the coalesced set."""
        t0 = self.clock.now()
        routed = []
        for req in requests:
            q = np.asarray(req.node_ids, dtype=np.int64).ravel()
            try:
                bidx, rows = self.plan.routing.lookup(q)
            except KeyError as e:
                req.error = str(e)
                req.done, req.logits = False, None
                continue
            req.logits = None
            routed.append((req, q, bidx, rows))
            self._bump(requests=1, nodes=len(q))
        # batch → list of (request index, positions) so completion is
        # tracked per request as its last batch lands
        needed: "OrderedDict[int, List[int]]" = OrderedDict()
        remaining = []
        for ri, (_req, _q, bidx, _rows) in enumerate(routed):
            uniq = np.unique(bidx)
            remaining.append(len(uniq))
            for bi in uniq:
                needed.setdefault(int(bi), []).append(ri)
        # all batches any request needs, fetched in one coalesced stream —
        # with a mesh this is where cross-REQUEST work packs onto devices;
        # each batch's rows scatter as its logits land, so only O(world)
        # batch outputs are ever held beyond the LRU
        for bi, lg in self._iter_logits(list(needed)):
            touching = needed[bi]
            for ri in touching:
                req, q, bidx, rows = routed[ri]
                if req.logits is None:
                    req.logits = np.empty((len(q), lg.shape[1]), lg.dtype)
                sel = bidx == bi
                req.logits[sel] = lg[rows[sel]]
                remaining[ri] -= 1
                if remaining[ri] == 0:
                    req.done = True
                    req.latency_s = self.clock.now() - t0
        for req, q, _bidx, _rows in routed:          # empty requests
            if len(q) == 0:
                req.logits = np.zeros((0, self.cfg.out_dim), np.float32)
                req.done, req.latency_s = True, self.clock.now() - t0
        return {"requests": len(requests), "batch_runs_total":
                self.stats["batch_runs"], "time_s": self.clock.now() - t0}
