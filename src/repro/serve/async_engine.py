"""Async micro-batching serving tier over ``GNNInferenceEngine``
(DESIGN.md §11).

The paper's 130x inference speedup comes from precomputed batches; the
synchronous ``GNNInferenceEngine`` (§8) only coalesces requests that arrive
inside ONE ``run`` call. This tier makes coalescing continuous across a
live request stream:

* **Bounded queue** — ``submit`` is non-blocking; beyond ``max_queue``
  in-flight requests admission rejects on arrival (backpressure, never
  unbounded memory).
* **Micro-batching window** — pending requests are dispatched as one
  coalesced ``GNNInferenceEngine.run`` when EITHER a full batch's worth of
  distinct routed rows accumulates for some precomputed batch (the plan's
  ``batch_occupancy`` hint: waiting longer cannot pack more work into that
  batch's forward) OR the oldest pending request has waited ``window_us``.
* **Deadline-aware admission** — a request carrying ``deadline_ms`` is
  rejected on arrival when the queue's drain estimate (EWMA of observed
  per-request service time × depth + one window) already exceeds it;
  admitted requests whose deadline passes while queued expire at dispatch
  time instead of wasting a forward.
* **Multi-tenant dispatch** — several ``(plan, params)`` tenants (each its
  own ``GNNInferenceEngine``, LRU and version chain) behind one queue and
  one worker. ``swap(tenant, plan, delta)`` hot-swaps ONE tenant atomically
  against its in-flight window without draining anyone's queue (§10's
  version chain per tenant).
* **Fault isolation** — a tenant forward that raises fails exactly that
  window's futures; the worker keeps serving other tenants (and the faulty
  tenant's next window).
* **Graceful degradation** (DESIGN.md §12, all opt-in via config) —
  bounded retry-with-backoff absorbs transient forward faults; a per-tenant
  circuit breaker opens after N consecutive window failures (fast-reject
  with retry-after, half-open probe to recover); a watchdog restarts a
  crashed worker loop after ``step`` has failed — never hung — its
  in-flight futures. All of it drivable deterministically by a seeded
  ``repro.faults.FaultInjector`` (``faults=``) and observable through
  ``fault_stats`` / ``snapshot()["faults"]``.

Determinism discipline: all timing flows through an injectable clock and
the dispatcher is a reentrant ``step()``; tests drive scripted arrival
traces against a fake clock with no worker thread and no sleeps
(``tests/test_async_engine.py``), while production uses ``start=True`` for
the condition-variable worker loop. Shutdown mirrors the ``PrefetchLoader``
Event/sentinel fix: ``close()`` flushes pending windows, completes every
future, and joins the worker.
"""
from __future__ import annotations

import copy
import dataclasses
import threading
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.faults import NO_FAULTS, FaultStats, WorkerDeath
from repro.serve.common import (
    CircuitBreaker, ServeClosed, ServeError, ServeExpired, ServeFuture,
    ServeRejected, ServeUnavailable, SystemClock)
from repro.serve.gnn_engine import GNNInferenceEngine, GNNRequest


@dataclasses.dataclass(frozen=True)
class AsyncServeConfig:
    """Window/admission policy knobs (DESIGN.md §11).

    ``max_requests_per_window=1`` degrades the tier to request-at-a-time
    dispatch — the A/B baseline the sustained-load bench beats.

    The degradation knobs (DESIGN.md §12) default OFF so the healthy-path
    behavior — and every pre-existing test — is bit-identical:
    ``max_retries=0`` fails a window on its first forward error exactly as
    before, and ``breaker_threshold=0`` disables the per-tenant circuit
    breaker entirely."""

    window_us: float = 2000.0            # max coalescing wait for a request
    max_queue: int = 1024                # bounded queue: reject beyond this
    max_requests_per_window: Optional[int] = None   # None = drain the window
    occupancy_dispatch: bool = True      # fire early on a full batch's worth
    service_time_init_us: float = 500.0  # drain-estimate seed per request
    ewma_alpha: float = 0.2              # service-time estimator smoothing
    latency_window: int = 4096           # completed-latency ring for pXX
    # graceful degradation (DESIGN.md §12) — all off by default
    max_retries: int = 0                 # window forward retries (transient)
    retry_backoff_us: float = 100.0      # backoff base, doubles per attempt
    breaker_threshold: int = 0           # consecutive window failures → open
    breaker_cooldown_us: float = 50_000.0   # open → half-open probe delay


class ServeStats:
    """Counters + latency ring of the serving tier — everything admission
    control and the load bench observe. Mutated only under the engine lock;
    ``snapshot()`` returns a consistent dict including p50/p95/p99."""

    COUNTERS = ("submitted", "accepted", "rejected_full", "rejected_deadline",
                "rejected_unroutable", "rejected_unavailable", "expired",
                "completed", "failed", "window_errors", "windows")

    def __init__(self, latency_window: int):
        for k in self.COUNTERS:
            setattr(self, k, 0)
        self.queue_depth = 0
        self.window_occupancy = 0.0      # last window: rows / batch capacity
        self._window_requests_sum = 0
        self._lat_us: deque = deque(maxlen=latency_window)

    @property
    def rejected(self) -> int:
        return (self.rejected_full + self.rejected_deadline +
                self.rejected_unroutable + self.rejected_unavailable)

    def record_window(self, n_requests: int, occupancy: float) -> None:
        self.windows += 1
        self._window_requests_sum += n_requests
        self.window_occupancy = occupancy

    def snapshot(self) -> Dict:
        d = {k: getattr(self, k) for k in self.COUNTERS}
        d["rejected"] = self.rejected
        d["queue_depth"] = self.queue_depth
        d["window_occupancy"] = self.window_occupancy
        d["mean_window_requests"] = (
            self._window_requests_sum / self.windows if self.windows else 0.0)
        if self._lat_us:
            lat = np.asarray(self._lat_us)
            d["p50_us"], d["p95_us"], d["p99_us"] = (
                float(np.percentile(lat, p)) for p in (50, 95, 99))
        return d


@dataclasses.dataclass
class _Pending:
    """One admitted request waiting in a tenant's window."""
    fut: ServeFuture
    node_ids: np.ndarray
    bidx: np.ndarray                     # routed batch per queried node
    rows: np.ndarray                     # routed row per queried node
    deadline_ms: Optional[float]
    t_submit: float


class _Tenant:
    """One ``(plan, params)`` model behind the shared queue: its own
    ``GNNInferenceEngine`` (LRU, stats, version chain), pending window, and
    a lock that makes ``swap`` atomic against its in-flight dispatch."""

    def __init__(self, name: str, engine: GNNInferenceEngine,
                 breaker: Optional[CircuitBreaker] = None):
        self.name = name
        self.engine = engine
        self.lock = threading.Lock()
        self.occupancy = engine.plan.batch_occupancy()
        self.pending: List[_Pending] = []
        self.full = False                # some batch's worth accumulated
        self.swaps = 0
        self.breaker = breaker           # None = breaker disabled (§12)

    def oldest_t(self) -> Optional[float]:
        return self.pending[0].t_submit if self.pending else None

    def note_pending_rows(self, occupancy_dispatch: bool,
                          max_rpw: Optional[int]) -> None:
        """Recompute the full-batch flag from the pending set (called after
        admission and after a partial take)."""
        if max_rpw is not None and len(self.pending) >= max_rpw:
            self.full = True
            return
        if not occupancy_dispatch:
            self.full = False
            return
        per_batch: Dict[int, set] = {}
        for p in self.pending:
            for bi, r in zip(p.bidx, p.rows):
                per_batch.setdefault(int(bi), set()).add(int(r))
        self.full = any(
            bi < len(self.occupancy) and 0 < self.occupancy[bi] <= len(rows)
            for bi, rows in per_batch.items())


class AsyncGNNEngine:
    """Micro-batching async serving tier (DESIGN.md §11).

    ``tenants`` maps name → a constructed :class:`GNNInferenceEngine` (the
    tenant owns its plan/params/LRU). ``submit`` returns a
    :class:`ServeFuture` immediately — rejected requests come back as an
    already-failed future (``fut.rejected``), admitted ones complete when
    their window runs. With ``start=True`` a worker thread drives dispatch;
    with ``start=False`` the caller (tests, schedulers) pumps ``step()``.
    """

    def __init__(self, tenants: Dict[str, GNNInferenceEngine],
                 config: Optional[AsyncServeConfig] = None,
                 clock=None, start: bool = True, faults=None):
        if not tenants:
            raise ValueError("AsyncGNNEngine needs at least one tenant")
        self.cfg = config or AsyncServeConfig()
        self._clock = clock or SystemClock()
        self.faults = faults or NO_FAULTS
        self.fault_stats = FaultStats(
            "retries", "fast_rejects", "worker_restarts", "breaker_opens",
            "breaker_closes", "swap_rollbacks")
        mk_breaker = (lambda: CircuitBreaker(
            self.cfg.breaker_threshold, self.cfg.breaker_cooldown_us / 1e6)
        ) if self.cfg.breaker_threshold > 0 else (lambda: None)
        self._tenants = {name: _Tenant(name, eng, mk_breaker())
                         for name, eng in tenants.items()}
        self._cond = threading.Condition()
        self._closed = False
        self.stats = ServeStats(self.cfg.latency_window)
        self._svc_us = float(self.cfg.service_time_init_us)
        self._thread: Optional[threading.Thread] = None
        if start:
            self._thread = threading.Thread(
                target=self._worker_main, name="async-gnn-dispatch",
                daemon=True)
            self._thread.start()

    def _sleep(self, seconds: float) -> None:
        """Backoff/stall through the injectable clock: a FakeClock's
        ``sleep`` just advances time, keeping fault tests sleep-free."""
        if seconds > 0:
            sleep = getattr(self._clock, "sleep", None)
            if sleep is not None:
                sleep(seconds)

    # -------------------------------------------------------------- submit
    def submit(self, tenant: str, node_ids: Sequence[int],
               deadline_ms: Optional[float] = None) -> ServeFuture:
        """Route + admit one request; never blocks on compute.

        Admission (in order): closed engine raises :class:`ServeClosed`;
        a full queue, an infeasible ``deadline_ms`` (drain estimate), or
        ids the tenant's CURRENT plan cannot route come back as an
        already-rejected future. The routing done here is an occupancy
        *hint* — the authoritative routing happens inside the dispatched
        ``GNNInferenceEngine.run``, so requests admitted before a ``swap``
        are served by the post-swap plan version."""
        t = self._tenants[tenant]
        now = self._clock.now()
        fut = ServeFuture(tenant, now)
        q = np.asarray(node_ids, dtype=np.int64).ravel()
        with self._cond:
            if self._closed:
                raise ServeClosed("submit after close()")
            self.stats.submitted += 1
            if t.breaker is not None:
                ok, retry_after = t.breaker.allow(now)
                if not ok:
                    # circuit open (DESIGN.md §12): O(1) fast-reject with a
                    # retry-after hint instead of queueing doomed work
                    self.stats.rejected_unavailable += 1
                    self.fault_stats.bump("fast_rejects")
                    fut.finish(exc=ServeUnavailable(
                        f"tenant {tenant!r} circuit open after "
                        f"{t.breaker.consecutive_failures} consecutive "
                        f"window failures; retry after "
                        f"{retry_after * 1e3:.1f}ms",
                        retry_after_ms=retry_after * 1e3), t_done=now)
                    return fut
            if self.stats.queue_depth >= self.cfg.max_queue:
                self.stats.rejected_full += 1
                fut.finish(exc=ServeRejected(
                    f"queue full ({self.cfg.max_queue} in flight)"),
                    t_done=now)
                return fut
            try:
                bidx, rows = t.engine.plan.routing.lookup(q)
            except KeyError as e:
                self.stats.rejected_unroutable += 1
                fut.finish(exc=ServeRejected(str(e)), t_done=now)
                return fut
            if deadline_ms is not None:
                est_ms = self._drain_estimate_us() / 1e3
                if est_ms > deadline_ms:
                    self.stats.rejected_deadline += 1
                    fut.finish(exc=ServeRejected(
                        f"deadline {deadline_ms:.1f}ms infeasible: drain "
                        f"estimate {est_ms:.1f}ms at depth "
                        f"{self.stats.queue_depth}"), t_done=now)
                    return fut
            t.pending.append(_Pending(fut, q, bidx, rows, deadline_ms, now))
            self.stats.accepted += 1
            self.stats.queue_depth += 1
            t.note_pending_rows(self.cfg.occupancy_dispatch,
                                self.cfg.max_requests_per_window)
            self._cond.notify_all()
        return fut

    def _drain_estimate_us(self) -> float:
        """Serve-by estimate for a request admitted NOW: everything queued
        ahead of it plus itself at the observed per-request service rate,
        plus one coalescing window of wait."""
        return ((self.stats.queue_depth + 1) * self._svc_us +
                self.cfg.window_us)

    # ------------------------------------------------------------ dispatch
    def _ready(self, t: _Tenant, now: float) -> bool:
        if not t.pending:
            return False
        if t.full:
            return True
        return (now - t.pending[0].t_submit) * 1e6 >= self.cfg.window_us

    def _take(self, t: _Tenant) -> List[_Pending]:
        """Pop one window's worth of this tenant's pending requests
        (caller holds the lock)."""
        k = len(t.pending) if self.cfg.max_requests_per_window is None \
            else min(len(t.pending), self.cfg.max_requests_per_window)
        chunk, t.pending = t.pending[:k], t.pending[k:]
        self.stats.queue_depth -= len(chunk)
        t.note_pending_rows(self.cfg.occupancy_dispatch,
                            self.cfg.max_requests_per_window)
        return chunk

    def step(self, now: Optional[float] = None, force: bool = False) -> int:
        """One dispatcher iteration: run every tenant whose window is ready
        (or, with ``force``, every tenant with pending work). Returns the
        number of requests dispatched or terminally resolved. Reentrant —
        the worker loop calls exactly this; tests call it directly.

        Crash-safe (DESIGN.md §12): windows popped off the queue are
        IN-FLIGHT — if the dispatcher dies between take and dispatch (the
        ``worker_death`` injection point, or any unexpected error escaping
        ``_dispatch``), every in-flight future is FAILED with that error
        before the exception propagates to the watchdog. A crashed worker
        may lose a window's work, never a future's completion."""
        now = self._clock.now() if now is None else now
        taken: List[Tuple[_Tenant, List[_Pending]]] = []
        with self._cond:
            for t in self._tenants.values():
                if t.pending and (force or self._ready(t, now)):
                    taken.append((t, self._take(t)))
        n = 0
        inflight = deque(taken)
        try:
            self.faults.fire("worker_death", WorkerDeath)
            while inflight:
                t, chunk = inflight[0]
                n += self._dispatch(t, chunk, now)
                inflight.popleft()
            return n
        except BaseException as e:
            failed = 0
            for t, chunk in inflight:    # fail, never hang, every in-flight
                for p in chunk:          # future (finish is one-shot, so
                    if p.fut.finish(exc=e, t_done=now):   # partially-
                        failed += 1      # dispatched windows are safe)
            with self._cond:
                self.stats.failed += failed
            raise

    def _dispatch(self, t: _Tenant, chunk: List[_Pending],
                  now: float) -> int:
        # deadline expiry while queued: fail, never waste the forward
        live: List[_Pending] = []
        for p in chunk:
            if p.deadline_ms is not None and \
                    (now - p.t_submit) * 1e3 > p.deadline_ms:
                with self._cond:
                    self.stats.expired += 1
                p.fut.finish(exc=ServeExpired(
                    f"deadline {p.deadline_ms:.1f}ms passed after "
                    f"{(now - p.t_submit) * 1e3:.1f}ms in queue"),
                    t_done=now)
                continue
            live.append(p)
        if not live:
            return len(chunk)
        # window occupancy: distinct routed rows vs the capacity of the
        # batches this window touches (1.0 = the forwards are full)
        per_batch: Dict[int, set] = {}
        for p in live:
            for bi, r in zip(p.bidx, p.rows):
                per_batch.setdefault(int(bi), set()).add(int(r))
        capacity = sum(int(t.occupancy[bi]) for bi in per_batch
                       if bi < len(t.occupancy))
        occ = (sum(len(v) for v in per_batch.values()) / capacity
               if capacity else 0.0)
        reqs = [GNNRequest(node_ids=p.node_ids) for p in live]
        stall = self.faults.delay("dispatch_delay")
        if stall:
            self._sleep(stall)
        t0 = self._clock.now()
        attempt = 0
        while True:
            try:
                with t.lock:             # atomic against swap(tenant, ...)
                    self.faults.fire("forward")
                    # by design: the per-tenant lock EXISTS to serialize
                    # engine.run against swap — only this tenant's
                    # traffic waits, and the window is the unit of work
                    t.engine.run(reqs)   # lint: allow(lock-blocking)
                break
            except Exception as e:
                if attempt < self.cfg.max_retries:
                    # transient-fault absorption (DESIGN.md §12): bounded
                    # retry with exponential backoff through the clock
                    attempt += 1
                    with self._cond:
                        self.fault_stats.bump("retries")
                    self._sleep(self.cfg.retry_backoff_us
                                * (2 ** (attempt - 1)) / 1e6)
                    continue
                # retries exhausted — fault isolation: fail ONLY this
                t_done = self._clock.now()   # window; keep serving every
                with self._cond:             # tenant (including this one)
                    self.stats.window_errors += 1
                    self.stats.failed += len(live)
                    self.stats.record_window(len(live), occ)
                    if t.breaker is not None and \
                            t.breaker.record_failure(t_done):
                        self.fault_stats.bump("breaker_opens")
                for p in live:
                    p.fut.finish(exc=e, t_done=t_done)
                return len(chunk)
        t_done = self._clock.now()
        with self._cond:
            if t.breaker is not None:
                was = t.breaker.state
                t.breaker.record_success(t_done)
                if was != CircuitBreaker.CLOSED:
                    self.fault_stats.bump("breaker_closes")
            obs_us = (t_done - t0) * 1e6 / len(live)
            a = self.cfg.ewma_alpha
            self._svc_us = (1 - a) * self._svc_us + a * obs_us
            self.stats.record_window(len(live), occ)
            for p, r in zip(live, reqs):
                if r.error is not None:
                    self.stats.failed += 1
                else:
                    self.stats.completed += 1
                    self.stats._lat_us.append((t_done - p.t_submit) * 1e6)
        for p, r in zip(live, reqs):
            if r.error is not None:
                p.fut.finish(exc=ServeError(r.error), t_done=t_done)
            else:
                p.fut.finish(value=r.logits, t_done=t_done)
        return len(chunk)

    # --------------------------------------------------------- worker loop
    def _wait_timeout(self, now: float) -> Optional[float]:
        """Seconds until the oldest pending window expires; None when the
        queue is empty (sleep until submit notifies)."""
        oldest = [t.oldest_t() for t in self._tenants.values()
                  if t.pending]
        if not oldest:
            return None
        remain = self.cfg.window_us / 1e6 - (now - min(oldest))
        return max(remain, 1e-4)

    def _worker_main(self) -> None:
        """Watchdog shell around the dispatch loop (DESIGN.md §12): a
        crashed worker loop — injected ``worker_death`` or a genuine bug —
        has already FAILED its in-flight futures (``step`` guarantees it),
        so the watchdog just counts the restart and re-enters the loop.
        Queued-but-not-taken requests survive the crash untouched and are
        served by the restarted loop."""
        while True:
            try:
                self._worker_loop()
                return                   # clean exit: close() was called
            except BaseException:
                with self._cond:
                    self.fault_stats.bump("worker_restarts")
                    if self._closed:     # crashed during the close-path
                        break            # flush: drain below, then exit
        self._drain_all()

    def _worker_loop(self) -> None:
        while True:
            with self._cond:
                while not self._closed:
                    now = self._clock.now()
                    if any(self._ready(t, now)
                           for t in self._tenants.values()):
                        break
                    self._cond.wait(self._wait_timeout(now))
                if self._closed:
                    break
            self.step()
        self._drain_all()                # complete every admitted future

    def flush(self) -> int:
        """Dispatch every pending window regardless of readiness (close
        path; also useful to drain deterministically in tests)."""
        n = 0
        while True:
            got = self.step(force=True)
            if not got:
                return n
            n += got

    def _drain_all(self, max_crashes: int = 10) -> None:
        """Close-path drain that terminates even under a fault storm:
        ``flush`` is retried through worker crashes (each crash already
        failed its in-flight futures); after ``max_crashes`` consecutive
        crashes whatever is still queued is failed with ServeClosed. Either
        way, EVERY admitted future terminates (DESIGN.md §12)."""
        for _ in range(max_crashes):
            try:
                self.flush()
                return
            except BaseException:
                with self._cond:
                    self.fault_stats.bump("worker_restarts")
        now = self._clock.now()
        failed = 0
        with self._cond:
            for t in self._tenants.values():
                for p in t.pending:
                    if p.fut.finish(exc=ServeClosed(
                            "engine closed during a fault storm; request "
                            "was never dispatched"), t_done=now):
                        failed += 1
                self.stats.queue_depth -= len(t.pending)
                t.pending = []
                t.full = False
            self.stats.failed += failed

    def close(self) -> None:
        """Clean shutdown: stop admission, flush pending windows (every
        admitted future completes — with a result, its tenant's error, or
        expiry), join the worker. Idempotent."""
        with self._cond:
            already = self._closed
            self._closed = True
            self._cond.notify_all()
        if self._thread is not None:
            self._thread.join(timeout=30.0)
            self._thread = None
        elif not already:
            self._drain_all()

    def __enter__(self) -> "AsyncGNNEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------- tenants
    def swap(self, tenant: str, plan, delta=None) -> Dict[str, int]:
        """Hot-swap ONE tenant onto a refreshed plan (§10 version chain)
        without draining the queue: the tenant lock serializes the swap
        against that tenant's in-flight window only — other tenants keep
        dispatching, and this tenant's queued requests are served by the
        NEW plan version at their window (dispatch re-routes).

        A swap the engine REFUSES (invalid/corrupt plan, mismatched audit —
        DESIGN.md §12) raises out of here with the tenant untouched: it
        keeps serving the parent plan version, its occupancy hint and LRU
        intact, and the rollback is counted in ``fault_stats`` plus the
        engine's own ``swap_audit`` trail."""
        t = self._tenants[tenant]
        try:
            with t.lock:
                # by design: zero-downtime swap is "atomic between
                # windows" — the same per-tenant lock that serializes
                # run() must cover the validate+swap, or a window could
                # run mid-swap on a half-installed plan
                res = t.engine.swap(plan, delta)   # lint: allow(lock-blocking)
                t.occupancy = t.engine.plan.batch_occupancy()
        except Exception:
            with self._cond:
                self.fault_stats.bump("swap_rollbacks")
            raise
        with self._cond:
            t.swaps += 1
        return res

    def tenant_engine(self, tenant: str) -> GNNInferenceEngine:
        return self._tenants[tenant].engine

    # --------------------------------------------------------------- stats
    def snapshot(self) -> Dict:
        """Consistent ``ServeStats`` view plus per-tenant serving counters
        (the §10 per-version tables ride along unchanged) and the fault
        surface (DESIGN.md §12): degradation counters, per-tenant breaker
        state, and — when an injector is attached — what it injected."""
        with self._cond:
            d = self.stats.snapshot()
            d["service_estimate_us"] = self._svc_us
            d["tenants"] = {
                name: {"swaps": t.swaps, "pending": len(t.pending),
                       "engine": copy.deepcopy(t.engine.stats),
                       # out-of-core tenants also report lazy-cache
                       # faulting/eviction/IO counters (DESIGN.md §13)
                       "ooc": t.engine.ooc_stats(),
                       "breaker": (t.breaker.snapshot()
                                   if t.breaker is not None else None)}
                for name, t in self._tenants.items()}
            d["faults"] = self.fault_stats.snapshot()
            if self.faults.active:
                d["faults"]["injected"] = self.faults.snapshot()
        return d
