"""Batched serving engine: slot-based continuous batching over decode_step.

One compiled `decode_step` serves a fixed batch of SLOTS; requests stream
into free slots (continuous batching, `repro.serve.common.SlotPool` — the
same admission/lifecycle machinery the async GNN tier builds on,
DESIGN.md §11). Each slot tracks its own length; the step advances every
active slot by one token. Prefill is teacher-forced token-by-token through
the same decode path (adequate for the CPU demo; on TPU the prefill cell
from the dry-run would be used).

Mirrors the paper's inference story: with precomputed static shapes there is
exactly ONE executable, no recompilation, and batches are always full.

Stream lifecycle: the position counter is engine-global (lockstep decode),
so a stream ends when `pos` reaches `max_len`. `run` then RELEASES the
slots of unfinished requests — a wedged slot must never outlive the stream
that admitted it (slot-state leak) — and `reset_stream` re-arms the engine
(fresh cache, pos 0) for the next stream.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.lm import init_cache, decode_step
from repro.serve.common import SlotPool, SystemClock


@dataclasses.dataclass
class Request:
    prompt: np.ndarray                 # (P,) int32 prompt tokens
    max_new_tokens: int = 16
    out_tokens: Optional[List[int]] = None
    done: bool = False


class ServeEngine:
    def __init__(self, cfg, params, num_slots: int = 4, max_len: int = 512,
                 greedy: bool = True, clock=None):
        self.cfg = cfg
        self.params = params
        # all timing through the injectable clock (DESIGN.md §11) so the
        # FakeClock test suite can drive `run` deterministically
        self.clock = clock if clock is not None else SystemClock()
        self.num_slots = num_slots
        self.max_len = max_len
        self.greedy = greedy
        self.cache = init_cache(cfg, num_slots, max_len)
        # NOTE: position is tracked PER ENGINE (lockstep decode): slots share
        # the step counter; a slot joining mid-stream gets its prompt fed at
        # the current position. This keeps pos a scalar (cheap decode).
        self.pos = 0
        self.pool: SlotPool = SlotPool(num_slots)
        self._tokens = np.zeros((num_slots, 1), np.int32)

        @partial(jax.jit, donate_argnums=(1,))
        def _step(params, cache, tokens, pos):
            logits, cache = decode_step(cfg, params, cache, tokens, pos)
            return logits, cache

        self._step = _step

    @property
    def slots(self) -> List[Optional[Request]]:
        """Live view of the slot occupants (index-stable; None = free)."""
        return self.pool.slots

    def submit(self, req: Request) -> bool:
        """Admit `req` into the first free slot; False (busy-rejection, no
        silent queueing, no eviction) while every slot is occupied."""
        req.out_tokens = []
        req._fed = 0                    # prompt tokens fed so far
        if self.pool.acquire(req) is None:
            req.out_tokens = None       # not admitted: leave it unstarted
            return False
        return True

    # back-compat name; `submit` is the canonical admission API
    add_request = submit

    def step(self) -> None:
        """Advance every active slot by one token."""
        for i, req in enumerate(self.slots):
            if req is None:
                self._tokens[i, 0] = 0
            elif req._fed < len(req.prompt):
                self._tokens[i, 0] = req.prompt[req._fed]
                req._fed += 1
            else:
                self._tokens[i, 0] = req.out_tokens[-1] if req.out_tokens \
                    else req.prompt[-1]
        logits, self.cache = self._step(
            self.params, self.cache, jnp.asarray(self._tokens),
            jnp.int32(self.pos))
        self.pos += 1
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            if req._fed >= len(req.prompt):          # generating
                tok = int(nxt[i]) if nxt.ndim == 1 else int(nxt[i][0])
                req.out_tokens.append(tok)
                if len(req.out_tokens) >= req.max_new_tokens:
                    req.done = True
                    self.pool.release(i)    # freed THIS step: reusable now

    def run(self, requests: List[Request], max_steps: int = 10_000) -> Dict:
        pending = list(requests)
        t0 = self.clock.now()
        steps = 0
        while (pending or any(s is not None for s in self.slots)) \
                and steps < max_steps and self.pos < self.max_len - 1:
            while pending and self.submit(pending[0]):
                pending.pop(0)
            self.step()
            steps += 1
        evicted = 0
        if self.pos >= self.max_len - 1:
            # stream exhausted: unfinished requests can never advance, so
            # their slots MUST be released (they stay not-done) — leaking
            # them would wedge admission for every later submit/run
            evicted = len(self.pool.release_all())
        return {"steps": steps, "time_s": self.clock.now() - t0,
                "completed": sum(r.done for r in requests),
                "evicted": evicted}

    def reset_stream(self) -> None:
        """Re-arm the engine for a fresh stream: new cache, position 0.
        Refused while a slot is still serving (release/finish first)."""
        busy = sum(1 for s in self.slots if s is not None)
        if busy:
            raise RuntimeError(
                f"reset_stream with {busy} slot(s) still occupied")
        self.cache = init_cache(self.cfg, self.num_slots, self.max_len)
        self.pos = 0
        self._tokens[:] = 0
