from repro.serve.engine import ServeEngine
from repro.serve.gnn_engine import GNNInferenceEngine, GNNRequest

__all__ = ["ServeEngine", "GNNInferenceEngine", "GNNRequest"]
