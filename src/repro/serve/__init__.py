from repro.serve.async_engine import (
    AsyncGNNEngine, AsyncServeConfig, ServeStats)
from repro.serve.common import (
    ServeClosed, ServeError, ServeExpired, ServeFuture, ServeRejected,
    SlotPool, SystemClock)
from repro.serve.engine import ServeEngine
from repro.serve.gnn_engine import GNNInferenceEngine, GNNRequest

__all__ = [
    "AsyncGNNEngine", "AsyncServeConfig", "GNNInferenceEngine", "GNNRequest",
    "ServeClosed", "ServeEngine", "ServeError", "ServeExpired", "ServeFuture",
    "ServeRejected", "ServeStats", "SlotPool", "SystemClock",
]
