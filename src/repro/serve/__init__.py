from repro.serve.async_engine import (
    AsyncGNNEngine, AsyncServeConfig, ServeStats)
from repro.serve.common import (
    CircuitBreaker, ServeClosed, ServeError, ServeExpired, ServeFuture,
    ServeRejected, ServeUnavailable, SlotPool, SystemClock)
from repro.serve.engine import ServeEngine
from repro.serve.gnn_engine import GNNInferenceEngine, GNNRequest

__all__ = [
    "AsyncGNNEngine", "AsyncServeConfig", "CircuitBreaker",
    "GNNInferenceEngine", "GNNRequest", "ServeClosed", "ServeEngine",
    "ServeError", "ServeExpired", "ServeFuture", "ServeRejected",
    "ServeStats", "ServeUnavailable", "SlotPool", "SystemClock",
]
