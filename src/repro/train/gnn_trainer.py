"""GNN training loop reproducing the paper's recipe (Sec. 4 / App. B):

Adam + ReduceLROnPlateau(0.33, patience, cooldown) on val loss, early stop on
val loss, batch scheduling (TSP / weighted / none), optional gradient
accumulation, mini-batched evaluation with the SAME method used for training
("since full inference is too slow to execute every epoch").

One jit'd train_step / eval_step serves every method because all batchers
emit identical static shapes (per method).
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.batches import BatchCache, PaddedBatch
from repro.core.plan import Plan
from repro.core.scheduling import make_schedule
from repro.data.loader import PrefetchLoader
from repro.faults import FaultStats
from repro.models.gnn import ops as gnn_ops
from repro.models.gnn import policy as gnn_policy
from repro.models.gnn.models import (
    GNNConfig, init_gnn, gnn_apply, output_logits, masked_xent, masked_accuracy,
)
from repro.optim.optimizers import get_optimizer, apply_updates
from repro.optim.schedules import ReduceLROnPlateau
from repro.optim.accumulate import GradAccumulator


class NonFiniteGradError(RuntimeError):
    """Raised by ``nonfinite_policy="halt"`` when a step produces NaN/Inf
    loss or gradients (DESIGN.md §12) — training stops at the first
    poisoned step instead of silently corrupting the parameters."""


@dataclasses.dataclass
class TrainResult:
    params: Dict
    history: List[Dict]          # per-epoch metrics
    best_val_acc: float
    best_epoch: int
    time_per_epoch: float
    preprocess_time: float
    total_time: float


def step_rng(rng: jax.Array, epoch: int, step: int) -> jax.Array:
    """Dropout key for (epoch, step), derived statelessly from the base rng.

    Keys MUST differ across epochs for the same step: a fixed caller-passed
    rng that is merely re-split from the top every epoch replays identical
    dropout masks epoch after epoch (the regression this fixes). fold_in
    domain 1 keeps the (epoch, step) grid disjoint from the init key
    (domain 0, see ``fit``), and the same derivation drives both the
    single-device loop and the mesh super-steps — micro-batch `step` of
    epoch `epoch` sees one mask no matter how batches map to devices."""
    return jax.random.fold_in(
        jax.random.fold_in(jax.random.fold_in(rng, 1), epoch), step)


def as_host_batches(batches):
    """Normalize any batch container to an indexable sequence of host
    device-array dicts. ``Plan`` is the primary input (DESIGN.md §8); raw
    ``PaddedBatch`` lists and ``BatchCache`` keep working as the back-compat
    shim. A ``Plan``/``BatchCache`` is consumed in place — reading batch i
    slices the contiguous cache, no per-batch dict materialization."""
    if isinstance(batches, Plan):
        return batches.cache
    if isinstance(batches, BatchCache):
        return batches
    return [b.device_arrays() if isinstance(b, PaddedBatch) else b
            for b in batches]


def _batch_labels(batches) -> List[np.ndarray]:
    """Per-batch real output labels, for the scheduler."""
    if isinstance(batches, Plan):
        return batches.batch_labels()
    if isinstance(batches, BatchCache):
        lab, msk = batches.fields["labels"], batches.fields["output_mask"]
        return [lab[i][msk[i] > 0] for i in range(len(batches))]
    return [b.labels[b.output_mask] for b in batches]


class GNNTrainer:
    def __init__(self, model_cfg: GNNConfig, optimizer: str = "adam",
                 lr: float = 1e-3, weight_decay: float = 0.0,
                 plateau_patience: int = 30, early_stop_patience: int = 100,
                 grad_accum: int = 1, seed: int = 0,
                 backend=None,
                 nonfinite_policy: str = "off"):
        # `backend` overrides model_cfg.backend (DESIGN.md §7/§14): a name,
        # "auto", or a BackendPolicy — one config can be A/B'd across
        # aggregation backends without rebuilding it, and the auto policy
        # dispatches per batch on the plan's stored autotuner decisions.
        model_cfg, self.policy = gnn_policy.resolve(model_cfg, backend)
        # NaN/Inf grad guard (DESIGN.md §12): "off" keeps the donated fast
        # path bit-identical; "skip" drops the poisoned update and keeps
        # going; "halt" raises NonFiniteGradError at the first bad step.
        if nonfinite_policy not in ("off", "skip", "halt"):
            raise ValueError(
                f"nonfinite_policy must be 'off', 'skip' or 'halt': "
                f"{nonfinite_policy!r}")
        self.cfg = model_cfg
        self.opt = get_optimizer(optimizer, weight_decay=weight_decay)
        self.sched = ReduceLROnPlateau(lr=lr, patience=plateau_patience)
        self.early_stop_patience = early_stop_patience
        self.grad_accum = grad_accum
        self.seed = seed
        self.nonfinite_policy = nonfinite_policy
        self.fault_stats = FaultStats("nonfinite_steps", "skipped_steps",
                                      "halts")
        self._step_cache: Dict = {}
        base = self._steps_for(self.cfg.backend,
                               int(getattr(self.cfg, "bcsr_block_f", 0)))
        # the fixed-decision executables (and the back-compat attribute
        # names); auto dispatch fetches per-decision sets via _steps_for
        self._train_step = base["train"]
        self._grad_step = base["grad"]
        self._eval_step = base["eval"]
        self._guarded_step = base["guarded"]
        self._apply_step = base["apply"]
        self._finite_check = base["finite"]

    def _steps_for(self, backend: str, block_f: int = 0) -> Dict:
        """Jit'd step set for one (backend, block_f) decision — traced once
        per distinct decision in play (DESIGN.md §14)."""
        key = (backend, int(block_f))
        if key not in self._step_cache:
            self._step_cache[key] = self._build_steps(
                gnn_policy.batch_config(self.cfg, backend, int(block_f)))
        return self._step_cache[key]

    def _build_steps(self, cfg) -> Dict:
        opt = self.opt

        def loss_fn(params, batch, rng):
            h = gnn_apply(cfg, params, batch, rng=rng, train=True)
            logits = output_logits(h, batch)
            return masked_xent(logits, batch["labels"], batch["output_mask"])

        @partial(jax.jit, donate_argnums=(0, 1))
        def train_step(params, opt_state, batch, lr, rng):
            loss, grads = jax.value_and_grad(loss_fn)(params, batch, rng)
            updates, opt_state = opt.update(grads, opt_state, params, lr)
            params = apply_updates(params, updates)
            return params, opt_state, loss

        @jax.jit
        def grad_step(params, batch, rng):
            return jax.value_and_grad(loss_fn)(params, batch, rng)

        def tree_finite(loss, grads):
            ok = jnp.isfinite(loss)
            for g in jax.tree_util.tree_leaves(grads):
                ok = ok & jnp.all(jnp.isfinite(g))
            return ok

        # Guarded variant (DESIGN.md §12): NO buffer donation — when the
        # step is non-finite the OLD params/opt_state are the output, so
        # they must stay live. jnp.where keeps the whole guard on-device;
        # the donated fast path above is untouched when the policy is off.
        @jax.jit
        def guarded_train_step(params, opt_state, batch, lr, rng):
            loss, grads = jax.value_and_grad(loss_fn)(params, batch, rng)
            ok = tree_finite(loss, grads)
            updates, new_opt = opt.update(grads, opt_state, params, lr)
            new_params = apply_updates(params, updates)
            keep = lambda new, old: jnp.where(ok, new, old)
            return (jax.tree_util.tree_map(keep, new_params, params),
                    jax.tree_util.tree_map(keep, new_opt, opt_state),
                    loss, ok)

        finite_check = jax.jit(tree_finite)

        @partial(jax.jit, donate_argnums=(0, 1))
        def apply_step(params, opt_state, grads, lr):
            updates, opt_state = opt.update(grads, opt_state, params, lr)
            return apply_updates(params, updates), opt_state

        @jax.jit
        def eval_step(params, batch):
            h = gnn_apply(cfg, params, batch, train=False)
            logits = output_logits(h, batch)
            loss = masked_xent(logits, batch["labels"], batch["output_mask"])
            acc_num = (logits.argmax(-1) == batch["labels"]).astype(jnp.float32) * batch["output_mask"]
            return loss * batch["output_mask"].sum(), acc_num.sum(), batch["output_mask"].sum()

        return {"train": train_step, "grad": grad_step, "apply": apply_step,
                "eval": eval_step, "guarded": guarded_train_step,
                "finite": finite_check}

    # ------------------------------------------------------------------
    def _on_nonfinite(self, ep: int, step: int) -> None:
        """Apply the nonfinite policy to one poisoned step (DESIGN.md §12)."""
        self.fault_stats.bump("nonfinite_steps")
        if self.nonfinite_policy == "halt":
            self.fault_stats.bump("halts")
            raise NonFiniteGradError(
                f"non-finite loss/gradients at epoch {ep} step {step} "
                f"(nonfinite_policy='halt')")
        self.fault_stats.bump("skipped_steps")

    def snapshot(self) -> Dict:
        """Degradation observability (DESIGN.md §12), the ServeStats idiom."""
        return {"nonfinite_policy": self.nonfinite_policy,
                "faults": self.fault_stats.snapshot()}

    # ------------------------------------------------------------------
    def evaluate(self, params, batches) -> Dict[str, float]:
        """Mini-batched evaluation. Accepts a Plan (primary), a BatchCache,
        a list of PaddedBatch, or a list of device-array dicts. Under an
        auto policy each batch runs the backend the plan's stored autotuner
        decision selects (DESIGN.md §14); decisions are read from the
        ORIGINAL container before cache normalization."""
        decisions = gnn_policy.batch_decisions(batches, self.policy, self.cfg)
        batches = as_host_batches(batches)
        tot_l = tot_a = tot_n = 0.0
        for i in range(len(batches)):
            l, a, n = self._steps_for(*decisions[i])["eval"](
                params, batches[i])
            tot_l += float(l); tot_a += float(a); tot_n += float(n)
        n = max(tot_n, 1.0)
        return {"loss": tot_l / n, "acc": tot_a / n}

    def fit(self,
            train_batches,                    # Plan | List[PaddedBatch] | Batcher
            val_batches,                      # Plan | List[PaddedBatch]
            num_classes: int,
            epochs: int = 100,
            schedule_mode: str = "tsp",
            eval_every: int = 1,
            verbose: bool = False,
            preprocess_time: float = 0.0,
            rng: Optional[jax.Array] = None,
            mesh=None) -> TrainResult:
        """Train on precomputed batches; with ``mesh`` the Plan executes
        data-parallel via ``repro.dist.data_parallel.ShardedPlanExecutor``
        (DESIGN.md §9): params replicate, each device takes one batch per
        super-step, gradients psum-mean — equivalent to single-device
        training with ``grad_accum = mesh_world(mesh)``."""
        base_rng = jax.random.PRNGKey(self.seed) if rng is None else rng
        # init from fold_in domain 0; dropout keys live in domain 1 keyed by
        # (epoch, step) — see `step_rng` for why the split is stateless.
        params = init_gnn(self.cfg, jax.random.fold_in(base_rng, 0))
        opt_state = self.opt.init(params)
        accum = GradAccumulator(self.grad_accum)

        if isinstance(train_batches, Plan) and not preprocess_time:
            # amortization accounting rides along in the artifact
            m = train_batches.meta
            preprocess_time = train_batches.timings.get(
                f"preprocess/{m.get('split')}/{m.get('mode')}", 0.0)
        fixed = isinstance(train_batches, (Plan, BatchCache, list, tuple))
        if not fixed and self.cfg.kind != "gat" \
                and gnn_ops.resolve_backend(self.cfg.backend) == "bcsr":
            # fail with the batcher's name up front, not with a generic
            # missing-tiles error from deep inside the first epoch's trace
            name = getattr(train_batches, "name",
                           type(train_batches).__name__)
            raise ValueError(
                f"backend='bcsr' needs batches with precomputed BCSR tiles, "
                f"but batcher {name!r} (graph/sampling.py) regenerates "
                f"batches per epoch without tiles. Train from an "
                f"IBMBPipeline plan built with IBMBConfig(backend='bcsr'), "
                f"or use backend='segment' for this batcher (DESIGN.md §7).")
        if fixed:
            host = as_host_batches(train_batches)
            labels = _batch_labels(train_batches)
            order_fn = lambda ep: make_schedule(
                labels, num_classes, mode=schedule_mode, seed=self.seed + ep)
            # (backend, block_f) per batch — the plan's stored autotuner
            # decisions under an auto policy, uniform otherwise (§14)
            decisions = gnn_policy.batch_decisions(
                train_batches, self.policy, self.cfg)
        val_host = as_host_batches(val_batches)
        val_decisions = gnn_policy.batch_decisions(
            val_batches, self.policy, self.cfg)
        # fail fast (not mid-trace) if the batches lack the tiles the
        # configured backend needs (DESIGN.md §7); an auto policy validates
        # by tile presence, so any batch container passes
        vb = "auto" if self.policy.is_auto else self.cfg.backend
        for sample in ([host[0]] if fixed else []) + [val_host[0]]:
            gnn_ops.validate_batch_for_backend(sample, vb, self.cfg.kind)

        executor = None
        if mesh is not None:
            if not fixed:
                raise ValueError(
                    "mesh execution needs precomputed fixed batches (a "
                    "Plan/BatchCache/list) — resampling batchers regenerate "
                    "per epoch and cannot be staged as super-steps")
            if self.grad_accum != 1:
                raise ValueError(
                    "mesh=... already averages gradients over each "
                    "super-step (DESIGN.md §9); combining it with "
                    "grad_accum is not supported")
            if self.nonfinite_policy != "off":
                raise ValueError(
                    "nonfinite_policy guards the single-device loop only; "
                    "the mesh super-step path is unguarded (DESIGN.md §12) "
                    "— use nonfinite_policy='off' with mesh=...")
            from repro.dist.data_parallel import ShardedPlanExecutor
            executor = ShardedPlanExecutor(mesh, self.cfg, self.opt,
                                           backend=self.policy)
            params = executor.replicate(params)
            opt_state = executor.replicate(opt_state)

        history: List[Dict] = []
        best_val_loss, best_val_acc, best_epoch = float("inf"), 0.0, -1
        best_params = params
        bad = 0
        epoch_times = []
        t_total0 = time.time()

        for ep in range(epochs):
            t0 = time.time()
            if not fixed:  # resampling baselines pay regeneration every epoch
                epoch_pb = train_batches.epoch_batches(ep)
                host = as_host_batches(epoch_pb)
                order = np.random.default_rng(self.seed + ep).permutation(len(host))
                decisions = gnn_policy.batch_decisions(
                    epoch_pb, self.policy, self.cfg)
            else:
                order = order_fn(ep)
            ep_loss = 0.0
            nsteps = 0
            if executor is not None:
                # one shard_map super-step per `world` batches; micro-batch
                # j of super-step s is global step s*world+j, so its dropout
                # key matches the single-device loop's step counter exactly.
                # The loader groups with the SAME superstep_indices the
                # executor uses, so groups[si] names super-step si's batches
                # and its (backend, block_f) executable (§14).
                groups = executor.supersteps(order)
                loader = PrefetchLoader(
                    host, order, group=executor.world,
                    device=executor.batch_sharding)
                for si, (batch, w) in enumerate(loader):
                    fns = executor.steps_for(*gnn_policy.superstep_decision(
                        decisions, groups[si][0]))
                    keys = jnp.stack(
                        [step_rng(base_rng, ep, si * executor.world + j)
                         for j in range(executor.world)])
                    params, opt_state, losses = fns.train(
                        params, opt_state, batch, w,
                        jnp.float32(self.sched.lr), keys)
                    real = np.asarray(w) > 0
                    ep_loss += float(np.asarray(losses)[real].sum())
                    nsteps += int(real.sum())
            else:
                loader = PrefetchLoader(host, order)
                for bi, batch in enumerate(loader):
                    # loader position bi holds batch order[bi]; its stored
                    # decision picks the executable (uniform when fixed)
                    steps = self._steps_for(*decisions[int(order[bi])])
                    sub = step_rng(base_rng, ep, bi)
                    if self.grad_accum == 1:
                        if self.nonfinite_policy == "off":
                            params, opt_state, loss = steps["train"](
                                params, opt_state, batch,
                                jnp.float32(self.sched.lr), sub)
                        else:
                            params, opt_state, loss, ok = steps["guarded"](
                                params, opt_state, batch,
                                jnp.float32(self.sched.lr), sub)
                            if not bool(ok):
                                self._on_nonfinite(ep, bi)
                                continue   # loss is poisoned; update held
                    else:
                        loss, grads = steps["grad"](params, batch, sub)
                        if self.nonfinite_policy != "off" and \
                                not bool(self._finite_check(loss, grads)):
                            # never let a NaN enter the accumulator: one bad
                            # micro-batch would poison the whole macro-step
                            self._on_nonfinite(ep, bi)
                            continue
                        g = accum.add(grads)
                        if g is not None:
                            params, opt_state = self._apply_step(
                                params, opt_state, g, jnp.float32(self.sched.lr))
                    ep_loss += float(loss)
                    nsteps += 1
                if self.grad_accum > 1:
                    g = accum.flush()
                    if g is not None:
                        params, opt_state = self._apply_step(
                            params, opt_state, g, jnp.float32(self.sched.lr))
            epoch_times.append(time.time() - t0)

            if (ep + 1) % eval_every == 0:
                val = executor.evaluate(params, val_host,
                                        decisions=val_decisions) \
                    if executor is not None \
                    else self.evaluate(params, val_batches)
                self.sched.step(val["loss"])
                history.append({"epoch": ep, "train_loss": ep_loss / max(nsteps, 1),
                                "val_loss": val["loss"], "val_acc": val["acc"],
                                "lr": self.sched.lr,
                                "time": time.time() - t_total0})
                if verbose:
                    print(f"  ep {ep:4d} loss {ep_loss/max(nsteps,1):.4f} "
                          f"val_loss {val['loss']:.4f} val_acc {val['acc']:.4f} lr {self.sched.lr:.2e}")
                if val["loss"] < best_val_loss - 1e-6:
                    best_val_loss, best_val_acc, best_epoch = val["loss"], val["acc"], ep
                    best_params = jax.tree_util.tree_map(lambda x: x.copy(), params)
                    bad = 0
                else:
                    bad += 1
                    if bad >= self.early_stop_patience:
                        break
        return TrainResult(
            params=best_params, history=history, best_val_acc=best_val_acc,
            best_epoch=best_epoch,
            time_per_epoch=float(np.mean(epoch_times)) if epoch_times else 0.0,
            preprocess_time=preprocess_time, total_time=time.time() - t_total0)
