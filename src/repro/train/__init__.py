from repro.train.gnn_trainer import GNNTrainer, TrainResult, as_host_batches

__all__ = ["GNNTrainer", "TrainResult", "as_host_batches"]
