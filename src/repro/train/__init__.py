from repro.train.gnn_trainer import GNNTrainer, TrainResult

__all__ = ["GNNTrainer", "TrainResult"]
