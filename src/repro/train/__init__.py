from repro.train.gnn_trainer import (
    GNNTrainer, NonFiniteGradError, TrainResult, as_host_batches)

__all__ = ["GNNTrainer", "NonFiniteGradError", "TrainResult",
           "as_host_batches"]
