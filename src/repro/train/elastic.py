"""Elastic scaling + straggler mitigation over PRECOMPUTED batches.

IBMB's determinism is the enabler: the epoch's work is a fixed list of batch
IDs, so distribution questions become pure metadata:

* `partition_batches(ids, num_hosts, host)` — deterministic round-robin lease
  of batch IDs to hosts. On elastic restart with a different host count the
  same call re-partitions — no resharding of data, no sampler state.
* `WorkQueue` — per-epoch work-stealing queue: hosts lease batches; when a
  host finishes its lease it steals from the slowest host's remaining lease.
  Gradient all-reduce stays synchronous; stealing only rebalances the DATA
  path, so a straggling host's disk/NIC can't stall the epoch beyond one
  batch.
* a heartbeat registry with `dead_hosts()` so the coordinator can reassign a
  crashed host's lease at the next epoch boundary (checkpoint/restart covers
  mid-epoch loss of model state).
* `ElasticCoordinator` actually closes that loop (DESIGN.md §12): it folds
  `dead_hosts()` into each epoch's `WorkQueue` via `reassign`, so a crashed
  host's batches are re-leased to survivors and NO batch is silently
  dropped from the epoch.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Sequence, Set

import numpy as np

from repro.serve.common import SystemClock


def partition_batches(batch_ids: Sequence[int], num_hosts: int,
                      host: int) -> List[int]:
    """Deterministic strided lease (stable under elastic host-count change)."""
    return [int(b) for i, b in enumerate(batch_ids) if i % num_hosts == host]


class WorkQueue:
    """In-memory work-stealing queue (single-process stand-in for the
    coordinator service; the API is what a real deployment would back with
    etcd/redis)."""

    def __init__(self, batch_ids: Sequence[int], num_hosts: int):
        self.leases: Dict[int, List[int]] = {
            h: partition_batches(batch_ids, num_hosts, h)
            for h in range(num_hosts)}
        self._lock = threading.Lock()
        self.stolen = 0
        self.reassigned = 0

    def reassign(self, dead: Sequence[int]) -> int:
        """Move every dead host's remaining lease onto the survivors,
        round-robin (DESIGN.md §12). Returns the number of batches moved.
        The dead hosts' lease keys are removed so work-stealing never
        selects them as victims; determinism holds: for a fixed (batch_ids,
        num_hosts, dead set) every host computes the same reassignment."""
        with self._lock:
            gone = [h for h in dead if h in self.leases]
            survivors = sorted(h for h in self.leases if h not in gone)
            if not survivors:
                raise RuntimeError(
                    f"cannot reassign leases: all hosts dead ({list(dead)})")
            moved = 0
            for h in gone:
                for b in self.leases.pop(h):
                    self.leases[survivors[moved % len(survivors)]].append(b)
                    moved += 1
            self.reassigned += moved
            return moved

    def next_batch(self, host: int) -> Optional[int]:
        with self._lock:
            if self.leases[host]:
                return self.leases[host].pop(0)
            # steal from the host with the most remaining work
            victim = max(self.leases, key=lambda h: len(self.leases[h]))
            if self.leases[victim]:
                self.stolen += 1
                return self.leases[victim].pop()   # steal from the tail
            return None

    def remaining(self) -> int:
        with self._lock:
            return sum(len(v) for v in self.leases.values())


class Heartbeats:
    """Host liveness registry. ``clock`` is any object with a monotonic
    ``now()`` (the serving tier's injectable-clock idiom, DESIGN.md §11) so
    timeout behavior is testable with a FakeClock instead of sleeps."""

    def __init__(self, timeout_s: float = 60.0, clock=None):
        self.timeout_s = timeout_s
        # SystemClock.now is monotonic: a wall-clock (time.time) default
        # would declare every host dead across an NTP step backward/DST
        # jump; liveness timeouts must never depend on calendar time
        clock = clock if clock is not None else SystemClock()
        self._now = clock.now
        self._last: Dict[int, float] = {}
        self._lock = threading.Lock()

    def beat(self, host: int) -> None:
        with self._lock:
            self._last[host] = self._now()

    def dead_hosts(self) -> List[int]:
        now = self._now()
        with self._lock:
            return [h for h, t in self._last.items()
                    if now - t > self.timeout_s]


class ElasticCoordinator:
    """Epoch-boundary crash handling (DESIGN.md §12), built on the two
    primitives above: hosts ``beat`` between batches; ``epoch_queue``
    folds ``dead_hosts()`` into the epoch's :class:`WorkQueue` and
    re-leases a crashed host's batches to the survivors via ``reassign``.
    Death is sticky — a host that missed its timeout once stays out until
    ``revive`` (a rejoin is an elastic restart, not a heartbeat)."""

    def __init__(self, num_hosts: int, timeout_s: float = 60.0, clock=None):
        self.num_hosts = int(num_hosts)
        self.heartbeats = Heartbeats(timeout_s, clock=clock)
        self.dead: Set[int] = set()
        self.reassigned_total = 0

    def beat(self, host: int) -> None:
        if host not in self.dead:
            self.heartbeats.beat(host)

    def live_hosts(self) -> List[int]:
        return [h for h in range(self.num_hosts) if h not in self.dead]

    def revive(self, host: int) -> None:
        self.dead.discard(host)
        self.heartbeats.beat(host)

    def epoch_queue(self, batch_ids: Sequence[int]) -> WorkQueue:
        """Build this epoch's work queue with every known-dead host's lease
        already reassigned — the epoch runs over the FULL batch list no
        matter who died last epoch."""
        self.dead.update(self.heartbeats.dead_hosts())
        q = WorkQueue(batch_ids, self.num_hosts)
        if self.dead:
            self.reassigned_total += q.reassign(sorted(self.dead))
        return q

    def snapshot(self) -> Dict:
        return {"num_hosts": self.num_hosts, "dead": sorted(self.dead),
                "reassigned_total": self.reassigned_total}
