"""Elastic scaling + straggler mitigation over PRECOMPUTED batches.

IBMB's determinism is the enabler: the epoch's work is a fixed list of batch
IDs, so distribution questions become pure metadata:

* `partition_batches(ids, num_hosts, host)` — deterministic round-robin lease
  of batch IDs to hosts. On elastic restart with a different host count the
  same call re-partitions — no resharding of data, no sampler state.
* `WorkQueue` — per-epoch work-stealing queue: hosts lease batches; when a
  host finishes its lease it steals from the slowest host's remaining lease.
  Gradient all-reduce stays synchronous; stealing only rebalances the DATA
  path, so a straggling host's disk/NIC can't stall the epoch beyond one
  batch.
* a heartbeat registry with `dead_hosts()` so the coordinator can reassign a
  crashed host's lease at the next epoch boundary (checkpoint/restart covers
  mid-epoch loss of model state).
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional, Sequence

import numpy as np


def partition_batches(batch_ids: Sequence[int], num_hosts: int,
                      host: int) -> List[int]:
    """Deterministic strided lease (stable under elastic host-count change)."""
    return [int(b) for i, b in enumerate(batch_ids) if i % num_hosts == host]


class WorkQueue:
    """In-memory work-stealing queue (single-process stand-in for the
    coordinator service; the API is what a real deployment would back with
    etcd/redis)."""

    def __init__(self, batch_ids: Sequence[int], num_hosts: int):
        self.leases: Dict[int, List[int]] = {
            h: partition_batches(batch_ids, num_hosts, h)
            for h in range(num_hosts)}
        self._lock = threading.Lock()
        self.stolen = 0

    def next_batch(self, host: int) -> Optional[int]:
        with self._lock:
            if self.leases[host]:
                return self.leases[host].pop(0)
            # steal from the host with the most remaining work
            victim = max(self.leases, key=lambda h: len(self.leases[h]))
            if self.leases[victim]:
                self.stolen += 1
                return self.leases[victim].pop()   # steal from the tail
            return None

    def remaining(self) -> int:
        with self._lock:
            return sum(len(v) for v in self.leases.values())


class Heartbeats:
    def __init__(self, timeout_s: float = 60.0):
        self.timeout_s = timeout_s
        self._last: Dict[int, float] = {}
        self._lock = threading.Lock()

    def beat(self, host: int) -> None:
        with self._lock:
            self._last[host] = time.time()

    def dead_hosts(self) -> List[int]:
        now = time.time()
        with self._lock:
            return [h for h, t in self._last.items()
                    if now - t > self.timeout_s]
