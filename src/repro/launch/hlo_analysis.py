"""Trip-count-aware HLO cost analysis.

XLA's built-in `compiled.cost_analysis()` counts every `while` body ONCE —
with scan-over-layers (and chunked-attention / chunked-xent inner scans) that
undercounts FLOPs, bytes and collective traffic by the trip count. The
optimized HLO carries `backend_config={"known_trip_count":{"n":"N"}}` on
while ops, so we parse the module and accumulate costs recursively:

  cost(computation) = Σ_op local(op) + Σ_while trip·cost(body∪cond)
                      + Σ_fusion/call cost(called)       [flops only]

Local costs:
  * dot: 2 · prod(output dims) · prod(lhs contracting dims)
  * elementwise arithmetic: prod(output dims)
  * bytes: operands + outputs at fusion/op boundaries (fusion internals are
    on-chip and not counted — mirrors XLA's fusion-aware accounting)
  * collectives: output bytes × ring factor (all-reduce 2x, others 1x),
    multiplied through enclosing trip counts.

Shapes in an SPMD-partitioned module are PER-DEVICE, so all results are
per-chip per-step — exactly what the roofline terms need.
"""
from __future__ import annotations

import json
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "s32": 4, "s16": 2, "s8": 1,
                "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1, "c64": 8,
                "c128": 16, "s4": 1, "u4": 1}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\](?:\{[^}]*\})?")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_CALL_RE = re.compile(r"(?:calls|body|condition|to_apply)=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*?(\d+)')
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "exponential", "log", "tanh", "rsqrt", "sqrt", "negate", "abs", "and",
    "or", "xor", "compare", "select", "convert", "floor", "ceil", "sign",
    "cosine", "sine", "logistic", "atan2", "remainder", "clamp",
    "exponential-minus-one", "log-plus-one", "cbrt", "erf",
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_COLL_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}


def _shapes_bytes(sig: str) -> int:
    """Sum byte sizes of all typed shapes in a string (handles tuples)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(sig):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(sig: str) -> int:
    m = _SHAPE_RE.search(sig)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


class _Op:
    __slots__ = ("name", "opcode", "out_sig", "operands", "calls", "trip",
                 "line", "contracting")

    def __init__(self, name, opcode, out_sig, operands, calls, trip, line,
                 contracting):
        self.name = name
        self.opcode = opcode
        self.out_sig = out_sig
        self.operands = operands
        self.calls = calls
        self.trip = trip
        self.line = line
        self.contracting = contracting


def _parse_module(text: str) -> Tuple[Dict[str, List[_Op]], Dict[str, Dict[str, str]], Optional[str]]:
    """Returns (computations, shape tables, entry name)."""
    comps: Dict[str, List[_Op]] = {}
    shapes: Dict[str, Dict[str, str]] = {}
    entry = None
    cur = None
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        # computation header: `%name (p: t, ...) -> t {` or `ENTRY %name ...{`
        if s.endswith("{") and ("(" in s) and ("=" not in s.split("(")[0]):
            m = re.match(r"(ENTRY\s+)?%?([\w.\-]+)\s*\(", s)
            if m:
                cur = m.group(2)
                comps[cur] = []
                shapes[cur] = {}
                if m.group(1):
                    entry = cur
                # parameter shapes from the signature
                for pm in re.finditer(r"%?([\w.\-]+):\s*((?:\([^)]*\))|(?:\w+\[[0-9,]*\](?:\{[^}]*\})?))", s):
                    shapes[cur][pm.group(1)] = pm.group(2)
            continue
        if s == "}" or s.startswith("}"):
            continue
        if cur is None:
            continue
        m = _OP_RE.match(s)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        # out signature = leading type expr
        sig_m = re.match(r"((?:\([^)]*\))|(?:\w+\[[0-9,]*\](?:\{[^}]*\})?))\s+([\w\-]+)", rest)
        if not sig_m:
            continue
        out_sig, opcode = sig_m.group(1), sig_m.group(2)
        operands = re.findall(r"%([\w.\-]+)", rest[sig_m.end():].split("),")[0]
                              if opcode != "fusion" else rest[sig_m.end():])
        # operand list: inside the first (...) after opcode
        par = rest[sig_m.end():]
        pi = par.find("(")
        ops_list = []
        if pi >= 0:
            depth = 0
            j = pi
            for j in range(pi, len(par)):
                if par[j] == "(":
                    depth += 1
                elif par[j] == ")":
                    depth -= 1
                    if depth == 0:
                        break
            ops_list = re.findall(r"%([\w.\-]+)", par[pi:j + 1])
        calls = _CALL_RE.findall(rest)
        trip_m = _TRIP_RE.search(rest)
        trip = int(trip_m.group(1)) if trip_m else None
        con_m = _CONTRACT_RE.search(rest)
        contracting = [int(x) for x in con_m.group(1).split(",") if x] \
            if con_m else []
        comps[cur].append(_Op(name, opcode, out_sig, ops_list, calls, trip,
                              s, contracting))
        shapes[cur][name] = out_sig
    return comps, shapes, entry


def _dims(sig: str) -> List[int]:
    m = _SHAPE_RE.search(sig)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


class HLOAnalysis:
    def __init__(self, text: str):
        self.comps, self.shapes, self.entry = _parse_module(text)
        self._memo: Dict[str, Dict[str, float]] = {}

    def _local_flops(self, comp: str, op: _Op) -> float:
        if op.opcode == "dot":
            out = _dims(op.out_sig)
            out_elems = 1
            for d in out:
                out_elems *= d
            k = 1
            if op.operands:
                lhs_sig = self.shapes[comp].get(op.operands[0], "")
                ld = _dims(lhs_sig)
                for c in op.contracting:
                    if c < len(ld):
                        k *= ld[c]
            return 2.0 * out_elems * k
        if op.opcode in _ELEMENTWISE:
            return float(_shape_elems(op.out_sig))
        if op.opcode in ("reduce", "reduce-window"):
            # approx: one flop per input element
            if op.operands:
                in_sig = self.shapes[comp].get(op.operands[0], op.out_sig)
                return float(_shape_elems(in_sig))
            return float(_shape_elems(op.out_sig))
        return 0.0

    def _local_bytes(self, comp: str, op: _Op) -> float:
        oc = op.opcode
        if oc in ("tuple", "get-tuple-element", "parameter", "constant",
                  "bitcast", "while", "conditional", "call", "reshape",
                  "iota", "after-all", "partition-id", "replica-id"):
            return 0.0
        out_b = _shapes_bytes(op.out_sig)
        # Sliced/gathered reads touch only the OUTPUT-sized region of the
        # operand, not the whole buffer (a scan slicing (L, d, f) stacked
        # params reads d·f per step, not L·d·f).
        if oc in ("dynamic-slice", "slice", "gather", "broadcast"):
            return float(2 * out_b)
        if oc in ("dynamic-update-slice",):
            # in-place on TPU: read+write the update region only
            upd = _shapes_bytes(self.shapes[comp].get(op.operands[1], "")) \
                if len(op.operands) > 1 else out_b
            return float(2 * upd)
        if oc in ("scatter",):
            upd = _shapes_bytes(self.shapes[comp].get(op.operands[-1], "")) \
                if op.operands else out_b
            return float(2 * upd + out_b)
        total = out_b
        for o in op.operands:
            total += _shapes_bytes(self.shapes[comp].get(o, ""))
        return float(total)

    def _fusion_bytes(self, comp: str, op: _Op) -> float:
        """Fusion boundary bytes, but an operand whose ONLY use inside the
        fused computation is a slicing op (dynamic-slice/gather/slice) is
        charged at the slice size, not the full buffer — XLA fuses scan
        param-slicing into consumers and only the slice crosses HBM."""
        callee = op.calls[0]
        body = self.comps.get(callee, [])
        shapes = self.shapes.get(callee, {})
        # parameter name -> index order as declared
        params = [o for o in body if o.opcode == "parameter"]
        # map param name -> charged bytes
        charged: Dict[str, float] = {}
        for i, pop in enumerate(params):
            full = _shapes_bytes(pop.out_sig)
            uses = [o for o in body if pop.name in o.operands]
            if uses and all(u.opcode in ("dynamic-slice", "slice", "gather")
                            and u.operands and u.operands[0] == pop.name
                            for u in uses):
                charged[pop.name] = float(
                    sum(_shapes_bytes(u.out_sig) for u in uses))
            else:
                charged[pop.name] = float(full)
        total = float(_shapes_bytes(op.out_sig))
        for i, o in enumerate(op.operands):
            if i < len(params):
                total += charged[params[i].name]
            else:
                total += _shapes_bytes(self.shapes[comp].get(o, ""))
        return total

    def cost(self, comp: Optional[str] = None) -> Dict[str, float]:
        comp = comp or self.entry
        if comp in self._memo:
            return self._memo[comp]
        res = {"flops": 0.0, "bytes": 0.0, "collective_bytes": 0.0,
               "coll": {k: 0.0 for k in _COLLECTIVES}}
        self._memo[comp] = res  # guard cycles
        for op in self.comps.get(comp, []):
            if op.opcode == "while":
                trip = op.trip if op.trip is not None else 1
                for callee in op.calls:
                    sub = self.cost(callee)
                    res["flops"] += trip * sub["flops"]
                    res["bytes"] += trip * sub["bytes"]
                    res["collective_bytes"] += trip * sub["collective_bytes"]
                    for k in _COLLECTIVES:
                        res["coll"][k] += trip * sub["coll"][k]
            elif op.opcode in ("fusion", "call", "conditional", "custom-call",
                               "reduce", "sort", "map", "scatter", "select-and-scatter"):
                # flops descend into called computations; bytes at boundary
                if op.opcode == "fusion" and op.calls:
                    res["bytes"] += self._fusion_bytes(comp, op)
                else:
                    res["bytes"] += self._local_bytes(comp, op)
                if op.opcode == "reduce":
                    res["flops"] += self._local_flops(comp, op)
                for callee in op.calls:
                    sub = self.cost(callee)
                    res["flops"] += sub["flops"]
                    res["collective_bytes"] += sub["collective_bytes"]
                    for k in _COLLECTIVES:
                        res["coll"][k] += sub["coll"][k]
            elif any(op.opcode.startswith(c) for c in _COLLECTIVES):
                base = op.opcode
                for c in _COLLECTIVES:
                    if op.opcode.startswith(c):
                        base = c
                        break
                nbytes = _shapes_bytes(op.out_sig) * _COLL_FACTOR[base]
                res["collective_bytes"] += nbytes
                res["coll"][base] += nbytes
                res["bytes"] += self._local_bytes(comp, op)
            else:
                res["flops"] += self._local_flops(comp, op)
                res["bytes"] += self._local_bytes(comp, op)
        return res

    def entry_cost(self) -> Dict[str, float]:
        out = dict(self.cost(self.entry))
        out["coll"] = dict(out["coll"])
        return out


def analyze_hlo(text: str) -> Dict[str, float]:
    return HLOAnalysis(text).entry_cost()


def xla_cost_analysis(compiled) -> Dict[str, float]:
    """`compiled.cost_analysis()` normalized across jaxlib versions: older
    releases return a one-element list of dicts (one per executable), newer
    ones return the dict directly."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost
