import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell and
extract memory/cost/collective analysis for the roofline (EXPERIMENTS.md).

  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b \
      --shape train_4k --mesh single --out results/dryrun

Cells and meshes:
  * mesh "single"  = (data=16, model=16), 256 chips — roofline source.
  * mesh "multi"   = (pod=2, data=16, model=16), 512 chips — proves the pod
    axis shards.
  * --arch all --shape all runs every applicable cell (long_500k only for
    sub-quadratic archs).

Everything is abstract (ShapeDtypeStruct): no parameter or cache memory is
ever allocated; only XLA compilation happens on this host.
"""
import argparse
import dataclasses
import json
import re
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, ARCH_IDS
from repro.configs.shapes import SHAPES, ShapeSpec, shape_applies
from repro.dist.logical import logical_rules
from repro.dist.sharding import (
    param_spec, opt_spec, cache_spec, batch_spec, tree_shardings,
    with_shardings, logical_rules_for)
from repro.launch.mesh import make_production_mesh
from repro.models.lm import (
    LMConfig, abstract_params, abstract_cache, lm_loss, decode_step, lm_forward)
from repro.models.lm.model import head_logits
from repro.optim.optimizers import get_optimizer

# ---------------------------------------------------------- hardware constants
PEAK_FLOPS = 197e12        # bf16 per chip (TPU v5e)
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 45e9              # bytes/s effective per link (assignment: ~50 GB/s)


def optimizer_for(arch: str) -> str:
    """Adafactor for ≥100B params (optimizer state must stay sub-HBM)."""
    return "adafactor" if arch in ("deepseek-v3-671b", "command-r-plus-104b") \
        else "adamw"


# ------------------------------------------------------------------ input specs
def input_specs(cfg: LMConfig, shape: ShapeSpec, mesh) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    mk = lambda shp, dt, name: jax.ShapeDtypeStruct(
        shp, dt, sharding=NamedSharding(mesh, batch_spec(mesh, name, shp)))
    if shape.kind in ("train", "prefill"):
        if cfg.num_codebooks > 1:
            toks = mk((b, s, cfg.num_codebooks), jnp.int32, "tokens")
        else:
            toks = mk((b, s), jnp.int32, "tokens")
        batch = {"tokens": toks, "loss_mask": mk((b, s), jnp.float32, "loss_mask")}
        if cfg.vision_prefix_len:
            batch["prefix_embeds"] = mk(
                (b, cfg.vision_prefix_len, cfg.d_model), jnp.dtype(cfg.dtype),
                "prefix_embeds")
        return batch
    # decode: one new token against a seq_len cache
    if cfg.num_codebooks > 1:
        toks = mk((b, 1, cfg.num_codebooks), jnp.int32, "tokens")
    else:
        toks = mk((b, 1), jnp.int32, "tokens")
    return {"tokens": toks,
            "pos": jax.ShapeDtypeStruct((), jnp.int32,
                                        sharding=NamedSharding(mesh, P()))}


# ------------------------------------------------------------------- step fns
def make_train_step(cfg: LMConfig, opt):
    def train_step(params, opt_state, batch, lr):
        loss, grads = jax.value_and_grad(
            lambda p: lm_loss(cfg, p, batch, remat=True))(params)
        updates, opt_state = opt.update(grads, opt_state, params, lr)
        new_params = jax.tree_util.tree_map(
            lambda p, u: (p + u).astype(p.dtype), params, updates)
        return new_params, opt_state, loss
    return train_step


def make_prefill_step(cfg: LMConfig):
    def prefill(params, batch):
        prefix = batch.get("prefix_embeds")
        h = lm_forward(cfg, params, batch["tokens"], prefix_embeds=prefix,
                       remat=False)
        return head_logits(cfg, params, h[:, -1])
    return prefill


def make_decode_step(cfg: LMConfig):
    def serve_step(params, cache, batch):
        logits, cache = decode_step(cfg, params, cache, batch["tokens"],
                                    batch["pos"])
        return logits, cache
    return serve_step


# ---------------------------------------------------------- collective parsing
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"\b(f32|f16|bf16|s32|u32|s8|u8|pred|f64|s64|c64)\[([0-9,]*)\]")
_BYTES = {"f32": 4, "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "s8": 1,
          "u8": 1, "pred": 1, "f64": 8, "s64": 8, "c64": 8}
# ring all-reduce moves ~2x the buffer; others ~1x
_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
           "all-to-all": 1.0, "collective-permute": 1.0}


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum output-shape bytes of every collective op (per-device shapes in the
    SPMD-partitioned module), weighted by a ring-cost factor."""
    out = {k: 0.0 for k in _COLLECTIVES}
    count = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r".*= *((?:\([^)]*\)|\S+)) (all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)", ls)
        if not m:
            continue
        op = m.group(2)
        # parse every typed shape on the lhs (handles tuple outputs)
        lhs = ls.split("=")[0] + "=" + m.group(1)
        nbytes = 0
        for t, dims in _SHAPE_RE.findall(m.group(1)):
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _BYTES[t]
        out[op] += nbytes * _FACTOR[op]
        count[op] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = count
    return out


def save_hlo(hlo_text: str, out_dir: str, tag: str) -> None:
    """Store the partitioned HLO (zstd) so roofline re-analysis after parser
    improvements never needs a recompile."""
    try:
        import zstandard as zstd
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, tag + ".hlo.zst"), "wb") as f:
            f.write(zstd.ZstdCompressor(level=3).compress(hlo_text.encode()))
    except Exception:
        pass


# ------------------------------------------------------------------ GNN cells
# The paper's own model, distributed the IBMB way: every chip processes its
# own precomputed padded batch (pure DP over the flattened mesh — batches are
# independent by construction), gradients all-reduced. Shapes follow the
# products-like synthetic config at production padding.
GNN_SHAPE = dict(max_nodes=8192, max_edges=131072, max_outputs=1024,
                 feat_dim=100, num_classes=47, hidden=256, layers=3)


def run_gnn_cell(arch: str, mesh_kind: str, verbose: bool = True,
                 hlo_dir: Optional[str] = None) -> Dict[str, Any]:
    from repro.models.gnn.models import (
        GNNConfig, init_gnn, gnn_apply, output_logits, masked_xent)
    kind = arch.split("-", 1)[1]
    g = GNN_SHAPE
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = int(np.prod(list(mesh.shape.values())))
    all_axes = tuple(mesh.axis_names)
    nb = n_chips                        # one IBMB batch per chip per step
    cfg = GNNConfig(kind=kind, in_dim=g["feat_dim"], hidden=g["hidden"],
                    out_dim=g["num_classes"], num_layers=g["layers"],
                    dtype=os.environ.get("REPRO_GNN_DTYPE", "float32"))

    params_abs = jax.eval_shape(
        lambda k: __import__("repro.models.gnn.models", fromlist=["init_gnn"])
        .init_gnn(cfg, k), jax.random.PRNGKey(0))
    rep = NamedSharding(mesh, P())
    params_in = jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=rep),
        params_abs)

    def field(shape, dt):
        return jax.ShapeDtypeStruct(
            (nb,) + shape, dt,
            sharding=NamedSharding(mesh, P(all_axes)))
    batch = {
        "edge_src": field((g["max_edges"],), jnp.int32),
        "edge_dst": field((g["max_edges"],), jnp.int32),
        "edge_weight": field((g["max_edges"],), jnp.float32),
        "node_mask": field((g["max_nodes"],), jnp.float32),
        "output_idx": field((g["max_outputs"],), jnp.int32),
        "output_mask": field((g["max_outputs"],), jnp.float32),
        "features": field((g["max_nodes"], g["feat_dim"]), jnp.float32),
        "labels": field((g["max_outputs"],), jnp.int32),
    }
    opt = get_optimizer("adamw")
    opt_abs = jax.eval_shape(opt.init, params_abs)
    opt_in = jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=rep),
        opt_abs)
    lr = jax.ShapeDtypeStruct((), jnp.float32, sharding=rep)

    def loss_fn(p, b):
        def one(b1):
            h = gnn_apply(cfg, p, b1)
            lg = output_logits(h, b1)
            return masked_xent(lg, b1["labels"], b1["output_mask"])
        return jax.vmap(one)(b).mean()

    if os.environ.get("REPRO_GNN_SHMAP", "0") == "1":
        # §Perf C1: IBMB batches are independent by construction — shard_map
        # makes each chip compute ITS batch locally and psum only gradients.
        # The vmap/SPMD baseline loses the batch sharding through the
        # (NB·nodes, F) reshape inside dot lowering and replicates all
        # batches' compute on every chip.
        from jax import shard_map
        from jax.sharding import PartitionSpec as P2

        def local_grads(p, b):
            b1 = jax.tree_util.tree_map(lambda x: x[0], b)   # my one batch
            h = gnn_apply(cfg, p, b1)
            lg = output_logits(h, b1)
            loss = masked_xent(lg, b1["labels"], b1["output_mask"])
            loss, grads = jax.value_and_grad(
                lambda q: masked_xent(output_logits(gnn_apply(cfg, q, b1), b1),
                                      b1["labels"], b1["output_mask"]))(p)
            grads = jax.tree_util.tree_map(
                lambda g: jax.lax.pmean(g, all_axes), grads)
            return jax.lax.pmean(loss, all_axes), grads

        sm = shard_map(local_grads, mesh=mesh,
                       in_specs=(P2(), P2(all_axes)),
                       out_specs=(P2(), P2()), check_vma=False)

        def train_step(p, s, b, lr):
            loss, grads = sm(p, b)
            u, s = opt.update(grads, s, p, lr)
            p = jax.tree_util.tree_map(
                lambda a, x: (a + x).astype(a.dtype), p, u)
            return p, s, loss
    else:
        def train_step(p, s, b, lr):
            loss, grads = jax.value_and_grad(loss_fn)(p, b)
            u, s = opt.update(grads, s, p, lr)
            p = jax.tree_util.tree_map(
                lambda a, x: (a + x).astype(a.dtype), p, u)
            return p, s, loss

    t0 = time.time()
    with mesh:
        lowered = jax.jit(train_step, donate_argnums=(0, 1)).lower(
            params_in, opt_in, batch, lr)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    return _finish(arch, "train_products", mesh_kind, n_chips, cfg, None,
                   compiled, t_lower, t_compile, hlo_dir,
                   model_flops_override=_gnn_model_flops(g, nb))


def _gnn_model_flops(g, nb) -> float:
    """Useful FLOPs: 3 layers of (node matmul + edge aggregation), fwd+bwd."""
    dense = g["max_nodes"] * (g["feat_dim"] * g["hidden"] +
                              g["hidden"] * g["hidden"] +
                              g["hidden"] * g["num_classes"])
    agg = g["max_edges"] * (g["hidden"] * 2 + g["num_classes"])
    return float(nb * (2 * dense + 2 * agg) * 3)     # ×3 fwd+bwd


# ----------------------------------------------------------------------- cell
def run_cell(arch: str, shape_name: str, mesh_kind: str,
             verbose: bool = True, hlo_dir: Optional[str] = None) -> Dict[str, Any]:
    if arch.startswith("gnn-"):
        return run_gnn_cell(arch, mesh_kind, verbose=verbose, hlo_dir=hlo_dir)
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if not shape_applies(cfg, shape):
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "skipped": "full-attention arch, long_500k needs sub-quadratic"}
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()

    params_abs = abstract_params(cfg)
    params_sh = tree_shardings(mesh, params_abs, param_spec)
    params_in = with_shardings(params_abs, params_sh)
    batch = input_specs(cfg, shape, mesh)
    rules = logical_rules_for(cfg, mesh)

    with mesh, logical_rules(rules, mesh=mesh):
        if shape.kind == "train":
            opt = get_optimizer(optimizer_for(arch))
            opt_abs = jax.eval_shape(opt.init, params_abs)
            opt_sh = tree_shardings(
                mesh, opt_abs, lambda m, p, l: opt_spec(m, p, l, {}))
            opt_in = with_shardings(opt_abs, opt_sh)
            lr = jax.ShapeDtypeStruct((), jnp.float32,
                                      sharding=NamedSharding(mesh, P()))
            step = make_train_step(cfg, opt)
            lowered = jax.jit(step, donate_argnums=(0, 1)).lower(
                params_in, opt_in, batch, lr)
        elif shape.kind == "prefill":
            step = make_prefill_step(cfg)
            lowered = jax.jit(step).lower(params_in, batch)
        else:  # decode
            cache_abs = abstract_cache(cfg, shape.global_batch, shape.seq_len)
            cache_sh = tree_shardings(mesh, cache_abs, cache_spec)
            cache_in = with_shardings(cache_abs, cache_sh)
            step = make_decode_step(cfg)
            lowered = jax.jit(step, donate_argnums=(1,)).lower(
                params_in, cache_in, batch)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    return _finish(arch, shape_name, mesh_kind, n_chips, cfg, shape, compiled,
                   t_lower, t_compile, hlo_dir, verbose=verbose)


def _finish(arch, shape_name, mesh_kind, n_chips, cfg, shape, compiled,
            t_lower, t_compile, hlo_dir, model_flops_override=None,
            verbose=False) -> Dict[str, Any]:
    from repro.launch.hlo_analysis import analyze_hlo, xla_cost_analysis
    mem = compiled.memory_analysis()
    cost = xla_cost_analysis(compiled)
    hlo_text = compiled.as_text()
    if hlo_dir:
        save_hlo(hlo_text, hlo_dir, f"{arch}__{shape_name}__{mesh_kind}")
    # trip-count-aware accounting (XLA's cost_analysis counts scan bodies once)
    hlo = analyze_hlo(hlo_text)
    flops = float(hlo["flops"])              # per chip per step
    bytes_hbm = float(hlo["bytes"])
    coll_total = float(hlo["collective_bytes"])

    # MODEL_FLOPS: 6·N_active·tokens (train), 2·N_active·tokens (fwd-only)
    if model_flops_override is not None:
        model_flops = model_flops_override
        n_active = params_n = None
    else:
        n_active = cfg.active_param_count()
        params_n = cfg.param_count()
        if shape.kind == "train":
            tokens = shape.global_batch * shape.seq_len
            model_flops = 6.0 * n_active * tokens
        elif shape.kind == "prefill":
            tokens = shape.global_batch * shape.seq_len
            model_flops = 2.0 * n_active * tokens
        else:
            model_flops = 2.0 * n_active * shape.global_batch  # 1 token/seq
    model_flops_chip = model_flops / n_chips

    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "chips": n_chips,
        "params": params_n, "active_params": n_active,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": (getattr(mem, "argument_size_in_bytes", 0) or 0) +
                          (getattr(mem, "temp_size_in_bytes", 0) or 0),
        },
        "cost_xla_once": {"flops": float(cost.get("flops", 0.0)),
                          "bytes_accessed": float(cost.get("bytes accessed", 0.0))},
        "hlo": {"flops": flops, "bytes": bytes_hbm,
                "collective_bytes": coll_total,
                "collectives": hlo["coll"]},
        "model_flops_per_chip": model_flops_chip,
        "useful_ratio": model_flops_chip / flops if flops else None,
        "roofline": {
            "compute_s": flops / PEAK_FLOPS,
            "memory_s": bytes_hbm / HBM_BW,
            "collective_s": coll_total / ICI_BW,
        },
    }
    r = result["roofline"]
    dom = max(("compute_s", "memory_s", "collective_s"), key=lambda k: r[k])
    result["roofline"]["dominant"] = dom
    # roofline fraction: useful compute time / bound time
    bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
    result["roofline"]["model_compute_s"] = model_flops_chip / PEAK_FLOPS
    result["roofline"]["roofline_fraction"] = \
        (model_flops_chip / PEAK_FLOPS) / bound if bound else None
    if verbose:
        print(json.dumps(result, indent=2, default=str))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    os.makedirs(args.out, exist_ok=True)
    for arch in archs:
        arch_shapes = ["train_products"] if arch.startswith("gnn-") else shapes
        for shape in arch_shapes:
            for mesh_kind in meshes:
                tag = f"{arch}__{shape}__{mesh_kind}".replace("/", "_")
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    print(f"[skip] {tag} (cached)")
                    continue
                print(f"[cell] {tag}")
                try:
                    res = run_cell(arch, shape, mesh_kind, verbose=False,
                                   hlo_dir=os.path.join(args.out, "hlo"))
                except Exception as e:  # record failures — they are bugs
                    import traceback
                    res = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-3000:]}
                    print(res["error"])
                with open(path, "w") as f:
                    json.dump(res, f, indent=2, default=str)
                if "roofline" in res:
                    r = res["roofline"]
                    print(f"  compute {r['compute_s']:.3e}s  memory {r['memory_s']:.3e}s  "
                          f"collective {r['collective_s']:.3e}s  → {r['dominant']}")


if __name__ == "__main__":
    main()
