"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state. Single pod: (data=16, model=16) = 256 chips;
multi-pod: (pod=2, data=16, model=16) = 512 chips. The "model" axis carries
TP/EP/SP; "data" (+"pod") carries DP/FSDP. Inter-pod traffic crosses DCN-ish
links, so the sharding policy keeps only data-parallel gradient reduction on
the "pod" axis.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = 1
    for s in shape:
        need *= s
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, found {len(devices)} — "
            "launch via launch/dryrun.py which sets "
            "XLA_FLAGS=--xla_force_host_platform_device_count before jax init")
    import numpy as np
    dev_array = np.array(devices[:need]).reshape(shape)
    return jax.sharding.Mesh(dev_array, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for unit tests (requires ≥4 emulated devices)."""
    import numpy as np
    need = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(f"test mesh needs {need} devices")
    return jax.sharding.Mesh(np.array(devices[:need]).reshape(shape), axes)


# Single source of truth for the DP-axis policy lives in the sharding layer.
from repro.dist.sharding import data_axes  # noqa: E402,F401
