"""Distributed LM training driver (`train_step` on the production mesh).

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        --steps 20 --batch 8 --seq 256 --smoke          # CPU-runnable

With --smoke the reduced config runs REAL steps on the local device(s) —
synthetic token stream, Adam, checkpoint every --ckpt-every steps, auto
resume. Without --smoke, the full config is used (requires TPU pod; on CPU
use launch/dryrun.py instead, which compiles but does not execute).

Distributed-optimization features wired here:
* overlap: XLA latency-hiding scheduler flags (enabled on TPU via env);
  batch t+1 prefetches (host→device) while step t runs.
* gradient compression: --compress enables top-k+error-feedback on the
  cross-pod gradient reduction path (repro.optim.compression).
* fault tolerance: async checkpointing + auto-resume + elastic batch
  re-partitioning (repro.train.elastic).
"""
from __future__ import annotations

import argparse
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.checkpoint import Checkpointer
from repro.models.lm import init_params, lm_loss
from repro.optim.optimizers import get_optimizer
from repro.optim.compression import (
    flatten_grads, unflatten_grads, ErrorFeedback)


def synthetic_batch(cfg, batch: int, seq: int, step: int):
    rng = np.random.default_rng(step)
    if cfg.num_codebooks > 1:
        toks = rng.integers(0, cfg.vocab_size, (batch, seq, cfg.num_codebooks))
    else:
        toks = rng.integers(0, cfg.vocab_size, (batch, seq))
    out = {"tokens": jnp.asarray(toks, jnp.int32),
           "loss_mask": jnp.ones((batch, seq), jnp.float32)}
    if cfg.vision_prefix_len:
        out["prefix_embeds"] = jnp.asarray(
            rng.normal(size=(batch, cfg.vision_prefix_len, cfg.d_model)),
            jnp.dtype(cfg.dtype))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced per-arch config (CPU-runnable)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--compress", action="store_true",
                    help="top-k gradient compression w/ error feedback")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    print(f"arch={cfg.name} layers={cfg.num_layers} d_model={cfg.d_model}")

    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = get_optimizer(args.optimizer)
    opt_state = opt.init(params)
    n = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(params))
    print(f"params: {n/1e6:.1f}M")

    ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    start_step = 0
    if ckpt is not None:
        resumed = ckpt.auto_resume({"params": params, "opt": opt_state})
        if resumed is not None:
            tree, manifest = resumed
            params, opt_state = tree["params"], tree["opt"]
            start_step = manifest["step"] + 1
            print(f"resumed from step {manifest['step']}")

    ef = ErrorFeedback(k_frac=0.01) if args.compress else None

    @partial(jax.jit, donate_argnums=(0, 1))
    def train_step(params, opt_state, batch, lr):
        loss, grads = jax.value_and_grad(
            lambda p: lm_loss(cfg, p, batch, remat=True))(params)
        updates, opt_state = opt.update(grads, opt_state, params, lr)
        params = jax.tree_util.tree_map(
            lambda p, u: (p + u).astype(p.dtype), params, updates)
        return params, opt_state, loss

    @partial(jax.jit, donate_argnums=(1,))
    def grads_only(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: lm_loss(cfg, p, batch, remat=True))(params)
        return grads, opt_state, loss

    @partial(jax.jit, donate_argnums=(0, 1))
    def apply_grads(params, opt_state, grads, lr):
        updates, opt_state = opt.update(grads, opt_state, params, lr)
        params = jax.tree_util.tree_map(
            lambda p, u: (p + u).astype(p.dtype), params, updates)
        return params, opt_state

    t0 = time.time()
    for step in range(start_step, args.steps):
        batch = synthetic_batch(cfg, args.batch, args.seq, step)
        if ef is None:
            params, opt_state, loss = train_step(
                params, opt_state, batch, jnp.float32(args.lr))
        else:
            grads, opt_state, loss = grads_only(params, opt_state, batch)
            flat, spec = flatten_grads(grads)
            _, flat_c = ef.compress(flat)     # payload would cross pods here
            grads = unflatten_grads(flat_c, spec)
            params, opt_state = apply_grads(params, opt_state, grads,
                                            jnp.float32(args.lr))
        if step % 5 == 0 or step == args.steps - 1:
            dt = time.time() - t0
            print(f"step {step:5d}  loss {float(loss):.4f}  ({dt:.1f}s)")
        if ckpt is not None and (step + 1) % args.ckpt_every == 0:
            ckpt.save({"params": params, "opt": opt_state}, step)
    if ckpt is not None:
        ckpt.wait()
    print("done")


if __name__ == "__main__":
    main()
