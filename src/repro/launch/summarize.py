"""Summarize results/dryrun/*.json into the EXPERIMENTS.md roofline tables.

    PYTHONPATH=src python -m repro.launch.summarize [--dir results/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List


def load(dir_: str) -> List[Dict]:
    out = []
    for p in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(p) as f:
            out.append(json.load(f))
    return out


def fmt_bytes(b) -> str:
    if b is None:
        return "-"
    return f"{b/1e9:.1f}"


def roofline_table(cells: List[Dict], mesh: str = "single") -> str:
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "useful | roofline frac | HBM GB/chip | compile s |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for d in cells:
        if d.get("mesh") != mesh:
            continue
        tag = f"| {d['arch']} | {d['shape']} "
        if "skipped" in d:
            lines.append(tag + "| — | — | — | skipped (full-attn, needs sub-quadratic) | — | — | — | — |")
            continue
        if "error" in d:
            lines.append(tag + f"| — | — | — | ERROR {d['error'][:40]} | — | — | — | — |")
            continue
        r = d["roofline"]
        lines.append(
            tag + f"| {r['compute_s']:.2e} | {r['memory_s']:.2e} | "
            f"{r['collective_s']:.2e} | {r['dominant'].replace('_s','')} | "
            f"{(d.get('useful_ratio') or 0):.2f} | "
            f"{(r.get('roofline_fraction') or 0):.4f} | "
            f"{fmt_bytes(d['memory']['peak_bytes'])} | "
            f"{d.get('compile_s','-')} |")
    return "\n".join(lines)


def multi_pod_proof(cells: List[Dict]) -> str:
    ok = sum(1 for d in cells if d.get("mesh") == "multi" and "roofline" in d)
    skip = sum(1 for d in cells if d.get("mesh") == "multi" and "skipped" in d)
    err = [d for d in cells if d.get("mesh") == "multi" and "error" in d]
    lines = [f"multi-pod (2×16×16 = 512 chips): {ok} cells compiled, "
             f"{skip} skipped (sub-quadratic rule), {len(err)} errors."]
    for d in err:
        lines.append(f"  ERROR {d['arch']}×{d['shape']}: {d['error'][:120]}")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    cells = load(args.dir)
    print(roofline_table(cells, args.mesh))
    print()
    print(multi_pod_proof(cells))


if __name__ == "__main__":
    main()
