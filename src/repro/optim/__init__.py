from repro.optim.optimizers import (
    adam, adamw, adagrad, adafactor, sgd, Optimizer, OptState, apply_updates,
)
from repro.optim.schedules import ReduceLROnPlateau, cosine_schedule, linear_warmup_cosine
from repro.optim.accumulate import GradAccumulator
from repro.optim.compression import topk_compress, topk_decompress, ErrorFeedback, quantize_int8, dequantize_int8

__all__ = [
    "adam", "adamw", "adagrad", "adafactor", "sgd", "Optimizer", "OptState",
    "apply_updates", "ReduceLROnPlateau", "cosine_schedule",
    "linear_warmup_cosine", "GradAccumulator",
    "topk_compress", "topk_decompress", "ErrorFeedback",
    "quantize_int8", "dequantize_int8",
]
