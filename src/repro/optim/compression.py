"""Gradient compression for slow inter-pod links (distributed-optimization
trick for the 1000+-node deployment; see DESIGN.md §6).

* top-k sparsification with error feedback (Stich et al.): transmit the k
  largest-magnitude entries, accumulate the residual locally so nothing is
  lost in expectation.
* int8 stochastic-free linear quantization for dense all-reduce payloads.

Both are jit-safe pure functions over flat vectors; `repro.train` wires them
around the cross-pod all-reduce when `grad_compression` is enabled.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class TopKPayload(NamedTuple):
    indices: jnp.ndarray   # (k,) int32
    values: jnp.ndarray    # (k,) float32
    size: int              # static


def topk_compress(flat: jnp.ndarray, k: int) -> TopKPayload:
    k = min(k, flat.shape[0])
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    return TopKPayload(idx.astype(jnp.int32), flat[idx], flat.shape[0])


def topk_decompress(payload: TopKPayload) -> jnp.ndarray:
    out = jnp.zeros((payload.size,), payload.values.dtype)
    return out.at[payload.indices].set(payload.values)


class ErrorFeedback:
    """e_{t+1} = (g + e_t) − decompress(compress(g + e_t)); the transmitted
    payload is compress(g + e_t)."""

    def __init__(self, k_frac: float = 0.01):
        self.k_frac = k_frac
        self._residual = None

    def compress(self, flat: jnp.ndarray) -> Tuple[TopKPayload, jnp.ndarray]:
        if self._residual is None:
            self._residual = jnp.zeros_like(flat)
        corrected = flat + self._residual
        k = max(1, int(self.k_frac * flat.shape[0]))
        payload = topk_compress(corrected, k)
        self._residual = corrected - topk_decompress(payload)
        return payload, topk_decompress(payload)


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    scale = jnp.maximum(jnp.abs(x).max(), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def flatten_grads(grads: Any) -> Tuple[jnp.ndarray, Any]:
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    shapes = [l.shape for l in leaves]
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])
    return flat, (treedef, shapes)


def unflatten_grads(flat: jnp.ndarray, spec: Any) -> Any:
    treedef, shapes = spec
    out, off = [], 0
    for s in shapes:
        n = 1
        for d in s:
            n *= d
        out.append(flat[off:off + n].reshape(s))
        off += n
    return jax.tree_util.tree_unflatten(treedef, out)
