"""LR schedules. ReduceLROnPlateau is the paper's scheduler (App. B: factor
0.33, patience 30, min_lr 1e-4, cooldown 10, on validation loss)."""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class ReduceLROnPlateau:
    lr: float = 1e-3
    factor: float = 0.33
    patience: int = 30
    min_lr: float = 1e-4
    cooldown: int = 10
    best: float = float("inf")
    bad_epochs: int = 0
    cooldown_left: int = 0

    def step(self, metric: float) -> float:
        """Call once per epoch with the validation loss; returns current lr."""
        if metric < self.best - 1e-12:
            self.best = metric
            self.bad_epochs = 0
        elif self.cooldown_left > 0:
            self.cooldown_left -= 1
        else:
            self.bad_epochs += 1
            if self.bad_epochs > self.patience:
                self.lr = max(self.lr * self.factor, self.min_lr)
                self.bad_epochs = 0
                self.cooldown_left = self.cooldown
        return self.lr


def cosine_schedule(base_lr: float, total_steps: int, min_frac: float = 0.1):
    def fn(step: int) -> float:
        t = min(step / max(total_steps, 1), 1.0)
        return base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + np.cos(np.pi * t)))
    return fn


def linear_warmup_cosine(base_lr: float, warmup: int, total_steps: int,
                         min_frac: float = 0.0):
    cos = cosine_schedule(base_lr, max(total_steps - warmup, 1), min_frac)
    def fn(step: int) -> float:
        if step < warmup:
            return base_lr * (step + 1) / warmup
        return cos(step - warmup)
    return fn
