"""Optimizers from scratch (no optax on this box).

The paper's training recipe depends on ADAPTIVE optimization: fixed IBMB
batches give sparse, correlated gradients, and Sec. 4 argues (via the
consensus-constraint/primal-dual view) that momentum + adaptivity suppress
the induced oscillations. Adam is the paper's optimizer; Adagrad included as
the classic sparse-gradient method; Adafactor added for the 671B-scale arch
(factored 2nd moment ⇒ optimizer state ≪ params).

API: ``opt = adam(); state = opt.init(params);``
``updates, state = opt.update(grads, state, params, lr)``;
``params = apply_updates(params, updates)``. lr is a traced scalar so
ReduceLROnPlateau can change it without recompilation.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

OptState = Any
PyTree = Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], OptState]
    update: Callable[[PyTree, OptState, PyTree, jnp.ndarray], Tuple[PyTree, OptState]]
    name: str = "opt"


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree_util.tree_map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def sgd(momentum: float = 0.0) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return {"step": jnp.zeros((), jnp.int32)}
        return {"step": jnp.zeros((), jnp.int32),
                "mu": jax.tree_util.tree_map(jnp.zeros_like, params)}

    def update(grads, state, params, lr):
        if momentum == 0.0:
            return jax.tree_util.tree_map(lambda g: -lr * g, grads), \
                {"step": state["step"] + 1}
        mu = jax.tree_util.tree_map(lambda m, g: momentum * m + g, state["mu"], grads)
        upd = jax.tree_util.tree_map(lambda m: -lr * m, mu)
        return upd, {"step": state["step"] + 1, "mu": mu}

    return Optimizer(init, update, "sgd")


def adam(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0) -> Optimizer:
    """Adam with optional L2 (coupled, as the paper's 'L2 regularization')."""

    def init(params):
        z = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return {"step": jnp.zeros((), jnp.int32),
                "m": jax.tree_util.tree_map(z, params),
                "v": jax.tree_util.tree_map(z, params)}

    def update(grads, state, params, lr):
        step = state["step"] + 1
        if weight_decay > 0.0:
            grads = jax.tree_util.tree_map(
                lambda g, p: g + weight_decay * p.astype(g.dtype), grads, params)
        m = jax.tree_util.tree_map(
            lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32), state["m"], grads)
        v = jax.tree_util.tree_map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["v"], grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        upd = jax.tree_util.tree_map(
            lambda m_, v_: -lr * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps), m, v)
        return upd, {"step": step, "m": m, "v": v}

    return Optimizer(init, update, "adam")


def adamw(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.01) -> Optimizer:
    base = adam(b1, b2, eps, 0.0)

    def update(grads, state, params, lr):
        upd, state = base.update(grads, state, params, lr)
        upd = jax.tree_util.tree_map(
            lambda u, p: u - lr * weight_decay * p.astype(u.dtype), upd, params)
        return upd, state

    return Optimizer(base.init, update, "adamw")


def adagrad(eps: float = 1e-10) -> Optimizer:
    def init(params):
        return {"step": jnp.zeros((), jnp.int32),
                "acc": jax.tree_util.tree_map(
                    lambda p: jnp.zeros_like(p, jnp.float32), params)}

    def update(grads, state, params, lr):
        acc = jax.tree_util.tree_map(
            lambda a, g: a + jnp.square(g.astype(jnp.float32)), state["acc"], grads)
        upd = jax.tree_util.tree_map(
            lambda g, a: -lr * g.astype(jnp.float32) / (jnp.sqrt(a) + eps), grads, acc)
        return upd, {"step": state["step"] + 1, "acc": acc}

    return Optimizer(init, update, "adagrad")


def adafactor(decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0) -> Optimizer:
    """Adafactor (factored second moment, no first moment) — the optimizer
    state for a (a, b) matrix is a + b floats instead of 2·a·b. Used for the
    671B config so optimizer state fits HBM (see DESIGN.md §4)."""

    def _factored(shape) -> bool:
        return len(shape) >= 2

    def init(params):
        def per_leaf(p):
            if _factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros_like(p, jnp.float32)}
        return {"step": jnp.zeros((), jnp.int32),
                "slots": jax.tree_util.tree_map(per_leaf, params,
                                                is_leaf=lambda x: hasattr(x, "shape"))}

    def update(grads, state, params, lr):
        step = state["step"] + 1
        beta = 1.0 - (step.astype(jnp.float32)) ** (-decay)

        def per_leaf(g, slot):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + eps
            if "vr" in slot:
                vr = beta * slot["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * slot["vc"] + (1 - beta) * g2.mean(axis=-2)
                denom = jnp.sqrt(
                    vr[..., None] * vc[..., None, :] /
                    jnp.maximum(vr.mean(axis=-1, keepdims=True)[..., None], eps))
                u = g32 / jnp.maximum(denom, eps)
                new_slot = {"vr": vr, "vc": vc}
            else:
                v = beta * slot["v"] + (1 - beta) * g2
                u = g32 / jnp.sqrt(v)
                new_slot = {"v": v}
            # update clipping (RMS)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + eps)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return -lr * u, new_slot

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_s = treedef.flatten_up_to(state["slots"])
        outs = [per_leaf(g, s) for g, s in zip(flat_g, flat_s)]
        upd = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
        slots = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
        return upd, {"step": step, "slots": slots}

    return Optimizer(init, update, "adafactor")


def get_optimizer(name: str, weight_decay: float = 0.0) -> Optimizer:
    if name == "adam":
        return adam(weight_decay=weight_decay)
    if name == "adamw":
        return adamw(weight_decay=weight_decay or 0.01)
    if name == "adagrad":
        return adagrad()
    if name == "adafactor":
        return adafactor()
    if name == "sgd":
        return sgd(momentum=0.9)
    raise ValueError(f"unknown optimizer {name}")
