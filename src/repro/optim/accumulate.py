"""Gradient accumulation (paper Fig. 8: accumulating over up to the whole
epoch barely changes IBMB convergence — we reproduce that ablation)."""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp


class GradAccumulator:
    """Host-side accumulator over jit boundaries.

    Usage:
        acc = GradAccumulator(every=k)
        g = acc.add(grads)          # returns averaged grads every k-th call, else None
    """

    def __init__(self, every: int = 1):
        self.every = max(1, every)
        self._buf: Optional[Any] = None
        self._count = 0

    def add(self, grads):
        if self.every == 1:
            return grads
        if self._buf is None:
            self._buf = grads
        else:
            self._buf = jax.tree_util.tree_map(jnp.add, self._buf, grads)
        self._count += 1
        if self._count >= self.every:
            out = jax.tree_util.tree_map(lambda g: g / self._count, self._buf)
            self._buf, self._count = None, 0
            return out
        return None

    def flush(self):
        if self._buf is None:
            return None
        out = jax.tree_util.tree_map(lambda g: g / self._count, self._buf)
        self._buf, self._count = None, 0
        return out
