"""Atomic artifact-write helpers (DESIGN.md §12).

Every persisted artifact in this repo — plan headers, store indices,
checkpoints, bench-trajectory JSONs — must be published with the
tmp + ``os.replace`` idiom so readers see the old file or the new one,
never a truncated in-between. These helpers are the one sanctioned home
for that idiom; the ``atomic-write`` rule of ``repro.analysis`` flags
plain write-mode ``open()`` calls on artifact paths that do not flow
through here (DESIGN.md §15).
"""
from __future__ import annotations

import json
import os
from typing import Any


def atomic_write_text(path: str, text: str) -> None:
    """tmp + os.replace publish: crash-safe, single-file, same-directory
    (os.replace is only atomic within a filesystem)."""
    tmp = path + ".tmp"
    try:
        with open(tmp, "w") as f:
            f.write(text)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        try:
            os.remove(tmp)
        except OSError:
            pass
        raise


def atomic_write_json(path: str, obj: Any, **dump_kwargs: Any) -> None:
    """Serialize first, publish once — a json.dump that dies mid-stream
    never leaves a half-written artifact behind."""
    atomic_write_text(path, json.dumps(obj, **dump_kwargs))


def atomic_savez(path: str, **arrays: Any) -> None:
    """np.savez with the same tmp + os.replace publish."""
    import numpy as np

    tmp = path + ".tmp"
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **arrays)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.remove(tmp)
        except OSError:
            pass
        raise
