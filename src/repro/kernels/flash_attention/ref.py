"""Pure-jnp oracle: plain masked attention (materializes S×S — tests only)."""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  causal: bool = True, window: int = 0,
                  scale: float | None = None) -> jnp.ndarray:
    """q (B, H, S, D), k/v (B, H, S, D) → (B, H, S, D).

    window > 0 ⇒ sliding-window attention: position i sees [i-window+1, i].
    """
    b, h, s, d = q.shape
    scale = scale if scale is not None else d ** -0.5
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    qi = jnp.arange(s)[:, None]
    ki = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= ki <= qi
    if window > 0:
        mask &= ki > qi - window
    logits = jnp.where(mask, logits, -1e30)
    p = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v)
