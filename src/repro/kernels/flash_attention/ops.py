"""Public flash-attention API with impl switch.

"reference" materializes S×S (tests / tiny shapes). The XLA-level flash path
used by the dry-run on CPU is `repro.models.lm.attention.chunked_attention`
(same online-softmax math as the kernel, expressed with lax.scan so the
compiled HLO never holds an S×S buffer).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.flash_attention.flash_attention import flash_attention_pallas


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, window: int = 0,
                    impl: str = "reference", block_q: int = 128,
                    block_k: int = 128) -> jnp.ndarray:
    if impl == "reference":
        return attention_ref(q, k, v, causal=causal, window=window)
    if impl == "pallas":
        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      block_q=block_q, block_k=block_k)
    if impl == "interpret":
        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      block_q=block_q, block_k=block_k,
                                      interpret=True)
    raise ValueError(f"unknown impl {impl}")
