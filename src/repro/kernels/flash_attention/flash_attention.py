"""Blockwise causal flash attention — Pallas TPU kernel.

Grid (B·H, Sq/BQ, Sk/BK): the innermost KV dimension streams key/value blocks
through VMEM while the (BQ, D) output block and the (BQ,) running max/denom
live in VMEM scratch across the revisits (online softmax). Causal and
sliding-window masks are applied per block; fully-masked blocks are skipped
cheaply via `pl.when` on the block indices (block-level skipping gives the
2× causal FLOP saving and turns sliding-window attention into O(S·W)).

VMEM budget per step: q (BQ·D) + k,v (2·BK·D) + out (BQ·D) + p (BQ·BK)
≈ 4·128·128·4B ≈ 260 KB at the default tile sizes — comfortably inside the
~16 MB v5e VMEM, leaving room for double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, out_ref, m_ref, l_ref, acc_ref,
            *, scale, causal, window, block_q, block_k, seq_len):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    # block-level skip: causal ⇒ skip blocks entirely above the diagonal;
    # window ⇒ skip blocks entirely left of the window
    run = True
    if causal:
        run = k_start <= q_start + block_q - 1
    if window > 0:
        run = jnp.logical_and(run, k_start + block_k - 1 > q_start - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)           # (BQ, D)
        k = k_ref[0].astype(jnp.float32)           # (BK, D)
        v = v_ref[0].astype(jnp.float32)           # (BK, D)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= k_pos <= q_pos
        if window > 0:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                        # (BQ,)
        m_cur = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_cur[:, None])
        alpha = jnp.exp(m_prev - m_cur)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + \
            jnp.dot(p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_cur

    @pl.when(ki == nk - 1)
    def _finalize():
        out_ref[0] = (acc_ref[...] /
                      jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "interpret"))
def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                           causal: bool = True, window: int = 0,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = False) -> jnp.ndarray:
    """q, k, v: (B, H, S, D) → (B, H, S, D). H = q heads (GQA expansion is the
    caller's job). D and S must be multiples of the tile sizes."""
    b, h, s, d = q.shape
    bq, bk = min(block_q, s), min(block_k, s)
    assert s % bq == 0 and s % bk == 0
    scale = d ** -0.5
    grid = (b * h, s // bq, s // bk)

    qf = q.reshape(b * h, s, d)
    kf = k.reshape(b * h, s, d)
    vf = v.reshape(b * h, s, d)

    kern = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        block_q=bq, block_k=bk, seq_len=s)

    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),      # running max
            pltpu.VMEM((bq,), jnp.float32),      # running denom
            pltpu.VMEM((bq, d), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, s, d)
