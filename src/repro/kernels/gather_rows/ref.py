"""Pure-jnp oracle for the row-gather kernel."""
import jax.numpy as jnp


def gather_rows_ref(table: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """out[i] = table[idx[i]]. table (N, F), idx (M,) int32 → (M, F)."""
    return table[idx]
