from repro.kernels.gather_rows.ops import gather_rows

__all__ = ["gather_rows"]
