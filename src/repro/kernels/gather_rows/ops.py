"""Public gather API with impl switch.

This is the STANDALONE gather. The bcsr aggregation path no longer calls
it followed by a separate SpMM — `repro.kernels.spmm.fused` fuses the row
gather into the SpMM so feature tiles stream HBM→VMEM once (DESIGN.md
§14); this module remains the kernel for gathers that stand alone
(embedding lookups, the micro-bench row).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.gather_rows.ref import gather_rows_ref
from repro.kernels.gather_rows.gather_rows import gather_rows_pallas


def gather_rows(table: jnp.ndarray, idx: jnp.ndarray,
                impl: str = "reference", block_f: int = 512) -> jnp.ndarray:
    if impl == "reference":
        return gather_rows_ref(table, idx)
    if impl == "pallas":
        return gather_rows_pallas(table, idx, block_f=block_f, interpret=False)
    if impl == "interpret":
        return gather_rows_pallas(table, idx, block_f=block_f, interpret=True)
    raise ValueError(f"unknown impl {impl}")
