"""Row-gather Pallas TPU kernel (batch assembly from the feature table).

IBMB assembles a batch by gathering the features of its node set from the
big (N, F) table. On TPU the natural formulation is an indexed DMA: the index
vector is a scalar-prefetch operand, and each grid step copies one
(block_rows, F) stripe whose source row is chosen by the prefetched index —
HBM→VMEM→HBM streaming with zero compute, bounded VMEM (2·block·F floats).

We gather `block_rows` rows per grid step by flattening the index into a
(M/block, block) layout and letting the x BlockSpec pick a single source row
per inner step: block_rows=1 stripes of shape (1, F). For larger F the F axis
is tiled too.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _kernel(idx_ref, table_ref, out_ref):
    out_ref[...] = table_ref[...]


@functools.partial(jax.jit, static_argnames=("block_f", "interpret"))
def gather_rows_pallas(table: jnp.ndarray, idx: jnp.ndarray,
                       block_f: int = 512, interpret: bool = False) -> jnp.ndarray:
    n, f = table.shape
    m = idx.shape[0]
    bf = min(block_f, f)
    assert f % bf == 0, f"feature dim {f} % block_f {bf} != 0"
    grid = (m, f // bf)

    return pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[pl.BlockSpec((1, bf), lambda i, fi, idx: (idx[i], fi))],
            out_specs=pl.BlockSpec((1, bf), lambda i, fi, idx: (i, fi)),
        ),
        out_shape=jax.ShapeDtypeStruct((m, f), table.dtype),
        interpret=interpret,
    )(idx, table)
