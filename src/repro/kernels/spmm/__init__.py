from repro.kernels.spmm.ops import spmm_bcsr, spmm_bcsr_sym, csr_to_bcsr, BCSR

__all__ = ["spmm_bcsr", "spmm_bcsr_sym", "csr_to_bcsr", "BCSR"]
