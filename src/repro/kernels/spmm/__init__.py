from repro.kernels.spmm.fused import spmm_bcsr_fused_pallas, spmm_bcsr_stream
from repro.kernels.spmm.ops import spmm_bcsr, spmm_bcsr_sym, csr_to_bcsr, BCSR

__all__ = ["spmm_bcsr", "spmm_bcsr_sym", "csr_to_bcsr", "BCSR",
           "spmm_bcsr_fused_pallas", "spmm_bcsr_stream"]
