from repro.kernels.spmm.ops import spmm_bcsr, csr_to_bcsr, BCSR

__all__ = ["spmm_bcsr", "csr_to_bcsr", "BCSR"]
