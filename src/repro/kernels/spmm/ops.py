"""Public SpMM API: host-side CSR→BCSR conversion + impl-switched wrapper.

This is the aggregation-backend boundary (DESIGN.md §7): preprocessing emits
the padded block-CSR layout once per batch via ``csr_to_bcsr`` (vectorized —
O(nnz log nnz) lexsort, no Python loop over nonzeros, so the conversion stays
amortizable like the rest of IBMB preprocessing), and the GNN hot loop calls
``spmm_bcsr`` / ``spmm_bcsr_sym`` every step.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.spmm.fused import spmm_bcsr_fused_pallas, spmm_bcsr_stream
from repro.kernels.spmm.ref import spmm_bcsr_ref
from repro.kernels.spmm.spmm import spmm_bcsr_pallas


@dataclasses.dataclass
class BCSR:
    """Padded block-CSR: every row-tile holds exactly K tile slots (zero tiles
    pad). Block size B is MXU-native 128 by default."""
    tile_cols: np.ndarray   # (R, K) int32
    tile_vals: np.ndarray   # (R, K, B, B) float32
    num_rows: int
    num_cols: int

    @property
    def block(self) -> int:
        return self.tile_vals.shape[-1]

    def with_pad_k(self, pad_k: int) -> "BCSR":
        """Pad every row-tile to exactly `pad_k` slots (all-zero tiles at
        col-tile 0) — the ONE place K-padding lives, used both by the
        csr_to_bcsr pad_k arg and by build_batches when stacking batches
        into a shared-shape cache."""
        k = self.tile_cols.shape[1]
        if pad_k < k:
            raise ValueError(f"pad_k={pad_k} < required K={k}")
        if pad_k == k:
            return self
        return BCSR(
            np.pad(self.tile_cols, ((0, 0), (0, pad_k - k))),
            np.pad(self.tile_vals, ((0, 0), (0, pad_k - k), (0, 0), (0, 0))),
            self.num_rows, self.num_cols)

    def density_stats(self) -> dict:
        nz_tiles = int((np.abs(self.tile_vals).sum(axis=(2, 3)) > 0).sum())
        r, k, b, _ = self.tile_vals.shape
        return dict(row_tiles=r, max_tiles_per_row=k, nonzero_tiles=nz_tiles,
                    tile_fill=float(np.count_nonzero(self.tile_vals)) /
                              max(nz_tiles * b * b, 1))


def csr_to_bcsr(indptr: np.ndarray, indices: np.ndarray, weights: np.ndarray,
                num_rows: int, num_cols: int, block: int = 128,
                pad_k: Optional[int] = None) -> BCSR:
    """Host-side conversion (preprocessing time — amortized like the paper's
    batch cache). Rows/cols are padded up to a multiple of `block`.

    Vectorized (DESIGN.md §7): entries are bucketed into (row_tile, col_tile)
    keys with one stable argsort; tile slots and in-tile offsets then come
    from ``np.unique`` + searchsorted arithmetic, so the cost is
    O(nnz log nnz) regardless of tile population. Explicit zero entries
    (e.g. masked/padded edges) are dropped — they carry no aggregation mass
    and would only deflate tile fill.

    pad_k: pad every row-tile to exactly `pad_k` slots (so batches built
    separately can be stacked into one contiguous cache array).
    """
    rpad = (num_rows + block - 1) // block * block
    cpad = (num_cols + block - 1) // block * block
    r_tiles, c_tiles = rpad // block, cpad // block

    counts = np.diff(np.asarray(indptr, dtype=np.int64))
    rows = np.repeat(np.arange(num_rows, dtype=np.int64), counts)
    cols = np.asarray(indices, dtype=np.int64)
    data = np.asarray(weights, dtype=np.float32)
    nz = data != 0
    rows, cols, data = rows[nz], cols[nz], data[nz]

    if len(rows) == 0:
        return BCSR(np.zeros((r_tiles, 1), np.int32),
                    np.zeros((r_tiles, 1, block, block), np.float32),
                    rpad, cpad).with_pad_k(max(pad_k or 1, 1))

    rt, ct = rows // block, cols // block
    key = rt * c_tiles + ct
    order = np.argsort(key, kind="stable")
    key_s = key[order]
    uniq, entry_tile = np.unique(key_s, return_inverse=True)
    tile_r = uniq // c_tiles                      # (T,) row-tile of each tile
    tile_c = uniq % c_tiles                       # (T,) col-tile of each tile
    # slot of each tile within its row-tile (tiles sorted ⇒ contiguous rows)
    row_first = np.searchsorted(tile_r, np.arange(r_tiles))
    slot = np.arange(len(uniq)) - row_first[tile_r]
    k = int(slot.max()) + 1

    tile_cols = np.zeros((r_tiles, k), np.int32)
    tile_cols[tile_r, slot] = tile_c
    tile_vals = np.zeros((r_tiles, k, block, block), np.float32)
    # scatter-add (duplicate (i,j) within a tile accumulates, matching CSR
    # sum_duplicates semantics)
    np.add.at(tile_vals,
              (tile_r[entry_tile], slot[entry_tile],
               rows[order] % block, cols[order] % block),
              data[order])
    out = BCSR(tile_cols, tile_vals, rpad, cpad)
    return out if pad_k is None else out.with_pad_k(pad_k)


def spmm_bcsr(bcsr_cols: jnp.ndarray, bcsr_vals: jnp.ndarray, x: jnp.ndarray,
              impl: str = "reference", block_f: int = 128) -> jnp.ndarray:
    """out = A @ x.

    impl: "fused" (TPU, gather fused into the SpMM's DMA — DESIGN.md §14),
    "pallas" (TPU, unfused tile kernel), "stream" (compiled off-TPU
    production path, O(R·B·F) peak memory), "reference" (pure jnp oracle,
    materializes the (R, K, B, F) gather), "interpret"/"fused_interpret"
    (the Pallas kernels CPU-validated in interpret mode).
    """
    r = bcsr_vals.shape[0]
    if impl == "reference":
        return spmm_bcsr_ref(bcsr_cols, bcsr_vals, x, r)
    if impl == "stream":
        return spmm_bcsr_stream(bcsr_cols, bcsr_vals, x)
    if impl == "pallas":
        return spmm_bcsr_pallas(bcsr_cols, bcsr_vals, x, block_f=block_f,
                                interpret=False)
    if impl == "interpret":
        return spmm_bcsr_pallas(bcsr_cols, bcsr_vals, x, block_f=block_f,
                                interpret=True)
    if impl == "fused":
        return spmm_bcsr_fused_pallas(bcsr_cols, bcsr_vals, x,
                                      block_f=block_f, interpret=False)
    if impl == "fused_interpret":
        return spmm_bcsr_fused_pallas(bcsr_cols, bcsr_vals, x,
                                      block_f=block_f, interpret=True)
    raise ValueError(f"unknown impl {impl}")


@partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def spmm_bcsr_sym(bcsr_cols: jnp.ndarray, bcsr_vals: jnp.ndarray,
                  x: jnp.ndarray, impl: str = "reference",
                  block_f: int = 128) -> jnp.ndarray:
    """``A @ x`` for a SYMMETRIC block-CSR ``A`` — differentiable wrt ``x``.

    Raw ``pallas_call`` has no transpose rule, so training cannot backprop
    through ``spmm_bcsr`` directly. For the IBMB batch adjacency A is
    symmetric by construction (undirected graph + symmetric normalization,
    preserved by induced subgraphs and by batch-local reordering PAPᵀ — see
    DESIGN.md §7), hence ∂L/∂x = Aᵀ g = A g: the backward pass is the SAME
    kernel on the cotangent. ``build_batches`` verifies the symmetry before
    emitting tiles.
    """
    return spmm_bcsr(bcsr_cols, bcsr_vals, x, impl=impl, block_f=block_f)


def _spmm_sym_fwd(bcsr_cols, bcsr_vals, x, impl, block_f):
    out = spmm_bcsr(bcsr_cols, bcsr_vals, x, impl=impl, block_f=block_f)
    return out, (bcsr_cols, bcsr_vals)


def _spmm_sym_bwd(impl, block_f, res, g):
    bcsr_cols, bcsr_vals = res
    dx = spmm_bcsr(bcsr_cols, bcsr_vals, g, impl=impl, block_f=block_f)
    # tiles are preprocessing constants: cols is int (float0 cotangent),
    # vals gets a symbolic zero that XLA dead-code-eliminates.
    return (np.zeros(bcsr_cols.shape, dtype=jax.dtypes.float0),
            jnp.zeros_like(bcsr_vals), dx)


spmm_bcsr_sym.defvjp(_spmm_sym_fwd, _spmm_sym_bwd)
