"""Public SpMM API: host-side CSR→BCSR conversion + impl-switched wrapper."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.spmm.ref import spmm_bcsr_ref
from repro.kernels.spmm.spmm import spmm_bcsr_pallas


@dataclasses.dataclass
class BCSR:
    """Padded block-CSR: every row-tile holds exactly K tile slots (zero tiles
    pad). Block size B is MXU-native 128 by default."""
    tile_cols: np.ndarray   # (R, K) int32
    tile_vals: np.ndarray   # (R, K, B, B) float32
    num_rows: int
    num_cols: int

    @property
    def block(self) -> int:
        return self.tile_vals.shape[-1]

    def density_stats(self) -> dict:
        nz_tiles = int((np.abs(self.tile_vals).sum(axis=(2, 3)) > 0).sum())
        r, k, b, _ = self.tile_vals.shape
        return dict(row_tiles=r, max_tiles_per_row=k, nonzero_tiles=nz_tiles,
                    tile_fill=float(np.count_nonzero(self.tile_vals)) /
                              max(nz_tiles * b * b, 1))


def csr_to_bcsr(indptr: np.ndarray, indices: np.ndarray, weights: np.ndarray,
                num_rows: int, num_cols: int, block: int = 128) -> BCSR:
    """Host-side conversion (preprocessing time — amortized like the paper's
    batch cache). Rows/cols are padded up to a multiple of `block`."""
    import scipy.sparse as sp
    rpad = (num_rows + block - 1) // block * block
    cpad = (num_cols + block - 1) // block * block
    m = sp.csr_matrix((weights, indices, indptr), shape=(num_rows, num_cols))
    m = sp.csr_matrix((m.data, m.indices, m.indptr), shape=(rpad, cpad)) \
        if num_rows == rpad else sp.vstack(
            [m, sp.csr_matrix((rpad - num_rows, num_cols))]).tocsr()
    m.resize((rpad, cpad))
    coo = m.tocoo()
    rt, ct = coo.row // block, coo.col // block
    tiles = {}
    for r, c, i, j, v in zip(rt, ct, coo.row % block, coo.col % block, coo.data):
        tiles.setdefault((int(r), int(c)), []).append((int(i), int(j), float(v)))
    r_tiles = rpad // block
    per_row: list = [[] for _ in range(r_tiles)]
    for (r, c), entries in sorted(tiles.items()):
        per_row[r].append((c, entries))
    k = max(1, max((len(p) for p in per_row), default=1))
    tile_cols = np.zeros((r_tiles, k), np.int32)
    tile_vals = np.zeros((r_tiles, k, block, block), np.float32)
    for r, plist in enumerate(per_row):
        for s, (c, entries) in enumerate(plist):
            tile_cols[r, s] = c
            for i, j, v in entries:
                tile_vals[r, s, i, j] = v
    return BCSR(tile_cols, tile_vals, rpad, cpad)


def spmm_bcsr(bcsr_cols: jnp.ndarray, bcsr_vals: jnp.ndarray, x: jnp.ndarray,
              impl: str = "reference", block_f: int = 128) -> jnp.ndarray:
    """out = A @ x. impl: "pallas" (TPU), "interpret" (CPU-validated Pallas),
    "reference" (pure jnp oracle)."""
    r = bcsr_vals.shape[0]
    if impl == "reference":
        return spmm_bcsr_ref(bcsr_cols, bcsr_vals, x, r)
    if impl == "pallas":
        return spmm_bcsr_pallas(bcsr_cols, bcsr_vals, x, block_f=block_f,
                                interpret=False)
    if impl == "interpret":
        return spmm_bcsr_pallas(bcsr_cols, bcsr_vals, x, block_f=block_f,
                                interpret=True)
    raise ValueError(f"unknown impl {impl}")
