"""Fused gather+SpMM: features stream through VMEM once (DESIGN.md §14).

The unfused pipeline (``ref.spmm_bcsr_ref``) materializes the gathered
operand ``x[tile_cols]`` — an (R, K, B, F) array, K× the size of the batch
feature matrix — before a single multiply runs. That is exactly the access
pattern DGL fuses in ``gather_mm.cu``: the gather is an *address
computation*, not a tensor, so fuse it into the SpMM's operand fetch.

Two implementations share this contract, ``out = A @ x`` over padded
block-CSR tiles, without ever materializing the gathered matrix:

* ``spmm_bcsr_fused_pallas`` — the TPU kernel. ``x`` stays in HBM
  (``memory_space=ANY``); the kernel loops over a row-tile's K column
  tiles, issuing an explicit ``make_async_copy`` per (B, BF) feature
  stripe into a double-buffered VMEM scratch, overlapping the next
  stripe's DMA with the current MXU ``dot``. Each feature stripe crosses
  VMEM exactly once per consuming tile; the (R, K, B, F) intermediate
  never exists. Validated in interpret mode on CPU (tier-1/CI).

* ``spmm_bcsr_stream`` — the compiled off-TPU production path: a
  ``lax.scan`` over tile slots whose carry is the (R, B, F) accumulator.
  Per step it gathers ONE (R, B, F) operand slice and contracts it — peak
  memory O(R·B·F) instead of O(R·K·B·F), and it is ordinary XLA, so it
  jits fast, runs at compiled speed (the previous CPU fallback ran the
  Pallas kernel in *interpret* mode — the reason bcsr lost to segment),
  and partitions cleanly inside ``shard_map`` bodies (DESIGN.md §9).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def spmm_bcsr_stream(tile_cols: jnp.ndarray, tile_vals: jnp.ndarray,
                     x: jnp.ndarray) -> jnp.ndarray:
    """out = A @ x, streaming one tile slot at a time.

    tile_cols: (R, K) int32; tile_vals: (R, K, B, B); x: (C·B, F).
    Returns (R·B, F). Bitwise-deterministic: the K slots accumulate in
    slot order, matching the Pallas kernels' innermost-K accumulation.
    """
    r, k, b, _ = tile_vals.shape
    f = x.shape[1]
    # device arrays throughout: callers outside jit hand in host numpy, and
    # the scan body fancy-indexes xt with a traced carry index
    tile_cols, tile_vals = jnp.asarray(tile_cols), jnp.asarray(tile_vals)
    xt = jnp.asarray(x).reshape(-1, b, f)           # (C, B, F) view

    def step(acc, slot):
        cols_k, vals_k = slot                       # (R,), (R, B, B)
        acc = acc + jnp.einsum("rij,rjf->rif", vals_k, xt[cols_k],
                               preferred_element_type=acc.dtype)
        return acc, None

    init = jnp.zeros((r, b, f), x.dtype)
    acc, _ = jax.lax.scan(
        step, init, (tile_cols.T, jnp.swapaxes(tile_vals, 0, 1)))
    return acc.reshape(r * b, f)


def _fused_kernel(k, b, bf, nbuf,
                  cols_ref, vals_ref, x_any, out_ref, xbuf, sem):
    ri = pl.program_id(0)
    fi = pl.program_id(1)

    def stripe_copy(ki, slot):
        # the gather, fused: an indexed DMA of x's (B, BF) stripe for
        # column tile cols[ri, ki] straight from HBM into VMEM scratch
        c = cols_ref[ri, ki]
        return pltpu.make_async_copy(
            x_any.at[pl.ds(c * b, b), pl.ds(fi * bf, bf)],
            xbuf.at[slot], sem.at[slot])

    stripe_copy(0, 0).start()

    def body(ki, acc):
        slot = jax.lax.rem(ki, nbuf)

        @pl.when(ki + 1 < k)
        def _prefetch():                 # overlap next DMA with this dot
            stripe_copy(ki + 1, jax.lax.rem(ki + 1, nbuf)).start()

        stripe_copy(ki, slot).wait()
        return acc + jnp.dot(vals_ref[0, ki], xbuf[slot],
                             preferred_element_type=jnp.float32)

    acc = jax.lax.fori_loop(0, k, body, jnp.zeros((b, bf), jnp.float32))
    out_ref[...] = acc.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_f", "interpret"))
def spmm_bcsr_fused_pallas(tile_cols: jnp.ndarray, tile_vals: jnp.ndarray,
                           x: jnp.ndarray, block_f: int = 256,
                           interpret: bool = False) -> jnp.ndarray:
    """tile_cols (R, K) int32; tile_vals (R, K, B, B); x (C·B, F) → (R·B, F).

    Grid (R, F/BF): one kernel invocation owns one (B, BF) output block and
    loops K internally, so the output block is written once and the x
    stripes it needs are fetched by explicit double-buffered DMA — the
    fused-gather contract. ``vals`` rides in via an ordinary (1, K, B, B)
    BlockSpec (the whole row-tile of values resident per step); ``x`` is
    left unblocked in HBM and only touched by the in-kernel copies.
    """
    r, k, b, _ = tile_vals.shape
    f = x.shape[1]
    bf = min(block_f, f)
    assert f % bf == 0, f"feature dim {f} not divisible by block_f {bf}"
    nbuf = 2 if k > 1 else 1

    return pl.pallas_call(
        functools.partial(_fused_kernel, k, b, bf, nbuf),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(r, f // bf),
            in_specs=[
                pl.BlockSpec((1, k, b, b), lambda ri, fi, cols: (ri, 0, 0, 0)),
                pl.BlockSpec(memory_space=pltpu.ANY),
            ],
            out_specs=pl.BlockSpec((b, bf), lambda ri, fi, cols: (ri, fi)),
            scratch_shapes=[pltpu.VMEM((nbuf, b, bf), jnp.float32),
                            pltpu.SemaphoreType.DMA((nbuf,))],
        ),
        out_shape=jax.ShapeDtypeStruct((r * b, f), x.dtype),
        interpret=interpret,
    )(tile_cols, tile_vals, x)
