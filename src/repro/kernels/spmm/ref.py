"""Pure-jnp oracle for block-CSR SpMM."""
from __future__ import annotations

import jax.numpy as jnp


def spmm_bcsr_ref(tile_cols: jnp.ndarray, tile_vals: jnp.ndarray,
                  x: jnp.ndarray, num_row_tiles: int) -> jnp.ndarray:
    """out = A @ x with A given as padded block-CSR.

    tile_cols: (R, K) int32 — column-tile index of each of the K tile slots of
               row-tile r (padded slots have all-zero tile_vals).
    tile_vals: (R, K, B, B) — dense tiles.
    x:         (C·B, F).
    Returns (R·B, F).
    """
    r_tiles, k, b, _ = tile_vals.shape
    f = x.shape[1]
    xt = x.reshape(-1, b, f)                       # (C, B, F)
    gathered = xt[tile_cols]                       # (R, K, B, F)
    out = jnp.einsum("rkij,rkjf->rif", tile_vals, gathered)
    return out.reshape(r_tiles * b, f)
