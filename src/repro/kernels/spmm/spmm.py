"""Block-CSR SpMM Pallas TPU kernel.

TPU adaptation of sparse neighborhood aggregation (see DESIGN.md §3): after
IBMB partition-ordering, the batch adjacency is block-sparse; we store the
nonzero B×B tiles (B = 128, MXU-native) in padded block-CSR and compute

    out[r·B:(r+1)·B, f·F:(f+1)·F] = Σ_k  vals[r,k] @ x[cols[r,k]·B : ·, f]

Grid = (row_tiles, feat_tiles, K). The innermost K dimension revisits the same
output block, which Pallas keeps resident in VMEM (multiple-visit
accumulation); `tile_cols` is a scalar-prefetch operand so the x BlockSpec can
index data-dependently (an indexed DMA from HBM into VMEM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _kernel(cols_ref, vals_ref, x_ref, out_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    # vals_ref: (1, 1, B, B) tile; x_ref: (B, BF) gathered column tile
    out_ref[...] += jnp.dot(vals_ref[0, 0], x_ref[...],
                            preferred_element_type=out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_f", "interpret"))
def spmm_bcsr_pallas(tile_cols: jnp.ndarray, tile_vals: jnp.ndarray,
                     x: jnp.ndarray, block_f: int = 128,
                     interpret: bool = False) -> jnp.ndarray:
    """tile_cols (R, K) int32; tile_vals (R, K, B, B); x (C·B, F) → (R·B, F)."""
    r, k, b, _ = tile_vals.shape
    f = x.shape[1]
    bf = min(block_f, f)
    assert f % bf == 0, f"feature dim {f} not divisible by block_f {bf}"

    grid = (r, f // bf, k)

    def vals_map(ri, fi, ki, cols):
        return (ri, ki, 0, 0)

    def x_map(ri, fi, ki, cols):
        return (cols[ri, ki], fi)

    def out_map(ri, fi, ki, cols):
        return (ri, fi)

    return pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, b, b), vals_map),
                pl.BlockSpec((b, bf), x_map),
            ],
            out_specs=pl.BlockSpec((b, bf), out_map),
        ),
        out_shape=jax.ShapeDtypeStruct((r * b, f), x.dtype),
        interpret=interpret,
    )(tile_cols, tile_vals.reshape(r, k, b, b), x)
