"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel ships three files:
  <name>.py — pl.pallas_call + BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper with an `impl` switch
              ("pallas" | "interpret" | "reference")
  ref.py    — pure-jnp oracle used by tests and by the CPU dry-run path

Kernels:
  spmm            — block-CSR SpMM: the GNN aggregation hot spot. IBMB's
                    locality-clustered batches make the adjacency block-sparse
                    after partition ordering; each nonzero 128×128 tile is a
                    dense MXU matmul (the TPU-native re-think of torch-
                    geometric's scatter/gather — see DESIGN.md §3).
  gather_rows     — feature-table row gather for batch assembly (scalar-
                    prefetch indexed DMA).
  flash_attention — blockwise causal attention with online softmax (used by
                    the LM archs for train/prefill), sliding-window capable.
"""
