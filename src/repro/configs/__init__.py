from repro.configs.registry import get_config, list_archs, get_smoke_config, ARCH_IDS

__all__ = ["get_config", "list_archs", "get_smoke_config", "ARCH_IDS"]
