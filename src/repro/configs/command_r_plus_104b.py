"""command-r-plus-104b [dense]: 64L d_model=12288 96H (GQA kv=8) d_ff=33792
vocab=256000 — GQA, no-bias [hf:CohereForAI/c4ai-command-r-plus]."""
from repro.models.lm.config import LMConfig, dense_stages

CONFIG = LMConfig(
    name="command-r-plus-104b",
    d_model=12288, num_heads=96, num_kv_heads=8, head_dim=128,
    d_ff=33792, vocab_size=256000,
    stages=dense_stages(64),
    rope_theta=75_000_000.0,
    norm="layernorm", act="silu", glu=True,
)

SMOKE = LMConfig(
    name="command-r-plus-104b-smoke",
    d_model=128, num_heads=8, num_kv_heads=2, head_dim=16,
    d_ff=256, vocab_size=512,
    stages=dense_stages(2),
    norm="layernorm", dtype="float32",
)
