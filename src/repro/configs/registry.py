"""Architecture registry: --arch <id> resolution for launchers/tests."""
from __future__ import annotations

import importlib
from typing import Dict, List

ARCH_IDS: List[str] = [
    "recurrentgemma-2b",
    "musicgen-large",
    "rwkv6-3b",
    "deepseek-v2-lite-16b",
    "deepseek-v3-671b",
    "llama3.2-1b",
    "command-r-plus-104b",
    "granite-34b",
    "qwen2-1.5b",
    "internvl2-1b",
]

_MODULES: Dict[str, str] = {
    "recurrentgemma-2b": "recurrentgemma_2b",
    "musicgen-large": "musicgen_large",
    "rwkv6-3b": "rwkv6_3b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "llama3.2-1b": "llama3_2_1b",
    "command-r-plus-104b": "command_r_plus_104b",
    "granite-34b": "granite_34b",
    "qwen2-1.5b": "qwen2_1_5b",
    "internvl2-1b": "internvl2_1b",
}


def _module(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str):
    return _module(arch).CONFIG


def get_smoke_config(arch: str):
    return _module(arch).SMOKE


def list_archs() -> List[str]:
    return list(ARCH_IDS)
