"""Paper's own GAT (App. B): 3 layers, hidden 128, 4 heads (ogbn) /
2 layers, hidden 64, 4 heads (Reddit)."""
from repro.models.gnn.models import GNNConfig

CONFIG = GNNConfig(kind="gat", hidden=128, num_layers=3, heads=4, dropout=0.3)
CONFIG_REDDIT = GNNConfig(kind="gat", hidden=64, num_layers=2, heads=4,
                          dropout=0.3)
SMOKE = GNNConfig(kind="gat", hidden=32, num_layers=2, heads=4, dropout=0.0,
                  in_dim=16, out_dim=5)
