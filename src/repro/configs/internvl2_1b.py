"""internvl2-1b [vlm]: 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655 — InternViT + Qwen2-0.5B backbone [arXiv:2404.16821].

The InternViT frontend is a STUB per the assignment: input_specs() provides
precomputed patch embeddings (B, 256, d_model) that are prepended to the text
embeddings; loss_mask zeroes the vision positions."""
from repro.models.lm.config import LMConfig, dense_stages

VISION_PREFIX = 256

CONFIG = LMConfig(
    name="internvl2-1b",
    d_model=896, num_heads=14, num_kv_heads=2, head_dim=64,
    d_ff=4864, vocab_size=151655,
    stages=dense_stages(24),
    qkv_bias=True, rope_theta=1_000_000.0,
    vision_prefix_len=VISION_PREFIX,
    tie_embeddings=True,
    norm="rmsnorm", act="silu", glu=True,
)

SMOKE = LMConfig(
    name="internvl2-1b-smoke",
    d_model=96, num_heads=6, num_kv_heads=2, head_dim=16,
    d_ff=192, vocab_size=512,
    stages=dense_stages(2),
    qkv_bias=True, vision_prefix_len=16,
    tie_embeddings=True, dtype="float32",
)
