"""Paper's own GraphSAGE (App. B): 3 layers, hidden 256."""
from repro.models.gnn.models import GNNConfig

CONFIG = GNNConfig(kind="sage", hidden=256, num_layers=3, dropout=0.3)
SMOKE = GNNConfig(kind="sage", hidden=32, num_layers=2, dropout=0.0,
                  in_dim=16, out_dim=5)
