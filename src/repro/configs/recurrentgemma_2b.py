"""recurrentgemma-2b [hybrid]: 26L d_model=2560 10H (MQA kv=1) d_ff=7680
vocab=256000 — RG-LRU + local attention (window 2048), pattern
(recurrent, recurrent, attention) [arXiv:2402.19427].

26 layers = 8 × (R, R, A) superblocks + 1 × (R, R) tail. Sub-quadratic
(local attention + diagonal recurrence) ⇒ runs long_500k."""
from repro.models.lm.config import LMConfig, LayerSpec, Stage

_R = LayerSpec("rglru", "dense")
_A = LayerSpec("local", "dense")

CONFIG = LMConfig(
    name="recurrentgemma-2b",
    d_model=2560, num_heads=10, num_kv_heads=1, head_dim=256,
    d_ff=7680, vocab_size=256000,
    stages=(Stage((_R, _R, _A), 8), Stage((_R, _R), 1)),
    window=2048, rnn_width=2560, conv_width=4,
    rope_theta=10_000.0, logit_softcap=30.0,
    tie_embeddings=True,
    norm="rmsnorm", act="gelu", glu=True,
)

SMOKE = LMConfig(
    name="recurrentgemma-2b-smoke",
    d_model=128, num_heads=4, num_kv_heads=1, head_dim=32,
    d_ff=256, vocab_size=512,
    stages=(Stage((_R, _R, _A), 1),),
    window=32, rnn_width=128, conv_width=4,
    tie_embeddings=True, act="gelu", dtype="float32",
)
