"""Assigned input-shape set (same for all 10 LM archs).

train/prefill lower `train_step`/`prefill`; decode_* / long_* lower
`serve_step` (one new token against a KV/state cache of seq_len).
`long_500k` requires sub-quadratic attention: it runs only for
recurrentgemma-2b (hybrid) and rwkv6-3b (SSM); the 8 pure full-attention
archs skip it (documented in DESIGN.md §Arch-applicability).
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str           # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def shape_applies(cfg, shape: ShapeSpec) -> bool:
    if shape.name == "long_500k":
        return cfg.is_subquadratic
    return True
