"""deepseek-v3-671b [moe]: 61L d_model=7168 128H d_ff=2048(moe)
vocab=129280 — MLA (q_lora 1536, kv_lora 512), MoE 256 routed top-8 +
1 shared, MTP [arXiv:2412.19437]. First 3 layers dense (d_ff 18432)."""
from repro.models.lm.config import LMConfig, LayerSpec, Stage

CONFIG = LMConfig(
    name="deepseek-v3-671b",
    d_model=7168, num_heads=128, num_kv_heads=128,
    d_ff=18432, vocab_size=129280,
    stages=(Stage((LayerSpec("mla", "dense"),), 3),
            Stage((LayerSpec("mla", "moe"),), 58)),
    q_lora_rank=1536,
    kv_lora_rank=512, qk_nope_head_dim=128, qk_rope_head_dim=64,
    v_head_dim=128,
    moe_num_experts=256, moe_top_k=8, moe_num_shared=1, moe_d_ff=2048,
    mtp_depth=1,
    rope_theta=10_000.0,
    norm="rmsnorm", act="silu", glu=True,
)

SMOKE = LMConfig(
    name="deepseek-v3-671b-smoke",
    d_model=128, num_heads=4, num_kv_heads=4,
    d_ff=256, vocab_size=512,
    stages=(Stage((LayerSpec("mla", "dense"),), 1),
            Stage((LayerSpec("mla", "moe"),), 1)),
    q_lora_rank=64,
    kv_lora_rank=64, qk_nope_head_dim=32, qk_rope_head_dim=16,
    v_head_dim=32,
    moe_num_experts=8, moe_top_k=2, moe_num_shared=1, moe_d_ff=64,
    mtp_depth=1, dtype="float32",
)
