"""musicgen-large [audio]: 48L d_model=2048 32H (GQA kv=32 — MHA) d_ff=8192
vocab=2048 — decoder-only over EnCodec tokens, 4 codebooks, sinusoidal
positions [arXiv:2306.05284]. Frontend (EnCodec) is a STUB: input_specs()
provides the (B, S, 4) codebook token grid directly."""
from repro.models.lm.config import LMConfig, dense_stages

CONFIG = LMConfig(
    name="musicgen-large",
    d_model=2048, num_heads=32, num_kv_heads=32, head_dim=64,
    d_ff=8192, vocab_size=2048,
    stages=dense_stages(48),
    pos_embed="sinusoidal",
    num_codebooks=4,
    norm="layernorm", act="gelu", glu=False,
)

SMOKE = LMConfig(
    name="musicgen-large-smoke",
    d_model=128, num_heads=8, num_kv_heads=8, head_dim=16,
    d_ff=256, vocab_size=128,
    stages=dense_stages(2),
    pos_embed="sinusoidal", num_codebooks=4,
    norm="layernorm", act="gelu", glu=False, dtype="float32",
)
