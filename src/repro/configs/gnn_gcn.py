"""Paper's own GCN (App. B): 3 layers, hidden 256 (ogbn datasets) /
2 layers, hidden 512 (Reddit). LayerNorm + ReLU + dropout.
Used with IBMB node-wise and batch-wise batch construction."""
from repro.models.gnn.models import GNNConfig

# dataset-parametric: in/out dims filled by the driver from the dataset
CONFIG = GNNConfig(kind="gcn", hidden=256, num_layers=3, dropout=0.3)
CONFIG_REDDIT = GNNConfig(kind="gcn", hidden=512, num_layers=2, dropout=0.3)
SMOKE = GNNConfig(kind="gcn", hidden=32, num_layers=2, dropout=0.0,
                  in_dim=16, out_dim=5)
