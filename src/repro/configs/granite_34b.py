"""granite-34b [dense]: 88L d_model=6144 48H (GQA kv=1 — MQA) d_ff=24576
vocab=49152 — code model [arXiv:2405.04324]. GPT-BigCode-style MQA with a
plain (non-gated) GELU MLP — a gated MLP would put the count at 47B, not
34B, so glu=False here."""
from repro.models.lm.config import LMConfig, dense_stages

CONFIG = LMConfig(
    name="granite-34b",
    d_model=6144, num_heads=48, num_kv_heads=1, head_dim=128,
    d_ff=24576, vocab_size=49152,
    stages=dense_stages(88),
    rope_theta=10_000.0,
    norm="layernorm", act="gelu", glu=False, qkv_bias=True,
)

SMOKE = LMConfig(
    name="granite-34b-smoke",
    d_model=128, num_heads=8, num_kv_heads=1, head_dim=16,
    d_ff=256, vocab_size=512,
    stages=dense_stages(3),
    norm="layernorm", act="gelu", glu=False, qkv_bias=True,
    dtype="float32",
)
