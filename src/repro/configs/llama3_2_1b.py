"""llama3.2-1b [dense]: 16L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=128256 [hf:meta-llama/Llama-3.2-1B]."""
from repro.models.lm.config import LMConfig, dense_stages

CONFIG = LMConfig(
    name="llama3.2-1b",
    d_model=2048, num_heads=32, num_kv_heads=8, head_dim=64,
    d_ff=8192, vocab_size=128256,
    stages=dense_stages(16),
    rope_theta=500_000.0,
    tie_embeddings=True,
    norm="rmsnorm", act="silu", glu=True,
)

SMOKE = LMConfig(
    name="llama3.2-1b-smoke",
    d_model=128, num_heads=8, num_kv_heads=2, head_dim=16,
    d_ff=256, vocab_size=512,
    stages=dense_stages(2),
    rope_theta=500_000.0, tie_embeddings=True, dtype="float32",
)
