"""rwkv6-3b "Finch" [ssm]: 32L d_model=2560 (attention-free) d_ff=8960
vocab=65536 — data-dependent decay [arXiv:2404.05892].

State-based (O(1) decode state per layer) ⇒ runs long_500k."""
from repro.models.lm.config import LMConfig, LayerSpec, Stage

CONFIG = LMConfig(
    name="rwkv6-3b",
    d_model=2560, num_heads=40, num_kv_heads=40,
    d_ff=8960, vocab_size=65536,
    stages=(Stage((LayerSpec("rwkv6", "rwkv_cmix"),), 32),),
    rwkv_head_dim=64, rwkv_lora_dim=64,
    pos_embed="none",
    norm="layernorm",
)

SMOKE = LMConfig(
    name="rwkv6-3b-smoke",
    d_model=128, num_heads=4, num_kv_heads=4,
    d_ff=256, vocab_size=512,
    stages=(Stage((LayerSpec("rwkv6", "rwkv_cmix"),), 2),),
    rwkv_head_dim=32, rwkv_lora_dim=16,
    pos_embed="none", norm="layernorm", dtype="float32",
)
