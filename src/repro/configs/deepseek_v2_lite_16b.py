"""deepseek-v2-lite-16b [moe]: 27L d_model=2048 16H d_ff=1408(moe)
vocab=102400 — MLA kv_lora=512, MoE 64 routed top-6 + 2 shared
[arXiv:2405.04434]. Layer 0 dense (d_ff 10944), layers 1-26 MoE."""
from repro.models.lm.config import LMConfig, LayerSpec, Stage

CONFIG = LMConfig(
    name="deepseek-v2-lite-16b",
    d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=10944, vocab_size=102400,
    stages=(Stage((LayerSpec("mla", "dense"),), 1),
            Stage((LayerSpec("mla", "moe"),), 26)),
    q_lora_rank=0,                # v2-lite: no q compression
    kv_lora_rank=512, qk_nope_head_dim=128, qk_rope_head_dim=64,
    v_head_dim=128,
    moe_num_experts=64, moe_top_k=6, moe_num_shared=2, moe_d_ff=1408,
    rope_theta=10_000.0,
    norm="rmsnorm", act="silu", glu=True,
)

SMOKE = LMConfig(
    name="deepseek-v2-lite-16b-smoke",
    d_model=128, num_heads=4, num_kv_heads=4,
    d_ff=256, vocab_size=512,
    stages=(Stage((LayerSpec("mla", "dense"),), 1),
            Stage((LayerSpec("mla", "moe"),), 1)),
    kv_lora_rank=64, qk_nope_head_dim=32, qk_rope_head_dim=16,
    v_head_dim=32,
    moe_num_experts=8, moe_top_k=2, moe_num_shared=1, moe_d_ff=64,
    dtype="float32",
)
