"""qwen2-1.5b [dense]: 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936 — QKV bias [arXiv:2407.10671]."""
from repro.models.lm.config import LMConfig, dense_stages

CONFIG = LMConfig(
    name="qwen2-1.5b",
    d_model=1536, num_heads=12, num_kv_heads=2, head_dim=128,
    d_ff=8960, vocab_size=151936,
    stages=dense_stages(28),
    qkv_bias=True, rope_theta=1_000_000.0,
    tie_embeddings=True,
    norm="rmsnorm", act="silu", glu=True,
)

SMOKE = LMConfig(
    name="qwen2-1.5b-smoke",
    d_model=96, num_heads=6, num_kv_heads=2, head_dim=16,
    d_ff=192, vocab_size=512,
    stages=dense_stages(2),
    qkv_bias=True, tie_embeddings=True, dtype="float32",
)
