"""Model zoo: the paper's GNNs + the 10 assigned LM-family architectures."""
