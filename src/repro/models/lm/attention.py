"""Attention mixers: GQA/MQA, sliding-window (local), and MLA (DeepSeek).

Design notes (TPU):
* Train/prefill attention is CHUNKED with an online softmax expressed in
  lax.scan — the same math as the Pallas flash kernel
  (repro.kernels.flash_attention) but lowerable by plain XLA, so the compiled
  dry-run never materializes an S×S buffer. On real TPUs the Pallas kernel is
  the fast path (impl switch at the step level).
* GQA uses the grouped formulation (B, KV, G, S, D) — no materialized
  head-expansion of K/V.
* Sliding-window attention uses neighbor-chunk pairing: with chunk size W a
  query chunk attends exactly (its own + previous) chunk ⇒ O(S·2W) FLOPs,
  static shapes, no gather.
* MLA keeps the compressed cache (c_kv, k_rope) and expands K/V per KV-chunk
  inside the scan (prefill) or runs fully absorbed in the compressed space
  (decode) — cache is rank·S instead of 2·H·D·S.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist import annotate
from repro.models.lm.common import apply_rope

NEG_INF = jnp.float32(-1e30)


def pick_chunk(s: int, target: int) -> int:
    """Largest divisor of s that is ≤ target (chunked scans need s % c == 0;
    odd lengths like S−1=4095 for MTP or prefix+text=4352 for VLMs occur)."""
    c = min(target, s)
    while s % c != 0:
        c -= 1
    return max(c, 1)


# ---------------------------------------------------------------- chunked core
def _online_softmax_step(carry, kv_chunk, q, q_pos, k_pos_chunk, scale,
                         causal, window, softcap=0.0):
    """One KV-chunk update. q: (B, KV, G, Sq, D); kv_chunk: (k, v) each
    (B, KV, Ck, D[v]); positions broadcastable."""
    m_prev, l_prev, acc = carry
    k, v = kv_chunk
    s = jnp.einsum("bkgqd,bkcd->bkgqc", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    mask = jnp.ones(s.shape[-2:], bool)
    if causal:
        mask &= k_pos_chunk[None, :] <= q_pos[:, None]
    if window > 0:
        mask &= k_pos_chunk[None, :] > q_pos[:, None] - window
    s = jnp.where(mask, s, NEG_INF)
    m_cur = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_cur[..., None])
    alpha = jnp.exp(m_prev - m_cur)
    l_cur = l_prev * alpha + p.sum(axis=-1)
    acc = acc * alpha[..., None] + jnp.einsum(
        "bkgqc,bkcd->bkgqd", p, v.astype(jnp.float32))
    return (m_cur, l_cur, acc), None


def chunked_attention(
    q: jnp.ndarray,           # (B, S, H, D)
    k: jnp.ndarray,           # (B, Sk, KV, D)
    v: jnp.ndarray,           # (B, Sk, KV, Dv)
    causal: bool = True,
    window: int = 0,
    chunk_k: int = 1024,
    scale: Optional[float] = None,
    q_offset: int = 0,
    softcap: float = 0.0,
) -> jnp.ndarray:
    """Online-softmax attention, scanning KV chunks. Returns (B, S, H, Dv)."""
    b, sq, h, d = q.shape
    sk, kv = k.shape[1], k.shape[2]
    g = h // kv
    dv = v.shape[-1]
    scale = scale if scale is not None else d ** -0.5
    ck = pick_chunk(sk, chunk_k)
    nk = sk // ck

    qg = q.reshape(b, sq, kv, g, d).transpose(0, 2, 3, 1, 4)   # (B,KV,G,S,D)
    kc = k.transpose(0, 2, 1, 3).reshape(b, kv, nk, ck, d).transpose(2, 0, 1, 3, 4)
    vc = v.transpose(0, 2, 1, 3).reshape(b, kv, nk, ck, dv).transpose(2, 0, 1, 3, 4)
    q_pos = q_offset + jnp.arange(sq)
    k_pos = jnp.arange(sk).reshape(nk, ck)

    m0 = jnp.full((b, kv, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kv, g, sq), jnp.float32)
    a0 = jnp.zeros((b, kv, g, sq, dv), jnp.float32)

    def body(carry, xs):
        kch, vch, kp = xs
        return _online_softmax_step(carry, (kch, vch), qg, q_pos, kp, scale,
                                    causal, window, softcap)

    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kc, vc, k_pos))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, dv)
    return out.astype(q.dtype)


def sliding_window_attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, window: int,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """Causal sliding-window attention in O(S·2W): chunk size = W, each query
    chunk attends (previous, own) chunks only. q (B,S,H,D), k/v (B,S,KV,D)."""
    b, s, h, d = q.shape
    kv = k.shape[2]
    dv = v.shape[-1]
    g = h // kv
    scale = scale if scale is not None else d ** -0.5
    if s <= window:   # degenerate: plain causal
        return chunked_attention(q, k, v, causal=True, window=window,
                                 chunk_k=min(s, 1024), scale=scale)
    w = window
    assert s % w == 0, f"seq {s} % window {w}"
    nc = s // w
    qg = q.reshape(b, nc, w, kv, g, d)
    kc = k.reshape(b, nc, w, kv, d)
    vc = v.reshape(b, nc, w, kv, dv)
    # previous chunk (zero-padded for the first)
    k_prev = jnp.pad(kc[:, :-1], ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))
    v_prev = jnp.pad(vc[:, :-1], ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))
    k2 = jnp.concatenate([k_prev, kc], axis=2)     # (B,nc,2W,KV,D)
    v2 = jnp.concatenate([v_prev, vc], axis=2)
    s_ = jnp.einsum("bnqkgd,bnckd->bnkgqc", qg.astype(jnp.float32),
                    k2.astype(jnp.float32)) * scale
    q_pos = jnp.arange(w)[:, None]                  # within-pair positions
    k_pos = jnp.arange(2 * w)[None, :] - w
    mask = (k_pos <= q_pos) & (k_pos > q_pos - w)
    first_mask = mask & (k_pos >= 0)                # first chunk has no prev
    full_mask = jnp.broadcast_to(mask, (nc,) + mask.shape).at[0].set(first_mask)
    s_ = jnp.where(full_mask[None, :, None, None], s_, NEG_INF)
    p = jax.nn.softmax(s_, axis=-1)
    out = jnp.einsum("bnkgqc,bnckd->bnqkgd", p, v2.astype(jnp.float32))
    return out.reshape(b, s, h, dv).astype(q.dtype)


# ------------------------------------------------------------------- GQA mixer
def gqa_params_shape(cfg):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    shapes = {
        "wq": (d, h * hd), "wk": (d, kv * hd), "wv": (d, kv * hd),
        "wo": (h * hd, d),
    }
    if cfg.qkv_bias:
        shapes.update({"bq": (h * hd,), "bk": (kv * hd,), "bv": (kv * hd,)})
    return shapes


def gqa_forward(cfg, p: Dict, x: jnp.ndarray, positions: jnp.ndarray,
                window: int = 0, chunk_k: int = 1024) -> jnp.ndarray:
    """Full-sequence (train/prefill). x: (B, S, D_model)."""
    b, s, _ = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    # SP→TP transition: gather the sequence ONCE and let q/k/v share it
    # (§Perf B5 — without the explicit constraint XLA materializes three
    # separate full-seq all-gathers per pass).
    x = annotate(x, "batch", None, "embed")
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, kv, hd)
    v = v.reshape(b, s, kv, hd)
    if cfg.pos_embed == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = annotate(q, "batch", "seq", "heads", None)
    k = annotate(k, "batch", "seq", "kv_heads", None)
    if window > 0:
        out = sliding_window_attention(q, k, v, window)
    else:
        out = chunked_attention(q, k, v, causal=True, chunk_k=chunk_k,
                                softcap=cfg.logit_softcap)
    out = annotate(out, "batch", "seq", "heads", None)
    return out.reshape(b, s, h * hd) @ p["wo"]


def gqa_decode(cfg, p: Dict, x: jnp.ndarray, cache: Dict, pos: jnp.ndarray,
               window: int = 0) -> Tuple[jnp.ndarray, Dict]:
    """Single-token decode. x: (B, 1, D). cache: k/v (B, S_max, KV, hd)
    (ring buffer of size `window` for local layers). pos: scalar int32 —
    absolute position of the new token."""
    b = x.shape[0]
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, 1, h, hd)
    k = k.reshape(b, 1, kv, hd)
    v = v.reshape(b, 1, kv, hd)
    if cfg.pos_embed == "rope":
        q = apply_rope(q, pos[None], cfg.rope_theta)
        k = apply_rope(k, pos[None], cfg.rope_theta)
    s_max = cache["k"].shape[1]
    slot = jnp.where(window > 0, pos % s_max, jnp.minimum(pos, s_max - 1))
    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                      (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                      (0, slot, 0, 0))
    ck_a = annotate(ck, "batch", "cache_seq", "kv_heads", None)
    cv_a = annotate(cv, "batch", "cache_seq", "kv_heads", None)
    # positions of cache slots
    idx = jnp.arange(s_max)
    if window > 0:
        # ring: slot i holds absolute position pos - ((slot - i) mod s_max)
        abs_pos = pos - ((slot - idx) % s_max)
        valid = (abs_pos >= 0) & (abs_pos >= pos - window + 1) & (abs_pos <= pos)
    else:
        abs_pos = idx
        valid = idx <= pos
    qg = q.reshape(b, 1, kv, h // kv, hd).transpose(0, 2, 3, 1, 4)
    s_ = jnp.einsum("bkgqd,bskd->bkgqs", qg.astype(jnp.float32),
                    ck_a.astype(jnp.float32)) * (hd ** -0.5)
    if cfg.logit_softcap > 0:
        s_ = cfg.logit_softcap * jnp.tanh(s_ / cfg.logit_softcap)
    s_ = jnp.where(valid[None, None, None, None, :], s_, NEG_INF)
    pr = jax.nn.softmax(s_, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bkgqd", pr, cv_a.astype(jnp.float32))
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, 1, h * hd).astype(x.dtype)
    return out @ p["wo"], {"k": ck, "v": cv}


def gqa_cache_shape(cfg, batch: int, s_max: int, window: int = 0):
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    size = min(window, s_max) if window > 0 else s_max
    return {"k": (batch, size, kv, hd), "v": (batch, size, kv, hd)}


# ------------------------------------------------------------------- MLA mixer
def mla_params_shape(cfg):
    d = cfg.d_model
    h = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    r_kv, r_q = cfg.kv_lora_rank, cfg.q_lora_rank
    shapes = {
        "wkv_a": (d, r_kv + dr),
        "kv_norm": (r_kv,),
        "wk_b": (r_kv, h * dn),
        "wv_b": (r_kv, h * dv),
        "wo": (h * dv, d),
    }
    if r_q:
        shapes.update({"wq_a": (d, r_q), "q_norm": (r_q,),
                       "wq_b": (r_q, h * (dn + dr))})
    else:
        shapes.update({"wq": (d, h * (dn + dr))})
    return shapes


def _mla_q(cfg, p, x, positions):
    b, s, _ = x.shape
    h = cfg.num_heads
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    if cfg.q_lora_rank:
        from repro.models.lm.common import rms_norm
        q = rms_norm(x @ p["wq_a"], p["q_norm"], cfg.norm_eps) @ p["wq_b"]
    else:
        q = x @ p["wq"]
    q = q.reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_forward(cfg, p: Dict, x: jnp.ndarray, positions: jnp.ndarray,
                chunk_k: int = 1024) -> jnp.ndarray:
    """Prefill/train MLA. K/V are expanded from the compressed cache PER
    KV-CHUNK inside the scan, so the expanded (S, H, D) tensors never exist
    at full length — HBM peak stays O(S·rank + chunk·H·D)."""
    from repro.models.lm.common import rms_norm
    b, s, _ = x.shape
    h = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    r_kv = cfg.kv_lora_rank

    # SP→TP: one shared full-seq gather for q/kv projections (§Perf B5)
    x = annotate(x, "batch", None, "embed")
    kv_a = x @ p["wkv_a"]                               # (B,S,r+dr)
    c_kv = rms_norm(kv_a[..., :r_kv], p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(kv_a[..., None, r_kv:], positions, cfg.rope_theta)  # (B,S,1,dr)

    q_nope, q_rope = _mla_q(cfg, p, x, positions)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)       # (B,S,H,dn+dr)
    q = annotate(q, "batch", "seq", "heads", None)

    ck = pick_chunk(s, chunk_k)
    nk = s // ck
    scale = (dn + dr) ** -0.5
    qg = q.transpose(0, 2, 1, 3)[:, None]                # (B,1,H,S,dn+dr)
    q_pos = jnp.arange(s)

    c_chunks = c_kv.reshape(b, nk, ck, r_kv).transpose(1, 0, 2, 3)
    r_chunks = k_rope.reshape(b, nk, ck, dr).transpose(1, 0, 2, 3)
    k_pos = jnp.arange(s).reshape(nk, ck)

    m0 = jnp.full((b, 1, h, s), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, 1, h, s), jnp.float32)
    a0 = jnp.zeros((b, 1, h, s, dv), jnp.float32)

    wk_b = p["wk_b"].reshape(r_kv, h, dn)
    wv_b = p["wv_b"].reshape(r_kv, h, dv)

    def body2(carry, xs):
        m_prev, l_prev, acc = carry
        cc, rc, kp = xs
        k_nope = jnp.einsum("bcr,rhd->bhcd", cc.astype(jnp.float32),
                            wk_b.astype(jnp.float32))
        v_full = jnp.einsum("bcr,rhd->bhcd", cc.astype(jnp.float32),
                            wv_b.astype(jnp.float32))
        s_n = jnp.einsum("bhqd,bhcd->bhqc", qg[:, 0, :, :, :dn].astype(jnp.float32), k_nope)
        s_r = jnp.einsum("bhqd,bcd->bhqc", qg[:, 0, :, :, dn:].astype(jnp.float32),
                         rc.astype(jnp.float32))
        s_ = (s_n + s_r) * scale
        mask = kp[None, :] <= q_pos[:, None]
        s_ = jnp.where(mask[None, None], s_, NEG_INF)
        m_cur = jnp.maximum(m_prev[:, 0], s_.max(axis=-1))
        pr = jnp.exp(s_ - m_cur[..., None])
        alpha = jnp.exp(m_prev[:, 0] - m_cur)
        l_cur = l_prev[:, 0] * alpha + pr.sum(axis=-1)
        acc_new = acc[:, 0] * alpha[..., None] + jnp.einsum("bhqc,bhcd->bhqd", pr, v_full)
        return (m_cur[:, None], l_cur[:, None], acc_new[:, None]), None

    (m, l, acc), _ = jax.lax.scan(body2, (m0, l0, a0), (c_chunks, r_chunks, k_pos))
    out = acc[:, 0] / jnp.maximum(l[:, 0], 1e-30)[..., None]   # (B,H,S,dv)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, h * dv).astype(x.dtype)
    out = annotate(out, "batch", "seq", None)
    return out @ p["wo"]


def mla_decode(cfg, p: Dict, x: jnp.ndarray, cache: Dict, pos: jnp.ndarray
               ) -> Tuple[jnp.ndarray, Dict]:
    """Absorbed MLA decode: all work in the compressed space.
    cache: c_kv (B, S_max, r_kv), k_rope (B, S_max, dr)."""
    from repro.models.lm.common import rms_norm
    b = x.shape[0]
    h = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    r_kv = cfg.kv_lora_rank

    kv_a = x @ p["wkv_a"]
    c_new = rms_norm(kv_a[..., :r_kv], p["kv_norm"], cfg.norm_eps)
    kr_new = apply_rope(kv_a[..., None, r_kv:], pos[None], cfg.rope_theta)[:, :, 0]

    cc = jax.lax.dynamic_update_slice(cache["c_kv"], c_new.astype(cache["c_kv"].dtype),
                                      (0, pos, 0))
    cr = jax.lax.dynamic_update_slice(cache["k_rope"], kr_new.astype(cache["k_rope"].dtype),
                                      (0, pos, 0))

    q_nope, q_rope = _mla_q(cfg, p, x, pos[None])        # (B,1,H,dn/dr)
    wk_b = p["wk_b"].reshape(r_kv, h, dn)
    wv_b = p["wv_b"].reshape(r_kv, h, dv)
    q_abs = jnp.einsum("bqhd,rhd->bqhr", q_nope.astype(jnp.float32),
                       wk_b.astype(jnp.float32))          # (B,1,H,r_kv)

    cc_a = annotate(cc, "batch", "cache_seq", None)
    cr_a = annotate(cr, "batch", "cache_seq", None)
    s_n = jnp.einsum("bqhr,bsr->bhqs", q_abs, cc_a.astype(jnp.float32))
    s_r = jnp.einsum("bqhd,bsd->bhqs", q_rope.astype(jnp.float32),
                     cr_a.astype(jnp.float32))
    s_ = (s_n + s_r) * ((dn + dr) ** -0.5)
    valid = jnp.arange(cc.shape[1]) <= pos
    s_ = jnp.where(valid[None, None, None], s_, NEG_INF)
    pr = jax.nn.softmax(s_, axis=-1)
    ctx = jnp.einsum("bhqs,bsr->bqhr", pr, cc_a.astype(jnp.float32))   # (B,1,H,r)
    out = jnp.einsum("bqhr,rhd->bqhd", ctx, wv_b.astype(jnp.float32))  # (B,1,H,dv)
    out = out.reshape(b, 1, h * dv).astype(x.dtype)
    return out @ p["wo"], {"c_kv": cc, "k_rope": cr}


def mla_cache_shape(cfg, batch: int, s_max: int):
    return {"c_kv": (batch, s_max, cfg.kv_lora_rank),
            "k_rope": (batch, s_max, cfg.qk_rope_head_dim)}
