"""Config-driven LM: embedding → scanned stages → head; loss & decode.

Depth is lowered as one lax.scan PER STAGE over parameters stacked on a
leading `repeat` axis, so HLO size (and 512-way SPMD compile time) is
independent of layer count. Activation rematerialization wraps the scan body
(`remat=True`), giving per-layer checkpointing.

Losses use a SEQ-CHUNKED cross-entropy: logits are produced (B, chunk, V) at
a time inside a scan — the full (B, S, V) logits tensor never exists, which
matters at vocab 256k (musicgen excepted: 4 codebook heads of 2048).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist import annotate
from repro.models.lm.common import (
    apply_norm, norm_params, dense_init, sinusoidal_embed, KeyGen)
from repro.models.lm.config import LMConfig, LayerSpec, Stage
from repro.models.lm.blocks import (
    layer_param_shapes, layer_forward, layer_decode, layer_cache_shape,
    _cache_dtype, _norm_shape)


# ----------------------------------------------------------------- param trees
def param_shapes(cfg: LMConfig) -> Dict:
    d, v = cfg.d_model, cfg.vocab_size
    tree: Dict = {}
    if cfg.num_codebooks > 1:
        tree["embed"] = {"table": (cfg.num_codebooks, v, d)}
    else:
        tree["embed"] = {"table": (v, d)}
    stages = []
    for st in cfg.stages:
        layers = {}
        for i, spec in enumerate(st.layers):
            shapes = layer_param_shapes(cfg, spec)
            layers[f"layer{i}"] = _stack_shapes(shapes, st.repeat)
        stages.append(layers)
    tree["stages"] = stages
    tree["final_norm"] = _norm_shape(cfg)
    if not cfg.tie_embeddings:
        if cfg.num_codebooks > 1:
            tree["head"] = {"w": (d, cfg.num_codebooks * v)}
        else:
            tree["head"] = {"w": (d, v)}
    if cfg.mtp_depth > 0:
        spec = cfg.stages[-1].layers[-1]
        tree["mtp"] = {
            "proj": (2 * d, d),
            "norm_h": _norm_shape(cfg), "norm_e": _norm_shape(cfg),
            "layer": layer_param_shapes(cfg, spec),
        }
    return tree


def _stack_shapes(shapes: Any, repeat: int) -> Any:
    return jax.tree_util.tree_map(lambda s: (repeat,) + tuple(s), shapes,
                                  is_leaf=lambda x: isinstance(x, tuple))


def abstract_params(cfg: LMConfig) -> Any:
    dt = jnp.dtype(cfg.dtype)
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(tuple(s), dt), param_shapes(cfg),
        is_leaf=lambda x: isinstance(x, tuple))


def init_params(cfg: LMConfig, key) -> Any:
    dt = jnp.dtype(cfg.dtype)
    kg = KeyGen(key)

    def leaf(path, s):
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        s = tuple(s)
        if "norm" in name or name in ("scale", "ln_x_scale"):
            return jnp.ones(s, dt)
        if name in ("bias", "ba", "bi", "conv_b", "ln_x_bias", "bq", "bk", "bv",
                    "mu_base", "w_base", "cmix_mu_k", "cmix_mu_r"):
            return jnp.zeros(s, dt)
        if name == "scale":
            return jnp.ones(s, dt)
        if name == "lam":
            return jnp.asarray(
                np.linspace(0.5, 2.0, s[0]), dt)      # spread decay rates
        if name in ("mu", "u"):
            return (jax.random.uniform(kg(), s, jnp.float32) * 0.5).astype(dt)
        return dense_init(kg(), s, dt)

    return jax.tree_util.tree_map_with_path(
        leaf, param_shapes(cfg), is_leaf=lambda x: isinstance(x, tuple))


# -------------------------------------------------------------------- embedding
def embed_tokens(cfg: LMConfig, params, tokens: jnp.ndarray,
                 positions: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    table = params["embed"]["table"]
    if cfg.num_codebooks > 1:
        # tokens: (B, S, K) — sum of per-codebook embeddings (MusicGen)
        h = sum(table[k][tokens[..., k]] for k in range(cfg.num_codebooks))
    else:
        h = table[tokens]
    if cfg.pos_embed == "sinusoidal":
        if positions is None:
            positions = jnp.arange(h.shape[1])
        h = h + sinusoidal_embed(positions, cfg.d_model).astype(h.dtype)
    return h


def head_logits(cfg: LMConfig, params, h: jnp.ndarray) -> jnp.ndarray:
    """h (..., D) → logits (..., V) (or (..., K·V) for multi-codebook)."""
    if cfg.tie_embeddings:
        return h @ params["embed"]["table"].T
    return h @ params["head"]["w"]


# ------------------------------------------------------------------- forward
def _run_stages(cfg: LMConfig, params, h: jnp.ndarray, positions: jnp.ndarray,
                remat: bool = True) -> jnp.ndarray:
    for st, st_params in zip(cfg.stages, params["stages"]):
        def body(x, layer_p):
            for i, spec in enumerate(st.layers):
                x = layer_forward(cfg, spec, layer_p[f"layer{i}"], x, positions)
            return x, None
        if remat:
            body = jax.checkpoint(body)
        h, _ = jax.lax.scan(body, h, st_params)
    return h


def lm_forward(cfg: LMConfig, params, tokens: jnp.ndarray,
               prefix_embeds: Optional[jnp.ndarray] = None,
               remat: bool = True) -> jnp.ndarray:
    """Returns final hidden states (B, S_total, D)."""
    h = embed_tokens(cfg, params, tokens)
    if prefix_embeds is not None:       # VLM stub: precomputed patch embeds
        h = jnp.concatenate([prefix_embeds.astype(h.dtype), h], axis=1)
    h = annotate(h, "batch", "seq", "embed")
    positions = jnp.arange(h.shape[1])
    h = _run_stages(cfg, params, h, positions, remat=remat)
    return apply_norm(cfg, h, params["final_norm"])


def _xent_chunk(cfg, params, h_chunk, labels_chunk, mask_chunk):
    logits = head_logits(cfg, params, h_chunk).astype(jnp.float32)
    if cfg.num_codebooks > 1:
        b, s, _ = logits.shape
        logits = logits.reshape(b, s, cfg.num_codebooks, cfg.vocab_size)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, labels_chunk[..., None], axis=-1)[..., 0]
        nll = nll.sum(-1)                     # sum over codebooks
    else:
        logits = annotate(logits, "batch", "seq", "vocab")
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, labels_chunk[..., None], axis=-1)[..., 0]
    return (nll * mask_chunk).sum(), mask_chunk.sum()


def chunked_xent(cfg: LMConfig, params, h: jnp.ndarray, labels: jnp.ndarray,
                 mask: jnp.ndarray, chunk: int = 512) -> jnp.ndarray:
    """Mean NLL with (B, chunk, V) logits at a time."""
    from repro.models.lm.attention import pick_chunk
    b, s = h.shape[0], h.shape[1]
    c = pick_chunk(s, chunk)
    nc = s // c
    hc = h.reshape(b, nc, c, -1).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, nc, c, -1) if labels.ndim > 2 \
        else labels.reshape(b, nc, c)
    lc = jnp.moveaxis(lc, 1, 0)
    mc = jnp.moveaxis(mask.reshape(b, nc, c), 1, 0)

    def body(carry, xs):
        tot, cnt = carry
        hh, ll, mm = xs
        l, n = _xent_chunk(cfg, params, hh, ll, mm)
        return (tot + l, cnt + n), None

    import os
    if os.environ.get("REPRO_XENT_REMAT", "0") == "1":
        # §Perf: recompute the (B, chunk, V) logits in the backward pass
        # instead of saving softmax intermediates per chunk (V can be 256k).
        body = jax.checkpoint(body)
    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)),
                                 (hc, lc, mc))
    return tot / jnp.maximum(cnt, 1.0)


def lm_loss(cfg: LMConfig, params, batch: Dict[str, jnp.ndarray],
            remat: bool = True) -> jnp.ndarray:
    """batch: tokens (B,S[,K]) int32, loss_mask (B,S) f32,
    optional prefix_embeds (B,P,D). Next-token LM loss (+ MTP if enabled)."""
    tokens = batch["tokens"]
    prefix = batch.get("prefix_embeds")
    h = lm_forward(cfg, params, tokens, prefix_embeds=prefix, remat=remat)
    p_len = 0 if prefix is None else prefix.shape[1]
    h_text = h[:, p_len:]
    # predict token t+1 from position t
    h_in = h_text[:, :-1]
    labels = tokens[:, 1:].astype(jnp.int32)
    mask = batch["loss_mask"][:, 1:].astype(jnp.float32)
    loss = chunked_xent(cfg, params, h_in, labels, mask)

    if cfg.mtp_depth > 0:
        mtp = params["mtp"]
        # MTP (DeepSeek-V3): combine h_t with embedding of token t+1 to
        # predict token t+2 through one extra layer sharing the main head.
        emb_next = embed_tokens(cfg, params, tokens[:, 1:])
        h_n = apply_norm(cfg, h_in, mtp["norm_h"])
        e_n = apply_norm(cfg, emb_next, mtp["norm_e"])
        h2 = jnp.concatenate([h_n, e_n], axis=-1) @ mtp["proj"]
        spec = cfg.stages[-1].layers[-1]
        h2 = layer_forward(cfg, spec, mtp["layer"], h2, jnp.arange(h2.shape[1]))
        h2 = apply_norm(cfg, h2, params["final_norm"])
        labels2 = tokens[:, 2:].astype(jnp.int32)
        mask2 = batch["loss_mask"][:, 2:].astype(jnp.float32)
        loss = loss + 0.3 * chunked_xent(cfg, params, h2[:, :-1], labels2, mask2)
    return loss


# --------------------------------------------------------------------- decode
def cache_shapes(cfg: LMConfig, batch: int, s_max: int) -> Any:
    stages = []
    for st in cfg.stages:
        layers = {}
        for i, spec in enumerate(st.layers):
            shapes = layer_cache_shape(cfg, spec, batch, s_max)
            layers[f"layer{i}"] = _stack_shapes(shapes, st.repeat)
        stages.append(layers)
    return {"stages": stages}


def abstract_cache(cfg: LMConfig, batch: int, s_max: int) -> Any:
    def leaf(path, s):
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        return jax.ShapeDtypeStruct(tuple(s), _cache_dtype(cfg, name))
    return jax.tree_util.tree_map_with_path(
        leaf, cache_shapes(cfg, batch, s_max),
        is_leaf=lambda x: isinstance(x, tuple))


def init_cache(cfg: LMConfig, batch: int, s_max: int) -> Any:
    def leaf(path, s):
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        return jnp.zeros(tuple(s), _cache_dtype(cfg, name))
    return jax.tree_util.tree_map_with_path(
        leaf, cache_shapes(cfg, batch, s_max),
        is_leaf=lambda x: isinstance(x, tuple))


def decode_step(cfg: LMConfig, params, cache, tokens: jnp.ndarray,
                pos: jnp.ndarray) -> Tuple[jnp.ndarray, Any]:
    """One decode step. tokens (B, 1[,K]) int32; pos: scalar int32 (absolute
    position of this token). Returns (logits (B, 1, V[·K]), new cache)."""
    h = embed_tokens(cfg, params, tokens, positions=pos[None])
    h = annotate(h, "batch", None, "embed")
    new_stage_caches = []
    for st, st_params, st_cache in zip(cfg.stages, params["stages"],
                                       cache["stages"]):
        def body(x, xs):
            layer_p, layer_c = xs
            new_c = {}
            for i, spec in enumerate(st.layers):
                x, c = layer_decode(cfg, spec, layer_p[f"layer{i}"], x,
                                    layer_c[f"layer{i}"], pos)
                new_c[f"layer{i}"] = c
            return x, new_c
        h, new_c = jax.lax.scan(body, h, (st_params, st_cache))
        new_stage_caches.append(new_c)
    h = apply_norm(cfg, h, params["final_norm"])
    logits = head_logits(cfg, params, h)
    return logits, {"stages": new_stage_caches}
