"""Mixture-of-Experts FFN (DeepSeek-style: shared + routed, top-k).

Dispatch is the Mesh-TF/MaxText einsum formulation: top-k routing produces a
capacity-bucketed one-hot dispatch tensor; expert compute is a batched
(E, C, d)×(E, d, f) einsum. Under EP sharding (experts on the "model" mesh
axis, tokens on "data") the dispatch/combine einsums lower to all-to-alls —
the canonical MoE collective pattern — with no manual communication code.
Tokens over capacity C = ceil(T·k/E · cf) are dropped (residual passthrough),
standard for capacity-based routing.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.dist import annotate
from repro.models.lm.common import activation


def moe_params_shape(cfg):
    d, e, f = cfg.d_model, cfg.moe_num_experts, cfg.moe_d_ff
    shapes = {
        "router": (d, e),
        "we_in": (e, d, f), "we_gate": (e, d, f), "we_out": (e, f, d),
    }
    if cfg.moe_num_shared:
        fs = cfg.moe_d_ff * cfg.moe_num_shared
        shapes.update({"sh_in": (d, fs), "sh_gate": (d, fs), "sh_out": (fs, d)})
    return shapes


def moe_capacity(cfg, tokens: int) -> int:
    c = math.ceil(tokens * cfg.moe_top_k / cfg.moe_num_experts
                  * cfg.moe_capacity_factor)
    return max(8, int(math.ceil(c / 8) * 8))


import os


def moe_forward(cfg, p: Dict, x: jnp.ndarray) -> jnp.ndarray:
    """x: (B, S, D) → (B, S, D).

    Dispatch impls (env REPRO_MOE_DISPATCH, default "einsum"):
      einsum — Mesh-TF one-hot dispatch. O(T·E·C·D) dispatch/combine matmuls:
               FLOP-faithful to the classic formulation but wasteful (§Perf
               baseline).
      sort   — sort tokens by expert id, scatter into the (E, C, D) capacity
               buffer, gather back. Dispatch cost collapses from matmul FLOPs
               to O(T·k·D) data movement (§Perf optimized).
    """
    mode = os.environ.get("REPRO_MOE_DISPATCH", "einsum")
    if mode == "shmap":
        return _moe_forward_shmap(cfg, p, x)
    if mode == "sort":
        return _moe_forward_sort(cfg, p, x)
    return _moe_forward_einsum(cfg, p, x)


def _shared_out(cfg, p, xt):
    sh = activation(cfg, xt @ p["sh_gate"]) * (xt @ p["sh_in"])
    return sh @ p["sh_out"]


def _moe_forward_einsum(cfg, p: Dict, x: jnp.ndarray) -> jnp.ndarray:
    b, s, d = x.shape
    e, k = cfg.moe_num_experts, cfg.moe_top_k
    t = b * s
    xt = x.reshape(t, d)
    cap = moe_capacity(cfg, t)

    logits = (xt @ p["router"]).astype(jnp.float32)      # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)               # (T, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # capacity positions: for each expert, order tokens by arrival
    onehot = jax.nn.one_hot(top_e, e, dtype=jnp.float32)         # (T, k, E)
    pos_in_e = jnp.cumsum(onehot.sum(1), axis=0) - onehot.sum(1)  # (T, E)
    pos = jnp.einsum("tke,te->tk", onehot, pos_in_e)              # (T, k)
    keep = pos < cap
    gate = top_p * keep

    # dispatch/combine tensors (T, E, C)
    pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=jnp.float32)  # (T,k,C)
    dispatch = jnp.einsum("tke,tkc->tec", onehot * keep[..., None], pos_oh)
    combine = jnp.einsum("tke,tkc,tk->tec", onehot, pos_oh, gate)

    xin = jnp.einsum("tec,td->ecd", dispatch, xt.astype(jnp.float32))       # (E,C,D)
    xin = annotate(xin, "expert", None, None)
    hgate = jnp.einsum("ecd,edf->ecf", xin, p["we_gate"].astype(jnp.float32))
    hin = jnp.einsum("ecd,edf->ecf", xin, p["we_in"].astype(jnp.float32))
    hact = activation(cfg, hgate) * hin
    eout = jnp.einsum("ecf,efd->ecd", hact, p["we_out"].astype(jnp.float32))
    eout = annotate(eout, "expert", None, None)
    out = jnp.einsum("tec,ecd->td", combine, eout)                          # (T,D)

    if cfg.moe_num_shared:
        out = out + _shared_out(cfg, p, xt).astype(out.dtype)
    return out.reshape(b, s, d).astype(x.dtype)


def _moe_forward_sort(cfg, p: Dict, x: jnp.ndarray) -> jnp.ndarray:
    """Sort-based capacity dispatch: no one-hot matmuls.

    1. route: top-k experts per token.
    2. sort the T·k (expert, token) assignments by expert id.
    3. position-in-expert = rank − first_rank_of_expert (searchsorted on the
       sorted ids); drop positions ≥ capacity.
    4. scatter token vectors into the (E, C, D) buffer (data movement only),
       run the batched expert matmuls, gather back, weight by gate,
       segment-sum the k copies per token.
    Under EP sharding the scatter/gather to the expert-sharded buffer lowers
    to the same all-to-all pattern as einsum dispatch — without the
    O(T·E·C·D) dispatch FLOPs.
    """
    b, s, d = x.shape
    e, k = cfg.moe_num_experts, cfg.moe_top_k
    t = b * s
    xt = x.reshape(t, d)
    cap = moe_capacity(cfg, t)

    logits = (xt @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)               # (T, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    flat_e = top_e.reshape(-1)                           # (T·k,)
    flat_g = top_p.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)

    order = jnp.argsort(flat_e, stable=True)
    se = flat_e[order]
    st_ = flat_tok[order]
    sg = flat_g[order]
    first = jnp.searchsorted(se, se, side="left")        # first rank of expert
    pos = jnp.arange(t * k, dtype=jnp.int32) - first.astype(jnp.int32)
    keep = pos < cap
    pos_c = jnp.where(keep, pos, 0)
    eid_c = jnp.where(keep, se, 0).astype(jnp.int32)

    # scatter tokens into the capacity buffer (E, C, D)
    buf = jnp.zeros((e, cap, d), xt.dtype)
    buf = buf.at[eid_c, pos_c].set(
        jnp.where(keep[:, None], xt[st_], 0), mode="drop")
    buf = annotate(buf, "expert", None, None)

    h_gate = jnp.einsum("ecd,edf->ecf", buf.astype(jnp.float32),
                        p["we_gate"].astype(jnp.float32))
    h_in = jnp.einsum("ecd,edf->ecf", buf.astype(jnp.float32),
                      p["we_in"].astype(jnp.float32))
    eout = jnp.einsum("ecf,efd->ecd", activation(cfg, h_gate) * h_in,
                      p["we_out"].astype(jnp.float32))
    eout = annotate(eout, "expert", None, None)

    # gather back and combine the k expert outputs per token
    per_assign = eout[eid_c, pos_c] * (sg * keep)[:, None]     # (T·k, D)
    out = jax.ops.segment_sum(per_assign, st_, num_segments=t)
    if cfg.moe_num_shared:
        out = out + _shared_out(cfg, p, xt).astype(out.dtype)
    return out.reshape(b, s, d).astype(x.dtype)


def _local_dispatch(cfg, router, xt, cap):
    """Local routing + capacity-bucketed send buffer (pure, per-shard)."""
    t, d = xt.shape
    e, k = cfg.moe_num_experts, cfg.moe_top_k
    logits = (xt @ router).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    flat_e = top_e.reshape(-1)
    flat_g = top_p.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    order = jnp.argsort(flat_e, stable=True)
    se, st_, sg = flat_e[order], flat_tok[order], flat_g[order]
    first = jnp.searchsorted(se, se, side="left")
    pos = (jnp.arange(t * k, dtype=jnp.int32) - first.astype(jnp.int32))
    keep = pos < cap
    pos_c = jnp.where(keep, pos, 0)
    eid_c = jnp.where(keep, se, 0).astype(jnp.int32)
    # exchange payload stays in the model dtype (bf16): halves a2a bytes;
    # expert matmuls accumulate in f32 via preferred_element_type
    send = jnp.zeros((e, cap, d), xt.dtype)
    send = send.at[eid_c, pos_c].set(
        jnp.where(keep[:, None], xt[st_], jnp.zeros((), xt.dtype)), mode="drop")
    return send, (eid_c, pos_c, st_, sg, keep)


def _moe_forward_shmap(cfg, p: Dict, x: jnp.ndarray) -> jnp.ndarray:
    """Production MoE: shard_map with explicit all_to_all expert parallelism.

    Per device: LOCAL top-k routing and sort-based bucketing (no global sort,
    no one-hot matmuls) → tiled all_to_all over the "model" (EP) axis sends
    each expert's bucket to its owner → batched local expert matmuls →
    reverse all_to_all → local combine. Collective volume per device per
    layer = 2 · E·C_send·D — the minimal EP exchange.
    """
    from repro.dist.logical import current_mesh
    mesh = current_mesh()
    if mesh is None or "model" not in mesh.axis_names \
            or cfg.moe_num_experts % mesh.shape["model"] != 0:
        return _moe_forward_sort(cfg, p, x)
    from jax.sharding import PartitionSpec as P
    from jax import shard_map

    b, s, d = x.shape
    e, k = cfg.moe_num_experts, cfg.moe_top_k
    ep = mesh.shape["model"]
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    # per-shard token count decides the SEND capacity
    b_loc = b // _axis_prod(mesh, dp)
    s_loc = s // ep if s % ep == 0 else s
    t_loc = max(b_loc, 1) * s_loc
    cap = moe_capacity(cfg, t_loc)

    def local_fn(router, we_in, we_gate, we_out, xl):
        bl, sl, _ = xl.shape
        xt = xl.reshape(bl * sl, d)
        send, (eid_c, pos_c, st_, sg, keep) = _local_dispatch(
            cfg, router, xt, cap)
        # (E, C, D) → (E_local, ep·C, D): experts to their owner shard
        recv = jax.lax.all_to_all(send, "model", split_axis=0,
                                  concat_axis=1, tiled=True)
        hg = jnp.einsum("ecd,edf->ecf", recv, we_gate,
                        preferred_element_type=jnp.float32)
        hi = jnp.einsum("ecd,edf->ecf", recv, we_in,
                        preferred_element_type=jnp.float32)
        eo = jnp.einsum("ecf,efd->ecd",
                        (activation(cfg, hg) * hi).astype(recv.dtype),
                        we_out, preferred_element_type=jnp.float32)
        # reverse exchange: (E_local, ep·C, D) → (E, C, D), bf16 payload
        back = jax.lax.all_to_all(eo.astype(recv.dtype), "model",
                                  split_axis=1, concat_axis=0, tiled=True)
        per_assign = back[eid_c, pos_c].astype(jnp.float32) * \
            (sg * keep)[:, None]
        out = jax.ops.segment_sum(per_assign, st_, num_segments=bl * sl)
        return out.reshape(bl, sl, d).astype(xl.dtype)

    seq_ax = "model" if s % ep == 0 else None
    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(), P("model", None, None), P("model", None, None),
                  P("model", None, None), P(dp, seq_ax, None)),
        out_specs=P(dp, seq_ax, None),
        check_vma=False)
    out = fn(p["router"], p["we_in"], p["we_gate"], p["we_out"], x)
    if cfg.moe_num_shared:
        xt = x.reshape(-1, d)
        out = out + _shared_out(cfg, p, xt).reshape(b, s, d).astype(out.dtype)
    return out


def _axis_prod(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def moe_router_stats(cfg, p: Dict, x: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """Load-balance diagnostics (aux-loss-style fraction per expert)."""
    b, s, d = x.shape
    logits = (x.reshape(-1, d) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    _, top_e = jax.lax.top_k(probs, cfg.moe_top_k)
    frac = jnp.bincount(top_e.reshape(-1), length=cfg.moe_num_experts
                        ).astype(jnp.float32) / top_e.size
    return {"expert_fraction": frac, "mean_prob": probs.mean(0)}
