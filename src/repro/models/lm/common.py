"""Shared LM building blocks: norms, rope, init helpers, activation."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def layer_norm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
               eps: float = 1e-5) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    mu = x32.mean(axis=-1, keepdims=True)
    var = ((x32 - mu) ** 2).mean(axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale + bias


def apply_norm(cfg, x: jnp.ndarray, p: dict) -> jnp.ndarray:
    if cfg.norm == "rmsnorm":
        return rms_norm(x, p["scale"], cfg.norm_eps)
    return layer_norm(x, p["scale"], p["bias"], cfg.norm_eps)


def norm_params(cfg, d: int, dtype) -> dict:
    if cfg.norm == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def activation(cfg, x: jnp.ndarray) -> jnp.ndarray:
    if cfg.act == "silu":
        return jax.nn.silu(x)
    return jax.nn.gelu(x)


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, D); positions: (S,) or (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                          # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]                   # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_embed(positions: jnp.ndarray, d_model: int) -> jnp.ndarray:
    """(S,) → (S, D) classic transformer sinusoidal position embedding."""
    half = d_model // 2
    freqs = jnp.exp(-np.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[:, None].astype(jnp.float32) * freqs[None]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    std = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


class KeyGen:
    """Deterministic key splitter so init order changes don't ripple."""

    def __init__(self, key):
        self.key = key
        self.count = 0

    def __call__(self):
        self.count += 1
        return jax.random.fold_in(self.key, self.count)
