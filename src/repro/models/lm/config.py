"""Unified LM-family architecture config.

One config type covers all 10 assigned architectures: dense GQA/MQA
transformers, MLA+MoE (DeepSeek), attention-free RWKV6, hybrid RG-LRU
(RecurrentGemma), multi-codebook audio decoders (MusicGen) and VLM backbones
(InternVL). A model is a sequence of STAGES; each stage is `repeat` copies of
a short layer pattern and is lowered as ONE lax.scan over stacked parameters
(keeps HLO size and compile time independent of depth).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: str          # "gqa" | "local" | "mla" | "rglru" | "rwkv6"
    ffn: str            # "dense" | "moe" | "rwkv_cmix"


@dataclasses.dataclass(frozen=True)
class Stage:
    layers: Tuple[LayerSpec, ...]   # the pattern applied sequentially
    repeat: int                     # scanned `repeat` times


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    stages: Tuple[Stage, ...]
    head_dim: int = 0                 # 0 → d_model // num_heads
    # attention details
    qkv_bias: bool = False
    rope_theta: float = 500_000.0
    pos_embed: str = "rope"           # rope | sinusoidal | none
    window: int = 0                   # sliding-window size for "local" mixer
    logit_softcap: float = 0.0
    # MLA (DeepSeek)
    q_lora_rank: int = 0              # 0 → direct q projection
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    # MoE
    moe_num_experts: int = 0
    moe_top_k: int = 0
    moe_num_shared: int = 0
    moe_d_ff: int = 0
    moe_capacity_factor: float = 1.25
    # multi-token prediction (DeepSeek-V3)
    mtp_depth: int = 0
    # RWKV6
    rwkv_head_dim: int = 64
    rwkv_lora_dim: int = 64
    # RG-LRU (RecurrentGemma)
    rnn_width: int = 0                # 0 → d_model
    conv_width: int = 4
    # modality frontends (stubs per assignment)
    num_codebooks: int = 1            # MusicGen EnCodec codebooks
    vision_prefix_len: int = 0        # InternVL patch-embedding prefix
    # misc
    norm: str = "rmsnorm"             # rmsnorm | layernorm
    act: str = "silu"                 # silu | gelu
    glu: bool = True                  # gated FFN (SwiGLU/GeGLU)
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    norm_eps: float = 1e-5

    # ---------- derived ----------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def num_layers(self) -> int:
        return sum(len(s.layers) * s.repeat for s in self.stages)

    @property
    def is_subquadratic(self) -> bool:
        """True iff no layer does full-context attention (long_500k eligible)."""
        for s in self.stages:
            for l in s.layers:
                if l.mixer in ("gqa", "mla"):
                    return False
        return True

    @property
    def qk_head_dim(self) -> int:
        """Per-head q/k dim for MLA (nope + rope) or standard heads."""
        if self.qk_nope_head_dim:
            return self.qk_nope_head_dim + self.qk_rope_head_dim
        return self.resolved_head_dim

    def param_count(self) -> int:
        """Exact parameter count from the init shapes (host-side, cheap)."""
        import jax
        import numpy as np
        from repro.models.lm.model import abstract_params
        tree = abstract_params(self)
        return int(sum(np.prod(l.shape) for l in jax.tree_util.tree_leaves(tree)))

    def active_param_count(self) -> int:
        """Active params per token (MoE: shared + top_k routed only)."""
        import jax
        import numpy as np
        from repro.models.lm.model import abstract_params
        tree = abstract_params(self)
        total = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
            keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
            size = int(np.prod(leaf.shape))
            if any("experts" in str(k) for k in keys) and self.moe_num_experts:
                size = size // self.moe_num_experts * self.moe_top_k
            total += size
        return total


def dense_stages(num_layers: int, mixer: str = "gqa") -> Tuple[Stage, ...]:
    return (Stage((LayerSpec(mixer, "dense"),), num_layers),)
