"""Transformer layer assembly: (mixer, ffn) per LayerSpec, pre-norm residual.

Provides three things per layer spec:
  * param SHAPE tree (pure dict of tuples — materialized by model.init/abstract)
  * full-sequence apply (train / prefill)
  * single-token decode apply with functional cache
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist import annotate
from repro.models.lm.common import apply_norm, norm_params, activation
from repro.models.lm.config import LayerSpec
from repro.models.lm import attention as attn
from repro.models.lm import moe as moe_mod
from repro.models.lm import rglru as rglru_mod
from repro.models.lm import rwkv as rwkv_mod


# --------------------------------------------------------------- shape trees
def _norm_shape(cfg):
    if cfg.norm == "rmsnorm":
        return {"scale": (cfg.d_model,)}
    return {"scale": (cfg.d_model,), "bias": (cfg.d_model,)}


def ffn_params_shape(cfg):
    d, f = cfg.d_model, cfg.d_ff
    if cfg.glu:
        return {"w_in": (d, f), "w_gate": (d, f), "w_out": (f, d)}
    return {"w_in": (d, f), "w_out": (f, d)}


def layer_param_shapes(cfg, spec: LayerSpec) -> Dict:
    shapes: Dict = {"norm1": _norm_shape(cfg)}
    if spec.mixer in ("gqa", "local"):
        shapes["mixer"] = attn.gqa_params_shape(cfg)
    elif spec.mixer == "mla":
        shapes["mixer"] = attn.mla_params_shape(cfg)
    elif spec.mixer == "rglru":
        shapes["mixer"] = rglru_mod.rglru_params_shape(cfg)
    elif spec.mixer == "rwkv6":
        shapes["mixer"] = rwkv_mod.rwkv_params_shape(cfg)
    else:
        raise ValueError(spec.mixer)
    shapes["norm2"] = _norm_shape(cfg)
    if spec.ffn == "dense":
        shapes["ffn"] = ffn_params_shape(cfg)
    elif spec.ffn == "moe":
        shapes["ffn"] = moe_mod.moe_params_shape(cfg)
    elif spec.ffn == "rwkv_cmix":
        shapes["ffn"] = {}      # channel-mix params live in the rwkv mixer dict
    else:
        raise ValueError(spec.ffn)
    return shapes


# ------------------------------------------------------------------- applies
def ffn_forward(cfg, p: Dict, x: jnp.ndarray) -> jnp.ndarray:
    h = x @ p["w_in"]
    if cfg.glu:
        h = activation(cfg, x @ p["w_gate"]) * h
    else:
        h = activation(cfg, h)
    h = annotate(h, "batch", "seq", "mlp")
    return h @ p["w_out"]


def layer_forward(cfg, spec: LayerSpec, p: Dict, x: jnp.ndarray,
                  positions: jnp.ndarray) -> jnp.ndarray:
    """Full-sequence layer. x: (B, S, D)."""
    h = apply_norm(cfg, x, p["norm1"])
    if spec.mixer == "gqa":
        mix = attn.gqa_forward(cfg, p["mixer"], h, positions)
    elif spec.mixer == "local":
        mix = attn.gqa_forward(cfg, p["mixer"], h, positions, window=cfg.window)
    elif spec.mixer == "mla":
        mix = attn.mla_forward(cfg, p["mixer"], h, positions)
    elif spec.mixer == "rglru":
        mix = rglru_mod.rglru_forward(cfg, p["mixer"], h)
    elif spec.mixer == "rwkv6":
        mix, _ = rwkv_mod.rwkv_time_mix(cfg, p["mixer"], h)
    else:
        raise ValueError(spec.mixer)
    x = x + mix
    h = apply_norm(cfg, x, p["norm2"])
    if spec.ffn == "dense":
        x = x + ffn_forward(cfg, p["ffn"], h)
    elif spec.ffn == "moe":
        x = x + moe_mod.moe_forward(cfg, p["ffn"], h)
    elif spec.ffn == "rwkv_cmix":
        out, _ = rwkv_mod.rwkv_channel_mix(cfg, p["mixer"], h)
        x = x + out
    x = annotate(x, "batch", "seq", "embed")
    return x


def layer_cache_shape(cfg, spec: LayerSpec, batch: int, s_max: int) -> Dict:
    if spec.mixer == "gqa":
        return attn.gqa_cache_shape(cfg, batch, s_max)
    if spec.mixer == "local":
        return attn.gqa_cache_shape(cfg, batch, s_max, window=cfg.window)
    if spec.mixer == "mla":
        return attn.mla_cache_shape(cfg, batch, s_max)
    if spec.mixer == "rglru":
        return rglru_mod.rglru_cache_shape(cfg, batch)
    if spec.mixer == "rwkv6":
        return rwkv_mod.rwkv_cache_shape(cfg, batch)
    raise ValueError(spec.mixer)


def _cache_dtype(cfg, name: str):
    # recurrent states stay fp32 (stability); kv caches use model dtype
    return jnp.float32 if name in ("wkv", "shift_t", "shift_c", "h", "conv") \
        else jnp.dtype(cfg.dtype)


def layer_decode(cfg, spec: LayerSpec, p: Dict, x: jnp.ndarray,
                 cache: Dict, pos: jnp.ndarray) -> Tuple[jnp.ndarray, Dict]:
    """Single-token decode. x: (B, 1, D)."""
    h = apply_norm(cfg, x, p["norm1"])
    if spec.mixer == "gqa":
        mix, cache_m = attn.gqa_decode(cfg, p["mixer"], h, cache, pos)
    elif spec.mixer == "local":
        mix, cache_m = attn.gqa_decode(cfg, p["mixer"], h, cache, pos,
                                       window=cfg.window)
    elif spec.mixer == "mla":
        mix, cache_m = attn.mla_decode(cfg, p["mixer"], h, cache, pos)
    elif spec.mixer == "rglru":
        mix, st = rglru_mod.rglru_decode(cfg, p["mixer"], h,
                                         {"h": cache["h"], "conv": cache["conv"]}, pos)
        cache_m = st
    elif spec.mixer == "rwkv6":
        # single-step time mix via the chunked path with C = 1
        mix, st = rwkv_mod.rwkv_time_mix(
            cfg, p["mixer"], h, chunk=1,
            state={"wkv": cache["wkv"], "shift_t": cache["shift_t"]})
        cache_m = {"wkv": st["wkv"], "shift_t": st["shift_t"],
                   "shift_c": cache["shift_c"]}
    else:
        raise ValueError(spec.mixer)
    x = x + mix
    h = apply_norm(cfg, x, p["norm2"])
    if spec.ffn == "dense":
        x = x + ffn_forward(cfg, p["ffn"], h)
    elif spec.ffn == "moe":
        x = x + moe_mod.moe_forward(cfg, p["ffn"], h)
    elif spec.ffn == "rwkv_cmix":
        out, shift_c = rwkv_mod.rwkv_channel_mix(cfg, p["mixer"], h,
                                                 state=cache["shift_c"])
        x = x + out
        cache_m = dict(cache_m, shift_c=shift_c)
    return x, cache_m
