"""RWKV6 "Finch" (arXiv:2404.05892): attention-free time mix with
data-dependent per-channel decay + squared-ReLU channel mix.

Recurrence per head (k-dim dk, v-dim dv, state S ∈ R^{dk×dv}):

    o_t = Sᵀ r_t + (r_t · (u ⊙ k_t)) v_t
    S   ← diag(w_t) S + k_t v_tᵀ

TPU adaptation — CHUNKED linear attention: within a chunk of length C the
contribution is an (C×C) masked "attention" with decay weights; across chunks
the state is carried by lax.scan. All decay products are computed as
exp(L_i − L_j) with L = cumsum(log w) ≤ 0 and i ≥ j, so every factor is ≤ 1 —
no under/overflow at any chunk size (this replaces the CUDA kernel's
sequential in-register scan; see DESIGN.md §3). Cost: O(S·C·(dk+dv)) per
channel — sub-quadratic, and decode keeps an O(dk·dv) state ⇒ long_500k runs.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.dist import annotate

_MIX = ("r", "k", "v", "w", "g")


def rwkv_params_shape(cfg):
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    h = d // hd
    lo = cfg.rwkv_lora_dim
    return {
        # time-mix
        "mu": (len(_MIX), d), "mu_base": (d,),
        "lora_a": (d, len(_MIX) * lo), "lora_b": (len(_MIX), lo, d),
        "w_base": (d,), "wa_w": (d, lo), "wb_w": (lo, d),
        "wr": (d, d), "wk": (d, d), "wv": (d, d), "wg": (d, d), "wo": (d, d),
        "u": (h, hd),
        "ln_x_scale": (d,), "ln_x_bias": (d,),
        # channel-mix
        "cmix_mu_k": (d,), "cmix_mu_r": (d,),
        "ck": (d, cfg.d_ff), "cv": (cfg.d_ff, d), "cr": (d, d),
    }


def _token_shift(x: jnp.ndarray, last: jnp.ndarray = None) -> jnp.ndarray:
    """x_{t-1} (zero/state-filled at t=0). x: (B, S, D)."""
    if last is None:
        return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    return jnp.concatenate([last[:, None], x[:, :-1]], axis=1)


def _ddlerp(p, x, xx):
    """Data-dependent mixing for r/k/v/w/g (RWKV6 'ddlerp')."""
    lo = p["lora_b"].shape[1]
    base = x + xx * p["mu_base"]
    lora = jnp.tanh(base @ p["lora_a"])                    # (B,S,5*lo)
    lora = lora.reshape(*lora.shape[:-1], len(_MIX), lo)
    delta = jnp.einsum("bsml,mld->bsmd", lora, p["lora_b"])  # (B,S,5,D)
    mixed = x[..., None, :] + xx[..., None, :] * (p["mu"] + delta)
    return {m: mixed[..., i, :] for i, m in enumerate(_MIX)}


def _decay(p, xw):
    """log w_t ∈ [−5, 0): w = exp(−exp(w_base + lora_w(x))).

    The upper clip bounds per-step log-decay at −5 (w ≥ 6.7e-3), which makes
    the FACTORED chunk formulation overflow-safe for chunks ≤ 16
    (e^{|logw|·C} ≤ e^{80} < f32 max) — same spirit as the clamps in the
    reference CUDA kernels. §Perf A3."""
    lw = p["w_base"] + jnp.tanh(xw @ p["wa_w"]) @ p["wb_w"]
    return -jnp.exp(jnp.clip(lw, -10.0, 1.609))            # log-decay ∈ [−5, 0)


def _wkv_chunk(r, k, v, logw, u, state, factored: bool = False):
    """One chunk. r/k: (B,H,C,dk), v: (B,H,C,dv), logw: (B,H,C,dk),
    state: (B,H,dk,dv). Returns (out (B,H,C,dv), new_state).

    factored=True (§Perf A3): A = (r·e^{L_prev}) @ (k·e^{−L})ᵀ — a plain C×C
    dot instead of a (C,C,dk) pairwise-exp tensor. Mathematically identical;
    needs the decay clamp in `_decay` so e^{−L} stays finite (chunks ≤ 16)."""
    b, h, c, dk = r.shape
    L = jnp.cumsum(logw, axis=2)                            # (B,H,C,dk)
    L_prev = L - logw                                       # exclusive cumsum
    # state contribution: o_i += Sᵀ (e^{L_prev_i} ⊙ r_i)
    r_dec = r * jnp.exp(L_prev)
    out_state = jnp.einsum("bhcd,bhde->bhce", r_dec, state)
    mask = jnp.tril(jnp.ones((c, c), bool), k=-1)
    if factored:
        k_dec = k * jnp.exp(-L)
        A = jnp.einsum("bhid,bhjd->bhij", r_dec, k_dec)
        A = jnp.where(mask[None, None], A, 0.0)
    else:
        # intra-chunk: A_ij = Σ_c r_ic k_jc e^{L_prev_i,c − L_j,c}   (j < i)
        expo = L_prev[:, :, :, None, :] - L[:, :, None, :, :]  # (B,H,i,j,dk)
        expo = jnp.where(mask[None, None, :, :, None], expo, -1e30)
        A = jnp.einsum("bhid,bhjd,bhijd->bhij", r, k, jnp.exp(expo))
    # diagonal bonus term: (r_i · (u ⊙ k_i)) v_i
    diag = jnp.einsum("bhcd,bhcd->bhc", r, k * u[None, :, None, :])
    out = out_state + jnp.einsum("bhij,bhje->bhie", A, v) + diag[..., None] * v
    # state update: S' = e^{L_C} ⊙ S + Σ_j (e^{L_C − L_j} ⊙ k_j) v_jᵀ
    Lc = L[:, :, -1]                                        # (B,H,dk)
    k_dec = k * jnp.exp(Lc[:, :, None, :] - L)
    new_state = jnp.exp(Lc)[..., None] * state + \
        jnp.einsum("bhjd,bhje->bhde", k_dec, v)
    return out, new_state


def rwkv_time_mix(cfg, p: Dict, x: jnp.ndarray, chunk: int = None,
                  state: Dict = None) -> Tuple[jnp.ndarray, Dict]:
    """Full-sequence time mix. x: (B, S, D). Returns (out, final_state).
    Chunk length C trades intra-chunk O(S·C·dk) work/memory against
    (S/C)·dk·dv state traffic — env REPRO_RWKV_CHUNK tunes it (§Perf)."""
    if chunk is None:
        import os
        chunk = int(os.environ.get("REPRO_RWKV_CHUNK", "64"))
    b, s, d = x.shape
    hd = cfg.rwkv_head_dim
    h = d // hd
    xf = x.astype(jnp.float32)
    last = None if state is None else state["shift_t"]
    xx = _token_shift(xf, last) - xf
    mixed = _ddlerp({k_: p[k_].astype(jnp.float32) for k_ in
                     ("mu", "mu_base", "lora_a", "lora_b")}, xf, xx)
    r = (mixed["r"] @ p["wr"].astype(jnp.float32)).reshape(b, s, h, hd)
    k = (mixed["k"] @ p["wk"].astype(jnp.float32)).reshape(b, s, h, hd)
    v = (mixed["v"] @ p["wv"].astype(jnp.float32)).reshape(b, s, h, hd)
    g = jax.nn.silu(mixed["g"] @ p["wg"].astype(jnp.float32))
    logw = _decay({k_: p[k_].astype(jnp.float32) for k_ in
                   ("w_base", "wa_w", "wb_w")}, mixed["w"]).reshape(b, s, h, hd)

    import os
    from repro.models.lm.attention import pick_chunk
    c = pick_chunk(s, chunk)
    # §Perf A4: two-level chunking. The scan saves its carry STATE per
    # iteration for backward (inherent); macro-chunks keep that count small
    # while micro-chunks keep the factored intra math overflow-safe.
    macro = pick_chunk(s, int(os.environ.get("REPRO_RWKV_MACRO", str(c))))
    macro = max(macro, c)
    n_macro = s // macro
    n_micro = macro // c
    def to_chunks(t):
        return t.reshape(b, n_macro, macro, h, hd).transpose(1, 0, 3, 2, 4)
    rc, kc, vc, wc = map(to_chunks, (r, k, v, logw))   # (nM,B,H,Cm,hd)
    s0 = jnp.zeros((b, h, hd, hd), jnp.float32) if state is None \
        else state["wkv"]

    u = p["u"].astype(jnp.float32)
    # factored intra-chunk math is overflow-safe only for C·|logw|max ≤ ~80
    # (decay clamp −5) ⇒ C ≤ 16; silently fall back to pairwise otherwise
    factored = os.environ.get("REPRO_RWKV_FACTORED", "0") == "1" and c <= 16

    def body(st, xs):
        rr, kk, vv, ww = xs                            # (B,H,Cm,hd)
        outs = []
        for i in range(n_micro):                       # unrolled micro loop
            sl = slice(i * c, (i + 1) * c)
            o, st = _wkv_chunk(rr[:, :, sl], kk[:, :, sl], vv[:, :, sl],
                               ww[:, :, sl], u, st, factored=factored)
            outs.append(o)
        return st, jnp.concatenate(outs, axis=2) if n_micro > 1 else outs[0]

    if os.environ.get("REPRO_RWKV_REMAT", "0") == "1":
        # §Perf A2: recompute chunk intermediates in backward — without this
        # the scan stacks (nc, B, H, C, C, dk) residuals across ALL chunks.
        body = jax.checkpoint(body)
    s_fin, outs = jax.lax.scan(body, s0, (rc, kc, vc, wc))
    out = outs.transpose(1, 0, 3, 2, 4).reshape(b, s, d)            # (B,S,D)
    # per-head group norm, then gate and project
    out = out.reshape(b, s, h, hd)
    mu = out.mean(-1, keepdims=True)
    var = ((out - mu) ** 2).mean(-1, keepdims=True)
    out = ((out - mu) * jax.lax.rsqrt(var + 64e-5)).reshape(b, s, d)
    out = out * p["ln_x_scale"].astype(jnp.float32) + p["ln_x_bias"].astype(jnp.float32)
    out = (out * g) @ p["wo"].astype(jnp.float32)
    new_state = {"wkv": s_fin, "shift_t": xf[:, -1]}
    return out.astype(x.dtype), new_state


def rwkv_channel_mix(cfg, p: Dict, x: jnp.ndarray, state: Dict = None
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    xf = x.astype(jnp.float32)
    last = None if state is None else state
    xx = _token_shift(xf, last) - xf
    xk = xf + xx * p["cmix_mu_k"].astype(jnp.float32)
    xr = xf + xx * p["cmix_mu_r"].astype(jnp.float32)
    k = jnp.square(jax.nn.relu(xk @ p["ck"].astype(jnp.float32)))
    r = jax.nn.sigmoid(xr @ p["cr"].astype(jnp.float32))
    out = r * (k @ p["cv"].astype(jnp.float32))
    return out.astype(x.dtype), xf[:, -1]


def rwkv_cache_shape(cfg, batch: int):
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    h = d // hd
    return {"wkv": (batch, h, hd, hd), "shift_t": (batch, d),
            "shift_c": (batch, d)}
