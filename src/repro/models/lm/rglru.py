"""RG-LRU recurrent block (RecurrentGemma, arXiv:2402.19427).

Block: x → [W_main → conv1d(w=4, causal, depthwise) → RG-LRU] ⊙ gelu(W_gate)
→ W_out. The RG-LRU diagonal recurrence

    r_t = σ(W_a x_t + b_a)            (recurrence gate)
    i_t = σ(W_i x_t + b_i)            (input gate)
    log a_t = −c · softplus(Λ) ⊙ r_t  (c = 8)
    h_t = a_t ⊙ h_{t-1} + √(1 − a_t²) ⊙ (i_t ⊙ x_t)

is ELEMENTWISE, so train/prefill lowers to jax.lax.associative_scan over time
(log₂S depth on TPU) and decode is a single fused elementwise step with an
O(B·width) state — this is why the arch runs the long_500k cell.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.dist import annotate

_C = 8.0


def rglru_params_shape(cfg):
    d, w = cfg.d_model, cfg.rnn_width or cfg.d_model
    return {
        "w_main": (d, w), "w_gate": (d, w), "w_out": (w, d),
        "conv_w": (cfg.conv_width, w), "conv_b": (w,),
        "wa": (w, w), "ba": (w,), "wi": (w, w), "bi": (w,),
        "lam": (w,),
    }


def _gates(p, x):
    r = jax.nn.sigmoid(x @ p["wa"] + p["ba"])
    i = jax.nn.sigmoid(x @ p["wi"] + p["bi"])
    log_a = -_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9)) * (i * x)
    return a, gated


def _causal_conv(p, x):
    """Depthwise causal conv over time. x: (B, S, W)."""
    w = p["conv_w"].shape[0]
    xp = jnp.pad(x, ((0, 0), (w - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1]] * p["conv_w"][i] for i in range(w))
    return out + p["conv_b"]


def rglru_forward(cfg, p: Dict, x: jnp.ndarray) -> jnp.ndarray:
    """Full-sequence. x: (B, S, D) → (B, S, D)."""
    xf = x.astype(jnp.float32)
    main = xf @ p["w_main"].astype(jnp.float32)
    main = _causal_conv({k: p[k].astype(jnp.float32) for k in ("conv_w", "conv_b")}, main)
    a, b = _gates({k: p[k].astype(jnp.float32) for k in ("wa", "ba", "wi", "bi", "lam")}, main)
    a = annotate(a, "batch", "seq", None)

    def combine(l, r):
        a1, b1 = l
        a2, b2 = r
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    gate = jax.nn.gelu(xf @ p["w_gate"].astype(jnp.float32))
    out = (h * gate) @ p["w_out"].astype(jnp.float32)
    return out.astype(x.dtype)


def rglru_decode(cfg, p: Dict, x: jnp.ndarray, cache: Dict, pos: jnp.ndarray
                 ) -> Tuple[jnp.ndarray, Dict]:
    """One step. x: (B, 1, D). cache: h (B, W), conv (B, conv_width-1, W)."""
    xf = x[:, 0].astype(jnp.float32)
    main = xf @ p["w_main"].astype(jnp.float32)
    # causal conv with rolling state
    hist = jnp.concatenate([cache["conv"], main[:, None]], axis=1)  # (B, cw, W)
    conv = (hist * p["conv_w"].astype(jnp.float32)).sum(1) + p["conv_b"]
    a, b = _gates({k: p[k].astype(jnp.float32) for k in ("wa", "ba", "wi", "bi", "lam")}, conv)
    h = a * cache["h"] + b
    gate = jax.nn.gelu(xf @ p["w_gate"].astype(jnp.float32))
    out = ((h * gate) @ p["w_out"].astype(jnp.float32)).astype(x.dtype)
    return out[:, None], {"h": h, "conv": hist[:, 1:]}


def rglru_cache_shape(cfg, batch: int):
    w = cfg.rnn_width or cfg.d_model
    return {"h": (batch, w), "conv": (batch, cfg.conv_width - 1, w)}
