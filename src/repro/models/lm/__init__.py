from repro.models.lm.config import LMConfig, LayerSpec, Stage
from repro.models.lm.model import (
    init_params, abstract_params, lm_forward, lm_loss, init_cache,
    abstract_cache, decode_step,
)

__all__ = [
    "LMConfig", "LayerSpec", "Stage",
    "init_params", "abstract_params", "lm_forward", "lm_loss",
    "init_cache", "abstract_cache", "decode_step",
]
