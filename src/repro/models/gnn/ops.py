"""Message-passing primitives on padded COO edge lists + backend dispatch.

All ops take static-shape padded arrays (see core.batches.PaddedBatch) —
padded edges carry weight 0 and point at node 0, so weighted segment sums are
exact without branching. This is the TPU-friendly formulation: gathers +
segment reductions lower to XLA gather/scatter-add which the SPMD partitioner
understands.

Aggregation runs on one of three backends (DESIGN.md §7):

* "segment" — COO gather + ``segment_sum`` (reference; XLA scatter-add).
* "bcsr"    — the Pallas block-CSR SpMM over the tiles that preprocessing
              emitted (``core.batches.build_batches(bcsr_block=...)``):
              compiled Pallas on TPU, interpret mode elsewhere.
* "dense"   — materialize the (N, N) batch adjacency and matmul; the
              MXU-roofline upper bound the tiled kernel is judged against.

Selection: ``repro.models.gnn.policy.BackendPolicy`` — fixed per-plan or
per-batch *auto* from the plan's autotuned decisions (DESIGN.md §14). A
``GNNConfig.backend`` of ``"auto"`` resolves per batch at trace time by
tile presence. ``REPRO_GNN_BACKEND`` is a deprecated alias that warns once
and maps onto a fixed policy. GAT always uses the segment path (its edge
weights are recomputed by attention every step, so there are no
precomputable tiles).
"""
from __future__ import annotations

import os
import warnings

import jax
import jax.numpy as jnp

BACKENDS = ("segment", "bcsr", "dense")

_env_warned = False


def _env_backend() -> str:
    """Deprecated ``REPRO_GNN_BACKEND`` alias — warns ONCE per process and
    keeps the old force-this-backend semantics (it maps onto
    ``BackendPolicy.fixed``, so it also overrides auto dispatch)."""
    global _env_warned
    name = os.environ.get("REPRO_GNN_BACKEND", "")
    if name and not _env_warned:
        warnings.warn(
            "REPRO_GNN_BACKEND is deprecated: pass "
            "backend=BackendPolicy.fixed(...) (or a backend name) to the "
            "trainer/engine/executor instead (DESIGN.md §14)",
            DeprecationWarning, stacklevel=3)
        _env_warned = True
    return name


def resolve_backend(backend: str, allow_auto: bool = False) -> str:
    """Config value, overridable by the deprecated REPRO_GNN_BACKEND alias
    (DESIGN.md §7/§14). Resolved at trace time — one executable per backend.
    ``allow_auto=True`` passes ``"auto"`` through for callers that resolve
    it per batch (``validate_batch_for_backend``)."""
    b = _env_backend() or backend or "segment"
    if allow_auto and b == "auto":
        return b
    if b not in BACKENDS:
        raise ValueError(f"unknown aggregation backend {b!r}; want one of {BACKENDS}")
    return b


def _require_tiles(batch) -> None:
    if "tile_cols" not in batch or "tile_vals" not in batch:
        raise ValueError(
            "backend='bcsr' needs tile_cols/tile_vals in the batch — build "
            "batches with bcsr_block set (IBMBConfig(backend='bcsr') or "
            "build_batches(bcsr_block=128)), or use backend='segment'")


def validate_batch_for_backend(batch, backend: str, kind: str = "gcn") -> str:
    """Fail fast (not mid-trace) if `batch` lacks what `backend` needs.

    The public pre-flight check for anything that stages batches for a jit'd
    forward (``GNNTrainer``, ``GNNInferenceEngine``): resolves the backend
    (env override included), verifies bcsr tiles are present when required,
    and returns the resolved backend name. `kind` is the GNN variant — GAT
    always runs the segment path (DESIGN.md §7), so it needs no tiles.

    ``backend="auto"`` resolves per batch, at trace time, by tile presence
    (batch *keys* are static under jit): tiles ⇒ bcsr, else segment. This is
    the degenerate auto mode for raw ``gnn_apply`` callers; plan-serving
    consumers dispatch on the autotuner's stored per-batch decisions instead
    (DESIGN.md §14).
    """
    b = resolve_backend(backend, allow_auto=True)
    if b == "auto":
        has_tiles = "tile_cols" in batch and "tile_vals" in batch
        b = "bcsr" if (has_tiles and kind != "gat") else "segment"
    if b == "bcsr" and kind != "gat":
        _require_tiles(batch)
    return b


def _spmm_tiles(tile_cols: jnp.ndarray, tile_vals: jnp.ndarray,
                x: jnp.ndarray, block_f: int = 0) -> jnp.ndarray:
    """A @ x through the symmetric-adjacency SpMM (DESIGN.md §7/§14).

    On TPU this is the fused gather+SpMM Pallas kernel; everywhere else the
    compiled streaming path (the old CPU fallback ran the Pallas kernel in
    interpret mode — the reason bcsr lost to segment in the benches).
    ``block_f`` is the autotuner's tuned feature-tile width; 0 (or a width
    that does not divide the live feature dim — hidden dims vary per layer)
    falls back to the 128-lane default.
    """
    from repro.kernels.spmm.ops import spmm_bcsr_sym
    r, _, b, _ = tile_vals.shape
    assert r * b == x.shape[0], (
        f"bcsr tiles cover {r * b} rows but h has {x.shape[0]}")
    f = x.shape[1]
    if block_f and f % block_f == 0:
        bf = int(block_f)
    else:
        bf = 128 if f % 128 == 0 else f
    impl = "fused" if jax.default_backend() == "tpu" else "stream"
    return spmm_bcsr_sym(tile_cols, tile_vals, x, impl, bf)


def _dense_adj(n: int, edge_src: jnp.ndarray, edge_dst: jnp.ndarray,
               values: jnp.ndarray, dtype) -> jnp.ndarray:
    return jnp.zeros((n, n), dtype).at[edge_src, edge_dst].add(
        values.astype(dtype))


def weighted_agg(h: jnp.ndarray, edge_src: jnp.ndarray, edge_dst: jnp.ndarray,
                 edge_weight: jnp.ndarray) -> jnp.ndarray:
    """out[u] = Σ_{(u,v)∈E} w_uv · h[v]   (rows = edge_src, gathers edge_dst).

    h: (N, F); edges are local indices; padded edges have weight 0.
    """
    msgs = h[edge_dst] * edge_weight[:, None]
    return jax.ops.segment_sum(msgs, edge_src, num_segments=h.shape[0])


def mean_agg(h: jnp.ndarray, edge_src: jnp.ndarray, edge_dst: jnp.ndarray,
             edge_mask: jnp.ndarray) -> jnp.ndarray:
    """Mean aggregation (GraphSAGE): masked mean over real edges."""
    w = edge_mask.astype(h.dtype)
    s = jax.ops.segment_sum(h[edge_dst] * w[:, None], edge_src,
                            num_segments=h.shape[0])
    cnt = jax.ops.segment_sum(w, edge_src, num_segments=h.shape[0])
    return s / jnp.maximum(cnt, 1.0)[:, None]


def weighted_agg_backend(h: jnp.ndarray, batch, backend: str = "segment",
                         block_f: int = 0) -> jnp.ndarray:
    """``out[u] = Σ w_uv h[v]`` on the selected backend (DESIGN.md §7).

    All three backends compute the identical weighted sum — the
    backend-equivalence test suite pins them to the segment reference.
    ``block_f`` is the tuned bcsr feature-tile width (DESIGN.md §14).
    """
    if backend == "bcsr":
        _require_tiles(batch)
        return _spmm_tiles(batch["tile_cols"], batch["tile_vals"], h,
                           block_f=block_f)
    if backend == "dense":
        a = _dense_adj(h.shape[0], batch["edge_src"], batch["edge_dst"],
                       batch["edge_weight"], h.dtype)
        return a @ h
    return weighted_agg(h, batch["edge_src"], batch["edge_dst"],
                        batch["edge_weight"])


def mean_agg_backend(h: jnp.ndarray, batch, backend: str = "segment",
                     block_f: int = 0) -> jnp.ndarray:
    """Masked neighbor mean on the selected backend (DESIGN.md §7).

    bcsr/dense recover the binary adjacency from nonzero weights: the batch
    graph is GCN-normalized, so every real edge has a strictly positive
    weight and ``w != 0`` equals the edge mask.
    """
    if backend == "bcsr":
        _require_tiles(batch)
        bin_tiles = (batch["tile_vals"] != 0).astype(h.dtype)
        s = _spmm_tiles(batch["tile_cols"], bin_tiles, h, block_f=block_f)
        cnt = bin_tiles.sum(axis=(1, 3)).reshape(-1)   # (R·B,) real in-batch degree
        return s / jnp.maximum(cnt, 1.0)[:, None]
    if backend == "dense":
        a = _dense_adj(h.shape[0], batch["edge_src"], batch["edge_dst"],
                       (batch["edge_weight"] != 0), h.dtype)
        return (a @ h) / jnp.maximum(a.sum(axis=1), 1.0)[:, None]
    return mean_agg(h, batch["edge_src"], batch["edge_dst"],
                    batch["edge_mask"])


def segment_softmax(logits: jnp.ndarray, segment_ids: jnp.ndarray,
                    num_segments: int, mask: jnp.ndarray) -> jnp.ndarray:
    """Numerically-stable softmax over edges grouped by destination segment.

    logits: (E, H); mask: (E,) 1.0 for real edges.
    """
    neg = jnp.asarray(-1e9, logits.dtype)
    logits = jnp.where(mask[:, None] > 0, logits, neg)
    seg_max = jax.ops.segment_max(logits, segment_ids, num_segments=num_segments)
    logits = logits - seg_max[segment_ids]
    ex = jnp.exp(logits) * mask[:, None]
    denom = jax.ops.segment_sum(ex, segment_ids, num_segments=num_segments)
    return ex / jnp.maximum(denom[segment_ids], 1e-16)


def layer_norm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
               eps: float = 1e-5) -> jnp.ndarray:
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * scale + bias


def dropout(x: jnp.ndarray, rate: float, key, deterministic: bool) -> jnp.ndarray:
    if deterministic or rate <= 0.0:
        return x
    keep = jax.random.bernoulli(key, 1.0 - rate, x.shape)
    return jnp.where(keep, x / (1.0 - rate), 0.0)
