"""Message-passing primitives on padded COO edge lists.

All ops take static-shape padded arrays (see core.batches.PaddedBatch) —
padded edges carry weight 0 and point at node 0, so weighted segment sums are
exact without branching. This is the TPU-friendly formulation: gathers +
segment reductions lower to XLA gather/scatter-add which the SPMD partitioner
understands; the blocked Pallas SpMM in repro.kernels.spmm is a drop-in for
the weighted-sum aggregation when a CSR layout is used.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def weighted_agg(h: jnp.ndarray, edge_src: jnp.ndarray, edge_dst: jnp.ndarray,
                 edge_weight: jnp.ndarray) -> jnp.ndarray:
    """out[u] = Σ_{(u,v)∈E} w_uv · h[v]   (rows = edge_src, gathers edge_dst).

    h: (N, F); edges are local indices; padded edges have weight 0.
    """
    msgs = h[edge_dst] * edge_weight[:, None]
    return jax.ops.segment_sum(msgs, edge_src, num_segments=h.shape[0])


def mean_agg(h: jnp.ndarray, edge_src: jnp.ndarray, edge_dst: jnp.ndarray,
             edge_mask: jnp.ndarray) -> jnp.ndarray:
    """Mean aggregation (GraphSAGE): masked mean over real edges."""
    w = edge_mask.astype(h.dtype)
    s = jax.ops.segment_sum(h[edge_dst] * w[:, None], edge_src,
                            num_segments=h.shape[0])
    cnt = jax.ops.segment_sum(w, edge_src, num_segments=h.shape[0])
    return s / jnp.maximum(cnt, 1.0)[:, None]


def segment_softmax(logits: jnp.ndarray, segment_ids: jnp.ndarray,
                    num_segments: int, mask: jnp.ndarray) -> jnp.ndarray:
    """Numerically-stable softmax over edges grouped by destination segment.

    logits: (E, H); mask: (E,) 1.0 for real edges.
    """
    neg = jnp.asarray(-1e9, logits.dtype)
    logits = jnp.where(mask[:, None] > 0, logits, neg)
    seg_max = jax.ops.segment_max(logits, segment_ids, num_segments=num_segments)
    logits = logits - seg_max[segment_ids]
    ex = jnp.exp(logits) * mask[:, None]
    denom = jax.ops.segment_sum(ex, segment_ids, num_segments=num_segments)
    return ex / jnp.maximum(denom[segment_ids], 1e-16)


def layer_norm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
               eps: float = 1e-5) -> jnp.ndarray:
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * scale + bias


def dropout(x: jnp.ndarray, rate: float, key, deterministic: bool) -> jnp.ndarray:
    if deterministic or rate <= 0.0:
        return x
    keep = jax.random.bernoulli(key, 1.0 - rate, x.shape)
    return jnp.where(keep, x / (1.0 - rate), 0.0)
