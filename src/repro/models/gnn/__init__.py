from repro.models.gnn.models import GNNConfig, init_gnn, gnn_apply

__all__ = ["GNNConfig", "init_gnn", "gnn_apply"]
