from repro.models.gnn.models import GNNConfig, init_gnn, gnn_apply
from repro.models.gnn.ops import validate_batch_for_backend
from repro.models.gnn.policy import BackendPolicy

__all__ = ["GNNConfig", "init_gnn", "gnn_apply",
           "validate_batch_for_backend", "BackendPolicy"]
