"""BackendPolicy: the one aggregation-backend resolution entry point
(DESIGN.md §14).

Before this module, three call sites (``GNNTrainer``, ``GNNInferenceEngine``,
``ShardedPlanExecutor``) each re-implemented the same override dance —
``backend=str`` → ``dataclasses.replace(model_cfg, backend=...)`` — and the
only decision surface was a single global string. A per-batch *auto* mode
cannot live in a global string, so the override arg now accepts a policy:

* ``BackendPolicy.fixed("segment" | "bcsr" | "dense")`` — every batch runs
  the named backend; exactly the old ``backend="..."`` behaviour.
* ``BackendPolicy.auto()`` — per-batch dispatch: batches execute on the
  backend the plan-build autotuner decided for them (``Plan.batch_backends``,
  driven by the tile-fill/degree stats recorded during preprocessing —
  ``repro.core.autotune``), falling back to tile presence for raw batch
  containers that carry no decision.

``resolve(model_cfg, backend)`` is the ONE shared helper: it normalizes a
``None | str | BackendPolicy`` override (plain strings keep working;
``"auto"`` means the auto policy), applies the deprecated
``REPRO_GNN_BACKEND`` env alias (warns once, maps onto a fixed policy), and
returns the adjusted model config plus the policy. Consumers then key their
jitted executables by ``(backend, block_f)`` per batch — static shapes per
backend, so auto dispatch never recompiles beyond one executable per
distinct decision.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple, Union

from repro.models.gnn import ops as gnn_ops


@dataclasses.dataclass(frozen=True)
class BackendPolicy:
    """How batches map to aggregation backends: ``fixed(name)`` or ``auto``."""
    mode: str                               # "fixed" | "auto"
    backend: Optional[str] = None           # fixed mode only

    @classmethod
    def fixed(cls, name: str) -> "BackendPolicy":
        if name not in gnn_ops.BACKENDS:
            raise ValueError(
                f"unknown aggregation backend {name!r}; want one of "
                f"{gnn_ops.BACKENDS}")
        return cls("fixed", name)

    @classmethod
    def auto(cls) -> "BackendPolicy":
        return cls("auto")

    @property
    def is_auto(self) -> bool:
        return self.mode == "auto"


BackendSpec = Union[None, str, BackendPolicy]


def as_policy(spec: BackendSpec) -> Optional[BackendPolicy]:
    """Normalize a ``None | str | BackendPolicy`` override. ``"auto"``
    (string) means the auto policy; other strings are fixed backends."""
    if spec is None or isinstance(spec, BackendPolicy):
        return spec
    if isinstance(spec, str):
        return BackendPolicy.auto() if spec == "auto" \
            else BackendPolicy.fixed(spec)
    raise TypeError(
        f"backend must be None, a backend name, 'auto', or a BackendPolicy "
        f"— got {type(spec).__name__}")


def resolve(model_cfg, backend: BackendSpec = None):
    """THE shared resolution helper (replaces the triplicated
    ``dataclasses.replace(model_cfg, backend=...)`` pattern).

    Precedence: deprecated ``REPRO_GNN_BACKEND`` env alias (warns once,
    forces a fixed policy — it predates per-batch dispatch) > explicit
    ``backend`` arg > ``model_cfg.backend`` (which may itself be ``"auto"``).

    Returns ``(model_cfg, policy)``: for a fixed policy the config's
    ``backend`` field is the fixed name; for auto it is the ``"segment"``
    base (always executable — every batch carries COO edges), and consumers
    derive per-batch configs via :func:`batch_config`.
    """
    env = gnn_ops._env_backend()
    pol = BackendPolicy.fixed(env) if env else as_policy(backend)
    if pol is None:
        pol = as_policy(getattr(model_cfg, "backend", "segment") or "segment")
    base = "segment" if pol.is_auto else pol.backend
    if getattr(model_cfg, "backend", None) != base:
        model_cfg = dataclasses.replace(model_cfg, backend=base)
    return model_cfg, pol


def batch_config(model_cfg, backend: str, block_f: int = 0):
    """The per-executable config for one (backend, tuned block_f) decision —
    consumers jit one forward per distinct config, picked host-side."""
    if getattr(model_cfg, "backend", None) == backend \
            and int(getattr(model_cfg, "bcsr_block_f", 0)) == int(block_f):
        return model_cfg
    return dataclasses.replace(model_cfg, backend=backend,
                               bcsr_block_f=int(block_f))


def _has_tiles(batch) -> bool:
    if hasattr(batch, "has_bcsr"):
        return bool(batch.has_bcsr)
    return "tile_cols" in batch and "tile_vals" in batch


def batch_decisions(host, policy: BackendPolicy, model_cfg
                    ) -> List[Tuple[str, int]]:
    """Per-batch ``(backend, block_f)`` execution decisions for `host`.

    `host` is anything the trainer/engine serve from: a ``Plan`` (carries
    the autotuner's v3 decisions), a ``BatchCache``/``LazyBatchCache``, or a
    plain sequence of batch dicts / ``PaddedBatch``. Fixed policies return a
    uniform list; the auto policy reads the plan's stored decisions and
    degrades to tile-presence dispatch for containers without them.
    GAT has no precomputable tiles, so auto always resolves it to segment.
    """
    n = len(host)
    bf = int(getattr(model_cfg, "bcsr_block_f", 0))
    if not policy.is_auto:
        be = policy.backend or getattr(model_cfg, "backend", "segment")
        return [(be, bf)] * n
    if getattr(model_cfg, "kind", "gcn") == "gat":
        return [("segment", 0)] * n
    names = getattr(host, "batch_backends", None)
    if callable(names):                      # Plan v3 (or v2 fallback)
        tuned = host.batch_block_fs()
        return [(str(b), int(t)) for b, t in zip(names(), tuned)]
    cache = getattr(host, "cache", None)     # Plan-like wrapper
    if cache is not None and host is not cache:
        return batch_decisions(cache, policy, model_cfg)
    return [("bcsr", bf) if _has_tiles(host[i]) else ("segment", 0)
            for i in range(n)]


def superstep_decision(decisions: Sequence[Tuple[str, int]],
                       idx) -> Tuple[str, int]:
    """One decision for a shard_map super-step: its members execute in a
    single jitted body, so they must share a backend. Uniform groups keep
    their decision; mixed groups fall back to segment (always executable —
    the schedule groups consecutive batches, and the autotuner's decisions
    are strongly run-length-uniform in practice, so this is the rare tail).
    """
    got = {decisions[int(i)] for i in idx}
    if len(got) == 1:
        return next(iter(got))
    backends = {b for b, _ in got}
    if len(backends) == 1:                   # same backend, mixed block_f
        return (next(iter(backends)), 0)
    return ("segment", 0)
