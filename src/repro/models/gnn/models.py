"""The paper's three evaluation GNNs: GCN, GAT, GraphSAGE (App. B configs).

Pure-JAX functional models: params are pytrees, apply is jit/pjit-safe, all
shapes static. Batch format = padded induced subgraph (core.batches).
All models use LayerNorm, ReLU and dropout per paper App. B.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.gnn import ops


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    kind: str = "gcn"            # gcn | gat | sage
    in_dim: int = 128
    hidden: int = 256            # paper: 256 (ogbn), 512 (Reddit GCN)
    out_dim: int = 40
    num_layers: int = 3          # paper: 3 (ogbn), 2 (Reddit)
    heads: int = 4               # GAT
    dropout: float = 0.3
    dtype: str = "float32"
    # aggregation backend: segment | bcsr | dense | auto (DESIGN.md §7/§14);
    # "auto" resolves per batch (tiles ⇒ bcsr). Deprecated env override
    # REPRO_GNN_BACKEND. bcsr needs batches built with bcsr_block.
    backend: str = "segment"
    # tuned bcsr feature-tile width (0 = 128-lane default); plan-serving
    # consumers set this per batch from the autotuner's stored decision via
    # repro.models.gnn.policy.batch_config (DESIGN.md §14)
    bcsr_block_f: int = 0


def _glorot(key, shape, dtype):
    fan_in, fan_out = shape[0], shape[-1]
    lim = float(np.sqrt(6.0 / (fan_in + fan_out)))
    return jax.random.uniform(key, shape, dtype, -lim, lim)


def init_gnn(cfg: GNNConfig, key) -> Dict:
    dtype = jnp.dtype(cfg.dtype)
    dims = [cfg.in_dim] + [cfg.hidden] * (cfg.num_layers - 1) + [cfg.out_dim]
    params: Dict = {"layers": []}
    for l in range(cfg.num_layers):
        key, *ks = jax.random.split(key, 6)
        d_in, d_out = dims[l], dims[l + 1]
        if cfg.kind == "gcn":
            layer = {
                "w": _glorot(ks[0], (d_in, d_out), dtype),
                "b": jnp.zeros((d_out,), dtype),
            }
        elif cfg.kind == "sage":
            layer = {
                "w_self": _glorot(ks[0], (d_in, d_out), dtype),
                "w_nbr": _glorot(ks[1], (d_in, d_out), dtype),
                "b": jnp.zeros((d_out,), dtype),
            }
        elif cfg.kind == "gat":
            h = cfg.heads
            dh = d_out // h if l < cfg.num_layers - 1 else d_out
            layer = {
                "w": _glorot(ks[0], (d_in, h * dh), dtype),
                "a_src": _glorot(ks[1], (h, dh), dtype),
                "a_dst": _glorot(ks[2], (h, dh), dtype),
                "b": jnp.zeros((h * dh if l < cfg.num_layers - 1 else d_out,), dtype),
            }
        else:
            raise ValueError(cfg.kind)
        if l < cfg.num_layers - 1:
            layer["ln_scale"] = jnp.ones((d_out,), dtype)
            layer["ln_bias"] = jnp.zeros((d_out,), dtype)
        params["layers"].append(layer)
    return params


def _gcn_layer(p, h, batch, backend="segment", block_f=0):
    # §Perf: edge-gather traffic is E×width of whatever flows along edges.
    # Aggregating in the NARROWER of (d_in, d_out) minimizes it; both orders
    # are mathematically identical because aggregation is linear:
    #   agg(h) @ W  ==  agg(h @ W)
    import os
    d_in, d_out = p["w"].shape
    mode = os.environ.get("REPRO_GCN_AGG_ORDER", "transform_first")
    agg_first = (mode == "agg_first"
                 or (mode == "auto" and d_in < d_out))
    if agg_first:
        h = ops.weighted_agg_backend(h, batch, backend, block_f=block_f)
        return h @ p["w"] + p["b"]
    h = h @ p["w"]
    h = ops.weighted_agg_backend(h, batch, backend, block_f=block_f)
    return h + p["b"]


def _sage_layer(p, h, batch, backend="segment", block_f=0):
    nbr = ops.mean_agg_backend(h, batch, backend, block_f=block_f)
    return h @ p["w_self"] + nbr @ p["w_nbr"] + p["b"]


def _gat_layer(p, h, batch, backend="segment", block_f=0):
    # GAT recomputes edge weights from attention every step, so there are no
    # precomputable tiles — it always falls back to the segment path
    # (DESIGN.md §7); `backend` is accepted for a uniform layer signature.
    n = h.shape[0]
    heads, dh = p["a_src"].shape
    z = (h @ p["w"]).reshape(n, heads, dh)
    src, dst, mask = batch["edge_src"], batch["edge_dst"], batch["edge_mask"]
    e_src = (z * p["a_src"][None]).sum(-1)   # (N, H)
    e_dst = (z * p["a_dst"][None]).sum(-1)
    logits = jax.nn.leaky_relu(e_src[src] + e_dst[dst], 0.2)   # (E, H)
    att = ops.segment_softmax(logits, src, n, mask)
    msgs = z[dst] * att[..., None]                              # (E, H, dh)
    out = jax.ops.segment_sum(msgs, src, num_segments=n)
    if p["b"].shape[0] == heads * dh:       # hidden layers: concat heads
        return out.reshape(n, heads * dh) + p["b"]
    return out.mean(axis=1) + p["b"]        # output layer: average heads


_LAYERS = {"gcn": _gcn_layer, "sage": _sage_layer, "gat": _gat_layer}


def gnn_apply(cfg: GNNConfig, params: Dict, batch: Dict[str, jnp.ndarray],
              rng: Optional[jax.Array] = None, train: bool = False) -> jnp.ndarray:
    """Forward pass on one padded batch. Returns logits for ALL nodes (N, C);
    the caller selects output rows via batch['output_idx']."""
    layer_fn = _LAYERS[cfg.kind]
    h = batch["features"].astype(jnp.dtype(cfg.dtype))
    if "edge_mask" not in batch:
        batch = dict(batch)
        batch["edge_mask"] = (batch["edge_weight"] != 0).astype(h.dtype)
    backend = ops.validate_batch_for_backend(
        batch, getattr(cfg, "backend", "segment"), cfg.kind)
    block_f = int(getattr(cfg, "bcsr_block_f", 0))
    for l, p in enumerate(params["layers"]):
        h = layer_fn(p, h, batch, backend, block_f)
        if l < cfg.num_layers - 1:
            h = ops.layer_norm(h, p["ln_scale"], p["ln_bias"])
            h = jax.nn.relu(h)
            if train and rng is not None:
                rng, sub = jax.random.split(rng)
                h = ops.dropout(h, cfg.dropout, sub, deterministic=False)
    return h


def output_logits(logits_all: jnp.ndarray, batch: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    """Select the batch's output-node rows (paper: only output nodes get
    predictions; auxiliary nodes exist only to feed them)."""
    return logits_all[batch["output_idx"]]


def masked_xent(logits: jnp.ndarray, labels: jnp.ndarray,
                mask: jnp.ndarray) -> jnp.ndarray:
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None].astype(jnp.int32), axis=-1)[:, 0]
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def masked_accuracy(logits: jnp.ndarray, labels: jnp.ndarray,
                    mask: jnp.ndarray) -> jnp.ndarray:
    pred = logits.argmax(-1)
    correct = (pred == labels).astype(jnp.float32) * mask
    return correct.sum() / jnp.maximum(mask.sum(), 1.0)
