"""PPR correctness: push APPR bound, topic-sensitive equivalence, heat kernel."""
import numpy as np
import pytest
from _hypothesis_fallback import given, settings, st

from repro.core.ppr import push_appr, topic_sensitive_ppr, dense_ppr, heat_kernel
from repro.graph.csr import coo_to_csr, make_undirected


def _random_graph(n, avg_deg, seed):
    rng = np.random.default_rng(seed)
    e = max(n * avg_deg // 2, n)  # ensure connectivity-ish
    src = rng.integers(0, n, e)
    dst = rng.integers(0, n, e)
    keep = src != dst
    g = coo_to_csr(src[keep], dst[keep], n)
    return make_undirected(g)


def test_push_appr_bound(tiny_ds):
    g = tiny_ds.graph
    dense = dense_ppr(g, alpha=0.25)
    roots = np.arange(16)
    eps = 1e-5
    appr = push_appr(g, roots, alpha=0.25, eps=eps, max_iters=200, topk=g.num_nodes)
    deg = np.maximum(g.degrees(), 1)
    for i, r in enumerate(roots):
        row = np.zeros(g.num_nodes)
        m = appr.indices[i] >= 0
        row[appr.indices[i][m]] = appr.values[i][m]
        assert (np.abs(row - dense[r]) / deg).max() < eps * 1.01


def test_push_appr_monotone_mass(tiny_ds):
    """Approximate PPR mass is ≤ 1 and > 0 for every root."""
    appr = push_appr(tiny_ds.graph, np.arange(32), topk=64)
    mass = appr.values.sum(axis=1)
    assert (mass > 0).all() and (mass <= 1.0 + 1e-6).all()


def test_topic_sensitive_equals_dense_average(tiny_ds):
    g = tiny_ds.graph
    dense = dense_ppr(g, alpha=0.25)
    batch = np.array([3, 7, 11])
    pi = topic_sensitive_ppr(g, [batch], alpha=0.25, num_iters=500)
    ref = dense[batch].mean(axis=0)
    assert np.abs(pi[0] - ref).max() < 1e-6


def test_heat_kernel_row_stochastic(tiny_ds):
    hk = heat_kernel(tiny_ds.graph, [np.array([0, 1])], t=3.0, num_terms=40)
    assert abs(hk[0].sum() - 1.0) < 1e-4
    assert (hk >= -1e-9).all()


@settings(max_examples=10, deadline=None)
@given(n=st.integers(20, 60), seed=st.integers(0, 100))
def test_push_appr_bound_property(n, seed):
    """Property: frontier-synchronous push obeys the ε·deg(v) error bound on
    arbitrary random graphs once residuals are exhausted."""
    g = _random_graph(n, 4, seed)
    eps = 1e-4
    dense = dense_ppr(g, alpha=0.3)
    appr = push_appr(g, np.arange(min(5, n)), alpha=0.3, eps=eps,
                     max_iters=500, topk=n)
    deg = np.maximum(g.degrees(), 1)
    for i in range(min(5, n)):
        row = np.zeros(n)
        m = appr.indices[i] >= 0
        row[appr.indices[i][m]] = appr.values[i][m]
        assert (np.abs(row - dense[i]) / deg).max() < eps * 1.01
