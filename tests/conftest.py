import os
import sys

# NOTE: do NOT set xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device. Sharded-lowering tests spawn subprocesses.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest

from repro.graph.datasets import get_dataset


@pytest.fixture(scope="session")
def tiny_ds():
    return get_dataset("tiny")


@pytest.fixture(scope="session")
def small_ds():
    return get_dataset("small")
