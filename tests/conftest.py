import os
import sys

# NOTE: do NOT set xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device. Sharded-lowering tests spawn subprocesses.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest

from repro.graph.datasets import get_dataset


@pytest.fixture(scope="session")
def tiny_ds():
    return get_dataset("tiny")


@pytest.fixture(scope="session")
def small_ds():
    return get_dataset("small")


# ---------------------------------------------------------------------------
# Deterministic concurrency harness (DESIGN.md §11): the serving tier takes
# any object with a monotonic `now()`, so window-expiry, deadline and
# coalescing behavior are tested by ADVANCING a fake clock and pumping the
# dispatcher — never by wall-clock sleeps.
class FakeClock:
    """Manually-advanced stand-in for `repro.serve.common.SystemClock`."""

    def __init__(self, t0: float = 0.0):
        self._t = float(t0)

    def now(self) -> float:
        return self._t

    def advance(self, seconds: float) -> float:
        self._t += float(seconds)
        return self._t

    def sleep(self, seconds: float) -> None:
        # Retry backoff goes through clock.sleep (DESIGN.md §12); under the
        # fake clock a "sleep" is just time passing — tests stay sleep-free.
        self.advance(seconds)


@pytest.fixture
def fake_clock():
    return FakeClock()


@pytest.fixture
def arrival_trace():
    """Replay a scripted arrival trace against an (unstarted) async engine:
    events are ``(dt_s, tenant, node_ids)`` or ``(dt_s, tenant, node_ids,
    deadline_ms)`` tuples — advance the clock by ``dt_s``, submit, and
    (by default) pump one dispatcher ``step()`` exactly as the worker loop
    would. Returns the futures in arrival order."""

    def replay(engine, clock, events, pump: bool = True):
        futs = []
        for ev in events:
            dt, tenant, node_ids = ev[0], ev[1], ev[2]
            deadline_ms = ev[3] if len(ev) > 3 else None
            clock.advance(dt)
            futs.append(engine.submit(tenant, node_ids,
                                      deadline_ms=deadline_ms))
            if pump:
                engine.step()
        return futs

    return replay
