"""Fault injection + graceful degradation (DESIGN.md §12): deterministic
injector, circuit-breaker lifecycle, retry absorption, worker-death
watchdog, swap rollback bit-exactness, corrupt/truncated artifact
detection and recovery, NaN-grad policies, dead-host lease reassignment —
all driven by seeded FaultInjector scripts and a FakeClock, zero sleeps."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_fallback import given, settings, st
from conftest import FakeClock
from repro.checkpoint import (
    Checkpointer, CheckpointCorruptError, CheckpointError, latest_step)
from repro.core import IBMBPipeline, IBMBConfig
from repro.core.batches import BatchCache
from repro.core.plan import Plan, PlanFormatError, RoutingIndex
from repro.data.loader import PrefetchLoader
from repro.faults import (
    FaultInjector, FaultStats, InjectedFault, NO_FAULTS, WorkerDeath,
    corrupt_file)
from repro.models.gnn import GNNConfig, init_gnn
from repro.serve import (
    AsyncGNNEngine, AsyncServeConfig, CircuitBreaker, GNNInferenceEngine,
    ServeUnavailable)
from repro.train import GNNTrainer, NonFiniteGradError
from repro.train.elastic import (
    ElasticCoordinator, Heartbeats, WorkQueue, partition_batches)


def _pipe(ds, **kw):
    cfg = dict(variant="node", k_per_output=8, max_outputs_per_batch=32,
               pad_multiple=16)
    cfg.update(kw)
    return IBMBPipeline(ds, IBMBConfig(**cfg))


@pytest.fixture(scope="module")
def served(tiny_ds):
    pipe = _pipe(tiny_ds)
    plan = pipe.plan("test", for_inference=True)
    assert len(plan) >= 2
    cfg = GNNConfig(kind="gcn", in_dim=tiny_ds.feat_dim, hidden=32,
                    out_dim=tiny_ds.num_classes, num_layers=2)
    params = init_gnn(cfg, jax.random.PRNGKey(0))
    return pipe, plan, cfg, params


def _tier(served, clock, faults=None, tenants=("m",), **cfg_kw):
    _, plan, cfg, params = served
    cfg_kw.setdefault("window_us", 1000.0)
    return AsyncGNNEngine(
        {n: GNNInferenceEngine(plan, cfg, params, cache_batches=4)
         for n in tenants},
        AsyncServeConfig(**cfg_kw), clock=clock, start=False, faults=faults)


def _batch_nodes(plan, bi):
    return plan.routing.node_ids[np.asarray(plan.routing.batch) == bi]


# ======================================================= injector mechanics
def test_injector_script_fires_exact_calls():
    fi = FaultInjector(script={"forward": [0, 2]})
    hits = [fi.should_fire("forward") for _ in range(4)]
    assert hits == [True, False, True, False]
    assert fi.snapshot() == {"forward": {"calls": 4, "fired": 2}}


def test_injector_rate_deterministic_and_per_point_independent():
    a = FaultInjector(seed=7, rates={"forward": 0.3, "loader": 0.3})
    seq_fwd = [a.should_fire("forward") for _ in range(64)]
    # interleaving traffic on ANOTHER point must not perturb this point
    b = FaultInjector(seed=7, rates={"forward": 0.3, "loader": 0.3})
    seq_fwd2 = []
    for _ in range(64):
        b.should_fire("loader")
        seq_fwd2.append(b.should_fire("forward"))
    assert seq_fwd == seq_fwd2
    assert any(seq_fwd) and not all(seq_fwd)
    # a different seed draws a different sequence
    c = FaultInjector(seed=8, rates={"forward": 0.3})
    assert [c.should_fire("forward") for _ in range(64)] != seq_fwd


def test_injector_fire_raises_with_context():
    fi = FaultInjector(seed=3, script={"plan_io": [1]})
    fi.fire("plan_io")                                   # call 0: no-op
    with pytest.raises(InjectedFault, match="plan_io.*call 1.*seed 3"):
        fi.fire("plan_io")
    with pytest.raises(OSError):
        FaultInjector(script={"x": [0]}).fire("x", OSError)


def test_injector_delay_only_when_scripted():
    fi = FaultInjector(script={"dispatch_delay": [1]},
                       delays={"dispatch_delay": 0.25})
    assert fi.delay("dispatch_delay") == 0.0
    assert fi.delay("dispatch_delay") == 0.25


def test_no_faults_is_inert():
    assert NO_FAULTS.active is False
    assert NO_FAULTS.should_fire("forward") is False
    NO_FAULTS.fire("forward")                            # never raises
    assert NO_FAULTS.delay("dispatch_delay") == 0.0
    assert NO_FAULTS.snapshot() == {}


def test_fault_stats_counter_bag():
    fs = FaultStats("a", "b")
    fs.bump("a")
    fs.bump("b", 3)
    assert fs.snapshot() == {"a": 1, "b": 3}


def test_corrupt_file_flips_deterministic_positions(tmp_path):
    p = str(tmp_path / "blob.bin")
    payload = bytes(range(256)) * 8
    with open(p, "wb") as f:
        f.write(payload)
    pos = corrupt_file(p, seed=5, nbytes=4)
    with open(p, "rb") as f:
        got = f.read()
    assert got != payload and len(got) == len(payload)
    for i in pos:
        assert got[i] == payload[i] ^ 0xFF and i >= len(payload) // 2
    # deterministic: same seed → same positions
    with open(p, "wb") as f:
        f.write(payload)
    assert corrupt_file(p, seed=5, nbytes=4) == pos


# ================================================= serving: retry + breaker
def test_retry_absorbs_transient_forward_fault(served):
    clock = FakeClock()
    tier = _tier(served, clock, faults=FaultInjector(script={"forward": [0]}),
                 max_retries=2)
    _, plan, _, _ = served
    fut = tier.submit("m", _batch_nodes(plan, 0)[:4])
    clock.advance(2e-3)
    tier.step()
    assert fut.result(0) is not None
    assert tier.fault_stats.retries == 1
    assert tier.stats.window_errors == 0 and tier.stats.completed == 1
    tier.close()


def test_retries_exhausted_fail_only_that_window(served):
    clock = FakeClock()
    tier = _tier(served, clock,
                 faults=FaultInjector(script={"forward": [0, 1]}),
                 max_retries=1)
    _, plan, _, _ = served
    fut = tier.submit("m", _batch_nodes(plan, 0)[:4])
    clock.advance(2e-3)
    tier.step()
    assert isinstance(fut.exception(0), InjectedFault)
    assert tier.fault_stats.retries == 1
    assert tier.stats.window_errors == 1 and tier.stats.failed == 1
    # next window is clean — fault isolation holds with retries on
    fut2 = tier.submit("m", _batch_nodes(plan, 0)[:4])
    clock.advance(2e-3)
    tier.step()
    assert fut2.result(0) is not None
    tier.close()


def _fail_windows(tier, clock, plan, n):
    """Drive n consecutive failing windows through the scripted injector."""
    for _ in range(n):
        fut = tier.submit("m", _batch_nodes(plan, 0)[:2])
        clock.advance(2e-3)
        tier.step()
        assert fut.done() and fut.exception(0) is not None
    return fut


def test_breaker_opens_fast_rejects_then_recovers(served):
    """CLOSED → OPEN → (cooldown) → HALF_OPEN → CLOSED, all on the fake
    clock: the full lifecycle of DESIGN.md §12's state machine."""
    _, plan, _, _ = served
    clock = FakeClock()
    tier = _tier(served, clock,
                 faults=FaultInjector(script={"forward": [0, 1]}),
                 breaker_threshold=2, breaker_cooldown_us=50_000.0)
    _fail_windows(tier, clock, plan, 2)                  # threshold reached
    snap = tier.snapshot()
    assert snap["tenants"]["m"]["breaker"]["state"] == CircuitBreaker.OPEN
    assert tier.fault_stats.breaker_opens == 1

    # open: O(1) fast-reject with a retry-after hint, nothing queued
    fut = tier.submit("m", _batch_nodes(plan, 0)[:2])
    exc = fut.exception(0)
    assert isinstance(exc, ServeUnavailable) and exc.retry_after_ms > 0
    assert tier.stats.rejected_unavailable == 1
    assert tier.fault_stats.fast_rejects == 1
    assert tier.stats.queue_depth == 0

    # cooldown elapsed: the next submit IS the half-open probe; its window
    # succeeds (script exhausted) and the breaker closes
    clock.advance(0.051)
    probe = tier.submit("m", _batch_nodes(plan, 0)[:2])
    assert not probe.done()
    clock.advance(2e-3)
    tier.step()
    assert probe.result(0) is not None
    snap = tier.snapshot()
    assert snap["tenants"]["m"]["breaker"]["state"] == CircuitBreaker.CLOSED
    assert tier.fault_stats.breaker_closes == 1
    assert snap["faults"]["injected"]["forward"]["fired"] == 2
    tier.close()


def test_breaker_half_open_probe_failure_reopens(served):
    _, plan, _, _ = served
    clock = FakeClock()
    tier = _tier(served, clock,
                 faults=FaultInjector(script={"forward": [0, 1, 2]}),
                 breaker_threshold=2, breaker_cooldown_us=50_000.0)
    _fail_windows(tier, clock, plan, 2)
    clock.advance(0.051)
    probe = tier.submit("m", _batch_nodes(plan, 0)[:2])   # half-open probe
    clock.advance(2e-3)
    tier.step()
    assert isinstance(probe.exception(0), InjectedFault)  # probe fails
    assert tier.fault_stats.breaker_opens == 2            # re-opened
    fut = tier.submit("m", _batch_nodes(plan, 0)[:2])     # still shedding
    assert isinstance(fut.exception(0), ServeUnavailable)
    tier.close()


def test_breaker_isolated_per_tenant(served):
    """Tenant m's open breaker must not shed tenant n's traffic."""
    _, plan, _, _ = served
    clock = FakeClock()
    tier = _tier(served, clock, tenants=("m", "n"),
                 faults=FaultInjector(script={"forward": [0, 1]}),
                 breaker_threshold=2, breaker_cooldown_us=1e9)
    _fail_windows(tier, clock, plan, 2)
    assert isinstance(tier.submit("m", _batch_nodes(plan, 0)[:2])
                      .exception(0), ServeUnavailable)
    fut = tier.submit("n", _batch_nodes(plan, 0)[:2])
    clock.advance(2e-3)
    tier.step()
    assert fut.result(0) is not None
    snap = tier.snapshot()["tenants"]
    assert snap["m"]["breaker"]["state"] == CircuitBreaker.OPEN
    assert snap["n"]["breaker"]["state"] == CircuitBreaker.CLOSED
    tier.close()


def test_breaker_unit_threshold_validation():
    with pytest.raises(ValueError):
        CircuitBreaker(0, 1.0)


# ==================================================== serving: worker death
def test_worker_death_fails_inflight_never_hangs(served):
    """A dispatcher crash between take and dispatch FAILS the in-flight
    futures (step's crash-safety contract) — and requests queued but not
    yet taken survive to be served by the next step."""
    _, plan, _, _ = served
    clock = FakeClock()
    tier = _tier(served, clock,
                 faults=FaultInjector(script={"worker_death": [0]}))
    futs = [tier.submit("m", _batch_nodes(plan, 0)[i:i + 2])
            for i in (0, 2)]
    clock.advance(2e-3)
    with pytest.raises(WorkerDeath):
        tier.step()
    assert all(isinstance(f.exception(0), WorkerDeath) for f in futs)
    assert tier.stats.failed == 2 and tier.stats.queue_depth == 0
    # the tier is not wedged: the next window serves normally
    fut = tier.submit("m", _batch_nodes(plan, 0)[:2])
    clock.advance(2e-3)
    tier.step()
    assert fut.result(0) is not None
    tier.close()


def test_threaded_watchdog_restarts_worker(served):
    """With the real worker thread, an injected worker death is absorbed:
    the crashed loop's futures FAIL (never hang), the watchdog restarts
    the loop, and subsequent traffic is served."""
    _, plan, cfg, params = served
    tier = AsyncGNNEngine(
        {"m": GNNInferenceEngine(plan, cfg, params, cache_batches=4)},
        AsyncServeConfig(window_us=500.0),
        faults=FaultInjector(script={"worker_death": [0]}), start=True)
    f1 = tier.submit("m", _batch_nodes(plan, 0)[:2])
    assert isinstance(f1.exception(10.0), WorkerDeath)
    f2 = tier.submit("m", _batch_nodes(plan, 0)[:2])
    assert f2.result(10.0) is not None
    tier.close()
    assert tier.fault_stats.worker_restarts >= 1
    assert f1.done() and f2.done()


def test_close_terminates_futures_under_fault_storm(served):
    """Every admitted future terminates even when EVERY step crashes: the
    close-path drain caps watchdog restarts and fails the remainder."""
    _, plan, _, _ = served
    clock = FakeClock()
    tier = _tier(served, clock,
                 faults=FaultInjector(rates={"worker_death": 1.0}))
    futs = [tier.submit("m", _batch_nodes(plan, 0)[i:i + 2])
            for i in (0, 2)]
    tier.close()
    assert all(f.done() and f.exception(0) is not None for f in futs)
    assert tier.stats.queue_depth == 0
    assert tier.stats.accepted == tier.stats.failed


# ===================================================== serving: swap safety
def test_failed_swap_rolls_back_bit_exact(served):
    """The acceptance bar: a refused swap leaves the tenant serving the
    parent plan with logits BIT-identical to pre-swap, and the rollback is
    audited."""
    _, plan, _, _ = served
    clock = FakeClock()
    tier = _tier(served, clock)
    q = _batch_nodes(plan, 0)[:4]
    fut = tier.submit("m", q)
    clock.advance(2e-3)
    tier.step()
    before = np.asarray(fut.result(0))

    bad = dataclasses.replace(plan, routing=RoutingIndex(
        node_ids=plan.routing.node_ids,
        batch=np.full(len(plan.routing), 99, np.int32),
        row=plan.routing.row))
    with pytest.raises(ValueError, match="out of range"):
        tier.swap("m", bad)

    eng = tier.tenant_engine("m")
    assert eng.plan is plan                       # parent still serving
    assert eng.stats["swap_rollbacks"] == 1
    assert tier.fault_stats.swap_rollbacks == 1
    audit = eng.swap_audit[-1]
    assert audit["ok"] is False and "out of range" in audit["reason"]

    fut2 = tier.submit("m", q)
    clock.advance(2e-3)
    tier.step()
    assert np.array_equal(np.asarray(fut2.result(0)), before)
    tier.close()


def test_swap_audit_records_success(tiny_ds):
    from repro.core.update import GraphDelta
    pipe = _pipe(tiny_ds)
    plan = pipe.plan("test", for_inference=True)
    cfg = GNNConfig(kind="gcn", in_dim=tiny_ds.feat_dim, hidden=32,
                    out_dim=tiny_ds.num_classes, num_layers=2)
    eng = GNNInferenceEngine(plan, cfg,
                             init_gnn(cfg, jax.random.PRNGKey(0)),
                             cache_batches=4)
    rng = np.random.default_rng(0)
    touch = plan.routing.node_ids[:2].astype(np.int64)
    delta = GraphDelta(
        feat_nodes=touch,
        feat_values=rng.normal(
            size=(len(touch), tiny_ds.feat_dim)).astype(np.float32))
    new_plan, d = pipe.refresh(plan, delta)
    eng.swap(new_plan, d)
    audit = eng.swap_audit[-1]
    assert audit["ok"] is True
    assert audit["to_version"] == new_plan.version
    assert audit["from_version"] == plan.version


# =============================================== property: futures terminate
@settings(deadline=None, max_examples=5)
@given(st.integers(0, 6))
def test_every_submitted_future_terminates_under_chaos(served, seed):
    """Invariant (DESIGN.md §12): no matter what the injector throws —
    forward faults, retries, breaker trips, worker deaths, stalls — every
    submitted future terminates by close(), and the counters account for
    every accepted request."""
    _, plan, _, _ = served
    clock = FakeClock()
    faults = FaultInjector(
        seed=seed, rates={"forward": 0.2, "worker_death": 0.1,
                          "dispatch_delay": 0.2},
        delays={"dispatch_delay": 5e-4})
    tier = _tier(served, clock, faults=faults, max_queue=8, max_retries=1,
                 breaker_threshold=3, breaker_cooldown_us=10_000.0)
    rng = np.random.default_rng(seed)
    all_nodes = plan.routing.node_ids
    futs = []
    for i in range(40):
        if rng.random() < 0.1:                   # unroutable id
            q = np.array([10 ** 6 + i])
        else:
            lo = int(rng.integers(0, len(all_nodes) - 2))
            q = all_nodes[lo:lo + int(rng.integers(1, 4))]
        futs.append(tier.submit("m", q))
        clock.advance(float(rng.random()) * 2e-3)
        if rng.random() < 0.7:
            try:
                tier.step()
            except WorkerDeath:
                pass
    tier.close()
    assert all(f.done() for f in futs)
    s = tier.stats
    assert s.queue_depth == 0
    assert s.submitted == len(futs) == s.accepted + s.rejected
    assert s.accepted == s.completed + s.failed + s.expired


# ====================================================== persistence: plans
def test_plan_save_is_atomic_under_injected_io_error(served, tmp_path):
    _, plan, _, _ = served
    path = str(tmp_path / "plan.npz")
    plan.save(path)
    good = os.path.getsize(path)
    with pytest.raises(OSError):
        plan.save(path, faults=FaultInjector(script={"plan_io": [0]}))
    assert os.path.getsize(path) == good          # old artifact intact
    assert not os.path.exists(path + ".tmp")      # no debris
    loaded = Plan.load(path, expect_fingerprint=plan.fingerprint)
    assert len(loaded) == len(plan)


def test_plan_load_injected_io_error(served, tmp_path):
    _, plan, _, _ = served
    path = str(tmp_path / "plan.npz")
    plan.save(path)
    with pytest.raises(OSError):
        Plan.load(path, faults=FaultInjector(script={"plan_io": [0]}))


def test_corrupt_plan_detected_not_served(served, tmp_path):
    _, plan, _, _ = served
    path = str(tmp_path / "plan.npz")
    plan.save(path)
    corrupt_file(path, seed=1, nbytes=8)
    with pytest.raises(PlanFormatError, match="corrupt|checksum"):
        Plan.load(path)


def test_truncated_plan_detected(served, tmp_path):
    _, plan, _, _ = served
    path = str(tmp_path / "plan.npz")
    plan.save(path)
    blob = open(path, "rb").read()
    with open(path, "wb") as f:
        f.write(blob[:len(blob) // 2])
    with pytest.raises(PlanFormatError):
        Plan.load(path)
    # absent stays absent — a different recovery decision than corrupt
    with pytest.raises(FileNotFoundError):
        Plan.load(str(tmp_path / "nope.npz"))


def test_plan_checksums_in_header(served, tmp_path):
    _, plan, _, _ = served
    path = str(tmp_path / "plan.npz")
    plan.save(path)
    import json
    with np.load(path) as z:
        header = json.loads(str(z["__plan_json__"]))
    sums = header["checksums"]
    assert "schedule" in sums and any(k.startswith("cache/") for k in sums)


# ================================================ persistence: checkpoints
def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.normal(size=(8, 4)).astype(np.float32)),
            "step": jnp.int32(seed)}


def test_corrupt_checkpoint_falls_back_to_newest_intact(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=5)
    ck.save(_tree(1), 1, blocking=True)
    ck.save(_tree(2), 2, blocking=True)
    shard2 = str(tmp_path / "step-00000002" / "shard-0.npz")
    corrupt_file(shard2, seed=2, nbytes=8)
    with pytest.raises(CheckpointCorruptError):
        ck.restore(_tree(), step=2)
    out, manifest = ck.auto_resume(_tree())       # newest INTACT wins
    assert manifest["step"] == 1
    assert np.array_equal(np.asarray(out["w"]), np.asarray(_tree(1)["w"]))
    # all corrupt → explicit corruption error, not a silent fresh start
    corrupt_file(str(tmp_path / "step-00000001" / "shard-0.npz"),
                 seed=3, nbytes=8)
    with pytest.raises(CheckpointCorruptError, match="all 2 checkpoints"):
        ck.auto_resume(_tree())


def test_auto_resume_empty_dir_returns_none(tmp_path):
    assert Checkpointer(str(tmp_path)).auto_resume(_tree()) is None


def test_async_save_error_reraised_not_swallowed(tmp_path):
    """Satellite: a background-save failure surfaces on the NEXT save/wait
    instead of silently losing every checkpoint."""
    ck = Checkpointer(str(tmp_path),
                      faults=FaultInjector(script={"ckpt_io": [0]}))
    ck.save(_tree(1), 1)                          # async — error captured
    with pytest.raises(CheckpointError, match="async checkpoint save"):
        ck.wait()
    ck.save(_tree(2), 2, blocking=True)           # error was one-shot
    assert latest_step(str(tmp_path)) == 2


def test_blocking_save_error_raises_immediately(tmp_path):
    ck = Checkpointer(str(tmp_path),
                      faults=FaultInjector(script={"ckpt_io": [0]}))
    with pytest.raises(CheckpointError):
        ck.save(_tree(1), 1, blocking=True)
    assert latest_step(str(tmp_path)) is None     # no half-written debris


# ====================================================== training: NaN guard
@pytest.fixture(scope="module")
def train_setup(tiny_ds):
    pipe = _pipe(tiny_ds, max_outputs_per_batch=64, pad_multiple=32)
    tr_plan = pipe.plan("train")
    val_plan = pipe.plan("val", for_inference=True)
    cfg = GNNConfig(kind="gcn", in_dim=tiny_ds.feat_dim, hidden=32,
                    out_dim=tiny_ds.num_classes, num_layers=2)
    return tr_plan, val_plan, cfg


def _poisoned(plan, batch_i=0):
    """A copy of `plan` whose batch `batch_i` has all-NaN features."""
    fields = {k: np.array(v, copy=True) for k, v in plan.cache.fields.items()}
    fields["features"][batch_i] = np.nan
    meta = np.array([[m.get("nodes", 0), m.get("edges", 0),
                      m.get("outputs", 0)] for m in plan.cache.meta],
                    np.int64)
    return dataclasses.replace(plan,
                               cache=BatchCache.from_fields(fields, meta))


def test_nonfinite_policy_validation(train_setup):
    _, _, cfg = train_setup
    with pytest.raises(ValueError, match="nonfinite_policy"):
        GNNTrainer(cfg, nonfinite_policy="retry")


def test_guarded_step_holds_params_bit_exact(train_setup):
    tr_plan, _, cfg = train_setup
    tr = GNNTrainer(cfg, nonfinite_policy="skip")
    params = init_gnn(cfg, jax.random.PRNGKey(0))
    opt_state = tr.opt.init(params)
    bad = _poisoned(tr_plan).cache[0]
    p2, o2, loss, ok = tr._guarded_step(params, opt_state, bad,
                                        jnp.float32(1e-3),
                                        jax.random.PRNGKey(1))
    assert not bool(ok) and not np.isfinite(float(loss))
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(p2)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    # clean batch: the guarded step trains
    good = tr_plan.cache[0]
    p3, _, loss3, ok3 = tr._guarded_step(params, opt_state, good,
                                         jnp.float32(1e-3),
                                         jax.random.PRNGKey(1))
    assert bool(ok3) and np.isfinite(float(loss3))


def test_nan_guard_skip_trains_through(train_setup, tiny_ds):
    tr_plan, val_plan, cfg = train_setup
    tr = GNNTrainer(cfg, nonfinite_policy="skip", seed=0)
    res = tr.fit(_poisoned(tr_plan), val_plan, tiny_ds.num_classes,
                 epochs=2, schedule_mode="none")
    assert tr.fault_stats.nonfinite_steps == 2    # one poisoned step/epoch
    assert tr.fault_stats.skipped_steps == 2 and tr.fault_stats.halts == 0
    assert all(np.isfinite(h["train_loss"]) and np.isfinite(h["val_loss"])
               for h in res.history)
    for leaf in jax.tree_util.tree_leaves(res.params):
        assert np.isfinite(np.asarray(leaf)).all()
    assert tr.snapshot()["faults"]["skipped_steps"] == 2


def test_nan_guard_halt_raises(train_setup, tiny_ds):
    tr_plan, val_plan, cfg = train_setup
    tr = GNNTrainer(cfg, nonfinite_policy="halt", seed=0)
    with pytest.raises(NonFiniteGradError, match="epoch 0"):
        tr.fit(_poisoned(tr_plan), val_plan, tiny_ds.num_classes,
               epochs=2, schedule_mode="none")
    assert tr.fault_stats.halts == 1


def test_nan_guard_skip_with_grad_accum(train_setup, tiny_ds):
    """A NaN micro-batch must never reach the accumulator — one poisoned
    grad would poison the whole macro-step."""
    tr_plan, val_plan, cfg = train_setup
    tr = GNNTrainer(cfg, nonfinite_policy="skip", grad_accum=2, seed=0)
    res = tr.fit(_poisoned(tr_plan), val_plan, tiny_ds.num_classes,
                 epochs=1, schedule_mode="none")
    assert tr.fault_stats.nonfinite_steps == 1
    for leaf in jax.tree_util.tree_leaves(res.params):
        assert np.isfinite(np.asarray(leaf)).all()


# ============================================================ loader faults
def test_loader_injected_fault_surfaces_in_consumer():
    batches = [{"x": np.full((2, 2), i, np.float32)} for i in range(4)]
    loader = PrefetchLoader(batches,
                            faults=FaultInjector(script={"loader": [2]}))
    got = []
    with pytest.raises(InjectedFault):
        for b in loader:
            got.append(b)
    assert len(got) == 2                          # items before the fault
    assert isinstance(loader.failed, InjectedFault)
    assert not loader._worker.is_alive() or loader._worker.join(10.0) is None


# ================================================== elastic: dead-host lease
def test_heartbeats_fake_clock():
    clock = FakeClock()
    hb = Heartbeats(timeout_s=1.0, clock=clock)
    hb.beat(0)
    hb.beat(1)
    clock.advance(2.0)
    hb.beat(1)
    assert hb.dead_hosts() == [0]


def test_dead_host_lease_reassigned_at_epoch_boundary():
    """Satellite: dead_hosts() is actually WIRED — the crashed host's
    batches are re-leased and the epoch still covers every batch."""
    clock = FakeClock()
    coord = ElasticCoordinator(3, timeout_s=1.0, clock=clock)
    for h in range(3):
        coord.beat(h)
    clock.advance(2.0)
    coord.beat(0)
    coord.beat(1)                                 # host 2 went silent
    ids = list(range(10))
    q = coord.epoch_queue(ids)
    assert coord.dead == {2} and coord.live_hosts() == [0, 1]
    assert 2 not in q.leases                      # never a steal victim
    assert q.reassigned == len(partition_batches(ids, 3, 2))
    drained = []
    while True:
        got = [b for h in (0, 1) if (b := q.next_batch(h)) is not None]
        if not got:
            break
        drained.extend(got)
    assert sorted(drained) == ids                 # full coverage, no loss
    # death is sticky across epochs until revive
    q2 = coord.epoch_queue(ids)
    assert 2 not in q2.leases
    coord.revive(2)
    assert coord.live_hosts() == [0, 1, 2]
    assert 2 in coord.epoch_queue(ids).leases


def test_reassign_with_all_hosts_dead_raises():
    q = WorkQueue(list(range(4)), 2)
    with pytest.raises(RuntimeError, match="all hosts dead"):
        q.reassign([0, 1])
