"""Padded batch construction: induced subgraph oracle, padding, cache."""
import numpy as np
import pytest
from _hypothesis_fallback import given, settings, st

from repro.graph.csr import coo_to_csr, make_undirected, induced_subgraph
from repro.core.batches import build_batches, BatchCache


def test_induced_subgraph_oracle(tiny_ds):
    g = tiny_ds.norm_graph
    nodes = np.unique(np.random.default_rng(0).choice(g.num_nodes, 50))
    src, dst, w = induced_subgraph(g, nodes)
    m = g.to_scipy()
    sub = m[np.ix_(nodes, nodes)].tocoo()
    got = {(int(s), int(d)): float(x) for s, d, x in zip(src, dst, w)}
    want = {(int(r), int(c)): float(v)
            for r, c, v in zip(sub.row, sub.col, sub.data)}
    assert got == pytest.approx(want)


def test_build_batches_padding(tiny_ds):
    outputs = [tiny_ds.splits["train"][:40], tiny_ds.splits["train"][40:70]]
    aux = [np.unique(np.concatenate([o, o + 1])) % tiny_ds.num_nodes
           for o in outputs]
    aux = [np.unique(np.concatenate([a, o])) for a, o in zip(aux, outputs)]
    batches = build_batches(tiny_ds.norm_graph, tiny_ds.features,
                            tiny_ds.labels, outputs, aux, pad_multiple=32)
    shapes = {(b.node_ids.shape, b.edge_src.shape, b.output_idx.shape)
              for b in batches}
    assert len(shapes) == 1, "all batches share ONE static shape"
    for b, outs in zip(batches, outputs):
        assert b.num_real_outputs == len(outs)
        # labels of real outputs match dataset labels
        assert (b.labels[:len(outs)] == tiny_ds.labels[outs]).all()
        # features cached for real nodes
        nid = b.node_ids[b.node_mask]
        assert np.allclose(b.features[:len(nid)], tiny_ds.features[nid])
        # padded edges have zero weight
        assert (b.edge_weight[~b.edge_mask] == 0).all()


def test_batch_cache_roundtrip(tmp_path, tiny_ds):
    outputs = [tiny_ds.splits["train"][:32]]
    aux = [np.unique(np.concatenate([outputs[0], outputs[0] + 1]))
           % tiny_ds.num_nodes]
    aux = [np.unique(np.concatenate([aux[0], outputs[0]]))]
    batches = build_batches(tiny_ds.norm_graph, tiny_ds.features,
                            tiny_ds.labels, outputs, aux, pad_multiple=32)
    cache = BatchCache(batches)
    # contiguity: every field is one contiguous block
    for v in cache.fields.values():
        assert v.flags["C_CONTIGUOUS"]
    path = str(tmp_path / "cache.npz")
    cache.save(path)
    loaded = BatchCache.load(path)
    assert set(loaded.fields) == set(cache.fields)
    for k in cache.fields:
        assert np.array_equal(cache.fields[k], loaded.fields[k])
    # meta (real nodes/edges/outputs counts) must survive the round-trip —
    # load used to restore it as empty dicts
    assert loaded.meta == cache.meta
    assert loaded.meta[0]["outputs"] == 32
    assert loaded.meta[0]["nodes"] > 0 and loaded.meta[0]["edges"] > 0


def test_batch_cache_legacy_npz_resave(tmp_path, tiny_ds):
    """A cache saved WITHOUT meta (pre-fix format) must load with empty meta
    and still be re-saveable (writes zero counts, no KeyError)."""
    outputs = [tiny_ds.splits["train"][:32]]
    aux = [np.unique(np.concatenate([outputs[0], outputs[0] + 1]))
           % tiny_ds.num_nodes]
    cache = BatchCache(build_batches(tiny_ds.norm_graph, tiny_ds.features,
                                     tiny_ds.labels, outputs, aux,
                                     pad_multiple=32))
    legacy = str(tmp_path / "legacy.npz")
    np.savez(legacy, **cache.fields)            # old format: fields only
    loaded = BatchCache.load(legacy)
    assert loaded.meta == [{}]
    resaved = str(tmp_path / "resaved.npz")
    loaded.save(resaved)                        # must not crash
    again = BatchCache.load(resaved)
    assert again.meta == [dict(nodes=0, edges=0, outputs=0)]
    for k in cache.fields:
        assert np.array_equal(cache.fields[k], again.fields[k])


def test_batch_cache_stacks_bcsr_tiles(tmp_path, tiny_ds):
    """Tiles ride in the contiguous cache like every other field."""
    outputs = [tiny_ds.splits["train"][:32], tiny_ds.splits["train"][32:64]]
    aux = [np.unique(np.concatenate([o, o + 1, o])) % tiny_ds.num_nodes
           for o in outputs]
    aux = [np.unique(np.concatenate([a, o])) for a, o in zip(aux, outputs)]
    batches = build_batches(tiny_ds.norm_graph, tiny_ds.features,
                            tiny_ds.labels, outputs, aux, pad_multiple=32,
                            bcsr_block=32)
    assert all(b.has_bcsr for b in batches)
    assert len({b.tile_vals.shape for b in batches}) == 1, "shared K pad"
    cache = BatchCache(batches)
    assert cache.fields["tile_vals"].flags["C_CONTIGUOUS"]
    assert cache.fields["tile_cols"].shape[0] == len(batches)
    path = str(tmp_path / "cache.npz")
    cache.save(path)
    loaded = BatchCache.load(path)
    assert np.array_equal(cache.fields["tile_vals"], loaded.fields["tile_vals"])


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_induced_subgraph_property(seed):
    """Property: induced subgraph == scipy fancy-index for random graphs."""
    rng = np.random.default_rng(seed)
    n = 40
    e = 150
    g = make_undirected(coo_to_csr(rng.integers(0, n, e),
                                   rng.integers(0, n, e), n))
    nodes = np.unique(rng.choice(n, rng.integers(2, n)))
    src, dst, w = induced_subgraph(g, nodes)
    sub = g.to_scipy()[np.ix_(nodes, nodes)].tocoo()
    assert len(src) == sub.nnz
    got = sorted(zip(src.tolist(), dst.tolist()))
    want = sorted(zip(sub.row.tolist(), sub.col.tolist()))
    assert got == want
