"""Trip-count-aware HLO analysis: scan/nested-scan FLOP accounting."""
import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import analyze_hlo, xla_cost_analysis


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def test_scan_flops_multiplied():
    def f(x, w):
        def body(c, wi):
            return c @ wi, None
        out, _ = jax.lax.scan(body, x, w)
        return out
    c = _compile(f, jax.ShapeDtypeStruct((128, 128), jnp.float32),
                 jax.ShapeDtypeStruct((10, 128, 128), jnp.float32))
    res = analyze_hlo(c.as_text())
    expect = 10 * 2 * 128 ** 3
    assert abs(res["flops"] - expect) / expect < 0.01
    # XLA's own counter is ~10x off — that's why the parser exists
    assert xla_cost_analysis(c)["flops"] < expect / 5


def test_nested_scan_flops():
    def g(x, w):
        def outer(c, wi):
            def inner(c2, _):
                return c2 @ wi, None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        out, _ = jax.lax.scan(outer, x, w)
        return out
    c = _compile(g, jax.ShapeDtypeStruct((128, 128), jnp.float32),
                 jax.ShapeDtypeStruct((10, 128, 128), jnp.float32))
    res = analyze_hlo(c.as_text())
    expect = 30 * 2 * 128 ** 3
    assert abs(res["flops"] - expect) / expect < 0.01


def test_bytes_positive_and_sane():
    def f(x):
        return jnp.tanh(x @ x)
    c = _compile(f, jax.ShapeDtypeStruct((256, 256), jnp.float32))
    res = analyze_hlo(c.as_text())
    assert res["bytes"] >= 3 * 256 * 256 * 4   # two reads + one write minimum
    assert res["collective_bytes"] == 0.0
