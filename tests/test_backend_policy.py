"""BackendPolicy + plan-build autotuner (DESIGN.md §14): policy resolution
precedence, the analytic tile autotuner (determinism, fingerprint pinning,
streamed/resident agreement), plan format v3 round-trips (v2 back-compat
included), the fused/streaming bcsr SpMM impls, and bitwise auto-vs-forced
dispatch parity through the engine, the trainer, and the shard_map executor
(a 1-device mesh runs the full machinery everywhere).
"""
import dataclasses
import json
import warnings

import jax
import numpy as np
import pytest
import scipy.sparse as sp

from repro.core import IBMBConfig, IBMBPipeline, Plan, autotune
from repro.core.batches import build_batches
from repro.core.plan import BACKEND_CODES, decode_backends, encode_backends
from repro.graph.csr import coo_to_csr, make_undirected
from repro.kernels.spmm import csr_to_bcsr, spmm_bcsr, spmm_bcsr_sym
from repro.models.gnn import GNNConfig, init_gnn
from repro.models.gnn import ops as gnn_ops
from repro.models.gnn import policy as gnn_policy
from repro.models.gnn.policy import BackendPolicy
from repro.serve import GNNInferenceEngine
from repro.train import GNNTrainer

# decisions pinned for parity tests: kappa huge → every tiled batch decides
# bcsr; kappa 0 → every batch decides segment. tune_block_fs=() keeps the
# stored block_f at 0, so the auto-dispatched executable's config is
# field-for-field the forced one (bitwise parity is then a jit identity).
ALL_BCSR = dict(autotune=True, auto_kappa=1e9, tune_block_fs=())
ALL_SEG = dict(autotune=True, auto_kappa=0.0, tune_block_fs=())


def _pipe(ds, **kw):
    cfg = dict(variant="node", k_per_output=8, max_outputs_per_batch=16,
               pad_multiple=32, backend="bcsr")
    cfg.update(kw)
    return IBMBPipeline(ds, IBMBConfig(**cfg))


def _cfg(ds, **kw):
    kw.setdefault("dropout", 0.0)
    kw.setdefault("kind", "gcn")
    return GNNConfig(in_dim=ds.feat_dim, hidden=32,
                     out_dim=ds.num_classes, num_layers=2, **kw)


def _band_graph(n=256, width=4, seed=0):
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    src = np.concatenate([perm[:-d] for d in range(1, width + 1)])
    dst = np.concatenate([perm[d:] for d in range(1, width + 1)])
    return make_undirected(coo_to_csr(src, dst, n))


# ------------------------------------------------------------ policy API
def test_as_policy_normalization():
    assert gnn_policy.as_policy(None) is None
    p = gnn_policy.as_policy("bcsr")
    assert p == BackendPolicy.fixed("bcsr") and not p.is_auto
    assert gnn_policy.as_policy("auto").is_auto
    pol = BackendPolicy.auto()
    assert gnn_policy.as_policy(pol) is pol
    with pytest.raises(ValueError, match="unknown aggregation backend"):
        gnn_policy.as_policy("warp")
    with pytest.raises(TypeError, match="BackendPolicy"):
        gnn_policy.as_policy(3)


def test_resolve_precedence_and_auto_base():
    cfg = GNNConfig(kind="gcn", in_dim=4, hidden=8, out_dim=2, num_layers=2,
                    backend="segment")
    # no override → the config's own backend, as a fixed policy
    c, p = gnn_policy.resolve(cfg)
    assert c.backend == "segment" and p == BackendPolicy.fixed("segment")
    # explicit arg wins over the config
    c, p = gnn_policy.resolve(cfg, "dense")
    assert c.backend == "dense" and p.backend == "dense"
    # auto resolves the config to the always-executable segment base
    c, p = gnn_policy.resolve(cfg, BackendPolicy.auto())
    assert c.backend == "segment" and p.is_auto
    # a config may itself ask for auto
    c, p = gnn_policy.resolve(dataclasses.replace(cfg, backend="auto"))
    assert c.backend == "segment" and p.is_auto


def test_env_alias_forces_fixed_and_warns_once(monkeypatch):
    monkeypatch.setenv("REPRO_GNN_BACKEND", "dense")
    monkeypatch.setattr(gnn_ops, "_env_warned", False)
    cfg = GNNConfig(kind="gcn", in_dim=4, hidden=8, out_dim=2, num_layers=2)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        c, p = gnn_policy.resolve(cfg, BackendPolicy.auto())
        gnn_policy.resolve(cfg, "bcsr")
    # the deprecated alias overrides even an explicit auto/bcsr override...
    assert p == BackendPolicy.fixed("dense") and c.backend == "dense"
    # ...and deprecation-warns exactly once per process
    dep = [x for x in w if issubclass(x.category, DeprecationWarning)]
    assert len(dep) == 1 and "REPRO_GNN_BACKEND" in str(dep[0].message)


def test_batch_config_is_noop_when_matching():
    cfg = GNNConfig(kind="gcn", in_dim=4, hidden=8, out_dim=2, num_layers=2,
                    backend="bcsr")
    assert gnn_policy.batch_config(cfg, "bcsr", 0) is cfg
    c2 = gnn_policy.batch_config(cfg, "segment", 0)
    assert c2.backend == "segment" and cfg.backend == "bcsr"


def test_superstep_decision_uniform_and_mixed():
    d = [("bcsr", 128), ("bcsr", 128), ("segment", 0), ("bcsr", 256)]
    assert gnn_policy.superstep_decision(d, [0, 1]) == ("bcsr", 128)
    # same backend, mixed block_f → keep the backend, drop the tuned width
    assert gnn_policy.superstep_decision(d, [0, 3]) == ("bcsr", 0)
    # mixed backends → the always-executable fallback
    assert gnn_policy.superstep_decision(d, [1, 2]) == ("segment", 0)


# ----------------------------------------------------- analytic autotuner
@pytest.mark.parametrize("block", [16, 32, 64])
def test_tile_shape_stats_matches_converter(block):
    """The analytic (nonzero_tiles, K) must equal what csr_to_bcsr emits —
    including its drop-zero-weights and empty→K=1 conventions."""
    rng = np.random.default_rng(block)
    n = 128
    a = sp.random(n, n, density=0.03, random_state=int(block),
                  format="csr", dtype=np.float32)
    a = (a + a.T).tocsr()
    coo = a.tocoo()
    src, dst, w = coo.row, coo.col, coo.data.copy()
    w[rng.random(len(w)) < 0.2] = 0.0            # padded entries to drop
    tiles, k = autotune.tile_shape_stats(src, dst, w, n, block)
    nz = w != 0
    g = coo_to_csr(src[nz], dst[nz], n, weights=w[nz])
    bc = csr_to_bcsr(g.indptr, g.indices, g.weights, n, n, block=block)
    stats = bc.density_stats()
    assert tiles == stats["nonzero_tiles"]
    assert k == bc.tile_cols.shape[1]
    # empty adjacency: converter emits one zero tile per row, K=1
    assert autotune.tile_shape_stats(src, dst, np.zeros_like(w), n, block) \
        == (0, 1)


def test_tune_block_f_budget():
    # k=1 single-buffers; everything fits a generous budget → widest wins
    assert autotune.tune_block_f(1, 64, (128, 256, 512), 8192) == 512
    # shrink the budget until only the narrowest candidate fits:
    # vals = 4*4*64*64 = 64KiB, per-bf cost = 4*3*64*bf
    assert autotune.tune_block_f(4, 64, (128, 256, 512), 160) == 128
    # nothing fits → the narrowest candidate anyway (never 0 tiles wide)
    assert autotune.tune_block_f(4, 64, (128, 256), 1) == 128
    assert autotune.tune_block_f(4, 64, (), 8192) == 0


def test_decide_backend_kappa_threshold():
    s = dict(edges=100, block=16, nonzero_tiles=4)   # padded flops 1024
    assert autotune.decide_backend(s, 16.0) == "bcsr"     # 1024 <= 1600
    assert autotune.decide_backend(s, 10.0) == "segment"  # 1024 > 1000
    assert autotune.decide_backend(dict(edges=100), 16.0) == "segment"


def test_retile_matches_direct_build():
    """Resident retiling (build at the default block, retile at the winner)
    must be bitwise what building at the winner directly produces — the
    invariant that keeps streamed and resident tuned plans identical."""
    g = _band_graph()
    n = g.num_nodes
    feats = np.zeros((n, 4), np.float32)
    labels = np.zeros(n, np.int32)
    outs = [np.arange(n // 2), np.arange(n // 2, n)]
    kw = dict(pad_multiple=64, reorder="bfs")
    at128 = build_batches(g, feats, labels, outs, outs, bcsr_block=128, **kw)
    at32 = build_batches(g, feats, labels, outs, outs, bcsr_block=32, **kw)
    pad_k = at32[0].tile_cols.shape[1]
    retiled = autotune.retile_batches(at128, 32, pad_k)
    for a, b in zip(retiled, at32):
        assert np.array_equal(a.tile_cols, b.tile_cols)
        assert np.array_equal(a.tile_vals, b.tile_vals)


def test_retune_picks_cheapest_block_ties_to_larger():
    g = _band_graph()
    n = g.num_nodes
    feats = np.zeros((n, 4), np.float32)
    labels = np.zeros(n, np.int32)
    outs = [np.arange(n)]
    batches = build_batches(g, feats, labels, outs, outs, pad_multiple=64,
                            bcsr_block=128, reorder="bfs")
    cfg = IBMBConfig(variant="node", backend="bcsr", bcsr_block=128,
                     tune_blocks=(16, 32, 64))
    tuned, block = autotune.retune_tile_block(batches, cfg)
    mn = batches[0].node_ids.shape[0]
    costs, _ = autotune.sweep_tile_blocks(
        batches, autotune.tile_block_candidates(cfg, mn))
    assert block == min(costs, key=lambda b: (costs[b], -b))
    assert tuned[0].tile_vals.shape[-1] == block
    # ties break to the larger block
    assert autotune.pick_tile_block({16: 100, 32: 100, 64: 200}) == 32


def test_autotune_deterministic_and_fingerprint_pinned(tiny_ds):
    kw = dict(tune_blocks=(16, 32), **ALL_BCSR)
    p1 = _pipe(tiny_ds, **kw).plan("train")
    p2 = _pipe(tiny_ds, **kw).plan("train")
    assert p1.fingerprint == p2.fingerprint
    assert np.array_equal(p1.batch_backend, p2.batch_backend)
    assert np.array_equal(p1.batch_block_f, p2.batch_block_f)
    assert np.array_equal(p1.cache.fields["tile_vals"],
                          p2.cache.fields["tile_vals"])
    # the autotuner knobs are pinned by the fingerprint: changing the sweep
    # (or kappa) yields a DIFFERENT artifact identity, so a cached plan can
    # never silently serve another tuning config's decisions
    assert _pipe(tiny_ds, **ALL_BCSR).fingerprint("train") \
        != _pipe(tiny_ds, tune_blocks=(16, 32), **ALL_BCSR) \
        .fingerprint("train")
    assert _pipe(tiny_ds, **ALL_BCSR).fingerprint("train") \
        != _pipe(tiny_ds, **ALL_SEG).fingerprint("train")


def test_plan_stores_decisions_and_stats(tiny_ds):
    plan = _pipe(tiny_ds, **ALL_BCSR).plan("train")
    assert plan.batch_backend is not None
    assert plan.batch_backends() == ["bcsr"] * len(plan)
    assert list(plan.batch_block_fs()) == [0] * len(plan)
    stats = plan.meta["batch_stats"]
    assert len(stats) == len(plan)
    for s in stats:
        assert {"nodes", "edges", "avg_degree", "tile_fill",
                "backend", "block_f"} <= set(s)
        assert s["backend"] == "bcsr"
    json.dumps(stats)                     # meta must stay JSON-serializable
    seg = _pipe(tiny_ds, **ALL_SEG).plan("train")
    assert seg.batch_backends() == ["segment"] * len(seg)


# ------------------------------------------------- plan format v3 / v2
def test_plan_v3_save_load_roundtrip(tiny_ds, tmp_path):
    plan = _pipe(tiny_ds, **ALL_BCSR).plan("train")
    path = str(tmp_path / "plan.npz")
    plan.save(path)
    loaded = Plan.load(path)
    assert np.array_equal(loaded.batch_backend, plan.batch_backend)
    assert np.array_equal(loaded.batch_block_f, plan.batch_block_f)
    assert loaded.batch_backends() == plan.batch_backends()
    assert loaded.meta["batch_stats"] == plan.meta["batch_stats"]


def test_plan_v2_artifact_still_loads(tiny_ds, tmp_path):
    """A doctored v2 artifact (no decision arrays, header version 2) loads,
    and its decisions fall back to the configured backend — exactly what a
    v2 plan executed before per-batch dispatch existed."""
    plan = _pipe(tiny_ds, **ALL_SEG).plan("train")   # mixed-free baseline
    p3 = str(tmp_path / "v3.npz")
    plan.save(p3)
    with np.load(p3, allow_pickle=False) as z:
        arrays = {k: z[k] for k in z.files}
    hdr = json.loads(str(arrays.pop("__plan_json__")))
    hdr["version"] = 2
    for k in ("batch_backend", "batch_block_f"):
        arrays.pop(k, None)
        hdr["checksums"].pop(k, None)
    arrays["__plan_json__"] = np.array(json.dumps(hdr))
    p2 = str(tmp_path / "v2.npz")
    with open(p2, "wb") as f:
        np.savez(f, **arrays)
    loaded = Plan.load(p2, expect_fingerprint=plan.fingerprint)
    assert loaded.batch_backend is None
    assert loaded.batch_backends() == ["bcsr"] * len(plan)   # meta backend
    assert list(loaded.batch_block_fs()) == [0] * len(plan)
    # auto dispatch over the v2 plan = the configured backend everywhere
    cfg = _cfg(tiny_ds)
    decs = gnn_policy.batch_decisions(loaded, BackendPolicy.auto(), cfg)
    assert decs == [("bcsr", 0)] * len(plan)


def test_backend_codes_roundtrip_and_stability():
    names = ["segment", "bcsr", "dense", "bcsr"]
    codes = encode_backends(names)
    assert codes.dtype == np.int8
    assert decode_backends(codes) == names
    # serialization table is frozen: re-numbering would corrupt artifacts
    assert BACKEND_CODES == {"segment": 0, "bcsr": 1, "dense": 2}


def test_ooc_store_roundtrips_decisions(tiny_ds, tmp_path):
    from repro.ooc.store import PlanStore, write_store
    plan = _pipe(tiny_ds, **ALL_BCSR).plan("train")
    write_store(str(tmp_path / "store"), plan)
    store = PlanStore.open(str(tmp_path / "store"))
    back = store.as_plan()
    assert np.array_equal(back.batch_backend, plan.batch_backend)
    assert np.array_equal(back.batch_block_f, plan.batch_block_f)
    assert back.batch_backends() == plan.batch_backends()


def test_streamed_plan_decisions_match_resident(tiny_ds, tmp_path):
    from repro.ooc.stream import stream_plan
    from repro.ooc.store import PlanStore
    kw = dict(tune_blocks=(16, 32), **ALL_BCSR)
    resident = _pipe(tiny_ds, **kw).plan("train")
    stream_plan(_pipe(tiny_ds, **kw), "train", False, str(tmp_path / "s"))
    streamed = PlanStore.open(str(tmp_path / "s")).as_plan()
    assert streamed.fingerprint == resident.fingerprint
    assert streamed.batch_backends() == resident.batch_backends()
    assert np.array_equal(streamed.batch_block_fs(),
                          resident.batch_block_fs())
    assert np.array_equal(streamed.cache.fields["tile_vals"],
                          resident.cache.fields["tile_vals"])
    assert streamed.meta["batch_stats"] == resident.meta["batch_stats"]


# ------------------------------------------------------- spmm impls
def _bcsr_case(seed=0, n=96, f=128, block=32):
    rng = np.random.default_rng(seed)
    a = sp.random(n, n, density=0.08, random_state=seed, format="csr",
                  dtype=np.float32)
    a = (a + a.T).tocsr()
    bc = csr_to_bcsr(a.indptr, a.indices, a.data, n, n, block=block)
    x = rng.normal(size=(n, f)).astype(np.float32)
    return a, bc, x


@pytest.mark.parametrize("impl", ["stream", "fused_interpret"])
def test_spmm_impls_match_reference(impl):
    a, bc, x = _bcsr_case()
    want = np.asarray(spmm_bcsr(bc.tile_cols, bc.tile_vals, x,
                                impl="reference"))
    got = np.asarray(spmm_bcsr(bc.tile_cols, bc.tile_vals, x, impl=impl,
                               block_f=64))
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-5)
    np.testing.assert_allclose(want, a @ x, atol=1e-4, rtol=1e-5)


def test_spmm_stream_vjp_is_transpose():
    a, bc, x = _bcsr_case(seed=3, f=16)
    g = np.random.default_rng(4).normal(size=x.shape).astype(np.float32)
    _, vjp = jax.vjp(
        lambda x_: spmm_bcsr_sym(bc.tile_cols, bc.tile_vals, x_,
                                 impl="stream"), x)
    (dx,) = vjp(g)
    np.testing.assert_allclose(np.asarray(dx), a.T @ g, atol=1e-4)


# ----------------------------------------- auto-vs-forced bitwise parity
@pytest.mark.parametrize("pin, forced", [(ALL_BCSR, "bcsr"),
                                         (ALL_SEG, "segment")])
def test_engine_auto_matches_forced_bitwise(tiny_ds, pin, forced):
    plan = _pipe(tiny_ds, **pin).plan("test", for_inference=True)
    assert plan.batch_backends() == [forced] * len(plan)
    cfg = _cfg(tiny_ds)
    params = init_gnn(cfg, jax.random.PRNGKey(0))
    q = plan.routing.node_ids
    auto = GNNInferenceEngine(plan, cfg, params, backend="auto",
                              cache_batches=0)
    force = GNNInferenceEngine(plan, cfg, params, backend=forced,
                               cache_batches=0)
    assert np.array_equal(auto.query(q), force.query(q))


@pytest.mark.parametrize("pin, forced", [(ALL_BCSR, "bcsr"),
                                         (ALL_SEG, "segment")])
def test_trainer_auto_matches_forced_bitwise(tiny_ds, pin, forced):
    pipe = _pipe(tiny_ds, **pin)
    tr = pipe.plan("train")
    va = pipe.plan("val", for_inference=True)
    cfg = _cfg(tiny_ds, dropout=0.3)
    kw = dict(lr=1e-3, seed=0)
    res_a = GNNTrainer(cfg, backend="auto", **kw).fit(
        tr, va, tiny_ds.num_classes, epochs=2)
    res_f = GNNTrainer(cfg, backend=forced, **kw).fit(
        tr, va, tiny_ds.num_classes, epochs=2)
    for ha, hf in zip(res_a.history, res_f.history):
        assert ha["train_loss"] == hf["train_loss"]
        assert ha["val_loss"] == hf["val_loss"]
    for a, b in zip(jax.tree_util.tree_leaves(res_a.params),
                    jax.tree_util.tree_leaves(res_f.params)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_executor_auto_matches_forced_bitwise(tiny_ds):
    """Auto dispatch through the shard_map super-step path (1-device mesh
    runs the full machinery on tier-1) — bitwise vs the forced backend."""
    from repro.dist.data_parallel import data_mesh
    pipe = _pipe(tiny_ds, **ALL_BCSR)
    tr = pipe.plan("train")
    va = pipe.plan("val", for_inference=True)
    cfg = _cfg(tiny_ds, dropout=0.3)
    res_a = GNNTrainer(cfg, backend="auto", lr=1e-3, seed=0).fit(
        tr, va, tiny_ds.num_classes, epochs=2, mesh=data_mesh(1))
    res_f = GNNTrainer(cfg, backend="bcsr", lr=1e-3, seed=0).fit(
        tr, va, tiny_ds.num_classes, epochs=2, mesh=data_mesh(1))
    for a, b in zip(jax.tree_util.tree_leaves(res_a.params),
                    jax.tree_util.tree_leaves(res_f.params)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_mixed_plan_dispatches_per_batch(tiny_ds):
    """With a mid kappa the plan may mix backends; whatever it decided, the
    engine's auto answers must match the all-segment forced engine to fp32
    tolerance (different backends = different float association, so this is
    allclose, not bitwise), and the stored decisions drive the dispatch."""
    plan = _pipe(tiny_ds, **ALL_BCSR).plan("test", for_inference=True)
    cfg = _cfg(tiny_ds)
    decs = gnn_policy.batch_decisions(plan, BackendPolicy.auto(), cfg)
    assert decs == list(zip(plan.batch_backends(),
                            (int(x) for x in plan.batch_block_fs())))
    params = init_gnn(cfg, jax.random.PRNGKey(0))
    q = plan.routing.node_ids
    auto = GNNInferenceEngine(plan, cfg, params, backend="auto",
                              cache_batches=0)
    seg = GNNInferenceEngine(plan, cfg, params, backend="segment",
                             cache_batches=0)
    np.testing.assert_allclose(auto.query(q), seg.query(q), atol=1e-4)


def test_gat_auto_resolves_to_segment(tiny_ds):
    plan = _pipe(tiny_ds, **ALL_BCSR).plan("test", for_inference=True)
    cfg = _cfg(tiny_ds, kind="gat", heads=2)
    decs = gnn_policy.batch_decisions(plan, BackendPolicy.auto(), cfg)
    assert decs == [("segment", 0)] * len(plan)
