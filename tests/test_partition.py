"""Output-node partitioning: true partitions, size caps, locality."""
import numpy as np
import pytest

from repro.core.ppr import push_appr
from repro.core.partition import (
    ppr_distance_partition, graph_partition, random_partition)


def _check_partition(parts, outputs):
    allnodes = np.concatenate(parts)
    assert len(allnodes) == len(outputs), "must cover every output exactly once"
    assert set(allnodes.tolist()) == set(np.asarray(outputs).tolist())


def test_ppr_distance_partition(tiny_ds):
    outputs = tiny_ds.splits["train"]
    ppr = push_appr(tiny_ds.graph, outputs, topk=32)
    parts = ppr_distance_partition(ppr, outputs, max_outputs_per_batch=64)
    _check_partition(parts, outputs)
    assert all(len(p) <= 64 for p in parts)


def test_ppr_distance_partition_groups_neighbors(tiny_ds):
    """Nodes of the same SBM community should co-occur more than chance."""
    outputs = tiny_ds.splits["train"]
    ppr = push_appr(tiny_ds.graph, outputs, topk=32)
    parts = ppr_distance_partition(ppr, outputs, max_outputs_per_batch=64)
    labels = tiny_ds.labels
    # average intra-batch label agreement vs global
    agree = []
    for p in parts:
        if len(p) < 2:
            continue
        l = labels[p]
        agree.append((l[:, None] == l[None, :]).mean())
    global_p = np.mean([
        (labels[outputs][:, None] == labels[outputs][None, :]).mean()])
    assert np.mean(agree) > global_p + 0.05


@pytest.mark.parametrize("method", ["fennel", "louvain", "random"])
def test_graph_partition(tiny_ds, method):
    outputs = tiny_ds.splits["train"]
    parts = graph_partition(tiny_ds.graph, outputs, 4, method=method)
    _check_partition(parts, outputs)


def test_random_partition(tiny_ds):
    outputs = tiny_ds.splits["train"]
    parts = random_partition(outputs, 4)
    _check_partition(parts, outputs)
    sizes = [len(p) for p in parts]
    assert max(sizes) - min(sizes) <= 1
