"""Aggregation-backend subsystem (DESIGN.md §7): backend equivalence on every
GNN variant, BCSR conversion correctness, node-reordering tile-fill
regression, and end-to-end segment-vs-bcsr training parity."""
import jax
import numpy as np
import pytest
import scipy.sparse as sp

from repro.core import IBMBPipeline, IBMBConfig
from repro.core.batches import batch_node_order, build_batches
from repro.kernels.spmm.ops import csr_to_bcsr, spmm_bcsr, spmm_bcsr_sym
from repro.models.gnn import GNNConfig, init_gnn, gnn_apply
from repro.models.gnn.ops import resolve_backend


@pytest.fixture(scope="module")
def bcsr_batches(tiny_ds):
    pipe = IBMBPipeline(tiny_ds, IBMBConfig(
        variant="node", k_per_output=8, max_outputs_per_batch=64,
        pad_multiple=32, backend="bcsr"))
    return pipe.preprocess("train")


# ------------------------------------------------------- backend equivalence
@pytest.mark.parametrize("kind", ["gcn", "sage", "gat"])
@pytest.mark.parametrize("backend", ["bcsr", "dense"])
def test_backend_matches_segment_reference(tiny_ds, bcsr_batches, kind, backend):
    """bcsr (interpret-mode Pallas) and dense match the segment reference on
    every GNN variant, on real padded/masked-edge batches."""
    b = bcsr_batches[0]
    assert b.has_bcsr
    # the batch genuinely exercises padding + masked edges
    assert not b.node_mask.all() and not b.edge_mask.all()
    bd = b.device_arrays()
    outs = {}
    for be in ["segment", backend]:
        cfg = GNNConfig(kind=kind, in_dim=tiny_ds.feat_dim, hidden=64,
                        out_dim=tiny_ds.num_classes, num_layers=3, backend=be)
        params = init_gnn(cfg, jax.random.PRNGKey(0))
        outs[be] = np.asarray(gnn_apply(cfg, params, bd))
    np.testing.assert_allclose(outs[backend], outs["segment"], atol=1e-4)


@pytest.mark.parametrize("kind", ["gcn", "sage"])
def test_backend_gradient_matches_segment(tiny_ds, bcsr_batches, kind):
    """The custom-vjp symmetric SpMM gives the same parameter gradients as
    the differentiable segment path (DESIGN.md §7)."""
    from repro.models.gnn.models import output_logits, masked_xent
    bd = bcsr_batches[0].device_arrays()

    grads = {}
    for be in ["segment", "bcsr"]:
        cfg = GNNConfig(kind=kind, in_dim=tiny_ds.feat_dim, hidden=32,
                        out_dim=tiny_ds.num_classes, num_layers=2, backend=be)
        params = init_gnn(cfg, jax.random.PRNGKey(1))

        def loss(p):
            h = gnn_apply(cfg, p, bd)
            return masked_xent(output_logits(h, bd), bd["labels"],
                               bd["output_mask"])

        grads[be] = jax.grad(loss)(params)
    for ga, gb in zip(jax.tree_util.tree_leaves(grads["segment"]),
                      jax.tree_util.tree_leaves(grads["bcsr"])):
        np.testing.assert_allclose(np.asarray(ga), np.asarray(gb), atol=1e-4)


def test_bcsr_backend_requires_tiles(tiny_ds):
    pipe = IBMBPipeline(tiny_ds, IBMBConfig(
        variant="node", k_per_output=8, max_outputs_per_batch=64,
        pad_multiple=32))                       # segment pipeline: no tiles
    bd = pipe.preprocess("train")[0].device_arrays()
    assert "tile_cols" not in bd
    cfg = GNNConfig(kind="gcn", in_dim=tiny_ds.feat_dim, hidden=32,
                    out_dim=tiny_ds.num_classes, num_layers=2, backend="bcsr")
    params = init_gnn(cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="bcsr"):
        gnn_apply(cfg, params, bd)


def test_env_override_resolves_backend(monkeypatch):
    assert resolve_backend("segment") == "segment"
    monkeypatch.setenv("REPRO_GNN_BACKEND", "dense")
    assert resolve_backend("segment") == "dense"
    monkeypatch.setenv("REPRO_GNN_BACKEND", "nope")
    with pytest.raises(ValueError):
        resolve_backend("segment")


# ------------------------------------------------------------ conversion
@pytest.mark.parametrize("n,nc,density,block", [
    (300, 300, 0.02, 128), (130, 200, 0.1, 64), (64, 64, 0.3, 32)])
def test_csr_to_bcsr_dense_reconstruction(n, nc, density, block):
    """Vectorized conversion reproduces the matrix exactly (tile scatter)."""
    m = sp.random(n, nc, density=density, random_state=7, format="csr",
                  dtype=np.float32)
    bc = csr_to_bcsr(m.indptr, m.indices, m.data, n, nc, block=block)
    dense = np.zeros((bc.num_rows, bc.num_cols), np.float32)
    r_t, k_t, b, _ = bc.tile_vals.shape
    for r in range(r_t):
        for k in range(k_t):
            c = int(bc.tile_cols[r, k])
            dense[r * b:(r + 1) * b, c * b:(c + 1) * b] += bc.tile_vals[r, k]
    want = np.zeros_like(dense)
    want[:n, :nc] = m.toarray()
    np.testing.assert_array_equal(dense, want)


def test_csr_to_bcsr_pad_k_and_empty():
    bc = csr_to_bcsr(np.zeros(9, np.int64), np.zeros(0, np.int32),
                     np.zeros(0, np.float32), 8, 8, block=8, pad_k=4)
    assert bc.tile_vals.shape == (1, 4, 8, 8)
    assert bc.density_stats()["nonzero_tiles"] == 0
    m = sp.random(64, 64, density=0.1, random_state=0, format="csr",
                  dtype=np.float32)
    tight = csr_to_bcsr(m.indptr, m.indices, m.data, 64, 64, block=32)
    k = tight.tile_cols.shape[1]
    padded = csr_to_bcsr(m.indptr, m.indices, m.data, 64, 64, block=32,
                         pad_k=k + 3)
    assert padded.tile_cols.shape[1] == k + 3
    x = np.random.default_rng(0).normal(size=(64, 16)).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(spmm_bcsr(tight.tile_cols, tight.tile_vals, x)),
        np.asarray(spmm_bcsr(padded.tile_cols, padded.tile_vals, x)),
        atol=1e-5)
    with pytest.raises(ValueError):
        csr_to_bcsr(m.indptr, m.indices, m.data, 64, 64, block=32, pad_k=1)


def test_spmm_sym_vjp_is_transpose():
    """For symmetric A, d(A@x)/dx applied to g must equal A@g."""
    rng = np.random.default_rng(3)
    a = sp.random(96, 96, density=0.1, random_state=3, format="csr",
                  dtype=np.float32)
    a = (a + a.T).tocsr()
    bc = csr_to_bcsr(a.indptr, a.indices, a.data, 96, 96, block=32)
    x = rng.normal(size=(96, 8)).astype(np.float32)
    g = rng.normal(size=(96, 8)).astype(np.float32)
    _, vjp = jax.vjp(lambda x_: spmm_bcsr_sym(bc.tile_cols, bc.tile_vals, x_),
                     x)
    (dx,) = vjp(g)
    np.testing.assert_allclose(np.asarray(dx), a.T @ g, atol=1e-4)


# ------------------------------------------------- reordering / tile fill
def _shuffled_band_graph(n=256, width=3, seed=0):
    """Banded (locality-rich) graph whose node ids are shuffled, so
    sorted-global-id order scatters nonzeros across tiles."""
    from repro.graph.csr import coo_to_csr, make_undirected
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    src, dst = [], []
    for d in range(1, width + 1):
        src.append(perm[:-d]); dst.append(perm[d:])
    g = coo_to_csr(np.concatenate(src), np.concatenate(dst), n)
    return make_undirected(g)


def test_reorder_concentrates_tiles():
    """Tile-fill regression (DESIGN.md §7): BFS/RCM reordering must populate
    no more tiles than the identity order, and strictly fewer on a
    shuffled banded graph, with higher per-tile fill."""
    g = _shuffled_band_graph()
    n = g.num_nodes
    feats = np.zeros((n, 4), np.float32)
    labels = np.zeros(n, np.int32)
    outs = [np.arange(n)]
    stats = {}
    for mode in ["none", "bfs"]:
        (b,) = build_batches(g, feats, labels, outs, outs, pad_multiple=64,
                             bcsr_block=64, reorder=mode)
        stats[mode] = b.bcsr_stats()
    assert stats["bfs"]["nonzero_tiles"] < stats["none"]["nonzero_tiles"], stats
    assert stats["bfs"]["tile_fill"] > stats["none"]["tile_fill"], stats


def test_reordered_batches_stay_equivalent(tiny_ds):
    """Reordering permutes local indices consistently: the segment backend
    gives identical output logits on reordered vs unordered batches."""
    cfgs = dict(variant="node", k_per_output=8, max_outputs_per_batch=64,
                pad_multiple=32)
    plain = IBMBPipeline(tiny_ds, IBMBConfig(**cfgs)).preprocess("train")
    tiled = IBMBPipeline(tiny_ds, IBMBConfig(**cfgs, backend="bcsr")).preprocess("train")
    cfg = GNNConfig(kind="gcn", in_dim=tiny_ds.feat_dim, hidden=32,
                    out_dim=tiny_ds.num_classes, num_layers=2)
    params = init_gnn(cfg, jax.random.PRNGKey(0))
    from repro.models.gnn.models import output_logits
    for bp, bt in zip(plain, tiled):
        # same outputs in the same order → same REAL logits rows (padded
        # output slots point at local node 0, which reordering relabels)
        dp, dt = bp.device_arrays(), bt.device_arrays()
        lp = np.asarray(output_logits(gnn_apply(cfg, params, dp), dp))
        lt = np.asarray(output_logits(gnn_apply(cfg, params, dt), dt))
        m = bp.output_mask
        assert np.array_equal(m, bt.output_mask)
        np.testing.assert_allclose(lp[m], lt[m], atol=1e-5)


def test_batch_node_order_modes():
    g = _shuffled_band_graph(n=64, width=2)
    src, dst = g.to_coo()
    for mode in ["none", "bfs", "degree"]:
        perm = batch_node_order(64, src, dst, mode=mode)
        assert sorted(perm.tolist()) == list(range(64))
    with pytest.raises(ValueError):
        batch_node_order(64, src, dst, mode="zigzag")


def test_asymmetric_adjacency_rejected():
    """bcsr emission refuses directed batch adjacencies — the backward pass
    would silently use Aᵀ ≠ A (DESIGN.md §7)."""
    from repro.graph.csr import coo_to_csr
    n = 40
    rng = np.random.default_rng(0)
    g = coo_to_csr(rng.integers(0, n, 200), rng.integers(0, n, 200), n)
    feats = np.zeros((n, 4), np.float32)
    labels = np.zeros(n, np.int32)
    with pytest.raises(ValueError, match="symmetric"):
        build_batches(g, feats, labels, [np.arange(n)], [np.arange(n)],
                      pad_multiple=32, bcsr_block=32, reorder="none")


# ------------------------------------------------------------- end-to-end
def test_bcsr_trains_end_to_end_matching_segment(tiny_ds):
    """Acceptance: GNNConfig(backend='bcsr') trains/evals through
    IBMBPipeline + GNNTrainer with loss/acc matching segment within 1e-4."""
    from repro.train import GNNTrainer
    pipe = IBMBPipeline(tiny_ds, IBMBConfig(
        variant="node", k_per_output=8, max_outputs_per_batch=64,
        pad_multiple=32, backend="bcsr"))
    tr = pipe.preprocess("train")
    va = pipe.preprocess("val", for_inference=True)
    hist = {}
    for be in ["segment", "bcsr"]:
        cfg = GNNConfig(kind="gcn", in_dim=tiny_ds.feat_dim, hidden=32,
                        out_dim=tiny_ds.num_classes, num_layers=2,
                        dropout=0.0, backend=be)
        res = GNNTrainer(cfg, lr=1e-3, seed=0).fit(
            tr, va, tiny_ds.num_classes, epochs=3, schedule_mode="none")
        hist[be] = res.history
    for hs, hb in zip(hist["segment"], hist["bcsr"]):
        assert abs(hs["train_loss"] - hb["train_loss"]) < 1e-4
        assert abs(hs["val_loss"] - hb["val_loss"]) < 1e-4
        assert abs(hs["val_acc"] - hb["val_acc"]) < 1e-4
