"""Sharding policy: divisibility fallback, logical arbitration, and an
end-to-end sharded lowering in a subprocess (tests keep 1 local device)."""
import json
import subprocess
import sys

import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

import jax
from repro.dist.sharding import fit_spec, param_spec, logical_rules_for
from repro.dist.logical import logical_rules, spec_for


class _FakeDim:
    pass


def _mesh_1dev(axes=("data", "model"), shape=(1, 1)):
    devs = np.array(jax.devices()[:1] * (shape[0] * shape[1])).reshape(shape)
    return Mesh(devs, axes)


def test_fit_spec_divisibility_fallback():
    mesh = _mesh_1dev()
    # axis size 1 divides everything → names kept
    assert fit_spec(mesh, (16, 32), ("data", "model")) == P("data", "model")


def test_fit_spec_left_pads_stacked_axes():
    mesh = _mesh_1dev()
    spec = fit_spec(mesh, (4, 16, 32), ("data", "model"))
    assert spec == P(None, "data", "model")


def test_logical_priority_arbitration():
    with logical_rules({"seq": "model", "heads": "model", "batch": "data"}):
        spec = spec_for(("batch", "seq", "heads", None))
        # heads (TP-primary) must win the "model" axis; seq yields
        assert spec == P("data", None, "model", None)


def test_param_spec_names():
    mesh = _mesh_1dev()
    leaf = jax.ShapeDtypeStruct((128, 256), jnp_dtype())
    assert param_spec(mesh, _path(("mixer", "wq")), leaf) == P("data", "model")
    leaf_o = jax.ShapeDtypeStruct((256, 128), jnp_dtype())
    assert param_spec(mesh, _path(("mixer", "wo")), leaf_o) == P("model", "data")
    norm = jax.ShapeDtypeStruct((128,), jnp_dtype())
    assert param_spec(mesh, _path(("norm1", "scale")), norm) == P(None)


def jnp_dtype():
    import jax.numpy as jnp
    return jnp.float32


def _path(keys):
    from jax.tree_util import DictKey
    return tuple(DictKey(k) for k in keys)


_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import jax, numpy as np, jax.numpy as jnp, json
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.configs import get_smoke_config
from repro.dist.sharding import param_spec, tree_shardings, with_shardings, logical_rules_for, batch_spec
from repro.dist.logical import logical_rules
from repro.models.lm import abstract_params, lm_loss

mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
cfg = get_smoke_config("llama3.2-1b")
pa = abstract_params(cfg)
pin = with_shardings(pa, tree_shardings(mesh, pa, param_spec))
B, S = 4, 64
batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32,
             sharding=NamedSharding(mesh, batch_spec(mesh, "tokens", (B, S)))),
         "loss_mask": jax.ShapeDtypeStruct((B, S), jnp.float32,
             sharding=NamedSharding(mesh, batch_spec(mesh, "loss_mask", (B, S))))}
with mesh, logical_rules(logical_rules_for(cfg, mesh)):
    compiled = jax.jit(lambda p, b: lm_loss(cfg, p, b)).lower(pin, batch).compile()
txt = compiled.as_text()
has_coll = any(op in txt for op in ("all-reduce", "all-gather", "reduce-scatter"))
print(json.dumps({"ok": True, "has_collectives": has_coll}))
"""


@pytest.mark.slow
def test_sharded_lowering_subprocess():
    out = subprocess.run([sys.executable, "-c", _SUBPROC], cwd=".",
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["ok"] and res["has_collectives"]
