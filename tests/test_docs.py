"""Docs drift: DESIGN.md section references in docstrings must resolve."""
import os
import sys

_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(_ROOT, "tools"))

from check_docs_refs import check, cited_sections  # noqa: E402


def test_design_md_sections_exist():
    assert check(_ROOT) == []


def test_known_citations_present():
    """The references this repo is built around must keep resolving."""
    refs = cited_sections(_ROOT)
    for section in ("3", "4", "5", "6", "Arch-applicability"):
        assert section in refs, f"expected a docstring citing DESIGN.md §{section}"


def test_readme_exists_with_tier1_command():
    with open(os.path.join(_ROOT, "README.md"), encoding="utf-8") as f:
        text = f.read()
    assert "PYTHONPATH=src python -m pytest -x -q" in text
