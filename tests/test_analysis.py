"""The invariant analyzer (DESIGN.md §15): every rule must both fire on
its known-bad fixture and stay silent on the known-good one; suppression
(allow comments + baseline) has exact semantics; the repo itself is
clean end to end; and the real violations the analyzer surfaced are
pinned by behavioral regression tests so they cannot quietly return."""
import inspect
import json
import time
from pathlib import Path

import numpy as np
import pytest

from conftest import FakeClock
from repro.analysis.atomic_write import AtomicWriteChecker
from repro.analysis.bench_gate import BenchGateChecker
from repro.analysis.cli import find_repo_root, main
from repro.analysis.determinism import DeterminismChecker
from repro.analysis.fault_points import FaultPointChecker
from repro.analysis.jit_cache import JitCacheChecker
from repro.analysis.locks import LockDisciplineChecker
from repro.analysis.model import (BASELINE_RELPATH, Finding, Module, Project,
                                  filter_allowed, filter_baselined,
                                  load_baseline)

FIXTURES = Path(__file__).parent / "fixtures" / "analysis"
REPO_ROOT = Path(find_repo_root())


def _snippet_project(relpath, fixture):
    """Map a fixture snippet onto a virtual path inside a checker's scope."""
    return Project.from_sources(
        {relpath: (FIXTURES / fixture).read_text()})


def _run(checker_cls, project):
    return checker_cls().run(project)


# ------------------------------------------------------------- determinism
def test_determinism_fires_on_known_bad():
    proj = _snippet_project("src/repro/core/x.py", "determinism_bad.py")
    found = _run(DeterminismChecker, proj)
    assert len(found) == 6
    assert all(f.rule == "determinism" for f in found)
    msgs = "\n".join(f.message for f in found)
    for needle in ("wall-clock", "unseeded", "global-state RNG",
                   "`id()` is salted"):
        assert needle in msgs
    assert sum("iterating a set" in f.message for f in found) == 2


def test_determinism_silent_on_known_good():
    proj = _snippet_project("src/repro/core/x.py", "determinism_good.py")
    kept, suppressed = filter_allowed(_run(DeterminismChecker, proj), proj)
    assert kept == []
    assert len(suppressed) == 2  # the two annotated timing-only reads


def test_determinism_scope_excludes_serving_tier():
    proj = _snippet_project("src/repro/serve/x.py", "determinism_bad.py")
    assert _run(DeterminismChecker, proj) == []


# ---------------------------------------------------------- lock discipline
def test_locks_fire_on_known_bad():
    proj = _snippet_project("src/repro/serve/x.py", "locks_bad.py")
    by_rule = {}
    for f in _run(LockDisciplineChecker, proj):
        by_rule.setdefault(f.rule, []).append(f)
    # the A->B and B->A edges each close the cycle
    assert len(by_rule["lock-order"]) == 2
    # open + json.dump under lock, future.result, time.sleep
    assert len(by_rule["lock-blocking"]) == 4
    assert len(by_rule["condvar-wait"]) == 1
    assert len(by_rule["clock-injectable"]) == 1


def test_locks_good_needs_only_the_justified_allow():
    proj = _snippet_project("src/repro/serve/x.py", "locks_good.py")
    kept, suppressed = filter_allowed(
        _run(LockDisciplineChecker, proj), proj)
    assert kept == []
    # engine.run under the per-tenant lock is by design and annotated;
    # SystemClock's own time.* lines are exempt by name, the predicate-
    # looped condvar wait and the consistent A->B order are simply clean
    assert [f.rule for f in suppressed] == ["lock-blocking"]


# ------------------------------------------------------------- atomic write
def test_atomic_write_fires_on_known_bad():
    proj = _snippet_project("src/repro/ooc/x.py", "atomic_bad.py")
    found = _run(AtomicWriteChecker, proj)
    assert len(found) == 2
    assert all("os.replace" in f.message for f in found)


def test_atomic_write_silent_on_known_good():
    proj = _snippet_project("src/repro/ooc/x.py", "atomic_good.py")
    assert _run(AtomicWriteChecker, proj) == []


def test_atomic_write_scope_excludes_serving_tier():
    proj = _snippet_project("src/repro/serve/x.py", "atomic_bad.py")
    assert _run(AtomicWriteChecker, proj) == []


# ----------------------------------------------------------------- jit-cache
def test_jit_cache_fires_on_known_bad():
    proj = _snippet_project("src/repro/serve/x.py", "jit_bad.py")
    found = _run(JitCacheChecker, proj)
    assert len(found) == 3
    msgs = "\n".join(f.message for f in found)
    assert "inside a loop" in msgs
    assert "per-request entry" in msgs


def test_jit_cache_silent_on_known_good():
    proj = _snippet_project("src/repro/serve/x.py", "jit_good.py")
    assert _run(JitCacheChecker, proj) == []


# ------------------------------------------------------ fault-point registry
def test_fault_registry_drift_fires():
    proj = Project.load(str(FIXTURES / "faultreg_bad"))
    found = _run(FaultPointChecker, proj)
    assert len(found) == 5
    joined = "\n".join(f.message for f in found)
    assert "unregistered fault point `unknown`" in joined
    assert "non-literal point name" in joined
    assert "`stale` has no injection site" in joined
    assert "`stale` missing from the" in joined
    assert "`ghost` which is" in joined


def test_fault_registry_in_sync_is_silent():
    proj = Project.load(str(FIXTURES / "faultreg_good"))
    assert _run(FaultPointChecker, proj) == []


# ----------------------------------------------------------------- bench gate
def test_bench_gate_drift_fires():
    proj = Project.load(str(FIXTURES / "benchgate_bad"))
    found = _run(BenchGateChecker, proj)
    assert len(found) == 2
    joined = "\n".join(f.message for f in found)
    assert "`x/missing`" in joined
    assert "`t/pre_`" in joined


def test_bench_gate_silent_when_rows_emitted():
    # exact literals plus an f-string prefix both count as emitters
    proj = Project.load(str(FIXTURES / "benchgate_good"))
    assert _run(BenchGateChecker, proj) == []


# ------------------------------------------------------ suppression semantics
_ALLOW_SRC = """\
import time

def a():
    t = time.time()  # lint: allow(determinism) — same line
    # lint: allow(determinism) — comment-only line above
    u = time.time()
    v = 0  # lint: allow(determinism) on a CODE line, not a comment
    w = time.time()
    x = time.time()
    return t, u, v, w, x
"""


def test_allow_comment_semantics():
    mod = Module("src/repro/core/x.py", _ALLOW_SRC)
    assert mod.allowed("determinism", 4)        # trailing, same line
    assert mod.allowed("determinism", 6)        # comment-only line above
    assert not mod.allowed("determinism", 8)    # previous line is code
    assert not mod.allowed("determinism", 9)    # no annotation at all
    assert not mod.allowed("lock-order", 4)     # rule name must match


def test_allow_comments_filter_end_to_end():
    proj = Project.from_sources({"src/repro/core/x.py": _ALLOW_SRC})
    kept, suppressed = filter_allowed(_run(DeterminismChecker, proj), proj)
    assert sorted(f.line for f in kept) == [8, 9]
    assert sorted(f.line for f in suppressed) == [4, 6]


def test_baseline_matching_semantics():
    f1 = Finding("determinism", "src/a.py", 10, "m")
    f2 = Finding("determinism", "src/b.py", 10, "m")
    f3 = Finding("lock-order", "src/a.py", 10, "m")
    baseline = [
        {"rule": "determinism", "path": "src/a.py"},            # any line
        {"rule": "lock-order", "path": "src/a.py", "line": 11},  # wrong line
    ]
    kept, matched = filter_baselined([f1, f2, f3], baseline)
    assert matched == [f1]
    assert kept == [f2, f3]


def test_shipped_baseline_is_empty():
    # acceptance: real violations were fixed, not baselined
    assert load_baseline(str(REPO_ROOT / BASELINE_RELPATH)) == []
    assert load_baseline(str(REPO_ROOT / "no-such-baseline.json")) == []


# ------------------------------------------------------------- CLI + smoke
def test_repo_is_clean_end_to_end(capsys):
    rc = main(["--format", "json"])
    report = json.loads(capsys.readouterr().out)
    assert rc == 0
    assert report["findings"] == []
    assert report["suppressed"]["baseline"] == []
    lock_rules = {"lock-order", "lock-blocking", "condvar-wait",
                  "clock-injectable"}
    allowed = report["suppressed"]["allow_comments"]
    # by-design lock suppressions stay within the reviewed budget; every
    # other allow is an annotated timing-only determinism read
    assert len([f for f in allowed if f["rule"] in lock_rules]) <= 3
    assert {f["rule"] for f in allowed} <= lock_rules | {"determinism"}


def test_cli_nonzero_exit_and_json_report_on_drift(capsys):
    rc = main(["--root", str(FIXTURES / "faultreg_bad"), "--format", "json"])
    report = json.loads(capsys.readouterr().out)
    assert rc == 1
    assert len(report["findings"]) == 5
    assert {f["rule"] for f in report["findings"]} == {"fault-point"}


def test_cli_only_and_path_filters(capsys):
    # --only selects checkers; a path argument narrows reported findings
    rc = main(["--root", str(FIXTURES / "faultreg_bad"),
               "--only", "bench-gate"])
    capsys.readouterr()
    assert rc == 0
    rc = main(["--root", str(FIXTURES / "faultreg_bad"), "tools"])
    capsys.readouterr()
    assert rc == 0  # all drift findings live under src/ and DESIGN.md


# ---------------------------------------------------------------- regressions
# Behavioral pins for the real violations the analyzer surfaced (ISSUE:
# fixed, not baselined).

def test_heartbeats_default_clock_survives_wallclock_jump(monkeypatch):
    from repro.train.elastic import Heartbeats

    hb = Heartbeats(timeout_s=60.0)
    hb.beat(0)
    # an NTP step / DST jump moves time.time by hours; the monotonic
    # SystemClock default must not declare every host dead (the old
    # `self._now = time.time` default did exactly that)
    monkeypatch.setattr(time, "time", lambda: time.monotonic() + 7200.0)
    assert hb.dead_hosts() == []


def test_heartbeats_timeout_under_fake_clock():
    from repro.train.elastic import Heartbeats

    clock = FakeClock()
    hb = Heartbeats(timeout_s=10.0, clock=clock)
    hb.beat(0)
    hb.beat(1)
    clock.advance(5.0)
    hb.beat(1)
    clock.advance(6.0)
    assert hb.dead_hosts() == [0]


def test_atomic_write_json_failed_serialize_keeps_original(tmp_path):
    from repro.ioutil import atomic_write_json

    path = tmp_path / "bench.json"
    atomic_write_json(str(path), {"ok": 1})
    with pytest.raises(TypeError):
        atomic_write_json(str(path), {"bad": object()})
    assert json.loads(path.read_text()) == {"ok": 1}
    assert list(tmp_path.iterdir()) == [path]  # no .tmp debris


def test_atomic_write_text_failed_publish_keeps_original(tmp_path,
                                                         monkeypatch):
    from repro import ioutil

    path = tmp_path / "artifact.txt"
    path.write_text("old")

    def boom(src, dst):
        raise OSError("device gone")

    monkeypatch.setattr(ioutil.os, "replace", boom)
    with pytest.raises(OSError):
        ioutil.atomic_write_text(str(path), "new")
    assert path.read_text() == "old"
    assert list(tmp_path.iterdir()) == [path]


def test_ppr_partition_is_a_pure_function_of_seed(tiny_ds):
    from repro.core.partition import ppr_distance_partition
    from repro.core.ppr import push_appr

    outputs = tiny_ds.splits["train"]
    ppr = push_appr(tiny_ds.graph, outputs, topk=32)
    a = ppr_distance_partition(ppr, outputs, 16, seed=7)
    b = ppr_distance_partition(ppr, outputs, 16, seed=7)
    assert len(a) == len(b)
    assert all(np.array_equal(x, y) for x, y in zip(a, b))


def test_gnn_engine_latency_flows_through_injected_clock(tiny_ds):
    import jax

    from repro.core import IBMBConfig, IBMBPipeline
    from repro.models.gnn import GNNConfig, init_gnn
    from repro.serve import GNNInferenceEngine, GNNRequest

    pipe = IBMBPipeline(tiny_ds, IBMBConfig(
        variant="node", k_per_output=8, max_outputs_per_batch=32,
        pad_multiple=16))
    plan = pipe.plan("test", for_inference=True)
    cfg = GNNConfig(kind="gcn", in_dim=tiny_ds.feat_dim, hidden=32,
                    out_dim=tiny_ds.num_classes, num_layers=2)
    params = init_gnn(cfg, jax.random.PRNGKey(0))

    clock = FakeClock(100.0)
    eng = GNNInferenceEngine(plan, cfg, params, clock=clock)
    req = GNNRequest(node_ids=plan.routing.node_ids[:4])
    stats = eng.run([req])
    # a frozen fake clock means the recorded latencies are exactly zero —
    # proof the engine never consults the wall clock directly
    assert req.done
    assert req.latency_s == 0.0
    assert stats["time_s"] == 0.0


def test_serve_engine_accepts_injected_clock():
    from repro.serve.engine import ServeEngine

    assert "clock" in inspect.signature(ServeEngine.__init__).parameters
