"""Serving engine: continuous batching over decode_step."""
import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models.lm import init_params
from repro.serve import ServeEngine
from repro.serve.engine import Request


def test_serve_engine_completes_requests():
    cfg = get_smoke_config("llama3.2-1b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, num_slots=2, max_len=128)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, 5).astype(np.int32),
                    max_new_tokens=4) for _ in range(4)]
    stats = eng.run(reqs)
    assert stats["completed"] == 4
    for r in reqs:
        assert len(r.out_tokens) == 4
        assert all(0 <= t < cfg.vocab_size for t in r.out_tokens)


def test_serve_engine_continuous_batching():
    """More requests than slots: slots must be reused."""
    cfg = get_smoke_config("qwen2-1.5b")
    params = init_params(cfg, jax.random.PRNGKey(1))
    eng = ServeEngine(cfg, params, num_slots=1, max_len=128)
    rng = np.random.default_rng(1)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, 3).astype(np.int32),
                    max_new_tokens=2) for _ in range(3)]
    stats = eng.run(reqs)
    assert stats["completed"] == 3


# ------------------------------------------------------- slot lifecycle
def _tiny_engine(num_slots=2, max_len=64):
    cfg = get_smoke_config("llama3.2-1b")
    params = init_params(cfg, jax.random.PRNGKey(2))
    return cfg, ServeEngine(cfg, params, num_slots=num_slots, max_len=max_len)


def _req(cfg, prompt_len=2, max_new_tokens=2, seed=0):
    rng = np.random.default_rng(seed)
    return Request(prompt=rng.integers(0, cfg.vocab_size,
                                       prompt_len).astype(np.int32),
                   max_new_tokens=max_new_tokens)


def test_slot_freed_on_completion_and_reused():
    """A slot returns to the free pool the step its request completes, and
    the next admission lands in that same slot."""
    cfg, eng = _tiny_engine(num_slots=1)
    r1 = _req(cfg, prompt_len=1, max_new_tokens=1, seed=0)
    assert eng.add_request(r1)
    assert eng.slots[0] is r1
    while not r1.done:
        eng.step()
    assert eng.slots[0] is None                  # freed on completion
    r2 = _req(cfg, seed=1)
    assert eng.add_request(r2)
    assert eng.slots[0] is r2                    # same slot, reused


def test_admission_rejected_while_all_slots_busy():
    """add_request returns False (no silent queueing, no eviction) while
    every slot holds an unfinished request."""
    cfg, eng = _tiny_engine(num_slots=2)
    a, b = _req(cfg, seed=0), _req(cfg, seed=1)
    assert eng.add_request(a) and eng.add_request(b)
    c = _req(cfg, seed=2)
    assert not eng.add_request(c)
    assert eng.slots == [a, b]                   # occupants untouched
    eng.step()                                   # one step: still busy
    assert not eng.add_request(c)
    while not (a.done and b.done):
        eng.step()
    assert eng.add_request(c)                    # space opened up


def test_max_len_exhaustion_leaves_requests_not_done():
    """When the shared position counter hits max_len, run() must stop and
    requests that could not finish stay marked not-done."""
    cfg, eng = _tiny_engine(num_slots=1, max_len=8)
    # prompt + generation budget far exceeds the 8-position window
    r = _req(cfg, prompt_len=4, max_new_tokens=100, seed=3)
    stats = eng.run([r])
    assert stats["completed"] == 0
    assert not r.done
    assert eng.pos >= eng.max_len - 1            # stopped by exhaustion
    assert len(r.out_tokens) < r.max_new_tokens


def test_submit_interleaved_slot_reuse_mid_stream():
    """Scripted interleaving: with both slots busy, the SHORT request
    finishes mid-stream and the next submit must land in its exact freed
    slot while the long request keeps decoding undisturbed."""
    cfg, eng = _tiny_engine(num_slots=2)
    long = _req(cfg, prompt_len=1, max_new_tokens=12, seed=0)
    short = _req(cfg, prompt_len=1, max_new_tokens=2, seed=1)
    assert eng.submit(long) and eng.submit(short)
    assert eng.slots == [long, short]
    late = _req(cfg, seed=2)
    assert not eng.submit(late)                  # busy-rejection: full
    assert late.out_tokens is None               # rejected req left unstarted
    while not short.done:
        eng.step()
    assert not long.done                         # mid-stream, still decoding
    assert eng.slots == [long, None]             # short's slot freed exactly
    assert eng.submit(late)
    assert eng.slots[1] is late                  # reused short's slot
    assert eng.slots[0] is long                  # long undisturbed
    while not (long.done and late.done):
        eng.step()
    assert len(long.out_tokens) == 12 and len(late.out_tokens) == 2


def test_exhaustion_releases_slots_no_leak():
    """The slot-state leak regression: a stream that dies of max_len
    exhaustion must RELEASE the slots of its unfinished requests — before
    the fix they stayed occupied forever and every later submit/run was
    wedged with all-busy rejection."""
    cfg, eng = _tiny_engine(num_slots=1, max_len=8)
    r = _req(cfg, prompt_len=4, max_new_tokens=100, seed=3)
    stats = eng.run([r])
    assert stats["completed"] == 0 and stats["evicted"] == 1
    assert eng.slots == [None]                   # released, not leaked
    nxt = _req(cfg, prompt_len=1, max_new_tokens=1, seed=4)
    assert eng.submit(nxt)                       # admission works again
    eng.pool.release(0)
    # reset_stream refuses while a slot is serving, then re-arms cleanly
    assert eng.submit(nxt)
    try:
        eng.reset_stream()
        raise AssertionError("reset_stream must refuse while occupied")
    except RuntimeError:
        pass
    eng.pool.release(0)
    eng.reset_stream()
    assert eng.pos == 0
    fresh = _req(cfg, prompt_len=1, max_new_tokens=2, seed=5)
    stats = eng.run([fresh])
    assert stats["completed"] == 1 and fresh.done
