"""Serving engine: continuous batching over decode_step."""
import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models.lm import init_params
from repro.serve import ServeEngine
from repro.serve.engine import Request


def test_serve_engine_completes_requests():
    cfg = get_smoke_config("llama3.2-1b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, num_slots=2, max_len=128)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, 5).astype(np.int32),
                    max_new_tokens=4) for _ in range(4)]
    stats = eng.run(reqs)
    assert stats["completed"] == 4
    for r in reqs:
        assert len(r.out_tokens) == 4
        assert all(0 <= t < cfg.vocab_size for t in r.out_tokens)


def test_serve_engine_continuous_batching():
    """More requests than slots: slots must be reused."""
    cfg = get_smoke_config("qwen2-1.5b")
    params = init_params(cfg, jax.random.PRNGKey(1))
    eng = ServeEngine(cfg, params, num_slots=1, max_len=128)
    rng = np.random.default_rng(1)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, 3).astype(np.int32),
                    max_new_tokens=2) for _ in range(3)]
    stats = eng.run(reqs)
    assert stats["completed"] == 3
