"""Per-arch smoke tests (deliverable f): one forward/train step on CPU with
the REDUCED config — shapes + no NaNs. Full configs are exercised only via
the dry-run (abstract, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.configs.shapes import SHAPES, shape_applies
from repro.models.lm import (
    init_params, lm_loss, lm_forward, init_cache, decode_step)
from repro.models.lm.model import head_logits
from repro.optim.optimizers import adam, apply_updates


def _batch(cfg, b=2, s=32, key=jax.random.PRNGKey(0)):
    if cfg.num_codebooks > 1:
        toks = jax.random.randint(key, (b, s, cfg.num_codebooks), 0,
                                  cfg.vocab_size)
    else:
        toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    out = {"tokens": toks, "loss_mask": jnp.ones((b, s), jnp.float32)}
    if cfg.vision_prefix_len:
        out["prefix_embeds"] = jax.random.normal(
            key, (b, cfg.vision_prefix_len, cfg.d_model), jnp.float32)
    return out


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    opt = adam()
    state = opt.init(params)

    @jax.jit
    def step(p, s_, b):
        loss, grads = jax.value_and_grad(lambda q: lm_loss(cfg, q, b))(p)
        u, s_ = opt.update(grads, s_, p, jnp.float32(1e-3))
        return apply_updates(p, u), s_, loss

    p1, state, l1 = step(params, state, batch)
    p2, state, l2 = step(p1, state, batch)
    assert np.isfinite(float(l1)) and np.isfinite(float(l2))
    assert float(l2) < float(l1), f"{arch}: loss must drop on repeated batch"
    # output embedding table shape preserved
    t = p2["embed"]["table"]
    exp = (cfg.num_codebooks, cfg.vocab_size, cfg.d_model) \
        if cfg.num_codebooks > 1 else (cfg.vocab_size, cfg.d_model)
    assert t.shape == exp


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_matches_forward(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(1))
    b, s = 2, 16
    key = jax.random.PRNGKey(2)
    if cfg.num_codebooks > 1:
        toks = jax.random.randint(key, (b, s, cfg.num_codebooks), 0, cfg.vocab_size)
        tok_at = lambda t: toks[:, t:t + 1, :]
    else:
        toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
        tok_at = lambda t: toks[:, t:t + 1]
    full = head_logits(cfg, params, lm_forward(cfg, params, toks,
                                               remat=False)[:, -1])
    cache = init_cache(cfg, b, 32)
    step = jax.jit(lambda p, c, t, pos: decode_step(cfg, p, c, t, pos))
    for t in range(s):
        logits, cache = step(params, cache, tok_at(t), jnp.int32(t))
    err = float(jnp.abs(logits[:, 0] - full).max())
    scale = float(jnp.abs(full).max()) + 1e-9
    assert err / scale < 2e-2, f"{arch}: decode diverges from forward ({err})"


def test_full_config_metadata():
    """Exact assigned configs: layer counts / dims / vocab (no allocation)."""
    expect = {
        "recurrentgemma-2b": (26, 2560, 256000),
        "musicgen-large": (48, 2048, 2048),
        "rwkv6-3b": (32, 2560, 65536),
        "deepseek-v2-lite-16b": (27, 2048, 102400),
        "deepseek-v3-671b": (61, 7168, 129280),
        "llama3.2-1b": (16, 2048, 128256),
        "command-r-plus-104b": (64, 12288, 256000),
        "granite-34b": (88, 6144, 49152),
        "qwen2-1.5b": (28, 1536, 151936),
        "internvl2-1b": (24, 896, 151655),
    }
    for arch, (layers, d, v) in expect.items():
        cfg = get_config(arch)
        assert cfg.num_layers == layers, arch
        assert cfg.d_model == d, arch
        assert cfg.vocab_size == v, arch


def test_param_counts_match_arch_names():
    """Abstract param counts are in the ballpark of the arch names."""
    approx = {"llama3.2-1b": (1.0e9, 1.9e9),
              "qwen2-1.5b": (1.2e9, 2.0e9),
              "deepseek-v2-lite-16b": (12e9, 20e9),
              "deepseek-v3-671b": (600e9, 750e9),
              "command-r-plus-104b": (90e9, 120e9),
              "granite-34b": (30e9, 40e9),
              "rwkv6-3b": (2.5e9, 4e9),
              "recurrentgemma-2b": (2.2e9, 3.6e9),
              "musicgen-large": (1.5e9, 2.6e9),
              "internvl2-1b": (0.35e9, 1.1e9)}
    for arch, (lo, hi) in approx.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B params out of range"


def test_long_context_eligibility():
    """long_500k runs ONLY for sub-quadratic archs (DESIGN §Arch-applicability)."""
    eligible = {a for a in ARCH_IDS
                if shape_applies(get_config(a), SHAPES["long_500k"])}
    assert eligible == {"recurrentgemma-2b", "rwkv6-3b"}
