"""Elastic batch partitioning + work stealing + heartbeats."""
import numpy as np

from repro.train.elastic import partition_batches, WorkQueue, Heartbeats


def test_partition_batches_cover_disjoint():
    ids = list(range(37))
    for hosts in (1, 2, 4, 8):
        leases = [partition_batches(ids, hosts, h) for h in range(hosts)]
        flat = sorted(b for l in leases for b in l)
        assert flat == ids


def test_partition_deterministic_under_elastic_change():
    ids = list(range(64))
    a = partition_batches(ids, 8, 3)
    b = partition_batches(ids, 8, 3)
    assert a == b
    # different host count: still a valid cover (elastic restart)
    leases4 = [partition_batches(ids, 4, h) for h in range(4)]
    assert sorted(x for l in leases4 for x in l) == ids


def test_work_stealing_drains_everything():
    q = WorkQueue(list(range(20)), num_hosts=4)
    # host 0 is fast, others slow: host 0 keeps asking
    seen = []
    while True:
        b = q.next_batch(0)
        if b is None:
            break
        seen.append(b)
    assert sorted(seen) == list(range(20))
    assert q.stolen > 0, "fast host must have stolen work"
    assert q.remaining() == 0


def test_heartbeats_detect_dead_host():
    hb = Heartbeats(timeout_s=0.05)
    hb.beat(0)
    hb.beat(1)
    import time
    time.sleep(0.08)
    hb.beat(1)
    assert hb.dead_hosts() == [0]
