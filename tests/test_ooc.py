"""Out-of-core plans (DESIGN.md §13): streamed-vs-resident bitwise
equality, lazy/shard-routed serving parity on segment and bcsr backends,
resident-budget eviction, crash/corruption detection at the store layer,
``batch_io`` fault semantics, and the O(metadata) ``Plan.open`` path."""
import json
import os

import jax
import numpy as np
import pytest

from repro.core import IBMBPipeline, IBMBConfig, Plan, PlanFormatError
from repro.data.loader import PrefetchLoader
from repro.dist.data_parallel import stack_batches
from repro.faults import FaultInjector, corrupt_file
from repro.models.gnn import GNNConfig, init_gnn
from repro.ooc import (LazyBatchCache, OOCConfig, PlanStore, PlanStoreWriter,
                       ShardRouter, build_shards, load_manifest, write_store)
from repro.serve import GNNInferenceEngine


def _pipe(ds, **kw):
    cfg = dict(variant="node", k_per_output=8, max_outputs_per_batch=64,
               pad_multiple=32)
    cfg.update(kw)
    return IBMBPipeline(ds, IBMBConfig(**cfg))


def _model(ds, backend):
    cfg = GNNConfig(kind="gcn", in_dim=ds.feat_dim, hidden=32,
                    out_dim=ds.num_classes, num_layers=2, backend=backend)
    return cfg, init_gnn(cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="module", params=["segment", "bcsr"])
def pair(request, tiny_ds, tmp_path_factory):
    """(backend, resident plan, streamed OOC plan, store dir) per backend —
    built once; the equality/serving tests all read from it."""
    backend = request.param
    d = str(tmp_path_factory.mktemp(f"ooc_{backend}") / "store")
    resident = _pipe(tiny_ds, backend=backend).plan("train")
    ooc = _pipe(tiny_ds, backend=backend).plan(
        "train", out_of_core=True, store_dir=d,
        ooc=OOCConfig(chunk_batches=2, resident_batches=4))
    return backend, resident, ooc, d


# ------------------------------------------------------- streamed == resident
def test_stream_requires_store_dir(tiny_ds):
    with pytest.raises(ValueError, match="store_dir"):
        _pipe(tiny_ds).plan("train", out_of_core=True)


def test_stream_equals_resident(pair):
    """The §13 acceptance bar: chunked streaming produces the SAME plan —
    fingerprint, schedule, routing, membership, per-batch payload — as the
    resident build, on both aggregation backends."""
    _, res, ooc, _ = pair
    assert ooc.fingerprint == res.fingerprint
    assert np.array_equal(ooc.schedule, res.schedule)
    assert np.array_equal(ooc.routing.node_ids, res.routing.node_ids)
    assert np.array_equal(ooc.routing.batch, res.routing.batch)
    assert np.array_equal(ooc.routing.row, res.routing.row)
    assert np.array_equal(ooc.node_ids, res.node_ids)
    assert len(ooc.cache) == len(res.cache)
    assert ooc.cache.meta == res.cache.meta
    assert set(ooc.cache.fields) == set(res.cache.fields)
    for k, v in res.cache.fields.items():       # mmap view == stacked block
        assert np.array_equal(np.asarray(ooc.cache.fields[k]), v), k
    for i in range(len(res.cache)):             # verified per-batch read
        got = ooc.cache[i]
        assert all(np.array_equal(got[k], v)
                   for k, v in res.cache[i].items())


def test_store_open_is_metadata_only(pair):
    """Opening a store must not read batch payload: only header + index."""
    _, _, _, d = pair
    store = PlanStore.open(d)
    assert store.stats.snapshot()["reads"] == 0
    assert store.payload_nbytes() > 0
    assert len(store) == store.num_batches


# ------------------------------------------------------------- lazy serving
def test_lazy_engine_logits_bitwise(pair, tiny_ds):
    """Engine over the mmap-backed lazy plan answers bitwise-identical
    logits to the resident engine (same jitted forward, same arrays)."""
    backend, res, ooc, _ = pair
    cfg, params = _model(tiny_ds, backend)
    q = np.random.default_rng(0).permutation(tiny_ds.splits["train"])
    want = GNNInferenceEngine(res, cfg, params).query(q)
    got = GNNInferenceEngine(ooc, cfg, params).query(q)
    assert got.dtype == want.dtype and np.array_equal(got, want)


def test_eviction_under_budget(pair):
    """The resident-batch budget binds: touching every batch with budget 2
    keeps at most 2 materialized and evicts LRU-first; re-touching a hot
    batch is a hit, not a re-read."""
    _, _, _, d = pair
    cache = PlanStore.open(d).as_plan(resident_batches=2).cache
    assert isinstance(cache, LazyBatchCache)
    for i in range(len(cache)):
        cache[i]
    snap = cache.snapshot()
    assert snap["resident"] <= 2
    assert snap["budget"] == 2
    assert snap["loads"] == len(cache)
    assert snap["evictions"] == len(cache) - 2
    assert snap["resident_bytes"] <= 2 * (cache.nbytes() // len(cache)) + 1
    last = len(cache) - 1
    cache[last]                                  # hot: still resident
    assert cache.snapshot()["hits"] == 1
    cache[0]                                     # cold: evicted, re-loads
    assert cache.snapshot()["loads"] == len(cache) + 1


def test_lazy_superstep_goes_through_verified_path(pair):
    """``stack_batches``/``PrefetchLoader`` over a lazy plan must stage
    super-steps through the LRU-budgeted verified read (the ``stack``
    hook), and yield the same stacked arrays as the resident fields."""
    _, res, ooc, _ = pair
    idx = np.arange(min(2, len(res.cache)))
    want = stack_batches(res.cache, idx)
    before = ooc.cache.snapshot()["loads"] + ooc.cache.snapshot()["hits"]
    got = stack_batches(ooc.cache, idx)
    assert ooc.cache.snapshot()["loads"] + ooc.cache.snapshot()["hits"] \
        >= before + len(idx)                     # went through the LRU
    assert set(got) == set(want)
    assert all(np.array_equal(got[k], want[k]) for k in want)
    lw = list(PrefetchLoader(ooc, group=int(len(idx))))
    assert np.array_equal(lw[0][0]["features"],
                          np.asarray(jax.device_get(lw[0][0]["features"])))


# ---------------------------------------------------------------- sharding
@pytest.fixture(scope="module")
def sharded(pair, tmp_path_factory):
    backend, res, _, _ = pair
    root = str(tmp_path_factory.mktemp(f"shards_{backend}"))
    os.rmdir(root)                               # build_shards mkdirs
    # fresh pipeline: sharding must not depend on prior pipeline state
    man = build_shards(_pipe(res_ds(res), backend=backend), "train", 3, root,
                       ooc=OOCConfig(chunk_batches=2))
    return backend, res, root, man


def res_ds(plan):
    from repro.graph.datasets import get_dataset
    return get_dataset(plan.meta["dataset"])


def test_shard_router_logits_bitwise(sharded, tiny_ds):
    """Queries spanning >= 2 shards return logits bitwise identical to the
    resident single-host engine, merged back in query order."""
    backend, res, root, man = sharded
    cfg, params = _model(tiny_ds, backend)
    router = ShardRouter.load(root, cfg, params)
    q = np.random.default_rng(1).permutation(tiny_ds.splits["train"])
    assert router.shards_hit(q) >= 2
    want = GNNInferenceEngine(res, cfg, params).query(q)
    got = router.query(q)
    assert got.dtype == want.dtype and np.array_equal(got, want)
    snap = router.snapshot()
    assert snap["loaded"] == [0, 1, 2] and snap["requests"] == 1


def test_shard_chain_commits_to_every_shard(sharded):
    backend, _, root, man = sharded
    assert len(man["shards"]) == man["num_shards"] == 3
    load_manifest(root)                          # chain verifies
    mpath = os.path.join(root, "manifest.json")
    doc = json.load(open(mpath))
    doc["shards"][1]["fingerprint"] = "0" * 16   # swapped shard plan
    json.dump(doc, open(mpath, "w"))
    with pytest.raises(PlanFormatError, match="chain"):
        load_manifest(root)
    json.dump(man, open(mpath, "w"))             # restore for other tests


def test_shard_partial_load_names_missing_shard(sharded, tiny_ds):
    """One-shard router: own ids answer, foreign ids raise a clear error
    naming the shard to route to — never a silent wrong answer."""
    backend, res, root, _ = sharded
    cfg, params = _model(tiny_ds, backend)
    router = ShardRouter.load(root, cfg, params, shards=[1])
    q = np.asarray(tiny_ds.splits["train"], np.int64)
    own = q[router.owner(q) == 1]
    want = GNNInferenceEngine(res, cfg, params).query(own)
    assert np.array_equal(router.query(own), want)
    with pytest.raises(KeyError, match="did not load"):
        router.query(q)
    with pytest.raises(KeyError, match="not covered by any shard"):
        router.owner(np.array([10 ** 9]))


# ------------------------------------------------- crash/corruption/faults
def test_store_refuses_uncommitted_build(tmp_path, pair):
    """A crash mid-stream leaves no header — the directory must not open."""
    _, res, _, _ = pair
    d = str(tmp_path / "halfbuilt")
    w = PlanStoreWriter(d)
    fields = res.cache.fields
    w.append({k: v[:1] for k, v in fields.items()},
             np.zeros((1, 3), np.int64))
    w.abort()                                    # no finalize == crash
    with pytest.raises(FileNotFoundError, match="no finalized PlanStore"):
        PlanStore.open(d)


def test_store_reopen_after_truncated_chunk(tmp_path, pair):
    """A field file cut short (torn copy, disk-full crash) is caught at
    open time by size — before any batch could read past EOF."""
    _, res, _, _ = pair
    d = str(tmp_path / "trunc")
    write_store(d, res, chunk_batches=2)
    fpath = os.path.join(d, "fields", "features.bin")
    with open(fpath, "r+b") as f:
        f.truncate(os.path.getsize(fpath) - 7)
    with pytest.raises(PlanFormatError, match="truncated"):
        PlanStore.open(d)


def test_batch_corruption_detected_per_batch(tmp_path, pair):
    """Flipped bytes inside one batch's slice fail THAT batch's checksum
    (PlanFormatError, no retry); every other batch still serves."""
    _, res, _, _ = pair
    d = str(tmp_path / "corrupt")
    store = write_store(d, res, chunk_batches=2)
    spec = next(s for s in store.specs if s.name == "features")
    corrupt_file(os.path.join(d, "fields", "features.bin"),
                 offset=spec.rowbytes + 3, nbytes=4)   # inside batch 1
    store = PlanStore.open(d)                    # sizes fine: opens
    store.read_batch(0)
    with pytest.raises(PlanFormatError, match="checksum mismatch"):
        store.read_batch(1)
    assert store.stats.snapshot()["crc_failures"] == 1
    for i in range(2, len(store)):
        store.read_batch(i)


def test_batch_io_fault_retries_then_succeeds(tmp_path, pair):
    """Scripted transient read fault on the first attempt: bounded retry
    absorbs it, the batch round-trips, and the retry is counted."""
    _, res, _, _ = pair
    d = str(tmp_path / "faulty")
    write_store(d, res, chunk_batches=2)
    store = PlanStore.open(d, faults=FaultInjector(
        seed=7, script={"batch_io": [0]}), io_retries=2)
    got = store.read_batch(0)
    assert all(np.array_equal(got[k], v) for k, v in res.cache[0].items())
    assert store.stats.snapshot()["io_retries"] == 1


def test_batch_io_fault_exhausts_retries(tmp_path, pair):
    """A persistent fault burns every retry and surfaces as OSError (the
    §12 contract: transient-vs-corrupt stay distinct exception types)."""
    _, res, _, _ = pair
    d = str(tmp_path / "dead")
    write_store(d, res, chunk_batches=2)
    store = PlanStore.open(d, faults=FaultInjector(
        seed=7, rates={"batch_io": 1.0}), io_retries=2)
    with pytest.raises(OSError):
        store.read_batch(0)
    assert store.stats.snapshot()["io_retries"] == 2


# --------------------------------------------------------- Plan.open (O(1))
def test_plan_open_is_header_only(tmp_path, pair):
    """``Plan.open`` answers fingerprint/version/split questions without
    materializing the payload; a wrong expectation is refused the same way
    ``load`` refuses it."""
    _, res, _, _ = pair
    path = str(tmp_path / "plan.npz")
    res.save(path)
    hdr = Plan.open(path)
    assert hdr.fingerprint == res.fingerprint
    assert hdr.num_batches == len(res.cache)
    assert hdr.meta["split"] == "train"
    assert hdr.checksums                         # integrity table present
    with pytest.raises(PlanFormatError, match="fingerprint mismatch"):
        Plan.open(path, expect_fingerprint="f" * 16)
    with pytest.raises(FileNotFoundError):
        Plan.open(str(tmp_path / "absent.npz"))
