"""Data-parallel Plan execution (DESIGN.md §9): super-step grouping,
ragged-tail padding, shard_map trainer parity vs the single-device loop,
engine mesh routing, and the per-(epoch, step) dropout-rng regression.

Pure-logic tests and 1-device-mesh tests run everywhere (a 1-device mesh
exercises the full shard_map machinery with world=1). The multi-device
parity tests need >= 2 emulated devices — the CI `multidevice` job provides
8 via ``XLA_FLAGS=--xla_force_host_platform_device_count=8``; under plain
tier-1 (1 device) they are covered by the @slow subprocess test instead.
"""
import json
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import IBMBPipeline, IBMBConfig, Plan
from repro.dist.data_parallel import (
    ShardedPlanExecutor, data_mesh, mesh_world, replicate, stack_batches,
    superstep_indices)
from repro.models.gnn import GNNConfig, init_gnn
from repro.serve import GNNInferenceEngine, GNNRequest
from repro.train import GNNTrainer
from repro.train.gnn_trainer import step_rng

NDEV = jax.device_count()
multidevice = pytest.mark.skipif(
    NDEV < 2, reason="needs >1 device (CI multidevice job emulates 8)")


def _pipe(ds, **kw):
    cfg = dict(variant="node", k_per_output=8, max_outputs_per_batch=16,
               pad_multiple=32)
    cfg.update(kw)
    return IBMBPipeline(ds, IBMBConfig(**cfg))


def _cfg(ds, **kw):
    kw.setdefault("dropout", 0.3)
    return GNNConfig(kind="gcn", in_dim=ds.feat_dim, hidden=32,
                     out_dim=ds.num_classes, num_layers=2, **kw)


# ------------------------------------------------------------ super-steps
def test_superstep_indices_exact_fit():
    steps = superstep_indices(np.array([3, 1, 2, 0]), 2)
    assert len(steps) == 2
    for idx, w in steps:
        assert len(idx) == len(w) == 2
        assert (w == 1.0).all()
    assert np.concatenate([s[0] for s in steps]).tolist() == [3, 1, 2, 0]


def test_superstep_indices_ragged_tail():
    """The tail repeats the LAST REAL batch (same shape bucket) with
    weight 0 — the psum mean must divide by the real count only."""
    (i0, w0), (i1, w1) = superstep_indices(np.array([5, 4, 3, 2, 1]), 4)
    assert i0.tolist() == [5, 4, 3, 2] and (w0 == 1.0).all()
    assert i1.tolist() == [1, 1, 1, 1]
    assert w1.tolist() == [1.0, 0.0, 0.0, 0.0]


def test_superstep_indices_world_one_is_identity():
    steps = superstep_indices(np.array([2, 0, 1]), 1)
    assert [int(s[0][0]) for s in steps] == [2, 0, 1]
    assert all(s[1].tolist() == [1.0] for s in steps)


def test_plan_supersteps_groups_schedule(tiny_ds):
    plan = _pipe(tiny_ds).plan("train")
    steps = plan.supersteps(4)
    assert len(steps) == -(-len(plan) // 4)
    flat = np.concatenate([s[0][s[1] > 0] for s in steps])
    assert np.array_equal(flat, plan.schedule)


def test_stack_batches_cache_fast_path(tiny_ds):
    plan = _pipe(tiny_ds).plan("train")
    idx = np.array([1, 0, 1])
    stacked = stack_batches(plan.cache, idx)
    assert set(stacked) == set(plan.cache.fields)
    for k, v in stacked.items():
        assert v.shape[0] == 3
        assert np.array_equal(v[0], plan.cache[1][k]), k
        assert np.array_equal(v[1], plan.cache[0][k]), k
    # raw-list path gives identical stacks
    listed = stack_batches([plan.cache[i] for i in range(len(plan))], idx)
    for k in stacked:
        assert np.array_equal(stacked[k], listed[k]), k


# ------------------------------------------------------- specs / plumbing
def test_mesh_world_requires_data_axis():
    from jax.sharding import Mesh
    mesh = Mesh(np.array(jax.devices()[:1]), ("model",))
    with pytest.raises(ValueError, match="data axis"):
        mesh_world(mesh)
    assert mesh_world(data_mesh(1)) == 1


def test_replicate_places_full_tree():
    mesh = data_mesh()
    tree = {"w": np.ones((4, 3), np.float32), "b": np.zeros(3, np.float32)}
    rep = replicate(tree, mesh)
    for leaf in jax.tree_util.tree_leaves(rep):
        assert leaf.sharding.is_fully_replicated
        assert leaf.sharding.mesh == mesh


def test_fit_mesh_rejects_resamplers_and_grad_accum(tiny_ds):
    pipe = _pipe(tiny_ds)
    tr, va = pipe.plan("train"), pipe.plan("val", for_inference=True)
    mesh = data_mesh(1)
    with pytest.raises(ValueError, match="grad_accum"):
        GNNTrainer(_cfg(tiny_ds), grad_accum=2).fit(
            tr, va, tiny_ds.num_classes, epochs=1, mesh=mesh)
    from repro.graph.sampling import make_batcher
    bt = make_batcher("neighbor_sampling", tiny_ds, num_batches=2)
    if not bt.fixed:
        with pytest.raises(ValueError, match="fixed batches"):
            GNNTrainer(_cfg(tiny_ds)).fit(
                bt, va, tiny_ds.num_classes, epochs=1, mesh=mesh)


# ------------------------------------------------------------ rng satellite
def test_step_rng_unique_over_epoch_step_grid():
    """Regression (PR 4 satellite): dropout keys must differ across BOTH
    epochs and steps for a fixed caller rng — the old per-epoch re-split
    replayed identical masks every epoch."""
    base = jax.random.PRNGKey(7)
    keys = {tuple(np.asarray(step_rng(base, ep, st)))
            for ep in range(5) for st in range(7)}
    assert len(keys) == 35
    # and distinct from the init-key domain (fold_in(base, 0))
    assert tuple(np.asarray(jax.random.fold_in(base, 0))) not in keys


def test_fit_fixed_rng_varies_dropout_per_epoch(tiny_ds, monkeypatch):
    """fit() with a fixed caller-passed rng derives a FRESH key per
    (epoch, step): record the keys it consumes and assert epoch 1 differs
    from epoch 0 at every step."""
    import repro.train.gnn_trainer as mod
    seen = []

    def spy(rng, epoch, step):
        k = step_rng(rng, epoch, step)
        seen.append((epoch, step, tuple(np.asarray(k))))
        return k

    monkeypatch.setattr(mod, "step_rng", spy)
    pipe = _pipe(tiny_ds)
    GNNTrainer(_cfg(tiny_ds), lr=1e-3).fit(
        pipe.plan("train"), pipe.plan("val", for_inference=True),
        tiny_ds.num_classes, epochs=2, schedule_mode="none",
        rng=jax.random.PRNGKey(123))
    by_epoch = {}
    for ep, st, k in seen:
        by_epoch.setdefault(ep, {})[st] = k
    assert set(by_epoch) == {0, 1}
    assert by_epoch[0].keys() == by_epoch[1].keys()
    for st in by_epoch[0]:
        assert by_epoch[0][st] != by_epoch[1][st], f"epoch-reused key @ {st}"


# ------------------------------------------------- parity: 1-device mesh
def test_mesh1_fit_matches_plain_fit(tiny_ds):
    """world=1 super-steps ARE per-batch SGD: the shard_map path must
    reproduce the plain jit loop exactly (same Plan, same seed, dropout
    active)."""
    pipe = _pipe(tiny_ds)
    tr, va = pipe.plan("train"), pipe.plan("val", for_inference=True)
    cfg = _cfg(tiny_ds)
    res_m = GNNTrainer(cfg, lr=1e-3, seed=0).fit(
        tr, va, tiny_ds.num_classes, epochs=3, mesh=data_mesh(1))
    res_p = GNNTrainer(cfg, lr=1e-3, seed=0).fit(
        tr, va, tiny_ds.num_classes, epochs=3)
    for hm, hp in zip(res_m.history, res_p.history):
        assert hm["train_loss"] == pytest.approx(hp["train_loss"], abs=1e-6)
        assert hm["val_loss"] == pytest.approx(hp["val_loss"], abs=1e-6)
        assert hm["val_acc"] == pytest.approx(hp["val_acc"], abs=1e-6)
    for a, b in zip(jax.tree_util.tree_leaves(res_m.params),
                    jax.tree_util.tree_leaves(res_p.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-6, rtol=1e-6)


# ------------------------------------------------- parity: multi-device
@multidevice
def test_mesh_grad_parity_single_superstep(tiny_ds):
    """One super-step's psum-mean gradients == the mean of the per-batch
    gradients computed serially (segment backend, fp32 tolerance)."""
    from repro.models.gnn.models import gnn_apply, masked_xent, output_logits
    pipe = _pipe(tiny_ds)
    plan = pipe.plan("train")
    world = min(8, NDEV)
    mesh = data_mesh(world)
    cfg = _cfg(tiny_ds, dropout=0.0)
    params = init_gnn(cfg, jax.random.PRNGKey(0))
    from repro.optim.optimizers import get_optimizer
    ex = ShardedPlanExecutor(mesh, cfg, get_optimizer("adam"))
    assert ex.sharded and ex.world == world

    def loss_fn(p, b):
        return masked_xent(output_logits(gnn_apply(cfg, p, b), b),
                           b["labels"], b["output_mask"])

    idx, w = ex.supersteps(plan.schedule)[0]
    nreal = int((w > 0).sum())
    want = None
    for i in idx[:nreal]:
        g = jax.grad(loss_fn)(params, plan.cache[int(i)])
        want = g if want is None else jax.tree_util.tree_map(jnp.add, want, g)
    want = jax.tree_util.tree_map(lambda x: x / nreal, want)

    # recover the psum-mean grads through one adam step: compare params
    # after the executor step vs after applying `want` manually. The
    # reference is computed FIRST: `replicate` may zero-copy-alias the
    # original buffers on CPU, and the donating executor step would
    # invalidate them.
    from repro.optim.optimizers import apply_updates
    upd, _ = ex.opt.update(want, ex.opt.init(params), params,
                           jnp.float32(1e-3))
    pw = jax.tree_util.tree_map(np.asarray, apply_updates(params, upd))

    opt_state = ex.replicate(ex.opt.init(params))
    pr = ex.replicate(params)
    batch, wd = ex.stage(plan.cache, idx, w)
    keys = jnp.stack([step_rng(jax.random.PRNGKey(0), 0, j)
                      for j in range(world)])
    p2, _, _ = ex.train_superstep(pr, opt_state, batch, wd,
                                  jnp.float32(1e-3), keys)
    for a, b in zip(jax.tree_util.tree_leaves(p2),
                    jax.tree_util.tree_leaves(pw)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-5)


@multidevice
def test_mesh_fit_matches_grad_accum_trainer(tiny_ds):
    """Acceptance: on N fake devices the executor-driven fit matches the
    single-device trainer with grad_accum=N — same Plan, same seed, same
    dropout keys — to fp32 tolerance, ragged tail included."""
    world = min(8, NDEV)
    pipe = _pipe(tiny_ds)
    tr, va = pipe.plan("train"), pipe.plan("val", for_inference=True)
    assert len(tr) % world != 0, "want a ragged tail for this test"
    cfg = _cfg(tiny_ds)                          # dropout ACTIVE
    res_m = GNNTrainer(cfg, lr=1e-3, seed=0).fit(
        tr, va, tiny_ds.num_classes, epochs=4, mesh=data_mesh(world))
    res_s = GNNTrainer(cfg, lr=1e-3, seed=0, grad_accum=world).fit(
        tr, va, tiny_ds.num_classes, epochs=4)
    assert len(res_m.history) == len(res_s.history)
    for hm, hs in zip(res_m.history, res_s.history):
        assert hm["train_loss"] == pytest.approx(hs["train_loss"], abs=1e-5)
        assert hm["val_loss"] == pytest.approx(hs["val_loss"], abs=1e-5)
        assert hm["val_acc"] == pytest.approx(hs["val_acc"], abs=1e-6)
    for a, b in zip(jax.tree_util.tree_leaves(res_m.params),
                    jax.tree_util.tree_leaves(res_s.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-5)


@pytest.mark.skipif(NDEV < 4, reason="needs >=4 devices for a 2x2 mesh")
def test_multi_data_axis_mesh_psum_all_axes(tiny_ds):
    """Regression: a ('pod', 'data') mesh must psum gradients over BOTH
    data axes — reducing over 'data' alone lets the 'pod' replicas silently
    diverge (check_rep=False hides it). Parity vs grad_accum=4 pins it."""
    from jax.sharding import Mesh
    mesh = Mesh(np.array(jax.devices()[:4]).reshape(2, 2), ("pod", "data"))
    assert mesh_world(mesh) == 4
    pipe = _pipe(tiny_ds)
    tr, va = pipe.plan("train"), pipe.plan("val", for_inference=True)
    cfg = _cfg(tiny_ds)
    res_m = GNNTrainer(cfg, lr=1e-3, seed=0).fit(
        tr, va, tiny_ds.num_classes, epochs=2, mesh=mesh)
    res_s = GNNTrainer(cfg, lr=1e-3, seed=0, grad_accum=4).fit(
        tr, va, tiny_ds.num_classes, epochs=2)
    for a, b in zip(jax.tree_util.tree_leaves(res_m.params),
                    jax.tree_util.tree_leaves(res_s.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-5)


@multidevice
def test_mesh_eval_matches_single_device(tiny_ds):
    pipe = _pipe(tiny_ds)
    plan = pipe.plan("val", for_inference=True)
    cfg = _cfg(tiny_ds, dropout=0.0)
    params = init_gnn(cfg, jax.random.PRNGKey(1))
    ex = ShardedPlanExecutor(data_mesh(min(8, NDEV)), cfg)
    got = ex.evaluate(ex.replicate(params), plan.cache)
    want = GNNTrainer(cfg).evaluate(params, plan)
    assert got["loss"] == pytest.approx(want["loss"], abs=1e-5)
    assert got["acc"] == pytest.approx(want["acc"], abs=1e-6)


@multidevice
def test_engine_mesh_routing_parity(tiny_ds):
    """Engine with a mesh returns the same logits as without, coalesces
    misses into ceil(misses/world) super-steps, and still serves repeat
    traffic from the LRU."""
    plan = _pipe(tiny_ds).plan("test", for_inference=True)
    cfg = _cfg(tiny_ds, dropout=0.0)
    params = init_gnn(cfg, jax.random.PRNGKey(0))
    world = min(8, NDEV)
    test = tiny_ds.splits["test"]
    e1 = GNNInferenceEngine(plan, cfg, params, cache_batches=len(plan))
    em = GNNInferenceEngine(plan, cfg, params, cache_batches=len(plan),
                            mesh=data_mesh(world))
    np.testing.assert_allclose(e1.query(test), em.query(test),
                               atol=1e-5, rtol=1e-5)
    assert em.stats["batch_runs"] == len(plan)
    assert em.stats["supersteps"] == -(-len(plan) // world)
    em.query(test)                               # repeat traffic
    assert em.stats["batch_runs"] == len(plan)
    assert em.stats["lru_hits"] > 0
    # run(): coalesced requests, mesh execution, per-request completion
    reqs = [GNNRequest(node_ids=test), GNNRequest(node_ids=test[:3])]
    em.run(reqs)
    assert all(r.done for r in reqs)
    np.testing.assert_array_equal(reqs[1].logits, reqs[0].logits[:3])


# --------------------------------------------- tier-1 subprocess coverage
_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import sys; sys.path.insert(0, "src")
import json
import jax, numpy as np
from repro.core import IBMBPipeline, IBMBConfig
from repro.graph.datasets import get_dataset
from repro.models.gnn import GNNConfig
from repro.train import GNNTrainer
from repro.dist.data_parallel import data_mesh

ds = get_dataset("tiny")
pipe = IBMBPipeline(ds, IBMBConfig(variant="node", k_per_output=8,
                                   max_outputs_per_batch=16, pad_multiple=32))
tr, va = pipe.plan("train"), pipe.plan("val", for_inference=True)
cfg = GNNConfig(kind="gcn", in_dim=ds.feat_dim, hidden=32,
                out_dim=ds.num_classes, num_layers=2, dropout=0.3)
rm = GNNTrainer(cfg, lr=1e-3, seed=0).fit(tr, va, ds.num_classes, epochs=3,
                                          mesh=data_mesh())
rs = GNNTrainer(cfg, lr=1e-3, seed=0, grad_accum=8).fit(tr, va,
                                                        ds.num_classes,
                                                        epochs=3)
dl = max(abs(a["val_loss"] - b["val_loss"])
         for a, b in zip(rm.history, rs.history))
dp = max(float(np.abs(np.asarray(a) - np.asarray(b)).max())
         for a, b in zip(jax.tree_util.tree_leaves(rm.params),
                         jax.tree_util.tree_leaves(rs.params)))
print(json.dumps({"devices": jax.device_count(), "ragged": len(tr) % 8 != 0,
                  "dloss": dl, "dparam": dp}))
"""


@pytest.mark.slow
def test_8dev_parity_subprocess():
    """Tier-1 stays single-device (conftest note), so the 8-fake-device
    acceptance parity runs in a subprocess — same check the CI multidevice
    job runs in-process."""
    out = subprocess.run([sys.executable, "-c", _SUBPROC], cwd=".",
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["devices"] == 8
    assert res["dloss"] < 1e-5, res
    assert res["dparam"] < 1e-5, res


# ------------------------------------------------------------ loader group
def test_loader_group_staging(tiny_ds):
    from repro.data.loader import PrefetchLoader
    plan = _pipe(tiny_ds).plan("train")
    world = 4
    loader = PrefetchLoader(plan.cache, np.asarray(plan.schedule),
                            group=world)
    steps = list(loader)
    assert len(steps) == len(loader) == -(-len(plan) // world)
    seen = []
    for batch, w in steps:
        assert all(v.shape[0] == world for v in batch.values())
        for idx_pos in range(world):
            if w[idx_pos] > 0:
                seen.append(1)
    assert len(seen) == len(plan)
    # padded tail weights are zero, real ones are one
    tail_w = steps[-1][1]
    assert tail_w[:len(plan) % world or world].tolist() == \
        [1.0] * (len(plan) % world or world)


# ----------------------------------- bcsr under shard_map (DESIGN.md §14)
def _bcsr_pins(**kw):
    """Plan-build pins that force every batch's auto decision to bcsr with
    block_f 0 — the auto-dispatched executable is then config-identical to
    the forced one, so parity below is bitwise."""
    return dict(backend="bcsr", autotune=True, auto_kappa=1e9,
                tune_block_fs=(), **kw)


def test_bcsr_executor_is_sharded():
    """Regression for the retired TODO(bcsr-shard_map): bcsr no longer
    drops off the shard_map super-step path onto a per-device jit loop."""
    cfg = GNNConfig(kind="gcn", in_dim=8, hidden=16, out_dim=4,
                    num_layers=2, backend="bcsr")
    ex = ShardedPlanExecutor(data_mesh(1), cfg)
    assert ex.sharded is True
    for be in ("segment", "dense", "auto"):
        assert ShardedPlanExecutor(data_mesh(1), cfg,
                                   backend=be).sharded is True


def test_mesh1_bcsr_fit_matches_plain_fit(tiny_ds):
    """bcsr through the shard_map path == bcsr through the plain jit loop
    (same Plan, same seed, dropout active) — the bit-identical acceptance
    for retiring the per-device fallback, on a 1-device mesh."""
    pipe = _pipe(tiny_ds, backend="bcsr")
    tr, va = pipe.plan("train"), pipe.plan("val", for_inference=True)
    cfg = _cfg(tiny_ds, backend="bcsr")
    res_m = GNNTrainer(cfg, lr=1e-3, seed=0).fit(
        tr, va, tiny_ds.num_classes, epochs=3, mesh=data_mesh(1))
    res_p = GNNTrainer(cfg, lr=1e-3, seed=0).fit(
        tr, va, tiny_ds.num_classes, epochs=3)
    for hm, hp in zip(res_m.history, res_p.history):
        assert hm["train_loss"] == hp["train_loss"]
        assert hm["val_loss"] == hp["val_loss"]
    for a, b in zip(jax.tree_util.tree_leaves(res_m.params),
                    jax.tree_util.tree_leaves(res_p.params)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_mesh1_eval_bcsr_matches_single_device(tiny_ds):
    pipe = _pipe(tiny_ds, backend="bcsr")
    plan = pipe.plan("val", for_inference=True)
    cfg = _cfg(tiny_ds, dropout=0.0, backend="bcsr")
    params = init_gnn(cfg, jax.random.PRNGKey(1))
    ex = ShardedPlanExecutor(data_mesh(1), cfg)
    got = ex.evaluate(ex.replicate(params), plan.cache)
    want = GNNTrainer(cfg).evaluate(params, plan)
    assert got["loss"] == pytest.approx(want["loss"], abs=1e-6)
    assert got["acc"] == pytest.approx(want["acc"], abs=1e-6)


@multidevice
def test_mesh_bcsr_fit_matches_grad_accum_trainer(tiny_ds):
    """Multi-device acceptance for §14: bcsr super-steps on N fake devices
    match the single-device grad_accum=N trainer to fp32 tolerance."""
    world = min(8, NDEV)
    pipe = _pipe(tiny_ds, backend="bcsr")
    tr, va = pipe.plan("train"), pipe.plan("val", for_inference=True)
    cfg = _cfg(tiny_ds, backend="bcsr")
    res_m = GNNTrainer(cfg, lr=1e-3, seed=0).fit(
        tr, va, tiny_ds.num_classes, epochs=3, mesh=data_mesh(world))
    res_s = GNNTrainer(cfg, lr=1e-3, seed=0, grad_accum=world).fit(
        tr, va, tiny_ds.num_classes, epochs=3)
    for hm, hs in zip(res_m.history, res_s.history):
        assert hm["train_loss"] == pytest.approx(hs["train_loss"], abs=1e-5)
        assert hm["val_loss"] == pytest.approx(hs["val_loss"], abs=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(res_m.params),
                    jax.tree_util.tree_leaves(res_s.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-5)


@multidevice
def test_mesh_auto_matches_forced_bcsr(tiny_ds):
    """Auto dispatch through multi-device super-steps: with decisions
    pinned all-bcsr at block_f 0, backend='auto' is bitwise the forced
    bcsr executor run."""
    world = min(8, NDEV)
    pipe = _pipe(tiny_ds, **_bcsr_pins())
    tr, va = pipe.plan("train"), pipe.plan("val", for_inference=True)
    assert tr.batch_backends() == ["bcsr"] * len(tr)
    cfg = _cfg(tiny_ds)
    res_a = GNNTrainer(cfg, backend="auto", lr=1e-3, seed=0).fit(
        tr, va, tiny_ds.num_classes, epochs=2, mesh=data_mesh(world))
    res_f = GNNTrainer(cfg, backend="bcsr", lr=1e-3, seed=0).fit(
        tr, va, tiny_ds.num_classes, epochs=2, mesh=data_mesh(world))
    for a, b in zip(jax.tree_util.tree_leaves(res_a.params),
                    jax.tree_util.tree_leaves(res_f.params)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
