"""Deterministic stand-in for `hypothesis` so property tests still collect
and run (with a fixed example set) in environments without it installed.

Usage in test modules:

    from _hypothesis_fallback import given, settings, st

When the real hypothesis is importable it is re-exported unchanged. The
fallback supports exactly what this suite uses — `st.integers(lo, hi)`,
`@given(...)` with positional or keyword strategies, and `@settings(...)`
(ignored) — by expanding each strategy into `_N_EXAMPLES` evenly spaced
values (endpoints included) and parametrizing over their cartesian product
via `pytest.mark.parametrize`.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
except ImportError:
    import inspect
    import itertools

    import numpy as np
    import pytest

    _N_EXAMPLES = 5

    class _IntStrategy:
        def __init__(self, lo, hi):
            self.lo, self.hi = int(lo), int(hi)

        def examples(self, n):
            vals = np.linspace(self.lo, self.hi, n).round().astype(int)
            out, seen = [], set()
            for v in vals:
                if int(v) not in seen:
                    seen.add(int(v))
                    out.append(int(v))
            return out

    class st:  # noqa: N801 — mimic `hypothesis.strategies as st`
        @staticmethod
        def integers(min_value, max_value):
            return _IntStrategy(min_value, max_value)

    def settings(**_kw):
        return lambda fn: fn

    def given(*arg_strategies, **kw_strategies):
        def deco(fn):
            if kw_strategies:
                names = list(kw_strategies)
                strategies = [kw_strategies[k] for k in names]
            else:
                # hypothesis fills the RIGHTMOST parameters with positional
                # strategies (fixtures occupy the left).
                params = list(inspect.signature(fn).parameters)
                names = params[-len(arg_strategies):]
                strategies = list(arg_strategies)
            cols = [s.examples(_N_EXAMPLES) for s in strategies]
            rows = list(itertools.product(*cols))
            if len(names) == 1:
                rows = [r[0] for r in rows]
            return pytest.mark.parametrize(",".join(names), rows)(fn)
        return deco
