"""Theorem 1 validation: PPR ranks auxiliary nodes consistently with the
EXACT influence score of a randomly-initialized GCN — the empirical bridge
between the paper's theory (Sec. 3) and its practical instantiation."""
import jax
import numpy as np

from repro.core.influence import exact_influence, expected_influence_rw
from repro.core.ppr import dense_ppr
from repro.graph.datasets import get_dataset
from repro.models.gnn.models import GNNConfig, init_gnn
from repro.models.gnn import ops as gops


def _full_graph_apply(cfg, params, ds):
    m = ds.norm_graph.to_scipy().tocoo()
    batch = {
        "edge_src": np.asarray(m.row, np.int32),
        "edge_dst": np.asarray(m.col, np.int32),
        "edge_weight": np.asarray(m.data, np.float32),
        "edge_mask": np.ones(m.nnz, np.float32),
    }

    def apply_fn(feats):
        h = feats
        for l, p in enumerate(params["layers"]):
            h = h @ p["w"]
            h = gops.weighted_agg(h, batch["edge_src"], batch["edge_dst"],
                                  batch["edge_weight"]) + p["b"]
            if l < cfg.num_layers - 1:
                h = jax.nn.relu(h)
        return h

    return apply_fn


def _spearman(a, b):
    ra = np.argsort(np.argsort(a))
    rb = np.argsort(np.argsort(b))
    ra = ra - ra.mean()
    rb = rb - rb.mean()
    return float((ra * rb).sum() / np.sqrt((ra ** 2).sum() * (rb ** 2).sum()))


def test_ppr_approximates_influence():
    ds = get_dataset("tiny")
    cfg = GNNConfig(kind="gcn", in_dim=ds.feat_dim, hidden=32,
                    out_dim=ds.num_classes, num_layers=3, dropout=0.0)
    params = init_gnn(cfg, jax.random.PRNGKey(0))
    apply_fn = _full_graph_apply(cfg, params, ds)
    ppr = dense_ppr(ds.graph, alpha=0.25)
    cors = []
    for u in [3, 50, 111]:
        inf = exact_influence(apply_fn, ds.features, u)
        # compare rankings on nodes with nonzero influence
        nz = inf > 0
        if nz.sum() < 5:
            continue
        cors.append(_spearman(inf[nz], ppr[u][nz]))
    assert np.mean(cors) > 0.5, f"PPR should rank like influence, got {cors}"


def test_expected_influence_matches_rw():
    """Sanity: L-step expected influence == row-normalized A^L (Xu et al.)."""
    ds = get_dataset("tiny")
    import scipy.sparse as sp
    a = ds.graph.to_scipy()
    deg = np.asarray(a.sum(1)).ravel()
    p = (sp.diags(1.0 / np.maximum(deg, 1)) @ a).toarray()
    walk = expected_influence_rw(p, num_layers=3)
    assert np.allclose(walk, np.linalg.matrix_power(p, 3), atol=1e-8)
    # restart variant rows sum to ≤ 1
    walk_r = expected_influence_rw(p, num_layers=10, alpha=0.2)
    assert (walk_r.sum(1) <= 1.0 + 1e-6).all()
