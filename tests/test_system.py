"""End-to-end behaviour: IBMB trains to high accuracy, fast, with the
properties the paper claims (fixed batches, scheduling helps, preprocessing
amortized, unbiased epochs)."""
import time

import numpy as np
import pytest

from repro.core import IBMBPipeline, IBMBConfig
from repro.graph.datasets import get_dataset
from repro.models.gnn import GNNConfig
from repro.train import GNNTrainer


@pytest.fixture(scope="module")
def tiny():
    return get_dataset("tiny")


def _train(ds, batches, val, epochs=40, schedule="tsp", grad_accum=1, seed=0):
    cfg = GNNConfig(kind="gcn", in_dim=ds.feat_dim, hidden=64,
                    out_dim=ds.num_classes, num_layers=3)
    tr = GNNTrainer(cfg, lr=1e-3, seed=seed, grad_accum=grad_accum,
                    early_stop_patience=100)
    return tr.fit(batches, val, ds.num_classes, epochs=epochs,
                  schedule_mode=schedule)


def test_ibmb_node_wise_trains(tiny):
    pipe = IBMBPipeline(tiny, IBMBConfig(
        variant="node", k_per_output=8, max_outputs_per_batch=64,
        pad_multiple=32))
    tr = pipe.preprocess("train")
    va = pipe.preprocess("val", for_inference=True)
    res = _train(tiny, tr, va)
    assert res.best_val_acc > 0.8, res.best_val_acc


def test_ibmb_batch_wise_trains(tiny):
    pipe = IBMBPipeline(tiny, IBMBConfig(
        variant="batch", num_batches=4, max_outputs_per_batch=64,
        pad_multiple=32))
    tr = pipe.preprocess("train")
    va = pipe.preprocess("val", for_inference=True)
    res = _train(tiny, tr, va)
    assert res.best_val_acc > 0.8, res.best_val_acc


def test_preprocessing_amortized(tiny):
    """PPR is cached across splits/models — the paper re-uses preprocessing."""
    pipe = IBMBPipeline(tiny, IBMBConfig(variant="node", k_per_output=8,
                                         max_outputs_per_batch=64,
                                         pad_multiple=32))
    t0 = time.time()
    pipe.preprocess("train")
    first = time.time() - t0
    t0 = time.time()
    pipe.preprocess("train")
    second = time.time() - t0
    assert second < first, "cached PPR must make re-preprocessing cheaper"


def test_batch_cache_contiguous(tiny):
    """IBMB batches are precomputed once and cached contiguously
    (the paper's consecutive-memory-access property)."""
    pipe = IBMBPipeline(tiny, IBMBConfig(variant="node", k_per_output=8,
                                         max_outputs_per_batch=64,
                                         pad_multiple=32))
    cache = pipe.build_cache(pipe.preprocess("train"))
    assert cache.nbytes() > 0
    for v in cache.fields.values():
        assert v.flags["C_CONTIGUOUS"]


def test_gradient_accumulation_insensitive(tiny):
    """Paper Fig. 8: gradient accumulation barely changes final accuracy."""
    pipe = IBMBPipeline(tiny, IBMBConfig(variant="node", k_per_output=8,
                                         max_outputs_per_batch=64,
                                         pad_multiple=32))
    tr = pipe.preprocess("train")
    va = pipe.preprocess("val", for_inference=True)
    res1 = _train(tiny, tr, va, epochs=30, grad_accum=1)
    res4 = _train(tiny, tr, va, epochs=30, grad_accum=len(tr))   # full epoch
    assert abs(res1.best_val_acc - res4.best_val_acc) < 0.15


def test_every_output_used_exactly_once(tiny):
    """Unbiased training: every training node appears as output exactly once
    per epoch (paper Sec. 4)."""
    pipe = IBMBPipeline(tiny, IBMBConfig(variant="node", k_per_output=8,
                                         max_outputs_per_batch=64,
                                         pad_multiple=32))
    batches = pipe.preprocess("train")
    outs = np.concatenate([
        b.node_ids[b.output_idx[b.output_mask]] for b in batches])
    train = tiny.splits["train"]
    assert sorted(outs.tolist()) == sorted(train.tolist())
