"""Batch scheduling (paper Sec. 4 / Fig. 7)."""
import numpy as np

from repro.core.scheduling import (
    label_distributions, pairwise_kl_distance, tsp_max_order,
    weighted_sampling_order, make_schedule)


def _dists(seed=0, n=8, c=5):
    rng = np.random.default_rng(seed)
    labs = [rng.integers(0, c, size=rng.integers(10, 50)) for _ in range(n)]
    p = label_distributions(labs, c)
    return labs, pairwise_kl_distance(p)


def test_kl_distance_properties():
    _, d = _dists()
    assert np.allclose(d, d.T)
    assert (d >= -1e-9).all()
    assert np.allclose(np.diag(d), 0.0)


def test_tsp_beats_random_order():
    _, d = _dists(n=10)
    rng = np.random.default_rng(0)
    rand_len = np.mean([
        d[o, np.roll(o, -1)].sum()
        for o in (rng.permutation(10) for _ in range(50))])
    tsp = tsp_max_order(d, iters=5000)
    tsp_len = d[tsp, np.roll(tsp, -1)].sum()
    assert tsp_len >= rand_len          # maximizing tour must beat average
    assert sorted(tsp.tolist()) == list(range(10))


def test_weighted_order_is_permutation_per_epoch():
    _, d = _dists(n=7)
    order = weighted_sampling_order(d, num_epochs=3)
    for e in range(3):
        epoch = order[e * 7:(e + 1) * 7]
        assert sorted(epoch.tolist()) == list(range(7))


def test_make_schedule_modes():
    labs, _ = _dists(n=6)
    for mode in ("tsp", "weighted", "none"):
        s = make_schedule(labs, 5, mode=mode, num_epochs=2)
        assert len(s) == 12
