"""Known-good corpus for the jit-cache rule: jit once at setup (module
decorator or __init__) and reuse the compiled callable per request."""
from functools import partial

import jax


@jax.jit
def step(x):
    return x * 2


@partial(jax.jit, static_argnums=0)
def sized_step(n, x):
    return x[:n]


class Engine:
    def __init__(self, fn):
        self._step = jax.jit(fn)            # compiled once at construction

    def run(self, batch):
        return self._step(batch)            # reuse per request


def drive(batches):
    return [step(b) for b in batches]
