"""Known-bad corpus for the lock-discipline rules: order inversion,
blocking under a held lock, bare condvar wait, raw clock use."""
import json
import threading
import time


class Inverted:
    def __init__(self):
        self._a_lock = threading.Lock()
        self._b_lock = threading.Lock()

    def forward(self):
        with self._a_lock:
            with self._b_lock:              # A -> B ...
                pass

    def backward(self):
        with self._b_lock:
            with self._a_lock:              # ... then B -> A: inversion
                pass


class BlockingUnderLock:
    def __init__(self):
        self._lock = threading.Lock()
        self._cond = threading.Condition()

    def persist(self, path, payload):
        with self._lock:
            with open(path, "w") as f:      # file I/O under the lock
                json.dump(payload, f)

    def collect(self, future):
        with self._lock:
            return future.result()          # unbounded wait under lock

    def nap(self):
        with self._lock:
            time.sleep(0.1)                 # raw sleep under lock

    def bare_wait(self):
        with self._cond:
            if True:
                self._cond.wait()           # not a while-predicate loop
