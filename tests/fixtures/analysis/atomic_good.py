"""Known-good corpus for the atomic-write rule: the sanctioned shapes —
tmp + os.replace in the same function, an atomic_* helper, the
writer-class finalize pattern, and read-only opens."""
import json
import os

from repro.ioutil import atomic_write_text


def atomic_save_manifest(path, manifest):
    tmp = path + ".tmp"
    with open(tmp, "w") as f:               # writes the tmp, then publishes
        json.dump(manifest, f)
    os.replace(tmp, path)


def save_via_helper(path, manifest):
    atomic_write_text(path, json.dumps(manifest))


def load_manifest(path):
    with open(path) as f:                   # read-only: not a write at all
        return json.load(f)


class StreamingWriter:
    """Writer-class publish pattern: appends go to a tmp member, a single
    finalize() republishes — the class-level os.replace sanctions the
    open("w") in __init__."""

    def __init__(self, path):
        self._final = path
        self._tmp = path + ".tmp"
        self._f = open(self._tmp, "w")

    def append(self, line):
        self._f.write(line + "\n")

    def finalize(self):
        self._f.close()
        os.replace(self._tmp, self._final)
