"""Fixture call sites: every registered point fired, all literals."""
from repro.faults import FaultInjector  # fixture-only import


class Engine:
    def __init__(self, faults):
        self.faults = faults

    def run(self, batch):
        self.faults.fire("forward", batch)
        return batch


def make_injector():
    return FaultInjector(rates={"batch_io": 0.01}, seed=0)
