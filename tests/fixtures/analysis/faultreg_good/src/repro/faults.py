"""Fixture registry in sync with its call sites and design table."""

FAULT_POINTS = {
    "forward": "fixture forward fault",
    "batch_io": "fixture batch read fault",
}
