"""Known-good corpus for the lock-discipline rules: consistent order,
predicate-looped condvar wait, clock injection, SystemClock exemption,
and a justified by-design allow."""
import threading
import time


class SystemClock:
    """The one sanctioned home of the real clock (exempt by name)."""

    def now(self):
        return time.monotonic()

    def sleep(self, seconds):
        time.sleep(seconds)


class Disciplined:
    def __init__(self, clock):
        self._a_lock = threading.Lock()
        self._b_lock = threading.Lock()
        self._cond = threading.Condition()
        self._clock = clock
        self._items = []
        self._closed = False

    def nested_consistently(self):
        with self._a_lock:
            with self._b_lock:              # always A -> B: acyclic
                pass

    def other_site_same_order(self):
        with self._a_lock:
            with self._b_lock:
                pass

    def take(self):
        with self._cond:
            while not self._items and not self._closed:
                self._cond.wait(0.1)        # predicate-looped wait
            return self._items.pop() if self._items else None

    def compute_outside(self, path, engine, reqs):
        with self._a_lock:
            snapshot = list(self._items)    # only cheap work under lock
        # by design: the engine call is the unit of work this lock
        # serializes in the real tier — justified suppression
        with self._b_lock:
            engine.run(reqs)   # lint: allow(lock-blocking)
        return snapshot

    def timed(self):
        return self._clock.now()            # injectable clock, not time.*
