"""Known-bad corpus for the jit-cache rule: jax.jit invoked per loop
iteration or inside a per-request entry point — each call re-traces and
the compile cache churns."""
from functools import partial

import jax


def retrace_per_batch(fn, batches):
    outs = []
    for b in batches:
        outs.append(jax.jit(fn)(b))         # fresh jit object every batch
    return outs


def retrace_partial(fn, batches):
    outs = []
    for b in batches:
        step = partial(jax.jit, static_argnums=0)(fn)
        outs.append(step(b))
    return outs


class Engine:
    def run(self, fn, batch):
        return jax.jit(fn)(batch)           # per-request entry point
