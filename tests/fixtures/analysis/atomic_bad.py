"""Known-bad corpus for the atomic-write rule: direct writes to final
paths with no tmp + os.replace publish step."""
import json

import numpy as np


def save_manifest(path, manifest):
    with open(path, "w") as f:              # torn on crash mid-write
        json.dump(manifest, f)


def save_arrays(path, arrays):
    f = open(path, "wb")                    # non-with form, same hazard
    np.savez(f, **arrays)
    f.close()
