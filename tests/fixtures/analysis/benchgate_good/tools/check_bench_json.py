"""Fixture gate whose required rows all have emitters."""

REQUIRED_ROWS = {
    "m": ("x/exists", "x/missing"),
}

REQUIRED_PREFIXES = {
    "t": ("t/pre_",),
}
