"""Fixture bench module: emits every required row, one via f-string."""


def run(record, sizes):
    record("x/exists", 1.0)
    record("x/missing", 2.0)
    for n in sizes:
        record(f"t/pre_{n}", 3.0)
