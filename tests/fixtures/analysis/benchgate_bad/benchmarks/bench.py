"""Fixture bench module: emits only one of the gate's required rows."""


def run(record):
    record("x/exists", 1.0)
