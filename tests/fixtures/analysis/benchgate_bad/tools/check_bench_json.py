"""Fixture gate whose required rows drifted from the bench emitters."""

REQUIRED_ROWS = {
    "m": ("x/exists", "x/missing"),
}

REQUIRED_PREFIXES = {
    "t": ("t/pre_",),
}
