"""Known-good corpus for the determinism rule: the sanctioned idioms —
seeded generators, sorted set iteration, annotated timing-only reads."""
import time

import numpy as np


def seeded_partition(outputs, seed):
    rng = np.random.default_rng(seed)       # seeded: pure function of seed
    return rng.permutation(outputs)


def order_from_sorted_set(members):
    return np.asarray(sorted(set(members)))


def timed_build(build):
    # lint: allow(determinism) — timing telemetry only, never persisted
    t0 = time.time()
    out = build()
    out_time = time.time() - t0  # lint: allow(determinism) telemetry only
    return out, out_time


def key_by_content(batches):
    return {b.fingerprint: b for b in batches}
