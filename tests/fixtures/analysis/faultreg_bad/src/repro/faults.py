"""Fixture registry with a dead entry ("stale" has no call site)."""

FAULT_POINTS = {
    "forward": "fixture forward fault",
    "stale": "registered but never fired anywhere",
}
