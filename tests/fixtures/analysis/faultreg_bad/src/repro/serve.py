"""Fixture call sites: one registered, one unregistered, one computed."""


class Engine:
    def __init__(self, faults):
        self.faults = faults

    def run(self, batch, point):
        self.faults.fire("forward", batch)      # registered: fine
        self.faults.fire("unknown", batch)      # not in FAULT_POINTS
        self.faults.should_fire(point)          # non-literal point name
        return batch
