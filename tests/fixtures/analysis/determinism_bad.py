"""Known-bad corpus for the determinism rule (DESIGN.md §15): every
construct here must be flagged when mapped into a fingerprinted build
path. NOT importable production code — parsed by tests only."""
import time

import numpy as np


def stamp_build(meta):
    meta["built_at"] = time.time()          # wall clock into an artifact
    return meta


def unseeded_partition(outputs):
    rng = np.random.default_rng()           # unseeded generator
    return rng.permutation(outputs)


def global_rng_partition(outputs):
    np.random.shuffle(outputs)              # process-global RNG state
    return outputs


def key_by_identity(batches):
    return {id(b): b for b in batches}      # per-process salted ids


def order_from_set(members):
    out = []
    for m in set(members):                  # hash-salted iteration order
        out.append(m)
    return np.asarray(out)


def comp_from_set(members):
    return np.asarray([m for m in {1, 2, 3}])
