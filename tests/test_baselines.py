"""Baseline batchers produce valid PaddedBatches with correct outputs."""
import numpy as np
import pytest

from repro.graph.sampling import make_batcher


@pytest.mark.parametrize("name,kw", [
    ("neighbor_sampling", {"num_batches": 4}),
    ("ladies", {"num_batches": 4}),
    ("graphsaint_rw", {"num_steps": 4, "batch_roots": 100}),
    ("cluster_gcn", {"num_batches": 4}),
    ("shadow_ppr", {"outputs_per_batch": 100}),
    ("full_batch", {}),
])
def test_batcher_valid(tiny_ds, name, kw):
    bt = make_batcher(name, tiny_ds, **kw)
    batches = bt.epoch_batches(0)
    assert len(batches) >= 1
    total_outputs = 0
    for b in batches:
        total_outputs += b.num_real_outputs
        # output labels match the dataset
        outs_local = b.output_idx[b.output_mask]
        node_ids = b.node_ids
        gids = node_ids[outs_local]
        assert (b.labels[b.output_mask] == tiny_ds.labels[gids]).all()
        # edges reference valid in-batch nodes
        real_src = b.edge_src[b.edge_mask]
        assert (node_ids[real_src] >= 0).all()
    if name in ("cluster_gcn", "full_batch"):
        # global methods cover every training node exactly once
        assert total_outputs == len(tiny_ds.splits["train"])
    if name == "graphsaint_rw":
        return  # RW coverage is stochastic by design
    assert total_outputs >= len(tiny_ds.splits["train"]) * 0.9


def test_resampling_changes_batches(tiny_ds):
    bt = make_batcher("neighbor_sampling", tiny_ds, num_batches=4)
    b0 = bt.epoch_batches(0)[0]
    b1 = bt.epoch_batches(1)[0]
    assert not np.array_equal(b0.node_ids, b1.node_ids), \
        "resampling baselines must resample per epoch (their cost!)"


def test_fixed_batchers_are_fixed(tiny_ds):
    bt = make_batcher("cluster_gcn", tiny_ds, num_batches=4)
    b0 = bt.epoch_batches(0)[0]
    b1 = bt.epoch_batches(7)[0]
    assert np.array_equal(b0.node_ids, b1.node_ids)
