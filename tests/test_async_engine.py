"""Async micro-batching serving tier (DESIGN.md §11): window policy,
deadline admission, backpressure, multi-tenant swap, fault isolation, and
clean shutdown — all deterministic: scripted arrival traces against a fake
clock (no wall-clock sleeps; the threaded cases block on Event-backed
futures, never on time)."""
import threading

import jax
import numpy as np
import pytest

from _hypothesis_fallback import given, settings, st
from conftest import FakeClock
from repro.core import IBMBPipeline, IBMBConfig
from repro.core.update import GraphDelta
from repro.models.gnn import GNNConfig, init_gnn
from repro.serve import (
    AsyncGNNEngine, AsyncServeConfig, GNNInferenceEngine, ServeClosed,
    ServeError, ServeExpired, ServeRejected)


def _pipe(ds, **kw):
    cfg = dict(variant="node", k_per_output=8, max_outputs_per_batch=32,
               pad_multiple=16)
    cfg.update(kw)
    return IBMBPipeline(ds, IBMBConfig(**cfg))


@pytest.fixture(scope="module")
def served(tiny_ds):
    """(pipe, plan, model cfg, params) on a multi-batch tiny plan."""
    pipe = _pipe(tiny_ds)
    plan = pipe.plan("test", for_inference=True)
    assert len(plan) >= 2, "window tests need a multi-batch plan"
    cfg = GNNConfig(kind="gcn", in_dim=tiny_ds.feat_dim, hidden=32,
                    out_dim=tiny_ds.num_classes, num_layers=2)
    params = init_gnn(cfg, jax.random.PRNGKey(0))
    return pipe, plan, cfg, params


def _engine(served, cache_batches=4, plan=None):
    _pipe_, default_plan, cfg, params = served
    return GNNInferenceEngine(plan if plan is not None else default_plan,
                              cfg, params, cache_batches=cache_batches)


@pytest.fixture
def fresh_chain(tiny_ds, served):
    """A PRIVATE pipeline + plan for tests that `refresh` — refresh advances
    the pipeline's graph state, so swap tests must never mutate the shared
    module-scoped `served` chain."""
    pipe = _pipe(tiny_ds)
    return pipe, pipe.plan("test", for_inference=True)


def _tier(served, clock, tenants=("m",), cache_batches=4, plan=None,
          **cfg_kw):
    cfg_kw.setdefault("window_us", 1000.0)
    return AsyncGNNEngine(
        {name: _engine(served, cache_batches, plan=plan) for name in tenants},
        AsyncServeConfig(**cfg_kw), clock=clock, start=False)


def _batch_nodes(plan, bi):
    return plan.routing.node_ids[np.asarray(plan.routing.batch) == bi]


# ------------------------------------------------------------ window policy
def test_window_fires_on_full_batch_count(served, fake_clock):
    """A full batch's worth of routed rows dispatches IMMEDIATELY — no
    clock advance — because waiting cannot coalesce more work into that
    batch's forward (the plan's batch_occupancy hint)."""
    _, plan, _, _ = served
    tier = _tier(served, fake_clock, window_us=1e9)
    nodes = _batch_nodes(plan, 0)
    occ = plan.batch_occupancy()
    assert len(nodes) == occ[0]
    chunks = np.array_split(nodes, 4)
    futs = [tier.submit("m", c) for c in chunks[:-1]]
    assert tier.step() == 0                      # partial window: hold
    assert not any(f.done() for f in futs)
    futs.append(tier.submit("m", chunks[-1]))    # completes batch 0's rows
    assert tier.step() == len(futs)              # fired on count, t=0
    assert all(f.done() and f.result().shape[0] == len(c)
               for f, c in zip(futs, chunks))
    assert tier.stats.windows == 1
    assert tier.snapshot()["window_occupancy"] == 1.0
    tier.close()


def test_window_fires_on_timeout(served, fake_clock, arrival_trace):
    """A lone request that can never fill a batch still dispatches once the
    window elapses — scripted trace, fake clock, no sleeps."""
    _, plan, _, _ = served
    tier = _tier(served, fake_clock, window_us=1000.0)
    (fut,) = arrival_trace(tier, fake_clock,
                           [(0.0, "m", plan.routing.node_ids[:2])])
    assert not fut.done()                        # 0 µs elapsed
    fake_clock.advance(999e-6)
    assert tier.step() == 0                      # 999 µs: still inside
    fake_clock.advance(1e-6)
    assert tier.step() == 1                      # 1000 µs: expired
    assert fut.done() and fut.latency_s == pytest.approx(1000e-6)
    tier.close()


def test_coalescing_window_shares_one_forward(served, fake_clock):
    """The tier's reason to exist: N requests for one batch inside one
    window cost ONE batch forward; request-at-a-time costs N."""
    _, plan, _, _ = served
    nodes = _batch_nodes(plan, 0)
    reqs = [nodes[i:i + 2] for i in range(0, 10, 2)]

    coalesced = _tier(served, FakeClock(), cache_batches=0, window_us=1e9)
    for q in reqs:
        coalesced.submit("m", q)
    coalesced.flush()
    assert coalesced.tenant_engine("m").stats["batch_runs"] == 1
    assert coalesced.stats.completed == len(reqs)
    coalesced.close()

    one_at_a_time = _tier(served, FakeClock(), cache_batches=0,
                          window_us=0.0, max_requests_per_window=1,
                          occupancy_dispatch=False)
    for q in reqs:
        one_at_a_time.submit("m", q)
    one_at_a_time.flush()
    assert one_at_a_time.tenant_engine("m").stats["batch_runs"] == len(reqs)
    one_at_a_time.close()


# ------------------------------------------------------- admission control
def test_deadline_rejection_on_arrival(served, fake_clock):
    """Infeasible deadlines are refused at submit (drain estimate), before
    any queueing — the estimate is deterministic from the config seed."""
    _, plan, _, _ = served
    tier = _tier(served, fake_clock, window_us=0.0,
                 service_time_init_us=10_000.0)
    q = plan.routing.node_ids[:2]
    rej = tier.submit("m", q, deadline_ms=5.0)   # estimate: 10ms > 5ms
    assert rej.done() and rej.rejected
    with pytest.raises(ServeRejected, match="infeasible"):
        rej.result()
    ok = tier.submit("m", q, deadline_ms=50.0)
    assert not ok.done() and not ok.rejected
    assert tier.stats.rejected_deadline == 1
    assert tier.stats.accepted == 1
    tier.flush()
    assert ok.result().shape == (2, tier.tenant_engine("m").cfg.out_dim)
    tier.close()


def test_deadline_expires_while_queued(served, fake_clock):
    """An admitted request whose deadline passes in the queue expires at
    dispatch time — it never wastes a forward and its future raises."""
    _, plan, _, _ = served
    tier = _tier(served, fake_clock, window_us=1000.0,
                 service_time_init_us=100.0)
    fut = tier.submit("m", plan.routing.node_ids[:2], deadline_ms=5.0)
    assert not fut.done()                        # feasible → admitted
    runs_before = tier.tenant_engine("m").stats["batch_runs"]
    fake_clock.advance(0.010)                    # 10ms in queue > 5ms budget
    assert tier.step() == 1
    with pytest.raises(ServeExpired):
        fut.result()
    assert tier.stats.expired == 1
    assert tier.tenant_engine("m").stats["batch_runs"] == runs_before
    tier.close()


def test_queue_full_backpressure(served, fake_clock):
    """Beyond max_queue in-flight requests, submit rejects on arrival; a
    drained queue admits again."""
    _, plan, _, _ = served
    tier = _tier(served, fake_clock, window_us=1e9, max_queue=2)
    q = plan.routing.node_ids[:1]
    a, b = tier.submit("m", q), tier.submit("m", q)
    c = tier.submit("m", q)
    assert c.rejected
    with pytest.raises(ServeRejected, match="queue full"):
        c.result()
    assert tier.stats.rejected_full == 1
    assert tier.stats.queue_depth == 2
    tier.flush()                                 # drain
    assert a.result() is not None and b.result() is not None
    d = tier.submit("m", q)
    assert not d.rejected                        # space opened up
    tier.close()
    assert d.done()


def test_unroutable_ids_rejected_at_submit(served, fake_clock):
    _, plan, _, _ = served
    tier = _tier(served, fake_clock)
    bad = int(plan.routing.node_ids.max()) + 10_000
    fut = tier.submit("m", [bad])
    assert fut.rejected and tier.stats.rejected_unroutable == 1
    assert tier.stats.queue_depth == 0
    tier.close()


# ------------------------------------------------------------- correctness
def test_async_results_match_sync_engine(served, fake_clock):
    """The tier is a scheduler, not a model: window-coalesced results are
    bitwise what the synchronous engine answers for the same ids."""
    _, plan, _, _ = served
    sync = _engine(served)
    rng = np.random.default_rng(0)
    queries = [rng.choice(plan.routing.node_ids, size=5, replace=False)
               for _ in range(8)]
    tier = _tier(served, fake_clock, window_us=1000.0)
    futs = [tier.submit("m", q) for q in queries]
    fake_clock.advance(1.0)
    tier.step()
    for f, q in zip(futs, queries):
        np.testing.assert_array_equal(f.result(), sync.query(q))
    tier.close()


# ---------------------------------------------------------- fault isolation
def test_faulty_tenant_fails_only_its_window(served, fake_clock):
    """A tenant forward that raises fails exactly that window's futures;
    other tenants' windows complete, and the faulty tenant serves again
    once healthy (the try/except isolation this test pins)."""
    _, plan, _, _ = served
    tier = _tier(served, fake_clock, tenants=("a", "b"), cache_batches=0,
                 window_us=1000.0)
    eng_a = tier.tenant_engine("a")
    healthy_forward = eng_a._forward

    def exploding_forward(params, batch):
        raise RuntimeError("injected fault: tenant a forward")

    eng_a._forward = exploding_forward
    q = plan.routing.node_ids[:3]
    fa = [tier.submit("a", q) for _ in range(2)]
    fb = tier.submit("b", q)
    fake_clock.advance(1.0)
    tier.step()
    for f in fa:                                 # only a's window failed
        with pytest.raises(RuntimeError, match="injected fault"):
            f.result()
    assert fb.result().shape == (3, tier.tenant_engine("b").cfg.out_dim)
    assert tier.stats.window_errors == 1
    assert tier.stats.failed == 2
    assert tier.stats.completed == 1
    # the engine keeps serving: tenant a recovers on the next window
    eng_a._forward = healthy_forward
    fut = tier.submit("a", q)
    fake_clock.advance(1.0)
    tier.step()
    assert fut.result() is not None
    tier.close()


# ------------------------------------------------------- multi-tenant swap
def _feature_delta(ds, plan, rng):
    """A payload-only GraphDelta touching a few of the plan's output
    nodes — refreshable without structural rebuilds."""
    nodes = rng.choice(plan.routing.node_ids, size=4, replace=False)
    feats = ds.features[nodes] + 0.5
    return GraphDelta(feat_nodes=nodes.astype(np.int64),
                      feat_values=feats)


def test_per_tenant_swap_mid_stream(tiny_ds, served, fresh_chain,
                                    fake_clock):
    """swap(tenant) swaps ONE tenant's plan version under live queueing:
    the queue is not drained, queued requests are served by the NEW
    version, and the other tenant's LRU/stats are untouched (no
    cross-tenant pollution)."""
    pipe, plan = fresh_chain
    tier = _tier(served, fake_clock, tenants=("a", "b"), plan=plan,
                 window_us=1000.0)
    warm = plan.routing.node_ids[:4]
    for name in ("a", "b"):
        tier.submit(name, warm)
    fake_clock.advance(1.0)
    tier.step()                                  # both LRUs warmed
    eng_a, eng_b = tier.tenant_engine("a"), tier.tenant_engine("b")
    b_lru_before = set(eng_b._lru)
    assert b_lru_before

    child, audit = pipe.refresh(plan, _feature_delta(
        tiny_ds, plan, np.random.default_rng(3)))
    # mid-stream: requests queued BEFORE the swap...
    fa = tier.submit("a", warm)
    fb = tier.submit("b", warm)
    assert tier.stats.queue_depth == 2
    res = tier.swap("a", child, audit)
    assert tier.stats.queue_depth == 2           # nothing drained
    assert res["invalidated"] + res["kept"] == len(b_lru_before)
    # ...are served after it, by the tenant's NEW plan version
    fake_clock.advance(1.0)
    tier.step()
    assert fa.result() is not None and fb.result() is not None
    assert eng_a.plan is child
    assert eng_a.stats["swap_count"] == 1
    assert eng_a.stats["versions"][child.version]["requests"] == 1
    # no cross-tenant pollution: b's plan, LRU and swap chain untouched
    assert eng_b.plan is plan
    assert eng_b.stats["swap_count"] == 0
    assert set(eng_b._lru) == b_lru_before
    assert tier.snapshot()["tenants"]["a"]["swaps"] == 1
    tier.close()


def test_swap_occupancy_hint_follows_plan(tiny_ds, served, fresh_chain,
                                          fake_clock):
    """After a swap the full-batch dispatch hint reflects the NEW plan's
    routing occupancy (a stale hint would mistime windows silently)."""
    pipe, plan = fresh_chain
    tier = _tier(served, fake_clock, plan=plan, window_us=1e9)
    child, audit = pipe.refresh(plan, _feature_delta(
        tiny_ds, plan, np.random.default_rng(4)))
    tier.swap("m", child, audit)
    np.testing.assert_array_equal(tier._tenants["m"].occupancy,
                                  child.batch_occupancy())
    fut = tier.submit("m", _batch_nodes(child, 0))   # a full batch's worth
    assert tier.step() == 1                          # fires on count
    assert fut.result() is not None
    tier.close()


# ------------------------------------------------------------ threaded path
def test_threaded_dispatch_and_clean_shutdown(served):
    """Worker-thread path: dispatch is event-driven (window_us=0 → fire on
    arrival), completion is awaited on futures, and close() joins the
    worker with every admitted future completed — the Event/sentinel
    discipline, no sleeps anywhere."""
    _, plan, _, _ = served
    tier = AsyncGNNEngine({"m": _engine(served)},
                          AsyncServeConfig(window_us=0.0))
    assert tier._thread.is_alive()
    futs = [tier.submit("m", plan.routing.node_ids[i:i + 3])
            for i in range(0, 12, 3)]
    for f in futs:
        assert f.result(timeout=60.0) is not None
    tier.close()
    assert tier._thread is None
    snap = tier.snapshot()
    assert snap["completed"] == len(futs) == snap["accepted"]
    assert snap["queue_depth"] == 0
    with pytest.raises(ServeClosed):
        tier.submit("m", plan.routing.node_ids[:1])


def test_close_flushes_pending_windows(served):
    """Requests still coalescing when close() lands are NOT dropped: the
    shutdown path flushes them and completes their futures."""
    _, plan, _, _ = served
    tier = AsyncGNNEngine({"m": _engine(served)},
                          AsyncServeConfig(window_us=1e9))  # never expires
    futs = [tier.submit("m", plan.routing.node_ids[:2]) for _ in range(3)]
    tier.close()
    assert all(f.done() for f in futs)
    assert all(f.result().shape[0] == 2 for f in futs)
    assert tier.stats.completed == 3


def test_threaded_multi_client_stats_consistent(served):
    """Satellite: GNNInferenceEngine stats invariants hold when the engine
    is driven through the async tier by many submitter threads."""
    _, plan, _, _ = served
    tier = AsyncGNNEngine({"m": _engine(served, cache_batches=2)},
                          AsyncServeConfig(window_us=200.0))
    results = []

    def client(seed):
        rng = np.random.default_rng(seed)
        futs = [tier.submit(
            "m", rng.choice(plan.routing.node_ids, size=2, replace=False))
            for _ in range(10)]
        results.append([f.result(timeout=60.0) for f in futs])

    threads = [threading.Thread(target=client, args=(s,)) for s in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60.0)
    tier.close()
    assert len(results) == 4 and all(len(r) == 10 for r in results)
    st_ = tier.snapshot()
    eng = tier.tenant_engine("m").stats
    assert st_["completed"] == 40 == st_["accepted"]
    assert eng["requests"] == 40
    served_batches = eng["lru_hits"] + eng["batch_runs"]
    assert served_batches >= 1
    vs = eng["versions"][0]
    assert vs["requests"] == eng["requests"]
    assert vs["lru_hits"] + vs["batch_runs"] == served_batches


# --------------------------------------------- stats invariants (property)
@settings(deadline=None)
@given(st.integers(1, 20), st.integers(0, 4))
def test_engine_stats_invariants_under_async_drive(served, n_requests,
                                                   cache_batches):
    """Property-style (via the hypothesis fallback): with single-node
    requests dispatched one window each, every request is covered by
    exactly one batch event — requests == lru_hits + batch_runs — and the
    per-version buckets sum to the totals with consistent hit rates."""
    _, plan, _, _ = served
    tier = _tier(served, FakeClock(), cache_batches=cache_batches,
                 window_us=0.0, max_requests_per_window=1,
                 occupancy_dispatch=False)
    ids = plan.routing.node_ids
    futs = [tier.submit("m", ids[[i % len(ids)]]) for i in range(n_requests)]
    tier.flush()
    assert all(f.done() for f in futs)
    snap = tier.snapshot()
    assert snap["submitted"] == snap["accepted"] == snap["completed"] \
        == n_requests
    assert snap["queue_depth"] == 0
    eng = tier.tenant_engine("m").stats
    assert eng["requests"] == n_requests
    assert eng["lru_hits"] + eng["batch_runs"] == n_requests
    if cache_batches == 0:
        assert eng["lru_hits"] == 0
    total_v = {k: 0 for k in ("requests", "lru_hits", "batch_runs")}
    for v in eng["versions"].values():
        for k in total_v:
            total_v[k] += v[k]
        covered = v["lru_hits"] + v["batch_runs"]
        if covered:
            assert v["hit_rate"] == pytest.approx(v["lru_hits"] / covered)
    for k in total_v:
        assert total_v[k] == eng[k], k
    tier.close()


def test_swap_chain_stats_consistent_under_stream(tiny_ds, served,
                                                  fresh_chain, fake_clock):
    """Versioned stats stay consistent while swaps interleave with a live
    stream: swap_count matches the chain walked and per-version requests
    sum to the engine total."""
    pipe, plan = fresh_chain
    tier = _tier(served, fake_clock, plan=plan, window_us=0.0)
    rng = np.random.default_rng(5)
    current, n_swaps = plan, 0
    for i in range(3):
        for _ in range(4):
            tier.submit("m", rng.choice(plan.routing.node_ids, size=2,
                                        replace=False))
            tier.step()
        if i < 2:
            child, audit = pipe.refresh(current, _feature_delta(
                tiny_ds, current, rng))
            tier.swap("m", child, audit)
            current, n_swaps = child, n_swaps + 1
    tier.flush()
    eng = tier.tenant_engine("m").stats
    assert eng["swap_count"] == n_swaps == 2
    assert sorted(eng["versions"]) == [0, 1, 2]
    assert sum(v["requests"] for v in eng["versions"].values()) \
        == eng["requests"] == 12
    assert tier.snapshot()["completed"] == 12
    tier.close()
