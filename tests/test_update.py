"""Versioned plans for dynamic graphs (DESIGN.md §10): GraphDelta →
incremental delta-PPR refresh → minimal dirty-batch rebuild → zero-downtime
engine hot swap.

Acceptance (ISSUE 5): refreshed-plan logits are numerically identical (same
tolerance as the §8 parity tests) to a from-scratch ``pipeline.plan()`` on
the post-delta graph, on both segment and bcsr backends.
"""
import copy
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import (
    GraphDelta, IBMBConfig, IBMBPipeline, Plan, check_routing,
)
from repro.core.ppr import (
    ppr_dirty_roots, push_appr, push_appr_incremental,
)
from repro.models.gnn import GNNConfig, init_gnn
from repro.serve import GNNInferenceEngine
from repro.train import GNNTrainer

PIPE_KW = dict(variant="node", k_per_output=8, max_outputs_per_batch=16,
               pad_multiple=32)


def _pipe(ds, **kw):
    cfg = dict(PIPE_KW)
    cfg.update(kw)
    return IBMBPipeline(ds, IBMBConfig(**cfg))


def _model(ds, backend="segment"):
    cfg = GNNConfig(kind="gcn", in_dim=ds.feat_dim, hidden=32,
                    out_dim=ds.num_classes, num_layers=2, backend=backend)
    return cfg, init_gnn(cfg, jax.random.PRNGKey(0))


def _mixed_delta(ds, rng=None):
    """Features + edge insert/delete + label flip, localized around a few
    test outputs."""
    rng = rng or np.random.default_rng(0)
    test = ds.splits["test"]
    u, v = int(test[0]), int(test[1])
    nb = ds.graph.neighbors(u)
    feat_nodes = np.asarray(test[:3], dtype=np.int64)
    return GraphDelta(
        feat_nodes=feat_nodes,
        feat_values=ds.features[feat_nodes] + 0.5,
        edge_inserts=None if np.isin(v, nb) else np.array([[u, v]]),
        edge_deletes=np.array([[u, int(nb[0])]]) if len(nb) else None,
        label_nodes=np.array([u]),
        label_values=np.array([(int(ds.labels[u]) + 1) % ds.num_classes]))


# ------------------------------------------------------------- GraphDelta
def test_delta_apply_copy_on_write(tiny_ds):
    delta = _mixed_delta(tiny_ds)
    before = (tiny_ds.features.copy(), tiny_ds.labels.copy(),
              tiny_ds.graph.num_edges)
    ds2 = delta.apply(tiny_ds)
    assert np.array_equal(tiny_ds.features, before[0])       # untouched
    assert np.array_equal(tiny_ds.labels, before[1])
    assert tiny_ds.graph.num_edges == before[2]
    assert not np.array_equal(ds2.features, tiny_ds.features)
    assert ds2.labels[delta.label_nodes[0]] == delta.label_values[0]
    if delta.edge_inserts is not None:
        u, v = delta.edge_inserts[0]
        assert np.isin(v, ds2.graph.neighbors(int(u)))
    if delta.edge_deletes is not None:
        u, v = delta.edge_deletes[0]
        assert not np.isin(v, ds2.graph.neighbors(int(u)))


def test_delta_validation(tiny_ds):
    with pytest.raises(ValueError, match="come together"):
        GraphDelta(feat_nodes=np.array([0]))
    with pytest.raises(ValueError, match="pairs"):
        GraphDelta(edge_inserts=np.array([0, 1]))
    with pytest.raises(ValueError, match="self-loop"):
        GraphDelta(edge_inserts=np.array([[3, 3]])).apply(tiny_ds)
    with pytest.raises(ValueError, match="shape"):
        GraphDelta(feat_nodes=np.array([0]),
                   feat_values=np.zeros((1, 3))).apply(tiny_ds)
    # duplicates are ambiguous (apply keeps last, a patch would keep first)
    with pytest.raises(ValueError, match="duplicate"):
        GraphDelta(feat_nodes=np.array([5, 5]),
                   feat_values=np.zeros((2, tiny_ds.feat_dim)))
    with pytest.raises(ValueError, match="duplicate"):
        GraphDelta(label_nodes=np.array([5, 5]),
                   label_values=np.array([0, 1]))
    # negative ids would wrap in fancy indexing but miss membership patches
    with pytest.raises(ValueError, match="range"):
        GraphDelta(feat_nodes=np.array([-1]),
                   feat_values=np.zeros((1, tiny_ds.feat_dim))
                   ).apply(tiny_ds)
    with pytest.raises(ValueError, match="range"):
        GraphDelta(label_nodes=np.array([tiny_ds.num_nodes]),
                   label_values=np.array([0])).apply(tiny_ds)
    test = tiny_ds.splits["test"]
    with pytest.raises(ValueError, match="already in the split"):
        GraphDelta(output_adds={"test": test[:1]}).apply(tiny_ds)
    train_only = np.setdiff1d(tiny_ds.splits["train"], test)
    with pytest.raises(ValueError, match="not.*in the split"):
        GraphDelta(output_removes={"test": train_only[:1]}).apply(tiny_ds)


# ------------------------------------------------------ incremental PPR
def test_incremental_ppr_bit_exact(tiny_ds):
    """Clean-root splice + dirty-root re-push == full from-scratch push,
    bit for bit (the exactness the whole dirty-batch criterion rests on)."""
    test = tiny_ds.splits["test"]
    prev = push_appr(tiny_ds.graph, test, max_iters=3, topk=16)
    delta = _mixed_delta(tiny_ds)
    ds2 = delta.apply(tiny_ds)
    dirty = ppr_dirty_roots(test, delta.touched_nodes(),
                            [tiny_ds.graph, ds2.graph], hops=2)
    inc = push_appr_incremental(ds2.graph, test, prev, dirty,
                                max_iters=3, topk=16)
    full = push_appr(ds2.graph, test, max_iters=3, topk=16)
    assert np.array_equal(inc.indices, full.indices)
    assert np.array_equal(inc.values, full.values)
    # and a feature-only delta dirties nothing
    assert not ppr_dirty_roots(test, np.zeros(0, np.int64),
                               [tiny_ds.graph], hops=2).any()


# ----------------------------------------------------------- the refresh
def test_feature_only_delta_patches_without_rebuild(tiny_ds):
    """A payload-only delta rebuilds NOTHING: dirty batches are patched in
    place, PPR/partition/schedule are reused, and the result is
    bit-identical to a from-scratch plan on the post-delta graph."""
    pipe = _pipe(tiny_ds)
    plan = pipe.plan("test", for_inference=True)
    nid = plan.node_ids[0]
    target = int(nid[nid >= 0][0])
    delta = GraphDelta(feat_nodes=np.array([target]),
                       feat_values=tiny_ds.features[[target]] + 1.0)
    child, audit = pipe.refresh(plan, delta)
    assert len(audit.rebuilt) == 0
    assert audit.dirty_roots == 0
    assert audit.fallback is None
    assert len(audit.patched) >= 1
    assert len(audit.patched) + len(audit.untouched) == len(plan)
    check_routing(child)
    scratch = _pipe(delta.apply(tiny_ds)).plan("test", for_inference=True)
    assert scratch.fingerprint == child.fingerprint
    for k in scratch.cache.fields:
        assert np.array_equal(scratch.cache.fields[k],
                              child.cache.fields[k]), k
    assert np.array_equal(scratch.schedule, child.schedule)


def test_structural_refresh_keeps_clean_batches(tiny_ds):
    """An edge edit rebuilds only batches whose node set (or influence-
    selected aux set) it actually reached; the rest carry over verbatim."""
    pipe = _pipe(tiny_ds)
    plan = pipe.plan("test", for_inference=True)
    assert plan.num_batches > 2
    delta = _mixed_delta(tiny_ds)
    child, audit = pipe.refresh(plan, delta)
    assert audit.fallback is None
    assert len(audit.rebuilt) >= 1
    assert len(audit.untouched) >= 1
    assert audit.dirty_roots < len(tiny_ds.splits["test"])
    check_routing(child)
    # carried-over batches are bitwise the parent's
    for i in audit.untouched:
        for k in plan.cache.fields:
            assert np.array_equal(child.cache.fields[k][i],
                                  plan.cache.fields[k][i]), (i, k)
    # and the whole plan equals a from-scratch build on the new graph
    scratch = _pipe(delta.apply(tiny_ds)).plan("test", for_inference=True)
    for k in scratch.cache.fields:
        assert np.array_equal(scratch.cache.fields[k],
                              child.cache.fields[k]), k


def test_refresh_version_chain_roundtrip(tmp_path, tiny_ds):
    """version/parent advance along the chain, survive save/load, and a
    LOADED plan refreshes from its stored top-k (no warm pipeline)."""
    pipe = _pipe(tiny_ds)
    plan = pipe.plan("test", for_inference=True)
    path = str(tmp_path / "v0.npz")
    plan.save(path)

    ds = copy.copy(tiny_ds)   # fresh pipeline, no PPR cache: cold server
    pipe2 = _pipe(ds)
    loaded = pipe2.load_plan(path, "test", for_inference=True)
    assert loaded.ppr is not None and loaded.version == 0
    delta = _mixed_delta(tiny_ds)
    child, audit = pipe2.refresh(loaded, delta)
    assert audit.fallback is None        # stored top-k was enough to warm it
    assert child.version == 1 and child.parent == loaded.fingerprint
    delta2 = GraphDelta(feat_nodes=np.array([0]),
                        feat_values=ds.features[[0]] - 1.0)
    grand, _ = pipe2.refresh(child, delta2)
    assert grand.version == 2 and grand.parent == child.fingerprint
    p2 = str(tmp_path / "v2.npz")
    grand.save(p2)
    back = Plan.load(p2)
    assert back.version == 2 and back.parent == child.fingerprint
    check_routing(back)
    # the advanced pipeline accepts its own chained artifact
    assert pipe2.load_plan(p2, "test", for_inference=True).version == 2


def test_refresh_rejects_foreign_plan(tiny_ds):
    pipe = _pipe(tiny_ds)
    plan = pipe.plan("test", for_inference=True)
    other = _pipe(tiny_ds, k_per_output=4).plan("test", for_inference=True)
    with pytest.raises(ValueError, match="fingerprint"):
        pipe.refresh(other, GraphDelta())
    # a stale (pre-delta) plan is refused after the pipeline advanced
    delta = _mixed_delta(tiny_ds)
    pipe.refresh(plan, delta)
    with pytest.raises(ValueError, match="fingerprint"):
        pipe.refresh(plan, delta)


def test_refresh_output_set_changes(tiny_ds):
    """Adding/removing output nodes re-partitions just the affected
    batches; the refreshed routing covers exactly the new output set."""
    pipe = _pipe(tiny_ds)
    plan = pipe.plan("test", for_inference=True)
    test = tiny_ds.splits["test"]
    val_only = np.setdiff1d(tiny_ds.splits["val"],
                            np.concatenate([test,
                                            tiny_ds.splits["train"]]))
    delta = GraphDelta(output_adds={"test": val_only[:2]},
                       output_removes={"test": test[:2]})
    child, audit = pipe.refresh(plan, delta)
    check_routing(child)
    new_test = pipe.ds.splits["test"]
    assert np.array_equal(np.asarray(child.routing.node_ids),
                          np.unique(new_test))
    scratch = _pipe(delta.apply(tiny_ds)).plan("test", for_inference=True)
    assert scratch.num_batches == child.num_batches
    for k in scratch.cache.fields:
        assert np.array_equal(scratch.cache.fields[k],
                              child.cache.fields[k]), k


def test_refresh_batch_variant_structural_fallback(tiny_ds):
    """Batch-wise aux is a global diffusion: a structural delta dirties
    every batch and the audit says so — but the refresh stays correct."""
    pipe = _pipe(tiny_ds, variant="batch", num_batches=3)
    plan = pipe.plan("test", for_inference=True)
    delta = GraphDelta(edge_deletes=np.array(
        [[int(tiny_ds.splits["test"][0]),
          int(tiny_ds.graph.neighbors(int(tiny_ds.splits["test"][0]))[0])]]))
    child, audit = pipe.refresh(plan, delta)
    assert audit.fallback is not None
    assert len(audit.untouched) == 0
    # padded caps may legitimately differ (refresh keeps the parent's shape
    # bucket) — compare logits, which padding cannot affect
    scratch = _pipe(delta.apply(tiny_ds), variant="batch",
                    num_batches=3).plan("test", for_inference=True)
    cfg, params = _model(tiny_ds)
    query = np.asarray(pipe.ds.splits["test"])
    np.testing.assert_allclose(
        GNNInferenceEngine(child, cfg, params).query(query),
        GNNInferenceEngine(scratch, cfg, params).query(query),
        atol=1e-5, rtol=1e-5)


# ------------------------------------------- acceptance: logit parity
@pytest.mark.parametrize("backend", ["segment", "bcsr"])
def test_refreshed_logits_match_scratch(tiny_ds, backend):
    """ACCEPTANCE: refreshed-plan logits are numerically identical (same
    tolerance as the §8 parity tests) to a from-scratch pipeline.plan() on
    the post-delta graph — segment AND bcsr backends, structural delta."""
    pipe = _pipe(tiny_ds, backend="bcsr")
    plan = pipe.plan("test", for_inference=True)
    delta = _mixed_delta(tiny_ds)
    child, audit = pipe.refresh(plan, delta)
    check_routing(child)

    ds2 = delta.apply(tiny_ds)
    scratch = _pipe(ds2, backend="bcsr").plan("test", for_inference=True)
    assert scratch.fingerprint == child.fingerprint

    cfg, params = _model(tiny_ds, backend=backend)
    eng_child = GNNInferenceEngine(child, cfg, params)
    eng_scratch = GNNInferenceEngine(scratch, cfg, params)
    query = np.asarray(ds2.splits["test"])
    np.testing.assert_allclose(eng_child.query(query),
                               eng_scratch.query(query),
                               atol=1e-5, rtol=1e-5)
    # the refreshed artifact also still trains/evaluates
    trainer = GNNTrainer(cfg, lr=1e-3, backend=backend)
    ev_child = trainer.evaluate(params, child)
    ev_scratch = trainer.evaluate(params, scratch)
    assert ev_child["acc"] == pytest.approx(ev_scratch["acc"], abs=1e-6)
    assert ev_child["loss"] == pytest.approx(ev_scratch["loss"], abs=1e-6)


# -------------------------------------------------------- engine hot swap
def test_engine_hot_swap_zero_downtime(tiny_ds):
    """swap() keeps untouched batches serving from the LRU (no new batch
    runs for them), drops only dirty entries, and the stats expose
    swap_count / evictions / per-version hit rates."""
    pipe = _pipe(tiny_ds)
    plan = pipe.plan("test", for_inference=True)
    assert plan.num_batches > 2
    cfg, params = _model(tiny_ds)
    engine = GNNInferenceEngine(plan, cfg, params,
                                cache_batches=plan.num_batches)
    test = tiny_ds.splits["test"]
    engine.query(test)                        # fill the LRU completely
    runs_v0 = engine.stats["batch_runs"]
    assert runs_v0 == plan.num_batches

    # delta confined to nodes of ONE batch → exactly one dirty batch
    others = set()
    for i in range(1, plan.num_batches):
        m = plan.node_ids[i]
        others |= set(m[m >= 0].tolist())
    m0 = plan.node_ids[0]
    only0 = sorted(set(m0[m0 >= 0].tolist()) - others)
    assert only0, "tiny batch 0 has no private nodes?"
    delta = GraphDelta(feat_nodes=np.asarray(only0),
                       feat_values=tiny_ds.features[only0] + 1.0)
    child, audit = pipe.refresh(plan, delta)
    assert list(audit.dirty) == [0]

    swap = engine.swap(child, audit)
    assert swap == {"invalidated": 1, "kept": plan.num_batches - 1}
    assert engine.stats["swap_count"] == 1
    assert engine.stats["evictions"] == 1

    got = engine.query(test)                  # post-swap traffic
    # zero downtime: only the dirty batch re-ran; the rest came from LRU
    assert engine.stats["batch_runs"] == runs_v0 + 1
    v0, v1 = engine.stats["versions"][0], engine.stats["versions"][1]
    assert v0["requests"] == 1 and v1["requests"] == 1
    assert v1["batch_runs"] == 1
    assert v1["lru_hits"] == plan.num_batches - 1
    assert 0 < v1["hit_rate"] < 1
    # and the served logits are the refreshed plan's, not stale ones
    eng_fresh = GNNInferenceEngine(child, cfg, params)
    np.testing.assert_allclose(got, eng_fresh.query(test),
                               atol=1e-5, rtol=1e-5)

    # swapping against the wrong parent is refused
    with pytest.raises(ValueError, match="chain|parents"):
        engine.swap(plan, audit)
    # ...as is an audit that does not describe the incoming plan: pairing
    # grand's plan with child's audit would keep stale LRU entries serving
    grand, audit2 = pipe.refresh(
        child, GraphDelta(feat_nodes=np.asarray(only0[:1]),
                          feat_values=tiny_ds.features[only0[:1]] - 2.0))
    with pytest.raises(ValueError, match="audit|describe"):
        engine.swap(child, audit2)
    assert engine.plan is child and engine.stats["swap_count"] == 1
    # swap without an audit record clears the LRU conservatively
    engine.swap(child, None)
    assert engine.stats["swap_count"] == 2
    assert engine.stats["evictions"] == 1 + plan.num_batches


def test_engine_swap_validates_backend(tiny_ds):
    """Swapping a tile-less plan under a bcsr engine fails fast and leaves
    the serving state untouched."""
    bcsr_plan = _pipe(tiny_ds, backend="bcsr").plan("test",
                                                    for_inference=True)
    seg_plan = _pipe(tiny_ds).plan("test", for_inference=True)
    cfg, params = _model(tiny_ds, backend="bcsr")
    engine = GNNInferenceEngine(bcsr_plan, cfg, params)
    with pytest.raises(ValueError, match="bcsr"):
        engine.swap(seg_plan)
    assert engine.plan is bcsr_plan
    assert engine.stats["swap_count"] == 0


# ----------------------------------------------------------- satellites
def test_trainer_names_batcher_in_bcsr_error(tiny_ds):
    """Satellite: a baseline Batcher + backend='bcsr' fails up front with
    the batcher's name, not mid-trace with a generic tiles error."""
    from repro.graph.sampling import make_batcher
    bt = make_batcher("cluster_gcn", tiny_ds, split="train", num_batches=2)
    val = _pipe(tiny_ds, backend="bcsr").plan("val", for_inference=True)
    cfg = GNNConfig(kind="gcn", in_dim=tiny_ds.feat_dim, hidden=32,
                    out_dim=tiny_ds.num_classes, num_layers=2)
    trainer = GNNTrainer(cfg, lr=1e-3, backend="bcsr")
    with pytest.raises(ValueError, match="cluster_gcn"):
        trainer.fit(bt, val, tiny_ds.num_classes, epochs=1)


def test_loader_rejects_stale_schedule(tiny_ds):
    """Satellite ride-along: a schedule referencing batches the container
    does not hold fails in the caller with a version hint, not in the
    prefetch worker."""
    from repro.data.loader import PrefetchLoader
    plan = _pipe(tiny_ds).plan("test", for_inference=True)
    with pytest.raises(IndexError, match="plan version"):
        PrefetchLoader(plan.cache, order=np.array([0, len(plan) + 3]))
