"""Every module under src/repro must import.

A missing package (as `repro.dist` once was) breaks test modules at
COLLECTION time, silently disabling half the suite; this test turns any such
hole into one precise failure naming the module."""
import importlib
import pkgutil

import jax
import pytest

import repro


def _all_modules():
    mods = ["repro"]
    for info in pkgutil.walk_packages(repro.__path__, prefix="repro."):
        mods.append(info.name)
    return sorted(mods)


@pytest.mark.parametrize("mod", _all_modules())
def test_module_imports(mod):
    # Lock the jax backend FIRST: repro.launch.dryrun prepends
    # --xla_force_host_platform_device_count to XLA_FLAGS at import, which
    # must not take effect inside the shared test process (smoke tests and
    # benches expect exactly 1 device).
    assert len(jax.devices()) >= 1
    importlib.import_module(mod)


def test_dryrun_import_does_not_change_device_count():
    n = len(jax.devices())
    importlib.import_module("repro.launch.dryrun")
    assert len(jax.devices()) == n
