"""Pallas kernels: shape/dtype sweeps, interpret-mode vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
import scipy.sparse as sp

from repro.kernels.spmm import csr_to_bcsr, spmm_bcsr
from repro.kernels.gather_rows import gather_rows
from repro.kernels.flash_attention import flash_attention


# ------------------------------------------------------------------------ spmm
@pytest.mark.parametrize("n,f,density", [
    (128, 128, 0.05), (256, 64, 0.02), (300, 256, 0.01), (130, 128, 0.1)])
def test_spmm_shapes(n, f, density):
    rng = np.random.default_rng(0)
    m = sp.random(n, n, density=density, random_state=0, format="csr",
                  dtype=np.float32)
    x = rng.normal(size=(n, f)).astype(np.float32)
    bc = csr_to_bcsr(m.indptr, m.indices, m.data, n, n, block=128)
    xp = np.zeros((bc.num_cols, f), np.float32)
    xp[:n] = x
    oracle = m @ x
    ref = spmm_bcsr(jnp.asarray(bc.tile_cols), jnp.asarray(bc.tile_vals),
                    jnp.asarray(xp), impl="reference")
    np.testing.assert_allclose(np.asarray(ref)[:n], oracle, atol=1e-4)
    out = spmm_bcsr(jnp.asarray(bc.tile_cols), jnp.asarray(bc.tile_vals),
                    jnp.asarray(xp), impl="interpret", block_f=64)
    np.testing.assert_allclose(np.asarray(out)[:n], oracle, atol=1e-4)


def test_spmm_on_gnn_batch(tiny_ds):
    """The kernel computes the actual GCN aggregation for an IBMB batch."""
    from repro.core import IBMBPipeline, IBMBConfig
    pipe = IBMBPipeline(tiny_ds, IBMBConfig(
        variant="node", k_per_output=8, max_outputs_per_batch=64,
        pad_multiple=32))
    b = pipe.preprocess("train")[0]
    n = b.node_ids.shape[0]
    m = sp.csr_matrix((b.edge_weight[b.edge_mask],
                       (b.edge_src[b.edge_mask], b.edge_dst[b.edge_mask])),
                      shape=(n, n))
    bc = csr_to_bcsr(m.indptr, m.indices, m.data, n, n, block=128)
    f = b.features.shape[1]
    xp = np.zeros((bc.num_cols, f), np.float32)
    xp[:n] = b.features
    out = spmm_bcsr(jnp.asarray(bc.tile_cols), jnp.asarray(bc.tile_vals),
                    jnp.asarray(xp), impl="interpret", block_f=f)
    oracle = m @ b.features
    np.testing.assert_allclose(np.asarray(out)[:n], oracle, atol=1e-4)


# ---------------------------------------------------------------------- gather
@pytest.mark.parametrize("n,f,m_rows,dtype", [
    (256, 128, 64, np.float32), (512, 256, 100, np.float32),
    (128, 512, 16, np.float32)])
def test_gather_rows(n, f, m_rows, dtype):
    rng = np.random.default_rng(1)
    table = jnp.asarray(rng.normal(size=(n, f)).astype(dtype))
    idx = jnp.asarray(rng.integers(0, n, m_rows).astype(np.int32))
    ref = gather_rows(table, idx, impl="reference")
    out = gather_rows(table, idx, impl="interpret", block_f=128)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# ----------------------------------------------------------------------- flash
@pytest.mark.parametrize("b,h,s,d", [(1, 2, 128, 64), (2, 4, 256, 32)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention(b, h, s, d, causal):
    rng = np.random.default_rng(2)
    q, k, v = (jnp.asarray(rng.normal(size=(b, h, s, d)).astype(np.float32))
               for _ in range(3))
    ref = flash_attention(q, k, v, causal=causal, impl="reference")
    out = flash_attention(q, k, v, causal=causal, impl="interpret",
                          block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_flash_attention_window():
    rng = np.random.default_rng(3)
    q, k, v = (jnp.asarray(rng.normal(size=(1, 2, 256, 32)).astype(np.float32))
               for _ in range(3))
    ref = flash_attention(q, k, v, causal=True, window=64, impl="reference")
    out = flash_attention(q, k, v, causal=True, window=64, impl="interpret",
                          block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_flash_attention_bf16():
    rng = np.random.default_rng(4)
    q, k, v = (jnp.asarray(rng.normal(size=(1, 2, 128, 64)),
                           dtype=jnp.bfloat16) for _ in range(3))
    ref = flash_attention(q.astype(jnp.float32), k.astype(jnp.float32),
                          v.astype(jnp.float32), impl="reference")
    out = flash_attention(q, k, v, impl="interpret", block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(out, dtype=np.float32),
                               np.asarray(ref), atol=3e-2)


def test_xla_chunked_attention_matches_ref():
    """The XLA-lowerable chunked path (used by the dry-run) is the same math."""
    from repro.models.lm.attention import chunked_attention
    rng = np.random.default_rng(5)
    b, s, h, kv, d = 2, 256, 8, 2, 32
    q = jnp.asarray(rng.normal(size=(b, s, h, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, kv, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, kv, d)).astype(np.float32))
    out = chunked_attention(q, k, v, causal=True, chunk_k=64)
    # oracle via flash ref with expanded kv heads
    g = h // kv
    k_e = jnp.repeat(k, g, axis=2).transpose(0, 2, 1, 3)
    v_e = jnp.repeat(v, g, axis=2).transpose(0, 2, 1, 3)
    q_t = q.reshape(b, s, kv, g, d).transpose(0, 2, 3, 1, 4).reshape(b, h, s, d)
    ref = flash_attention(q_t, k_e, v_e, causal=True, impl="reference")
    np.testing.assert_allclose(
        np.asarray(out.reshape(b, s, kv, g, d).transpose(0, 2, 3, 1, 4)
                   .reshape(b, h, s, d)),
        np.asarray(ref), atol=2e-5)
