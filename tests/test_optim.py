"""Optimizers, schedules, accumulation, compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_fallback import given, settings, st

from repro.optim.optimizers import adam, adagrad, adafactor, sgd, apply_updates
from repro.optim.schedules import ReduceLROnPlateau
from repro.optim.accumulate import GradAccumulator
from repro.optim.compression import (
    topk_compress, topk_decompress, ErrorFeedback, quantize_int8,
    dequantize_int8, flatten_grads, unflatten_grads)


@pytest.mark.parametrize("opt_fn,lr", [
    (adam, 0.05), (adagrad, 0.5), (lambda: sgd(0.9), 0.05), (adafactor, 0.05)])
def test_optimizer_minimizes_quadratic(opt_fn, lr):
    # adagrad's effective step decays as 1/√Σg² — it needs a larger base lr
    opt = opt_fn()
    params = {"x": jnp.array([3.0, -2.0]), "w": jnp.ones((4, 3)) * 2}
    state = opt.init(params)

    def loss(p):
        return (p["x"] ** 2).sum() + (p["w"] ** 2).sum()

    l0 = loss(params)
    for _ in range(300):
        grads = jax.grad(loss)(params)
        upd, state = opt.update(grads, state, params, jnp.float32(lr))
        params = apply_updates(params, upd)
    assert float(loss(params)) < float(l0) * 0.05


def test_adafactor_state_is_factored():
    opt = adafactor()
    params = {"w": jnp.ones((64, 32))}
    st_ = opt.init(params)
    assert st_["slots"]["w"]["vr"].shape == (64,)
    assert st_["slots"]["w"]["vc"].shape == (32,)


def test_plateau_scheduler_paper_config():
    s = ReduceLROnPlateau(lr=1e-3, factor=0.33, patience=3, min_lr=1e-4,
                          cooldown=2)
    s.step(1.0)                      # establishes best
    for _ in range(3):               # 3 bad epochs = patience, no drop yet
        s.step(1.0)
    assert s.lr == 1e-3
    s.step(1.0)                      # 4th bad epoch > patience → reduce
    assert abs(s.lr - 3.3e-4) < 1e-9
    for _ in range(30):
        s.step(1.0)
    assert s.lr >= 1e-4 - 1e-12      # respects min_lr


def test_grad_accumulator():
    acc = GradAccumulator(every=3)
    g = {"w": jnp.ones(4)}
    assert acc.add(g) is None
    assert acc.add({"w": jnp.ones(4) * 2}) is None
    out = acc.add({"w": jnp.ones(4) * 3})
    np.testing.assert_allclose(np.asarray(out["w"]), 2.0)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 500), st.integers(0, 100))
def test_topk_roundtrip_preserves_topk(n, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=n).astype(np.float32))
    k = max(1, n // 10)
    payload = topk_compress(x, k)
    y = topk_decompress(payload)
    kept = np.asarray(jnp.abs(x)).argsort()[-k:]
    np.testing.assert_allclose(np.asarray(y)[kept], np.asarray(x)[kept])


def test_error_feedback_conserves_signal():
    ef = ErrorFeedback(k_frac=0.2)
    rng = np.random.default_rng(0)
    total_in = np.zeros(50, np.float32)
    total_out = np.zeros(50, np.float32)
    for _ in range(50):
        g = rng.normal(size=50).astype(np.float32)
        _, sent = ef.compress(jnp.asarray(g))
        total_in += g
        total_out += np.asarray(sent)
    residual = np.asarray(ef._residual)
    np.testing.assert_allclose(total_out + residual, total_in, rtol=1e-4,
                               atol=1e-4)


def test_int8_quantization_error():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=1000).astype(np.float32))
    q, s = quantize_int8(x)
    y = dequantize_int8(q, s)
    assert float(jnp.abs(x - y).max()) <= float(s) * 0.5 + 1e-7


def test_flatten_unflatten_roundtrip():
    tree = {"a": jnp.ones((3, 2)), "b": {"c": jnp.arange(4.0)}}
    flat, spec = flatten_grads(tree)
    back = unflatten_grads(flat, spec)
    assert jax.tree_util.tree_structure(back) == jax.tree_util.tree_structure(tree)
    np.testing.assert_allclose(np.asarray(back["b"]["c"]), np.arange(4.0))
