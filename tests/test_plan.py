"""Plan artifact + request-level serving (DESIGN.md §8): save/load
round-trip, fingerprint binding, routing-index correctness, and
engine-vs-batch-eval logit parity on segment and bcsr backends."""
import dataclasses
import os

import jax
import numpy as np
import pytest

from repro.core import IBMBPipeline, IBMBConfig, Plan, PlanFormatError
from repro.models.gnn import GNNConfig, init_gnn
from repro.models.gnn.models import gnn_apply, output_logits
from repro.serve import GNNInferenceEngine, GNNRequest
from repro.train import GNNTrainer


def _pipe(ds, **kw):
    cfg = dict(variant="node", k_per_output=8, max_outputs_per_batch=64,
               pad_multiple=32)
    cfg.update(kw)
    return IBMBPipeline(ds, IBMBConfig(**cfg))


@pytest.fixture(scope="module")
def bcsr_plan(tiny_ds):
    return _pipe(tiny_ds, backend="bcsr").plan("test", for_inference=True)


@pytest.fixture(scope="module")
def seg_plan(tiny_ds):
    return _pipe(tiny_ds).plan("test", for_inference=True)


# ---------------------------------------------------------------- artifact
def test_plan_bundles_everything(tiny_ds, seg_plan):
    assert seg_plan.num_batches == len(seg_plan.cache)
    assert len(seg_plan.schedule) == seg_plan.num_batches
    assert seg_plan.meta["split"] == "test"
    assert seg_plan.meta["mode"] == "inference"
    assert any(k.startswith("preprocess/test/inference")
               for k in seg_plan.timings)
    # frozen: the schedule/routing arrays are write-protected
    with pytest.raises(ValueError):
        seg_plan.schedule[0] = 0
    with pytest.raises(ValueError):
        seg_plan.routing.node_ids[0] = 0


def test_plan_roundtrip_with_tiles(tmp_path, tiny_ds, bcsr_plan):
    """BCSR tiles, schedule, routing index, fingerprint, timings all
    survive save → load."""
    path = str(tmp_path / "plan.npz")
    bcsr_plan.save(path)
    loaded = Plan.load(path)
    assert loaded.fingerprint == bcsr_plan.fingerprint
    assert loaded.meta == bcsr_plan.meta
    assert set(loaded.timings) == set(bcsr_plan.timings)
    assert np.array_equal(loaded.schedule, bcsr_plan.schedule)
    assert set(loaded.cache.fields) == set(bcsr_plan.cache.fields)
    assert "tile_vals" in loaded.cache.fields
    for k in bcsr_plan.cache.fields:
        assert np.array_equal(loaded.cache.fields[k],
                              bcsr_plan.cache.fields[k]), k
    assert loaded.cache.meta == bcsr_plan.cache.meta
    for f in ("node_ids", "batch", "row"):
        assert np.array_equal(getattr(loaded.routing, f),
                              getattr(bcsr_plan.routing, f))


def test_plan_fingerprint_mismatch_raises(tmp_path, tiny_ds, seg_plan):
    path = str(tmp_path / "plan.npz")
    seg_plan.save(path)
    with pytest.raises(PlanFormatError, match="fingerprint"):
        Plan.load(path, expect_fingerprint="deadbeef")
    # a pipeline with a DIFFERENT config refuses the artifact...
    other = _pipe(tiny_ds, k_per_output=4)
    with pytest.raises(PlanFormatError, match="fingerprint"):
        other.load_plan(path, "test", for_inference=True)
    # ...as does the same config loading for the wrong split/mode
    same = _pipe(tiny_ds)
    with pytest.raises(PlanFormatError, match="fingerprint"):
        same.load_plan(path, "val", for_inference=True)
    with pytest.raises(PlanFormatError, match="fingerprint"):
        same.load_plan(path, "test", for_inference=False)
    # the matching pipeline accepts it
    ok = same.load_plan(path, "test", for_inference=True)
    assert ok.fingerprint == seg_plan.fingerprint


def test_plan_load_rejects_foreign_npz(tmp_path):
    path = str(tmp_path / "not_a_plan.npz")
    np.savez(path, x=np.zeros(3))
    with pytest.raises(PlanFormatError, match="not a Plan"):
        Plan.load(path)


def test_plan_load_rejects_truncated_artifact(tmp_path, seg_plan):
    """A versioned artifact missing routing/schedule arrays raises
    PlanFormatError (not a bare KeyError)."""
    import json as _json
    from repro.core.plan import PLAN_VERSION
    path = str(tmp_path / "truncated.npz")
    header = _json.dumps({"version": PLAN_VERSION, "fingerprint": "",
                          "meta": {}, "timings": {}})
    np.savez(path, __plan_json__=np.array(header),
             **{"cache/features": np.zeros((1, 4, 2), np.float32)})
    with pytest.raises(PlanFormatError, match="missing fields"):
        Plan.load(path)


def test_plan_load_rejects_stale_version(tmp_path):
    """A pre-v2 artifact (no membership/ppr arrays) is refused by version,
    not by a confusing missing-field error."""
    import json as _json
    path = str(tmp_path / "stale.npz")
    header = _json.dumps({"version": 1, "fingerprint": "", "meta": {},
                          "timings": {}})
    np.savez(path, __plan_json__=np.array(header))
    with pytest.raises(PlanFormatError, match="version"):
        Plan.load(path)


def test_plan_compressed_roundtrip(tmp_path, tiny_ds, bcsr_plan):
    """Satellite: save(compress=True) writes a zipped npz that load
    auto-detects; both flavors round-trip identically."""
    from repro.core import check_routing
    plain = str(tmp_path / "plain.npz")
    packed = str(tmp_path / "packed.npz")
    bcsr_plan.save(plain)
    bcsr_plan.save(packed, compress=True)
    assert os.path.getsize(packed) < os.path.getsize(plain)
    for path in (plain, packed):
        loaded = Plan.load(path)
        assert loaded.fingerprint == bcsr_plan.fingerprint
        assert loaded.version == bcsr_plan.version
        assert loaded.parent == bcsr_plan.parent
        for k in bcsr_plan.cache.fields:
            assert np.array_equal(loaded.cache.fields[k],
                                  bcsr_plan.cache.fields[k]), k
        assert np.array_equal(loaded.node_ids, bcsr_plan.node_ids)
        assert loaded.ppr is not None
        assert np.array_equal(loaded.ppr.indices, bcsr_plan.ppr.indices)
        check_routing(loaded)


def test_fingerprint_tracks_graph_content(tiny_ds):
    """Same shapes, different edge weights/features ⇒ different fingerprint
    (a regenerated dataset must invalidate old plans)."""
    import copy
    fp1 = _pipe(tiny_ds).fingerprint("test", for_inference=True)
    ds2 = copy.copy(tiny_ds)
    ds2.features = tiny_ds.features + 1.0
    fp2 = _pipe(ds2).fingerprint("test", for_inference=True)
    assert fp1 != fp2


def test_check_routing_after_build_and_load(tmp_path, tiny_ds, seg_plan,
                                            bcsr_plan):
    """Satellite: the routing invariants (sorted, bijective over output
    nodes, every entry addresses its node) hold after build and load, and
    check_routing actually rejects violations."""
    from repro.core import check_routing
    for plan in (seg_plan, bcsr_plan):
        stats = check_routing(plan)
        assert stats["entries"] == len(tiny_ds.splits["test"])
    path = str(tmp_path / "plan.npz")
    seg_plan.save(path)
    check_routing(Plan.load(path))
    # a corrupted index is rejected
    bad = dataclasses.replace(
        seg_plan, routing=dataclasses.replace(
            seg_plan.routing,
            node_ids=seg_plan.routing.node_ids[::-1].copy()))
    with pytest.raises(ValueError, match="increasing"):
        check_routing(bad)
    shifted = dataclasses.replace(
        seg_plan, routing=dataclasses.replace(
            seg_plan.routing,
            node_ids=(seg_plan.routing.node_ids + 1).copy()))
    with pytest.raises(ValueError, match="address"):
        check_routing(shifted)


def test_routing_index_inverse_map(tiny_ds, seg_plan):
    """Routing maps every covered output node to the (batch, row) slot that
    actually holds it, and raises KeyError for uncovered ids."""
    test = tiny_ds.splits["test"]
    assert len(seg_plan.routing) == len(test)
    bidx, rows = seg_plan.routing.lookup(test)
    lab = seg_plan.cache.fields["labels"]
    oidx = seg_plan.cache.fields["output_idx"]
    feats = seg_plan.cache.fields["features"]
    for node, bi, r in zip(test, bidx, rows):
        assert lab[bi][r] == tiny_ds.labels[node]
        assert np.allclose(feats[bi][oidx[bi][r]], tiny_ds.features[node])
    train_only = np.setdiff1d(tiny_ds.splits["train"], test)
    with pytest.raises(KeyError):
        seg_plan.routing.lookup(train_only[:3])


# ----------------------------------------------------------------- serving
@pytest.mark.parametrize("backend", ["segment", "bcsr"])
def test_engine_matches_batch_eval(tmp_path, tiny_ds, bcsr_plan, backend):
    """Acceptance: engine per-node logits from a Plan.load'ed artifact (no
    re-preprocessing) are numerically identical to the batch-eval forward,
    on segment and bcsr backends."""
    path = str(tmp_path / "plan.npz")
    bcsr_plan.save(path)
    plan = Plan.load(path)

    cfg = GNNConfig(kind="gcn", in_dim=tiny_ds.feat_dim, hidden=32,
                    out_dim=tiny_ds.num_classes, num_layers=2,
                    backend=backend)
    params = init_gnn(cfg, jax.random.PRNGKey(0))
    engine = GNNInferenceEngine(plan, cfg, params)

    test = tiny_ds.splits["test"]
    rng = np.random.default_rng(0)
    query = rng.permutation(test)                # all covered nodes, shuffled
    got = engine.query(query)

    # reference: the batch forward (same gnn_apply path; run unjitted, so
    # XLA fusion may differ in the last float32 ulp — hence allclose)
    want = np.zeros_like(got)
    bidx, rows = plan.routing.lookup(query)
    for bi in np.unique(bidx):
        bd = plan.cache[int(bi)]
        logits = np.asarray(output_logits(gnn_apply(cfg, params, bd), bd))
        sel = bidx == bi
        want[sel] = logits[rows[sel]]
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)

    # and the engine's predictions reproduce trainer.evaluate's accuracy
    trainer = GNNTrainer(cfg, lr=1e-3)
    ev = trainer.evaluate(params, plan)
    acc = float((got.argmax(-1) == tiny_ds.labels[query]).mean())
    assert acc == pytest.approx(ev["acc"], abs=1e-6)


def test_engine_coalesces_and_caches(tiny_ds):
    """Concurrent requests hitting the same batch share ONE forward; repeat
    traffic is served from the LRU without new batch runs."""
    plan = _pipe(tiny_ds, max_outputs_per_batch=16).plan(
        "test", for_inference=True)
    assert plan.num_batches > 1
    cfg = GNNConfig(kind="gcn", in_dim=tiny_ds.feat_dim, hidden=32,
                    out_dim=tiny_ds.num_classes, num_layers=2)
    engine = GNNInferenceEngine(plan, cfg, init_gnn(cfg, jax.random.PRNGKey(0)),
                                cache_batches=plan.num_batches)
    test = tiny_ds.splits["test"]
    reqs = [GNNRequest(node_ids=test), GNNRequest(node_ids=test[:5]),
            GNNRequest(node_ids=test[-5:])]
    engine.run(reqs)
    assert all(r.done and r.latency_s is not None for r in reqs)
    np.testing.assert_array_equal(reqs[1].logits, reqs[0].logits[:5])
    # coalesced: each batch ran exactly once despite 3 overlapping requests
    assert engine.stats["batch_runs"] == plan.num_batches
    engine.query(test)                           # pure repeat traffic
    assert engine.stats["batch_runs"] == plan.num_batches
    assert engine.stats["lru_hits"] >= plan.num_batches


def test_engine_run_isolates_bad_requests(tiny_ds, seg_plan):
    """One request with uncovered ids gets `error` set; the rest of the
    coalesced set is still served."""
    cfg = GNNConfig(kind="gcn", in_dim=tiny_ds.feat_dim, hidden=32,
                    out_dim=tiny_ds.num_classes, num_layers=2)
    engine = GNNInferenceEngine(seg_plan, cfg,
                                init_gnn(cfg, jax.random.PRNGKey(0)))
    test = tiny_ds.splits["test"]
    bad_id = int(np.setdiff1d(tiny_ds.splits["train"], test)[0])
    good = GNNRequest(node_ids=test[:4])
    bad = GNNRequest(node_ids=np.array([bad_id]))
    engine.run([bad, good])
    assert good.done and good.logits.shape == (4, tiny_ds.num_classes)
    assert not bad.done and bad.error is not None and bad.logits is None


def test_engine_empty_query_shape(tiny_ds, seg_plan):
    """An empty query returns (0, num_classes), vstack-compatible with
    non-empty results."""
    cfg = GNNConfig(kind="gcn", in_dim=tiny_ds.feat_dim, hidden=32,
                    out_dim=tiny_ds.num_classes, num_layers=2)
    engine = GNNInferenceEngine(seg_plan, cfg,
                                init_gnn(cfg, jax.random.PRNGKey(0)))
    empty = engine.query(np.zeros(0, np.int64))
    assert empty.shape == (0, tiny_ds.num_classes)
    full = engine.query(tiny_ds.splits["test"][:4])
    assert np.vstack([empty, full]).shape == (4, tiny_ds.num_classes)


def test_engine_validates_backend_upfront(tiny_ds, seg_plan):
    """A bcsr engine on a tile-less plan fails at construction, not query."""
    cfg = GNNConfig(kind="gcn", in_dim=tiny_ds.feat_dim, hidden=32,
                    out_dim=tiny_ds.num_classes, num_layers=2)
    params = init_gnn(cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="bcsr"):
        GNNInferenceEngine(seg_plan, cfg, params, backend="bcsr")


# ----------------------------------------------------------------- training
def test_trainer_fit_plan_equals_list(tiny_ds):
    """The Plan path and the legacy list path drive IDENTICAL training:
    same batches, same schedule, same history."""
    pipe = _pipe(tiny_ds)
    cfg = GNNConfig(kind="gcn", in_dim=tiny_ds.feat_dim, hidden=32,
                    out_dim=tiny_ds.num_classes, num_layers=2, dropout=0.0)
    histories = {}
    for name, (tr, va) in {
        "plan": (pipe.plan("train"), pipe.plan("val", for_inference=True)),
        "list": (pipe.preprocess("train"),
                 pipe.preprocess("val", for_inference=True)),
    }.items():
        res = GNNTrainer(cfg, lr=1e-3, seed=0).fit(
            tr, va, tiny_ds.num_classes, epochs=3, schedule_mode="tsp")
        histories[name] = res.history
    for hp, hl in zip(histories["plan"], histories["list"]):
        assert hp["train_loss"] == pytest.approx(hl["train_loss"], abs=1e-6)
        assert hp["val_loss"] == pytest.approx(hl["val_loss"], abs=1e-6)
        assert hp["val_acc"] == pytest.approx(hl["val_acc"], abs=1e-6)


def test_trainer_fit_plan_carries_preprocess_time(tiny_ds):
    pipe = _pipe(tiny_ds)
    plan = pipe.plan("train")
    va = pipe.plan("val", for_inference=True)
    cfg = GNNConfig(kind="gcn", in_dim=tiny_ds.feat_dim, hidden=32,
                    out_dim=tiny_ds.num_classes, num_layers=2)
    res = GNNTrainer(cfg, lr=1e-3).fit(plan, va, tiny_ds.num_classes,
                                       epochs=1, schedule_mode="none")
    assert res.preprocess_time == plan.timings["preprocess/train/train"] > 0


def test_pipeline_timings_keyed_by_mode(tiny_ds):
    """Satellite: preprocessing the SAME split for training and inference
    records two distinct timings (the old key collided)."""
    pipe = _pipe(tiny_ds)
    pipe.preprocess("val")
    pipe.preprocess("val", for_inference=True)
    assert "preprocess/val/train" in pipe.timings
    assert "preprocess/val/inference" in pipe.timings


def test_plan_from_batches_wraps_baseline_batchers(tiny_ds):
    """Any batcher's PaddedBatch list can be frozen into a servable Plan."""
    from repro.graph.sampling import make_batcher
    bt = make_batcher("cluster_gcn", tiny_ds, split="test", num_batches=2)
    plan = Plan.from_batches(bt.epoch_batches(0))
    test = tiny_ds.splits["test"]
    bidx, rows = plan.routing.lookup(test)       # full coverage
    cfg = GNNConfig(kind="gcn", in_dim=tiny_ds.feat_dim, hidden=32,
                    out_dim=tiny_ds.num_classes, num_layers=2)
    engine = GNNInferenceEngine(plan, cfg, init_gnn(cfg, jax.random.PRNGKey(0)))
    assert engine.query(test[:4]).shape == (4, tiny_ds.num_classes)
