"""Checkpointing: roundtrip, async, retention, resume; hypothesis pytrees."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_fallback import given, settings, st

from repro.checkpoint import Checkpointer, save_pytree, load_pytree, latest_step


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.normal(size=(8, 4)).astype(np.float32)),
                   "layers": [{"b": jnp.arange(3.0)},
                              {"b": jnp.arange(3.0) * 2}]},
        "step": jnp.int32(7),
    }


def test_roundtrip(tmp_path):
    tree = _tree()
    save_pytree(tree, str(tmp_path), 5, extra={"lr": 0.1})
    out, manifest = load_pytree(tree, str(tmp_path), 5)
    assert manifest["step"] == 5 and manifest["extra"]["lr"] == 0.1
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_resume(tmp_path):
    c = Checkpointer(str(tmp_path), keep=2)
    assert c.auto_resume(_tree()) is None
    for s in (1, 3, 9):
        c.save(_tree(s), s, blocking=True)
    assert latest_step(str(tmp_path)) == 9
    out, manifest = c.auto_resume(_tree())
    assert manifest["step"] == 9
    # retention: only `keep` newest survive
    steps = sorted(fn for fn in os.listdir(tmp_path) if fn.startswith("step-"))
    assert len(steps) == 2


def test_async_save_does_not_block(tmp_path):
    c = Checkpointer(str(tmp_path))
    big = {"w": jnp.ones((512, 512))}
    t0 = time.time()
    c.save(big, 1)            # async
    async_t = time.time() - t0
    c.wait()
    out, m = c.restore(big)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.ones((512, 512)))


def test_half_written_checkpoint_is_ignored(tmp_path):
    c = Checkpointer(str(tmp_path))
    c.save(_tree(), 4, blocking=True)
    # simulate a crash mid-write of a later checkpoint: dir without manifest
    os.makedirs(tmp_path / "step-00000009")
    assert latest_step(str(tmp_path)) == 4


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), depth=st.integers(1, 3))
def test_roundtrip_property(tmp_path_factory, seed, depth):
    rng = np.random.default_rng(seed)

    def rand_tree(d):
        if d == 0:
            shape = tuple(rng.integers(1, 5, size=rng.integers(1, 3)))
            return jnp.asarray(rng.normal(size=shape).astype(np.float32))
        return {f"k{i}": rand_tree(d - 1) for i in range(rng.integers(1, 3))}

    tree = rand_tree(depth)
    path = str(tmp_path_factory.mktemp("ck"))
    save_pytree(tree, path, 0)
    out, _ = load_pytree(tree, path, 0)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
