"""PrefetchLoader: ordering, prefetch overlap, and worker shutdown — a
consumer that abandons the iterator early must not strand the worker thread
on a full queue (sentinel/Event shutdown)."""
import threading
import time

import numpy as np
import pytest

from repro.data.loader import PrefetchLoader


def _batches(n=6):
    return [dict(x=np.full(4, i, np.float32)) for i in range(n)]


def _wait_dead(t, timeout=10.0):
    deadline = time.time() + timeout
    while t.is_alive() and time.time() < deadline:
        time.sleep(0.01)
    return not t.is_alive()


def test_loader_yields_in_order():
    order = np.array([3, 1, 2])
    got = [int(np.asarray(b["x"])[0]) for b in PrefetchLoader(_batches(), order)]
    assert got == [3, 1, 2]


def test_loader_worker_joins_after_exhaustion():
    loader = PrefetchLoader(_batches(3))
    assert len(list(loader)) == 3
    assert _wait_dead(loader._worker)


def test_loader_early_exit_no_thread_leak():
    """Breaking out of the loop mid-epoch (early stopping, exceptions) must
    terminate the worker; before the Event-based shutdown it stayed blocked
    on q.put forever."""
    loader = PrefetchLoader(_batches(50), prefetch=1)
    for i, _ in enumerate(loader):
        if i == 1:
            break       # abandons the generator → GeneratorExit → finally
    assert _wait_dead(loader._worker), "worker thread leaked after early exit"


def test_loader_early_close_via_gc():
    loader = PrefetchLoader(_batches(50), prefetch=2)
    it = iter(loader)
    next(it)
    it.close()          # explicit generator close, same path as GC
    assert _wait_dead(loader._worker)


def test_loader_worker_error_propagates():
    """A crash inside the worker (bad batch payload, device error) must
    surface in the consumer instead of deadlocking q.get()."""
    bad = _batches(3)
    bad[1] = {"x": object()}          # device_put chokes mid-prefetch
    loader = PrefetchLoader(bad)
    with pytest.raises(Exception):
        list(loader)
    assert _wait_dead(loader._worker)


def test_loader_rejects_out_of_range_order_up_front():
    """An out-of-range order (e.g. a schedule carried over from a different
    plan version, DESIGN.md §10) fails in the CALLER at construction."""
    with pytest.raises(IndexError, match="plan version"):
        PrefetchLoader(_batches(3), order=np.array([0, 99]))


def test_loader_reusable_after_early_exit():
    loader = PrefetchLoader(_batches(4))
    it = iter(loader)
    next(it)
    it.close()
    assert [int(np.asarray(b["x"])[0]) for b in loader] == [0, 1, 2, 3]
