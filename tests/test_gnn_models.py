"""GNN models: shapes, finiteness, and the padding-invariance property."""
import jax
import numpy as np
import pytest

from repro.core import IBMBPipeline, IBMBConfig
from repro.models.gnn import GNNConfig, init_gnn, gnn_apply
from repro.models.gnn.models import output_logits, masked_xent, masked_accuracy


@pytest.fixture(scope="module")
def batch(tiny_ds):
    pipe = IBMBPipeline(tiny_ds, IBMBConfig(
        variant="node", k_per_output=8, max_outputs_per_batch=64,
        pad_multiple=32))
    return pipe.preprocess("train")[0]


@pytest.mark.parametrize("kind", ["gcn", "gat", "sage"])
def test_forward_shapes_finite(tiny_ds, batch, kind):
    cfg = GNNConfig(kind=kind, in_dim=tiny_ds.feat_dim, hidden=64,
                    out_dim=tiny_ds.num_classes, num_layers=3)
    params = init_gnn(cfg, jax.random.PRNGKey(0))
    b = batch.device_arrays()
    logits = output_logits(gnn_apply(cfg, params, b), b)
    assert logits.shape == (batch.output_idx.shape[0], tiny_ds.num_classes)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("kind", ["gcn", "gat", "sage"])
def test_padding_invariance(tiny_ds, batch, kind):
    """Doubling the padding must not change real-node outputs — the masked
    formulation is exact, not approximate."""
    cfg = GNNConfig(kind=kind, in_dim=tiny_ds.feat_dim, hidden=32,
                    out_dim=tiny_ds.num_classes, num_layers=2)
    params = init_gnn(cfg, jax.random.PRNGKey(1))
    b = batch.device_arrays()
    out1 = np.asarray(gnn_apply(cfg, params, b))

    # re-pad: append extra zero nodes/edges
    extra_n, extra_e = 32, 64
    b2 = dict(b)
    f = b["features"]
    b2["features"] = np.concatenate(
        [np.asarray(f), np.zeros((extra_n, f.shape[1]), np.float32)])
    b2["node_mask"] = np.concatenate(
        [np.asarray(b["node_mask"]), np.zeros(extra_n, np.float32)])
    b2["edge_src"] = np.concatenate(
        [np.asarray(b["edge_src"]), np.zeros(extra_e, np.int32)])
    b2["edge_dst"] = np.concatenate(
        [np.asarray(b["edge_dst"]), np.zeros(extra_e, np.int32)])
    b2["edge_weight"] = np.concatenate(
        [np.asarray(b["edge_weight"]), np.zeros(extra_e, np.float32)])
    out2 = np.asarray(gnn_apply(cfg, params, b2))
    n = out1.shape[0]
    np.testing.assert_allclose(out1, out2[:n], rtol=1e-5, atol=1e-5)


def test_losses_and_metrics(tiny_ds, batch):
    cfg = GNNConfig(kind="gcn", in_dim=tiny_ds.feat_dim, hidden=32,
                    out_dim=tiny_ds.num_classes, num_layers=2)
    params = init_gnn(cfg, jax.random.PRNGKey(0))
    b = batch.device_arrays()
    logits = output_logits(gnn_apply(cfg, params, b), b)
    loss = masked_xent(logits, b["labels"], b["output_mask"])
    acc = masked_accuracy(logits, b["labels"], b["output_mask"])
    assert np.isfinite(float(loss)) and 0 <= float(acc) <= 1
    # loss at init should be close to ln(num_classes)
    assert abs(float(loss) - np.log(tiny_ds.num_classes)) < 1.0
