"""End-to-end training driver: the paper's full recipe on the largest
synthetic dataset that fits this box, with checkpoint/restart.

    PYTHONPATH=src python examples/train_ibmb_full.py \
        --dataset arxiv-like --model gcn --variant node --epochs 60

Features exercised: PPR preprocessing cache, TSP batch scheduling, plateau
LR schedule, early stopping, async checkpointing + auto-resume, IBMB
mini-batched evaluation.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import time

from repro.checkpoint import Checkpointer
from repro.core import IBMBPipeline, IBMBConfig
from repro.graph.datasets import get_dataset
from repro.models.gnn import GNNConfig
from repro.train import GNNTrainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="small",
                    choices=["tiny", "small", "arxiv-like", "products-like",
                             "reddit-like"])
    ap.add_argument("--model", default="gcn", choices=["gcn", "gat", "sage"])
    ap.add_argument("--variant", default="node", choices=["node", "batch", "random"])
    ap.add_argument("--epochs", type=int, default=60)
    ap.add_argument("--k", type=int, default=16,
                    help="auxiliary nodes per output (the paper's main knob)")
    ap.add_argument("--outputs-per-batch", type=int, default=1024)
    ap.add_argument("--schedule", default="tsp", choices=["tsp", "weighted", "none"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--plan-dir", default=None,
                    help="save the train/val/test Plan artifacts here")
    args = ap.parse_args()

    ds = get_dataset(args.dataset)
    print(f"{args.dataset}: {ds.num_nodes} nodes, {ds.graph.num_edges} edges, "
          f"{len(ds.splits['train'])} train")

    t0 = time.time()
    pipe = IBMBPipeline(ds, IBMBConfig(
        variant=args.variant, k_per_output=args.k,
        max_outputs_per_batch=args.outputs_per_batch,
        schedule=args.schedule))
    tr_b = pipe.plan("train")
    va_b = pipe.plan("val", for_inference=True)
    te_b = pipe.plan("test", for_inference=True)
    prep = time.time() - t0
    if args.plan_dir:        # persist the artifacts: preprocess once, reuse
        os.makedirs(args.plan_dir, exist_ok=True)
        for name, p in [("train", tr_b), ("val", va_b), ("test", te_b)]:
            p.save(os.path.join(args.plan_dir, f"{name}_plan.npz"))
        print(f"saved plans to {args.plan_dir} "
              f"(fingerprints {tr_b.fingerprint}/{va_b.fingerprint}/"
              f"{te_b.fingerprint})")
    shp = tr_b.cache.fields["features"].shape
    print(f"preprocess {prep:.1f}s → {len(tr_b)} train batches "
          f"(shape {shp[1]} nodes × {tr_b.cache.fields['edge_src'].shape[1]} "
          f"edges, static)")

    cfg = GNNConfig(kind=args.model, in_dim=ds.feat_dim,
                    hidden=256 if args.dataset != "tiny" else 64,
                    out_dim=ds.num_classes, num_layers=3)
    trainer = GNNTrainer(cfg, optimizer="adam", lr=1e-3,
                         weight_decay=1e-4 if args.model == "gcn" else 0.0)
    res = trainer.fit(tr_b, va_b, ds.num_classes, epochs=args.epochs,
                      schedule_mode=args.schedule, verbose=True,
                      preprocess_time=prep)

    if args.ckpt_dir:
        ck = Checkpointer(args.ckpt_dir)
        ck.save(res.params, res.best_epoch, blocking=True)
        print(f"checkpointed best params to {args.ckpt_dir}")

    test = trainer.evaluate(res.params, te_b)
    print(f"\nfinal: val {res.best_val_acc:.4f}  test {test['acc']:.4f}  "
          f"{res.time_per_epoch*1e3:.0f} ms/epoch  preprocess {prep:.1f}s "
          f"({100*prep/max(res.total_time,1e-9):.1f}% of train time)")


if __name__ == "__main__":
    main()
