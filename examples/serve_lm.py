"""Batched LM serving demo: continuous batching over the compiled decode
step (any of the 10 assigned architectures, reduced config on CPU).

    PYTHONPATH=src python examples/serve_lm.py --arch qwen2-1.5b --requests 6
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse

import jax
import numpy as np

from repro.configs import get_smoke_config, ARCH_IDS
from repro.models.lm import init_params
from repro.serve import ServeEngine
from repro.serve.engine import Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=ARCH_IDS)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--new-tokens", type=int, default=8)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    if cfg.num_codebooks > 1:
        print(f"{args.arch} is multi-codebook; serving demo uses text-style "
              "archs — pick another --arch")
        return
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, num_slots=args.slots, max_len=256)

    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, 6).astype(np.int32),
                    max_new_tokens=args.new_tokens)
            for _ in range(args.requests)]
    stats = eng.run(reqs)
    print(f"{args.arch} ({cfg.name}): {stats['completed']}/{len(reqs)} requests "
          f"in {stats['steps']} decode steps, {stats['time_s']:.2f}s "
          f"({args.slots} slots, continuous batching)")
    for i, r in enumerate(reqs[:3]):
        print(f"  req{i}: prompt {r.prompt.tolist()} → {r.out_tokens}")


if __name__ == "__main__":
    main()
