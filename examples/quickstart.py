"""Quickstart: influence-based mini-batching end to end in ~1 minute on CPU.

    PYTHONPATH=src python examples/quickstart.py

1. Build a synthetic homophilic graph (ogbn-arxiv stand-in, 400 nodes).
2. IBMB preprocessing: PPR influence scores → output-node partitioning →
   auxiliary-node selection → padded, contiguously-cached batches.
3. Train a GCN with the paper's recipe (Adam + plateau LR + TSP batch order).
4. Run IBMB inference on the test split.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time
import numpy as np

from repro.graph.datasets import get_dataset
from repro.core import IBMBPipeline, IBMBConfig
from repro.models.gnn import GNNConfig
from repro.train import GNNTrainer


def main():
    ds = get_dataset("tiny")
    print(f"graph: {ds.num_nodes} nodes, {ds.graph.num_edges} edges, "
          f"{ds.num_classes} classes, {len(ds.splits['train'])} train nodes")

    # -- IBMB preprocessing (node-wise variant) ---------------------------
    t0 = time.time()
    pipe = IBMBPipeline(ds, IBMBConfig(
        variant="node", k_per_output=8, max_outputs_per_batch=64,
        pad_multiple=32, schedule="tsp"))
    train_batches = pipe.preprocess("train")
    val_batches = pipe.preprocess("val", for_inference=True)
    test_batches = pipe.preprocess("test", for_inference=True)
    cache = pipe.build_cache(train_batches)
    print(f"preprocessing: {time.time()-t0:.2f}s → {len(train_batches)} "
          f"batches, cache {cache.nbytes()/1e6:.1f} MB (contiguous)")

    # -- training (paper recipe) ------------------------------------------
    cfg = GNNConfig(kind="gcn", in_dim=ds.feat_dim, hidden=64,
                    out_dim=ds.num_classes, num_layers=3)
    trainer = GNNTrainer(cfg, optimizer="adam", lr=1e-3)
    res = trainer.fit(train_batches, val_batches, ds.num_classes,
                      epochs=40, schedule_mode="tsp", verbose=False)
    print(f"training: best val acc {res.best_val_acc:.3f} "
          f"(epoch {res.best_epoch}), {res.time_per_epoch*1e3:.0f} ms/epoch")

    # -- IBMB inference -----------------------------------------------------
    t0 = time.time()
    test = trainer.evaluate(res.params,
                            [b.device_arrays() for b in test_batches])
    print(f"inference: test acc {test['acc']:.3f} in {time.time()-t0:.2f}s "
          f"({len(test_batches)} batches)")


if __name__ == "__main__":
    main()
