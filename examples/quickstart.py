"""Quickstart: influence-based mini-batching end to end in ~1 minute on CPU.

    PYTHONPATH=src python examples/quickstart.py

1. Build a synthetic homophilic graph (ogbn-arxiv stand-in, 400 nodes).
2. IBMB preprocessing → a frozen `Plan` artifact (DESIGN.md §8): PPR
   influence → output-node partitioning → auxiliary selection → padded,
   contiguously-cached batches + schedule + routing index + fingerprint.
3. `Plan.save` / `IBMBPipeline.load_plan`: preprocess once, reuse across
   models/seeds/processes — the paper's amortization, as an artifact.
4. Train a GCN with the paper's recipe (Adam + plateau LR + TSP batch order)
   straight from the plan.
5. Serve per-node requests from the loaded plan with `GNNInferenceEngine`.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import tempfile
import time
import numpy as np

from repro.graph.datasets import get_dataset
from repro.core import IBMBPipeline, IBMBConfig
from repro.models.gnn import GNNConfig
from repro.serve import GNNInferenceEngine
from repro.train import GNNTrainer


def main():
    ds = get_dataset("tiny")
    print(f"graph: {ds.num_nodes} nodes, {ds.graph.num_edges} edges, "
          f"{ds.num_classes} classes, {len(ds.splits['train'])} train nodes")

    # -- IBMB preprocessing → frozen Plan artifacts -----------------------
    t0 = time.time()
    pipe = IBMBPipeline(ds, IBMBConfig(
        variant="node", k_per_output=8, max_outputs_per_batch=64,
        pad_multiple=32, schedule="tsp"))
    train_plan = pipe.plan("train")
    val_plan = pipe.plan("val", for_inference=True)
    test_plan = pipe.plan("test", for_inference=True)
    print(f"preprocessing: {time.time()-t0:.2f}s → {train_plan.num_batches} "
          f"train batches, plan {train_plan.nbytes()/1e6:.1f} MB "
          f"(contiguous cache + schedule + routing index)")

    # -- save / load: compute once, reuse everywhere ----------------------
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "test_plan.npz")
        test_plan.save(path)
        test_plan = pipe.load_plan(path, "test", for_inference=True)
    print(f"plan round-trip: saved+loaded test_plan.npz "
          f"(fingerprint {test_plan.fingerprint})")

    # -- training (paper recipe), straight from the plan ------------------
    cfg = GNNConfig(kind="gcn", in_dim=ds.feat_dim, hidden=64,
                    out_dim=ds.num_classes, num_layers=3)
    trainer = GNNTrainer(cfg, optimizer="adam", lr=1e-3)
    res = trainer.fit(train_plan, val_plan, ds.num_classes,
                      epochs=40, schedule_mode="tsp", verbose=False)
    print(f"training: best val acc {res.best_val_acc:.3f} "
          f"(epoch {res.best_epoch}), {res.time_per_epoch*1e3:.0f} ms/epoch")

    # -- batch-eval IBMB inference ----------------------------------------
    t0 = time.time()
    test = trainer.evaluate(res.params, test_plan)
    print(f"inference: test acc {test['acc']:.3f} in {time.time()-t0:.2f}s "
          f"({test_plan.num_batches} batches)")

    # -- request-level serving from the loaded artifact -------------------
    engine = GNNInferenceEngine(test_plan, cfg, res.params)
    query = np.random.default_rng(0).choice(ds.splits["test"], size=16,
                                            replace=False)
    t0 = time.time()
    logits = engine.query(query)
    print(f"serving: {len(query)}-node query → logits {logits.shape} in "
          f"{(time.time()-t0)*1e3:.1f} ms "
          f"({engine.stats['batch_runs']} batch forwards)")


if __name__ == "__main__":
    main()
