"""Zero-downtime serving on a living graph (DESIGN.md §10).

    PYTHONPATH=src python examples/dynamic_graph.py [--dataset tiny]

The dynamic-graphs loop end to end:

1. Preprocess ONCE → versioned ``Plan`` (v0), train a GCN, bring up a
   ``GNNInferenceEngine`` and serve requests.
2. The graph changes: a ``GraphDelta`` records feature drift + edge edits.
3. ``pipeline.refresh(plan, delta)`` emits plan v1 — only the batches the
   delta dirtied are rebuilt (incremental delta-PPR push decides); the
   ``PlanDelta`` audit says exactly what was rebuilt / patched / untouched.
4. ``engine.swap(v1, audit)`` hot-swaps between requests: untouched batches
   keep serving from the LRU, and the per-version stats prove traffic never
   stopped.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import time
import numpy as np

from repro.core import GraphDelta, IBMBConfig, IBMBPipeline, check_routing
from repro.models.gnn import GNNConfig
from repro.serve import GNNInferenceEngine
from repro.train import GNNTrainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="tiny",
                    choices=["tiny", "small", "arxiv-like"])
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--epochs", type=int, default=20)
    args = ap.parse_args()

    from repro.graph.datasets import get_dataset
    ds = get_dataset(args.dataset)
    test = ds.splits["test"]

    # -- v0: plan once, train once, serve -------------------------------
    pipe = IBMBPipeline(ds, IBMBConfig(
        variant="node", k_per_output=8, max_outputs_per_batch=16,
        pad_multiple=32))
    plan = pipe.plan("test", for_inference=True)
    check_routing(plan)
    print(f"v0: {plan.num_batches} batches, fingerprint {plan.fingerprint}")

    cfg = GNNConfig(kind="gcn", in_dim=ds.feat_dim, hidden=64,
                    out_dim=ds.num_classes, num_layers=3)
    trainer = GNNTrainer(cfg, lr=1e-3)
    res = trainer.fit(pipe.plan("train"), pipe.plan("val", for_inference=True),
                      ds.num_classes, epochs=args.epochs)
    engine = GNNInferenceEngine(plan, cfg, res.params,
                                cache_batches=plan.num_batches)
    rng = np.random.default_rng(0)
    for _ in range(args.requests):
        engine.query(rng.choice(test, size=min(8, len(test)), replace=False))
    runs_v0 = engine.stats["batch_runs"]
    print(f"v0: served {args.requests} requests with {runs_v0} batch "
          f"forwards ({engine.stats['lru_hits']} LRU hits)")

    # -- the graph changes: payload drift on one batch's private nodes ---
    # (the steady-state dynamic case — features move, topology holds; an
    # edge edit would instead re-derive influence scores incrementally)
    others = set()
    for i in range(1, plan.num_batches):
        m = plan.node_ids[i]
        others |= set(m[m >= 0].tolist())
    m0 = plan.node_ids[0]
    upd = np.array(sorted(set(m0[m0 >= 0].tolist()) - others)[:8])
    delta = GraphDelta(
        feat_nodes=upd,
        feat_values=ds.features[upd]
        + rng.normal(0, 1, (len(upd), ds.feat_dim)).astype(np.float32))
    print(f"\ndelta: {delta.summary()}")

    t0 = time.time()
    child, audit = pipe.refresh(plan, delta)
    print(f"refresh → v{child.version} in {time.time()-t0:.2f}s: "
          f"{audit.summary()}")
    check_routing(child)
    assert child.parent == plan.fingerprint

    # -- zero-downtime hot swap ------------------------------------------
    swap = engine.swap(child, audit)
    print(f"swap: invalidated {swap['invalidated']} LRU entries, "
          f"kept {swap['kept']} serving")
    for _ in range(args.requests):
        engine.query(rng.choice(test, size=min(8, len(test)), replace=False))
    new_runs = engine.stats["batch_runs"] - runs_v0
    assert new_runs <= len(audit.dirty), \
        f"untouched batches re-ran after swap ({new_runs} runs)"
    print(f"v1: served {args.requests} more requests with only {new_runs} "
          f"new batch forwards (dirty set was {len(audit.dirty)})")
    for v, s in sorted(engine.stats["versions"].items()):
        print(f"  version {v}: requests={s['requests']} "
              f"lru_hits={s['lru_hits']} batch_runs={s['batch_runs']} "
              f"hit_rate={s['hit_rate']:.2f}")
    print(f"swap_count={engine.stats['swap_count']} "
          f"evictions={engine.stats['evictions']}")
    print("\nOK: traffic never stopped across the plan swap")


if __name__ == "__main__":
    main()
