"""Serving under load: the async micro-batching tier (DESIGN.md §11).

    PYTHONPATH=src python examples/serve_async.py [--dataset tiny]

What §8's engine does for one coalesced `run` call, `AsyncGNNEngine` does
for a live concurrent stream:

1. Stand up TWO tenants (two (plan, params) models — here the same plan
   family with independently trained weights) behind one bounded queue and
   one dispatch worker.
2. Fire a Zipf-popular burst of per-node requests from several client
   threads. Requests coalesce into micro-batching windows: dispatch when a
   full batch's worth of routed rows accumulates or the window elapses.
3. Show admission control: a request with an infeasible deadline is
   rejected on arrival instead of timing out in the queue.
4. Hot-swap tenant "a" onto a refreshed plan (§10 version chain) MID-STREAM
   — nobody's queue drains, tenant "b" never notices.
5. Print the `ServeStats` surface: throughput, windows, occupancy,
   p50/p95/p99, and the per-tenant engine counters.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import threading
import time

import jax
import numpy as np

from repro.core import IBMBPipeline, IBMBConfig
from repro.core.update import GraphDelta
from repro.graph.datasets import get_dataset
from repro.models.gnn import GNNConfig
from repro.serve import AsyncGNNEngine, AsyncServeConfig, GNNInferenceEngine
from repro.train import GNNTrainer


def zipf_queries(rng, nodes, n, size, exponent=1.1):
    ranks = np.arange(1, len(nodes) + 1, dtype=np.float64)
    p = ranks ** -exponent
    p /= p.sum()
    pop = rng.permutation(nodes)
    return [rng.choice(pop, size=size, replace=False, p=p) for _ in range(n)]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="tiny",
                    choices=["tiny", "small", "arxiv-like"])
    ap.add_argument("--requests", type=int, default=120,
                    help="requests per client thread")
    ap.add_argument("--clients", type=int, default=3)
    ap.add_argument("--request-size", type=int, default=4)
    ap.add_argument("--epochs", type=int, default=20)
    args = ap.parse_args()

    ds = get_dataset(args.dataset)
    pipe = IBMBPipeline(ds, IBMBConfig(
        variant="node", k_per_output=8, max_outputs_per_batch=32,
        pad_multiple=16))
    plan = pipe.plan("test", for_inference=True)
    cfg = GNNConfig(kind="gcn", in_dim=ds.feat_dim, hidden=32,
                    out_dim=ds.num_classes, num_layers=2)
    trainer = GNNTrainer(cfg, lr=1e-3)
    train_plan = pipe.plan("train")
    val_plan = pipe.plan("val", for_inference=True)
    tenants = {}
    for name, seed in [("a", 0), ("b", 1)]:
        res = trainer.fit(train_plan, val_plan, ds.num_classes,
                          epochs=args.epochs,
                          rng=jax.random.PRNGKey(seed))
        tenants[name] = GNNInferenceEngine(plan, cfg, res.params,
                                           cache_batches=max(1, len(plan)))
        print(f"tenant {name!r}: trained (val acc {res.best_val_acc:.3f})")

    config = AsyncServeConfig(window_us=2000.0, max_queue=10_000)
    with AsyncGNNEngine(tenants, config) as tier:
        # admission control: an impossible deadline is refused at the door
        doomed = tier.submit("a", plan.routing.node_ids[:2], deadline_ms=0.01)
        print(f"\nadmission: deadline 0.01ms → "
              f"{'rejected on arrival' if doomed.rejected else 'accepted?!'}")

        nodes = plan.routing.node_ids
        size = min(args.request_size, len(nodes))
        tier.submit("a", nodes[:size]).result(timeout=120)   # compile
        tier.submit("b", nodes[:size]).result(timeout=120)

        futs, lock = [], threading.Lock()

        def client(seed, tenant):
            rng = np.random.default_rng(seed)
            mine = [tier.submit(tenant, q) for q in zipf_queries(
                rng, nodes, args.requests, size)]
            with lock:
                futs.extend(mine)

        t0 = time.perf_counter()
        threads = [threading.Thread(target=client,
                                    args=(s, "ab"[s % 2]))
                   for s in range(args.clients)]
        for t in threads:
            t.start()
        # mid-stream: refresh + hot-swap tenant "a" while clients submit
        delta_nodes = np.random.default_rng(9).choice(
            nodes, size=4, replace=False).astype(np.int64)
        child, audit = pipe.refresh(plan, GraphDelta(
            feat_nodes=delta_nodes,
            feat_values=ds.features[delta_nodes] + 0.25))
        res = tier.swap("a", child, audit)
        for t in threads:
            t.join()
        for f in futs:
            f.result(timeout=120)
        wall = time.perf_counter() - t0

        snap = tier.snapshot()
        n = len(futs)
        print(f"\nswap('a') mid-stream: plan v{child.version}, "
              f"{res['invalidated']} LRU entries invalidated, "
              f"{res['kept']} kept — tenant 'b' untouched "
              f"(swaps: a={snap['tenants']['a']['swaps']}, "
              f"b={snap['tenants']['b']['swaps']})")
        print(f"\n{n} requests from {args.clients} clients in {wall:.2f}s "
              f"({n / wall:.0f} req/s)")
        print(f"  windows {snap['windows']} "
              f"(mean {snap['mean_window_requests']:.1f} requests/window, "
              f"last occupancy {snap['window_occupancy']:.2f})")
        print(f"  latency p50 {snap['p50_us']:.0f} us   "
              f"p95 {snap['p95_us']:.0f} us   p99 {snap['p99_us']:.0f} us")
        for name in ("a", "b"):
            e = snap["tenants"][name]["engine"]
            print(f"  tenant {name!r}: {e['requests']} requests → "
                  f"{e['batch_runs']} batch forwards + {e['lru_hits']} LRU "
                  f"hits, versions served {sorted(e['versions'])}")


if __name__ == "__main__":
    main()
