"""Paper Fig. 2 in miniature: one pretrained GCN, every mini-batching method
evaluated on the same weights — accuracy vs inference wall time.

    PYTHONPATH=src python examples/inference_comparison.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time

from repro.core import IBMBPipeline, IBMBConfig
from repro.graph.datasets import get_dataset
from repro.graph.sampling import make_batcher
from repro.models.gnn import GNNConfig
from repro.train import GNNTrainer


def main():
    ds = get_dataset("small")
    pipe = IBMBPipeline(ds, IBMBConfig(variant="node", k_per_output=8,
                                       max_outputs_per_batch=256))
    trainer = GNNTrainer(GNNConfig(kind="gcn", in_dim=ds.feat_dim, hidden=64,
                                   out_dim=ds.num_classes, num_layers=3),
                         lr=1e-3)
    res = trainer.fit(pipe.plan("train"),
                      pipe.plan("val", for_inference=True),
                      ds.num_classes, epochs=25)
    print(f"pretrained GCN: val acc {res.best_val_acc:.3f}\n")
    print(f"{'method':22s} {'test acc':>9s} {'time (s)':>9s}")

    def bench(name, batches):                    # Plan or raw batch list
        t0 = time.time()
        m = trainer.evaluate(res.params, batches)
        print(f"{name:22s} {m['acc']:9.3f} {time.time()-t0:9.2f}")

    bench("ibmb_node", pipe.plan("test", for_inference=True))
    pipe_b = IBMBPipeline(ds, IBMBConfig(variant="batch", num_batches=8,
                                         max_outputs_per_batch=256))
    bench("ibmb_batch", pipe_b.plan("test", for_inference=True))
    for name, kw in [("cluster_gcn", {"num_batches": 8}),
                     ("neighbor_sampling", {"num_batches": 8}),
                     ("graphsaint_rw", {"num_steps": 8, "batch_roots": 400}),
                     ("shadow_ppr", {"outputs_per_batch": 256}),
                     ("full_batch", {})]:
        bench(name, make_batcher(name, ds, split="test", **kw).epoch_batches(0))


if __name__ == "__main__":
    main()
