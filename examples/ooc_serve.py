"""Serving a plan bigger than the memory you give it (DESIGN.md §13).

    PYTHONPATH=src python examples/ooc_serve.py [--dataset small]

The out-of-core story end to end:

1. Stream-preprocess: `pipe.plan(..., out_of_core=True)` builds batches
   chunk by chunk straight into an on-disk `PlanStore` — peak host memory
   is one chunk, not the payload, and the fingerprint is bitwise-identical
   to the resident build.
2. Reopen the store O(metadata) and serve through `GNNInferenceEngine`
   with a bounded resident-batch LRU: only routed batches fault in from
   disk (checksum-verified per read), evicting under the budget.
3. Shard the same split into self-contained per-host stores with a
   fingerprint-chained manifest; `ShardRouter` fans queries out to owning
   shards and merges — still bitwise equal to the monolithic engine.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import tempfile
import time
import numpy as np
import jax

from repro.core import IBMBPipeline, IBMBConfig
from repro.graph.datasets import get_dataset
from repro.models.gnn import GNNConfig, init_gnn
from repro.ooc import OOCConfig, PlanStore, ShardRouter, build_shards
from repro.serve import GNNInferenceEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="small",
                    choices=["tiny", "small", "arxiv-like"])
    ap.add_argument("--requests", type=int, default=50)
    ap.add_argument("--request-size", type=int, default=16)
    ap.add_argument("--resident-batches", type=int, default=4)
    ap.add_argument("--shards", type=int, default=3)
    args = ap.parse_args()

    ds = get_dataset(args.dataset)
    pipe = IBMBPipeline(ds, IBMBConfig(
        variant="node", k_per_output=8, max_outputs_per_batch=64,
        pad_multiple=32))
    tmpdir = tempfile.TemporaryDirectory()      # cleaned up at interpreter exit
    store_dir = os.path.join(tmpdir.name, "test_store")

    # -- offline: stream the build, chunk by chunk, onto disk -------------
    ooc = OOCConfig(chunk_batches=2, resident_batches=args.resident_batches)
    t0 = time.time()
    pipe.plan("test", for_inference=True, out_of_core=True,
              store_dir=store_dir, ooc=ooc)
    store = PlanStore.open(store_dir)           # O(metadata) reopen
    print(f"offline: streamed {store.num_batches} batches "
          f"({store.payload_nbytes()/1e6:.1f} MB payload) to disk in "
          f"{time.time()-t0:.2f}s, never holding more than "
          f"{ooc.chunk_batches} batches in RAM "
          f"(fingerprint {store.fingerprint})")

    mcfg = GNNConfig(kind="gcn", in_dim=ds.feat_dim, hidden=64,
                     out_dim=ds.num_classes, num_layers=3)
    params = init_gnn(mcfg, jax.random.PRNGKey(0))

    # -- online: lazy engine under a resident-batch budget ----------------
    plan = store.as_plan(resident_batches=args.resident_batches)
    engine = GNNInferenceEngine(plan, mcfg, params)
    rng = np.random.default_rng(0)
    test = ds.splits["test"]
    size = min(args.request_size, len(test))
    ref = engine.query(test[:size])              # compile outside the timing
    lat_us = []
    for _ in range(args.requests):
        q = rng.choice(test, size=size, replace=False)
        t0 = time.perf_counter()
        engine.query(q)
        lat_us.append((time.perf_counter() - t0) * 1e6)
    s = plan.cache.snapshot()
    print(f"\nserved {args.requests} requests "
          f"(p50 {np.percentile(lat_us, 50):.0f} us): "
          f"{s['loads']} disk loads, {s['hits']} cache hits, "
          f"{s['evictions']} evictions — resident {s['resident']} "
          f"batches / {s['resident_bytes']/1e6:.1f} MB of "
          f"{store.payload_nbytes()/1e6:.1f} MB payload")

    # -- sharded: one self-contained store per host, routed queries -------
    root = os.path.join(tmpdir.name, "shards")
    num_shards = min(args.shards, store.num_batches)  # tiny split → 1 batch
    build_shards(pipe, "test", num_shards, root, for_inference=True, ooc=ooc)
    router = ShardRouter.load(root, mcfg, params,
                              resident_batches=args.resident_batches)
    q = test[:size]
    routed = router.query(q)
    print(f"\nsharded into {num_shards} stores: query of {size} nodes hit "
          f"{router.shards_hit(q)} shard(s), logits bitwise equal to "
          f"the monolithic engine: "
          f"{bool(np.array_equal(routed, ref))}")


if __name__ == "__main__":
    main()
