"""Request-level GNN serving from a frozen Plan artifact (DESIGN.md §8).

    PYTHONPATH=src python examples/serve_gnn.py [--dataset tiny]

The paper's serving story end to end:

1. Preprocess ONCE → `Plan` (batches + schedule + routing index), saved to
   disk.
2. Train a GCN from the same plan family (preprocessing is shared across
   models/seeds — the paper's amortization).
3. `Plan.load` in a "server": no re-preprocessing on the request path.
4. Stream per-node requests through `GNNInferenceEngine`: routing index →
   coalesced batch forwards → LRU for repeat traffic. Prints request-latency
   percentiles and the coalescing/caching counters.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import tempfile
import time
import numpy as np

from repro.core import IBMBPipeline, IBMBConfig, Plan
from repro.graph.datasets import get_dataset
from repro.models.gnn import GNNConfig
from repro.serve import GNNInferenceEngine, GNNRequest
from repro.train import GNNTrainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="tiny",
                    choices=["tiny", "small", "arxiv-like"])
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--request-size", type=int, default=16)
    ap.add_argument("--epochs", type=int, default=30)
    args = ap.parse_args()

    ds = get_dataset(args.dataset)

    # -- offline: preprocess once, save the artifact ----------------------
    pipe = IBMBPipeline(ds, IBMBConfig(
        variant="node", k_per_output=8, max_outputs_per_batch=64,
        pad_multiple=32))
    t0 = time.time()
    test_plan = pipe.plan("test", for_inference=True)
    tmpdir = tempfile.TemporaryDirectory()      # cleaned up at interpreter exit
    path = os.path.join(tmpdir.name, "test_plan.npz")
    test_plan.save(path)
    print(f"offline: preprocessed + saved plan in {time.time()-t0:.2f}s "
          f"({test_plan.num_batches} batches, {test_plan.nbytes()/1e6:.1f} MB, "
          f"fingerprint {test_plan.fingerprint})")

    cfg = GNNConfig(kind="gcn", in_dim=ds.feat_dim, hidden=64,
                    out_dim=ds.num_classes, num_layers=3)
    trainer = GNNTrainer(cfg, lr=1e-3)
    res = trainer.fit(pipe.plan("train"), pipe.plan("val", for_inference=True),
                      ds.num_classes, epochs=args.epochs)
    print(f"offline: trained GCN, val acc {res.best_val_acc:.3f}")

    # -- online: load the artifact, serve queries -------------------------
    t0 = time.time()
    plan = pipe.load_plan(path, "test", for_inference=True)
    engine = GNNInferenceEngine(plan, cfg, res.params, cache_batches=4)
    print(f"online: plan loaded + engine up in {time.time()-t0:.2f}s "
          f"(no re-preprocessing)")

    rng = np.random.default_rng(0)
    test = ds.splits["test"]
    size = min(args.request_size, len(test))
    engine.query(test[:size])                    # compile outside the timing
    lat_us = []
    for _ in range(args.requests):
        q = rng.choice(test, size=size, replace=False)
        t0 = time.perf_counter()
        engine.query(q)
        lat_us.append((time.perf_counter() - t0) * 1e6)
    p50, p95, p99 = (np.percentile(lat_us, p) for p in (50, 95, 99))
    print(f"\nserved {args.requests} sequential requests of {size} nodes:")
    print(f"  latency p50 {p50:.0f} us   p95 {p95:.0f} us   p99 {p99:.0f} us")
    s = engine.stats
    print(f"  {s['batch_runs']} batch forwards for {s['requests']} requests "
          f"({s['lru_hits']} LRU hits) — repeat traffic never re-runs a batch")

    # concurrent burst: coalescing shares one forward per batch
    burst = [GNNRequest(node_ids=rng.choice(test, size=size, replace=False))
             for _ in range(32)]
    runs_before = engine.stats["batch_runs"]
    stats = engine.run(burst)
    lat = [r.latency_s * 1e6 for r in burst]
    print(f"\ncoalesced burst of {len(burst)} concurrent requests: "
          f"{engine.stats['batch_runs'] - runs_before} new batch forwards, "
          f"completed in {stats['time_s']*1e3:.1f} ms "
          f"(p95 request latency {np.percentile(lat, 95):.0f} us)")


if __name__ == "__main__":
    main()
