#!/usr/bin/env python
"""Docs drift check: every DESIGN.md section reference cited in a source
docstring (the `DESIGN.md` name followed by a `§` section token) must name
a section that actually exists in DESIGN.md, and the DESIGN.md §12
fault-point table must match the canonical registry
`repro.faults.FAULT_POINTS` in both directions (DESIGN.md §15).

Usage: python tools/check_docs_refs.py [repo_root]
Exits nonzero listing any dangling references or fault-table drift.
"""
import os
import re
import sys

# "DESIGN.md §3", "see DESIGN.md §Arch-applicability", "(DESIGN.md §6):"
_REF = re.compile(r"DESIGN\.md\s+§([\w-]+)")


def cited_sections(root):
    refs = {}
    for dirpath, _dirs, files in os.walk(root):
        rel = os.path.relpath(dirpath, root)
        if rel != "." and any(part.startswith(".") or part == "__pycache__"
                              for part in rel.split(os.sep)):
            continue
        for fn in files:
            if not fn.endswith(".py"):
                continue
            path = os.path.join(dirpath, fn)
            with open(path, encoding="utf-8") as f:
                text = f.read()
            for m in _REF.finditer(text):
                refs.setdefault(m.group(1), []).append(
                    os.path.relpath(path, root))
    return refs


def defined_sections(design_path):
    with open(design_path, encoding="utf-8") as f:
        text = f.read()
    return set(re.findall(r"^#+\s*§([\w-]+)", text, flags=re.MULTILINE))


def fault_table_drift(root):
    """Registry-vs-§12-table mismatches, reusing the analyzer's static
    parsers (repro.analysis never imports repo code, so neither do we)."""
    sys.path.insert(0, os.path.join(root, "src"))
    try:
        from repro.analysis.fault_points import (design_table_points,
                                                 registry_from_source)
    finally:
        sys.path.pop(0)
    faults_py = os.path.join(root, "src", "repro", "faults.py")
    if not os.path.exists(faults_py):
        return [f"faults module missing at {faults_py}"]
    with open(faults_py, encoding="utf-8") as f:
        registry = registry_from_source(f.read())
    if registry is None:
        return ["no FAULT_POINTS literal dict in src/repro/faults.py"]
    with open(os.path.join(root, "DESIGN.md"), encoding="utf-8") as f:
        table = design_table_points(f.read())
    if table is None:
        return ["DESIGN.md has no §12 fault-point table"]
    errors = []
    for point in sorted(set(registry) - table):
        errors.append(f"fault point `{point}` registered in FAULT_POINTS "
                      f"but missing from the DESIGN.md §12 table")
    for point in sorted(table - set(registry)):
        errors.append(f"DESIGN.md §12 table row `{point}` is not in "
                      f"repro.faults.FAULT_POINTS")
    return errors


def check(root):
    design = os.path.join(root, "DESIGN.md")
    if not os.path.exists(design):
        return [f"DESIGN.md missing at {design}"]
    have = defined_sections(design)
    errors = []
    for section, files in sorted(cited_sections(root).items()):
        if section not in have:
            errors.append(
                f"DESIGN.md §{section} cited in {sorted(set(files))} "
                f"but no '§{section}' heading exists (have: {sorted(have)})")
    errors.extend(fault_table_drift(root))
    return errors


def main():
    root = sys.argv[1] if len(sys.argv) > 1 else \
        os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    errors = check(root)
    for e in errors:
        print(f"ERROR: {e}", file=sys.stderr)
    if not errors:
        refs = cited_sections(root)
        print(f"ok: {sum(len(v) for v in refs.values())} references to "
              f"{len(refs)} DESIGN.md sections, all defined")
    sys.exit(1 if errors else 0)


if __name__ == "__main__":
    main()
