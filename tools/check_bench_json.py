#!/usr/bin/env python
"""Schema check for the perf-trajectory JSON files CI regenerates on every
run (BENCH_kernels.json / BENCH_inference.json) — replaces the inline
heredocs that used to live in .github/workflows/ci.yml.

Usage:
    python tools/check_bench_json.py kernels   BENCH_kernels.json
    python tools/check_bench_json.py inference BENCH_inference.json [--expect-devices N] [--require-serve]
    python tools/check_bench_json.py training  BENCH_kernels.json   [--expect-devices N]
    python tools/check_bench_json.py update    BENCH_update.json
    python tools/check_bench_json.py serve-faults BENCH_inference.json
    python tools/check_bench_json.py ooc       BENCH_ooc.json

Modes:
    kernels    backend-dispatch coverage: the agg_e2e A/B must contain all
               three aggregation backends plus tile-fill stats (DESIGN.md §7).
    inference  request-level engine rows: ibmb vs >=1 baseline batcher, each
               with p50/p95/p99 request-latency percentiles (DESIGN.md §8).
               With --require-serve, also the sustained-load A/B (§11):
               micro-batching must beat request-at-a-time on throughput at
               equal-or-better p99.
    training   data-parallel trainer rows (DESIGN.md §9): the 1-device row
               always; with --expect-devices N also the N-device row.
    update     dynamic-graph refresh rows (DESIGN.md §10): refresh must beat
               the from-scratch rebuild on a delta touching ≤10% of output
               nodes, and the refreshed plan's accuracy must equal the
               rebuilt plan's.
    serve-faults  chaos drill (DESIGN.md §12): under a seeded 1% injected
               forward-fault rate with retry + breaker enabled, ≥99% of
               admitted requests must complete, ZERO futures may be left
               unresolved, faults must actually have been injected, and the
               refused mid-burst swap must leave the tenant bit-identical
               on the parent plan.
    ooc        out-of-core drill (DESIGN.md §13): the streamed build must
               fingerprint-match the resident one; the serving child must
               hold a payload LARGER than its enforced RSS ceiling
               (resource.setrlimit) with bitwise-identical logits and a
               bounded p50 tax; shard-routed queries must span >=2 shards
               bit-identically; injected batch_io faults must be absorbed
               by bounded retry with zero request errors.

--expect-devices N (inference/training): require a data-parallel record
produced on an N-device mesh — what the CI multidevice job asserts after
running the benches under XLA_FLAGS=--xla_force_host_platform_device_count.
"""
import argparse
import json
import sys

# The ONE canonical table of bench rows the gates require, consumed both
# by the check_* functions below and (parsed statically) by the
# ``bench-gate`` rule of ``repro.analysis`` (DESIGN.md §15), which
# verifies every name/prefix here is actually emitted under benchmarks/.
# Keep these as literal dicts: the analyzer reads them with
# ast.literal_eval, so no computed values.
REQUIRED_ROWS = {
    "kernels": ("kernels/agg_e2e_segment", "kernels/agg_e2e_bcsr_tuned"),
    "inference": ("inference/engine_ibmb_node",),
    # the sustained-load A/B pair (inference --require-serve, DESIGN.md §11)
    "inference-serve": ("inference/serve_request_at_a_time",
                        "inference/serve_microbatch"),
    "serve-faults": ("inference/serve_faults",),
    "ooc": ("ooc/preprocess_stream", "ooc/serve_resident", "ooc/serve_ooc",
            "ooc/serve_shards", "ooc/serve_batch_io_faults"),
}
REQUIRED_PREFIXES = {
    "training": ("training/dp_",),
    "update": ("update/refresh_",),
}


def _op(r) -> str:
    """Record's op name; tolerate malformed records (no KeyError — a
    missing/None op simply never matches a required row)."""
    return r.get("op") or ""


def _by_op(recs, op: str, hint: str):
    """The required record named ``op``, or a clear AssertionError saying
    WHICH row is missing and what that usually means — never a bare
    KeyError from indexing a row that is not there."""
    rows = [r for r in recs if _op(r) == op]
    assert rows, f"required bench row {op!r} is missing — {hint}"
    return rows[-1]


def check_kernels(recs, expect_devices):
    assert recs, "empty BENCH_kernels.json"
    agg = [r for r in recs if _op(r).startswith("kernels/agg_e2e_")]
    backends = {r["backend"] for r in agg}
    assert backends == {"segment", "bcsr", "dense"}, backends
    assert any("tile_fill" in r for r in recs), "tile-fill stats missing"
    # autotuner contract (DESIGN.md §14): on the realistic banded batch the
    # tuned bcsr shape must BEAT the segment path — this is the number the
    # per-batch auto dispatch is betting on — and its fill/block fields
    # must describe the TUNED shape (so the row and the dispatch decision
    # agree), with the autotuner actually deciding bcsr for it.
    hint = "bench_kernels emits the autotuned bcsr A/B row (DESIGN.md §14)"
    seg_op, tuned_op = REQUIRED_ROWS["kernels"]
    seg = _by_op(recs, seg_op, hint)
    tuned = _by_op(recs, tuned_op, hint)
    assert {"tile_fill", "block", "block_f", "decision"} <= set(tuned), tuned
    assert tuned["block"] == tuned["tuned_block"], \
        f"tuned row reports stats for block {tuned['block']} but the " \
        f"autotuner picked {tuned['tuned_block']} — stale-fill bug"
    assert tuned["decision"] == "bcsr", \
        f"autotuner decided {tuned['decision']!r} on the tuned shape — " \
        f"the bcsr row would not actually run under auto dispatch"
    assert tuned["us_per_call"] < seg["us_per_call"], \
        (f"tuned bcsr ({tuned['us_per_call']:.0f}us) did not beat segment "
         f"({seg['us_per_call']:.0f}us) on the realistic-fill batch")
    win = seg["us_per_call"] / tuned["us_per_call"]
    return (f"{len(recs)} records, backends {sorted(backends)}, "
            f"tuned bcsr {win:.1f}x over segment at block "
            f"{tuned['block']}")


def check_inference(recs, expect_devices, require_serve=False):
    assert recs, "empty BENCH_inference.json"
    engine = [r for r in recs if _op(r).startswith("inference/engine_")]
    names = {_op(r) for r in engine}
    assert set(REQUIRED_ROWS["inference"]) <= names, names
    assert len(names) >= 2, f"need ibmb vs a baseline batcher: {names}"
    for r in engine:
        assert {"p50_us", "p95_us", "p99_us"} <= set(r), r
    if expect_devices:
        dp = [r for r in engine if r.get("devices") == expect_devices]
        assert dp, (f"no engine record with devices={expect_devices} "
                    f"(got {[r.get('devices') for r in engine]})")
    msg = f"{len(recs)} records, engine rows {sorted(names)}"
    # sustained-load A/B (DESIGN.md §11): micro-batching must beat
    # request-at-a-time on throughput at equal-or-better p99, on an
    # identical Zipf burst through identical tier machinery
    serve = {_op(r): r for r in recs
             if _op(r).startswith("inference/serve_")}
    # the chaos row (inference/serve_faults, gated by the serve-faults
    # mode) rides in the same full-bench JSON — the A/B needs its pair,
    # not exclusivity
    ra_op, mb_op = REQUIRED_ROWS["inference-serve"]
    need = {ra_op, mb_op}
    if require_serve or need & set(serve):
        assert need <= set(serve), \
            f"serve-load A/B incomplete: {sorted(serve)}"
        ra = serve[ra_op]
        mb = serve[mb_op]
        for r in (ra, mb):
            assert {"throughput_rps", "p50_us", "p95_us", "p99_us",
                    "requests", "completed", "windows",
                    "mean_window_requests", "batch_runs"} <= set(r), r
            assert r["completed"] == r["requests"], \
                f"dropped requests under load: {r['op']}"
        assert mb["throughput_rps"] > ra["throughput_rps"], \
            (f"micro-batching ({mb['throughput_rps']:.0f} rps) did not beat "
             f"request-at-a-time ({ra['throughput_rps']:.0f} rps)")
        assert mb["p99_us"] <= ra["p99_us"], \
            (f"micro-batching p99 {mb['p99_us']:.0f}us worse than "
             f"request-at-a-time {ra['p99_us']:.0f}us")
        gain = mb["throughput_rps"] / ra["throughput_rps"]
        msg += f", serve A/B {gain:.1f}x rps"
    return msg


def check_training(recs, expect_devices):
    (dp_prefix,) = REQUIRED_PREFIXES["training"]
    dp = [r for r in recs if _op(r).startswith(dp_prefix)]
    assert dp, "no training/dp_* records — bench_training did not run?"
    devices = {int(r["devices"]) for r in dp}
    assert 1 in devices, f"missing the 1-device baseline row: {devices}"
    for r in dp:
        assert {"us_per_call", "supersteps_per_epoch",
                "final_val_acc"} <= set(r), r
    if expect_devices:
        assert expect_devices in devices, \
            f"no training/dp_* record with devices={expect_devices}: {devices}"
    return f"{len(dp)} dp records, device counts {sorted(devices)}"


def check_update(recs, expect_devices):
    (refresh_prefix,) = REQUIRED_PREFIXES["update"]
    rows = [r for r in recs if _op(r).startswith(refresh_prefix)]
    assert rows, "no update/refresh_* records — bench_update did not run?"
    # contract (DESIGN.md §10): whenever the delta left ANY batch untouched
    # (the minimal-dirty-set path applied), refresh must beat the full
    # rebuild. A total partition cascade (untouched == 0) is the documented
    # boundary where refresh ~ rebuild; those rows only assert accuracy.
    wins = []
    for r in rows:
        assert {"rebuild_us", "speedup", "rebuilt", "patched", "untouched",
                "dirty_roots", "frac_outputs_touched"} <= set(r), r
        assert r["frac_outputs_touched"] <= 0.10 + 1e-9, \
            f"delta touches {r['frac_outputs_touched']:.1%} of outputs " \
            f"(bench contract: <=10%): {r['op']}"
        if r["untouched"] > 0 or r["patched"] > 0:
            assert r["us_per_call"] < r["rebuild_us"], \
                f"refresh ({r['us_per_call']:.0f}us) did not beat rebuild " \
                f"({r['rebuild_us']:.0f}us) despite locality: {r['op']}"
            wins.append(r)
    assert wins, "no refresh row exercised the minimal-dirty-set path"
    acc = [r for r in rows if "refreshed_acc" in r]
    assert acc, "no refresh row carries accuracy fields"
    for r in acc:
        assert abs(r["refreshed_acc"] - r["rebuilt_acc"]) < 1e-6, \
            f"refreshed plan accuracy {r['refreshed_acc']} != rebuilt " \
            f"{r['rebuilt_acc']}: {r['op']}"
    speed = max(r["speedup"] for r in wins)
    return (f"{len(rows)} refresh rows, {len(wins)} locality wins, "
            f"best speedup {speed:.1f}x")


def check_serve_faults(recs, expect_devices):
    (faults_op,) = REQUIRED_ROWS["serve-faults"]
    r = _by_op(recs, faults_op,
               "the CI chaos job runs bench_inference with "
               "REPRO_BENCH_INFERENCE_SECTION=faults")
    assert {"throughput_rps", "requests", "admitted", "success_rate",
            "unresolved", "injected_forward", "forward_fault_rate",
            "retries", "swap_rollbacks", "swap_rollback_bitexact",
            "worker_restarts"} <= set(r), r
    # the drill must not be vacuous: faults were actually injected
    assert r["injected_forward"] > 0, \
        "zero forward faults injected — the chaos drill tested nothing"
    # graceful degradation contract (DESIGN.md §12)
    assert r["unresolved"] == 0, \
        f"{r['unresolved']} futures never terminated (hung under faults)"
    assert r["success_rate"] >= 0.99, \
        (f"success rate {r['success_rate']:.4f} < 0.99 under a "
         f"{r['forward_fault_rate']:.0%} injected fault rate")
    assert r["swap_rollbacks"] >= 1, \
        "the corrupt-plan swap was not refused (no rollback recorded)"
    assert r["swap_rollback_bitexact"] == 1, \
        "tenant output changed across the refused swap (rollback not clean)"
    return (f"success {r['success_rate']:.4f} over {r['admitted']} admitted, "
            f"{r['injected_forward']} injected faults absorbed by "
            f"{r['retries']} retries, swap rollback bit-exact")


def check_ooc(recs, expect_devices):
    hint = "the CI ooc job runs bench_ooc (REPRO_BENCH_ONLY=bench_ooc)"
    pre_op, res_op, ooc_op, sh_op, fa_op = REQUIRED_ROWS["ooc"]
    pre = _by_op(recs, pre_op, hint)
    assert pre.get("fingerprint_equal") == 1, \
        "streamed plan fingerprint differs from the resident build"
    res = _by_op(recs, res_op, hint)
    ooc = _by_op(recs, ooc_op, hint)
    assert {"us_per_call", "p99_us", "serve_growth_mb", "load_growth_mb",
            "payload_mb", "rss_budget_mb", "enforced",
            "logits_equal_resident"} <= set(ooc), ooc
    # the ceiling was real (setrlimit child), and the payload dwarfs it
    assert ooc["enforced"] == 1, "ooc serve child ran without the rlimit"
    assert ooc["payload_mb"] > ooc["rss_budget_mb"], \
        (f"vacuous drill: payload {ooc['payload_mb']:.0f}MB fits the "
         f"{ooc['rss_budget_mb']:.0f}MB budget — nothing was out of core")
    assert ooc["serve_growth_mb"] <= ooc["rss_budget_mb"], \
        (f"serving grew the heap {ooc['serve_growth_mb']:.1f}MB, over the "
         f"{ooc['rss_budget_mb']:.0f}MB resident budget")
    # never materialized: plan-attributable heap growth (store open +
    # serving faults; data_growth also counts payload-independent JIT
    # compile heap, so it is NOT the right signal) stays far below payload
    plan_growth = ooc["load_growth_mb"] + ooc["serve_growth_mb"]
    assert plan_growth < 0.5 * ooc["payload_mb"], \
        (f"plan load+serve grew the heap {plan_growth:.0f}MB for a "
         f"{ooc['payload_mb']:.0f}MB payload — the lazy cache materialized "
         f"the plan")
    assert ooc["logits_equal_resident"] == 1, \
        "out-of-core logits are not bitwise equal to the resident engine"
    # bounded latency tax: mmap faulting may cost, but not an order of
    # magnitude on the p50 of steady request traffic (us_per_call IS the
    # request p50 for serve rows)
    assert ooc["us_per_call"] <= 10 * res["us_per_call"], \
        (f"ooc p50 {ooc['us_per_call']:.0f}us > 10x resident "
         f"{res['us_per_call']:.0f}us")
    sh = _by_op(recs, sh_op, hint)
    assert sh.get("shards_hit", 0) >= 2, \
        f"queries spanned {sh.get('shards_hit')} shard(s) — need >= 2"
    assert sh.get("logits_equal_resident") == 1, \
        "shard-routed logits are not bitwise equal to the resident engine"
    fa = _by_op(recs, fa_op, hint)
    assert fa.get("injected", 0) >= 1, \
        "zero batch_io faults injected — the retry drill tested nothing"
    assert fa.get("errors", 1) == 0, \
        f"{fa['errors']} requests failed despite bounded batch_io retry"
    return (f"payload {ooc['payload_mb']:.0f}MB under a "
            f"{ooc['rss_budget_mb']:.0f}MB ceiling (serve growth "
            f"{ooc['serve_growth_mb']:.1f}MB), logits bitwise equal, "
            f"{sh['shards_hit']} shards hit, {fa['injected']} IO faults "
            f"absorbed")


CHECKS = {"kernels": check_kernels, "inference": check_inference,
          "training": check_training, "update": check_update,
          "serve-faults": check_serve_faults, "ooc": check_ooc}


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("mode", choices=sorted(CHECKS))
    ap.add_argument("path")
    ap.add_argument("--expect-devices", type=int, default=0,
                    help="require a data-parallel record from an N-device mesh")
    ap.add_argument("--require-serve", action="store_true",
                    help="inference mode: require the sustained-load serve "
                         "A/B rows and assert micro-batching beats "
                         "request-at-a-time (DESIGN.md §11)")
    args = ap.parse_args()
    with open(args.path) as f:
        recs = json.load(f)
    try:
        if args.mode == "inference":
            msg = check_inference(recs, args.expect_devices,
                                  require_serve=args.require_serve)
        else:
            msg = CHECKS[args.mode](recs, args.expect_devices)
    except AssertionError as e:
        print(f"FAIL [{args.mode}] {args.path}: {e}", file=sys.stderr)
        return 1
    print(f"OK [{args.mode}] {args.path}: {msg}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
