"""Kernel micro-benchmarks + end-to-end aggregation backend A/B.

Micro rows time BCSR SpMM vs the XLA segment-sum path and the row gather.
The `agg/e2e_*` rows run the FULL GCN forward on a real IBMB batch under
each aggregation backend (segment | bcsr | dense — DESIGN.md §7), with the
tile-fill stats of the preprocessed block-CSR adjacency in the derived
column. On CPU the Pallas paths run in interpret mode (the kernels target
TPU); the numbers still track the perf trajectory and feed
BENCH_kernels.json via benchmarks/run.py.
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np
import scipy.sparse as sp

from benchmarks.common import Row, fmt
from repro.kernels.spmm import csr_to_bcsr, spmm_bcsr
from repro.kernels.gather_rows import gather_rows


# machine-readable mirror of the rows (op, backend, wall time, derived stats
# at full precision) — benchmarks/run.py writes it to BENCH_kernels.json.
# The CSV `derived` string is display-only (%.4g).
JSON_RECORDS: List[dict] = []


def _row(name: str, us: float, **derived) -> Row:
    JSON_RECORDS.append({"op": name, "backend": derived.get("backend"),
                         "us_per_call": us,
                         **{k: v for k, v in derived.items() if k != "backend"}})
    return (name, us, fmt(**derived))


def _timeit(fn, *args, iters=20):
    fn(*args).block_until_ready()
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    out.block_until_ready()
    return (time.time() - t0) / iters * 1e6


def _micro_rows() -> List[Row]:
    rows: List[Row] = []
    rng = np.random.default_rng(0)
    n, f, density = 2048, 128, 0.005
    m = sp.random(n, n, density=density, random_state=0, format="csr",
                  dtype=np.float32)
    x = jnp.asarray(rng.normal(size=(n, f)).astype(np.float32))

    # XLA gather+segment-sum path (what the GNN uses by default)
    coo = m.tocoo()
    src = jnp.asarray(coo.row.astype(np.int32))
    dst = jnp.asarray(coo.col.astype(np.int32))
    w = jnp.asarray(coo.data)

    @jax.jit
    def seg(x):
        return jax.ops.segment_sum(x[dst] * w[:, None], src, num_segments=n)

    us_seg = _timeit(seg, x)
    rows.append(_row("kernels/spmm_segment_sum", us_seg,
                     backend="segment", nnz=int(m.nnz),
                     gflops=2 * m.nnz * f / 1e9))

    bc = csr_to_bcsr(m.indptr, m.indices, m.data, n, n, block=128)
    cols = jnp.asarray(bc.tile_cols)
    vals = jnp.asarray(bc.tile_vals)
    xp = jnp.asarray(np.pad(np.asarray(x), ((0, bc.num_cols - n), (0, 0))))

    @jax.jit
    def bcsr_ref(xp):
        return spmm_bcsr(cols, vals, xp, impl="reference")

    us_b = _timeit(bcsr_ref, xp)
    stats = bc.density_stats()
    rows.append(_row("kernels/spmm_bcsr_ref", us_b,
                     backend="bcsr", tiles=stats["nonzero_tiles"],
                     tile_fill=stats["tile_fill"],
                     dense_gflops=2 * stats["nonzero_tiles"] * 128 * 128 * f / 1e9))

    table = jnp.asarray(rng.normal(size=(32768, 128)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, 32768, 4096).astype(np.int32))
    us_g = _timeit(jax.jit(lambda t, i: gather_rows(t, i)), table, idx)
    rows.append(_row("kernels/gather_rows_ref", us_g,
                     backend="segment", bytes_moved=4096 * 128 * 4))
    return rows


def _e2e_agg_rows() -> List[Row]:
    """Full GCN forward on one real IBMB batch per aggregation backend."""
    from repro.core import IBMBPipeline, IBMBConfig
    from repro.graph.datasets import get_dataset
    from repro.models.gnn import GNNConfig, init_gnn, gnn_apply

    ds = get_dataset("tiny")
    pipe = IBMBPipeline(ds, IBMBConfig(
        variant="node", k_per_output=8, max_outputs_per_batch=64,
        pad_multiple=128, backend="bcsr"))
    t0 = time.time()
    batch = pipe.preprocess("train")[0]
    prep_us = (time.time() - t0) * 1e6
    stats = batch.bcsr_stats()
    bd = {k: jnp.asarray(v) for k, v in batch.device_arrays().items()}

    rows: List[Row] = []
    for be in ("segment", "bcsr", "dense"):
        cfg = GNNConfig(kind="gcn", in_dim=ds.feat_dim, hidden=128,
                        out_dim=ds.num_classes, num_layers=3, dropout=0.0,
                        backend=be)
        params = init_gnn(cfg, jax.random.PRNGKey(0))
        step = jax.jit(lambda p, b: gnn_apply(cfg, p, b))
        us = _timeit(step, params, bd, iters=10)
        derived = dict(backend=be, nodes=batch.num_real_nodes,
                       edges=batch.num_real_edges)
        if be == "bcsr":
            derived.update(tile_fill=stats["tile_fill"],
                           nonzero_tiles=stats["nonzero_tiles"],
                           row_tiles=stats["row_tiles"],
                           preprocess_us=prep_us)
        rows.append(_row(f"kernels/agg_e2e_{be}", us, **derived))
    return rows


def run() -> List[Row]:
    JSON_RECORDS.clear()
    return _micro_rows() + _e2e_agg_rows()
