"""Kernel micro-benchmarks: BCSR SpMM vs XLA segment-sum aggregation, and
gather. On CPU these time the REFERENCE paths (the Pallas kernels target
TPU); the derived column carries the arithmetic-intensity bookkeeping used
in the roofline discussion."""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np
import scipy.sparse as sp

from benchmarks.common import Row, fmt
from repro.kernels.spmm import csr_to_bcsr, spmm_bcsr
from repro.kernels.gather_rows import gather_rows


def _timeit(fn, *args, iters=20):
    fn(*args).block_until_ready()
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    out.block_until_ready()
    return (time.time() - t0) / iters * 1e6


def run() -> List[Row]:
    rows: List[Row] = []
    rng = np.random.default_rng(0)
    n, f, density = 2048, 128, 0.005
    m = sp.random(n, n, density=density, random_state=0, format="csr",
                  dtype=np.float32)
    x = jnp.asarray(rng.normal(size=(n, f)).astype(np.float32))

    # XLA gather+segment-sum path (what the GNN uses by default)
    coo = m.tocoo()
    src = jnp.asarray(coo.row.astype(np.int32))
    dst = jnp.asarray(coo.col.astype(np.int32))
    w = jnp.asarray(coo.data)

    @jax.jit
    def seg(x):
        return jax.ops.segment_sum(x[dst] * w[:, None], src, num_segments=n)

    us_seg = _timeit(seg, x)
    rows.append(("kernels/spmm_segment_sum", us_seg,
                 fmt(nnz=m.nnz, gflops=2 * m.nnz * f / 1e9)))

    bc = csr_to_bcsr(m.indptr, m.indices, m.data, n, n, block=128)
    cols = jnp.asarray(bc.tile_cols)
    vals = jnp.asarray(bc.tile_vals)
    xp = jnp.asarray(np.pad(np.asarray(x), ((0, bc.num_cols - n), (0, 0))))

    @jax.jit
    def bcsr_ref(xp):
        return spmm_bcsr(cols, vals, xp, impl="reference")

    us_b = _timeit(bcsr_ref, xp)
    stats = bc.density_stats()
    rows.append(("kernels/spmm_bcsr_ref", us_b,
                 fmt(tiles=stats["nonzero_tiles"],
                     tile_fill=stats["tile_fill"],
                     dense_gflops=2 * stats["nonzero_tiles"] * 128 * 128 * f / 1e9)))

    table = jnp.asarray(rng.normal(size=(32768, 128)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, 32768, 4096).astype(np.int32))
    us_g = _timeit(jax.jit(lambda t, i: gather_rows(t, i)), table, idx)
    rows.append(("kernels/gather_rows_ref", us_g,
                 fmt(bytes_moved=4096 * 128 * 4)))
    return rows
