"""Kernel micro-benchmarks + end-to-end aggregation backend A/B.

Micro rows time BCSR SpMM vs the XLA segment-sum path and the row gather.
The `agg/e2e_*` rows run the FULL GCN forward on a real IBMB batch under
each aggregation backend (segment | bcsr | dense — DESIGN.md §7), with the
tile-fill stats of the preprocessed block-CSR adjacency in the derived
column. On CPU the Pallas paths run in interpret mode (the kernels target
TPU); the numbers still track the perf trajectory and feed
BENCH_kernels.json via benchmarks/run.py.
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np
import scipy.sparse as sp

from benchmarks.common import Row, fmt
from repro.kernels.spmm import csr_to_bcsr, spmm_bcsr
from repro.kernels.gather_rows import gather_rows


# machine-readable mirror of the rows (op, backend, wall time, derived stats
# at full precision) — benchmarks/run.py writes it to BENCH_kernels.json.
# The CSV `derived` string is display-only (%.4g).
JSON_RECORDS: List[dict] = []


def _row(name: str, us: float, **derived) -> Row:
    JSON_RECORDS.append({"op": name, "backend": derived.get("backend"),
                         "us_per_call": us,
                         **{k: v for k, v in derived.items() if k != "backend"}})
    return (name, us, fmt(**derived))


def _timeit(fn, *args, iters=20):
    fn(*args).block_until_ready()
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    out.block_until_ready()
    return (time.time() - t0) / iters * 1e6


def _micro_rows() -> List[Row]:
    rows: List[Row] = []
    rng = np.random.default_rng(0)
    n, f, density = 2048, 128, 0.005
    m = sp.random(n, n, density=density, random_state=0, format="csr",
                  dtype=np.float32)
    x = jnp.asarray(rng.normal(size=(n, f)).astype(np.float32))

    # XLA gather+segment-sum path (what the GNN uses by default)
    coo = m.tocoo()
    src = jnp.asarray(coo.row.astype(np.int32))
    dst = jnp.asarray(coo.col.astype(np.int32))
    w = jnp.asarray(coo.data)

    @jax.jit
    def seg(x):
        return jax.ops.segment_sum(x[dst] * w[:, None], src, num_segments=n)

    us_seg = _timeit(seg, x)
    rows.append(_row("kernels/spmm_segment_sum", us_seg,
                     backend="segment", nnz=int(m.nnz),
                     gflops=2 * m.nnz * f / 1e9))

    bc = csr_to_bcsr(m.indptr, m.indices, m.data, n, n, block=128)
    cols = jnp.asarray(bc.tile_cols)
    vals = jnp.asarray(bc.tile_vals)
    xp = jnp.asarray(np.pad(np.asarray(x), ((0, bc.num_cols - n), (0, 0))))

    @jax.jit
    def bcsr_ref(xp):
        return spmm_bcsr(cols, vals, xp, impl="reference")

    us_b = _timeit(bcsr_ref, xp)
    stats = bc.density_stats()
    rows.append(_row("kernels/spmm_bcsr_ref", us_b,
                     backend="bcsr", tiles=stats["nonzero_tiles"],
                     tile_fill=stats["tile_fill"],
                     dense_gflops=2 * stats["nonzero_tiles"] * 128 * 128 * f / 1e9))

    table = jnp.asarray(rng.normal(size=(32768, 128)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, 32768, 4096).astype(np.int32))
    us_g = _timeit(jax.jit(lambda t, i: gather_rows(t, i)), table, idx)
    rows.append(_row("kernels/gather_rows_ref", us_g,
                     backend="segment", bytes_moved=4096 * 128 * 4))
    return rows


def _e2e_agg_rows() -> List[Row]:
    """Full GCN forward per aggregation backend on one realistic IBMB batch:
    a shuffled banded-community graph whose BFS reorder re-bunches the band
    (the locality-rich, moderate-degree regime the paper's batches live in).

    The ``bcsr_tuned`` row runs the SAME adjacency at the tile shape the
    plan-build autotuner picks (DESIGN.md §14), and its tile_fill / block /
    block_f / decision fields come from ``autotune.decide_batches`` on the
    TUNED shape — so the bench row and the auto-dispatch decision it gates
    describe the same tiles (a fill reported for the un-tuned build would
    not be the fill the dispatcher acts on)."""
    from repro.core import IBMBConfig, autotune
    from repro.core.batches import build_batches
    from repro.graph.csr import coo_to_csr, make_undirected
    from repro.models.gnn import GNNConfig, init_gnn, gnn_apply

    n, f, width = 1024, 128, 8
    rng = np.random.default_rng(0)
    perm = rng.permutation(n)
    src = np.concatenate([perm[:-d] for d in range(1, width + 1)])
    dst = np.concatenate([perm[d:] for d in range(1, width + 1)])
    g = make_undirected(coo_to_csr(src, dst, n))
    feats = rng.normal(size=(n, f)).astype(np.float32)
    labels = np.zeros(n, np.int32)
    outs = [np.arange(n)]

    cfg_t = IBMBConfig(variant="node", backend="bcsr", pad_multiple=128,
                       bcsr_block=128, tune_blocks=(16, 32, 64, 256))
    t0 = time.time()
    (built,) = build_batches(g, feats, labels, outs, outs, pad_multiple=128,
                             bcsr_block=128, reorder="bfs")
    tuned_list, block = autotune.retune_tile_block([built], cfg_t)
    tuned = tuned_list[0]
    prep_us = (time.time() - t0) * 1e6
    backs, bfs, bstats = autotune.decide_batches([tuned], cfg_t)

    rows: List[Row] = []
    variants = [("segment", built, "segment", 0, None),
                ("bcsr", built, "bcsr", 0, built.bcsr_stats()),
                ("dense", built, "dense", 0, None),
                ("bcsr_tuned", tuned, "bcsr", bfs[0], tuned.bcsr_stats())]
    for name, batch, be, bf, stats in variants:
        cfg = GNNConfig(kind="gcn", in_dim=f, hidden=128, out_dim=8,
                        num_layers=3, dropout=0.0, backend=be,
                        bcsr_block_f=bf)
        params = init_gnn(cfg, jax.random.PRNGKey(0))
        step = jax.jit(lambda p, b, c=cfg: gnn_apply(c, p, b))
        bd = {k: jnp.asarray(v) for k, v in batch.device_arrays().items()}
        us = _timeit(step, params, bd, iters=10)
        derived = dict(backend=be, nodes=batch.num_real_nodes,
                       edges=batch.num_real_edges)
        if stats is not None:
            derived.update(tile_fill=stats["tile_fill"],
                           nonzero_tiles=stats["nonzero_tiles"],
                           row_tiles=stats["row_tiles"],
                           block=int(batch.tile_vals.shape[-1]),
                           preprocess_us=prep_us)
        if name == "bcsr_tuned":
            derived.update(block_f=bfs[0], decision=backs[0],
                           tuned_block=block)
        rows.append(_row(f"kernels/agg_e2e_{name}", us, **derived))
    return rows


def run() -> List[Row]:
    JSON_RECORDS.clear()
    return _micro_rows() + _e2e_agg_rows()
