"""Fig. 2 / Table 7 (inference): accuracy vs inference time per method, on a
FIXED pretrained model (the paper trains with node-wise IBMB and evaluates
every method on the same weights)."""
from __future__ import annotations

import time
from typing import List

import numpy as np

from benchmarks.common import (
    DS_MAIN, Row, evaluate_batches, fmt, ibmb_pipeline, train_with)
from repro.graph.datasets import get_dataset
from repro.graph.sampling import make_batcher


def run() -> List[Row]:
    ds = get_dataset(DS_MAIN)
    pipe = ibmb_pipeline(ds, "node")
    tr_b = pipe.preprocess("train")
    va_b = pipe.preprocess("val", for_inference=True)
    res, trainer = train_with(ds, tr_b, va_b)
    params = res.params

    rows: List[Row] = []

    def add(name, batches, prep_s):
        m = evaluate_batches(trainer, params, batches)
        rows.append((f"inference/{name}", m["time_s"] * 1e6,
                     fmt(test_acc=m["acc"], preprocess_s=prep_s)))

    t0 = time.time()
    add("ibmb_node", pipe.preprocess("test", for_inference=True),
        time.time() - t0)

    t0 = time.time()
    pipe_b = ibmb_pipeline(ds, "batch", num_batches=8)
    add("ibmb_batch", pipe_b.preprocess("test", for_inference=True),
        time.time() - t0)

    t0 = time.time()
    pipe_r = ibmb_pipeline(ds, "random")
    add("ibmb_rand_batch", pipe_r.preprocess("test", for_inference=True),
        time.time() - t0)

    for name, kw in [("cluster_gcn", {"num_batches": 8}),
                     ("neighbor_sampling", {"num_batches": 8}),
                     ("ladies", {"num_batches": 8}),
                     ("graphsaint_rw", {"num_steps": 8, "batch_roots": 400}),
                     ("shadow_ppr", {"outputs_per_batch": 256}),
                     ("full_batch", {})]:
        t0 = time.time()
        bt = make_batcher(name, ds, split="test", **kw)
        batches = bt.epoch_batches(0)
        add(name, batches, time.time() - t0)
    return rows
