"""Fig. 2 / Table 7 (inference): accuracy vs inference time per method, on a
FIXED pretrained model (the paper trains with node-wise IBMB and evaluates
every method on the same weights) — plus the request-level serving rows
(DESIGN.md §8): a `GNNInferenceEngine` serving per-node queries from a
saved-then-loaded `Plan` artifact, with request-latency percentiles, versus
the batch-eval path (which must run the full inference pass to answer an
arbitrary node query).

``benchmarks/run.py`` writes the full-precision records (`JSON_RECORDS`) to
``BENCH_inference.json``.

Sustained-load serving rows (DESIGN.md §11): a Zipf-distributed request
burst drained through the ``AsyncGNNEngine`` tier under two policies on
IDENTICAL machinery — request-at-a-time (window 0, one request per
dispatch) vs micro-batching (2 ms window + full-batch occupancy dispatch).
The micro-batching row must beat request-at-a-time on throughput at
equal-or-better p99 (``tools/check_bench_json.py inference
--require-serve`` gates this in the serve-load CI job).

Chaos row (DESIGN.md §12): the same Zipf burst with a seeded 1% forward
fault rate injected into the tier (retry + breaker enabled) and a failed
mid-burst swap. The gate (``check_bench_json serve-faults``): ≥99% of
admitted requests complete, ZERO futures are left unresolved, and the
refused swap leaves the tenant bit-identical on the parent plan.

``REPRO_BENCH_INFERENCE_SECTION=serve`` is a dev fast path: skip the
accuracy/baseline-batcher sections and produce only the serve-load rows
(CI runs the full bench — check_inference needs the engine rows too).
``REPRO_BENCH_INFERENCE_SECTION=faults`` likewise produces only the chaos
row — what the CI chaos job runs.
"""
from __future__ import annotations

import dataclasses
import os
import tempfile
import time
from typing import List

import numpy as np

from benchmarks.common import (
    DS_MAIN, Row, evaluate_batches, fmt, ibmb_pipeline, train_with)
from repro.core import Plan
from repro.core.plan import RoutingIndex
from repro.faults import FaultInjector
from repro.graph.datasets import get_dataset
from repro.graph.sampling import make_batcher
from repro.serve import AsyncGNNEngine, AsyncServeConfig, GNNInferenceEngine

JSON_RECORDS: List[dict] = []

NUM_REQUESTS = 200
REQUEST_SIZE = 32

# sustained-load section (DESIGN.md §11)
ZIPF_EXPONENT = 1.1
LOAD_REQUESTS = 400
LOAD_REQUEST_SIZE = 4

# chaos section (DESIGN.md §12)
FORWARD_FAULT_RATE = 0.01


def _record(name: str, us: float, **derived) -> Row:
    JSON_RECORDS.append({"op": name, "us_per_call": float(us), **derived})
    return (name, us, fmt(**derived))


def _timed_queries(eng, requests):
    lat_us = []
    for req in requests:
        t0 = time.perf_counter()
        eng.query(req)
        lat_us.append((time.perf_counter() - t0) * 1e6)
    return lat_us


def _engine_row(name: str, plan: Plan, trainer, params, requests,
                mesh=None) -> Row:
    """Request-latency percentiles for an engine serving from a saved-then-
    loaded plan (proves the request path never re-preprocesses).

    Two cache regimes, both sized RELATIVE to the plan so the ibmb-vs-
    baseline A/B compares batchers rather than LRU fit: "cold" (LRU
    disabled — every request pays the forwards for the batches it touches,
    measuring routing + coalesced execution) and "warm" (LRU holds every
    batch — steady-state repeat traffic, measuring the routed host-memory
    path). Primary percentiles are warm; cold rides in `derived`."""
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "plan.npz")
        plan.save(path)
        served = Plan.load(path)
    cold = GNNInferenceEngine(served, trainer.cfg, params, cache_batches=0,
                              mesh=mesh)
    cold.query(requests[0])                      # compile outside the timing
    cold_lat = _timed_queries(cold, requests)
    warm = GNNInferenceEngine(served, trainer.cfg, params,
                              cache_batches=len(served), mesh=mesh)
    warm.query(served.routing.node_ids)          # fill the LRU completely
    warm_lat = _timed_queries(warm, requests)
    p50, p95, p99 = (float(np.percentile(warm_lat, p)) for p in (50, 95, 99))
    # batch-eval comparison on the same artifact: answering ONE arbitrary
    # query without a routing index means a full inference pass
    t0 = time.perf_counter()
    m = trainer.evaluate(params, served)
    full_pass_us = (time.perf_counter() - t0) * 1e6
    from repro.dist.data_parallel import mesh_world
    return _record(
        f"inference/engine_{name}", float(np.mean(warm_lat)),
        p50_us=p50, p95_us=p95, p99_us=p99,
        cold_p50_us=float(np.percentile(cold_lat, 50)),
        cold_p95_us=float(np.percentile(cold_lat, 95)),
        full_pass_us=full_pass_us,
        requests=len(requests), request_size=len(requests[0]),
        cold_batch_runs=cold.stats["batch_runs"],
        devices=1 if mesh is None else mesh_world(mesh),
        num_batches=len(served), test_acc=m["acc"])


def _zipf_requests(rng, nodes, n, size, exponent):
    """Zipf-popular request stream: node popularity follows rank^-exponent
    over a random permutation of the servable nodes, so a few plan batches
    are hot (the regime micro-batching coalesces) but the tail keeps the
    LRU honest."""
    ranks = np.arange(1, len(nodes) + 1, dtype=np.float64)
    p = ranks ** -float(exponent)
    p /= p.sum()
    pop = rng.permutation(nodes)
    return [rng.choice(pop, size=size, replace=False, p=p)
            for _ in range(n)]


def _serve_load_row(name: str, plan: Plan, trainer, params, requests,
                    config: AsyncServeConfig) -> Row:
    """Drain a Zipf burst through the async tier under `config` and report
    sustained throughput + request-latency percentiles (submit → logits,
    measured on the futures themselves). The LRU is sized to a QUARTER of
    the plan so the A/B compares DISPATCH POLICIES, not cache fit — hot
    batches hit either way; the win must come from coalescing forwards."""
    eng = GNNInferenceEngine(plan, trainer.cfg, params,
                             cache_batches=max(1, len(plan) // 4))
    with AsyncGNNEngine({"m": eng}, config) as tier:
        tier.submit("m", requests[0]).result(timeout=300.0)  # compile outside
        t0 = time.perf_counter()
        futs = [tier.submit("m", q) for q in requests]
        for f in futs:
            f.result(timeout=300.0)
        wall_s = time.perf_counter() - t0
        snap = tier.snapshot()
    lat_us = [f.latency_s * 1e6 for f in futs]
    p50, p95, p99 = (float(np.percentile(lat_us, p)) for p in (50, 95, 99))
    return _record(
        f"inference/serve_{name}", wall_s * 1e6 / len(requests),
        throughput_rps=len(requests) / wall_s,
        p50_us=p50, p95_us=p95, p99_us=p99,
        requests=len(requests), request_size=len(requests[0]),
        completed=snap["completed"] - 1,         # minus the warmup request
        windows=snap["windows"],
        mean_window_requests=snap["mean_window_requests"],
        batch_runs=eng.stats["batch_runs"],
        window_us=config.window_us, devices=1, num_batches=len(plan),
        zipf_exponent=ZIPF_EXPONENT)


def _serve_load_rows(test_plan: Plan, trainer, params, ds) -> List[Row]:
    """The A/B the serve-load CI job gates on: identical burst, identical
    tier machinery, only the window policy differs."""
    rng = np.random.default_rng(7)
    nodes = test_plan.routing.node_ids
    size = min(LOAD_REQUEST_SIZE, len(nodes))
    burst = _zipf_requests(rng, nodes, LOAD_REQUESTS, size, ZIPF_EXPONENT)
    unbounded = dict(max_queue=1_000_000)        # measure drain, not admission
    return [
        _serve_load_row(
            "request_at_a_time", test_plan, trainer, params, burst,
            AsyncServeConfig(window_us=0.0, max_requests_per_window=1,
                             occupancy_dispatch=False, **unbounded)),
        _serve_load_row(
            "microbatch", test_plan, trainer, params, burst,
            AsyncServeConfig(window_us=2000.0, occupancy_dispatch=True,
                             **unbounded)),
    ]


def _serve_faults_row(test_plan: Plan, trainer, params) -> Row:
    """Chaos drill the chaos CI job gates on (DESIGN.md §12): the Zipf
    burst with a seeded ``FORWARD_FAULT_RATE`` forward fault rate (plus one
    scripted injection so the drill is never vacuous), retry + breaker
    enabled, and a REFUSED mid-burst swap onto a corrupt-routing plan.
    ``check_bench_json serve-faults`` asserts ≥99% of admitted requests
    complete, zero futures are left unresolved, and the refused swap left
    the tenant bit-identical on the parent plan."""
    rng = np.random.default_rng(11)
    nodes = test_plan.routing.node_ids
    size = min(LOAD_REQUEST_SIZE, len(nodes))
    burst = _zipf_requests(rng, nodes, LOAD_REQUESTS, size, ZIPF_EXPONENT)
    faults = FaultInjector(seed=0, rates={"forward": FORWARD_FAULT_RATE},
                           script={"forward": [1]})
    cfg = AsyncServeConfig(window_us=2000.0, occupancy_dispatch=True,
                           max_queue=1_000_000, max_retries=3,
                           breaker_threshold=4, breaker_cooldown_us=50_000.0)
    eng = GNNInferenceEngine(test_plan, trainer.cfg, params,
                             cache_batches=max(1, len(test_plan) // 4))
    probe = np.asarray(nodes[:size])
    bad = dataclasses.replace(test_plan, routing=RoutingIndex(
        node_ids=test_plan.routing.node_ids,
        batch=np.full(len(test_plan.routing.node_ids),
                      len(test_plan) + 99, dtype=np.int32),
        row=test_plan.routing.row))
    with AsyncGNNEngine({"m": eng}, cfg, faults=faults) as tier:
        before = tier.submit("m", probe).result(timeout=300.0)  # + compile
        t0 = time.perf_counter()
        futs = [tier.submit("m", q) for q in burst]
        swap_refused = 0
        try:                    # mid-burst swap onto a corrupt-routing plan:
            tier.swap("m", bad)  # must raise, tenant must stay untouched
        except ValueError:
            swap_refused = 1
        for f in futs:
            f.wait(timeout=300.0)
        wall_s = time.perf_counter() - t0
        after = tier.submit("m", probe).result(timeout=300.0)
        snap = tier.snapshot()
    unresolved = sum(1 for f in futs if not f.done())
    rejected = sum(1 for f in futs if f.done() and f.rejected)
    successes = sum(1 for f in futs
                    if f.done() and f.exception(0.0) is None)
    admitted = len(futs) - rejected
    fs = snap["faults"]
    return _record(
        "inference/serve_faults", wall_s * 1e6 / len(burst),
        throughput_rps=len(burst) / wall_s,
        requests=len(burst), admitted=admitted,
        success_rate=(successes / admitted) if admitted else 0.0,
        unresolved=unresolved,
        injected_forward=fs["injected"]["forward"]["fired"],
        forward_fault_rate=FORWARD_FAULT_RATE,
        retries=fs["retries"], fast_rejects=fs["fast_rejects"],
        breaker_opens=fs["breaker_opens"],
        worker_restarts=fs["worker_restarts"],
        swap_rollbacks=fs["swap_rollbacks"],
        swap_rollback_bitexact=int(bool(swap_refused)
                                   and np.array_equal(before, after)),
        window_us=cfg.window_us, devices=1, num_batches=len(test_plan),
        zipf_exponent=ZIPF_EXPONENT)


def run() -> List[Row]:
    JSON_RECORDS.clear()
    ds = get_dataset(DS_MAIN)
    pipe = ibmb_pipeline(ds, "node")
    res, trainer = train_with(ds, pipe.plan("train"),
                              pipe.plan("val", for_inference=True))
    params = res.params

    section = os.environ.get("REPRO_BENCH_INFERENCE_SECTION")
    if section == "serve":
        test_plan = pipe.plan("test", for_inference=True)
        return _serve_load_rows(test_plan, trainer, params, ds)
    if section == "faults":
        test_plan = pipe.plan("test", for_inference=True)
        return [_serve_faults_row(test_plan, trainer, params)]

    rows: List[Row] = []

    def add(name, batches, prep_s):
        m = evaluate_batches(trainer, params, batches)
        rows.append(_record(f"inference/{name}", m["time_s"] * 1e6,
                            test_acc=m["acc"], preprocess_s=prep_s))

    t0 = time.time()
    test_plan = pipe.plan("test", for_inference=True)
    add("ibmb_node", test_plan, time.time() - t0)

    t0 = time.time()
    pipe_b = ibmb_pipeline(ds, "batch", num_batches=8)
    add("ibmb_batch", pipe_b.plan("test", for_inference=True),
        time.time() - t0)

    t0 = time.time()
    pipe_r = ibmb_pipeline(ds, "random")
    add("ibmb_rand_batch", pipe_r.plan("test", for_inference=True),
        time.time() - t0)

    baseline_plans = {}
    for name, kw in [("cluster_gcn", {"num_batches": 8}),
                     ("neighbor_sampling", {"num_batches": 8}),
                     ("ladies", {"num_batches": 8}),
                     ("graphsaint_rw", {"num_steps": 8, "batch_roots": 400}),
                     ("shadow_ppr", {"outputs_per_batch": 256}),
                     ("full_batch", {})]:
        t0 = time.time()
        bt = make_batcher(name, ds, split="test", **kw)
        batches = bt.epoch_batches(0)
        if name == "cluster_gcn":               # engine-vs-engine baseline
            baseline_plans[name] = Plan.from_batches(
                batches, meta=dict(split="test", mode="inference",
                                   variant=name))
        add(name, batches, time.time() - t0)

    # ---- request-level serving (engine vs batch eval, DESIGN.md §8) ----
    rng = np.random.default_rng(0)
    test = ds.splits["test"]
    size = min(REQUEST_SIZE, len(test))
    requests = [rng.choice(test, size=size, replace=False)
                for _ in range(NUM_REQUESTS)]
    rows.append(_engine_row("ibmb_node", test_plan, trainer, params, requests))
    for name, plan in baseline_plans.items():
        rows.append(_engine_row(name, plan, trainer, params, requests))

    # 1-vs-N-device serving (DESIGN.md §9): same plan/params/requests, but
    # misses coalesce one-batch-per-device into shard_map super-steps. The
    # N-device row only exists when the process sees >1 device (the CI
    # multidevice job fakes 8 on CPU).
    import jax
    if jax.device_count() > 1:
        from repro.dist.data_parallel import data_mesh
        n = jax.device_count()
        rows.append(_engine_row(f"ibmb_node_dp{n}dev", test_plan, trainer,
                                params, requests, mesh=data_mesh(n)))

    # ---- sustained Zipf load through the async tier (DESIGN.md §11) ----
    rows.extend(_serve_load_rows(test_plan, trainer, params, ds))

    # ---- chaos drill: faults + refused swap (DESIGN.md §12) ----
    rows.append(_serve_faults_row(test_plan, trainer, params))
    return rows
