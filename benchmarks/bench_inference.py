"""Fig. 2 / Table 7 (inference): accuracy vs inference time per method, on a
FIXED pretrained model (the paper trains with node-wise IBMB and evaluates
every method on the same weights) — plus the request-level serving rows
(DESIGN.md §8): a `GNNInferenceEngine` serving per-node queries from a
saved-then-loaded `Plan` artifact, with request-latency percentiles, versus
the batch-eval path (which must run the full inference pass to answer an
arbitrary node query).

``benchmarks/run.py`` writes the full-precision records (`JSON_RECORDS`) to
``BENCH_inference.json``.
"""
from __future__ import annotations

import os
import tempfile
import time
from typing import List

import numpy as np

from benchmarks.common import (
    DS_MAIN, Row, evaluate_batches, fmt, ibmb_pipeline, train_with)
from repro.core import Plan
from repro.graph.datasets import get_dataset
from repro.graph.sampling import make_batcher
from repro.serve import GNNInferenceEngine

JSON_RECORDS: List[dict] = []

NUM_REQUESTS = 200
REQUEST_SIZE = 32


def _record(name: str, us: float, **derived) -> Row:
    JSON_RECORDS.append({"op": name, "us_per_call": float(us), **derived})
    return (name, us, fmt(**derived))


def _timed_queries(eng, requests):
    lat_us = []
    for req in requests:
        t0 = time.perf_counter()
        eng.query(req)
        lat_us.append((time.perf_counter() - t0) * 1e6)
    return lat_us


def _engine_row(name: str, plan: Plan, trainer, params, requests,
                mesh=None) -> Row:
    """Request-latency percentiles for an engine serving from a saved-then-
    loaded plan (proves the request path never re-preprocesses).

    Two cache regimes, both sized RELATIVE to the plan so the ibmb-vs-
    baseline A/B compares batchers rather than LRU fit: "cold" (LRU
    disabled — every request pays the forwards for the batches it touches,
    measuring routing + coalesced execution) and "warm" (LRU holds every
    batch — steady-state repeat traffic, measuring the routed host-memory
    path). Primary percentiles are warm; cold rides in `derived`."""
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "plan.npz")
        plan.save(path)
        served = Plan.load(path)
    cold = GNNInferenceEngine(served, trainer.cfg, params, cache_batches=0,
                              mesh=mesh)
    cold.query(requests[0])                      # compile outside the timing
    cold_lat = _timed_queries(cold, requests)
    warm = GNNInferenceEngine(served, trainer.cfg, params,
                              cache_batches=len(served), mesh=mesh)
    warm.query(served.routing.node_ids)          # fill the LRU completely
    warm_lat = _timed_queries(warm, requests)
    p50, p95, p99 = (float(np.percentile(warm_lat, p)) for p in (50, 95, 99))
    # batch-eval comparison on the same artifact: answering ONE arbitrary
    # query without a routing index means a full inference pass
    t0 = time.perf_counter()
    m = trainer.evaluate(params, served)
    full_pass_us = (time.perf_counter() - t0) * 1e6
    from repro.dist.data_parallel import mesh_world
    return _record(
        f"inference/engine_{name}", float(np.mean(warm_lat)),
        p50_us=p50, p95_us=p95, p99_us=p99,
        cold_p50_us=float(np.percentile(cold_lat, 50)),
        cold_p95_us=float(np.percentile(cold_lat, 95)),
        full_pass_us=full_pass_us,
        requests=len(requests), request_size=len(requests[0]),
        cold_batch_runs=cold.stats["batch_runs"],
        devices=1 if mesh is None else mesh_world(mesh),
        num_batches=len(served), test_acc=m["acc"])


def run() -> List[Row]:
    JSON_RECORDS.clear()
    ds = get_dataset(DS_MAIN)
    pipe = ibmb_pipeline(ds, "node")
    res, trainer = train_with(ds, pipe.plan("train"),
                              pipe.plan("val", for_inference=True))
    params = res.params

    rows: List[Row] = []

    def add(name, batches, prep_s):
        m = evaluate_batches(trainer, params, batches)
        rows.append(_record(f"inference/{name}", m["time_s"] * 1e6,
                            test_acc=m["acc"], preprocess_s=prep_s))

    t0 = time.time()
    test_plan = pipe.plan("test", for_inference=True)
    add("ibmb_node", test_plan, time.time() - t0)

    t0 = time.time()
    pipe_b = ibmb_pipeline(ds, "batch", num_batches=8)
    add("ibmb_batch", pipe_b.plan("test", for_inference=True),
        time.time() - t0)

    t0 = time.time()
    pipe_r = ibmb_pipeline(ds, "random")
    add("ibmb_rand_batch", pipe_r.plan("test", for_inference=True),
        time.time() - t0)

    baseline_plans = {}
    for name, kw in [("cluster_gcn", {"num_batches": 8}),
                     ("neighbor_sampling", {"num_batches": 8}),
                     ("ladies", {"num_batches": 8}),
                     ("graphsaint_rw", {"num_steps": 8, "batch_roots": 400}),
                     ("shadow_ppr", {"outputs_per_batch": 256}),
                     ("full_batch", {})]:
        t0 = time.time()
        bt = make_batcher(name, ds, split="test", **kw)
        batches = bt.epoch_batches(0)
        if name == "cluster_gcn":               # engine-vs-engine baseline
            baseline_plans[name] = Plan.from_batches(
                batches, meta=dict(split="test", mode="inference",
                                   variant=name))
        add(name, batches, time.time() - t0)

    # ---- request-level serving (engine vs batch eval, DESIGN.md §8) ----
    rng = np.random.default_rng(0)
    test = ds.splits["test"]
    size = min(REQUEST_SIZE, len(test))
    requests = [rng.choice(test, size=size, replace=False)
                for _ in range(NUM_REQUESTS)]
    rows.append(_engine_row("ibmb_node", test_plan, trainer, params, requests))
    for name, plan in baseline_plans.items():
        rows.append(_engine_row(name, plan, trainer, params, requests))

    # 1-vs-N-device serving (DESIGN.md §9): same plan/params/requests, but
    # misses coalesce one-batch-per-device into shard_map super-steps. The
    # N-device row only exists when the process sees >1 device (the CI
    # multidevice job fakes 8 on CPU).
    import jax
    if jax.device_count() > 1:
        from repro.dist.data_parallel import data_mesh
        n = jax.device_count()
        rows.append(_engine_row(f"ibmb_node_dp{n}dev", test_plan, trainer,
                                params, requests, mesh=data_mesh(n)))
    return rows
