"""Fig. 8: gradient accumulation for batch-wise IBMB — the difference should
be minor even when accumulating the whole epoch."""
from __future__ import annotations

from typing import List

from benchmarks.common import DS_MAIN, Row, fmt, ibmb_pipeline, train_with
from repro.graph.datasets import get_dataset


def run() -> List[Row]:
    ds = get_dataset(DS_MAIN)
    pipe = ibmb_pipeline(ds, "batch", num_batches=8)
    tr = pipe.preprocess("train")
    va = pipe.preprocess("val", for_inference=True)
    rows: List[Row] = []
    for accum in (1, 2, len(tr)):
        res, _ = train_with(ds, tr, va, grad_accum=accum)
        label = "full_epoch" if accum == len(tr) else str(accum)
        rows.append((f"grad_accum/{label}", res.time_per_epoch * 1e6,
                     fmt(val_acc=res.best_val_acc)))
    return rows
