"""Fig. 7: batch scheduling — none vs TSP-max order vs distance-weighted
sampling. Scheduling should reduce downward accuracy spikes and raise final
accuracy."""
from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.common import DS_MAIN, Row, fmt, ibmb_pipeline, train_with
from repro.graph.datasets import get_dataset


def _max_dip(history) -> float:
    accs = [h["val_acc"] for h in history]
    best = 0.0
    dip = 0.0
    for a in accs:
        best = max(best, a)
        dip = max(dip, best - a)
    return dip


def run() -> List[Row]:
    ds = get_dataset(DS_MAIN)
    pipe = ibmb_pipeline(ds, "node", max_outputs_per_batch=128)
    tr = pipe.preprocess("train")
    va = pipe.preprocess("val", for_inference=True)
    rows: List[Row] = []
    for mode in ("none", "tsp", "weighted"):
        res, _ = train_with(ds, tr, va, schedule=mode)
        rows.append((f"scheduling/{mode}", res.time_per_epoch * 1e6,
                     fmt(val_acc=res.best_val_acc,
                         max_acc_dip=_max_dip(res.history))))
    return rows
