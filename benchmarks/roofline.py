"""Roofline table: reads results/dryrun/*.json (produced by
repro.launch.dryrun) and emits one row per (arch × shape × mesh) cell with
the three terms, dominant bottleneck, and useful-compute ratio."""
from __future__ import annotations

import glob
import json
import os
from typing import List

from benchmarks.common import Row, fmt

RESULTS = os.environ.get("REPRO_DRYRUN_DIR", "results/dryrun")


def run() -> List[Row]:
    rows: List[Row] = []
    for path in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        with open(path) as f:
            d = json.load(f)
        tag = f"roofline/{d['arch']}__{d['shape']}__{d['mesh']}"
        if "skipped" in d:
            rows.append((tag, 0.0, "skipped=subquadratic_only"))
            continue
        if "error" in d:
            rows.append((tag, 0.0, f"error={d['error'][:60]}"))
            continue
        r = d["roofline"]
        bound_us = max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6
        rows.append((tag, bound_us, fmt(
            compute_s=r["compute_s"], memory_s=r["memory_s"],
            collective_s=r["collective_s"], dominant=r["dominant"],
            useful_ratio=d.get("useful_ratio") or 0.0,
            roofline_fraction=r.get("roofline_fraction") or 0.0,
            hbm_gb=(d["memory"]["peak_bytes"] or 0) / 1e9)))
    if not rows:
        rows.append(("roofline/none", 0.0,
                     "run repro.launch.dryrun first"))
    return rows
