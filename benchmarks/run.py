# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness — one module per paper table/figure:

  bench_inference     Fig. 2 / Table 7 (inference accuracy vs time)
  bench_update        plan refresh vs rebuild on a dynamic graph (§10)
  bench_ooc           out-of-core build+serve under an RSS ceiling (§13)
  bench_training      Fig. 3 / Table 7 (per-epoch time, convergence)
  bench_label_rate    Fig. 4 (training-set size scaling)
  bench_batch_size    Fig. 5 (outputs-per-batch sensitivity)
  bench_ablation      Fig. 6 (partitioning ablation)
  bench_scheduling    Fig. 7 (batch scheduling)
  bench_grad_accum    Fig. 8 (gradient accumulation)
  bench_sensitivity   Table 5 (aux-selection hyperparameters)
  bench_memory        Table 6 (main-memory usage)
  bench_kernels       kernel micro-benches
  roofline            dry-run roofline table (reads results/dryrun)

Env: REPRO_BENCH_SCALE=small|paper, REPRO_BENCH_ONLY=<module substring>,
REPRO_BENCH_JSON=<path> (where the kernel rows land as machine-readable
JSON; default <repo>/BENCH_kernels.json) and REPRO_BENCH_INFERENCE_JSON
(inference rows incl. request-latency percentiles and the sustained-load
serve A/B; default <repo>/BENCH_inference.json) — the perf-trajectory
files CI populates on every run. REPRO_BENCH_INFERENCE_SECTION=serve is a
dev fast path that limits bench_inference to the serve-load rows;
REPRO_BENCH_INFERENCE_SECTION=faults limits it to the chaos-drill row the
CI chaos job gates with check_bench_json serve-faults (DESIGN.md §12).
"""
import json
import os
import sys
import time
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.ioutil import atomic_write_json  # noqa: E402  (needs src on path)

MODULES = [
    "bench_kernels",
    "bench_memory",
    "bench_inference",
    "bench_update",
    "bench_ooc",
    "bench_training",
    "bench_ablation",
    "bench_scheduling",
    "bench_grad_accum",
    "bench_batch_size",
    "bench_label_rate",
    "bench_sensitivity",
    "roofline",
]


def _parse_derived(derived: str) -> dict:
    out = {}
    for kv in derived.split(";"):
        if "=" not in kv:
            continue
        k, v = kv.split("=", 1)
        try:
            out[k] = float(v)
        except ValueError:
            out[k] = v
    return out


# modules whose rows land in a machine-readable perf-trajectory JSON:
# mod_name → (env var overriding the path, default filename). Several
# modules may share one file (bench_training's data-parallel rows ride in
# BENCH_kernels.json) — the writer merges by op name instead of clobbering.
_JSON_OUTPUTS = {
    "bench_kernels": ("REPRO_BENCH_JSON", "BENCH_kernels.json"),
    "bench_training": ("REPRO_BENCH_JSON", "BENCH_kernels.json"),
    "bench_inference": ("REPRO_BENCH_INFERENCE_JSON", "BENCH_inference.json"),
    "bench_update": ("REPRO_BENCH_UPDATE_JSON", "BENCH_update.json"),
    "bench_ooc": ("REPRO_BENCH_OOC_JSON", "BENCH_ooc.json"),
}


def _write_bench_json(mod_name, mod, rows) -> None:
    """Machine-readable perf-trajectory file: one record per row with
    (op, wall time + derived stats — backend/tile fill for kernels,
    request-latency percentiles for inference, devices for data-parallel
    rows). Prefers the module's full-precision JSON_RECORDS mirror; parsing
    the display string (%.4g) is only the fallback. Records REPLACE any
    existing record with the same op and leave the rest of the file alone,
    so modules sharing a file (and partial REPRO_BENCH_ONLY runs) never
    erase each other's trajectory."""
    env, default = _JSON_OUTPUTS[mod_name]
    path = os.environ.get(env) or os.path.join(
        os.path.dirname(__file__), "..", default)
    records = getattr(mod, "JSON_RECORDS", None)
    if not records:
        records = []
        for name, us, derived in rows:
            d = _parse_derived(derived)
            records.append({"op": name, "backend": d.pop("backend", None),
                            "us_per_call": us, **d})
    new_ops = {r.get("op") for r in records}
    kept = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                kept = [r for r in json.load(f) if r.get("op") not in new_ops]
        except (ValueError, OSError):
            kept = []
    # tmp + os.replace publish: a bench run killed mid-write must never
    # truncate the perf-trajectory file CI accumulates across runs.
    atomic_write_json(path, kept + records, indent=1)
    print(f"# wrote {os.path.abspath(path)} ({len(records)} new, "
          f"{len(kept)} kept records)", file=sys.stderr, flush=True)


def main() -> None:
    only = os.environ.get("REPRO_BENCH_ONLY", "")
    print("name,us_per_call,derived")
    for mod_name in MODULES:
        if only and only not in mod_name:
            continue
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
            rows = mod.run()
            for name, us, derived in rows:
                print(f"{name},{us:.1f},{derived}", flush=True)
            if mod_name in _JSON_OUTPUTS:
                _write_bench_json(mod_name, mod, rows)
        except Exception as e:
            traceback.print_exc(file=sys.stderr)
            print(f"{mod_name}/ERROR,0,{type(e).__name__}", flush=True)
        print(f"# {mod_name} done in {time.time()-t0:.1f}s", file=sys.stderr,
              flush=True)


if __name__ == "__main__":
    main()
