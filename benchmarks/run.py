# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness — one module per paper table/figure:

  bench_inference     Fig. 2 / Table 7 (inference accuracy vs time)
  bench_training      Fig. 3 / Table 7 (per-epoch time, convergence)
  bench_label_rate    Fig. 4 (training-set size scaling)
  bench_batch_size    Fig. 5 (outputs-per-batch sensitivity)
  bench_ablation      Fig. 6 (partitioning ablation)
  bench_scheduling    Fig. 7 (batch scheduling)
  bench_grad_accum    Fig. 8 (gradient accumulation)
  bench_sensitivity   Table 5 (aux-selection hyperparameters)
  bench_memory        Table 6 (main-memory usage)
  bench_kernels       kernel micro-benches
  roofline            dry-run roofline table (reads results/dryrun)

Env: REPRO_BENCH_SCALE=small|paper, REPRO_BENCH_ONLY=<module substring>.
"""
import os
import sys
import time
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

MODULES = [
    "bench_kernels",
    "bench_memory",
    "bench_inference",
    "bench_training",
    "bench_ablation",
    "bench_scheduling",
    "bench_grad_accum",
    "bench_batch_size",
    "bench_label_rate",
    "bench_sensitivity",
    "roofline",
]


def main() -> None:
    only = os.environ.get("REPRO_BENCH_ONLY", "")
    print("name,us_per_call,derived")
    for mod_name in MODULES:
        if only and only not in mod_name:
            continue
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
            rows = mod.run()
            for name, us, derived in rows:
                print(f"{name},{us:.1f},{derived}", flush=True)
        except Exception as e:
            traceback.print_exc(file=sys.stderr)
            print(f"{mod_name}/ERROR,0,{type(e).__name__}", flush=True)
        print(f"# {mod_name} done in {time.time()-t0:.1f}s", file=sys.stderr,
              flush=True)


if __name__ == "__main__":
    main()
