"""Shared benchmark plumbing.

Every bench returns rows `(name, us_per_call, derived)` — us_per_call is the
primary wall-time metric of the thing the paper times (epoch, inference pass,
preprocessing); derived carries accuracy/ratios as `k=v;k=v`.

Scale: REPRO_BENCH_SCALE=small (default, CPU-friendly: 'tiny'/'small'
synthetic graphs, 64-hidden GCN) or =paper (bigger synthetic stand-ins).
The point on this box is the TRENDS the paper claims, not absolute numbers —
see EXPERIMENTS.md for the mapping discussion.
"""
from __future__ import annotations

import os
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import IBMBPipeline, IBMBConfig
from repro.graph.datasets import get_dataset
from repro.graph.sampling import make_batcher
from repro.models.gnn import GNNConfig
from repro.train import GNNTrainer, as_host_batches

SCALE = os.environ.get("REPRO_BENCH_SCALE", "small")
DS_MAIN = "small" if SCALE == "small" else "arxiv-like"
DS_TINY = "tiny"
EPOCHS = 25 if SCALE == "small" else 120
HIDDEN = 64 if SCALE == "small" else 256

Row = Tuple[str, float, str]


def model_cfg(ds, hidden=None) -> GNNConfig:
    return GNNConfig(kind="gcn", in_dim=ds.feat_dim, hidden=hidden or HIDDEN,
                     out_dim=ds.num_classes, num_layers=3, dropout=0.3)


def ibmb_pipeline(ds, variant="node", **kw) -> IBMBPipeline:
    defaults = dict(k_per_output=8, max_outputs_per_batch=256, pad_multiple=64)
    defaults.update(kw)
    return IBMBPipeline(ds, IBMBConfig(variant=variant, **defaults))


def train_with(ds, train_batches, val_batches, epochs=None, schedule="tsp",
               grad_accum=1, seed=0, preprocess_time=0.0, mesh=None):
    cfg = model_cfg(ds)
    tr = GNNTrainer(cfg, lr=1e-3, seed=seed, grad_accum=grad_accum,
                    early_stop_patience=max(40, (epochs or EPOCHS)))
    return tr.fit(train_batches, val_batches, ds.num_classes,
                  epochs=epochs or EPOCHS, schedule_mode=schedule,
                  preprocess_time=preprocess_time, mesh=mesh), tr


def time_to_acc(history: List[Dict], target: float) -> Optional[float]:
    for h in history:
        if h["val_acc"] >= target:
            return h["time"]
    return None


def evaluate_batches(trainer: GNNTrainer, params, batches) -> Dict[str, float]:
    """Timed batch-eval pass. `batches` is anything `trainer.evaluate`
    accepts — a Plan (primary), BatchCache, or raw PaddedBatch list; host
    staging happens outside the timed region either way."""
    host = as_host_batches(batches)
    t0 = time.time()
    metrics = trainer.evaluate(params, host)
    metrics["time_s"] = time.time() - t0
    return metrics


def fmt(**kw) -> str:
    return ";".join(f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
                    for k, v in kw.items())
