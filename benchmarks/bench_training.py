"""Fig. 3 / Table 7 (training): preprocessing time, time per epoch, final
val accuracy, and time-to-target per method — the paper's core training
comparison. Plus the 1-vs-N-device data-parallel rows (DESIGN.md §9):
`GNNTrainer.fit(mesh=...)` super-step execution over however many devices
the process sees (the CI multidevice job fakes 8 with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``); the DP records
land in ``BENCH_kernels.json`` via ``run.py``'s merge-by-op writer."""
from __future__ import annotations

import time
from typing import List

from benchmarks.common import (
    DS_MAIN, EPOCHS, Row, fmt, ibmb_pipeline, time_to_acc, train_with)
from repro.graph.datasets import get_dataset
from repro.graph.sampling import make_batcher

JSON_RECORDS: List[dict] = []

DP_EPOCHS = 10


def _dp_rows(ds, pipe, pipe_val) -> List[Row]:
    """Data-parallel A/B: identical Plan + seed trained on a 1-device mesh
    vs a mesh over every visible device. With 1 device only the 1dev row is
    emitted (the A/B needs emulated devices, see module docstring).

    `pipe`/`pipe_val` are run()'s pipelines — their PPR caches already hold
    the train/val pushes, so building the Plans here costs batch assembly
    only, not a third full preprocessing pass."""
    import jax
    from repro.dist.data_parallel import data_mesh, mesh_world

    tr = pipe.plan("train")
    va = pipe_val.plan("val", for_inference=True)
    rows: List[Row] = []
    worlds = [1] + ([jax.device_count()] if jax.device_count() > 1 else [])
    for n in worlds:
        mesh = data_mesh(n)
        res, _ = train_with(ds, tr, va, epochs=DP_EPOCHS, mesh=mesh)
        us = res.time_per_epoch * 1e6
        derived = dict(devices=mesh_world(mesh),
                       supersteps_per_epoch=-(-len(tr) // n),
                       batches=len(tr), epochs=DP_EPOCHS,
                       final_val_acc=res.best_val_acc)
        JSON_RECORDS.append({"op": f"training/dp_{n}dev",
                             "us_per_call": float(us), **derived})
        rows.append((f"training/dp_{n}dev", us, fmt(**derived)))
    return rows


def run() -> List[Row]:
    JSON_RECORDS.clear()
    ds = get_dataset(DS_MAIN)
    rows: List[Row] = []
    # validation batches shared (node-wise IBMB inference, the paper's choice)
    pipe_val = ibmb_pipeline(ds, "node")
    va_b = pipe_val.preprocess("val", for_inference=True)
    target = 0.75

    def add(name, train_src, prep_s):
        res, _ = train_with(ds, train_src, va_b, preprocess_time=prep_s)
        t_target = time_to_acc(res.history, target)
        rows.append((f"training/{name}", res.time_per_epoch * 1e6,
                     fmt(final_val_acc=res.best_val_acc,
                         preprocess_s=prep_s,
                         time_to_target_s=(t_target if t_target is not None
                                           else float("nan")),
                         epochs=len(res.history))))

    t0 = time.time()
    pipe = ibmb_pipeline(ds, "node")
    tr = pipe.preprocess("train")
    add("ibmb_node", tr, time.time() - t0)

    t0 = time.time()
    pipe_b = ibmb_pipeline(ds, "batch", num_batches=8)
    add("ibmb_batch", pipe_b.preprocess("train"), time.time() - t0)

    for name, kw in [("cluster_gcn", {"num_batches": 8}),
                     ("neighbor_sampling", {"num_batches": 8}),
                     ("graphsaint_rw", {"num_steps": 8, "batch_roots": 400})]:
        t0 = time.time()
        bt = make_batcher(name, ds, **kw)
        prep = time.time() - t0
        add(name, bt if not bt.fixed else bt.epoch_batches(0), prep)

    rows += _dp_rows(ds, pipe, pipe_val)
    return rows
