"""Fig. 3 / Table 7 (training): preprocessing time, time per epoch, final
val accuracy, and time-to-target per method — the paper's core training
comparison."""
from __future__ import annotations

import time
from typing import List

from benchmarks.common import (
    DS_MAIN, EPOCHS, Row, fmt, ibmb_pipeline, time_to_acc, train_with)
from repro.graph.datasets import get_dataset
from repro.graph.sampling import make_batcher


def run() -> List[Row]:
    ds = get_dataset(DS_MAIN)
    rows: List[Row] = []
    # validation batches shared (node-wise IBMB inference, the paper's choice)
    pipe_val = ibmb_pipeline(ds, "node")
    va_b = pipe_val.preprocess("val", for_inference=True)
    target = 0.75

    def add(name, train_src, prep_s):
        res, _ = train_with(ds, train_src, va_b, preprocess_time=prep_s)
        t_target = time_to_acc(res.history, target)
        rows.append((f"training/{name}", res.time_per_epoch * 1e6,
                     fmt(final_val_acc=res.best_val_acc,
                         preprocess_s=prep_s,
                         time_to_target_s=(t_target if t_target is not None
                                           else float("nan")),
                         epochs=len(res.history))))

    t0 = time.time()
    pipe = ibmb_pipeline(ds, "node")
    tr = pipe.preprocess("train")
    add("ibmb_node", tr, time.time() - t0)

    t0 = time.time()
    pipe_b = ibmb_pipeline(ds, "batch", num_batches=8)
    add("ibmb_batch", pipe_b.preprocess("train"), time.time() - t0)

    for name, kw in [("cluster_gcn", {"num_batches": 8}),
                     ("neighbor_sampling", {"num_batches": 8}),
                     ("graphsaint_rw", {"num_steps": 8, "batch_roots": 400})]:
        t0 = time.time()
        bt = make_batcher(name, ds, **kw)
        prep = time.time() - t0
        add(name, bt if not bt.fixed else bt.epoch_batches(0), prep)
    return rows
