"""Out-of-core plans under an ENFORCED RSS ceiling (DESIGN.md §13).

The claim being measured: a plan whose batch payload exceeds a hard heap
budget can still be built (streaming, chunk-resident) and served
(mmap-backed lazy cache, bounded resident-batch budget) with logits
bitwise identical to the resident engine — and the budget is not a
gentleman's agreement: the serving child runs under
``resource.setrlimit(RLIMIT_DATA, baseline + budget)``, so blowing it is a
MemoryError, not a footnote. (RLIMIT_DATA, not RLIMIT_AS: since Linux 4.7
it caps brk + private anonymous mappings — the heap the resident payload
would live on — while file-backed mmap, the whole point of the store, is
free.)

Rows (→ BENCH_ooc.json, gated by ``check_bench_json --mode ooc``):

  ooc/preprocess_resident   resident build wall time + payload size
  ooc/preprocess_stream     streamed build wall time; fingerprint equality
  ooc/serve_resident        subprocess, NO ceiling: heap growth ≈ payload,
                            p50/p99 request latency, logits hash
  ooc/serve_ooc             subprocess, ceiling ENFORCED: heap growth under
                            budget while payload_mb > budget; p50/p99;
                            logits hash equal to resident (bitwise)
  ooc/serve_shards          in-process shard router: queries span >= 2
                            shards, merged logits bitwise equal resident
  ooc/serve_batch_io_faults scripted ``batch_io`` faults during serving:
                            every injected fault absorbed by bounded
                            retry, zero failed requests

Both serve children replay the SAME seeded request trace with the SAME
seeded params, so sha256(logits) equality is exactly bitwise equality.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import subprocess
import sys
import tempfile
import time
from typing import List

import numpy as np

from benchmarks.common import Row, SCALE, fmt
from repro.graph.csr import gcn_preprocess
from repro.graph.datasets import GraphDataset
from repro.graph.synthetic import SyntheticSpec, make_sbm_dataset
from repro.core import IBMBPipeline, IBMBConfig

JSON_RECORDS: List[dict] = []

# bigger than DS_MAIN on purpose: the payload must dwarf the heap budget,
# and features are the payload driver (cache_features=True stores each
# batch's feature rows padded — the thing a resident cache cannot afford)
_SPEC = (SyntheticSpec("ooc-bench", 24_000, 16, 8.0, 256, 0.88,
                       0.35, 0.05, 0.30, seed=11)
         if SCALE == "small" else
         SyntheticSpec("ooc-bench", 80_000, 32, 10.0, 384, 0.88,
                       0.35, 0.05, 0.30, seed=11))
_PIPE_KW = dict(variant="node", k_per_output=8, max_outputs_per_batch=256,
                pad_multiple=64, schedule="none", backend="segment")
_NUM_REQUESTS = 200
_REQUEST_SIZE = 32
_NUM_SHARDS = 3
_RESIDENT_BATCHES = 4


def _record(name: str, us: float, **derived) -> Row:
    JSON_RECORDS.append({"op": name, "us_per_call": float(us), **derived})
    return (name, us, fmt(**derived))


def _dataset() -> GraphDataset:
    g, feats, labels, splits = make_sbm_dataset(_SPEC)
    return GraphDataset(_SPEC.name, g, gcn_preprocess(g), feats, labels,
                        splits)


def _model_cfg_dict(ds) -> dict:
    return dict(kind="gcn", in_dim=int(ds.feat_dim), hidden=64,
                out_dim=int(ds.num_classes), num_layers=2,
                backend="segment")


def _request_trace(ds, seed: int = 0) -> np.ndarray:
    """(R, q) request trace over the train outputs — the same seeded trace
    in parent and both children."""
    rng = np.random.default_rng(seed)
    outs = np.asarray(ds.splits["train"], np.int64)
    return rng.choice(outs, size=(_NUM_REQUESTS, _REQUEST_SIZE))


def _spawn_child(payload: dict) -> dict:
    env = dict(os.environ, JAX_PLATFORMS="cpu",
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(os.path.dirname(__file__), "..", "src"),
                    os.environ.get("PYTHONPATH", "")]))
    proc = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--child",
         json.dumps(payload)],
        capture_output=True, text=True, env=env, timeout=1800)
    for line in proc.stdout.splitlines():
        if line.startswith("OOC_CHILD_RESULT:"):
            return json.loads(line[len("OOC_CHILD_RESULT:"):])
    raise RuntimeError(
        f"serve child ({payload['role']}) died rc={proc.returncode}\n"
        f"stdout: {proc.stdout[-2000:]}\nstderr: {proc.stderr[-2000:]}")


def run() -> List[Row]:
    from repro.ooc import OOCConfig, PlanStore, build_shards, ShardRouter
    from repro.serve import GNNInferenceEngine
    from repro.faults import FaultInjector
    from repro.models.gnn import GNNConfig, init_gnn
    import jax

    rows: List[Row] = []
    ds = _dataset()
    tmp = tempfile.mkdtemp(prefix="bench_ooc_")
    trace = _request_trace(ds)

    # -- preprocess A/B --------------------------------------------------
    pipe = IBMBPipeline(ds, IBMBConfig(**_PIPE_KW))
    t0 = time.perf_counter()
    resident = pipe.plan("train")
    res_us = (time.perf_counter() - t0) * 1e6
    payload_mb = resident.cache.nbytes() / 2**20
    rows.append(_record("ooc/preprocess_resident", res_us,
                        payload_mb=payload_mb,
                        num_batches=len(resident.cache)))

    store_dir = os.path.join(tmp, "store")
    t0 = time.perf_counter()
    ooc_plan = IBMBPipeline(ds, IBMBConfig(**_PIPE_KW)).plan(
        "train", out_of_core=True, store_dir=store_dir,
        ooc=OOCConfig(chunk_batches=2, resident_batches=_RESIDENT_BATCHES))
    stream_us = (time.perf_counter() - t0) * 1e6
    rows.append(_record(
        "ooc/preprocess_stream", stream_us, payload_mb=payload_mb,
        fingerprint_equal=int(ooc_plan.fingerprint == resident.fingerprint),
        stream_vs_resident=stream_us / max(res_us, 1.0)))

    # the ceiling the ooc child must fit under — well below the payload
    budget_mb = max(32, int(payload_mb / 3))
    assert payload_mb > budget_mb, (payload_mb, budget_mb)

    plan_npz = os.path.join(tmp, "resident_plan.npz")
    resident.save(plan_npz)
    del resident, ooc_plan   # children pay their own materialization

    # -- serve A/B under the harness -------------------------------------
    common = dict(model=_model_cfg_dict(ds), trace=trace.tolist(),
                  resident_batches=_RESIDENT_BATCHES)
    res_child = _spawn_child(dict(common, role="resident",
                                  plan_npz=plan_npz))
    rows.append(_record("ooc/serve_resident", res_child["p50_us"],
                        p99_us=res_child["p99_us"],
                        load_growth_mb=res_child["load_growth_mb"],
                        serve_growth_mb=res_child["serve_growth_mb"],
                        data_growth_mb=res_child["data_growth_mb"],
                        payload_mb=payload_mb, enforced=0))
    ooc_child = _spawn_child(dict(common, role="ooc", store_dir=store_dir,
                                  budget_mb=budget_mb))
    rows.append(_record(
        "ooc/serve_ooc", ooc_child["p50_us"], p99_us=ooc_child["p99_us"],
        load_growth_mb=ooc_child["load_growth_mb"],
        serve_growth_mb=ooc_child["serve_growth_mb"],
        data_growth_mb=ooc_child["data_growth_mb"], payload_mb=payload_mb,
        rss_budget_mb=budget_mb, enforced=1,
        p50_vs_resident=ooc_child["p50_us"] / max(res_child["p50_us"], 1.0),
        logits_equal_resident=int(
            ooc_child["logits_sha"] == res_child["logits_sha"])))

    # -- sharded routing --------------------------------------------------
    mcfg = GNNConfig(**_model_cfg_dict(ds))
    params = init_gnn(mcfg, jax.random.PRNGKey(0))
    root = os.path.join(tmp, "shards")
    build_shards(pipe, "train", _NUM_SHARDS, root,
                 ooc=OOCConfig(chunk_batches=2))
    router = ShardRouter.load(root, mcfg, params,
                              resident_batches=_RESIDENT_BATCHES)
    h = hashlib.sha256()
    lat = []
    hit_min = _NUM_SHARDS + 1
    for req in trace:
        t0 = time.perf_counter()
        out = router.query(req)
        lat.append((time.perf_counter() - t0) * 1e6)
        h.update(np.ascontiguousarray(out).tobytes())
        hit_min = min(hit_min, router.shards_hit(req))
    rows.append(_record(
        "ooc/serve_shards", float(np.percentile(lat, 50)),
        p99_us=float(np.percentile(lat, 99)),
        num_shards=_NUM_SHARDS, shards_hit=router.shards_hit(trace.ravel()),
        shards_hit_min=hit_min,
        logits_equal_resident=int(h.hexdigest()
                                  == res_child["logits_sha"])))

    # -- fault drill: scripted transient read faults ----------------------
    faults = FaultInjector(seed=3, script={"batch_io": [0, 7, 19]})
    store = PlanStore.open(store_dir, faults=faults, io_retries=2)
    engine = GNNInferenceEngine(
        store.as_plan(resident_batches=_RESIDENT_BATCHES), mcfg, params)
    errors = 0
    flat = []
    for req in trace:
        t0 = time.perf_counter()
        try:
            engine.query(req)
        except Exception:
            errors += 1
        flat.append((time.perf_counter() - t0) * 1e6)
    snap = store.stats.snapshot()
    rows.append(_record(
        "ooc/serve_batch_io_faults", float(np.percentile(flat, 50)),
        injected=faults.fired.get("batch_io", 0),
        retries=snap["io_retries"], errors=errors,
        requests=len(trace), reads=snap["reads"]))
    return rows


# --------------------------------------------------------------- the child
def _child(payload: dict) -> None:
    """Serve the request trace in THIS process; for role=ooc, first pin the
    heap: RLIMIT_DATA soft limit = current VmData + budget. Baselines are
    taken after model init + forward warmup, so the ceiling binds exactly
    on what serving allocates — the batch payload."""
    import resource

    import jax
    from repro.core import Plan
    from repro.models.gnn import GNNConfig, init_gnn
    from repro.serve import GNNInferenceEngine

    def vm_mb(key: str) -> float:
        with open("/proc/self/status") as f:
            for line in f:
                if line.startswith(key + ":"):
                    return int(line.split()[1]) / 1024.0
        return 0.0

    mcfg = GNNConfig(**payload["model"])
    params = init_gnn(mcfg, jax.random.PRNGKey(0))
    trace = np.asarray(payload["trace"], np.int64)
    base_mb = vm_mb("VmData")                    # pre-plan heap watermark

    if payload["role"] == "resident":
        plan = Plan.load(payload["plan_npz"])
    else:
        from repro.ooc import PlanStore
        plan = PlanStore.open(payload["store_dir"]).as_plan(
            resident_batches=payload["resident_batches"])
    load_mb = vm_mb("VmData") - base_mb          # resident: ≈ payload

    engine = GNNInferenceEngine(plan, mcfg, params)
    engine.query(trace[0])                       # compile + first fault-in
    warm_mb = vm_mb("VmData")
    if payload["role"] == "ooc":
        # pin the ceiling ON SERVING: compile/warmup allocations are done,
        # so every further heap byte is batch payload or LRU traffic —
        # exactly what the resident-batch budget claims to bound
        limit = int((warm_mb + payload["budget_mb"]) * 2**20)
        resource.setrlimit(resource.RLIMIT_DATA,
                           (limit, resource.getrlimit(
                               resource.RLIMIT_DATA)[1]))

    h = hashlib.sha256()
    lat = []
    for req in trace:
        t0 = time.perf_counter()
        out = engine.query(req)
        lat.append((time.perf_counter() - t0) * 1e6)
        h.update(np.ascontiguousarray(out).tobytes())
    print("OOC_CHILD_RESULT:" + json.dumps(dict(
        p50_us=float(np.percentile(lat, 50)),
        p99_us=float(np.percentile(lat, 99)),
        load_growth_mb=max(0.0, load_mb),
        serve_growth_mb=max(0.0, vm_mb("VmData") - warm_mb),
        data_growth_mb=max(0.0, vm_mb("VmData") - base_mb),
        rss_mb=vm_mb("VmRSS"), logits_sha=h.hexdigest())))


if __name__ == "__main__":
    if len(sys.argv) >= 3 and sys.argv[1] == "--child":
        sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                        "src"))
        _child(json.loads(sys.argv[2]))
    else:
        for r in run():
            print(",".join(map(str, r)))
